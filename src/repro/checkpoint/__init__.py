from repro.checkpoint.checkpointer import (
    Checkpointer,
    latest_step,
    restore,
    restore_elastic_chains,
    save,
)

__all__ = [
    "Checkpointer",
    "save",
    "restore",
    "restore_elastic_chains",
    "latest_step",
]
