"""Sharded, atomic, elastic checkpointing (no external deps).

Layout (one directory per step)::

    <root>/step_000100/
        MANIFEST.json        # treedef, per-leaf shape/dtype/file, metadata
        host_00000/
            leaf_00000.npy   # one .npy per leaf owned by this host
            ...
    <root>/step_000100.tmp/  # staging dir; atomic os.replace on commit

Multi-host discipline (the part that matters at 1000+ nodes):
- every host writes ONLY its addressable shard bytes under ``host_<id>/``
  (here: process 0 owns everything — the layout is already per-host so a real
  multi-controller run changes the writer set, not the format);
- host 0 writes the manifest LAST, after all data files exist — a manifest's
  presence is the commit record; readers ignore step dirs without one;
- ``os.replace`` of the staging dir makes the commit atomic on POSIX — a
  crash mid-write leaves only ``.tmp`` litter that the next writer clears.

Async: ``Checkpointer(async_io=True)`` moves serialization+IO to a worker
thread; training only blocks on the previous write when a new one starts
(double-buffering, the standard overlap trick).

Elastic EP-MCMC restore (:func:`restore_elastic_chains`): chain-stacked state
``(C_old, ...)`` re-partitioned to ``C_new`` chains. Shrink keeps the first
``C_new`` chains (their subposterior targets change only through the prior
exponent 1/M, which is a step-function argument, not state); grow tiles
existing chains with fresh RNG folds. Retained streaming moments stay valid
for the chains that survive — the paper's footnote-1 ragged-T property is
what makes elasticity sound.
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _step_dir(root: pathlib.Path, step: int) -> pathlib.Path:
    return root / f"step_{step:09d}"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(
    root: str | os.PathLike,
    step: int,
    tree: PyTree,
    *,
    metadata: Optional[Dict[str, Any]] = None,
    host_id: int = 0,
    keep: int = 3,
) -> pathlib.Path:
    """Write one checkpoint synchronously; returns the committed directory."""
    root = pathlib.Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = _step_dir(root, step)
    tmp = final.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)  # crash litter from a previous writer
    host_dir = tmp / f"host_{host_id:05d}"
    host_dir.mkdir(parents=True)

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves: List[Dict[str, Any]] = []
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(host_dir / fname, arr)
        leaves.append(
            {
                "index": i,
                "path": _path_str(path),
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "file": f"host_{host_id:05d}/{fname}",
            }
        )
    try:  # best-effort structural fingerprint (NamedTuple nodes don't proto-serialize)
        treedef_hex = jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
    except Exception:
        treedef_hex = None
    manifest = {
        "step": step,
        "format": 1,
        "num_hosts": 1,
        "treedef": treedef_hex,
        "leaves": leaves,
        "metadata": metadata or {},
    }
    # manifest last = commit record
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    _apply_retention(root, keep)
    return final


def _apply_retention(root: pathlib.Path, keep: int) -> None:
    steps = sorted(
        int(m.group(1)) for p in root.iterdir() if (m := _STEP_RE.match(p.name))
    )
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)


def latest_step(root: str | os.PathLike) -> Optional[int]:
    root = pathlib.Path(root)
    if not root.exists():
        return None
    steps = [
        int(m.group(1))
        for p in root.iterdir()
        if (m := _STEP_RE.match(p.name)) and (p / "MANIFEST.json").exists()
    ]
    return max(steps) if steps else None


def restore(
    root: str | os.PathLike,
    *,
    step: Optional[int] = None,
    template: Optional[PyTree] = None,
) -> Tuple[PyTree, Dict[str, Any]]:
    """Load a checkpoint. With ``template``, leaves are matched by tree order
    and cast/reshaped onto the template's structure (the normal jit-restart
    path); without, returns (leaves-by-path dict, metadata)."""
    root = pathlib.Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {root}")
    d = _step_dir(root, step)
    manifest = json.loads((d / "MANIFEST.json").read_text())
    arrays = [np.load(d / leaf["file"]) for leaf in manifest["leaves"]]
    if template is not None:
        flat, treedef = jax.tree_util.tree_flatten(template)
        if len(flat) != len(arrays):
            raise ValueError(
                f"checkpoint has {len(arrays)} leaves, template {len(flat)}"
            )
        cast = [
            jnp.asarray(a, dtype=t.dtype) if hasattr(t, "dtype") else jnp.asarray(a)
            for a, t in zip(arrays, flat)
        ]
        return jax.tree_util.tree_unflatten(treedef, cast), manifest["metadata"]
    by_path = {leaf["path"]: arr for leaf, arr in zip(manifest["leaves"], arrays)}
    return by_path, manifest["metadata"]


def restore_elastic_chains(
    root: str | os.PathLike,
    template: PyTree,
    new_num_chains: int,
    *,
    step: Optional[int] = None,
    chain_axis: int = 0,
    rng_bump: int = 104729,
) -> Tuple[PyTree, Dict[str, Any]]:
    """Restore chain-stacked EP-MCMC state onto a different chain count.

    Every leaf whose dim-``chain_axis`` equals the checkpointed chain count is
    re-partitioned: shrink → slice, grow → wrap-around tile. Scalar/other
    leaves pass through. The caller owns re-partitioning the *data* (pure
    function of shard index) and using the new 1/M in the step function.
    """
    tree, meta = restore(root, step=step, template=None)
    old_c = meta.get("num_chains")
    if old_c is None:
        raise ValueError("checkpoint metadata lacks 'num_chains'")
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    by_path = tree  # path -> np.ndarray
    out = []
    for path, t_leaf in flat_t:
        key = _path_str(path)
        if key not in by_path:
            raise KeyError(f"leaf {key!r} missing from checkpoint")
        arr = by_path[key]
        if arr.ndim > chain_axis and arr.shape[chain_axis] == old_c != new_num_chains:
            if new_num_chains < old_c:
                arr = np.take(arr, np.arange(new_num_chains), axis=chain_axis)
            else:
                idx = np.arange(new_num_chains) % old_c
                arr = np.take(arr, idx, axis=chain_axis)
                if "key" in key.split("/")[-1]:  # de-duplicate RNG streams
                    bump = (np.arange(new_num_chains) // old_c).astype(arr.dtype)
                    arr = arr + (bump * rng_bump)[(...,) + (None,) * (arr.ndim - 1)].swapaxes(0, chain_axis)
        out.append(jnp.asarray(arr, dtype=getattr(t_leaf, "dtype", None)))
    meta = dict(meta, num_chains=new_num_chains, elastic_from=old_c)
    return jax.tree_util.tree_unflatten(treedef, out), meta


class Checkpointer:
    """Double-buffered async wrapper around :func:`save`."""

    def __init__(self, root: str | os.PathLike, *, keep: int = 3, async_io: bool = True):
        self.root = pathlib.Path(root)
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1) if async_io else None
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()

    def save(self, step: int, tree: PyTree, *, metadata=None) -> None:
        # materialize on host NOW (donated/mutating buffers must not race IO)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self._pool is None:
            save(self.root, step, host_tree, metadata=metadata, keep=self.keep)
            return
        with self._lock:
            if self._pending is not None:
                self._pending.result()  # block on the previous write only
            self._pending = self._pool.submit(
                save, self.root, step, host_tree, metadata=metadata, keep=self.keep
            )

    def wait(self) -> None:
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None

    def close(self) -> None:
        self.wait()
        if self._pool is not None:
            self._pool.shutdown(wait=True)
