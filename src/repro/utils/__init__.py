"""Shared small utilities: pytree parameter flattening, RNG fan-out, stats."""

from repro.utils.pytree import (  # noqa: F401
    ravel_pytree_batched,
    tree_size,
    tree_bytes,
)
