"""Pytree helpers used across the framework.

The combination algorithms (repro.core.combine) operate on flat sample arrays
``(M, T, d)``.  Model parameters are pytrees; these helpers bridge the two.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_size(tree: PyTree) -> int:
    """Total number of scalar elements in a pytree of arrays."""
    return int(sum(np.prod(l.shape, dtype=np.int64) for l in jax.tree.leaves(tree)))


def tree_bytes(tree: PyTree) -> int:
    """Total number of bytes in a pytree of arrays."""
    return int(
        sum(
            np.prod(l.shape, dtype=np.int64) * jnp.dtype(l.dtype).itemsize
            for l in jax.tree.leaves(tree)
        )
    )


def ravel_pytree_batched(tree: PyTree) -> Tuple[jnp.ndarray, Callable[[jnp.ndarray], PyTree]]:
    """Ravel a pytree whose leaves share leading batch dims ``(...B,)`` into a
    ``(...B, d)`` matrix, returning an unravel closure.

    Unlike ``jax.flatten_util.ravel_pytree`` this keeps the batch dimensions —
    used to turn per-chain sample pytrees ``(M, T, *leaf_shape)`` into the
    ``(M, T, d)`` layout the combiners expect.
    """
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("empty pytree")
    # The number of leading batch dims is inferred as the longest shared prefix.
    shapes = [l.shape for l in leaves]
    nbatch = 0
    while all(len(s) > nbatch for s in shapes) and len({s[: nbatch + 1] for s in shapes}) == 1:
        nbatch += 1
    # Allow a trailing event dim of size prod(shape[nbatch:]) per leaf.
    event_sizes = [int(np.prod(s[nbatch:], dtype=np.int64)) for s in shapes]
    event_shapes = [s[nbatch:] for s in shapes]
    batch_shape = shapes[0][:nbatch]
    flat = jnp.concatenate(
        [l.reshape(batch_shape + (es,)) for l, es in zip(leaves, event_sizes)], axis=-1
    )
    offsets = np.cumsum([0] + event_sizes)
    dtypes = [l.dtype for l in leaves]

    def unravel(vec: jnp.ndarray) -> PyTree:
        parts = [
            vec[..., offsets[i] : offsets[i + 1]].reshape(vec.shape[:-1] + event_shapes[i]).astype(dtypes[i])
            for i in range(len(leaves))
        ]
        return jax.tree.unflatten(treedef, parts)

    return flat, unravel
