"""Shared option-forwarding helper for the name registries.

Both registries (``repro.core.combiners``, ``repro.samplers``) let callers
broadcast ONE option dict over many implementations; each implementation must
only see the options its signature declares. The convention, shared verbatim:

- ``**options`` (no underscore) in a signature marks a *passthrough* wrapper
  that forwards to an inner implementation — it receives the full dict;
- ``**_ignored`` marks tolerated-but-unused keywords — unknown keys are
  dropped here rather than silently swallowed there.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable, Dict


def filter_kwargs(fn: Callable, options: Dict[str, Any]) -> Dict[str, Any]:
    """Keep only the keyword-only options ``fn``'s signature declares."""
    params = inspect.signature(fn).parameters.values()
    passthrough = any(
        p.kind is inspect.Parameter.VAR_KEYWORD and not p.name.startswith("_")
        for p in params
    )
    if passthrough:
        return dict(options)
    known = {p.name for p in params if p.kind is inspect.Parameter.KEYWORD_ONLY}
    return {k: v for k, v in options.items() if k in known}
