"""repro — production-grade JAX framework for Asymptotically Exact,
Embarrassingly Parallel MCMC (Neiswanger, Wang & Xing, 2013).

Layers
------
- ``repro.core``        the paper's contribution: subposteriors + combination
- ``repro.samplers``    any-MCMC substrate (RWMH/MALA/HMC/NUTS/Gibbs/SGLD)
- ``repro.models``      Bayesian experiment models + assigned LM architecture zoo
- ``repro.api``         experiment layer: RunSpec / Pipeline / run_matrix
- ``repro.distributed`` shard_map EP-MCMC runtime, sharding policies
- ``repro.kernels``     Pallas TPU kernels for the numeric hot spots
- ``repro.launch``      mesh / dryrun / train / serve / mcmc_run entry points
"""

__version__ = "1.0.0"
