"""Distributed runtime: sharding policies, EP-MCMC shard_map chains."""

from repro.distributed import sharding as sharding  # noqa: F401
