"""Sharding policy: pytree path → PartitionSpec for every arch and step kind.

Axes: ``data`` (+ ``pod`` multi-pod) = batch / FSDP / EP-MCMC chains;
``model`` = tensor parallel (heads / d_ff / experts / vocab).

Rules (see DESIGN.md §5):

- embed (V, d)            → (model, fsdp?)         vocab-parallel
- lm_head (d, V)          → (fsdp?, model)
- attn w_q/w_k/w_v (d, o) → (fsdp?, model)         o = flat heads·head_dim
- attn w_o (o, d)         → (model, fsdp?)
- MLA down-projections    → (fsdp?, None);  up-projections → (None, model)
- mlp w_gate/w_up (d, f)  → (fsdp?, model);  w_down (f, d) → (model, fsdp?)
- MoE experts (E, d, f)   → (model, fsdp?, None)   expert-parallel on model
- Mamba w_z/w_x (d, di)   → (fsdp?, model) iff per-shard heads stay whole,
                             else (fsdp?, None);   w_B/w_C/w_dt replicated
- norms / scalars         → replicated
- optimizer state         → same spec as its parameter (ZeRO follows FSDP)

``fsdp?`` = the 'data' axis when cfg.fsdp and the dim divides, else None.
Multi-pod: FSDP stays *intra-pod* ('data' only — weight all-gathers never
cross the pod axis; only gradient reductions do), batch shards over
('pod','data').

Divisibility is always checked; a rule that does not divide falls back to
replication on that dim (never a compile error). Non-divisible *head* counts
(qwen 20H, ds-coder 56H, llama 24H, whisper 8H vs model=16) still shard their
flat projection dim when divisible — GSPMD then chooses collectives at the
(B,S,H,hd) reshape; the roofline table quantifies that cost per arch
(§Perf discusses the fix).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.lm.config import ModelConfig

PyTree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def _div(n: int, mesh: Mesh, axis: Optional[str | Tuple[str, ...]]) -> bool:
    if axis is None:
        return True
    axes = (axis,) if isinstance(axis, str) else axis
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return n % size == 0


def _spec(mesh: Mesh, shape, *axes) -> P:
    """Build a PartitionSpec, dropping any axis that does not divide."""
    cleaned = []
    for dim, ax in zip(shape, axes):
        cleaned.append(ax if ax is not None and _div(dim, mesh, ax) else None)
    return P(*cleaned)


def param_spec(
    cfg: ModelConfig, mesh: Mesh, path: str, leaf: jax.ShapeDtypeStruct
) -> P:
    shape = leaf.shape
    rank = len(shape)
    fsdp = "data" if cfg.fsdp else None
    m = "model"

    def lead(n):  # None for leading stack dims (layer scan, vmapped periods)
        return (None,) * n

    # ---- embeddings / head -------------------------------------------------
    if path.endswith("embed"):
        return _spec(mesh, shape, m, fsdp)
    if path.endswith("lm_head"):
        return _spec(mesh, shape, fsdp, m)
    if path.endswith("img_proj"):
        return _spec(mesh, shape, None, m)

    # ---- MoE ---------------------------------------------------------------
    if "/moe/" in path or path.startswith("moe/"):
        if "router" in path:
            return P(*lead(rank))
        if "experts" in path:
            # (..., E, a, b): experts over model; FSDP over the larger matrix dim
            if path.endswith("w_down"):
                return _spec(mesh, shape, *lead(rank - 3), m, None, fsdp)
            return _spec(mesh, shape, *lead(rank - 3), m, fsdp, None)
        if "shared" in path:
            if path.endswith("w_down"):
                return _spec(mesh, shape, *lead(rank - 2), m, fsdp)
            return _spec(mesh, shape, *lead(rank - 2), fsdp, m)
        return P(*lead(rank))

    # ---- Mamba -------------------------------------------------------------
    if "/mamba/" in path or path.startswith("mamba/"):
        di = cfg.ssm.expand * cfg.d_model
        heads_ok = (
            di % mesh.shape[m] == 0 and (di // mesh.shape[m]) % cfg.ssm.head_dim == 0
        )
        inner = m if heads_ok else None
        if path.endswith(("w_z", "w_x")):
            return _spec(mesh, shape, *lead(rank - 2), fsdp, inner)
        if path.endswith("w_out"):
            return _spec(mesh, shape, *lead(rank - 2), inner, fsdp)
        if path.endswith(("conv_x", "conv_bias_x", "norm")):
            return _spec(mesh, shape, *lead(rank - 1), inner)
        if path.endswith(("w_B", "w_C", "w_dt")):
            return _spec(mesh, shape, *lead(rank - 2), fsdp, None)
        if path.endswith(("A_log", "dt_bias", "D")) and heads_ok:
            return _spec(mesh, shape, *lead(rank - 1), m)
        return P(*lead(rank))

    # ---- attention (GQA / MLA / cross) --------------------------------------
    if any(s in path for s in ("/attn/", "/cross/")):
        if path.endswith(("w_q/w", "w_k/w", "w_v/w")):
            return _spec(mesh, shape, *lead(rank - 2), fsdp, m)
        if path.endswith(("w_q/b", "w_k/b", "w_v/b")):
            return _spec(mesh, shape, *lead(rank - 1), m)
        if path.endswith("w_o/w"):
            return _spec(mesh, shape, *lead(rank - 2), m, fsdp)
        # MLA
        if path.endswith(("w_dq", "w_dkv")):
            return _spec(mesh, shape, *lead(rank - 2), fsdp, None)
        if path.endswith(("w_uq", "w_uk", "w_uv")):
            return _spec(mesh, shape, *lead(rank - 2), None, m)
        if path.endswith("w_o"):
            return _spec(mesh, shape, *lead(rank - 2), m, fsdp)
        return P(*lead(rank))

    # ---- dense MLP ----------------------------------------------------------
    if "/mlp/" in path or path.startswith("mlp/"):
        if path.endswith("w_down"):
            return _spec(mesh, shape, *lead(rank - 2), m, fsdp)
        return _spec(mesh, shape, *lead(rank - 2), fsdp, m)

    # norms, scalars, everything else: replicated
    return P(*lead(rank))


def param_specs(cfg: ModelConfig, mesh: Mesh, params: PyTree) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [param_spec(cfg, mesh, _path_str(path), leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_specs(cfg: ModelConfig, mesh: Mesh, opt_state: PyTree, pspecs: PyTree) -> PyTree:
    """AdamW state: mu/nu mirror their parameter's spec; count replicated."""
    return type(opt_state)(mu=pspecs, nu=pspecs, count=P())


# ---------------------------------------------------------------------------
# batch / cache / activation specs
# ---------------------------------------------------------------------------


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: PyTree) -> PyTree:
    dp = batch_axes(mesh)

    def spec(path, leaf):
        b = leaf.shape[0]
        lead = dp if _div(b, mesh, dp) else None
        return P(lead, *([None] * (len(leaf.shape) - 1)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(_path_str(p), l) for p, l in flat]
    )


def cache_specs(cfg: ModelConfig, mesh: Mesh, caches: PyTree) -> PyTree:
    """Decode cache sharding.

    GQA KV cache (…, B, S, K, hd): batch over data axes when divisible;
    K over model when divisible, else S over model (sequence-sharded cache —
    GSPMD lowers decode softmax into partial reductions = flash-decoding).
    For batch=1 (long_500k) the sequence shards over *all* axes.
    MLA cache (…, B, S, lora): S over model (latent is head-shared).
    Mamba h (…, B, H, hd, N): H over model when divisible.
    """
    dp = batch_axes(mesh)

    def spec(path, leaf):
        shape = leaf.shape
        rank = len(shape)
        # find (B, S, ...) position: caches from stacked groups have lead dims
        if path.endswith(("/k", "/v")) and rank >= 4:
            nl = rank - 4
            b, s, k, hd = shape[nl:]
            b_ax = dp if _div(b, mesh, dp) else None
            if _div(k, mesh, "model"):
                return P(*([None] * nl), b_ax, None, "model", None)
            seq_ax = ("data", "model") if b_ax is None and _div(s, mesh, ("data", "model")) else "model"
            if not _div(s, mesh, seq_ax):
                seq_ax = None
            return P(*([None] * nl), b_ax, seq_ax, None, None)
        if path.endswith(("c_kv", "k_rope")) and rank >= 3:
            nl = rank - 3
            b, s, r = shape[nl:]
            b_ax = dp if _div(b, mesh, dp) else None
            seq_ax = ("data", "model") if b_ax is None and _div(s, mesh, ("data", "model")) else "model"
            if not _div(s, mesh, seq_ax):
                seq_ax = None
            return P(*([None] * nl), b_ax, seq_ax, None)
        if path.endswith("/h") and rank >= 4:
            nl = rank - 4
            b, h, hd, n = shape[nl:]
            b_ax = dp if _div(b, mesh, dp) else None
            h_ax = "model" if (h % mesh.shape["model"] == 0) else None
            return P(*([None] * nl), b_ax, h_ax, None, None)
        if "/conv/" in path and rank >= 3:
            nl = rank - 3
            b = shape[nl]
            b_ax = dp if _div(b, mesh, dp) else None
            return P(*([None] * nl), b_ax, None, None)
        # fallback: shard dim0-batch if it divides
        b_ax = dp if shape and _div(shape[0], mesh, dp) else None
        return P(b_ax, *([None] * (rank - 1))) if rank else P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(_path_str(p), l) for p, l in flat]
    )


def to_shardings(mesh: Mesh, specs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
