"""EP-MCMC on the mesh — the paper's algorithm as a first-class training mode.

The mapping (DESIGN.md §3): the mesh's ``data`` axis (× ``pod`` on multi-pod)
hosts **M independent subposterior chains**. Chain c owns

- its own parameter state θ_c (pytree stacked on a leading chain axis,
  sharded ``P('data', <TP spec>)``),
- a disjoint data shard (the paper's partition),
- an independent RNG stream.

The SGLD transition on chain c targets the subposterior (paper Eq. 2.1)

    log p_c(θ) = (1/M)·log p(θ) + (N_c/B)·Σ_{i∈batch} log p(x_i|θ)

Because the chain axis is *vmapped* (no op ever mixes chains), GSPMD lowers
the whole sampling step with **zero collectives across the data/pod axes** —
the paper's "embarrassingly parallel" claim, checkable in the HLO
(:func:`assert_no_cross_chain_collectives`, exercised by tests and the
dry-run). The ``model`` axis still carries ordinary TP collectives *within*
a chain. Compare ``--mode sgd``: identical step, but gradients are averaged
over chains (psum over data axes) every step — the communication the paper
deletes.

Combination (§3) communicates once at the end:
- parametric, full θ (BvM regime): per-chain diagonal running moments →
  ``product_moments_diag`` over the chain axis — a single O(d) reduce.
- exact combiners (nonparametric/semiparametric IMG): run on a designated
  low-dim parameter *subset* (or summary) — all-gather of (M, T, d_sub),
  then :func:`combine_gathered` resolves the strategy by registry name
  (``repro.core.combiners``).
"""

from __future__ import annotations

import functools
import re
from typing import Any, Callable, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.gaussian import GaussianMoments, product_moments_diag
from repro.distributed import sharding as shd
from repro.models.lm import model as mdl
from repro.models.lm import steps
from repro.models.lm.config import ModelConfig

PyTree = Any

PRIOR_SIGMA = 1.0  # N(0, σ²) prior over every weight — BvM-regime reference prior


class EpmcmcState(NamedTuple):
    """State of M parallel subposterior SGLD chains (+ streaming moments)."""

    params: PyTree  # (C, ...) stacked chain parameters
    v: PyTree  # (C, ...) RMSProp preconditioner accumulators
    step: jnp.ndarray  # () int32
    key: jax.Array  # (C, 2) per-chain RNG
    # streaming diagonal moments of the post-burn-in samples, per chain:
    m_count: jnp.ndarray  # (C,)
    m_mean: PyTree  # (C, ...) running mean of θ samples
    m_var: PyTree  # (C, ...) running Σ(θ−mean)² (Welford)


def num_chains(mesh: Mesh) -> int:
    return int(
        mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
        if "data" in mesh.shape
        else 1
    )


def init_state(key: jax.Array, cfg: ModelConfig, n_chains: int) -> EpmcmcState:
    """vmapped per-chain init — every chain starts at a different draw
    (overdispersed starts parallelize burn-in diagnostics)."""
    keys = jax.random.split(key, n_chains)
    params = jax.vmap(lambda k: mdl.init_params(k, cfg))(keys)
    zeros_like_f32 = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t
    )
    return EpmcmcState(
        params=params,
        v=zeros_like_f32(params),
        step=jnp.zeros((), jnp.int32),
        key=jax.vmap(jax.random.fold_in)(keys, jnp.arange(n_chains)),
        m_count=jnp.zeros((n_chains,), jnp.float32),
        m_mean=zeros_like_f32(params),
        m_var=zeros_like_f32(params),
    )


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------


def chain_axes(mesh: Mesh) -> Tuple[str, ...]:
    return shd.batch_axes(mesh)  # ('pod','data') / ('data',)


def _prepend_chain_axis(spec: P, axes: Tuple[str, ...]) -> P:
    return P(axes, *tuple(spec))


def state_specs(cfg: ModelConfig, mesh: Mesh, state: EpmcmcState) -> EpmcmcState:
    """PartitionSpecs: chain axis over data(/pod); TP spec per chain inside.

    Reuses :func:`repro.distributed.sharding.param_spec` — stacked leaves have
    one extra leading dim, which the path rules emit as a leading ``None``;
    we overwrite it with the chain axes. FSDP is force-disabled: the data
    axis belongs to the chains (each chain's state is TP-sharded only —
    ZeRO-style sharding would put 'data' on a second dim of the same leaf).
    """
    import dataclasses as _dc

    ca = chain_axes(mesh)
    cfg_tp = _dc.replace(cfg, fsdp=False)

    def stacked_specs(tree: PyTree) -> PyTree:
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        out = []
        for path, leaf in flat:
            # spec of the UNSTACKED per-chain leaf (path rules are written
            # against per-chain shapes), then prepend the chain axis
            unstacked = jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)
            spec = shd.param_spec(cfg_tp, mesh, shd._path_str(path), unstacked)
            parts = list(spec) + [None] * (len(unstacked.shape) - len(spec))
            out.append(P(ca, *parts))
        return jax.tree_util.tree_unflatten(treedef, out)

    pspec = stacked_specs(state.params)
    return EpmcmcState(
        params=pspec,
        v=pspec,
        step=P(),
        key=P(ca, None),
        m_count=P(ca),
        m_mean=pspec,
        m_var=pspec,
    )


def batch_spec(mesh: Mesh, batch: PyTree) -> PyTree:
    """EP-MCMC batches are (C, b, ...) — chain axis sharded, rest local."""
    ca = chain_axes(mesh)
    return jax.tree.map(lambda l: P(ca, *([None] * (l.ndim - 1))), batch)


# ---------------------------------------------------------------------------
# the SGLD subposterior step (one transition of every chain, in parallel)
# ---------------------------------------------------------------------------


def _subposterior_neg_logpost(
    params: PyTree,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    *,
    num_shards: int,
    shard_tokens: float,
) -> jnp.ndarray:
    """−log p_c(θ) up to a constant, for ONE chain (vmapped by the caller).

    CE is mean/token, so ``shard_tokens × CE`` is −log-lik of the whole shard
    (the N_c/B unbiased scaling); the Gaussian prior enters with weight 1/M
    (paper Eq. 2.1's underweighted prior).
    """
    total, _metrics = steps.loss_fn(params, cfg, batch)
    neg_loglik = shard_tokens * total
    sq = sum(
        jnp.sum(jnp.square(p.astype(jnp.float32))) for p in jax.tree.leaves(params)
    )
    neg_logprior = sq / (2.0 * PRIOR_SIGMA**2)
    return neg_loglik + neg_logprior / num_shards


def epmcmc_step(
    state: EpmcmcState,
    batch: Dict[str, jnp.ndarray],  # (C, b, ...) — one sub-batch per chain
    cfg: ModelConfig,
    *,
    num_shards: int,
    shard_tokens: float,
    step_size: float = 1e-6,
    rmsprop_decay: float = 0.99,
    rmsprop_eps: float = 1e-4,
    temperature: float = 1.0,
    burn_in: int = 0,
) -> Tuple[EpmcmcState, Dict[str, jnp.ndarray]]:
    """One pSGLD transition of all chains + streaming-moment update.

    ``temperature=0`` turns the transition into preconditioned SGD *per
    chain* — still embarrassingly parallel. The synchronous baseline lives in
    :func:`sgd_baseline_step`.
    """

    def one_chain(params, v, key, batch_c):
        nlp = functools.partial(
            _subposterior_neg_logpost,
            cfg=cfg,
            num_shards=num_shards,
            shard_tokens=shard_tokens,
        )
        loss, grads = jax.value_and_grad(lambda p: nlp(p, batch=batch_c))(params)
        # pSGLD: G = 1/(√v̂ + ε);  θ += −(ε/2)·G·∇nlp + √(ε·G·T)·ξ
        v_new = jax.tree.map(
            lambda vi, g: rmsprop_decay * vi
            + (1 - rmsprop_decay) * jnp.square(g.astype(jnp.float32)),
            v,
            grads,
        )
        key, knoise = jax.random.split(key)
        leaves, treedef = jax.tree.flatten(params)
        nkeys = jax.tree.unflatten(
            treedef, list(jax.random.split(knoise, len(leaves)))
        )

        def upd(p, g, vi, nk):
            G = 1.0 / (jnp.sqrt(vi) + rmsprop_eps)
            drift = -0.5 * step_size * G * g.astype(jnp.float32)
            noise = jnp.sqrt(step_size * G * temperature) * jax.random.normal(
                nk, p.shape, jnp.float32
            )
            return (p.astype(jnp.float32) + drift + noise).astype(p.dtype)

        params_new = jax.tree.map(upd, params, grads, v_new, nkeys)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        return params_new, v_new, key, loss, gnorm

    p_new, v_new, k_new, losses, gnorms = jax.vmap(one_chain)(
        state.params, state.v, state.key, batch
    )
    # NB: metrics stay PER-CHAIN — even a scalar jnp.mean over the chain axis
    # would lower to a cross-chain all-reduce and break the zero-communication
    # property this mode exists to demonstrate. Average on the host if needed.

    # Streaming Welford moments, masked until burn-in completes. This is the
    # paper's §4 "online" combiner state: O(d) per chain, no samples stored.
    take = (state.step >= burn_in).astype(jnp.float32)
    n_new = state.m_count + take
    denom = jnp.maximum(n_new, 1.0)

    def welford(mean, var, p):
        p32 = p.astype(jnp.float32)
        bshape = (-1,) + (1,) * (p.ndim - 1)
        delta = p32 - mean
        mean_new = mean + (take.reshape(bshape) * delta) / denom.reshape(bshape)
        var_new = var + take.reshape(bshape) * delta * (p32 - mean_new)
        return mean_new, var_new

    flat_mean, treedef = jax.tree.flatten(state.m_mean)
    flat_var = jax.tree.leaves(state.m_var)
    flat_p = jax.tree.leaves(p_new)
    new_mean, new_var = [], []
    for mn, vr, p in zip(flat_mean, flat_var, flat_p):
        a, b = welford(mn, vr, p)
        new_mean.append(a)
        new_var.append(b)

    new_state = EpmcmcState(
        params=p_new,
        v=v_new,
        step=state.step + 1,
        key=k_new,
        m_count=n_new,
        m_mean=jax.tree.unflatten(treedef, new_mean),
        m_var=jax.tree.unflatten(treedef, new_var),
    )
    metrics = {"loss_per_chain": losses, "gnorm_per_chain": gnorms}
    return new_state, metrics


def sgd_baseline_step(
    state: EpmcmcState,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    num_shards: int,
    shard_tokens: float,
    step_size: float = 1e-6,
    rmsprop_decay: float = 0.99,
    rmsprop_eps: float = 1e-4,
) -> Tuple[EpmcmcState, Dict[str, jnp.ndarray]]:
    """The synchronous strawman: same per-chain gradient, then *averaged
    across chains* (a data-axis psum — the collective EP-MCMC eliminates).
    Used by the dry-run to quantify the paper's deleted collective bytes."""

    def one_chain_grad(params, batch_c):
        nlp = functools.partial(
            _subposterior_neg_logpost,
            cfg=cfg,
            num_shards=num_shards,
            shard_tokens=shard_tokens,
        )
        return jax.value_and_grad(lambda p: nlp(p, batch=batch_c))(params)

    losses, grads = jax.vmap(one_chain_grad)(state.params, batch)
    # gradient averaging over the chain axis == DP all-reduce under GSPMD
    grads = jax.tree.map(lambda g: jnp.mean(g, axis=0, keepdims=True), grads)
    grads = jax.tree.map(
        lambda g, p: jnp.broadcast_to(g, p.shape), grads, state.params
    )

    def upd(p, g, v):
        v_new = rmsprop_decay * v + (1 - rmsprop_decay) * jnp.square(
            g.astype(jnp.float32)
        )
        G = 1.0 / (jnp.sqrt(v_new) + rmsprop_eps)
        return (p.astype(jnp.float32) - 0.5 * step_size * G * g.astype(jnp.float32)).astype(
            p.dtype
        ), v_new

    flat_p, treedef = jax.tree.flatten(state.params)
    outs = [
        upd(p, g, v)
        for p, g, v in zip(flat_p, jax.tree.leaves(grads), jax.tree.leaves(state.v))
    ]
    p_new = jax.tree.unflatten(treedef, [o[0] for o in outs])
    v_new = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_state = state._replace(params=p_new, v=v_new, step=state.step + 1)
    return new_state, {"loss_per_chain": losses}


# ---------------------------------------------------------------------------
# combination (the single communicating stage)
# ---------------------------------------------------------------------------


def combine_parametric_diag(state: EpmcmcState) -> GaussianMoments:
    """Full-θ parametric product (Eqs 3.1–3.2, diagonal/BvM form) from the
    streaming moments. Per-leaf; the reduce over the chain axis is the only
    cross-chain communication in the entire run (O(d) scalars)."""

    counts = jnp.maximum(state.m_count - 1.0, 1.0)

    def product(mean, var):
        C = mean.shape[0]
        cshape = (C,) + (1,) * (mean.ndim - 1)
        v = var / counts.reshape(cshape) + 1e-12
        flat_mean = mean.reshape(C, -1)
        flat_var = v.reshape(C, -1)
        mom = product_moments_diag(flat_mean, flat_var)
        return mom.mean.reshape(mean.shape[1:]), mom.cov.reshape(mean.shape[1:])

    means, variances = {}, {}
    flat, treedef = jax.tree_util.tree_flatten(state.m_mean)
    flat_v = jax.tree.leaves(state.m_var)
    out_m, out_v = [], []
    for mn, vr in zip(flat, flat_v):
        a, b = product(mn, vr)
        out_m.append(a)
        out_v.append(b)
    return GaussianMoments(
        mean=jax.tree.unflatten(treedef, out_m), cov=jax.tree.unflatten(treedef, out_v)
    )


def combine_gathered(
    key: jax.Array,
    samples: jnp.ndarray,  # (M, T, d_sub) all-gathered subset samples
    n_draws: int,
    *,
    combiner: str = "nonparametric",
    **options,
):
    """Final-stage exact combination of all-gathered subset samples.

    The combiner is resolved by registry name (``repro.core.combiners``), so
    the mesh run selects its combination strategy with the same string the
    CLI and benchmarks use — e.g. ``combiner="semiparametric"`` or
    ``combiner="nonparametric", n_batch=8, weight_eval="kernel"`` for the
    batched Pallas-scored IMG chains. Options the chosen combiner's
    signature does not declare are filtered out (the registry's
    option-forwarding convention), so one option dict can drive a sweep over
    rival combiners.

    Shape contract: ``samples`` must be the dense ``(M, T, d_sub)`` stack —
    a single :func:`gather_subset_samples` snapshot is ``(C, d_sub)`` and
    needs ``history=True`` there (T=1) or :func:`stack_subset_history`
    across steps first.
    """
    from repro.core.combiners import filter_options, get_combiner

    if samples.ndim != 3:
        raise ValueError(
            f"combine_gathered needs (M, T, d_sub) samples, got {samples.shape}; "
            "gather_subset_samples returns one (C, d_sub) snapshot — pass "
            "history=True there or stack snapshots with stack_subset_history"
        )
    fn = get_combiner(combiner)
    return fn(key, samples, n_draws, **filter_options(fn, options))


def gather_subset_samples(
    params: PyTree = None,
    paths: Sequence[str] | None = None,
    *,
    history: bool = False,
    chunk: Optional[Sequence[PyTree]] = None,
) -> jnp.ndarray:
    """Flatten a designated low-dim θ subset per chain → ``(C, d_sub)``.

    Default subset: final-norm scale (tiny, present in every arch). The
    exact (IMG) combiners require a ``(M, T, d_sub)`` history, not a single
    snapshot — ``history=True`` returns ``(C, 1, d_sub)`` (the documented
    ``samples[:, None, :]`` adapter), and per-step snapshots accumulate into
    the full layout with :func:`stack_subset_history`.

    ``chunk=`` is the streaming gather: pass a *window* of per-step stacked
    params (e.g. the last k post-burn-in states) and get the dense
    ``(C, k, d_sub)`` device slice back — exactly one
    ``StreamingCombiner.update`` chunk (see :func:`combine_stream`), so the
    driver folds windows as they land rather than stacking the history
    itself. Whether the *combiner* then holds the full ``(C, T, d_sub)``
    stack depends on its streaming state: ``online`` keeps O(d²) moments
    only; the buffered implementations re-accumulate the stack (their win
    is per-chunk trajectory + bitwise finals, not memory). Per-chain slices
    are concatenated host-side; no collective is ever emitted across the
    chain axes (the sampling step's HLO stays assertable collective-free,
    exactly as before)."""
    if chunk is not None:
        if params is not None:
            raise ValueError(
                "pass either one stacked params pytree or chunk= (a window "
                "of them), not both"
            )
        if len(chunk) == 0:
            raise ValueError("chunk= needs at least one per-step snapshot")
        return jnp.stack(
            [gather_subset_samples(p, paths) for p in chunk], axis=1
        )
    if params is None:
        raise ValueError("gather_subset_samples needs params (or chunk=)")
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    sel = []
    for path, leaf in flat:
        name = shd._path_str(path)
        if paths is None:
            if "final_norm" in name:
                sel.append(leaf)
        elif any(re.search(p, name) for p in paths):
            sel.append(leaf)
    if not sel:
        raise ValueError("subset selector matched no parameters")
    C = sel[0].shape[0]
    # jnp.array: force an owned buffer — with a single selected leaf the
    # reshape/astype/concatenate chain can alias ``params``, and snapshots
    # held across a donating step_fn (donate_argnums) would be deleted
    # under the caller's feet
    out = jnp.array(
        jnp.concatenate([s.reshape(C, -1).astype(jnp.float32) for s in sel], axis=1)
    )
    return out[:, None, :] if history else out


def combine_stream(
    key: jax.Array,
    chunks,
    n_draws: int,
    *,
    combiner: str = "nonparametric",
    **options,
):
    """Streaming counterpart of :func:`combine_gathered`.

    Folds an iterable of dense ``(M, C, d_sub)`` chunks — e.g. successive
    ``gather_subset_samples(chunk=window)`` slices — through the registry's
    :class:`~repro.core.combiners.api.StreamingCombiner` for ``combiner``
    and finalizes. For the buffered implementations the result is bitwise
    :func:`combine_gathered` on the concatenated stack; for ``online`` the
    full history is never materialized at all. Options follow the same
    per-signature filtering convention as the batch path.
    """
    from repro.core.combiners import filter_options, get_streaming_combiner

    sc = get_streaming_combiner(combiner)
    state = None
    for ch in chunks:
        if ch.ndim != 3:
            raise ValueError(
                f"combine_stream folds (M, C, d_sub) chunks, got {ch.shape}; "
                "use gather_subset_samples(chunk=window) to build them"
            )
        if state is None:
            state = sc.init(ch.shape[0], ch.shape[2])
        state = sc.update(state, ch)
    if state is None:
        raise ValueError("combine_stream needs at least one chunk")
    return sc.finalize(
        key, state, n_draws, **filter_options(sc.finalize, options)
    )


def stack_subset_history(snapshots: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Stack per-step ``(C, d_sub)`` subset gathers → ``(C, T, d_sub)``.

    The bridge from the streaming sampler to the combiner engine's dense
    layout: collect ``gather_subset_samples(state.params)`` every post-burn-in
    step (host-side list is fine — d_sub is tiny by construction), stack, and
    hand the result to :func:`combine_gathered`."""
    if len(snapshots) == 0:
        raise ValueError("stack_subset_history needs at least one snapshot")
    return jnp.stack([jnp.asarray(s) for s in snapshots], axis=1)


# ---------------------------------------------------------------------------
# HLO assertions: the "embarrassingly parallel" proof
# ---------------------------------------------------------------------------

_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
# NB: the output type may be a (multi-line-wide) tuple, so match the op-kind
# token directly rather than anchoring on '= <type>'.
_COLLECTIVE_LINE_RE = re.compile(
    r"\s(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_PAIRS_RE = re.compile(r"source_target_pairs=\{([^}]*)\}")


_IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?"
)


def _iota_groups(ng: int, gs: int, dims, perm) -> list:
    """Decode the iota-v2 replica_groups format: [NG,GS]<=[dims]T(perm)."""
    import numpy as np

    total = 1
    for d in dims:
        total *= d
    ids = np.arange(total).reshape(dims)
    if perm is not None:
        ids = ids.transpose(perm)
    return ids.reshape(ng, gs).tolist()


def collective_groups(hlo_text: str) -> list:
    """Extract (kind, groups) for every collective in the HLO.

    Handles the explicit ``{{0,1},{2,3}}`` form, the iota-v2 form
    ``[NG,GS]<=[dims]T(perm)`` and collective-permute source/target pairs."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_LINE_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        groups = []
        im = _IOTA_GROUPS_RE.search(line)
        if im:
            dims = [int(x) for x in im.group(3).split(",")]
            perm = [int(x) for x in im.group(4).split(",")] if im.group(4) else None
            groups = _iota_groups(int(im.group(1)), int(im.group(2)), dims, perm)
        else:
            gm = _REPLICA_GROUPS_RE.search(line)
            if gm:
                for grp in re.findall(r"\{([0-9,\s]*)\}", gm.group(1)):
                    ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
                    if ids:
                        groups.append(ids)
        pm = _PAIRS_RE.search(line)
        if pm:
            pairs = [
                tuple(int(x) for x in p.split(","))
                for p in re.findall(r"\{([0-9,\s]*)\}", pm.group(1))
            ]
            groups = [list(p) for p in pairs]
        out.append((kind, groups))
    return out


def assert_no_cross_chain_collectives(
    hlo_text: str, mesh: Mesh, *, allow_kinds: Tuple[str, ...] = ()
) -> int:
    """Fail if any collective's device group spans >1 (pod, data) coordinate.

    Device ids on our mesh are row-major over (pod?, data, model), so the
    chain coordinate of device i is ``i // model_size``. Returns the number
    of collectives checked (all confined to the model axis). Meshes without
    a ``model`` axis (e.g. the ``run_matrix`` cell-fanout mesh, where the
    data axis indexes whole cells) treat every device as its own chain
    group — any cross-device collective fails."""
    model = dict(mesh.shape).get("model", 1)
    checked = 0
    for kind, groups in collective_groups(hlo_text):
        if kind in allow_kinds:
            continue
        for grp in groups:
            chains = {dev // model for dev in grp}
            if len(chains) > 1:
                raise AssertionError(
                    f"{kind} crosses chain groups {sorted(chains)[:4]}…: {grp[:8]}"
                )
        checked += 1
    return checked
