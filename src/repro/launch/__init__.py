"""Launch layer: mesh construction, multi-pod dry-run, training/serving/MCMC
entry points. Nothing here touches jax device state at import time."""
