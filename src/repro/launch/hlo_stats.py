"""Loop-aware HLO analyzer — the "profiler" of this CPU-only rig.

``compiled.cost_analysis()`` visits a ``while`` body ONCE (verified: a scanned
8-layer matmul reports 1/8 the FLOPs of its unrolled twin), so for scanned
models both FLOPs and collective bytes must be multiplied by loop trip counts.
This module parses the post-SPMD optimized HLO text and computes:

- ``flops``              dot-op FLOPs × enclosing-loop trip counts
- ``bytes``              fusion-boundary traffic (operands+outputs of top-level
                         ops) × trip counts — an HBM-traffic proxy
- ``collective_bytes``   Σ operand bytes of all-gather / all-reduce /
                         reduce-scatter / all-to-all / collective-permute
                         (+ their -start variants), × trip counts, per kind
- ``collective_count``   static op counts per kind

Trip counts come from the loop-condition computation's integer constant (the
scan bound). All quantities are per-device (the HLO is the SPMD-partitioned
per-device program).
"""

from __future__ import annotations

import math
import re
from typing import Dict, List, NamedTuple, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1,
    "u4": 1,
    "s8": 1,
    "u8": 1,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
    "f8e4m3b11fnuz": 1,
    "s16": 2,
    "u16": 2,
    "f16": 2,
    "bf16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
    "token": 0,
    "opaque": 0,
}

_ARRAY_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (array or tuple)."""
    total = 0
    for dtype, dims in _ARRAY_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


class Op(NamedTuple):
    name: str
    kind: str
    out_bytes: int
    out_type: str
    operands: Tuple[str, ...]
    attrs: str
    flops: int
    is_root: bool = False
    param_idx: Optional[int] = None  # parameter(N) index, kind=="parameter"


class Computation(NamedTuple):
    name: str
    ops: List[Op]
    defs: Dict[str, int]  # op/param name -> output bytes


_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$"
)


def _split_type_and_rest(rest: str) -> Tuple[str, str]:
    """rest = '<type> <opname>(<operands>)<attrs>'; type may be a tuple."""
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rest[: i + 1], rest[i + 1 :].strip()
    i = rest.find(" ")
    return rest[:i], rest[i + 1 :].strip()


_CALL_RE = re.compile(
    r"(?:calls|body|condition|branch_computations|to_apply)="
    r"(?:\{([^}]*)\}|%?([\w\.\-]+))"
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dot_flops(out_type: str, lhs_type: str, attrs: str) -> int:
    out_elems = 1
    m = _ARRAY_RE.search(out_type)
    if m and m.group(2):
        for d in m.group(2).split(","):
            out_elems *= int(d)
    lhs_dims: List[int] = []
    ml = _ARRAY_RE.search(lhs_type)
    if ml and ml.group(2):
        lhs_dims = [int(d) for d in ml.group(2).split(",")]
    mc = _CONTRACT_RE.search(attrs)
    contract = 1
    if mc and mc.group(1):
        for idx in mc.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2 * out_elems * contract


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[str] = None
    ops: List[Op] = []
    defs: Dict[str, int] = {}
    types: Dict[str, str] = {}
    for raw in text.splitlines():
        line = raw.rstrip()
        if current is None:
            if line and not line.startswith(("HloModule", "//", "#")) and line.endswith("{"):
                m = _COMP_HEADER_RE.match(line.strip())
                if m:
                    current = m.group(1)
                    ops, defs, types = [], {}, {}
            continue
        if line.strip() == "}":
            comps[current] = Computation(name=current, ops=ops, defs=defs)
            current = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        is_root = line.lstrip().startswith("ROOT ")
        name, rest = m.group(1), m.group(2)
        type_str, tail = _split_type_and_rest(rest)
        km = re.match(r"([\w\-]+)\(", tail)
        if not km:
            continue
        kind = km.group(1)
        # operand section = up to matching close paren of the op call
        depth = 0
        end = len(tail)
        for i in range(km.end() - 1, len(tail)):
            if tail[i] == "(":
                depth += 1
            elif tail[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = tail[km.end() : end]
        attrs = tail[end + 1 :]
        operands = tuple(_OPERAND_RE.findall(operand_str))
        out_bytes = _type_bytes(type_str)
        defs[name] = out_bytes
        types[name] = type_str
        flops = 0
        if kind == "dot":
            lhs_type = types.get(operands[0], "") if operands else ""
            flops = _dot_flops(type_str, lhs_type, attrs)
        param_idx = None
        if kind == "parameter":
            pm = re.match(r"\s*(\d+)\s*$", operand_str)
            if pm:
                param_idx = int(pm.group(1))
        ops.append(
            Op(name, kind, out_bytes, type_str, operands, attrs, flops, is_root, param_idx)
        )
    return comps


class HloStats(NamedTuple):
    flops: float
    bytes_accessed: float  # fusion-aware HBM-traffic proxy (see below)
    bytes_all_ops: float  # raw unfused operand+output count (upper bound)
    collective_bytes: float
    collective_bytes_by_kind: Dict[str, float]
    collective_count: Dict[str, int]
    trip_counts: Dict[str, int]


# The CPU backend emits almost-unfused HLO, so counting operands+outputs of
# EVERY op overstates TPU HBM traffic ~10-20× (every convert/add/broadcast
# materializes). The fusion-aware proxy emulates what the TPU compiler does:
#   - _HBM_OPS      (operands + outputs): real memory-bound ops — matmuls,
#                    reductions, (dynamic-)slices/updates (KV-cache writes,
#                    scan stacking), gathers/scatters (embeddings), RNG, sort.
#   - elementwise   (output only): producers fuse into these chains; one
#                    write survives per op (still a mild overcount for long
#                    chains, e.g. the AdamW update).
#   - _FREE_OPS     (0 bytes): layout/metadata ops fused away entirely.
#   - collectives   excluded here — they are the collective roofline term.
_HBM_OPS = {
    "dot",
    "convolution",
    "reduce",
    "reduce-window",
    "scatter",
    "gather",
    "dynamic-slice",
    "dynamic-update-slice",
    "sort",
    "rng-bit-generator",
    "custom-call",
    "fusion",
    "cholesky",
    "triangular-solve",
    "concatenate",
}
_FREE_OPS = {
    "reshape",
    "bitcast",
    "bitcast-convert",
    "transpose",
    "copy",
    "convert",
    "broadcast",
    "iota",
    "constant",
    "parameter",
    "get-tuple-element",
    "tuple",
    "slice",
    "reverse",
    "after-all",
    "partition-id",
    "replica-id",
    "optimization-barrier",
    "pad",
}
_SKIP_BYTES_KINDS = {
    "parameter",
    "constant",
    "get-tuple-element",
    "tuple",
    "bitcast",
    "while",
    "conditional",
    "call",
    "after-all",
    "partition-id",
    "replica-id",
}


def analyze(text: str, *, attribution: Optional[list] = None) -> HloStats:
    """``attribution``: pass a list to receive (bytes, comp, op_kind, op_name,
    out_type) tuples for every non-zero byte charge (perf-debug aid)."""
    comps = parse_hlo(text)
    # constants: re-scan raw text per computation for integer constants in
    # condition computations (the Op parser drops literal operands).
    const_vals: Dict[Tuple[str, str], int] = {}
    current = None
    for raw in text.splitlines():
        line = raw.strip()
        if line.endswith("{"):
            m = _COMP_HEADER_RE.match(line)
            if m:
                current = m.group(1)
            continue
        if line == "}":
            current = None
            continue
        m = re.match(r"(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*[su]\d+\[\]\s+constant\((\d+)\)", line)
        if m and current:
            const_vals[(current, m.group(1))] = int(m.group(2))

    # map body computation -> trip count (from its while's condition comp)
    body_trips: Dict[str, int] = {}
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", op.attrs)
                mc = re.search(r"condition=%?([\w\.\-]+)", op.attrs)
                trip = 1
                if mc:
                    cname = mc.group(1)
                    vals = [v for (c, _), v in const_vals.items() if c == cname]
                    if vals:
                        trip = max(vals)
                if mb:
                    body_trips[mb.group(1)] = max(trip, 1)

    # propagate multipliers down the call graph from ENTRY
    entry = None
    for name, comp in comps.items():
        if re.search(rf"ENTRY\s+%?{re.escape(name)}\b", text):
            entry = name
            break
    if entry is None:
        entry = list(comps)[-1]

    mult: Dict[str, int] = {}

    def visit(name: str, m: int):
        if name not in comps:
            return
        if mult.get(name, 0) >= m:
            return
        mult[name] = max(mult.get(name, 0), m)
        comp = comps[name]
        for op in comp.ops:
            for cm in _CALL_RE.finditer(op.attrs):
                group = cm.group(1) if cm.group(1) is not None else cm.group(2)
                for callee in re.split(r",\s*", group):
                    callee = callee.strip().lstrip("%")
                    if not callee:
                        continue
                    child_m = m
                    if op.kind == "while" and re.search(
                        rf"body=%?{re.escape(callee)}\b", op.attrs
                    ):
                        child_m = m * body_trips.get(callee, 1)
                    visit(callee, child_m)

    visit(entry, 1)

    # --- per-computation helpers for the fusion-aware byte model -----------
    def _sliced_param_indices(fused_comp: Computation) -> set:
        """Parameter indices of a fused computation that are consumed (through
        free/layout ops) by dynamic-slice/gather — i.e. buffers the fusion
        reads only a window of, not in full."""
        # map parameter names to their true parameter(N) indices (bodies may
        # list parameters in any order — appearance order is NOT the index)
        idx_map = {
            op.name: op.param_idx
            for op in fused_comp.ops
            if op.kind == "parameter" and op.param_idx is not None
        }
        # reverse reachability: start at dynamic-slice/gather inputs, walk
        # back through free ops to parameters
        producers = {op.name: op for op in fused_comp.ops}
        sliced: set = set()
        for op in fused_comp.ops:
            if op.kind not in ("dynamic-slice", "gather"):
                continue
            frontier = list(op.operands[:1])  # the sliced buffer operand
            seen = set()
            while frontier:
                nm = frontier.pop()
                if nm in seen:
                    continue
                seen.add(nm)
                prod = producers.get(nm)
                if prod is None:
                    continue
                if prod.kind == "parameter":
                    if nm in idx_map:
                        sliced.add(idx_map[nm])
                elif prod.kind in _FREE_OPS:
                    frontier.extend(prod.operands)
        return sliced

    sliced_params_cache: Dict[str, set] = {}

    # fusion callees: computations whose ops are charged via their fusion op,
    # never individually (CPU XLA wraps even single elementwise ops this way)
    fusion_callees: set = set()
    elementwise_callees: set = set()
    _EW_DETECT_HBM = _HBM_OPS | {"dynamic-slice", "dynamic-update-slice", "gather", "scatter"}
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "fusion":
                cm = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
                if cm and cm.group(1) in comps:
                    callee = cm.group(1)
                    fusion_callees.add(callee)
                    callee_kinds = {o.kind for o in comps[callee].ops}
                    if not (callee_kinds & _EW_DETECT_HBM):
                        elementwise_callees.add(callee)

    def _fusion_callee(op: Op) -> Optional[str]:
        cm = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
        return cm.group(1) if cm and cm.group(1) in comps else None

    def _dus_update_bytes(fused_comp: Computation) -> Optional[int]:
        """If the fused computation is a dynamic-update-slice accumulation,
        return the update-window bytes (its true HBM traffic)."""
        for op in fused_comp.ops:
            if op.kind == "dynamic-update-slice" and len(op.operands) > 1:
                return fused_comp.defs.get(op.operands[1], None)
        return None

    def fusion_bytes(comp: Computation, op: Op) -> float:
        """Output + operands, capping operands the fusion only slices into.

        DUS-rooted fusions (scan stacking / KV-cache writes) are charged
        2× the update window — the carried buffer updates in place."""
        callee = _fusion_callee(op)
        sliced: set = set()
        if callee:
            if callee not in sliced_params_cache:
                sliced_params_cache[callee] = _sliced_param_indices(comps[callee])
            sliced = sliced_params_cache[callee]
            upd = _dus_update_bytes(comps[callee])
            if upd is not None:
                total = 2 * upd
                for i, o in enumerate(op.operands):
                    b = comp.defs.get(o, 0)
                    if b < op.out_bytes:  # skip the carried buffer itself
                        total += min(b, upd) if i in sliced else b
                return total
        total = op.out_bytes
        for i, o in enumerate(op.operands):
            b = comp.defs.get(o, 0)
            if i in sliced:
                b = min(b, 2 * op.out_bytes)
            total += b
        return total

    def consumers_by_producer(comp: Computation) -> Dict[str, List[str]]:
        cons: Dict[str, List[str]] = {}
        for op in comp.ops:
            for o in op.operands:
                cons.setdefault(o, []).append(op.kind)
        return cons

    _FUSES_INTO = _FREE_OPS | _HBM_OPS | {
        "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
        "negate", "exponential", "log", "log-plus-one", "rsqrt", "sqrt",
        "power", "tanh", "logistic", "select", "compare", "and", "or", "not",
        "xor", "clamp", "floor", "ceil", "round-nearest-afz", "sign",
        "cosine", "sine", "is-finite", "reduce-precision", "exponential-minus-one",
        "map", "atan2", "rem", "shift-left", "shift-right-logical",
        "shift-right-arithmetic", "popcnt", "clz", "dynamic-slice", "gather",
        "dynamic-update-slice", "scatter", "dot", "convolution", "reduce",
        "reduce-window", "sort", "fusion", "concatenate",
    }

    flops = 0.0
    bytes_accessed = 0.0
    bytes_all_ops = 0.0
    coll_bytes: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    coll_count: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for name, comp in comps.items():
        m = mult.get(name)
        if not m:
            continue
        cons = consumers_by_producer(comp)
        in_fusion = name in fusion_callees
        for op in comp.ops:
            if op.flops:
                flops += op.flops * m
            base_kind = op.kind.replace("-start", "")
            operand_bytes = sum(comp.defs.get(o, 0) for o in op.operands)
            if base_kind in _COLLECTIVES:
                coll_bytes[base_kind] += operand_bytes * m
                coll_count[base_kind] += 1
            if op.kind not in _SKIP_BYTES_KINDS and not op.kind.endswith("-done"):
                bytes_all_ops += (operand_bytes + op.out_bytes) * m
            if in_fusion:
                continue  # bytes charged at the fusion op, not per internal op
            # ---- fusion-aware HBM proxy (byte-model v3, see _HBM_OPS) ------
            charge = 0.0
            if op.kind in ("dynamic-slice", "gather"):
                # reads only the sliced/gathered window (≈ output), never the
                # whole operand — a scan over stacked params must not be
                # billed the full stack every iteration
                charge = 2 * op.out_bytes * m
            elif op.kind in ("dynamic-update-slice", "scatter"):
                # in-place window update: read+write of the window
                upd_bytes = (
                    comp.defs.get(op.operands[1], op.out_bytes)
                    if len(op.operands) > 1
                    else op.out_bytes
                )
                charge = 2 * upd_bytes * m
            elif op.kind == "fusion":
                callee = _fusion_callee(op)
                if callee in elementwise_callees:
                    # a wrapped/pure-elementwise fusion behaves like one
                    # elementwise op: charge only where the value escapes
                    kinds = cons.get(op.name, [])
                    escapes = op.is_root or not kinds or any(
                        k not in _FUSES_INTO for k in kinds
                    )
                    if escapes:
                        charge = op.out_bytes * m
                else:
                    charge = fusion_bytes(comp, op) * m
            elif op.kind in _HBM_OPS:
                charge = (operand_bytes + op.out_bytes) * m
            elif (
                op.kind in _FREE_OPS
                or op.kind in _SKIP_BYTES_KINDS
                or op.kind.endswith(("-done", "-start"))
                or base_kind in _COLLECTIVES
            ):
                pass
            else:
                # elementwise: fuses into its consumer chain on TPU. Charge a
                # write only where the value escapes the fused region — at
                # the computation ROOT or a region boundary (tuple/while/…).
                kinds = cons.get(op.name, [])
                escapes = op.is_root or not kinds or any(
                    k not in _FUSES_INTO for k in kinds
                )
                if escapes:
                    charge = op.out_bytes * m
            if charge:
                bytes_accessed += charge
                if attribution is not None:
                    attribution.append((charge, name, op.kind, op.name, op.out_type[:80]))
    return HloStats(
        flops=flops,
        bytes_accessed=bytes_accessed,
        bytes_all_ops=bytes_all_ops,
        collective_bytes=sum(coll_bytes.values()),
        collective_bytes_by_kind={k: v for k, v in coll_bytes.items() if v},
        collective_count={k: v for k, v in coll_count.items() if v},
        trip_counts=body_trips,
    )
