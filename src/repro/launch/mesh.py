"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never initializes jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first init.
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """The batch/chain axes: ('pod','data') on multi-pod, ('data',) otherwise."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh: jax.sharding.Mesh) -> str:
    return "model"


def make_host_mesh() -> jax.sharding.Mesh:
    """A 1×1 mesh over the single local device — lets every mesh-aware code
    path (sharding specs, shard_map chains) run unchanged in CPU tests."""
    return jax.make_mesh((1, 1), ("data", "model"))
