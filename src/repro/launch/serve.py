"""Serving driver: batched prefill + greedy decode against the KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2_130m --reduced \
      --batch 2 --prompt-len 32 --gen 16

On the production meshes the same two jitted functions are exactly what the
``prefill_32k`` / ``decode_32k`` / ``long_500k`` dry-run cells lower.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs import ALIASES, get_config
from repro.launch.mesh import make_host_mesh
from repro.models.lm import steps as lm_steps
from repro.models.lm.config import reduced


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(ALIASES.get(args.arch, args.arch))
    if args.reduced:
        cfg = reduced(cfg)
    mesh = make_host_mesh()
    del mesh  # host run: jit on the single device; mesh kept for parity

    key = jax.random.PRNGKey(args.seed)
    from repro.models.lm import model as mdl

    params = mdl.init_params(key, cfg)
    max_len = args.prompt_len + args.gen + cfg.num_image_tokens

    batch = {
        "tokens": jax.random.randint(
            jax.random.fold_in(key, 1), (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
    }
    if cfg.num_image_tokens:
        batch["img_embeds"] = jnp.zeros((args.batch, cfg.num_image_tokens, 1024))
    if cfg.num_encoder_layers:
        batch["enc_frames"] = jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model))

    prefill = jax.jit(functools.partial(lm_steps.serve_prefill, cfg=cfg, max_len=max_len))

    def _decode(p, s):
        return lm_steps.serve_decode_step(p, cfg, s)

    decode = jax.jit(_decode, donate_argnums=(1,))

    import numpy as np

    t0 = time.time()
    state = prefill(params, batch=batch)
    t1 = time.time()
    # host copies: the decode step donates its input state, which would
    # invalidate device buffers we still hold
    tokens = [np.asarray(state.last_token)]
    for _ in range(args.gen - 1):
        state, _logits = decode(params, state)
        tokens.append(np.asarray(state.last_token))
    out = jnp.concatenate([jnp.asarray(t) for t in tokens], axis=1)
    t2 = time.time()
    print(f"prefill {args.batch}×{args.prompt_len}: {t1-t0:.2f}s; "
          f"decode {args.gen} tokens: {(t2-t1)/max(args.gen-1,1)*1e3:.1f} ms/token")
    print("generated token ids (first row):", out[0].tolist())
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))
    return {"tokens": out, "prefill_s": t1 - t0, "decode_s_per_tok": (t2 - t1) / max(args.gen - 1, 1)}


if __name__ == "__main__":
    main()
