"""EP-MCMC driver for the paper's Bayes models (§8) — the reproduction CLI.

A thin pipeline over the registries: **partition → sample → combine → score**.
Models are resolved by name from :mod:`repro.models.bayes.registry`, samplers
from :mod:`repro.samplers.registry` (any × any — criterion 3), combiners from
:mod:`repro.core.combiners`; adding an entry to any registry makes it
reachable here with zero driver changes.

  PYTHONPATH=src python -m repro.launch.mcmc_run --model logreg --M 10 \
      --sampler hmc --samples 2000
  PYTHONPATH=src python -m repro.launch.mcmc_run --model poisson --sampler gibbs
  PYTHONPATH=src python -m repro.launch.mcmc_run --model gmm --M 10

Step sizes are adapted per chain by the dual-averaging warmup phase
(``--warmup``, sampler-specific acceptance targets) — there are no hand-tuned
per-model step constants.

The sampling stage runs vmapped on one device, or — given >1 device (e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=4``) — ``shard_map``-ped
over the ``data`` axis of a mesh, one chain group per device. Either way the
stage contains zero cross-chain collectives; on the mesh path this is
*asserted on the compiled HLO* via
:func:`repro.distributed.epmcmc.assert_no_cross_chain_collectives` — the
paper's "embarrassingly parallel" claim, machine-checked per run.
"""

from __future__ import annotations

import argparse
import math
import time
import zlib
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import metrics
from repro.core.combiners import (
    available_combiners,
    canonical_combiners,
    filter_options,
    get_combiner,
)
from repro.core.subposterior import make_subposterior_logpdf, partition_data
from repro.models.bayes import BayesModel, available_models, get_model
from repro.samplers import available_samplers, run_chain, sampler_spec

PyTree = Any

# models at or above this θ-dimension are scored in log space: raw
# `l2_distance` enters the f32-overflow regime of the KDE normalizer there
# (its own docstring's warning) and becomes hypersensitive to dispersion
LOG_L2_DIM = 40


class SampleResult(NamedTuple):
    """Output of the parallel sampling stage."""

    theta: jnp.ndarray  # (M, T, d) shared-θ subposterior draws
    accept: jnp.ndarray  # (M,) mean acceptance per chain
    counts: jnp.ndarray  # (M,) real data rows per shard (pad=True convention)
    backend: str  # "vmap" | "shard_map(<ndev> devices)"
    collectives_checked: Optional[int]  # HLO collectives verified chain-local


def _shard_axes(shards: PyTree, shard_keys, per_datum_leaf, broadcast_leaf):
    """Per-leaf vmap axes / PartitionSpecs: per-datum leaves carry the chain
    axis, broadcast leaves (e.g. gmm mixture weights) are replicated."""
    if shard_keys is None:
        return jax.tree.map(lambda _: per_datum_leaf, shards)
    return {
        k: (per_datum_leaf if k in shard_keys else broadcast_leaf)
        for k in shards
    }


def make_shard_sampler(
    model: BayesModel,
    num_shards: int,
    sampler: str,
    *,
    num_samples: int,
    burn_in: int,
    warmup: int,
    step_size: float,
    sgld_batch: int = 256,
    use_counts: bool = True,
) -> Callable[[PyTree, jnp.ndarray, jax.Array], Tuple[jnp.ndarray, jnp.ndarray]]:
    """Build ``one_shard(shard, count, key) -> (theta (T, d), mean_accept)``.

    The returned function is pure and shape-uniform across shards, so the
    launch layer can drive it under ``vmap`` (one device) or ``shard_map``
    (chain groups over the mesh data axis) unchanged. ``use_counts=False``
    statically drops the padded-row likelihood correction (every shard row is
    real) so the divisible-N hot path pays nothing for pad support.
    """
    spec = sampler_spec(sampler)

    def one_shard(shard, count, key):
        k_init, k_run = jax.random.split(key)

        if spec.name == "gibbs":  # alias-safe: spec.name is canonical
            if not model.has_gibbs:
                raise ValueError(
                    f"model {model.name!r} supplies no Gibbs blocks "
                    "(BayesModel.gibbs_blocks)"
                )
            blocks = model.gibbs_blocks(shard, num_shards, step_size=step_size)
            kern = spec.factory(None, step_size=step_size, block_updates=blocks)
            pos0 = model.gibbs_init(k_init, shard)
            # non-adaptive: warmup transitions are just extra burn-in
            pos, info = run_chain(
                k_run, kern, pos0, num_samples, burn_in=burn_in + warmup
            )
            theta = model.gibbs_extract(pos)
            return theta, info.is_accepted.mean()

        logpdf = make_subposterior_logpdf(
            model.log_prior,
            model.log_lik,
            shard,
            num_shards,
            count=count if use_counts else None,
            per_datum=model.shard_keys,
        )
        pos0 = model.initial_position(k_init, shard)

        if spec.name == "sgld":
            # minibatch subposterior gradients (paper §7): scale by the
            # shard's REAL row count so padded rows never bias the estimate
            if model.shard_keys is None:
                per_datum = shard
                rest = None
            else:
                per_datum = {k: shard[k] for k in model.shard_keys}
                rest = {k: v for k, v in shard.items() if k not in model.shard_keys}
            shard_size = jax.tree.leaves(per_datum)[0].shape[0]
            batch_size = min(sgld_batch or shard_size, shard_size)
            inv_m = 1.0 / float(num_shards)
            n_real = count if use_counts else shard_size

            def mb_logpdf(theta, batch):
                scale = jnp.asarray(n_real, jnp.float32) / float(batch_size)
                return inv_m * model.log_prior(theta) + scale * model.log_lik(
                    theta, batch
                )

            def batch_fn(k, _t):
                idx = jax.random.randint(
                    k, (batch_size,), 0, jnp.maximum(n_real, 1)
                )
                batch = jax.tree.map(lambda x: x[idx], per_datum)
                return batch if rest is None else {**rest, **batch}

            kern = spec.factory(
                logpdf,
                step_size=step_size,
                grad_logpdf=jax.grad(mb_logpdf),
                batch_fn=batch_fn,
            )
            pos, info = run_chain(
                k_run, kern, pos0, num_samples, burn_in=burn_in + warmup
            )
            return pos, info.is_accepted.mean()

        if spec.adaptive and warmup > 0:
            factory = lambda eps: spec.factory(logpdf, step_size=eps)
            pos, info = run_chain(
                k_run,
                factory,
                pos0,
                num_samples,
                burn_in=burn_in,
                warmup=warmup,
                initial_step_size=step_size,
                target_accept=spec.target_accept,
            )
        else:
            kern = spec.factory(logpdf, step_size=step_size)
            # non-adaptive kernels treat warmup as extra burn-in (registry
            # convention); adaptive ones only reach here when warmup == 0
            pos, info = run_chain(
                k_run,
                kern,
                pos0,
                num_samples,
                burn_in=burn_in + (0 if spec.adaptive else warmup),
            )
        return pos, info.is_accepted.mean()

    return one_shard


def sample_subposteriors(
    key: jax.Array,
    model: BayesModel,
    data: PyTree,
    num_shards: int,
    num_samples: int,
    *,
    sampler: Optional[str] = None,
    warmup: int = 200,
    burn_in: int = 0,
    step_size: float = 0.1,
    sgld_batch: int = 256,
    check_hlo: bool = True,
) -> SampleResult:
    """The embarrassingly parallel stage: M independent subposterior chains.

    Partitions ``data`` (edge-padded — non-divisible N is fine), then runs
    one chain per shard. With >1 local device and ``num_shards`` divisible by
    the device count, chains are ``shard_map``-ped over the ``data`` axis of
    a ``(ndev, 1)`` ("data", "model") mesh and the compiled HLO is asserted
    collective-free across chains; otherwise the chains are vmapped on one
    device. Zero cross-chain communication either way.
    """
    sampler = sampler or model.default_sampler
    shards, counts = partition_data(
        data, num_shards, only=model.shard_keys, pad=True
    )
    shard_rows = jax.tree.leaves(
        shards if model.shard_keys is None
        else {k: shards[k] for k in model.shard_keys}
    )[0].shape[1]
    padded = bool(jax.device_get(jnp.any(counts != shard_rows)))
    if padded and sampler_spec(sampler).name == "gibbs":
        raise ValueError(
            "gibbs block updates operate on the raw shard and cannot mask "
            f"padded rows; choose M dividing N (counts={jax.device_get(counts)})"
        )
    one_shard = make_shard_sampler(
        model,
        num_shards,
        sampler,
        num_samples=num_samples,
        burn_in=burn_in,
        warmup=warmup,
        step_size=step_size,
        sgld_batch=sgld_batch,
        # divisible N ⇒ every row is real ⇒ skip the pad correction entirely
        use_counts=padded,
    )
    keys = jax.random.split(key, num_shards)
    in_axes = (_shard_axes(shards, model.shard_keys, 0, None), 0, 0)
    vmapped = jax.vmap(one_shard, in_axes=in_axes)

    ndev = jax.device_count()
    if ndev > 1 and num_shards % ndev == 0:
        theta, acc, checked = _sample_on_mesh(
            vmapped, shards, counts, keys, model, ndev, check_hlo
        )
        return SampleResult(
            theta, acc, counts, f"shard_map({ndev} devices)", checked
        )
    theta, acc = jax.jit(vmapped)(shards, counts, keys)
    return SampleResult(theta, acc, counts, "vmap", None)


def _sample_on_mesh(vmapped, shards, counts, keys, model, ndev, check_hlo):
    """shard_map the vmapped per-shard sampler over the mesh data axis.

    Each device owns ``M/ndev`` chains + their data shards; broadcast leaves
    are replicated. The jitted program is lowered AOT so the post-SPMD HLO
    can be asserted collective-free *before* it runs — the machine-checked
    "embarrassingly parallel" property.
    """
    from functools import partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    # late import: epmcmc pulls the (heavy) LM stack this CLI otherwise skips
    from repro.distributed.epmcmc import assert_no_cross_chain_collectives

    mesh = jax.make_mesh((ndev, 1), ("data", "model"))
    shard_specs = _shard_axes(shards, model.shard_keys, P("data"), P())
    in_specs = (shard_specs, P("data"), P("data"))
    body = partial(
        shard_map,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P("data"), P("data")),
        check_rep=False,
    )(vmapped)
    compiled = jax.jit(body).lower(shards, counts, keys).compile()
    checked = None
    if check_hlo:
        checked = assert_no_cross_chain_collectives(compiled.as_text(), mesh)
    put = lambda tree, specs: jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs
    )
    theta, acc = compiled(
        put(shards, shard_specs), put(counts, P("data")), put(keys, P("data"))
    )
    return theta, acc, checked


def groundtruth_chain(
    key: jax.Array,
    model: BayesModel,
    data: PyTree,
    num_samples: int,
    *,
    sampler: Optional[str] = None,
    warmup: int = 200,
    burn_in: int = 0,
    step_size: float = 0.1,
    sgld_batch: int = 256,
) -> jnp.ndarray:
    """Single full-data chain (num_shards=1) with the same sampler surface."""
    one = make_shard_sampler(
        model,
        1,
        sampler or model.default_sampler,
        num_samples=num_samples,
        burn_in=burn_in,
        warmup=warmup,
        step_size=step_size,
        sgld_batch=sgld_batch,
        use_counts=False,  # full data: every row is real
    )
    theta, _ = jax.jit(lambda k: one(data, jnp.zeros((), jnp.int32), k))(key)
    return theta


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="logreg", choices=available_models())
    ap.add_argument("--M", type=int, default=10)
    ap.add_argument("--samples", type=int, default=2000)
    ap.add_argument("--burn-in", type=int, default=0, help="0 = paper's T/6 rule")
    ap.add_argument(
        "--sampler", default=None, choices=available_samplers(),
        help="sampler registry name (default: the model's default_sampler)",
    )
    ap.add_argument(
        "--warmup", type=int, default=200,
        help="dual-averaging step-size adaptation steps per chain",
    )
    ap.add_argument(
        "--step", type=float, default=0.1,
        help="initial step size (adapted away by warmup for MH-style kernels; "
        "the fixed step for gibbs/sgld)",
    )
    ap.add_argument(
        "--sgld-batch", type=int, default=256,
        help="SGLD minibatch size (0 = full shard)",
    )
    ap.add_argument("--n", type=int, default=0, help="dataset size (0 = paper's)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--groundtruth-samples", type=int, default=4000)
    ap.add_argument(
        "--combiner", default="all", choices=("all",) + available_combiners(),
        help="combination strategy to score (default: every registered combiner)",
    )
    ap.add_argument(
        "--img-batch", type=int, default=1,
        help="independent vmapped IMG index-chains (n_batch) for the exact combiners",
    )
    args = ap.parse_args(argv)

    model = get_model(args.model)
    sampler = args.sampler or model.default_sampler
    key = jax.random.PRNGKey(args.seed)
    n = args.n or model.default_n
    data, _theta_true = model.generate_data(key, n)
    burn = args.burn_in or args.samples // 6  # paper §8: discard first 1/6
    t_start = time.time()

    # --- partition + subposterior chains (embarrassingly parallel) ----------
    res = sample_subposteriors(
        jax.random.fold_in(key, 1),
        model,
        data,
        args.M,
        args.samples,
        sampler=sampler,
        warmup=args.warmup,
        burn_in=burn,
        step_size=args.step,
        sgld_batch=args.sgld_batch,
    )
    subsamps = res.theta
    t_sample = time.time() - t_start

    # --- groundtruth: single full-data chain --------------------------------
    # the full posterior is ~√M narrower than a subposterior and its gradient
    # M× larger; warmup absorbs that for adaptive kernels, fixed-step ones
    # need the classic compensation (ε/M for Langevin time steps, ε/√M for
    # proposal scales)
    spec = sampler_spec(sampler)
    if spec.name == "sgld":
        gt_step = args.step / args.M
    elif not (spec.adaptive and args.warmup > 0):
        gt_step = args.step / math.sqrt(args.M)
    else:
        gt_step = args.step
    gt = groundtruth_chain(
        jax.random.fold_in(key, 2),
        model,
        data,
        args.groundtruth_samples,
        sampler=sampler,
        warmup=args.warmup,
        burn_in=args.groundtruth_samples // 6,
        step_size=gt_step,
        sgld_batch=args.sgld_batch,
    )
    t_full = time.time() - t_start - t_sample

    # --- combinations + error scoreboard ------------------------------------
    kc = jax.random.fold_in(key, 3)
    results = {}
    T = args.samples
    # high-d runs score in log space (f32-overflow regime of raw L2)
    use_log = model.d >= LOG_L2_DIM
    score = metrics.log_l2_distance if use_log else metrics.l2_distance
    label = "logL2" if use_log else "L2"

    names = canonical_combiners() if args.combiner == "all" else [args.combiner]
    t0 = time.time()
    for name in names:
        fn = get_combiner(name)
        # independent RNG per estimator (fold_in by a stable hash of the name
        # — one shared key would correlate the scoreboard entries), and only
        # the options each combiner's signature declares are forwarded
        k_name = jax.random.fold_in(kc, zlib.crc32(name.encode()) & 0x7FFFFFFF)
        opts = filter_options(fn, dict(rescale=True, n_batch=args.img_batch))
        out = fn(k_name, subsamps, T, **opts)
        results[name] = float(score(gt, out.samples))
    t_combine = time.time() - t0

    checked = (
        "" if res.collectives_checked is None
        else f" hlo_collectives_checked={res.collectives_checked}"
    )
    print(
        f"model={model.name} M={args.M} T={T} sampler={sampler} "
        f"warmup={args.warmup} acc={float(jnp.mean(res.accept)):.2f} "
        f"backend={res.backend}{checked}"
    )
    print(f"timing: {t_sample:.1f}s parallel sampling, {t_full:.1f}s full chain, "
          f"{t_combine:.1f}s all combinations")
    for k_, v in sorted(results.items(), key=lambda kv: kv[1]):
        print(f"  {label}({k_:15s}) = {v:.4f}")
    return results


if __name__ == "__main__":
    main()
