"""EP-MCMC driver CLI — a thin argparse adapter over :mod:`repro.api`.

Every flag maps onto a field of :class:`repro.api.RunSpec`; execution is one
:class:`repro.api.Pipeline` run (partition → sample → combine → score, same
RNG discipline and scoreboard as ever — fixed seeds reproduce pre-``repro.api``
numbers bitwise). Models, samplers, and combiners are resolved by registry
name; adding an entry to any registry makes it reachable here with zero
driver changes.

  PYTHONPATH=src python -m repro.launch.mcmc_run --model logreg --M 10 \
      --sampler hmc --samples 2000
  PYTHONPATH=src python -m repro.launch.mcmc_run --model poisson --sampler gibbs
  PYTHONPATH=src python -m repro.launch.mcmc_run --model gmm --M 10

Step sizes are adapted per chain by the dual-averaging warmup phase
(``--warmup``, sampler-specific acceptance targets) — there are no hand-tuned
per-model step constants.

The sampling stage runs vmapped on one device, or — given >1 device (e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=4``) — ``shard_map``-ped
over the ``data`` axis of a mesh, one chain group per device
(``--mesh-shape``, or automatic when the device count divides ``--M``).
Either way the stage contains zero cross-chain collectives; on the mesh
path this is *asserted on the compiled HLO* via
:func:`repro.distributed.epmcmc.assert_no_cross_chain_collectives` — the
paper's "embarrassingly parallel" claim, machine-checked per run. Since the
:mod:`repro.api.backends` unification the mesh composes with
``--stream-every`` and ``--checkpoint-dir``: chunk programs run on the mesh
and every chunk program's HLO is asserted the same way.

``--serve`` runs the same Pipeline behind the :mod:`repro.serve` posterior
server: sampling streams chunks into the folder task while concurrent
readers (``--serve-readers`` self-probes, plus any external
``repro.serve.ServeClient``) query mean/cov, quantiles, predictive draws,
and machine-KDE log density with staleness metadata on every response.

The sampling engine itself lives in :mod:`repro.api.sampling`; the historical
module-level names (``make_shard_sampler``, ``sample_subposteriors``,
``groundtruth_chain``, ``SampleResult``) are re-exported here with a
``DeprecationWarning`` — import them from ``repro.api`` instead.
"""

from __future__ import annotations

import argparse
import warnings

from repro.api import Pipeline, RunSpec
from repro.core.combiners import available_combiners
from repro.models.bayes import available_models
from repro.samplers import available_samplers

# historical internals, now owned by repro.api.sampling — resolved lazily so
# importing this CLI module stays cheap and old imports keep working (warned)
_MOVED = (
    "SampleResult",
    "make_shard_sampler",
    "sample_subposteriors",
    "groundtruth_chain",
    "_shard_axes",
    "_sample_on_mesh",
    "LOG_L2_DIM",
)


def __getattr__(name: str):
    if name in _MOVED:
        warnings.warn(
            f"repro.launch.mcmc_run.{name} moved to repro.api — import it "
            "from repro.api (or drive whole runs via RunSpec/Pipeline)",
            DeprecationWarning,
            stacklevel=2,
        )
        if name == "LOG_L2_DIM":
            from repro.api.pipeline import LOG_L2_DIM

            return LOG_L2_DIM
        from repro.api import sampling

        return getattr(sampling, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _parse_mesh(arg):
    """``"4,1"`` → ``(4, 1)``; ``""``/None → None (vmap or auto-mesh)."""
    if not arg:
        return None
    parts = tuple(int(x) for x in arg.split(","))
    if len(parts) == 1:
        parts = parts + (1,)
    return parts


def build_spec(args: argparse.Namespace) -> RunSpec:
    """The whole adapter: argparse namespace → declarative RunSpec."""
    return RunSpec(
        mesh_shape=_parse_mesh(getattr(args, "mesh_shape", None)),
        model=args.model,
        sampler=args.sampler,
        combiner=args.combiner,
        M=args.M,
        T=args.samples,
        warmup=args.warmup,
        burn_in=args.burn_in,
        step_size=args.step,
        sgld_batch=args.sgld_batch,
        n=args.n,
        seed=args.seed,
        groundtruth_T=args.groundtruth_samples,
        stream_every=args.stream_every,
        combiner_options={"n_batch": args.img_batch},
    )


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="logreg", choices=available_models())
    ap.add_argument("--M", type=int, default=10)
    ap.add_argument("--samples", type=int, default=2000)
    ap.add_argument("--burn-in", type=int, default=0, help="0 = paper's T/6 rule")
    ap.add_argument(
        "--sampler", default=None, choices=available_samplers(),
        help="sampler registry name (default: the model's default_sampler)",
    )
    ap.add_argument(
        "--warmup", type=int, default=200,
        help="dual-averaging step-size adaptation steps per chain",
    )
    ap.add_argument(
        "--step", type=float, default=0.1,
        help="initial step size (adapted away by warmup for MH-style kernels; "
        "the fixed step for gibbs/sgld)",
    )
    ap.add_argument(
        "--sgld-batch", type=int, default=256,
        help="SGLD minibatch size (0 = full shard)",
    )
    ap.add_argument("--n", type=int, default=0, help="dataset size (0 = paper's)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--groundtruth-samples", type=int, default=4000)
    ap.add_argument(
        "--combiner", default="all", choices=("all",) + available_combiners(),
        help="combination strategy to score (default: every registered combiner)",
    )
    ap.add_argument(
        "--img-batch", type=int, default=1,
        help="independent vmapped IMG index-chains (n_batch) for the exact combiners",
    )
    ap.add_argument(
        "--checkpoint-dir", default=None,
        help="persist/resume the sampling stage here (chunked kernel state)",
    )
    ap.add_argument(
        "--checkpoint-every", type=int, default=0,
        help="draws per sampling checkpoint (with --checkpoint-dir; 0 = at end)",
    )
    ap.add_argument(
        "--stream-every", type=int, default=0,
        help="combine-while-sampling: fold every N landed draws into the "
        "streaming combiners and print the scoreboard trajectory (0 = off)",
    )
    ap.add_argument(
        "--mesh-shape", default=None, metavar="NDATA[,NMODEL]",
        help="shard chains over a device mesh (e.g. 4,1 with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4); composes "
        "with --stream-every and --checkpoint-dir via the mesh chunk "
        "backend (default: auto-mesh when >1 device divides M)",
    )
    ap.add_argument(
        "--serve", action="store_true",
        help="posterior-as-a-service: run sampling behind a repro.serve "
        "asyncio server (needs --stream-every) and answer posterior "
        "queries while the chains extend; composes with --checkpoint-dir "
        "(restart resumes from the last checkpoint)",
    )
    ap.add_argument(
        "--serve-port", type=int, default=0,
        help="TCP port for --serve (0 = ephemeral, printed at startup)",
    )
    ap.add_argument(
        "--serve-readers", type=int, default=4,
        help="concurrent self-probe readers cycling posterior queries "
        "during --serve (each asserts staleness counters monotone — the "
        "CI smoke contract); 0 = serve without probing",
    )
    args = ap.parse_args(argv)

    pipe = Pipeline(
        build_spec(args),
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
    )
    if args.serve:
        if args.stream_every <= 0:
            ap.error("--serve needs --stream-every > 0 (the serving cadence)")
        from repro.serve import serve_pipeline

        serve_pipeline(
            pipe, port=args.serve_port, probe_readers=args.serve_readers
        )
        # sampling is complete (and cached on the Pipeline): fall through to
        # the ordinary combine+score scoreboard over the served draws
    elif args.stream_every > 0:
        sr = pipe.stream_combine()
        first = sr.trajectory[0] if sr.trajectory else None
        if first is not None:
            print(
                f"streaming: first {sr.metric} estimate "
                f"({first['combiner']}, t={first['t']}) after "
                f"{first['elapsed_s']:.1f}s; "
                f"{len(sr.trajectory)} trajectory points over "
                f"{sr.t_done}/{sr.total} draws"
            )
        for row in sr.trajectory:
            err = "  -  " if row["error"] is None else f"{row['error']:.4f}"
            print(f"  t={row['t']:6d} {sr.metric}({row['combiner']:15s}) = {err}"
                  f"  [{row['elapsed_s']:.1f}s]")
    board = pipe.run()

    checked = (
        "" if board.collectives_checked is None
        else f" hlo_collectives_checked={board.collectives_checked}"
    )
    print(
        f"model={board.model} M={board.M} T={board.T} sampler={board.sampler} "
        f"warmup={args.warmup} acc={board.accept:.2f} "
        f"backend={board.backend}{checked}"
    )
    t = board.timings
    print(f"timing: {t.get('sample_s', 0.0):.1f}s parallel sampling, "
          f"{t.get('groundtruth_s', 0.0):.1f}s full chain, "
          f"{t.get('combine_s', 0.0):.1f}s all combinations")
    for k_, v in sorted(board.errors.items(), key=lambda kv: kv[1]):
        print(f"  {board.metric}({k_:15s}) = {v:.4f}")
    return dict(board.errors)


if __name__ == "__main__":
    main()
