"""EP-MCMC driver for the paper's Bayes models (§8) — the reproduction CLI.

Runs the full pipeline on one of the paper's experiment families:
partition data → M independent subposterior chains (any sampler) → combine
(all estimators + baselines) → report L2 error against groundtruth.

  PYTHONPATH=src python -m repro.launch.mcmc_run --model logreg --M 10 \
      --sampler rwmh --samples 2000
  PYTHONPATH=src python -m repro.launch.mcmc_run --model gmm --M 10
  PYTHONPATH=src python -m repro.launch.mcmc_run --model poisson --M 10

Chains run vmapped (one device) or shard_mapped over the data axis of a mesh
(multi-device); either way the sampling stage contains zero cross-chain
collectives.
"""

from __future__ import annotations

import argparse
import time
import zlib
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core import metrics
from repro.core.combiners import (
    available_combiners,
    canonical_combiners,
    filter_options,
    get_combiner,
)
from repro.core.subposterior import make_subposterior_logpdf, partition_data
from repro.models.bayes import gmm, logistic_regression as logreg, poisson_gamma
from repro.samplers.base import run_chain
from repro.samplers.hmc import hmc_kernel
from repro.samplers.mala import mala_kernel
from repro.samplers.rwmh import rwmh_kernel

MODELS: Dict[str, dict] = {
    "logreg": dict(
        gen=lambda key, n: logreg.generate_data(key, n, 50),
        log_prior=logreg.log_prior,
        log_lik=logreg.log_lik,
        d=50,
        n=50_000,
        step=0.012,
    ),
    "gmm": dict(
        gen=lambda key, n: gmm.generate_data(key, n),
        log_prior=gmm.log_prior,
        log_lik=gmm.log_lik,
        d=None,  # model-provided init
        n=50_000,
        step=0.02,
    ),
    "poisson": dict(
        gen=lambda key, n: poisson_gamma.generate_data(key, n),
        log_prior=poisson_gamma.log_prior,
        log_lik=poisson_gamma.log_lik,
        d=2,
        n=50_000,
        step=0.03,
    ),
}


def make_kernel(name: str, logpdf: Callable, step: float):
    if name == "rwmh":
        return rwmh_kernel(logpdf, step_size=step)
    if name == "mala":
        return mala_kernel(logpdf, step_size=step)
    if name == "hmc":
        return hmc_kernel(logpdf, step_size=step, num_integration_steps=10)
    raise KeyError(name)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="logreg", choices=sorted(MODELS))
    ap.add_argument("--M", type=int, default=10)
    ap.add_argument("--samples", type=int, default=2000)
    ap.add_argument("--burn-in", type=int, default=0, help="0 = paper's T/6 rule")
    ap.add_argument("--sampler", default="rwmh", choices=["rwmh", "mala", "hmc"])
    ap.add_argument("--n", type=int, default=0, help="dataset size (0 = paper's)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--groundtruth-samples", type=int, default=4000)
    ap.add_argument(
        "--combiner", default="all", choices=("all",) + available_combiners(),
        help="combination strategy to score (default: every registered combiner)",
    )
    ap.add_argument(
        "--img-batch", type=int, default=1,
        help="independent vmapped IMG index-chains (n_batch) for the exact combiners",
    )
    args = ap.parse_args(argv)

    spec = MODELS[args.model]
    key = jax.random.PRNGKey(args.seed)
    n = args.n or spec["n"]
    data, theta0 = spec["gen"](key, n)
    d = int(theta0.size) if hasattr(theta0, "size") else spec["d"]
    burn = args.burn_in or args.samples // 6  # paper §8: discard first 1/6
    t_start = time.time()

    # --- subposterior chains (embarrassingly parallel: vmap over shards) ----
    shards = partition_data(data, args.M, only=("x",) if args.model == "gmm" else None)

    def one_shard(shard_idx, k):
        shard = (dict(shards, x=shards["x"][shard_idx]) if args.model == "gmm" else jax.tree.map(lambda x: x[shard_idx], shards))
        logpdf = make_subposterior_logpdf(
            spec["log_prior"], spec["log_lik"], shard, args.M
        )
        kern = make_kernel(args.sampler, logpdf, spec["step"])
        # independent keys: reusing one key for the init perturbation AND the
        # chain would correlate the starting point with the first transitions
        k_init, k_run = jax.random.split(k)
        pos, info = run_chain(
            k_run, kern, jnp.zeros(d) + 0.01 * jax.random.normal(k_init, (d,)),
            args.samples, burn_in=burn,
        )
        return pos, info.is_accepted.mean()

    keys = jax.random.split(jax.random.fold_in(key, 1), args.M)
    subsamps, acc = jax.jit(jax.vmap(one_shard))(jnp.arange(args.M), keys)
    t_sample = time.time() - t_start

    # --- groundtruth: single full-data chain --------------------------------
    logpdf_full = make_subposterior_logpdf(
        spec["log_prior"], spec["log_lik"], data, 1
    )
    kern_full = make_kernel(args.sampler, logpdf_full, spec["step"] / jnp.sqrt(args.M))
    gt, _ = jax.jit(
        lambda k: run_chain(
            k, kern_full, jnp.zeros(d), args.groundtruth_samples,
            burn_in=args.groundtruth_samples // 6,
        )
    )(jax.random.fold_in(key, 2))
    t_full = time.time() - t_start - t_sample

    # --- combinations + L2 error --------------------------------------------
    kc = jax.random.fold_in(key, 3)
    results = {}
    T = args.samples

    def l2(s):
        return float(metrics.l2_distance(gt, s))

    names = canonical_combiners() if args.combiner == "all" else [args.combiner]
    t0 = time.time()
    for name in names:
        fn = get_combiner(name)
        # independent RNG per estimator (fold_in by a stable hash of the name
        # — one shared key would correlate the scoreboard entries), and only
        # the options each combiner's signature declares are forwarded
        k_name = jax.random.fold_in(kc, zlib.crc32(name.encode()) & 0x7FFFFFFF)
        opts = filter_options(fn, dict(rescale=True, n_batch=args.img_batch))
        res = fn(k_name, subsamps, T, **opts)
        results[name] = l2(res.samples)
    t_combine = time.time() - t0

    print(f"model={args.model} M={args.M} T={T} sampler={args.sampler} "
          f"acc={float(jnp.mean(acc)):.2f}")
    print(f"timing: {t_sample:.1f}s parallel sampling, {t_full:.1f}s full chain, "
          f"{t_combine:.1f}s all combinations")
    for k_, v in sorted(results.items(), key=lambda kv: kv[1]):
        print(f"  L2({k_:15s}) = {v:.4f}")
    return results


if __name__ == "__main__":
    main()
