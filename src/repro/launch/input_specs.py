"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape) cell.

``build_cell(arch, shape_name, mesh)`` returns everything the dry-run (and the
real launcher) needs to ``jit(...).lower(...)`` one cell:

- ``fn``          the pure step function (cfg closed over)
- ``args``        pytree of jax.ShapeDtypeStruct — *no device allocation*
- ``in_shardings``/``out_shardings`` NamedSharding pytrees
- ``donate``      argnums whose buffers alias outputs (params/opt in train,
                  decode state in serve — matches production memory behaviour)

Shape semantics (assignment brief):
- ``train_4k``/``prefill_32k`` lower the batch through train_step /
  serve_prefill at (global_batch, seq_len).
- ``decode_32k``/``long_500k`` lower ``serve_decode_step``: ONE new token
  against a KV cache of seq_len — not a full forward.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import SHAPES, ShapeSpec, get_config
from repro.data.tokens import make_batch_specs
from repro.distributed import sharding as shd
from repro.models.lm import model as mdl
from repro.models.lm import steps
from repro.models.lm.config import ModelConfig

PyTree = Any

_KEY_SPEC = jax.ShapeDtypeStruct((2,), jnp.uint32)


class CellPlan(NamedTuple):
    arch: str
    shape: ShapeSpec
    cfg: ModelConfig
    fn: Any
    args: Tuple[PyTree, ...]
    in_shardings: Tuple[PyTree, ...]
    donate: Tuple[int, ...]


def shape_by_name(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; known: {[s.name for s in SHAPES]}")


def _specs_of(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def train_state_specs(cfg: ModelConfig) -> Tuple[PyTree, PyTree]:
    """(params, opt_state) as ShapeDtypeStructs — via eval_shape, no alloc."""
    return jax.eval_shape(lambda k: steps.init_train_state(k, cfg), _KEY_SPEC)


def param_specs_only(cfg: ModelConfig) -> PyTree:
    return jax.eval_shape(lambda k: mdl.init_params(k, cfg), _KEY_SPEC)


def _batch_structs(cfg: ModelConfig, batch: int, seq: int, *, labels: bool) -> PyTree:
    specs = make_batch_specs(cfg, batch, seq)
    if not labels:
        specs.pop("labels", None)
    return specs


def _decode_state_specs(cfg: ModelConfig, batch: int, seq_len: int) -> steps.DecodeState:
    cache_dtype = jnp.dtype(cfg.dtype)
    caches = jax.eval_shape(lambda: mdl.init_caches(cfg, batch, seq_len, cache_dtype))
    memory = None
    if cfg.num_encoder_layers:
        memory = jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model), cache_dtype)
    return steps.DecodeState(
        caches=caches,
        position=jax.ShapeDtypeStruct((), jnp.int32),
        last_token=jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        memory=memory,
    )


def _decode_state_shardings(cfg: ModelConfig, mesh: Mesh, state: steps.DecodeState):
    dp = shd.batch_axes(mesh)
    b = state.last_token.shape[0]
    b_ax = dp if b % _axes_size(mesh, dp) == 0 else None
    mem_spec = None
    if state.memory is not None:
        mem_spec = P(b_ax, None, None)
    return steps.DecodeState(
        caches=shd.cache_specs(cfg, mesh, state.caches),
        position=P(),
        last_token=P(b_ax, None),
        memory=mem_spec,
    )


def _axes_size(mesh: Mesh, axes) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def build_cell(arch: str, shape_name: str, mesh: Mesh) -> CellPlan:
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        params, opt = train_state_specs(cfg)
        batch = _batch_structs(cfg, B, S, labels=True)
        fn = functools.partial(_train_fn, cfg=cfg)
        p_spec = shd.param_specs(cfg, mesh, params)
        o_spec = shd.opt_specs(cfg, mesh, opt, p_spec)
        b_spec = shd.batch_specs(cfg, mesh, batch)
        return CellPlan(
            arch=arch,
            shape=shape,
            cfg=cfg,
            fn=fn,
            args=(params, opt, batch),
            in_shardings=(p_spec, o_spec, b_spec),
            donate=(0, 1),
        )

    if shape.kind == "prefill":
        params = param_specs_only(cfg)
        batch = _batch_structs(cfg, B, S, labels=False)
        # VLM: the image-patch prefix is prepended to the prompt, so the
        # emitted caches must hold S + num_image_tokens entries.
        fn = functools.partial(_prefill_fn, cfg=cfg, max_len=S + cfg.num_image_tokens)
        p_spec = shd.param_specs(cfg, mesh, params)
        b_spec = shd.batch_specs(cfg, mesh, batch)
        return CellPlan(
            arch=arch,
            shape=shape,
            cfg=cfg,
            fn=fn,
            args=(params, batch),
            in_shardings=(p_spec, b_spec),
            donate=(),
        )

    # decode: one token against a seq_len cache
    params = param_specs_only(cfg)
    state = _decode_state_specs(cfg, B, S)
    fn = functools.partial(_decode_fn, cfg=cfg)
    p_spec = shd.param_specs(cfg, mesh, params)
    s_spec = _decode_state_shardings(cfg, mesh, state)
    return CellPlan(
        arch=arch,
        shape=shape,
        cfg=cfg,
        fn=fn,
        args=(params, state),
        in_shardings=(p_spec, s_spec),
        donate=(1,),
    )


# module-level step wrappers (picklable, stable identity for jit caching)


def _train_fn(params, opt_state, batch, *, cfg):
    return steps.train_step(params, opt_state, batch, cfg)


def _prefill_fn(params, batch, *, cfg, max_len):
    return steps.serve_prefill(params, cfg, batch, max_len)


def _decode_fn(params, state, *, cfg):
    return steps.serve_decode_step(params, cfg, state)


def input_specs(arch: str, shape_name: str) -> PyTree:
    """The brief's entry point: ShapeDtypeStruct stand-ins for every model
    input of this cell (weak-type-correct, shardable, no allocation)."""
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    if shape.kind == "train":
        return _batch_structs(cfg, shape.global_batch, shape.seq_len, labels=True)
    if shape.kind == "prefill":
        return _batch_structs(cfg, shape.global_batch, shape.seq_len, labels=False)
    return _decode_state_specs(cfg, shape.global_batch, shape.seq_len)
