"""Training driver — synchronous AdamW *or* EP-MCMC (the paper) on one mesh.

Modes
-----
``--mode sgd``     classic data-parallel training: one θ, gradients averaged
                   over the data axes every step (the baseline whose
                   collective bytes the paper's mode deletes).
``--mode epmcmc``  the paper: M = |data axes| independent subposterior pSGLD
                   chains, zero cross-chain collectives during sampling,
                   parametric (BvM) combination at the end.

Fault tolerance: checkpoints every ``--ckpt-every`` steps via the async
:class:`repro.checkpoint.Checkpointer`; ``--resume`` restarts from the newest
manifest (elastic: ``--chains`` may differ from the checkpoint's). Data is a
pure function of (seed, shard, step): a restarted run replays the exact
stream; a re-sharded run reads disjoint shards by construction.

CPU smoke example (also examples/lm_bayes_sgld.py):
  PYTHONPATH=src python -m repro.launch.train --arch mamba2_130m --reduced \
      --mode epmcmc --steps 30 --batch 4 --seq 128
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer, latest_step, restore
from repro.configs import ALIASES, get_config
from repro.data.tokens import TokenStream
from repro.distributed import epmcmc
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.lm import steps as lm_steps
from repro.models.lm.config import reduced


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", default="epmcmc", choices=["sgd", "epmcmc", "adamw"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8, help="per-chain batch size")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--chains", type=int, default=0, help="0 = one per data-axis index")
    ap.add_argument("--step-size", type=float, default=1e-5)
    ap.add_argument("--burn-in", type=int, default=0)
    ap.add_argument("--shard-tokens", type=float, default=0.0,
                    help="tokens per data shard N_m (0 = batch*seq*100)")
    ap.add_argument("--reduced", action="store_true", help="CPU smoke config")
    ap.add_argument("--mesh", default="host", choices=["host", "pod", "multipod"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    return ap


def main(argv=None) -> dict:
    args = build_argparser().parse_args(argv)
    cfg = get_config(ALIASES.get(args.arch, args.arch))
    if args.reduced:
        cfg = reduced(cfg)

    mesh = (
        make_host_mesh()
        if args.mesh == "host"
        else make_production_mesh(multi_pod=(args.mesh == "multipod"))
    )
    n_chains = args.chains or max(epmcmc.num_chains(mesh), 1)
    key = jax.random.PRNGKey(args.seed)
    shard_tokens = args.shard_tokens or float(args.batch * args.seq * 100)

    streams = [
        TokenStream(cfg.vocab_size, args.batch, args.seq, seed=args.seed,
                    shard_index=c, num_shards=n_chains)
        for c in range(n_chains)
    ]

    def stacked_batch(step: int):
        batches = [s.batch(step) for s in streams]
        return {
            k: jnp.stack([b[k] for b in batches]) for k in batches[0]
        }

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0

    if args.mode in ("epmcmc", "sgd"):
        state = epmcmc.init_state(key, cfg, n_chains)
        if ckpt and args.resume and latest_step(args.ckpt_dir) is not None:
            state, meta = restore(args.ckpt_dir, template=state)
            start_step = int(meta.get("train_step", 0))
            print(f"resumed from step {start_step}")
        step_fn = (
            epmcmc.epmcmc_step if args.mode == "epmcmc" else epmcmc.sgd_baseline_step
        )
        kwargs = dict(
            num_shards=n_chains,
            shard_tokens=shard_tokens,
            step_size=args.step_size,
        )
        if args.mode == "epmcmc":
            kwargs["burn_in"] = args.burn_in
        jitted = jax.jit(functools.partial(step_fn, cfg=cfg, **kwargs), donate_argnums=(0,))
        metrics = {}
        t0 = time.time()
        for step in range(start_step, args.steps):
            state, metrics = jitted(state, stacked_batch(step))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(
                    f"step {step:5d} loss={float(jnp.mean(metrics['loss_per_chain'])):.4f} "
                    f"({(time.time()-t0)/max(step-start_step+1,1):.2f}s/step)"
                )
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(
                    step + 1, state,
                    metadata={"train_step": step + 1, "num_chains": n_chains,
                              "arch": cfg.name, "mode": args.mode},
                )
        if args.mode == "epmcmc":
            moments = jax.jit(epmcmc.combine_parametric_diag)(state)
            gm = jax.tree.leaves(moments.mean)
            print(
                "combined posterior (parametric/BvM): "
                f"{sum(g.size for g in gm)} parameter dims, "
                f"mean|μ|={float(jnp.mean(jnp.abs(gm[0]))):.4f}"
            )
        if ckpt:
            ckpt.close()
        return {"loss": float(jnp.mean(metrics["loss_per_chain"])) if "loss_per_chain" in metrics else float("nan")}

    # plain AdamW path (per-chip data parallel through jit; used by examples)
    params, opt = lm_steps.init_train_state(key, cfg)
    if ckpt and args.resume and latest_step(args.ckpt_dir) is not None:
        (params, opt), meta = restore(args.ckpt_dir, template=(params, opt))
        start_step = int(meta.get("train_step", 0))
    train = jax.jit(
        functools.partial(lm_steps.train_step, cfg=cfg), donate_argnums=(0, 1)
    )
    stream = TokenStream(cfg.vocab_size, args.batch, args.seq, seed=args.seed)
    metrics = {}
    for step in range(start_step, args.steps):
        params, opt, metrics = train(params, opt, stream.batch(step))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f}")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt), metadata={"train_step": step + 1})
    if ckpt:
        ckpt.close()
    return {"loss": float(metrics.get("loss", jnp.nan))}


if __name__ == "__main__":
    main()
