import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without hardware:
``jit(step, in_shardings=...).lower(**input_specs).compile()`` must succeed on
the single-pod (16×16) and multi-pod (2×16×16) production meshes for all 40
assigned cells (minus the DESIGN.md §4 long_500k skips, which are recorded,
not dropped). Per cell we persist:

- ``memory_analysis()``  per-device bytes (argument/output/temp/peak)
- ``cost_analysis()``    XLA's flops/bytes (NOTE: visits while bodies once)
- loop-aware HLO stats   flops / HBM-proxy bytes / collective bytes × trips
  (:mod:`repro.launch.hlo_stats` — the numbers §Roofline uses)
- the three roofline terms vs v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link

Usage:
  python -m repro.launch.dryrun                        # everything, resumable
  python -m repro.launch.dryrun --arch mamba2_130m --shape train_4k --mesh pod
  python -m repro.launch.dryrun --list                 # show cells + skips

Results accumulate in ``results/dryrun/<mesh>/<arch>--<shape>.json`` so an
interrupted sweep resumes where it stopped (--force recomputes).
"""

import argparse
import json
import pathlib
import sys
import time
import traceback

import jax

from repro.configs import ALIASES, ARCH_IDS, SHAPES, all_cells, get_config
from repro.launch import hlo_stats
from repro.launch.input_specs import build_cell
from repro.launch.mesh import make_production_mesh

# v5e hardware constants (assignment brief)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link (ICI)

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per step; decode D=B·1."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens  # forward only
    return 2.0 * n * shape.global_batch  # one token / sequence, forward only


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, force: bool = False) -> dict:
    out_dir = RESULTS_DIR / mesh_kind
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch}--{shape_name}.json"
    if out_path.exists() and not force:
        rec = json.loads(out_path.read_text())
        if rec.get("status") in ("ok", "skip"):
            print(f"[cached] {mesh_kind} {arch} {shape_name}: {rec['status']}")
            return rec

    cfg = get_config(arch)
    for cell in all_cells():
        if cell.arch == arch and cell.shape.name == shape_name and cell.skip:
            rec = {
                "arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skip", "reason": cell.skip,
            }
            out_path.write_text(json.dumps(rec, indent=2))
            print(f"[skip]   {mesh_kind} {arch} {shape_name}: {cell.skip}")
            return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = mesh.devices.size
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips}
    try:
        plan = build_cell(arch, shape_name, mesh)
        from repro.distributed.sharding import to_shardings

        in_shardings = tuple(to_shardings(mesh, s) for s in plan.in_shardings)
        with mesh:
            jitted = jax.jit(
                plan.fn,
                in_shardings=in_shardings,
                donate_argnums=plan.donate,
            )
            lowered = jitted.lower(*plan.args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        print(mem)  # proves it fits
        print({k: cost[k] for k in ("flops", "bytes accessed") if k in cost})

        hlo_text = compiled.as_text()
        hlo_dir = RESULTS_DIR.parent / "hlo" / mesh_kind
        hlo_dir.mkdir(parents=True, exist_ok=True)
        import gzip

        with gzip.open(hlo_dir / f"{arch}--{shape_name}.hlo.gz", "wt") as f:
            f.write(hlo_text)  # offline roofline recomputation without recompiling

        stats = hlo_stats.analyze(hlo_text)
        # hlo_stats quantities are per-device (post-SPMD partitioned program)
        compute_s = stats.flops / PEAK_FLOPS
        memory_s = stats.bytes_accessed / HBM_BW
        collective_s = stats.collective_bytes / LINK_BW
        terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
        dominant = max(terms, key=terms.get)

        mf = model_flops(cfg, plan.shape)
        hlo_flops_global = stats.flops * chips
        rec.update(
            status="ok",
            lower_s=round(t_lower - t0, 1),
            compile_s=round(t_compile - t_lower, 1),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
            cost_analysis={
                "flops": cost.get("flops"),
                "bytes_accessed": cost.get("bytes accessed"),
            },
            hlo={
                "flops_per_device": stats.flops,
                "bytes_per_device": stats.bytes_accessed,
                "bytes_all_ops_per_device": stats.bytes_all_ops,
                "collective_bytes_per_device": stats.collective_bytes,
                "collective_bytes_by_kind": stats.collective_bytes_by_kind,
                "collective_count": stats.collective_count,
            },
            roofline={
                **{k: float(v) for k, v in terms.items()},
                "dominant": dominant,
                "bound_s": float(max(terms.values())),
            },
            model_flops=mf,
            useful_flops_ratio=(mf / hlo_flops_global) if hlo_flops_global else None,
        )
        print(
            f"[ok]     {mesh_kind} {arch} {shape_name}: "
            f"compute={compute_s*1e3:.2f}ms memory={memory_s*1e3:.2f}ms "
            f"collective={collective_s*1e3:.2f}ms dominant={dominant} "
            f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)"
        )
    except Exception as e:  # a failure here is a bug in our sharding config
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[ERROR]  {mesh_kind} {arch} {shape_name}: {e}", file=sys.stderr)
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name (default: all)")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for cell in all_cells():
            status = f"SKIP: {cell.skip}" if cell.skip else "run"
            print(f"{cell.arch:24s} {cell.shape.name:12s} {status}")
        return

    archs = [ALIASES.get(args.arch, args.arch)] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else [s.name for s in SHAPES]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    n_err = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh_kind, force=args.force)
                n_err += rec.get("status") == "error"
    if n_err:
        sys.exit(f"{n_err} cells FAILED")
    print("all requested cells passed")


if __name__ == "__main__":
    main()
