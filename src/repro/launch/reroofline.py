"""Recompute roofline terms offline from the dry-run's saved HLO text.

The dry-run persists ``results/hlo/<mesh>/<arch>--<shape>.hlo.gz`` exactly so
the traffic model in :mod:`repro.launch.hlo_stats` can be iterated without
recompiling 64 cells. This script re-analyzes every saved HLO and patches the
``hlo``/``roofline`` blocks of the corresponding JSON record in place.

  PYTHONPATH=src python -m repro.launch.reroofline
"""

from __future__ import annotations

import gzip
import json
import pathlib

from repro.launch import hlo_stats
from repro.launch.dryrun import HBM_BW, LINK_BW, PEAK_FLOPS, RESULTS_DIR


def main() -> None:
    hlo_root = RESULTS_DIR.parent / "hlo"
    n = 0
    for gz in sorted(hlo_root.glob("*/*.hlo.gz")):
        mesh_kind = gz.parent.name
        cell = gz.name.replace(".hlo.gz", "")
        json_path = RESULTS_DIR / mesh_kind / f"{cell}.json"
        if not json_path.exists():
            continue
        rec = json.loads(json_path.read_text())
        if rec.get("status") != "ok":
            continue
        with gzip.open(gz, "rt") as f:
            stats = hlo_stats.analyze(f.read())
        chips = rec["chips"]
        terms = {
            "compute_s": stats.flops / PEAK_FLOPS,
            "memory_s": stats.bytes_accessed / HBM_BW,
            "collective_s": stats.collective_bytes / LINK_BW,
        }
        dominant = max(terms, key=terms.get)
        rec["hlo"] = {
            "flops_per_device": stats.flops,
            "bytes_per_device": stats.bytes_accessed,
            "bytes_all_ops_per_device": stats.bytes_all_ops,
            "collective_bytes_per_device": stats.collective_bytes,
            "collective_bytes_by_kind": stats.collective_bytes_by_kind,
            "collective_count": stats.collective_count,
        }
        rec["roofline"] = {
            **{k: float(v) for k, v in terms.items()},
            "dominant": dominant,
            "bound_s": float(max(terms.values())),
        }
        mf = rec.get("model_flops")
        if mf:
            g = stats.flops * chips
            rec["useful_flops_ratio"] = (mf / g) if g else None
        json_path.write_text(json.dumps(rec, indent=2))
        n += 1
        print(f"re-analyzed {mesh_kind}/{cell}: dominant={dominant} "
              f"mem={terms['memory_s']*1e3:.1f}ms comp={terms['compute_s']*1e3:.1f}ms "
              f"coll={terms['collective_s']*1e3:.1f}ms")
    print(f"{n} cells updated")


if __name__ == "__main__":
    main()
