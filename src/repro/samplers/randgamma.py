"""Fast exact Gamma sampling — Marsaglia & Tsang (2000) squeeze-rejection.

``jax.random.gamma`` is implemented via the Gamma CDF's Newton inversion to
stay differentiable in the shape parameter; that costs ~an order of magnitude
more per draw than rejection sampling and dominates the conjugate-Gibbs
sweeps of the Poisson–gamma model (q_i | a,b,x ~ Gamma(a+x_i, ·) is one
n-vector of gamma draws per sweep). MCMC never differentiates through its
own noise, so the conditionals can use the classic sampler instead:

    d = α − 1/3,  c = 1/sqrt(9d),  v = (1 + c·x)³ with x ~ N(0,1):
    accept v > 0 with  log u < x²/2 + d − d·v + d·log v   →   d·v ~ Gamma(α)

for α ≥ 1 (acceptance ≥ 95%), with Stirling's boost for α < 1:
Gamma(α) = Gamma(α+1) · U^{1/α}. Exact — the accepted density is the target,
not an approximation; only the RNG stream differs from ``jax.random.gamma``.

The rejection loop is a batched ``while_loop``: all lanes redraw until every
lane has accepted (expected < 2 rounds), which vmaps/shard_maps cleanly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gamma(key: jax.Array, alpha, shape=None, dtype=jnp.float32) -> jnp.ndarray:
    """Exact Gamma(alpha, 1) draws; drop-in for ``jax.random.gamma`` where
    differentiability in ``alpha`` is not needed (e.g. Gibbs conditionals).
    """
    alpha = jnp.asarray(alpha, dtype)
    if shape is None:
        shape = alpha.shape
    a = jnp.broadcast_to(alpha, shape)
    k_boost, k_loop = jax.random.split(key)

    small = a < 1.0
    a1 = jnp.where(small, a + 1.0, a)  # boosted shape for the α<1 lanes
    d = a1 - 1.0 / 3.0
    c = 1.0 / jnp.sqrt(9.0 * d)

    def cond(state):
        _, _, done = state
        return ~jnp.all(done)

    def body(state):
        k, val, done = state
        k, k_norm, k_unif = jax.random.split(k, 3)
        x = jax.random.normal(k_norm, shape, dtype)
        v = (1.0 + c * x) ** 3
        u = jax.random.uniform(k_unif, shape, dtype)
        # squeeze-free exact test; log v guarded for the rejected v ≤ 0 lanes
        logv = jnp.where(v > 0.0, jnp.log(jnp.maximum(v, jnp.finfo(dtype).tiny)), 0.0)
        ok = (v > 0.0) & (jnp.log(u) < 0.5 * x * x + d - d * v + d * logv)
        val = jnp.where(done | ~ok, val, d * v)
        return k, val, done | ok

    _, val, _ = jax.lax.while_loop(
        cond, body, (k_loop, jnp.zeros(shape, dtype), jnp.zeros(shape, bool))
    )
    # Gamma(α) = Gamma(α+1) · U^{1/α} for α < 1 (minval keeps U^{1/α} > 0)
    u_boost = jax.random.uniform(
        k_boost, shape, dtype, minval=jnp.finfo(dtype).tiny
    )
    boost = u_boost ** (1.0 / jnp.maximum(a, jnp.finfo(dtype).tiny))
    return jnp.where(small, val * boost, val)
