"""Hamiltonian Monte Carlo with leapfrog integration + Stan-style warmup.

The paper samples subposteriors with Stan's HMC/NUTS; this is the in-JAX
equivalent. ``window_adaptation`` performs dual-averaging step-size adaptation
(target accept 0.8) with Welford diagonal-metric estimation — a simplified
two-phase version of Stan's windowed scheme that runs entirely under
``lax.scan`` (jit-able, so it can run per-chain inside ``shard_map``).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.samplers.base import (
    LogDensityFn,
    MCMCKernel,
    PyTree,
    StepInfo,
    tree_add,
    tree_random_normal,
    tree_scale,
    tree_vdot,
    tree_where,
)


class HMCState(NamedTuple):
    position: PyTree
    log_density: jnp.ndarray
    grad: PyTree


def _kinetic(momentum: PyTree, inv_mass: PyTree) -> jnp.ndarray:
    return 0.5 * tree_vdot(momentum, jax.tree.map(jnp.multiply, inv_mass, momentum))


def hmc_kernel(
    logdensity: LogDensityFn,
    step_size: float | jnp.ndarray = 0.1,
    num_integration_steps: int = 16,
    inv_mass: Optional[PyTree] = None,
    *,
    jitter_steps: bool = True,
) -> MCMCKernel:
    """Fixed-length HMC. ``jitter_steps`` uniformly jitters the trajectory
    length in [1, L] per transition (cheap anti-resonance, standard practice).
    """
    value_and_grad = jax.value_and_grad(logdensity)

    def init(position: PyTree) -> HMCState:
        ld, g = value_and_grad(position)
        return HMCState(position=position, log_density=ld, grad=g)

    def step(key: jax.Array, state: HMCState):
        k_mom, k_acc, k_len = jax.random.split(key, 3)
        im = (
            inv_mass
            if inv_mass is not None
            else jax.tree.map(jnp.ones_like, state.position)
        )
        # p ~ N(0, M): sample standard normal and scale by sqrt(mass)=1/sqrt(im)
        raw = tree_random_normal(k_mom, state.position)
        momentum = jax.tree.map(lambda r, i: r / jnp.sqrt(i), raw, im)
        if jitter_steps:
            L = jax.random.randint(k_len, (), 1, num_integration_steps + 1)
        else:
            L = num_integration_steps

        def do_leapfrog(q, p, g, n):
            def body(carry, i):
                q, p, g, ld = carry
                active = i < n
                p_half = tree_add(p, tree_scale(0.5 * step_size, g))
                q_new = tree_add(
                    q, tree_scale(step_size, jax.tree.map(jnp.multiply, im, p_half))
                )
                ld_new, g_new = value_and_grad(q_new)
                p_new = tree_add(p_half, tree_scale(0.5 * step_size, g_new))
                q = tree_where(active, q_new, q)
                p = tree_where(active, p_new, p)
                g = tree_where(active, g_new, g)
                ld = jnp.where(active, ld_new, ld)
                return (q, p, g, ld), None

            (q, p, g, ld), _ = jax.lax.scan(
                body, (q, p, g, state.log_density), jnp.arange(num_integration_steps)
            )
            return q, p, g, ld

        q_new, p_new, g_new, ld_new = do_leapfrog(
            state.position, momentum, state.grad, L
        )
        h_old = -state.log_density + _kinetic(momentum, im)
        h_new = -ld_new + _kinetic(p_new, im)
        log_ratio = h_old - h_new
        log_ratio = jnp.where(jnp.isfinite(log_ratio), log_ratio, -jnp.inf)
        accept_prob = jnp.minimum(1.0, jnp.exp(jnp.minimum(log_ratio, 0.0)))
        accepted = jnp.log(jax.random.uniform(k_acc)) < log_ratio
        new_state = HMCState(
            position=tree_where(accepted, q_new, state.position),
            log_density=jnp.where(accepted, ld_new, state.log_density),
            grad=tree_where(accepted, g_new, state.grad),
        )
        return new_state, StepInfo(accept_prob, accepted, new_state.log_density)

    return MCMCKernel(init=init, step=step)


# ---------------------------------------------------------------------------
# warmup: dual averaging (shared, repro.samplers.adaptation) + Welford metric
# ---------------------------------------------------------------------------

from repro.samplers.adaptation import (  # noqa: E402  (re-export for compat)
    DualAveragingState,
    da_init,
    da_update,
)


def window_adaptation(
    logdensity: LogDensityFn,
    position: PyTree,
    key: jax.Array,
    num_steps: int = 500,
    *,
    num_integration_steps: int = 16,
    initial_step_size: float = 0.1,
    target_accept: float = 0.8,
) -> Tuple[PyTree, jnp.ndarray, PyTree]:
    """Two-phase warmup. Returns (position, step_size, inv_mass).

    Phase 1 (first half): adapt ε by dual averaging with unit metric while
    accumulating Welford variance of the position. Phase 2 (second half):
    freeze the diagonal metric to the Welford variance, re-adapt ε.
    """
    value_and_grad = jax.value_and_grad(logdensity)
    half = num_steps // 2

    # A light inline HMC step so ε and the metric can be traced values.
    def hmc_step(key, q, ld, g, eps, inv_mass):
        k_mom, k_acc, k_len = jax.random.split(key, 3)
        raw = tree_random_normal(k_mom, q)
        p = jax.tree.map(lambda r, i: r / jnp.sqrt(i), raw, inv_mass)
        n = jax.random.randint(k_len, (), 1, num_integration_steps + 1)

        def body(carry, i):
            q_, p_, g_, ld_ = carry
            active = i < n
            p_half = tree_add(p_, tree_scale(0.5 * eps, g_))
            q_new = tree_add(q_, tree_scale(eps, jax.tree.map(jnp.multiply, inv_mass, p_half)))
            ld_new, g_new = value_and_grad(q_new)
            p_new = tree_add(p_half, tree_scale(0.5 * eps, g_new))
            return (
                tree_where(active, q_new, q_),
                tree_where(active, p_new, p_),
                tree_where(active, g_new, g_),
                jnp.where(active, ld_new, ld_),
            ), None

        (q2, p2, g2, ld2), _ = jax.lax.scan(
            body, (q, p, g, ld), jnp.arange(num_integration_steps)
        )
        log_ratio = (-ld + _kinetic(p, inv_mass)) - (-ld2 + _kinetic(p2, inv_mass))
        log_ratio = jnp.where(jnp.isfinite(log_ratio), log_ratio, -jnp.inf)
        a_prob = jnp.minimum(1.0, jnp.exp(jnp.minimum(log_ratio, 0.0)))
        acc = jnp.log(jax.random.uniform(k_acc)) < log_ratio
        return (
            tree_where(acc, q2, q),
            jnp.where(acc, ld2, ld),
            tree_where(acc, g2, g),
            a_prob,
        )

    ld0, g0 = value_and_grad(position)
    unit_mass = jax.tree.map(jnp.ones_like, position)

    # Phase 1 -----------------------------------------------------------
    def phase1(carry, key):
        q, ld, g, da, w_count, w_mean, w_m2 = carry
        eps = jnp.exp(da.log_eps)
        q, ld, g, a_prob = hmc_step(key, q, ld, g, eps, unit_mass)
        da = da_update(da, a_prob, target_accept)
        # Welford over positions
        w_count = w_count + 1.0
        delta = jax.tree.map(jnp.subtract, q, w_mean)
        w_mean = jax.tree.map(lambda m, d: m + d / w_count, w_mean, delta)
        delta2 = jax.tree.map(jnp.subtract, q, w_mean)
        w_m2 = jax.tree.map(lambda m2, d, d2: m2 + d * d2, w_m2, delta, delta2)
        return (q, ld, g, da, w_count, w_mean, w_m2), a_prob

    zeros = jax.tree.map(jnp.zeros_like, position)
    carry = (
        position,
        ld0,
        g0,
        da_init(initial_step_size),
        jnp.zeros(()),
        zeros,
        jax.tree.map(jnp.zeros_like, position),
    )
    keys1 = jax.random.split(key, half + 1)
    carry, _ = jax.lax.scan(phase1, carry, keys1[1:])
    q, ld, g, da, w_count, w_mean, w_m2 = carry
    var = jax.tree.map(
        lambda m2: m2 / jnp.maximum(w_count - 1.0, 1.0) + 1e-6, w_m2
    )  # inv_mass = posterior variance (diag metric)

    # Phase 2 -----------------------------------------------------------
    def phase2(carry, key):
        q, ld, g, da = carry
        eps = jnp.exp(da.log_eps)
        q, ld, g, a_prob = hmc_step(key, q, ld, g, eps, var)
        da = da_update(da, a_prob, target_accept)
        return (q, ld, g, da), a_prob

    keys2 = jax.random.split(keys1[0], num_steps - half)
    da2 = da_init(initial_step_size)._replace(
        log_eps=da.log_eps_avg, mu=jnp.log(10.0) + da.log_eps_avg
    )
    (q, ld, g, da), _ = jax.lax.scan(phase2, (q, ld, g, da2), keys2)
    step_size = jnp.exp(da.log_eps_avg)
    return q, step_size, var
