"""Any-MCMC substrate (paper criterion 3: each machine may use any sampler).

All kernels share the ``(init, step)`` protocol of :mod:`repro.samplers.base`
and are pytree-generic; chains are driven by :func:`repro.samplers.base.run_chain`
(jit/scan) and batched with :func:`repro.samplers.base.run_chains` (vmap).
"""

from repro.samplers import base as base  # noqa: F401
from repro.samplers.base import run_chain, run_chains  # noqa: F401
from repro.samplers.gibbs import gibbs_kernel  # noqa: F401
from repro.samplers.hmc import hmc_kernel, window_adaptation  # noqa: F401
from repro.samplers.mala import mala_kernel  # noqa: F401
from repro.samplers.rwmh import rwmh_kernel  # noqa: F401
from repro.samplers.sgld import sgld_kernel  # noqa: F401
