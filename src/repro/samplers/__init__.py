"""Any-MCMC substrate (paper criterion 3: each machine may use any sampler).

Registry convention
-------------------
Samplers live behind a name registry, mirroring ``repro.core.combiners``:
implementations self-register with ``@register_sampler("name")`` and share one
uniform factory signature

    factory(logpdf, *, step_size, **options) -> MCMCKernel

so every consumer (the ``mcmc_run`` pipeline's ``--sampler`` flag, benchmarks,
conformance tests) resolves kernels with ``get_sampler(name)`` and forwards a
single option dict filtered per factory signature (``filter_options`` —
``**_ignored`` marks tolerated-but-unused keywords). Built-ins: ``rwmh``,
``mala``, ``hmc``, ``gibbs`` (Metropolis-within-Gibbs over model-supplied
block updates) and ``sgld`` (minibatch Langevin over
``make_minibatch_logpdf`` gradients). Adding a sampler here makes it
reachable from every consumer at once.

Warmup convention
-----------------
Registered samplers carry a ``SamplerSpec(adaptive, target_accept)``.
Adaptive kernels are warmed up by dual averaging: pass a *factory*
``step_size -> MCMCKernel`` plus ``warmup=n`` to ``run_chain`` and the step
size adapts toward ``target_accept`` per chain under ``lax.scan`` (vmap- and
shard_map-compatible; see :mod:`repro.samplers.adaptation`) — hand-tuned
per-model step constants are dead. Non-adaptive samplers (``gibbs``,
``sgld``) treat warmup steps as extra burn-in.

All kernels share the ``(init, step)`` protocol of :mod:`repro.samplers.base`
and are pytree-generic; chains are driven by :func:`repro.samplers.base.run_chain`
(jit/scan) and batched with :func:`repro.samplers.base.run_chains` (vmap).
"""

from repro.samplers import base as base  # noqa: F401
from repro.samplers.adaptation import (  # noqa: F401
    DualAveragingState,
    da_init,
    da_update,
    warmup_chain,
)
from repro.samplers.base import run_chain, run_chains  # noqa: F401
from repro.samplers.gibbs import gibbs_kernel, mh_within_gibbs_update  # noqa: F401
from repro.samplers.hmc import hmc_kernel, window_adaptation  # noqa: F401
from repro.samplers.mala import mala_kernel  # noqa: F401
from repro.samplers.registry import (  # noqa: F401
    SamplerSpec,
    available_samplers,
    canonical_samplers,
    filter_options,
    get_sampler,
    register_sampler,
    sampler_spec,
)
from repro.samplers.rwmh import rwmh_kernel  # noqa: F401
from repro.samplers.sgld import sgld_kernel  # noqa: F401
