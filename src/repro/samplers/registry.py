"""Sampler registry: every MCMC kernel behind one uniform factory signature.

A *sampler factory* is any callable

    factory(logpdf, *, step_size, **options) -> MCMCKernel

decorated with :func:`register_sampler`. Consumers (the ``mcmc_run`` pipeline,
benchmarks, conformance tests) resolve samplers by name with
:func:`get_sampler` and enumerate them with :func:`available_samplers` —
exactly the architecture of ``repro.core.combiners``: adding a sampler here
makes it reachable from every consumer at once, including the CLI's
``--sampler`` flag.

Each registration carries metadata in a :class:`SamplerSpec`:

- ``adaptive``: whether the kernel's acceptance probability responds to
  ``step_size`` — adaptive samplers are eligible for the dual-averaging
  warmup phase (``run_chain(..., warmup=n)`` with a ``step_size -> kernel``
  factory); non-adaptive ones (Gibbs always accepts, SGLD never rejects)
  treat warmup steps as extra burn-in.
- ``target_accept``: the warmup's target acceptance rate (sampler-specific
  optima: ~0.35 for random-walk MH, ~0.55 for MALA, 0.8 for HMC).

Option-forwarding follows the combiners' convention: callers broadcasting one
option dict over many samplers filter it per factory signature with
:func:`filter_options`; ``**_ignored`` in a factory marks tolerated-but-unused
keywords, which are dropped here rather than silently swallowed there.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.utils.options import filter_kwargs

from repro.samplers.base import (
    LogDensityFn,
    MCMCKernel,
    PyTree,
    StepInfo,
)
from repro.samplers.gibbs import BlockUpdate, gibbs_kernel
from repro.samplers.hmc import hmc_kernel
from repro.samplers.mala import mala_kernel
from repro.samplers.rwmh import rwmh_kernel
from repro.samplers.sgld import sgld_kernel

SamplerFactory = Callable[..., MCMCKernel]


class SamplerSpec(NamedTuple):
    """Registry entry: factory + the metadata the warmup phase needs."""

    name: str
    factory: SamplerFactory
    adaptive: bool
    target_accept: float


_REGISTRY: Dict[str, SamplerSpec] = {}
_CANONICAL: Dict[str, SamplerSpec] = {}  # primary names only (no aliases)


def register_sampler(
    name: str,
    *aliases: str,
    adaptive: bool = True,
    target_accept: float = 0.8,
) -> Callable[[SamplerFactory], SamplerFactory]:
    """Decorator: add a sampler factory to the registry under ``name``."""

    def deco(fn: SamplerFactory) -> SamplerFactory:
        spec = SamplerSpec(
            name=name, factory=fn, adaptive=adaptive, target_accept=target_accept
        )
        for key in (name, *aliases):
            if key in _REGISTRY:
                raise ValueError(f"sampler {key!r} already registered")
            _REGISTRY[key] = spec
        _CANONICAL[name] = spec
        return fn

    return deco


def sampler_spec(name: str) -> SamplerSpec:
    """Resolve the full registry entry (raises KeyError with choices)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown sampler {name!r}; available: {', '.join(available_samplers())}"
        ) from None


def get_sampler(name: str) -> SamplerFactory:
    """Resolve a sampler factory by registry name."""
    return sampler_spec(name).factory


def available_samplers() -> Tuple[str, ...]:
    """All registered sampler names (aliases included), sorted."""
    return tuple(sorted(_REGISTRY))


def canonical_samplers() -> Tuple[str, ...]:
    """Primary registration names only (aliases dropped), sorted."""
    return tuple(sorted(_CANONICAL))


def filter_options(factory: SamplerFactory, options: Dict[str, Any]) -> Dict[str, Any]:
    """Keep only the keyword options the factory's signature declares.

    Same convention as ``repro.core.combiners.filter_options`` — both
    delegate to :func:`repro.utils.options.filter_kwargs`: ``**options`` (no
    underscore) marks a passthrough wrapper that receives everything;
    ``**_ignored`` marks tolerated-but-unused keywords, dropped here.
    """
    return filter_kwargs(factory, options)


# ---------------------------------------------------------------------------
# built-in registrations
# ---------------------------------------------------------------------------


@register_sampler("rwmh", "mh", target_accept=0.35)
def rwmh(
    logpdf: LogDensityFn,
    *,
    step_size: float | jnp.ndarray = 0.1,
    proposal_fn: Optional[Callable[[jax.Array, PyTree], PyTree]] = None,
    **_ignored,
) -> MCMCKernel:
    """Random-walk Metropolis–Hastings (paper §2's example sampler)."""
    return rwmh_kernel(logpdf, step_size=step_size, proposal_fn=proposal_fn)


@register_sampler("mala", target_accept=0.55)
def mala(
    logpdf: LogDensityFn, *, step_size: float | jnp.ndarray = 0.05, **_ignored
) -> MCMCKernel:
    """Metropolis-adjusted Langevin."""
    return mala_kernel(logpdf, step_size=step_size)


@register_sampler("hmc", target_accept=0.8)
def hmc(
    logpdf: LogDensityFn,
    *,
    step_size: float | jnp.ndarray = 0.1,
    num_integration_steps: int = 10,
    inv_mass: Optional[PyTree] = None,
    **_ignored,
) -> MCMCKernel:
    """Fixed-length HMC with jittered trajectory length."""
    return hmc_kernel(
        logpdf,
        step_size=step_size,
        num_integration_steps=num_integration_steps,
        inv_mass=inv_mass,
    )


@register_sampler("gibbs", "metropolis_within_gibbs", adaptive=False)
def gibbs(
    logpdf: Optional[LogDensityFn],
    *,
    step_size: float = 0.1,
    block_updates: Sequence[BlockUpdate] = (),
    **_ignored,
) -> MCMCKernel:
    """(Metropolis-within-)Gibbs over model-supplied block updates.

    The blocks come from the model (``BayesModel.gibbs_blocks`` builds them
    against a concrete data shard — e.g. the Poisson–gamma conjugate
    ``q_i | a,b,x`` updates of paper §8.3); ``step_size`` is the scale the
    model used for its MH-within-Gibbs blocks and is accepted here only for
    signature uniformity. ``logpdf`` may be ``None``: Gibbs positions are
    often extended pytrees (shard-local latents) the flat-θ log-density
    cannot score, and the kernel only uses it for diagnostics.
    """
    if not block_updates:
        raise ValueError(
            "gibbs requires model-supplied block_updates "
            "(see BayesModel.gibbs_blocks)"
        )
    return gibbs_kernel(list(block_updates), logdensity=logpdf)


@register_sampler("sgld", adaptive=False)
def sgld(
    logpdf: Optional[LogDensityFn],
    *,
    step_size: float | Callable[[jnp.ndarray], jnp.ndarray] = 1e-3,
    grad_logpdf: Optional[Callable[[PyTree, Any], PyTree]] = None,
    batch_fn: Optional[Callable[[jax.Array, jnp.ndarray], Any]] = None,
    preconditioner: Optional[str] = None,
    temperature: float = 1.0,
    **_ignored,
) -> MCMCKernel:
    """SGLD adapted to the uniform ``(init, step)`` protocol.

    Minibatch mode (paper §7: stochastic-gradient subposterior sampling):
    ``grad_logpdf(theta, batch)`` is the minibatch gradient — e.g.
    ``jax.grad`` of :func:`repro.core.subposterior.make_minibatch_logpdf` —
    and ``batch_fn(key, t)`` draws the batch for step ``t``. With both left
    ``None`` the kernel degrades to full-gradient (unadjusted) Langevin on
    ``logpdf``. No MH correction ⇒ reported ``accept_prob`` is 1 and the
    sampler is non-adaptive (discretization bias is controlled by
    ``step_size``, not an acceptance rate).
    """
    if grad_logpdf is None:
        if logpdf is None:
            raise ValueError("sgld needs logpdf or an explicit grad_logpdf")
        full_grad = jax.grad(logpdf)
        grad_logpdf = lambda theta, _batch: full_grad(theta)
    base = sgld_kernel(
        grad_logpdf,
        step_size=step_size,
        preconditioner=preconditioner,
        temperature=temperature,
    )

    def init(position: PyTree):
        return base.init(position)

    def step(key: jax.Array, state):
        if batch_fn is None:
            batch, k_step = None, key
        else:
            k_batch, k_step = jax.random.split(key)
            batch = batch_fn(k_batch, state.step)
        state, _gnorm = base.step(k_step, state, batch)
        info = StepInfo(
            accept_prob=jnp.ones(()),
            is_accepted=jnp.ones((), bool),
            log_density=jnp.zeros(()),
        )
        return state, info

    return MCMCKernel(init=init, step=step)
