"""Random-walk Metropolis–Hastings with Gaussian proposals.

The paper's §2 example sampler: on machine m the acceptance ratio uses the
subposterior density (underweighted prior) — that is entirely contained in the
``logdensity`` closure built by :func:`repro.core.subposterior.make_subposterior_logpdf`,
so this kernel is identical for full-posterior and subposterior use.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.samplers.base import (
    LogDensityFn,
    MCMCKernel,
    PyTree,
    StepInfo,
    tree_axpy,
    tree_random_normal,
    tree_scale,
    tree_where,
)


class RWMHState(NamedTuple):
    position: PyTree
    log_density: jnp.ndarray


def rwmh_kernel(
    logdensity: LogDensityFn,
    step_size: float | jnp.ndarray = 0.1,
    *,
    proposal_fn: Optional[Callable[[jax.Array, PyTree], PyTree]] = None,
) -> MCMCKernel:
    """Symmetric Gaussian random-walk MH.

    ``step_size`` may be a scalar or a pytree matching the position (per-leaf
    scales). ``proposal_fn(key, position) -> position`` overrides the proposal
    entirely — used e.g. by the GMM experiment's label-permutation moves
    (paper §8.2), which are symmetric and therefore need no ratio correction.
    """

    def init(position: PyTree) -> RWMHState:
        return RWMHState(position=position, log_density=logdensity(position))

    def step(key: jax.Array, state: RWMHState):
        k_prop, k_acc = jax.random.split(key)
        if proposal_fn is not None:
            proposal = proposal_fn(k_prop, state.position)
        else:
            noise = tree_random_normal(k_prop, state.position)
            proposal = tree_axpy(1.0, tree_scale(step_size, noise), state.position)
        log_density_prop = logdensity(proposal)
        log_ratio = log_density_prop - state.log_density
        accept_prob = jnp.minimum(1.0, jnp.exp(jnp.minimum(log_ratio, 0.0)))
        accepted = jnp.log(jax.random.uniform(k_acc)) < log_ratio
        new_state = RWMHState(
            position=tree_where(accepted, proposal, state.position),
            log_density=jnp.where(accepted, log_density_prop, state.log_density),
        )
        info = StepInfo(
            accept_prob=accept_prob,
            is_accepted=accepted,
            log_density=new_state.log_density,
        )
        return new_state, info

    return MCMCKernel(init=init, step=step)
