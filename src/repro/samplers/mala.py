"""Metropolis-adjusted Langevin algorithm (MALA)."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.samplers.base import (
    LogDensityFn,
    MCMCKernel,
    PyTree,
    StepInfo,
    tree_add,
    tree_random_normal,
    tree_scale,
    tree_sub,
    tree_vdot,
    tree_where,
)


class MALAState(NamedTuple):
    position: PyTree
    log_density: jnp.ndarray
    grad: PyTree


def mala_kernel(logdensity: LogDensityFn, step_size: float = 0.05) -> MCMCKernel:
    """MALA: θ' = θ + (ε²/2)∇log p(θ) + ε ξ with the exact MH correction."""

    eps = step_size
    value_and_grad = jax.value_and_grad(logdensity)

    def init(position: PyTree) -> MALAState:
        ld, g = value_and_grad(position)
        return MALAState(position=position, log_density=ld, grad=g)

    def _forward_logq(x_from: PyTree, g_from: PyTree, x_to: PyTree) -> jnp.ndarray:
        # log q(x_to | x_from) up to a constant: −‖x_to − x_from − (ε²/2)g‖²/(2ε²)
        mean = tree_add(x_from, tree_scale(0.5 * eps**2, g_from))
        diff = tree_sub(x_to, mean)
        return -tree_vdot(diff, diff) / (2.0 * eps**2)

    def step(key: jax.Array, state: MALAState):
        k_prop, k_acc = jax.random.split(key)
        noise = tree_random_normal(k_prop, state.position)
        proposal = tree_add(
            tree_add(state.position, tree_scale(0.5 * eps**2, state.grad)),
            tree_scale(eps, noise),
        )
        ld_prop, g_prop = value_and_grad(proposal)
        log_ratio = (
            ld_prop
            - state.log_density
            + _forward_logq(proposal, g_prop, state.position)
            - _forward_logq(state.position, state.grad, proposal)
        )
        accept_prob = jnp.minimum(1.0, jnp.exp(jnp.minimum(log_ratio, 0.0)))
        accepted = jnp.log(jax.random.uniform(k_acc)) < log_ratio
        new_state = MALAState(
            position=tree_where(accepted, proposal, state.position),
            log_density=jnp.where(accepted, ld_prop, state.log_density),
            grad=tree_where(accepted, g_prop, state.grad),
        )
        info = StepInfo(accept_prob, accepted, new_state.log_density)
        return new_state, info

    return MCMCKernel(init=init, step=step)
