"""Step-size adaptation shared by every MH-style kernel.

Dual averaging (Hoffman & Gelman 2011, Alg. 5 constants) drives the log step
size toward a target acceptance rate; :func:`warmup_chain` wraps it into the
registry-wide warmup phase: given a *kernel factory* ``step_size -> MCMCKernel``
it runs ``num_steps`` adaptation transitions under ``lax.scan`` (jit-able, so
it vmaps per chain and runs inside ``shard_map``) and returns the kernel built
at the averaged step size plus the warmed-up position — the replacement for the
hand-tuned per-model step constants.

The HMC-specific two-phase scheme (dual averaging + Welford diagonal metric)
stays in :mod:`repro.samplers.hmc` (``window_adaptation``); this module is the
kernel-agnostic core both build on.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.samplers.base import MCMCKernel, PyTree

KernelFactory = Callable[[jnp.ndarray], MCMCKernel]  # step_size -> kernel


class DualAveragingState(NamedTuple):
    log_eps: jnp.ndarray
    log_eps_avg: jnp.ndarray
    h_avg: jnp.ndarray
    step: jnp.ndarray
    mu: jnp.ndarray


def da_init(initial_step_size: float) -> DualAveragingState:
    log_eps = jnp.log(jnp.asarray(initial_step_size))
    return DualAveragingState(
        log_eps=log_eps,
        log_eps_avg=jnp.zeros(()),
        h_avg=jnp.zeros(()),
        step=jnp.zeros(()),
        mu=jnp.log(10.0) + log_eps,
    )


def da_update(
    state: DualAveragingState, accept_prob: jnp.ndarray, target: float = 0.8
) -> DualAveragingState:
    """Nesterov dual averaging (Hoffman & Gelman 2011, Alg. 5 constants)."""
    t0, gamma, kappa = 10.0, 0.05, 0.75
    step = state.step + 1.0
    eta_h = 1.0 / (step + t0)
    h_avg = (1.0 - eta_h) * state.h_avg + eta_h * (target - accept_prob)
    log_eps = state.mu - jnp.sqrt(step) / gamma * h_avg
    eta_x = step ** (-kappa)
    log_eps_avg = eta_x * log_eps + (1.0 - eta_x) * state.log_eps_avg
    return DualAveragingState(log_eps, log_eps_avg, h_avg, step, state.mu)


def warmup_chain(
    key: jax.Array,
    factory: KernelFactory,
    position: PyTree,
    num_steps: int,
    *,
    initial_step_size: float = 0.1,
    target_accept: float = 0.8,
) -> Tuple[MCMCKernel, PyTree, jnp.ndarray]:
    """Dual-averaging warmup of a step-size-parameterized kernel.

    The kernel is rebuilt inside the scan body at the current (traced) ε, so
    the state layout must be ε-independent — true for every registered kernel
    (states hold position/log-density/grad only). Returns ``(kernel, position,
    step_size)`` with the kernel frozen at the averaged ε.
    """
    state0 = factory(jnp.asarray(initial_step_size)).init(position)

    def body(carry, k):
        state, da = carry
        kern = factory(jnp.exp(da.log_eps))
        state, info = kern.step(k, state)
        da = da_update(da, info.accept_prob, target_accept)
        return (state, da), info.accept_prob

    keys = jax.random.split(key, num_steps)
    (state, da), _ = jax.lax.scan(body, (state0, da_init(initial_step_size)), keys)
    step_size = jnp.exp(da.log_eps_avg)
    return factory(step_size), state.position, step_size
