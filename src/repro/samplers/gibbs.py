"""Generic Gibbs / Metropolis-within-Gibbs composition.

A Gibbs kernel is assembled from *block updates*: callables
``update(key, position) -> position`` that resample one block of the position
pytree from its full conditional (or perform an MH-within-Gibbs move for
non-conjugate blocks). The hierarchical Poisson–gamma model (paper §8.3)
supplies conjugate ``q_i | a,b,x`` updates and MH moves for ``a, b``.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.samplers.base import LogDensityFn, MCMCKernel, PyTree, StepInfo

BlockUpdate = Callable[[jax.Array, PyTree], PyTree]


class GibbsState(NamedTuple):
    position: PyTree


def gibbs_kernel(
    block_updates: Sequence[BlockUpdate],
    logdensity: LogDensityFn | None = None,
) -> MCMCKernel:
    """Compose block updates into one sweep; ``logdensity`` is only used to
    report diagnostics (Gibbs sweeps always "accept")."""

    def init(position: PyTree) -> GibbsState:
        return GibbsState(position=position)

    def step(key: jax.Array, state: GibbsState):
        keys = jax.random.split(key, len(block_updates))
        position = state.position
        for update, k in zip(block_updates, keys):
            position = update(k, position)
        ld = logdensity(position) if logdensity is not None else jnp.zeros(())
        info = StepInfo(
            accept_prob=jnp.ones(()), is_accepted=jnp.ones((), bool), log_density=ld
        )
        return GibbsState(position=position), info

    return MCMCKernel(init=init, step=step)


def mh_within_gibbs_update(
    conditional_logdensity: Callable[[PyTree], jnp.ndarray],
    select: Callable[[PyTree], jnp.ndarray],
    replace: Callable[[PyTree, jnp.ndarray], PyTree],
    step_size: float = 0.1,
) -> BlockUpdate:
    """Random-walk MH update of one block (for non-conjugate conditionals).

    ``select(position)`` extracts the block array; ``replace(position, block)``
    writes it back; ``conditional_logdensity(position)`` is the joint (any
    terms constant in the block cancel).
    """

    def update(key: jax.Array, position: PyTree) -> PyTree:
        k_prop, k_acc = jax.random.split(key)
        block = select(position)
        proposal_block = block + step_size * jax.random.normal(
            k_prop, block.shape, block.dtype
        )
        proposal = replace(position, proposal_block)
        log_ratio = conditional_logdensity(proposal) - conditional_logdensity(position)
        accepted = jnp.log(jax.random.uniform(k_acc)) < log_ratio
        return jax.tree.map(lambda p, q: jnp.where(accepted, q, p), position, proposal)

    return update
