"""Sampler protocol + chain drivers.

A *kernel* is a ``MCMCKernel(init, step)`` pair:

- ``init(position) -> state``            (state.position must exist)
- ``step(key, state) -> (state, info)``  (one MCMC transition)

Positions are arbitrary pytrees. ``run_chain`` drives one chain under
``lax.scan`` with burn-in and thinning; ``run_chains`` vmaps independent
chains (the paper's per-machine samplers are one ``run_chain`` per shard —
on the mesh, ``repro.distributed.epmcmc`` shard_maps it over the data axis).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
LogDensityFn = Callable[[PyTree], jnp.ndarray]


class MCMCKernel(NamedTuple):
    init: Callable[[PyTree], Any]
    step: Callable[[jax.Array, Any], Tuple[Any, Any]]


class StepInfo(NamedTuple):
    """Uniform per-step diagnostics across kernels."""

    accept_prob: jnp.ndarray
    is_accepted: jnp.ndarray
    log_density: jnp.ndarray


# -- pytree numerics ---------------------------------------------------------


def tree_random_normal(key: jax.Array, tree: PyTree) -> PyTree:
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef,
        [jax.random.normal(k, l.shape, l.dtype) for k, l in zip(keys, leaves)],
    )


def tree_axpy(a, x: PyTree, y: PyTree) -> PyTree:
    """a*x + y elementwise over pytrees (a scalar or matching pytree)."""
    if isinstance(a, (int, float)) or (hasattr(a, "ndim") and a.ndim == 0):
        return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)
    return jax.tree.map(lambda ai, xi, yi: ai * xi + yi, a, x, y)


def tree_scale(a, x: PyTree) -> PyTree:
    if isinstance(a, (int, float)) or (hasattr(a, "ndim") and a.ndim == 0):
        return jax.tree.map(lambda xi: a * xi, x)
    return jax.tree.map(lambda ai, xi: ai * xi, a, x)


def tree_add(x: PyTree, y: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, x, y)


def tree_sub(x: PyTree, y: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, x, y)


def tree_vdot(x: PyTree, y: PyTree) -> jnp.ndarray:
    parts = jax.tree.map(lambda xi, yi: jnp.vdot(xi, yi), x, y)
    return jax.tree.reduce(jnp.add, parts, jnp.zeros(()))


def tree_where(pred: jnp.ndarray, x: PyTree, y: PyTree) -> PyTree:
    return jax.tree.map(lambda xi, yi: jnp.where(pred, xi, yi), x, y)


# -- chain drivers -----------------------------------------------------------


def run_chain(
    key: jax.Array,
    kernel: "MCMCKernel | Callable[[jnp.ndarray], MCMCKernel]",
    position: PyTree,
    num_samples: int,
    *,
    burn_in: int = 0,
    thin: int = 1,
    warmup: int = 0,
    initial_step_size: float = 0.1,
    target_accept: float = 0.8,
) -> Tuple[PyTree, StepInfo]:
    """Drive one chain; returns stacked positions ``(num_samples, ...)`` + info.

    Burn-in follows the paper's fixed rule (callers discard 1/6 by default at
    the experiment layer); ``thin`` keeps every thin-th post-burn-in draw.

    ``kernel`` may instead be a *factory* ``step_size -> MCMCKernel`` (e.g. a
    partial over a ``repro.samplers.registry`` entry). With ``warmup > 0`` the
    factory is required: ``warmup`` dual-averaging transitions adapt the step
    size toward ``target_accept`` starting from ``initial_step_size``
    (per chain — the adaptation runs under the same ``lax.scan``/vmap nesting
    as the chain itself), then sampling proceeds at the frozen adapted step.
    Warmup transitions are discarded like burn-in.
    """
    if warmup > 0:
        # late import: adaptation imports this module for the kernel protocol
        from repro.samplers import adaptation

        if isinstance(kernel, MCMCKernel) or not callable(kernel):
            raise TypeError(
                "warmup needs a kernel factory (step_size -> MCMCKernel); "
                "got a built kernel whose step size cannot be adapted"
            )
        key, k_warm = jax.random.split(key)
        kernel, position, _eps = adaptation.warmup_chain(
            k_warm,
            kernel,
            position,
            warmup,
            initial_step_size=initial_step_size,
            target_accept=target_accept,
        )
    elif not isinstance(kernel, MCMCKernel) and callable(kernel):
        kernel = kernel(jnp.asarray(initial_step_size))
    state = kernel.init(position)

    def one_step(state, key):
        return kernel.step(key, state)

    if burn_in > 0:
        keys = jax.random.split(key, burn_in + 1)
        key = keys[0]

        def warm(state, k):
            state, _ = kernel.step(k, state)
            return state, None

        state, _ = jax.lax.scan(warm, state, keys[1:])

    def collect(state, k):
        if thin == 1:
            state, info = one_step(state, k)
        else:
            ks = jax.random.split(k, thin)

            def inner(s, kk):
                s, info = one_step(s, kk)
                return s, info

            state, infos = jax.lax.scan(inner, state, ks)
            info = jax.tree.map(lambda x: x[-1], infos)
        return state, (state.position, info)

    keys = jax.random.split(key, num_samples)
    _, (positions, infos) = jax.lax.scan(collect, state, keys)
    return positions, infos


def run_chains(
    key: jax.Array,
    kernel: "MCMCKernel | Callable[[jnp.ndarray], MCMCKernel]",
    positions: PyTree,
    num_samples: int,
    *,
    burn_in: int = 0,
    thin: int = 1,
    warmup: int = 0,
    initial_step_size: float = 0.1,
    target_accept: float = 0.8,
) -> Tuple[PyTree, StepInfo]:
    """vmap of :func:`run_chain` over a leading chain axis of ``positions``.

    With ``warmup > 0`` (and ``kernel`` a step-size factory) every chain
    adapts its own step size independently — no cross-chain communication.
    """
    n_chains = jax.tree.leaves(positions)[0].shape[0]
    keys = jax.random.split(key, n_chains)
    return jax.vmap(
        lambda k, p: run_chain(
            k,
            kernel,
            p,
            num_samples,
            burn_in=burn_in,
            thin=thin,
            warmup=warmup,
            initial_step_size=initial_step_size,
            target_accept=target_accept,
        )
    )(keys, positions)
