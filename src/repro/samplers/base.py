"""Sampler protocol + chain drivers.

A *kernel* is a ``MCMCKernel(init, step)`` pair:

- ``init(position) -> state``            (state.position must exist)
- ``step(key, state) -> (state, info)``  (one MCMC transition)

Positions are arbitrary pytrees. ``run_chain`` drives one chain under
``lax.scan`` with burn-in and thinning; ``run_chains`` vmaps independent
chains (the paper's per-machine samplers are one ``run_chain`` per shard —
on the mesh, ``repro.distributed.epmcmc`` shard_maps it over the data axis).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
LogDensityFn = Callable[[PyTree], jnp.ndarray]


class MCMCKernel(NamedTuple):
    init: Callable[[PyTree], Any]
    step: Callable[[jax.Array, Any], Tuple[Any, Any]]


class StepInfo(NamedTuple):
    """Uniform per-step diagnostics across kernels."""

    accept_prob: jnp.ndarray
    is_accepted: jnp.ndarray
    log_density: jnp.ndarray


# -- pytree numerics ---------------------------------------------------------


def tree_random_normal(key: jax.Array, tree: PyTree) -> PyTree:
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef,
        [jax.random.normal(k, l.shape, l.dtype) for k, l in zip(keys, leaves)],
    )


def tree_axpy(a, x: PyTree, y: PyTree) -> PyTree:
    """a*x + y elementwise over pytrees (a scalar or matching pytree)."""
    if isinstance(a, (int, float)) or (hasattr(a, "ndim") and a.ndim == 0):
        return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)
    return jax.tree.map(lambda ai, xi, yi: ai * xi + yi, a, x, y)


def tree_scale(a, x: PyTree) -> PyTree:
    if isinstance(a, (int, float)) or (hasattr(a, "ndim") and a.ndim == 0):
        return jax.tree.map(lambda xi: a * xi, x)
    return jax.tree.map(lambda ai, xi: ai * xi, a, x)


def tree_add(x: PyTree, y: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, x, y)


def tree_sub(x: PyTree, y: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, x, y)


def tree_vdot(x: PyTree, y: PyTree) -> jnp.ndarray:
    parts = jax.tree.map(lambda xi, yi: jnp.vdot(xi, yi), x, y)
    return jax.tree.reduce(jnp.add, parts, jnp.zeros(()))


def tree_where(pred: jnp.ndarray, x: PyTree, y: PyTree) -> PyTree:
    return jax.tree.map(lambda xi, yi: jnp.where(pred, xi, yi), x, y)


# -- chain drivers -----------------------------------------------------------


def run_chain(
    key: jax.Array,
    kernel: MCMCKernel,
    position: PyTree,
    num_samples: int,
    *,
    burn_in: int = 0,
    thin: int = 1,
) -> Tuple[PyTree, StepInfo]:
    """Drive one chain; returns stacked positions ``(num_samples, ...)`` + info.

    Burn-in follows the paper's fixed rule (callers discard 1/6 by default at
    the experiment layer); ``thin`` keeps every thin-th post-burn-in draw.
    """
    state = kernel.init(position)

    def one_step(state, key):
        return kernel.step(key, state)

    if burn_in > 0:
        keys = jax.random.split(key, burn_in + 1)
        key = keys[0]

        def warm(state, k):
            state, _ = kernel.step(k, state)
            return state, None

        state, _ = jax.lax.scan(warm, state, keys[1:])

    def collect(state, k):
        if thin == 1:
            state, info = one_step(state, k)
        else:
            ks = jax.random.split(k, thin)

            def inner(s, kk):
                s, info = one_step(s, kk)
                return s, info

            state, infos = jax.lax.scan(inner, state, ks)
            info = jax.tree.map(lambda x: x[-1], infos)
        return state, (state.position, info)

    keys = jax.random.split(key, num_samples)
    _, (positions, infos) = jax.lax.scan(collect, state, keys)
    return positions, infos


def run_chains(
    key: jax.Array,
    kernel: MCMCKernel,
    positions: PyTree,
    num_samples: int,
    *,
    burn_in: int = 0,
    thin: int = 1,
) -> Tuple[PyTree, StepInfo]:
    """vmap of :func:`run_chain` over a leading chain axis of ``positions``."""
    n_chains = jax.tree.leaves(positions)[0].shape[0]
    keys = jax.random.split(key, n_chains)
    return jax.vmap(
        lambda k, p: run_chain(k, kernel, p, num_samples, burn_in=burn_in, thin=thin)
    )(keys, positions)
