"""Stochastic-gradient Langevin dynamics (Welling & Teh 2011) + pSGLD.

The paper's §7 points out that minibatch samplers "can be directly used in our
algorithm to generate subposterior samples" — this is the LM-scale sampler:
each EP-MCMC chain group runs SGLD on its shard's subposterior

    θ ← θ + (ε/2)·∇[ (1/M)·log p(θ) + (N_m/B)·log p(batch|θ) ] + √ε·ξ .

Unlike the MH kernels, SGLD consumes a data batch per step, so its ``step``
has signature ``step(key, state, batch)``; :mod:`repro.distributed.epmcmc`
threads the per-shard data pipeline through. With RMSProp preconditioning
(``preconditioner="rmsprop"``) this is pSGLD (Li et al. 2016).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.samplers.base import PyTree, tree_random_normal

GradEstimator = Callable[[PyTree, Any], PyTree]  # (theta, batch) -> grad log subposterior


class SGLDState(NamedTuple):
    position: PyTree
    v: PyTree  # RMSProp second-moment accumulator (zeros when unpreconditioned)
    step: jnp.ndarray


class SGLDKernel(NamedTuple):
    init: Callable[[PyTree], SGLDState]
    step: Callable[[jax.Array, SGLDState, Any], Tuple[SGLDState, jnp.ndarray]]


def sgld_kernel(
    grad_estimator: GradEstimator,
    step_size: float | Callable[[jnp.ndarray], jnp.ndarray] = 1e-5,
    *,
    preconditioner: Optional[str] = None,
    rmsprop_decay: float = 0.99,
    rmsprop_eps: float = 1e-5,
    temperature: float = 1.0,
) -> SGLDKernel:
    """SGLD/pSGLD kernel. ``step_size`` may be a schedule ``t -> ε_t``.

    ``temperature=0`` degrades gracefully to preconditioned SGD — used by the
    ``--mode sgd`` baseline so both modes share one update rule (and one HLO).
    """

    def eps_at(t: jnp.ndarray) -> jnp.ndarray:
        if callable(step_size):
            return step_size(t)
        return jnp.asarray(step_size)

    def init(position: PyTree) -> SGLDState:
        return SGLDState(
            position=position,
            v=jax.tree.map(jnp.zeros_like, position),
            step=jnp.zeros((), jnp.int32),
        )

    def step(key: jax.Array, state: SGLDState, batch: Any):
        eps = eps_at(state.step)
        grad = grad_estimator(state.position, batch)
        if preconditioner == "rmsprop":
            v = jax.tree.map(
                lambda vi, gi: rmsprop_decay * vi + (1.0 - rmsprop_decay) * gi * gi,
                state.v,
                grad,
            )
            g_scale = jax.tree.map(lambda vi: 1.0 / (jnp.sqrt(vi) + rmsprop_eps), v)
        else:
            v = state.v
            g_scale = jax.tree.map(jnp.ones_like, grad)
        noise = tree_random_normal(key, state.position)
        new_position = jax.tree.map(
            lambda q, g, s, n: q
            + 0.5 * eps * s * g
            + jnp.sqrt(temperature * eps * s) * n,
            state.position,
            grad,
            g_scale,
            noise,
        )
        new_state = SGLDState(position=new_position, v=v, step=state.step + 1)
        gnorm = jnp.sqrt(
            sum(jnp.sum(g**2) for g in jax.tree.leaves(grad))
        )
        return new_state, gnorm

    return SGLDKernel(init=init, step=step)
