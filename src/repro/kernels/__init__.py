"""Pallas kernels for the numeric hot spots (+ shared platform policy).

One subpackage per hot spot the pipeline actually leans on — each ships a
``kernel.py`` (the Pallas body), an ``ops.py`` (jit'd public wrapper:
padding, dispatch, platform policy), and a ``ref.py`` oracle the tests pin
the kernel against.

:func:`default_interpret` is the single platform-aware resolver for the
kernels' ``interpret`` flag: Pallas interpret mode is what makes the kernels
runnable (and testable) on the CPU rig, while a real TPU wants the compiled
path. Every ``ops.py`` defaults its ``interpret`` argument to ``None`` and
resolves it here, so the policy lives in exactly one place and
``REPRO_PALLAS_INTERPRET=0|1`` overrides it fleet-wide without touching
call sites.
"""

from __future__ import annotations

import os

import jax

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def default_interpret() -> bool:
    """Platform-aware default for the Pallas ``interpret`` flag.

    ``False`` on a real TPU backend (compiled Mosaic path), ``True``
    everywhere else (CPU/GPU rigs run the kernels in interpret mode).
    The ``REPRO_PALLAS_INTERPRET`` environment variable overrides both.
    Resolution happens at trace time — the jitted wrappers cache on
    ``interpret=None``, so flip the env var before the first kernel call.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        val = env.strip().lower()
        if val in _TRUTHY:
            return True
        if val in _FALSY:
            return False
        raise ValueError(
            f"REPRO_PALLAS_INTERPRET={env!r} — expected one of "
            f"{_TRUTHY + _FALSY}"
        )
    return jax.default_backend() != "tpu"
