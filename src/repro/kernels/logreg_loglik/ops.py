"""jit'd wrapper: padding, masking, single-chain and multi-chain entry points."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.logreg_loglik.kernel import logreg_loglik_grad_kernel
from repro.kernels.logreg_loglik.ref import logreg_loglik_grad_ref


def _round_up(n: int, k: int) -> int:
    return (n + k - 1) // k * k


@functools.partial(jax.jit, static_argnames=("block_n", "interpret", "min_kernel_n"))
def logreg_loglik_grad(
    X: jnp.ndarray,  # (N, d)
    y: jnp.ndarray,  # (N,) in {-1, +1}
    beta: jnp.ndarray,  # (d,) or (d, C) for C chains
    *,
    scale: float | jnp.ndarray = 1.0,
    block_n: int = 1024,
    interpret: bool | None = None,  # None -> repro.kernels.default_interpret()
    min_kernel_n: int = 256,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused (ℓ, ∇ℓ) of the logistic likelihood; matches ``ref.py`` exactly.

    Returns ((), (d,)) for 1-D beta and ((C,), (d, C)) for 2-D beta.
    """
    if interpret is None:
        interpret = default_interpret()
    N, d = X.shape
    single = beta.ndim == 1
    if N < min_kernel_n:
        if single:
            l, g = logreg_loglik_grad_ref(X, y, beta, scale=scale)
            return l, g
        ls, gs = jax.vmap(
            lambda b: logreg_loglik_grad_ref(X, y, b, scale=scale), in_axes=1, out_axes=0
        )(beta)
        return ls, gs.T

    beta2 = beta[:, None] if single else beta
    block_n = min(block_n, _round_up(N, 8))
    Np = _round_up(N, block_n)
    Xp = jnp.zeros((Np, d), X.dtype).at[:N].set(X)
    yp = jnp.ones((Np, beta2.shape[1]), jnp.float32)
    yp = yp.at[:N].set(y.astype(jnp.float32)[:, None])
    w = jnp.zeros((Np, 1), jnp.float32).at[:N].set(1.0)
    loglik, grad = logreg_loglik_grad_kernel(
        Xp, yp, w, beta2, block_n=block_n, interpret=interpret
    )
    s = jnp.asarray(scale, jnp.float32)
    if single:
        return s * loglik[0], s * grad[:, 0]
    return s * loglik, s * grad
