from repro.kernels.logreg_loglik.ops import logreg_loglik_grad
from repro.kernels.logreg_loglik.ref import logreg_loglik_grad_ref

__all__ = ["logreg_loglik_grad", "logreg_loglik_grad_ref"]
