"""Pallas TPU kernel: fused logistic log-likelihood + gradient.

TPU-native design (vs the CPU/Stan loop the paper ran):

- grid = (N // block_n,): one sequential pass over row blocks. Each step
  pulls a (block_n, d) tile of X into VMEM, does the matvec on the MXU
  (block_n × d @ d × 1), the log-sigmoid on the VPU, and accumulates BOTH
  the scalar ℓ and the d-vector ∇ℓ in f32 VMEM scratch — X is read ONCE
  from HBM for value+grad (arithmetic intensity 2× the naive two-pass).
- d stays resident (d ≤ ~8k fits VMEM alongside the row tile; the paper's
  experiments are d ≤ 54 — sampling-regime posteriors are low-dim).
- ``w`` is a {0,1} row mask so ops.py can pad N without biasing ℓ: a padded
  row would otherwise add log σ(0) = −log 2.

The matvec-as-matmul shape (block_n, d)·(d, 1) keeps the MXU utilized when
callers batch multiple chains: beta may be (d, C) for C parallel chains
(vmapped subposterior chains on one device), giving a true matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer releases renamed it
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _logreg_kernel(x_ref, y_ref, w_ref, beta_ref, loglik_ref, grad_ref, acc_l, acc_g, *, n_blocks: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_l[...] = jnp.zeros_like(acc_l)
        acc_g[...] = jnp.zeros_like(acc_g)

    x = x_ref[...].astype(jnp.float32)  # (block_n, d)
    y = y_ref[...].astype(jnp.float32)  # (block_n, C)
    w = w_ref[...].astype(jnp.float32)  # (block_n, 1)
    beta = beta_ref[...].astype(jnp.float32)  # (d, C)

    z = y * jax.lax.dot(x, beta, preferred_element_type=jnp.float32)  # (block_n, C)
    # log σ(z) = −softplus(−z), computed stably on the VPU
    loglik = -jnp.sum(w * jnp.logaddexp(0.0, -z), axis=0)  # (C,)
    coeff = w * y * jax.nn.sigmoid(-z)  # (block_n, C)
    grad = jax.lax.dot(x.T, coeff, preferred_element_type=jnp.float32)  # (d, C)

    acc_l[...] += loglik
    acc_g[...] += grad

    @pl.when(i == n_blocks - 1)
    def _finalize():
        loglik_ref[...] = acc_l[...]
        grad_ref[...] = acc_g[...]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def logreg_loglik_grad_kernel(
    X: jnp.ndarray,  # (N, d) padded: N % block_n == 0
    y: jnp.ndarray,  # (N, C)
    w: jnp.ndarray,  # (N, 1) row mask
    beta: jnp.ndarray,  # (d, C)
    *,
    block_n: int = 1024,
    interpret: bool = False,
):
    N, d = X.shape
    C = beta.shape[1]
    n_blocks = N // block_n
    kernel = functools.partial(_logreg_kernel, n_blocks=n_blocks)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, C), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((d, C), lambda i: (0, 0)),  # beta resident
        ],
        out_specs=[
            pl.BlockSpec((C,), lambda i: (0,)),
            pl.BlockSpec((d, C), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C,), jnp.float32),
            jax.ShapeDtypeStruct((d, C), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((C,), jnp.float32),
            pltpu.VMEM((d, C), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(X, y, w, beta)
