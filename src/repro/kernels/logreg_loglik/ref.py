"""Pure-jnp oracle: fused logistic-regression log-likelihood + gradient.

The paper's per-machine sampler (§8.1) spends its time in exactly this O(N·d)
reduction every MH/HMC step:

    ℓ(β)  = Σ_i log σ(y_i · x_i·β)          (y ∈ {−1, +1})
    ∇ℓ(β) = Σ_i y_i · σ(−y_i · x_i·β) · x_i

``scale`` multiplies both (the subposterior's N_m/B minibatch factor).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def logreg_loglik_grad_ref(
    X: jnp.ndarray,  # (N, d)
    y: jnp.ndarray,  # (N,) in {-1, +1}
    beta: jnp.ndarray,  # (d,)
    *,
    scale: float | jnp.ndarray = 1.0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    X = X.astype(jnp.float32)
    y = y.astype(jnp.float32)
    beta = beta.astype(jnp.float32)
    z = y * (X @ beta)  # (N,)
    loglik = jnp.sum(jax.nn.log_sigmoid(z))
    coeff = y * jax.nn.sigmoid(-z)  # (N,)
    grad = X.T @ coeff
    return scale * loglik, scale * grad
