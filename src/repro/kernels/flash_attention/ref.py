"""Pure-jnp oracle for the Pallas flash-attention kernel.

GQA layout: q (B, S, K, G, hd); k (B, T, K, hd); v (B, T, K, hd_v) —
hd_v may differ from hd (MLA concatenates nope⊕rope on the qk side only).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def flash_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
) -> jnp.ndarray:
    b, s, kh, g, hd = q.shape
    t = k.shape[1]
    scale = hd ** -0.5
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        mask = jnp.arange(s)[:, None] >= jnp.arange(t)[None, :]
        scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
