"""jit'd wrapper: GQA layout handling, padding, VMEM-aware block sizing."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.flash_attention.kernel import flash_attention_fwd_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


def _round_up(n: int, k: int) -> int:
    return (n + k - 1) // k * k


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret", "min_kernel_s")
)
def flash_attention(
    q: jnp.ndarray,  # (B, S, K, G, hd)
    k: jnp.ndarray,  # (B, T, K, hd)
    v: jnp.ndarray,  # (B, T, K, hd_v)
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,  # None -> repro.kernels.default_interpret()
    min_kernel_s: int = 64,
) -> jnp.ndarray:
    """Pallas flash-attention forward; returns (B, S, K, G, hd_v)."""
    if interpret is None:
        interpret = default_interpret()
    b, s, kh, g, hd = q.shape
    t = k.shape[1]
    hd_v = v.shape[-1]
    if s < min_kernel_s or t < min_kernel_s:
        return flash_attention_ref(q, k, v, causal=causal)

    # shrink blocks until the f32 score tile (block_q·G × block_k) + q tile
    # fit a ~12 MB VMEM budget
    while block_q * g * (block_k + hd + hd_v) * 4 > 12 * 2**20 and block_q > 128:
        block_q //= 2
    while block_q * g * (block_k + hd + hd_v) * 4 > 12 * 2**20 and block_k > 128:
        block_k //= 2

    sp, tp = _round_up(s, block_q), _round_up(t, block_k)
    qf = q.transpose(0, 2, 1, 3, 4).reshape(b * kh, s, g * hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kh, t, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kh, t, hd_v)
    qf = jnp.pad(qf, ((0, 0), (0, sp - s), (0, 0)))
    kf = jnp.pad(kf, ((0, 0), (0, tp - t), (0, 0)))
    vf = jnp.pad(vf, ((0, 0), (0, tp - t), (0, 0)))

    out = flash_attention_fwd_kernel(
        qf, kf, vf,
        g=g, hd=hd, hd_v=hd_v, kv_len=t, causal=causal,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )  # (B*K, Sp, G*hd_v)
    out = out[:, :s].reshape(b, kh, s, g, hd_v).transpose(0, 2, 1, 3, 4)
    return out
