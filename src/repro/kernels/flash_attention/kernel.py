"""Pallas TPU flash-attention forward — the §Perf lever for the train/prefill
memory term (EXPERIMENTS.md: XLA-lowered flash streams every (q_blk × kv_blk)
f32 score tile through HBM; this kernel keeps them in VMEM).

TPU-native design:

- grid = (B·K, S/block_q, T/block_k): batch×kv-head program axis and q-tile
  axis are ``parallel``; the kv axis is ``arbitrary`` (sequential online-
  softmax accumulation — the FlashAttention-2 loop order).
- One program instance owns one kv-head's G query heads: the q tile loads as
  (block_q, G·hd) and is reshaped to (block_q·G, hd) so the score matmul
  (block_q·G, hd)·(hd, block_k) and the PV matmul run as plain MXU GEMMs —
  GQA grouping costs zero extra traffic.
- VMEM scratch carries the running (m, ℓ, acc) across kv steps; the output
  tile is written once, on the last kv block (single HBM write per tile).
- Causal tiles wholly above the diagonal are skipped via ``pl.when`` (the
  classic 2× saving); kv-tail padding is masked with −∞ from ``kv_len``.

VMEM at defaults (block_q=512, block_k=512, G≤8, hd=128, f32 scratch):
q 512·8·128·4 ≈ 2 MB, k/v 512·128·4 ≈ 0.25 MB each, scores 4096·512·4 ≈ 8 MB
— fits the 16 MB/core budget; ops.py shrinks blocks when G·hd is larger.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer releases renamed it
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG_INF = -1e30


def _flash_fwd_kernel(
    q_ref,  # (1, block_q, G*hd)
    k_ref,  # (1, block_k, hd)
    v_ref,  # (1, block_k, hd_v)
    out_ref,  # (1, block_q, G*hd_v)
    m_ref,  # (block_q*G,) scratch
    l_ref,  # (block_q*G,) scratch
    acc_ref,  # (block_q*G, hd_v) scratch
    *,
    n_kv: int,
    block_q: int,
    block_k: int,
    g: int,
    hd: int,
    hd_v: int,
    kv_len: int,
    causal: bool,
):
    jq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _step():
        q = q_ref[0].astype(jnp.float32).reshape(block_q, g, hd)
        q = q.transpose(1, 0, 2).reshape(g * block_q, hd)  # head-major rows
        k = k_ref[0].astype(jnp.float32)  # (block_k, hd)
        v = v_ref[0].astype(jnp.float32)  # (block_k, hd_v)
        scale = hd ** -0.5
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (g*block_q, block_k)

        kv_pos = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        valid = kv_pos < kv_len
        if causal:
            q_pos = jq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (g * block_q, 1), 0
            ) % block_q
            valid = valid & (q_pos >= kv_pos)
        scores = jnp.where(valid, scores, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal:
        # skip tiles strictly above the causal diagonal (the classic 2×)
        pl.when(jk * block_k <= jq * block_q + block_q - 1)(_step)
    else:
        _step()

    @pl.when(jk == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        out = (acc_ref[...] / denom).reshape(g, block_q, hd_v)
        out = out.transpose(1, 0, 2).reshape(block_q, g * hd_v)
        out_ref[0] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_k", "causal", "kv_len", "g", "hd", "hd_v", "interpret"),
)
def flash_attention_fwd_kernel(
    q: jnp.ndarray,  # (BK, S, G*hd) padded: S % block_q == 0
    k: jnp.ndarray,  # (BK, T, hd)   padded: T % block_k == 0
    v: jnp.ndarray,  # (BK, T, hd_v)
    *,
    g: int,
    hd: int,
    hd_v: int,
    kv_len: int,  # true T before padding
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    bk, s, _ = q.shape
    t = k.shape[1]
    n_q, n_kv = s // block_q, t // block_k
    kernel = functools.partial(
        _flash_fwd_kernel,
        n_kv=n_kv, block_q=block_q, block_k=block_k,
        g=g, hd=hd, hd_v=hd_v, kv_len=kv_len, causal=causal,
    )
    return pl.pallas_call(
        kernel,
        grid=(bk, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, g * hd), lambda i, jq, jk: (i, jq, 0)),
            pl.BlockSpec((1, block_k, hd), lambda i, jq, jk: (i, jk, 0)),
            pl.BlockSpec((1, block_k, hd_v), lambda i, jq, jk: (i, jk, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, g * hd_v), lambda i, jq, jk: (i, jq, 0)),
        out_shape=jax.ShapeDtypeStruct((bk, s, g * hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * g,), jnp.float32),
            pltpu.VMEM((block_q * g,), jnp.float32),
            pltpu.VMEM((block_q * g, hd_v), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
