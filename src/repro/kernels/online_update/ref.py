"""jnp reference for the fused Welford/Chan-merge update (the test oracle).

Mirrors ``repro.core.combiners.online.online_update_chunk`` on raw arrays
(the kernels layer stays independent of the combiner registry): a dense
``(M, C, d)`` chunk is reduced to per-machine batch moments and Chan-merged
into the running ``(count, mean, m2)`` state. Invalid rows (beyond each
machine's ``chunk_counts`` prefix) are excluded with ``where``, never
mask-multiplied — 0·NaN would leak.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def online_moments_update_ref(
    count: jnp.ndarray,  # (M,)
    mean: jnp.ndarray,  # (M, d)
    m2: jnp.ndarray,  # (M, d, d)
    chunk: jnp.ndarray,  # (M, C, d)
    chunk_counts: Optional[jnp.ndarray] = None,  # (M,) valid prefix (None ⇒ C)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    M, C, _ = chunk.shape
    cc = (
        jnp.full((M,), C, jnp.int32)
        if chunk_counts is None
        else chunk_counts.astype(jnp.int32)
    )
    mask = (jnp.arange(C)[None, :] < cc[:, None])[..., None]  # (M, C, 1)
    n_b = cc.astype(chunk.dtype)
    n_b_safe = jnp.maximum(n_b, 1.0)
    valid = jnp.where(mask, chunk, 0.0)
    mean_b = jnp.sum(valid, axis=1) / n_b_safe[:, None]  # (M, d)
    cent = jnp.where(mask, chunk - mean_b[:, None, :], 0.0)
    m2_b = jnp.einsum("mci,mcj->mij", cent, cent)  # (M, d, d)

    n_a = count
    n = n_a + n_b
    n_safe = jnp.maximum(n, 1.0)
    delta = mean_b - mean
    mean_new = mean + delta * (n_b / n_safe)[:, None]
    m2_new = m2 + m2_b + jnp.einsum("mi,mj->mij", delta, delta) * (
        n_a * n_b / n_safe
    )[:, None, None]
    upd = (n_b > 0)[:, None]
    return (
        n,
        jnp.where(upd, mean_new, mean),
        jnp.where(upd[..., None], m2_new, m2),
    )
