"""Pallas TPU kernel: fused Welford/Chan-merge streaming-moments update.

One grid step per machine (grid = (M,), fully parallel — machines never
share state). Each step loads its ``(block_c, block_d)`` chunk tile plus the
machine's running ``(mean, m2)`` into VMEM and fuses the whole update:

- batch moments of the chunk (masked mean + centered Gram via one MXU
  ``centᵀ·cent`` matmul);
- Chan's parallel-Welford merge of (n_a, mean_a, m2_a) with the chunk's
  (n_b, mean_b, m2_b), including the rank-one ``δδᵀ`` correction.

The per-machine scalars (valid-row count in the chunk, running count n_a)
ride in as a lane-broadcast ``(M, 128)`` f32 operand — cols 0/1 — so the
kernel needs no SMEM scalar plumbing and runs identically in interpret mode.

Padding contract (``ops.py`` enforces): padded d-features MUST be zero in
the chunk *and* the state — a zero feature has zero chunk mean, zero
centered residual, and zero δ, so every padded row/col of mean/m2 stays
exactly zero through the merge. Padded C rows are excluded by the row mask
(they sit beyond the valid count), so they never touch the moments either.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer releases renamed it
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _online_update_body(
    chunk_ref, sc_ref, mean_ref, m2_ref, mean_out_ref, m2_out_ref
):
    t = chunk_ref[0].astype(jnp.float32)  # (block_c, block_d)
    cc = sc_ref[0, 0]  # n_b: valid rows of this machine's chunk
    n_a = sc_ref[0, 1]  # running count
    mean0 = mean_ref[...].astype(jnp.float32)  # (1, block_d)
    m2_0 = m2_ref[0].astype(jnp.float32)  # (block_d, block_d)

    rows = jax.lax.broadcasted_iota(jnp.float32, t.shape, 0)
    mask = rows < cc
    valid = jnp.where(mask, t, 0.0)
    n_b_safe = jnp.maximum(cc, 1.0)
    mean_b = jnp.sum(valid, axis=0, keepdims=True) / n_b_safe  # (1, block_d)
    cent = jnp.where(mask, t - mean_b, 0.0)
    m2_b = jax.lax.dot_general(  # centᵀ·cent — the MXU-shaped reduction
        cent, cent, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    n_safe = jnp.maximum(n_a + cc, 1.0)
    delta = mean_b - mean0  # (1, block_d)
    mean_new = mean0 + delta * (cc / n_safe)
    outer = jax.lax.dot_general(  # δᵀ·δ from the (1, d) row vector
        delta, delta, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m2_new = m2_0 + m2_b + outer * (n_a * cc / n_safe)

    upd = cc > 0.0  # empty chunk ⇒ state untouched
    mean_out_ref[...] = jnp.where(upd, mean_new, mean0)
    m2_out_ref[...] = jnp.where(upd, m2_new, m2_0)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def online_update_kernel(
    chunk: jnp.ndarray,  # (M, Cp, dp) — C, d already padded (zeros)
    scalars: jnp.ndarray,  # (M, 128) f32: col 0 = chunk count, col 1 = n_a
    mean: jnp.ndarray,  # (M, dp)
    m2: jnp.ndarray,  # (M, dp, dp)
    *,
    interpret: bool = False,
):
    M, Cp, dp = chunk.shape
    return pl.pallas_call(
        _online_update_body,
        grid=(M,),
        in_specs=[
            pl.BlockSpec((1, Cp, dp), lambda m: (m, 0, 0)),
            pl.BlockSpec((1, 128), lambda m: (m, 0)),
            pl.BlockSpec((1, dp), lambda m: (m, 0)),
            pl.BlockSpec((1, dp, dp), lambda m: (m, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, dp), lambda m: (m, 0)),
            pl.BlockSpec((1, dp, dp), lambda m: (m, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, dp), jnp.float32),
            jax.ShapeDtypeStruct((M, dp, dp), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(chunk, scalars, mean, m2)
