"""Fused Welford/Chan-merge streaming-moments update (``online`` combiner)."""

from repro.kernels.online_update.ops import online_moments_update  # noqa: F401
from repro.kernels.online_update.ref import online_moments_update_ref  # noqa: F401
