"""jit'd public wrapper for the fused online-moments update: padding + dispatch.

Pads C to the sublane multiple and d to the lane multiple with zeros (both
are exactly moment-neutral: padded rows sit beyond the valid count and are
masked; padded features have zero mean/residual/δ so their mean/m2 entries
stay zero — sliced off on return). Falls back to the jnp reference for tiny
chunks where kernel launch overhead dominates.

Tolerance note (the ``online`` combiner's merge-rounding contract lives
here, next to the kernel): Welford merges associate differently across
chunkings *and* across evaluation orders, so the kernel agrees with
:func:`repro.kernels.online_update.ref.online_moments_update_ref` (and with
``combiners.online.online_update_chunk``) to f32 last-ulp per fold — the
centered Gram is one fused MXU matmul here vs an einsum there. Streams that
need a bitwise-vs-batch guarantee use the buffered combiners instead.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.online_update.kernel import online_update_kernel
from repro.kernels.online_update.ref import online_moments_update_ref


def _round_up(n: int, k: int) -> int:
    return (n + k - 1) // k * k


@functools.partial(jax.jit, static_argnames=("interpret", "min_kernel_c"))
def online_moments_update(
    count: jnp.ndarray,  # (M,)
    mean: jnp.ndarray,  # (M, d)
    m2: jnp.ndarray,  # (M, d, d)
    chunk: jnp.ndarray,  # (M, C, d)
    chunk_counts: Optional[jnp.ndarray] = None,  # (M,) valid prefix (None ⇒ C)
    *,
    interpret: bool | None = None,  # None -> repro.kernels.default_interpret()
    min_kernel_c: int = 32,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fold a dense ``(M, C, d)`` chunk into running ``(count, mean, m2)``."""
    if interpret is None:
        interpret = default_interpret()
    M, C, d = chunk.shape
    if C < min_kernel_c:
        return online_moments_update_ref(count, mean, m2, chunk, chunk_counts)
    cc = (
        jnp.full((M,), C, jnp.float32)
        if chunk_counts is None
        else chunk_counts.astype(jnp.float32)
    )
    Cp, dp = _round_up(C, 8), _round_up(d, 128)
    chunk_p = jnp.zeros((M, Cp, dp), jnp.float32).at[:, :C, :d].set(chunk)
    mean_p = jnp.zeros((M, dp), jnp.float32).at[:, :d].set(mean)
    m2_p = jnp.zeros((M, dp, dp), jnp.float32).at[:, :d, :d].set(m2)
    scalars = (
        jnp.zeros((M, 128), jnp.float32)
        .at[:, 0].set(cc)
        .at[:, 1].set(count.astype(jnp.float32))
    )
    mean_o, m2_o = online_update_kernel(
        chunk_p, scalars, mean_p, m2_p, interpret=interpret
    )
    n_b = cc.astype(chunk.dtype)
    return count + n_b, mean_o[:, :d], m2_o[:, :d, :d]
