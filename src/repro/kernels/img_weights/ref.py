"""Pure-jnp oracle for the IMG mixture log-weight kernel (paper Eq. 3.5).

Given P candidate components, each a selection of one sample per machine
``theta`` (P, M, d), the unnormalized log mixture weight is

    log w_t = Σ_m log N(θ^m_{t_m} | θ̄_t, h² I_d)
            = −SSE_t / (2h²) − M·(d/2)·log(2π h²),
    SSE_t  = Σ_m ‖θ^m_{t_m} − θ̄_t‖².

This is the inner loop of Algorithm 1 when proposals are evaluated in batch
(P parallel IMG chains / vectorized sweeps / tree combine scoring).
"""

from __future__ import annotations

import jax.numpy as jnp


def img_log_weights_ref(theta: jnp.ndarray, h: jnp.ndarray | float) -> jnp.ndarray:
    """theta (P, M, d), h scalar → (P,) float32 log weights."""
    theta = theta.astype(jnp.float32)
    h = jnp.asarray(h, jnp.float32)
    mean = jnp.mean(theta, axis=1, keepdims=True)  # (P, 1, d)
    sse = jnp.sum((theta - mean) ** 2, axis=(1, 2))  # (P,)
    m, d = theta.shape[1], theta.shape[2]
    return -0.5 * sse / (h * h) - m * (d / 2.0) * jnp.log(2.0 * jnp.pi * h * h)
