"""jit'd public wrapper for the IMG log-weight kernel: padding + dispatch.

Pads P to the block multiple (extra rows sliced off) and d with zeros (zero
features are exactly weight-neutral: they shift SSE by 0). Falls back to the
reference for tiny problems where kernel launch overhead dominates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.img_weights.kernel import img_log_weights_kernel
from repro.kernels.img_weights.ref import img_log_weights_ref


def _round_up(n: int, k: int) -> int:
    return (n + k - 1) // k * k


@functools.partial(
    jax.jit, static_argnames=("block_p", "block_d", "interpret", "min_kernel_p")
)
def img_log_weights(
    theta: jnp.ndarray,  # (P, M, d)
    h: jnp.ndarray | float,
    *,
    block_p: int = 256,
    block_d: int = 512,
    interpret: bool | None = None,  # None -> repro.kernels.default_interpret()
    min_kernel_p: int = 64,
) -> jnp.ndarray:
    if interpret is None:
        interpret = default_interpret()
    P, M, d = theta.shape
    if P < min_kernel_p:
        return img_log_weights_ref(theta, h)
    block_p = min(block_p, _round_up(P, 8))
    block_d = min(block_d, _round_up(d, 128))
    Pp, dp = _round_up(P, block_p), _round_up(d, block_d)
    padded = jnp.zeros((Pp, M, dp), theta.dtype).at[:P, :, :d].set(theta)
    h_arr = jnp.asarray(h, jnp.float32).reshape(1)
    out = img_log_weights_kernel(
        padded, h_arr, block_p=block_p, block_d=block_d, interpret=interpret
    )
    # padded d-features contribute 0 SSE but DO enter the log-normalizer the
    # kernel applies with the *padded* d; correct by the normalizer delta.
    if dp != d:
        h32 = jnp.asarray(h, jnp.float32)
        delta = M * ((dp - d) / 2.0) * jnp.log(2.0 * jnp.pi * h32 * h32)
        out = out + delta
    return out[:P]
