"""Pallas TPU kernel: batched IMG mixture log-weights (paper Eq. 3.5).

TPU-native layout (not a CUDA port — there is no warp/SMEM notion here):

- grid = (P // block_p, d // block_d): parallel over candidate components,
  *arbitrary* (sequential-accumulate) over feature blocks.
- Each grid step loads a (block_p, M, block_d) VMEM tile — the M axis stays
  fully resident (M ≤ 64 machines ⇒ ≤ 64·block_p·block_d·4B, sized for VMEM).
- SSE is accumulated across d-blocks in an f32 VMEM scratch (block_p,); the
  log-normalizer is applied once on the last d-block.
- All reductions are VPU-friendly (axis=1/2 sums over a dense tile); no
  gather/scatter — the caller materializes the (P, M, d) selection, which for
  Algorithm-1-style sweeps is a cheap take_along_axis outside the kernel.

The d-axis padding contract: padded features MUST be zero in ``theta`` (then
θ̄ is zero there too and the SSE contribution vanishes) — ``ops.py`` enforces
this. Padded P rows produce garbage and are sliced off by ``ops.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer releases renamed it
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _img_weights_kernel(theta_ref, h_ref, out_ref, acc_ref, *, n_dblocks: int, m: int, d: int):
    j = pl.program_id(1)  # d-block index (sequential accumulation axis)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    t = theta_ref[...].astype(jnp.float32)  # (block_p, M, block_d)
    mean = jnp.mean(t, axis=1, keepdims=True)
    sse = jnp.sum((t - mean) ** 2, axis=(1, 2))  # (block_p,)
    acc_ref[...] += sse

    @pl.when(j == n_dblocks - 1)
    def _finalize():
        h = h_ref[0]
        inv2h2 = 0.5 / (h * h)
        log_norm = m * (d / 2.0) * jnp.log(2.0 * jnp.pi * h * h)
        out_ref[...] = -acc_ref[...] * inv2h2 - log_norm


@functools.partial(jax.jit, static_argnames=("block_p", "block_d", "interpret"))
def img_log_weights_kernel(
    theta: jnp.ndarray,  # (P, M, d) — P, d already padded to block multiples
    h: jnp.ndarray,  # (1,) float32
    *,
    block_p: int = 256,
    block_d: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    P, M, d = theta.shape
    n_p, n_d = P // block_p, d // block_d
    kernel = functools.partial(
        _img_weights_kernel, n_dblocks=n_d, m=M, d=theta.shape[2]
    )
    return pl.pallas_call(
        kernel,
        grid=(n_p, n_d),
        in_specs=[
            pl.BlockSpec((block_p, M, block_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec(memory_space=pl.ANY),  # h: tiny scalar operand
        ],
        out_specs=pl.BlockSpec((block_p,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((P,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_p,), jnp.float32)],
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(theta, h)
