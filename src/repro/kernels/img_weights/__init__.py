from repro.kernels.img_weights.ops import img_log_weights
from repro.kernels.img_weights.ref import img_log_weights_ref

__all__ = ["img_log_weights", "img_log_weights_ref"]
