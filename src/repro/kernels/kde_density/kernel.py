"""Pallas TPU kernel: streaming Gaussian-KDE log-density.

Flash-attention-style online logsumexp, rethought for KDE scoring:

- grid = (nq // block_q, ns // block_s): parallel over query tiles,
  sequential over center tiles.
- Per step: squared distances via the MXU identity
      ‖q − s‖² = ‖q‖² + ‖s‖² − 2·q·sᵀ
  (one (block_q, d)·(d, block_s) matmul — the same trick flash attention
  uses to keep the QKᵀ score tile MXU-bound), then an online max/renormalize
  update of the running (m, ℓ) pair in VMEM scratch. The (nq, ns) score
  matrix never exists in HBM.
- Center-tile padding is handled with an additive mask row (−1e30 before
  max), provided by ops.py.

VMEM per step: (block_q + block_s)·d·4 + 2·block_q·block_s·4 + O(block_q).
Defaults (256, 512, d ≤ 1024) stay well under 16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer releases renamed it
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG_BIG = -1e30


def _kde_kernel(q_ref, s_ref, mask_ref, h_ref, out_ref, m_ref, l_ref, *, n_sblocks: int, d: int, ns: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_BIG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)  # (block_q, d)
    s = s_ref[...].astype(jnp.float32)  # (block_s, d)
    mask = mask_ref[...].astype(jnp.float32)  # (1, block_s) 0 / -1e30
    h = h_ref[0]

    qn = jnp.sum(q * q, axis=-1, keepdims=True)  # (block_q, 1)
    sn = jnp.sum(s * s, axis=-1)[None, :]  # (1, block_s)
    cross = jax.lax.dot_general(
        q, s, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (block_q, block_s)
    scores = -(qn + sn - 2.0 * cross) * (0.5 / (h * h)) + mask

    m_new = jnp.maximum(m_ref[...], jnp.max(scores, axis=-1))
    correction = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * correction + jnp.sum(
        jnp.exp(scores - m_new[:, None]), axis=-1
    )
    m_ref[...] = m_new

    @pl.when(j == n_sblocks - 1)
    def _finalize():
        log_norm = jnp.log(jnp.asarray(ns, jnp.float32)) + 0.5 * d * jnp.log(
            2.0 * jnp.pi * h * h
        )
        out_ref[...] = m_ref[...] + jnp.log(l_ref[...]) - log_norm


# ---------------------------------------------------------------------------
# batched all-machines variant: one launch scores every machine's KDE
# ---------------------------------------------------------------------------


def _machine_kde_kernel(
    h_ref,  # scalar-prefetch: (M,) per-machine bandwidth
    c_ref,  # scalar-prefetch: (M,) int32 valid-prefix counts
    w_ref,  # scalar-prefetch: (M,) log mixture weights (mixture epilogues)
    q_ref,  # (block_q, d) query tile
    s_ref,  # (1, block_s, d) center tile of machine m
    *refs,  # out refs (by `reduce`), then scratch: m, l, acc, mx_m, mx_l
    n_sblocks: int,
    n_machines: int,
    block_s: int,
    d: int,
    reduce: str,
):
    outs, (m_scr, l_scr, acc_scr, mxm_scr, mxl_scr) = refs[:-5], refs[-5:]
    m = pl.program_id(1)
    j = pl.program_id(2)
    first_machine = m == 0
    last_machine = m == n_machines - 1

    @pl.when(j == 0)
    def _init_machine():
        m_scr[...] = jnp.full_like(m_scr, _NEG_BIG)
        l_scr[...] = jnp.zeros_like(l_scr)

    @pl.when(jnp.logical_and(first_machine, j == 0))
    def _init_epilogue():
        acc_scr[...] = jnp.zeros_like(acc_scr)
        mxm_scr[...] = jnp.full_like(mxm_scr, _NEG_BIG)
        mxl_scr[...] = jnp.zeros_like(mxl_scr)

    q = q_ref[...].astype(jnp.float32)  # (block_q, d)
    s = s_ref[0].astype(jnp.float32)  # (block_s, d)
    h = h_ref[m]
    cnt = c_ref[m]

    qn = jnp.sum(q * q, axis=-1, keepdims=True)  # (block_q, 1)
    sn = jnp.sum(s * s, axis=-1)[None, :]  # (1, block_s)
    cross = jax.lax.dot_general(
        q, s, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (block_q, block_s)
    scores = -(qn + sn - 2.0 * cross) * (0.5 / (h * h))

    # valid-prefix mask lives IN the kernel: center column t of tile j is row
    # j·block_s + t of machine m's chain. A where-select (not an additive
    # mask) so NaN garbage beyond counts[m] can never poison max/exp.
    col = jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1) + j * block_s
    valid = col < cnt  # (1, block_s)
    scores = jnp.where(valid, scores, _NEG_BIG)

    m_new = jnp.maximum(m_scr[...], jnp.max(scores, axis=-1))
    p = jnp.where(valid, jnp.exp(scores - m_new[:, None]), 0.0)
    l_scr[...] = l_scr[...] * jnp.exp(m_scr[...] - m_new) + jnp.sum(p, axis=-1)
    m_scr[...] = m_new

    @pl.when(j == n_sblocks - 1)
    def _finalize_machine():
        cntf = jnp.maximum(cnt.astype(jnp.float32), 1.0)
        log_norm = jnp.log(cntf) + 0.5 * d * jnp.log(2.0 * jnp.pi * h * h)
        lpm = m_scr[...] + jnp.log(l_scr[...]) - log_norm  # (block_q,); -inf if empty

        if reduce == "none":
            outs[0][0, :] = lpm
            return

        k = 0
        if reduce in ("product", "product_mixture"):
            acc_scr[...] = acc_scr[...] + lpm  # Σ_m log p̂_m; -inf propagates

            @pl.when(last_machine)
            def _():
                outs[0][...] = acc_scr[...]

            k = 1
        if reduce in ("mixture", "product_mixture"):
            # online logsumexp across machines of log w_m + log p̂_m; empty
            # machines enter as the -1e30 sentinel and contribute exp→0.
            lw = jnp.maximum(lpm + w_ref[m], _NEG_BIG)
            mx_new = jnp.maximum(mxm_scr[...], lw)
            pm = jnp.where(lw > 0.1 * _NEG_BIG, jnp.exp(lw - mx_new), 0.0)
            mxl_scr[...] = mxl_scr[...] * jnp.exp(mxm_scr[...] - mx_new) + pm
            mxm_scr[...] = mx_new

            @pl.when(last_machine)
            def _():
                outs[k][...] = mxm_scr[...] + jnp.log(mxl_scr[...])


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_s", "interpret", "reduce"),
)
def machine_kde_log_density_kernel(
    queries: jnp.ndarray,  # (nq, d) padded: nq % block_q == 0
    samples: jnp.ndarray,  # (M, T, d) padded: T % block_s == 0
    h: jnp.ndarray,  # (M,) float32 per-machine bandwidth
    counts: jnp.ndarray,  # (M,) int32 valid-prefix counts (≤ unpadded T)
    log_mix_w: jnp.ndarray,  # (M,) float32 log mixture weights
    *,
    reduce: str = "none",
    block_q: int = 256,
    block_s: int = 512,
    interpret: bool = False,
):
    """All-machines KDE scoring in ONE launch: grid (q-tile, machine, s-tile).

    Flash-style online logsumexp per (query-tile, machine) in VMEM scratch —
    the (M, nq, T) score tensor never exists. ``reduce`` selects the fused
    epilogue: ``"none"`` → (M, nq) per-machine log densities; ``"product"`` →
    (nq,) pooled product score Σ_m log p̂_m; ``"mixture"`` → (nq,) mixture
    score logsumexp_m(log w_m + log p̂_m); ``"product_mixture"`` → both, with
    the (M, nq) matrix never materialized in any reduced mode. Per-machine
    bandwidth and valid-prefix ``counts`` ride the scalar-prefetch operand and
    are applied inside the kernel, so dense and ragged chains take the same
    code path (a machine's rows beyond ``counts[m]`` may hold NaN garbage —
    they are where-selected out before any max/exp).
    """
    nq, d = queries.shape
    M, T, _ = samples.shape
    n_q, n_s = nq // block_q, T // block_s
    if reduce == "none":
        out_shape = [jax.ShapeDtypeStruct((M, nq), jnp.float32)]
        out_specs = [pl.BlockSpec((1, block_q), lambda i, m, j, *_: (m, i))]
    elif reduce in ("product", "mixture"):
        out_shape = [jax.ShapeDtypeStruct((nq,), jnp.float32)]
        out_specs = [pl.BlockSpec((block_q,), lambda i, m, j, *_: (i,))]
    elif reduce == "product_mixture":
        out_shape = [jax.ShapeDtypeStruct((nq,), jnp.float32)] * 2
        out_specs = [pl.BlockSpec((block_q,), lambda i, m, j, *_: (i,))] * 2
    else:
        raise ValueError(f"unknown reduce={reduce!r}")

    kernel = functools.partial(
        _machine_kde_kernel,
        n_sblocks=n_s, n_machines=M, block_s=block_s, d=d, reduce=reduce,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_q, M, n_s),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, m, j, *_: (i, 0)),
            pl.BlockSpec((1, block_s, d), lambda i, m, j, *_: (m, j, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((block_q,), jnp.float32) for _ in range(5)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(
        h.astype(jnp.float32),
        counts.astype(jnp.int32),
        log_mix_w.astype(jnp.float32),
        queries,
        samples,
    )
    return out[0] if len(out) == 1 else tuple(out)


@functools.partial(jax.jit, static_argnames=("block_q", "block_s", "interpret", "ns_actual"))
def kde_log_density_kernel(
    queries: jnp.ndarray,  # (nq, d) padded: nq % block_q == 0
    centers: jnp.ndarray,  # (ns, d) padded: ns % block_s == 0
    mask: jnp.ndarray,  # (1, ns) additive: 0 valid / -1e30 padded
    h: jnp.ndarray,  # (1,)
    *,
    ns_actual: int,
    block_q: int = 256,
    block_s: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    nq, d = queries.shape
    ns = centers.shape[0]
    n_q, n_s = nq // block_q, ns // block_s
    kernel = functools.partial(_kde_kernel, n_sblocks=n_s, d=d, ns=ns_actual)
    return pl.pallas_call(
        kernel,
        grid=(n_q, n_s),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_s, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, block_s), lambda i, j: (0, j)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(queries, centers, mask, h)
