"""Pallas TPU kernel: streaming Gaussian-KDE log-density.

Flash-attention-style online logsumexp, rethought for KDE scoring:

- grid = (nq // block_q, ns // block_s): parallel over query tiles,
  sequential over center tiles.
- Per step: squared distances via the MXU identity
      ‖q − s‖² = ‖q‖² + ‖s‖² − 2·q·sᵀ
  (one (block_q, d)·(d, block_s) matmul — the same trick flash attention
  uses to keep the QKᵀ score tile MXU-bound), then an online max/renormalize
  update of the running (m, ℓ) pair in VMEM scratch. The (nq, ns) score
  matrix never exists in HBM.
- Center-tile padding is handled with an additive mask row (−1e30 before
  max), provided by ops.py.

VMEM per step: (block_q + block_s)·d·4 + 2·block_q·block_s·4 + O(block_q).
Defaults (256, 512, d ≤ 1024) stay well under 16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer releases renamed it
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG_BIG = -1e30


def _kde_kernel(q_ref, s_ref, mask_ref, h_ref, out_ref, m_ref, l_ref, *, n_sblocks: int, d: int, ns: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_BIG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)  # (block_q, d)
    s = s_ref[...].astype(jnp.float32)  # (block_s, d)
    mask = mask_ref[...].astype(jnp.float32)  # (1, block_s) 0 / -1e30
    h = h_ref[0]

    qn = jnp.sum(q * q, axis=-1, keepdims=True)  # (block_q, 1)
    sn = jnp.sum(s * s, axis=-1)[None, :]  # (1, block_s)
    cross = jax.lax.dot_general(
        q, s, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (block_q, block_s)
    scores = -(qn + sn - 2.0 * cross) * (0.5 / (h * h)) + mask

    m_new = jnp.maximum(m_ref[...], jnp.max(scores, axis=-1))
    correction = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * correction + jnp.sum(
        jnp.exp(scores - m_new[:, None]), axis=-1
    )
    m_ref[...] = m_new

    @pl.when(j == n_sblocks - 1)
    def _finalize():
        log_norm = jnp.log(jnp.asarray(ns, jnp.float32)) + 0.5 * d * jnp.log(
            2.0 * jnp.pi * h * h
        )
        out_ref[...] = m_ref[...] + jnp.log(l_ref[...]) - log_norm


@functools.partial(jax.jit, static_argnames=("block_q", "block_s", "interpret", "ns_actual"))
def kde_log_density_kernel(
    queries: jnp.ndarray,  # (nq, d) padded: nq % block_q == 0
    centers: jnp.ndarray,  # (ns, d) padded: ns % block_s == 0
    mask: jnp.ndarray,  # (1, ns) additive: 0 valid / -1e30 padded
    h: jnp.ndarray,  # (1,)
    *,
    ns_actual: int,
    block_q: int = 256,
    block_s: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    nq, d = queries.shape
    ns = centers.shape[0]
    n_q, n_s = nq // block_q, ns // block_s
    kernel = functools.partial(_kde_kernel, n_sblocks=n_s, d=d, ns=ns_actual)
    return pl.pallas_call(
        kernel,
        grid=(n_q, n_s),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_s, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, block_s), lambda i, j: (0, j)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(queries, centers, mask, h)
