"""Pure-jnp oracle: Gaussian-KDE log-density of queries under a sample set.

Used by the L2-distance metric (paper §8: d₂(p, p̂) between groundtruth and
combined samples) and by the semiparametric correction. For queries Q (nq, d)
and kernel centers S (ns, d) with bandwidth h:

    log p̂(q) = logsumexp_j [ −‖q − s_j‖² / (2h²) ] − log(ns) − (d/2)·log(2πh²)

The naive form materializes the (nq, ns) score matrix; the kernel streams it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def kde_log_density_ref(
    queries: jnp.ndarray,  # (nq, d)
    centers: jnp.ndarray,  # (ns, d)
    h: jnp.ndarray | float,
) -> jnp.ndarray:
    q = queries.astype(jnp.float32)
    s = centers.astype(jnp.float32)
    h = jnp.asarray(h, jnp.float32)
    d = q.shape[-1]
    sq = jnp.sum((q[:, None, :] - s[None, :, :]) ** 2, axis=-1)  # (nq, ns)
    lse = jax.scipy.special.logsumexp(-0.5 * sq / (h * h), axis=1)
    return lse - jnp.log(s.shape[0]) - 0.5 * d * jnp.log(2.0 * jnp.pi * h * h)
