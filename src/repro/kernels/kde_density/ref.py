"""Pure-jnp oracle: Gaussian-KDE log-density of queries under a sample set.

Used by the L2-distance metric (paper §8: d₂(p, p̂) between groundtruth and
combined samples) and by the semiparametric correction. For queries Q (nq, d)
and kernel centers S (ns, d) with bandwidth h:

    log p̂(q) = logsumexp_j [ −‖q − s_j‖² / (2h²) ] − log(ns) − (d/2)·log(2πh²)

The naive form materializes the (nq, ns) score matrix; the kernel streams it.
"""

from __future__ import annotations

import math

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

# host-side, not jnp.log(...): module import must not run a JAX
# computation (jax.distributed.initialize refuses to start after one)
_LOG2PI = math.log(2.0 * math.pi)


def machine_kde_log_density_ref(
    queries: jnp.ndarray,  # (Q, d)
    samples: jnp.ndarray,  # (M, T, d)
    h: jnp.ndarray,  # (M,) or scalar bandwidth
    counts: Optional[jnp.ndarray] = None,  # (M,) int; None ⇒ all T rows valid
    *,
    reduce: str = "none",
    mixture_weights: str = "counts",
    chunk: int = 256,
) -> Union[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Chunked masked-logsumexp oracle for the batched all-machines KDE op.

    Scores every machine's Gaussian KDE at every query without materializing
    the (M, Q, T) tensor all at once: queries stream through ``lax.map`` in
    ``chunk``-row tiles, each tile scored against all machines by one einsum.
    Rows at index ≥ ``counts[m]`` are where-selected to −inf before the
    logsumexp, so NaN garbage in the invalid suffix is inert. ``reduce``
    mirrors the kernel's fused epilogues: ``"none"`` → (M, Q); ``"product"``
    → (Q,) Σ_m log p̂_m; ``"mixture"`` → (Q,) logsumexp_m(log w_m + log p̂_m)
    with w from ``counts`` or uniform; ``"product_mixture"`` → both.
    """
    M, T, d = samples.shape
    h = jnp.broadcast_to(jnp.asarray(h), (M,))
    counts = (
        jnp.full((M,), T, jnp.int32) if counts is None else counts.astype(jnp.int32)
    )

    mask = jnp.arange(T)[None, :] < counts[:, None]  # (M, T) bool
    csq = jnp.sum(samples**2, axis=-1)  # (M, T)
    Q = queries.shape[0]
    pad = (-Q) % chunk
    qp = jnp.pad(queries, ((0, pad), (0, 0))).reshape(-1, chunk, d)

    def block(qc):  # (chunk, d) → (M, chunk)
        sq = (
            jnp.sum(qc**2, axis=-1)[None, :, None]
            + csq[:, None, :]
            - 2.0 * jnp.einsum("qd,mtd->mqt", qc, samples)
        )
        logk = -0.5 * sq / (h[:, None, None] ** 2)
        logk = jnp.where(mask[:, None, :], logk, -jnp.inf)
        return jax.scipy.special.logsumexp(logk, axis=-1)

    out = jax.lax.map(block, qp)  # (n_chunks, M, chunk)
    lse = jnp.moveaxis(out, 0, 1).reshape(M, -1)[:, :Q]  # (M, Q)
    log_norm = (
        -jnp.log(jnp.maximum(counts.astype(queries.dtype), 1.0))
        - 0.5 * d * (2.0 * jnp.log(h) + _LOG2PI)
    )
    logp = lse + log_norm[:, None]

    if reduce == "none":
        return logp
    want_prod = reduce in ("product", "product_mixture")
    want_mix = reduce in ("mixture", "product_mixture")
    if not (want_prod or want_mix):
        raise ValueError(f"unknown reduce={reduce!r}")
    prod = jnp.sum(logp, axis=0) if want_prod else None
    mix = None
    if want_mix:
        if mixture_weights == "uniform":
            # subtract-after form: bitwise-identical to the historical
            # importance_pool reduction logsumexp(logp, 0) − log M
            mix = jax.scipy.special.logsumexp(logp, axis=0) - jnp.log(
                jnp.asarray(M, logp.dtype)
            )
        elif mixture_weights == "counts":
            cf = counts.astype(logp.dtype)
            logw = jnp.log(cf) - jnp.log(jnp.sum(cf))
            mix = jax.scipy.special.logsumexp(logp + logw[:, None], axis=0)
        else:
            raise ValueError(f"unknown mixture_weights={mixture_weights!r}")
    if want_prod and want_mix:
        return prod, mix
    return prod if want_prod else mix


def kde_log_density_ref(
    queries: jnp.ndarray,  # (nq, d)
    centers: jnp.ndarray,  # (ns, d)
    h: jnp.ndarray | float,
) -> jnp.ndarray:
    q = queries.astype(jnp.float32)
    s = centers.astype(jnp.float32)
    h = jnp.asarray(h, jnp.float32)
    d = q.shape[-1]
    sq = jnp.sum((q[:, None, :] - s[None, :, :]) ** 2, axis=-1)  # (nq, ns)
    lse = jax.scipy.special.logsumexp(-0.5 * sq / (h * h), axis=1)
    return lse - jnp.log(s.shape[0]) - 0.5 * d * jnp.log(2.0 * jnp.pi * h * h)
