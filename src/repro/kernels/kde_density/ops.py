"""jit'd wrapper for the streaming KDE log-density kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.kde_density.kernel import kde_log_density_kernel
from repro.kernels.kde_density.ref import kde_log_density_ref


def _round_up(n: int, k: int) -> int:
    return (n + k - 1) // k * k


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_s", "interpret", "min_kernel_n")
)
def kde_log_density(
    queries: jnp.ndarray,  # (nq, d)
    centers: jnp.ndarray,  # (ns, d)
    h: jnp.ndarray | float,
    *,
    block_q: int = 256,
    block_s: int = 512,
    interpret: bool | None = None,  # None -> repro.kernels.default_interpret()
    min_kernel_n: int = 64,
) -> jnp.ndarray:
    if interpret is None:
        interpret = default_interpret()
    nq, d = queries.shape
    ns = centers.shape[0]
    if nq < min_kernel_n or ns < min_kernel_n:
        return kde_log_density_ref(queries, centers, h)
    block_q = min(block_q, _round_up(nq, 8))
    block_s = min(block_s, _round_up(ns, 128))
    nq_p, ns_p = _round_up(nq, block_q), _round_up(ns, block_s)
    qp = jnp.zeros((nq_p, d), queries.dtype).at[:nq].set(queries)
    sp = jnp.zeros((ns_p, d), centers.dtype).at[:ns].set(centers)
    mask = jnp.full((1, ns_p), -1e30, jnp.float32).at[:, :ns].set(0.0)
    h_arr = jnp.asarray(h, jnp.float32).reshape(1)
    out = kde_log_density_kernel(
        qp, sp, mask, h_arr,
        ns_actual=ns, block_q=block_q, block_s=block_s, interpret=interpret,
    )
    return out[:nq]
