"""jit'd wrappers for the streaming KDE log-density kernels."""

from __future__ import annotations

import functools
import math

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.kde_density.kernel import (
    kde_log_density_kernel,
    machine_kde_log_density_kernel,
)
from repro.kernels.kde_density.ref import (
    kde_log_density_ref,
    machine_kde_log_density_ref,
)


def _round_up(n: int, k: int) -> int:
    return (n + k - 1) // k * k


@functools.partial(
    jax.jit,
    static_argnames=(
        "reduce", "mixture_weights", "block_q", "block_s", "chunk",
        "interpret", "impl", "min_kernel_n",
    ),
)
def machine_kde_log_density(
    queries: jnp.ndarray,  # (Q, d)
    samples: jnp.ndarray,  # (M, T, d)
    h: jnp.ndarray,  # (M,) or scalar per-machine bandwidth
    counts: Optional[jnp.ndarray] = None,  # (M,) int; None ⇒ all rows valid
    *,
    reduce: str = "none",
    mixture_weights: str = "counts",
    block_q: int = 256,
    block_s: int = 512,
    chunk: int = 256,
    interpret: bool | None = None,  # None -> repro.kernels.default_interpret()
    impl: str | None = None,  # None -> "kernel" on real TPU, "ref" elsewhere
    min_kernel_n: int = 64,
) -> Union[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Batched all-machines KDE scoring: one launch for every machine.

    ``reduce="none"`` returns the (M, Q) per-machine log densities;
    ``"product"`` / ``"mixture"`` / ``"product_mixture"`` return the fused
    (Q,) reductions without materializing (M, Q) on the kernel path. Dense
    (``counts is None``) and ragged chains share one code path: validity is a
    per-machine prefix applied inside the kernel / ref, so NaN garbage beyond
    ``counts[m]`` never reaches a max or exp.

    Routing: the Pallas kernel only pays off where it compiles to real TPU
    code — under interpret mode it is a correctness tool, not an execution
    engine, so CPU runs take the vectorized chunked jnp ref (which is also
    the path small problems take, below ``min_kernel_n``).
    """
    if interpret is None:
        interpret = default_interpret()
    if impl is None:
        impl = "ref" if interpret else "kernel"
    M, T, d = samples.shape
    Q = queries.shape[0]
    if impl == "ref" or Q < min_kernel_n or T < min_kernel_n:
        return machine_kde_log_density_ref(
            queries, samples, h, counts,
            reduce=reduce, mixture_weights=mixture_weights, chunk=chunk,
        )

    h_arr = jnp.broadcast_to(jnp.asarray(h, jnp.float32), (M,))
    counts_arr = (
        jnp.full((M,), T, jnp.int32) if counts is None else counts.astype(jnp.int32)
    )
    if mixture_weights == "uniform":
        logw = jnp.full((M,), -math.log(M), jnp.float32)
    elif mixture_weights == "counts":
        cf = counts_arr.astype(jnp.float32)
        logw = jnp.log(cf) - jnp.log(jnp.sum(cf))
    else:
        raise ValueError(f"unknown mixture_weights={mixture_weights!r}")

    block_q = min(block_q, _round_up(Q, 8))
    block_s = min(block_s, _round_up(T, 128))
    Qp, Tp = _round_up(Q, block_q), _round_up(T, block_s)
    qp = jnp.zeros((Qp, d), queries.dtype).at[:Q].set(queries)
    # T-padding needs no special handling: padded rows sit at index ≥ T ≥
    # counts[m] and fall out of the same in-kernel valid-prefix mask.
    sp = jnp.zeros((M, Tp, d), samples.dtype).at[:, :T].set(samples)
    out = machine_kde_log_density_kernel(
        qp, sp, h_arr, counts_arr, logw,
        reduce=reduce, block_q=block_q, block_s=block_s, interpret=interpret,
    )
    if reduce == "none":
        return out[:, :Q]
    if reduce == "product_mixture":
        return out[0][:Q], out[1][:Q]
    return out[:Q]


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_s", "interpret", "min_kernel_n")
)
def kde_log_density(
    queries: jnp.ndarray,  # (nq, d)
    centers: jnp.ndarray,  # (ns, d)
    h: jnp.ndarray | float,
    *,
    block_q: int = 256,
    block_s: int = 512,
    interpret: bool | None = None,  # None -> repro.kernels.default_interpret()
    min_kernel_n: int = 64,
) -> jnp.ndarray:
    if interpret is None:
        interpret = default_interpret()
    nq, d = queries.shape
    ns = centers.shape[0]
    if nq < min_kernel_n or ns < min_kernel_n:
        return kde_log_density_ref(queries, centers, h)
    block_q = min(block_q, _round_up(nq, 8))
    block_s = min(block_s, _round_up(ns, 128))
    nq_p, ns_p = _round_up(nq, block_q), _round_up(ns, block_s)
    qp = jnp.zeros((nq_p, d), queries.dtype).at[:nq].set(queries)
    sp = jnp.zeros((ns_p, d), centers.dtype).at[:ns].set(centers)
    mask = jnp.full((1, ns_p), -1e30, jnp.float32).at[:, :ns].set(0.0)
    h_arr = jnp.asarray(h, jnp.float32).reshape(1)
    out = kde_log_density_kernel(
        qp, sp, mask, h_arr,
        ns_actual=ns, block_q=block_q, block_s=block_s, interpret=interpret,
    )
    return out[:nq]
