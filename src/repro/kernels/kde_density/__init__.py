from repro.kernels.kde_density.ops import kde_log_density, machine_kde_log_density
from repro.kernels.kde_density.ref import (
    kde_log_density_ref,
    machine_kde_log_density_ref,
)

__all__ = [
    "kde_log_density",
    "kde_log_density_ref",
    "machine_kde_log_density",
    "machine_kde_log_density_ref",
]
