"""whisper-base [audio] — enc-dec, conv frontend stub (arXiv:2212.04356).

6L d_model=512 8H (MHA) d_ff=2048 vocab=51865. The assignment specifies the
transformer BACKBONE; ``input_specs`` feeds precomputed (B, 1500, 512) frame
embeddings (the conv1d×2 + sinusoidal-position frontend is the stub).
Decoder runs at the assigned shapes; encoder at its native 1500 frames.
"""

from repro.models.lm.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,  # decoder layers
    num_encoder_layers=6,
    encoder_seq=1500,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51_865,
)
