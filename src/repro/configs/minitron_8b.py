"""minitron-8b [dense] — pruned Nemotron (arXiv:2407.14679; hf).

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""

from repro.models.lm.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256_000,
    fsdp=True,  # 8B params + fp32 Adam state want ZeRO sharding on v5e-16GB
    attn_chunk=2048,  # flash tile 1024->2048: -6.4% HBM term (EXPERIMENTS.md §Perf)
)
