"""granite-moe-1b-a400m [moe] — 32 experts top-8
(hf:ibm-granite/granite-3.0-1b-a400m-base; hf).

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8.
"""

from repro.models.lm.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=0,  # all FFNs are MoE
    vocab_size=49_155,
    moe=MoEConfig(
        num_experts=32,
        top_k=8,
        d_ff_expert=512,
        group_size=128,  # small d_ff ⇒ small groups keep dispatch overhead low
        capacity_factor=1.25,
    ),
    tie_embeddings=True,
)
