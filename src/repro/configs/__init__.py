"""Architecture registry: ``get_config(arch_id)`` + the assigned shape table.

Every assigned (arch × shape) cell is enumerable via :func:`all_cells`;
inapplicable cells (DESIGN.md §4 skips) carry a ``skip`` reason instead of
being silently dropped.
"""

from __future__ import annotations

import importlib
from typing import Dict, List, NamedTuple, Optional

from repro.models.lm.config import ModelConfig

ARCH_IDS = [
    "minitron_8b",
    "qwen1_5_4b",
    "deepseek_coder_33b",
    "llama3_2_3b",
    "jamba_1_5_large",
    "whisper_base",
    "granite_moe_1b",
    "deepseek_v2_236b",
    "mamba2_130m",
    "llava_next_mistral_7b",
]

# canonical external names (``--arch`` accepts either form)
ALIASES = {
    "minitron-8b": "minitron_8b",
    "qwen1.5-4b": "qwen1_5_4b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "llama3.2-3b": "llama3_2_3b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "whisper-base": "whisper_base",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "mamba2-130m": "mamba2_130m",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
}


class ShapeSpec(NamedTuple):
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = [
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
]


def get_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    module = importlib.import_module(f"repro.configs.{arch}")
    return module.CONFIG


class Cell(NamedTuple):
    arch: str
    shape: ShapeSpec
    skip: Optional[str]  # None = runs; else DESIGN.md §4 skip reason


def all_cells() -> List[Cell]:
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            skip = None
            if shape.name == "long_500k" and not cfg.subquadratic:
                skip = (
                    "long_500k requires sub-quadratic attention; "
                    f"{arch} is pure full-attention (DESIGN.md §4)"
                )
            cells.append(Cell(arch=arch, shape=shape, skip=skip))
    return cells
