"""llava-next-mistral-7b [vlm] — anyres tiling stub
(hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified).

Mistral-7B backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
The vision tower is a STUB: ``input_specs`` supplies (B, 576, 1024) patch
embeddings (CLIP-ViT-L/14 336px grid) which a learned projector maps to
d_model and prepends to the token sequence (anyres tiling collapses to the
base 576-token grid in the stub).
"""

from repro.models.lm.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32_000,
    num_image_tokens=576,
    fsdp=True,
)
