"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
(arXiv:2405.04434; hf).

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400; first layer dense
(d_ff 12288), layers 1..59 MoE. Decode uses the absorbed-MLA cache
(kv_lora 512 + rope 64 per token).
"""

from repro.models.lm.config import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,  # MLA: all heads read the shared latent
    head_dim=128,
    d_ff=12288,  # dense first layer
    vocab_size=102_400,
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        d_ff_expert=1536,
        num_shared_experts=2,
        first_dense=1,
        group_size=256,
        capacity_factor=1.25,
    ),
    fsdp=True,
    opt_state_dtype="bfloat16",  # 236B: params+mu+nu = 6B/param -> 5.5 GB/chip @256
)
