"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE
(arXiv:2403.19887; hf).

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Period-8 blocks: attention at in-period index 4, Mamba elsewhere; MoE replaces
the MLP on odd in-period layers (Jamba's every-other-layer MoE).
"""

from repro.models.lm.config import HybridConfig, MoEConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65_536,
    moe=MoEConfig(
        num_experts=16,
        top_k=2,
        d_ff_expert=24576,
        group_size=256,
        capacity_factor=1.25,
    ),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=128, head_block=16),  # chunk 256->128: SSD HBM traffic -18% (EXPERIMENTS.md §Perf)
    hybrid=HybridConfig(period=8, attn_index=4, moe_every=2, moe_offset=1),
    fsdp=True,
    subquadratic=True,  # hybrid: long_500k cell applies
    max_seq_len=32_768,
    opt_state_dtype="bfloat16",  # 398B: params+mu+nu = 6B/param -> 9.3 GB/chip @256
)
