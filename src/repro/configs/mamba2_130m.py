"""mamba2-130m [ssm] — SSD, attention-free (arXiv:2405.21060; unverified).

24L d_model=768 ssm_state=128 vocab=50280.
"""

from repro.models.lm.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True,
    subquadratic=True,  # SSM: long_500k cell applies (O(1) state per token)
    max_seq_len=524_288,
)
