"""Optimizers + distributed-optimization tricks (no external deps)."""

from repro.optim.adamw import adamw_init, adamw_update  # noqa: F401
from repro.optim.compression import (  # noqa: F401
    compress_lowrank,
    decompress_lowrank,
    error_feedback_update,
)
