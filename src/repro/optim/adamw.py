"""AdamW (decoupled weight decay), pytree-native, fp32 state.

State is kept in fp32 regardless of param dtype (bf16 params — standard mixed
precision). The sharding policy places optimizer state on the same
PartitionSpec as its parameter, plus ZeRO-1 sharding of the state over the
``data`` axis when ``fsdp`` is enabled in the arch config.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    mu: PyTree
    nu: PyTree
    count: jnp.ndarray


def adamw_init(params: PyTree, *, state_dtype=jnp.float32) -> AdamWState:
    """``state_dtype``: fp32 default; the 236B/398B archs use bf16 states so
    (params + μ + ν) fits v5e HBM at 256 chips (see DESIGN.md §5). The update
    arithmetic is always fp32; only storage is cast."""
    sd = jnp.dtype(state_dtype)
    return AdamWState(
        mu=jax.tree.map(lambda p: jnp.zeros(p.shape, sd), params),
        nu=jax.tree.map(lambda p: jnp.zeros(p.shape, sd), params),
        count=jnp.zeros((), jnp.int32),
    )


def adamw_update(
    params: PyTree,
    grads: PyTree,
    state: AdamWState,
    *,
    lr: float | jnp.ndarray = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Tuple[PyTree, AdamWState]:
    count = state.count + 1
    if grad_clip:
        gnorm = jnp.sqrt(
            sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    mu = jax.tree.map(
        lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(m.dtype),
        state.mu,
        grads,
    )
    nu = jax.tree.map(
        lambda v, g: (
            b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g.astype(jnp.float32))
        ).astype(v.dtype),
        state.nu,
        grads,
    )
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, m, v):
        step = (m.astype(jnp.float32) / c1) / (jnp.sqrt(v.astype(jnp.float32) / c2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(mu=mu, nu=nu, count=count)
