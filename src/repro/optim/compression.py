"""Gradient compression for the synchronous (``--mode sgd``) baseline.

PowerSGD-style rank-r compression with error feedback (Vogels et al. 2019):
matrices are factored G ≈ P Qᵀ by one subspace iteration; the all-reduce then
moves r·(n+m) floats instead of n·m — directly attacking the collective
roofline term the paper's EP-MCMC mode eliminates entirely. Error feedback
accumulates the compression residual so convergence is preserved.

This is a *beyond-paper* distributed-optimization trick: the paper removes the
gradient all-reduce altogether; for users who still want synchronous SGD this
shrinks it. Non-matrix leaves (biases, norms) pass through uncompressed.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class LowRankPair(NamedTuple):
    p: jnp.ndarray  # (n, r)
    q: jnp.ndarray  # (m, r)


def compress_lowrank(
    key: jax.Array, grad: jnp.ndarray, rank: int
) -> Tuple[LowRankPair, jnp.ndarray]:
    """One-shot subspace iteration. grad (n, m) → (P, Q), residual."""
    n, m = grad.shape[-2], grad.shape[-1]
    g2 = grad.reshape(-1, m) if grad.ndim > 2 else grad
    q0 = jax.random.normal(key, (m, rank), jnp.float32)
    p = g2.astype(jnp.float32) @ q0  # (n', r)
    # Orthonormalize p (Gram-Schmidt via QR) for a stable projection.
    p, _ = jnp.linalg.qr(p)
    q = g2.astype(jnp.float32).T @ p  # (m, r)
    approx = (p @ q.T).astype(grad.dtype).reshape(grad.shape)
    return LowRankPair(p=p, q=q), grad - approx


def decompress_lowrank(pair: LowRankPair, shape) -> jnp.ndarray:
    return (pair.p @ pair.q.T).reshape(shape)


def error_feedback_update(
    key: jax.Array,
    grads: PyTree,
    error: PyTree,
    rank: int = 8,
) -> Tuple[PyTree, PyTree]:
    """Compress+decompress every ≥2-d leaf with error feedback.

    Returns (compressed-approx grads to all-reduce, new error buffers).
    In the mesh runtime the P/Q factors are what cross the ``data`` axis;
    here we return the already-decompressed approximation so callers can
    psum it directly (bytes accounting happens at the collective layer).
    """
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(error)
    keys = jax.random.split(key, len(leaves))
    out, new_err = [], []
    for k, g, e in zip(keys, leaves, err_leaves):
        if g.ndim >= 2 and min(g.shape[-2], g.shape[-1]) > rank:
            pair, resid = compress_lowrank(k, g + e.astype(g.dtype), rank)
            out.append(decompress_lowrank(pair, g.shape).astype(g.dtype))
            new_err.append(resid.astype(e.dtype))
        else:
            out.append(g)
            new_err.append(jnp.zeros_like(e))
    return jax.tree.unflatten(treedef, out), jax.tree.unflatten(treedef, new_err)


def init_error_feedback(grads_like: PyTree) -> PyTree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
