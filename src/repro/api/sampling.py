"""The embarrassingly parallel sampling stage behind ``repro.api``.

Moved here from ``repro.launch.mcmc_run`` (which now only adapts argparse
flags onto a :class:`repro.api.RunSpec`) and factored into two layers:

- :func:`make_shard_kernel` packages one registry sampler for one model as a
  :class:`ShardKernel` — how to draw θ0, how to *build* the kernel from a
  concrete shard and a (possibly traced) step size, and how to project
  stacked positions back to the shared ``(T, d)`` θ. Because ``build`` is a
  pure function of ``(shard, count, step_size)``, the same ShardKernel
  serves three drivers: the one-shot chain here, the chunk-emitting stream
  driver (:mod:`repro.api.streaming` — checkpointing and combine-while-
  sampling subscribe to it; it rebuilds the kernel from a checkpointed ε on
  resume), and the compile-cached matrix runner (:mod:`repro.api.matrix`,
  which traces ``step_size`` so specs differing only there share one
  executable).
- :func:`run_shard_chain` is the per-shard glue — RNG discipline, warmup
  dispatch, burn-in accounting — shared by every driver so their draws are
  bitwise identical.

The public entry points keep their historical signatures:
:func:`make_shard_sampler`, :func:`sample_subposteriors` (vmap on one
device, ``shard_map`` over the mesh ``data`` axis with the compiled HLO
asserted collective-free given more), and :func:`groundtruth_chain`.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.subposterior import make_subposterior_logpdf, partition_data
from repro.models.bayes import BayesModel
from repro.samplers import filter_options, run_chain, sampler_spec
from repro.samplers.base import MCMCKernel

PyTree = Any


class SampleResult(NamedTuple):
    """Output of the parallel sampling stage."""

    theta: jnp.ndarray  # (M, T, d) shared-θ subposterior draws
    accept: jnp.ndarray  # (M,) mean acceptance per chain
    counts: jnp.ndarray  # (M,) real data rows per shard (pad=True convention)
    backend: str  # a repro.api.backends.BackendId string
    collectives_checked: Optional[int]  # HLO collectives verified chain-local


class ShardKernel(NamedTuple):
    """One (model, sampler) pairing, ready to instantiate per shard.

    ``build(shard, count, step_size)`` must be pure and accept a traced
    ``step_size`` — the resumable driver re-invokes it from a checkpointed
    (possibly warmup-adapted) ε, and the matrix runner from a runtime scalar.
    """

    init_position: Callable[[jax.Array, PyTree], PyTree]
    build: Callable[[PyTree, jnp.ndarray, jnp.ndarray], MCMCKernel]
    extract: Callable[[PyTree], jnp.ndarray]  # stacked positions -> (T, d) θ
    adaptive: bool  # eligible for dual-averaging warmup
    target_accept: float


def _shard_axes(shards: PyTree, shard_keys, per_datum_leaf, broadcast_leaf):
    """Per-leaf vmap axes / PartitionSpecs: per-datum leaves carry the chain
    axis, broadcast leaves (e.g. gmm mixture weights) are replicated."""
    if shard_keys is None:
        return jax.tree.map(lambda _: per_datum_leaf, shards)
    return {
        k: (per_datum_leaf if k in shard_keys else broadcast_leaf)
        for k in shards
    }


def make_shard_kernel(
    model: BayesModel,
    num_shards: int,
    sampler: str,
    *,
    sgld_batch: int = 256,
    use_counts: bool = True,
    sampler_options=(),
) -> ShardKernel:
    """Package one registry sampler for one model as a :class:`ShardKernel`.

    ``use_counts=False`` statically drops the padded-row likelihood
    correction (every shard row is real) so the divisible-N hot path pays
    nothing for pad support. ``sampler_options`` (e.g. RunSpec's field) is
    filtered per factory signature — the registry's option-forwarding
    convention — and splatted into every kernel build; keys this layer owns
    (the logpdf wiring, step size, Gibbs blocks, SGLD closures) are
    reserved and dropped.
    """
    spec = sampler_spec(sampler)
    _RESERVED = ("step_size", "block_updates", "grad_logpdf", "batch_fn")
    extra = {
        k: v
        for k, v in filter_options(spec.factory, dict(sampler_options)).items()
        if k not in _RESERVED
    }

    if spec.name == "gibbs":  # alias-safe: spec.name is canonical
        if not model.has_gibbs:
            raise ValueError(
                f"model {model.name!r} supplies no Gibbs blocks "
                "(BayesModel.gibbs_blocks)"
            )
        # models declaring gibbs_counts mask the edge-padded replicated rows
        # out of their conditionals (count= is the pad convention's valid
        # prefix); everyone else sees the raw shard, exactly as before
        pass_count = model.gibbs_counts and use_counts

        def build_gibbs(shard, count, step_size):
            kwargs = {"count": count} if pass_count else {}
            blocks = model.gibbs_blocks(
                shard, num_shards, step_size=step_size, **kwargs
            )
            return spec.factory(
                None, step_size=step_size, block_updates=blocks, **extra
            )

        return ShardKernel(
            init_position=lambda k, shard: model.gibbs_init(k, shard),
            build=build_gibbs,
            extract=model.gibbs_extract,
            adaptive=False,
            target_accept=spec.target_accept,
        )

    def make_logpdf(shard, count):
        return make_subposterior_logpdf(
            model.log_prior,
            model.log_lik,
            shard,
            num_shards,
            count=count if use_counts else None,
            per_datum=model.shard_keys,
        )

    if spec.name == "sgld":

        def build_sgld(shard, count, step_size):
            # minibatch subposterior gradients (paper §7): scale by the
            # shard's REAL row count so padded rows never bias the estimate
            if model.shard_keys is None:
                per_datum = shard
                rest = None
            else:
                per_datum = {k: shard[k] for k in model.shard_keys}
                rest = {k: v for k, v in shard.items() if k not in model.shard_keys}
            shard_size = jax.tree.leaves(per_datum)[0].shape[0]
            batch_size = min(sgld_batch or shard_size, shard_size)
            inv_m = 1.0 / float(num_shards)
            n_real = count if use_counts else shard_size

            def mb_logpdf(theta, batch):
                scale = jnp.asarray(n_real, jnp.float32) / float(batch_size)
                return inv_m * model.log_prior(theta) + scale * model.log_lik(
                    theta, batch
                )

            def batch_fn(k, _t):
                idx = jax.random.randint(
                    k, (batch_size,), 0, jnp.maximum(n_real, 1)
                )
                batch = jax.tree.map(lambda x: x[idx], per_datum)
                return batch if rest is None else {**rest, **batch}

            return spec.factory(
                make_logpdf(shard, count),
                step_size=step_size,
                grad_logpdf=jax.grad(mb_logpdf),
                batch_fn=batch_fn,
                **extra,
            )

        return ShardKernel(
            init_position=model.initial_position,
            build=build_sgld,
            extract=lambda pos: pos,
            adaptive=False,
            target_accept=spec.target_accept,
        )

    def build_mh(shard, count, step_size):
        return spec.factory(
            make_logpdf(shard, count), step_size=step_size, **extra
        )

    return ShardKernel(
        init_position=model.initial_position,
        build=build_mh,
        extract=lambda pos: pos,
        adaptive=spec.adaptive,
        target_accept=spec.target_accept,
    )


def run_shard_chain(
    sk: ShardKernel,
    shard: PyTree,
    count: jnp.ndarray,
    key: jax.Array,
    *,
    num_samples: int,
    burn_in: int,
    warmup: int,
    step_size: float | jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One subposterior chain: ``(theta (T, d), mean_accept)``.

    The single source of the per-shard RNG discipline (``k_init, k_run =
    split(key)``) and of the warmup/burn-in accounting: adaptive kernels
    spend ``warmup`` dual-averaging transitions, non-adaptive ones treat
    them as extra burn-in (registry convention).
    """
    k_init, k_run = jax.random.split(key)
    pos0 = sk.init_position(k_init, shard)
    if sk.adaptive and warmup > 0:
        pos, info = run_chain(
            k_run,
            lambda eps: sk.build(shard, count, eps),
            pos0,
            num_samples,
            burn_in=burn_in,
            warmup=warmup,
            initial_step_size=step_size,
            target_accept=sk.target_accept,
        )
    else:
        kern = sk.build(shard, count, step_size)
        pos, info = run_chain(
            k_run,
            kern,
            pos0,
            num_samples,
            burn_in=burn_in + (0 if sk.adaptive else warmup),
        )
    return sk.extract(pos), info.is_accepted.mean()


def make_shard_sampler(
    model: BayesModel,
    num_shards: int,
    sampler: str,
    *,
    num_samples: int,
    burn_in: int,
    warmup: int,
    step_size: float,
    sgld_batch: int = 256,
    use_counts: bool = True,
    sampler_options=(),
) -> Callable[[PyTree, jnp.ndarray, jax.Array], Tuple[jnp.ndarray, jnp.ndarray]]:
    """Build ``one_shard(shard, count, key) -> (theta (T, d), mean_accept)``.

    The returned function is pure and shape-uniform across shards, so the
    launch layer can drive it under ``vmap`` (one device) or ``shard_map``
    (chain groups over the mesh data axis) unchanged.
    """
    sk = make_shard_kernel(
        model,
        num_shards,
        sampler,
        sgld_batch=sgld_batch,
        use_counts=use_counts,
        sampler_options=sampler_options,
    )

    def one_shard(shard, count, key):
        return run_shard_chain(
            sk,
            shard,
            count,
            key,
            num_samples=num_samples,
            burn_in=burn_in,
            warmup=warmup,
            step_size=step_size,
        )

    return one_shard


def sample_subposteriors(
    key: jax.Array,
    model: BayesModel,
    data: PyTree,
    num_shards: int,
    num_samples: int,
    *,
    sampler: Optional[str] = None,
    warmup: int = 200,
    burn_in: int = 0,
    step_size: float = 0.1,
    sgld_batch: int = 256,
    check_hlo: bool = True,
    mesh_shape: Optional[Tuple[int, int]] = None,
    sampler_options=(),
    shards: Optional[PyTree] = None,
    counts: Optional[jnp.ndarray] = None,
) -> SampleResult:
    """The embarrassingly parallel stage: M independent subposterior chains.

    Partitions ``data`` (edge-padded — non-divisible N is fine), then runs
    one chain per shard; a caller that already partitioned (e.g.
    ``Pipeline.partition()``'s artifact) passes ``shards``/``counts`` to
    skip the duplicate copy. With >1 local device and ``num_shards``
    divisible by the device count, chains are ``shard_map``-ped over the
    ``data`` axis of a ``(ndev, 1)`` ("data", "model") mesh (override via
    ``mesh_shape``) and the compiled HLO is asserted collective-free across
    chains; otherwise the chains are vmapped on one device. Zero cross-chain
    communication either way.
    """
    sampler = sampler or model.default_sampler
    if shards is None or counts is None:
        shards, counts = partition_data(
            data, num_shards, only=model.shard_keys, pad=True
        )
    padded = is_padded(model, shards, counts, sampler)
    one_shard = make_shard_sampler(
        model,
        num_shards,
        sampler,
        num_samples=num_samples,
        burn_in=burn_in,
        warmup=warmup,
        step_size=step_size,
        sgld_batch=sgld_batch,
        # divisible N ⇒ every row is real ⇒ skip the pad correction entirely
        use_counts=padded,
        sampler_options=sampler_options,
    )
    keys = jax.random.split(key, num_shards)
    in_axes = (_shard_axes(shards, model.shard_keys, 0, None), 0, 0)
    vmapped = jax.vmap(one_shard, in_axes=in_axes)

    # late import: backends imports this module (kernel layer) at load time
    from repro.api.backends import BackendId

    ndev = jax.device_count()
    if mesh_shape is None and ndev > 1 and num_shards % ndev == 0:
        mesh_shape = (ndev, 1)
    if mesh_shape is not None and mesh_shape[0] > 1:
        theta, acc, checked = _sample_on_mesh(
            vmapped, shards, counts, keys, model, mesh_shape, check_hlo
        )
        return SampleResult(
            theta, acc, counts, BackendId.mesh(mesh_shape[0]), checked
        )
    theta, acc = jax.jit(vmapped)(shards, counts, keys)
    return SampleResult(theta, acc, counts, BackendId.vmap(), None)


def is_padded(model, shards, counts, sampler) -> bool:
    """Whether any shard carries replicated pad rows (and guard gibbs)."""
    shard_rows = jax.tree.leaves(
        shards if model.shard_keys is None
        else {k: shards[k] for k in model.shard_keys}
    )[0].shape[1]
    padded = bool(jax.device_get(jnp.any(counts != shard_rows)))
    if (
        padded
        and sampler_spec(sampler).name == "gibbs"
        and not model.gibbs_counts
    ):
        raise ValueError(
            f"model {model.name!r}'s gibbs block updates operate on the raw "
            "shard and cannot mask padded rows (BayesModel.gibbs_counts is "
            "False); choose M dividing N "
            f"(counts={jax.device_get(counts)})"
        )
    return padded


def _sample_on_mesh(vmapped, shards, counts, keys, model, mesh_shape, check_hlo):
    """shard_map the vmapped per-shard sampler over the mesh data axis.

    Each device owns ``M/ndev`` chains + their data shards; broadcast leaves
    are replicated. The jitted program is lowered AOT so the post-SPMD HLO
    can be asserted collective-free *before* it runs — the machine-checked
    "embarrassingly parallel" property.
    """
    from functools import partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    # late import: epmcmc pulls the (heavy) LM stack this path otherwise skips
    from repro.distributed.epmcmc import assert_no_cross_chain_collectives

    mesh = jax.make_mesh(tuple(mesh_shape), ("data", "model"))
    shard_specs = _shard_axes(shards, model.shard_keys, P("data"), P())
    in_specs = (shard_specs, P("data"), P("data"))
    body = partial(
        shard_map,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P("data"), P("data")),
        check_rep=False,
    )(vmapped)
    compiled = jax.jit(body).lower(shards, counts, keys).compile()
    checked = None
    if check_hlo:
        checked = assert_no_cross_chain_collectives(compiled.as_text(), mesh)
    put = lambda tree, specs: jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs
    )
    theta, acc = compiled(
        put(shards, shard_specs), put(counts, P("data")), put(keys, P("data"))
    )
    return theta, acc, checked


def groundtruth_chain(
    key: jax.Array,
    model: BayesModel,
    data: PyTree,
    num_samples: int,
    *,
    sampler: Optional[str] = None,
    warmup: int = 200,
    burn_in: int = 0,
    step_size: float = 0.1,
    sgld_batch: int = 256,
    sampler_options=(),
) -> jnp.ndarray:
    """Single full-data chain (num_shards=1) with the same sampler surface."""
    one = make_shard_sampler(
        model,
        1,
        sampler or model.default_sampler,
        num_samples=num_samples,
        burn_in=burn_in,
        warmup=warmup,
        step_size=step_size,
        sgld_batch=sgld_batch,
        use_counts=False,  # full data: every row is real
        sampler_options=sampler_options,
    )
    theta, _ = jax.jit(lambda k: one(data, jnp.zeros((), jnp.int32), k))(key)
    return theta
