"""Declarative run specification: one EP-MCMC scenario as a value.

A :class:`RunSpec` names everything the paper's pipeline needs — model,
sampler, combiner(s), the partition size M, chain length T, warmup, seed,
mesh shape, and per-registry option dicts — and nothing about *how* to run
it. Execution lives in :class:`repro.api.Pipeline` (staged, resumable) and
:func:`repro.api.run_matrix` (compile-cached sweeps); a spec is just data:

- **validated** against the three registries (models, samplers, combiners)
  plus cross-cutting feasibility rules (a ``gibbs`` spec needs a model with a
  Gibbs surface);
- **hashable and pytree-registered** (all-static, leafless) so specs can key
  caches, ride through ``jax.jit`` closures, and live in pytrees;
- **serializable**: ``to_dict``/``from_dict`` and JSON round-trip, with a
  canonical :attr:`spec_id` content hash naming checkpoints and result rows;
- **groupable**: :meth:`executable_signature` is the tuple of
  compile-relevant statics — two specs with equal signatures (e.g. differing
  only in ``seed`` or ``step_size``) share one compiled sampling executable
  in :func:`repro.api.run_matrix`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple, Union

import jax

Options = Union[Mapping[str, Any], Iterable[Tuple[str, Any]]]


def _freeze_options(options: Options) -> Tuple[Tuple[str, Any], ...]:
    """Canonicalize an option mapping to a sorted, hashable tuple of pairs."""
    items = list(options.items()) if isinstance(options, Mapping) else list(options)
    frozen = []
    for k, v in sorted(items):
        if isinstance(v, list):
            v = tuple(v)
        frozen.append((str(k), v))
    return tuple(frozen)


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One model × sampler × combiner × mesh scenario, as data.

    Fields mirror the ``mcmc_run`` CLI flags; zero values mean "use the
    registry/paper default" (``sampler=None`` → the model's
    ``default_sampler``, ``burn_in=0`` → the paper's T/6 rule, ``n=0`` → the
    model's ``default_n``). ``combiner`` may be ``"all"`` (every canonical
    combiner), one registry name, or a tuple of names.
    """

    model: str
    sampler: Optional[str] = None
    combiner: Union[str, Tuple[str, ...]] = "all"
    M: int = 10
    T: int = 2000
    warmup: int = 200
    burn_in: int = 0
    step_size: float = 0.1
    sgld_batch: int = 256
    n: int = 0
    seed: int = 0
    groundtruth_T: int = 4000
    score_metric: str = "auto"  # "auto" (logL2 iff d >= 40) | "l2" | "logl2"
    stream_every: int = 0  # >0: sample in chunks of this many draws and fold
    # each chunk into the streaming combiners as it lands (combine-while-
    # sampling; Pipeline.stream_combine). 0 = one chunk (classic gather).
    mesh_shape: Optional[Tuple[int, int]] = None
    sampler_options: Tuple[Tuple[str, Any], ...] = ()
    combiner_options: Tuple[Tuple[str, Any], ...] = ()

    def __post_init__(self):
        set_ = object.__setattr__
        if isinstance(self.combiner, list):
            set_(self, "combiner", tuple(self.combiner))
        if self.mesh_shape is not None:
            set_(self, "mesh_shape", tuple(int(x) for x in self.mesh_shape))
        set_(self, "sampler_options", _freeze_options(self.sampler_options))
        set_(self, "combiner_options", _freeze_options(self.combiner_options))
        for field, lo in (("M", 1), ("T", 1), ("warmup", 0), ("burn_in", 0),
                          ("n", 0), ("groundtruth_T", 1), ("sgld_batch", 0),
                          ("stream_every", 0)):
            if int(getattr(self, field)) < lo:
                raise ValueError(f"RunSpec.{field} must be >= {lo}")
        if not self.step_size > 0:
            raise ValueError("RunSpec.step_size must be positive")
        if self.score_metric not in ("auto", "l2", "logl2"):
            raise ValueError(
                f"RunSpec.score_metric must be auto|l2|logl2, got {self.score_metric!r}"
            )

    # -- registry resolution -------------------------------------------------

    def resolved_sampler(self) -> str:
        """Canonical sampler name (the model's default when ``sampler=None``)."""
        from repro.models.bayes import get_model
        from repro.samplers import sampler_spec

        name = self.sampler or get_model(self.model).default_sampler
        return sampler_spec(name).name

    def resolved_n(self) -> int:
        from repro.models.bayes import get_model

        return self.n or get_model(self.model).default_n

    def resolved_burn_in(self) -> int:
        """Paper §8: discard the first 1/6 of the chain unless overridden."""
        return self.burn_in or self.T // 6

    def combiner_names(self) -> Tuple[str, ...]:
        from repro.core.combiners import canonical_combiners

        if self.combiner == "all":
            return canonical_combiners()
        if isinstance(self.combiner, str):
            return (self.combiner,)
        return tuple(self.combiner)

    def validate(self) -> "RunSpec":
        """Resolve every name against its registry; raise on any mismatch."""
        from repro.core.combiners import get_combiner
        from repro.models.bayes import get_model

        model = get_model(self.model)
        sampler = self.resolved_sampler()
        if sampler == "gibbs" and not model.has_gibbs:
            raise ValueError(
                f"spec {self.spec_id}: model {self.model!r} supplies no Gibbs "
                "blocks (BayesModel.gibbs_blocks) but sampler resolves to 'gibbs'"
            )
        for name in self.combiner_names():
            get_combiner(name)
        if self.mesh_shape is not None:
            ndata = self.mesh_shape[0]
            if ndata < 1 or self.M % ndata != 0:
                raise ValueError(
                    f"spec {self.spec_id}: mesh data axis {ndata} must divide M={self.M}"
                )
        return self

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["sampler_options"] = dict(self.sampler_options)
        d["combiner_options"] = dict(self.combiner_options)
        if isinstance(self.combiner, tuple):
            d["combiner"] = list(self.combiner)
        if self.mesh_shape is not None:
            d["mesh_shape"] = list(self.mesh_shape)
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RunSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown RunSpec fields: {sorted(unknown)}")
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "RunSpec":
        return cls.from_dict(json.loads(s))

    @property
    def spec_id(self) -> str:
        """Canonical content hash — stable across processes, sensitive to
        every field (names checkpoints, result rows, compile groups)."""
        return hashlib.sha256(self.to_json().encode()).hexdigest()[:12]

    # -- compile grouping ----------------------------------------------------

    def executable_signature(self) -> Tuple[Any, ...]:
        """The statics that shape the compiled sampling program.

        ``seed`` and ``step_size`` are runtime inputs (the RNG key and a
        traced scalar), and the combiner list never enters the sampling
        stage, so specs differing only there share one executable —
        :func:`repro.api.run_matrix` keys its jit cache on this tuple.
        """
        return (
            "sample", self.model, self.resolved_sampler(), self.M, self.T,
            self.warmup, self.resolved_burn_in(), self.resolved_n(),
            self.sgld_batch, self.mesh_shape, self.sampler_options,
            # chunk cadence shapes the compiled chunk program (Pipeline's
            # chunked driver); 0 keeps pre-streaming signatures grouped
            self.stream_every,
        )

    # -- sweep grammar -------------------------------------------------------

    def sweep(self, **axes: Iterable[Any]) -> List["RunSpec"]:
        """Cartesian sweep over field values → a validated spec list.

        ``spec.sweep(seed=range(8), combiner=["parametric", "nonparametric"])``
        yields 16 cells ready for :func:`repro.api.run_matrix`. Each keyword
        names a RunSpec field and supplies an *iterable of values* for it
        (a bare string is rejected — pass ``combiner=["parametric"]``, not
        ``combiner="parametric"``); axes combine as an outer product in
        keyword order, varying the last axis fastest. Cells differing only
        in runtime inputs (``seed``, ``step_size``, ``combiner``) share one
        :meth:`executable_signature`, so the matrix runner compiles once
        for the whole sweep.
        """
        if not axes:
            return [self]
        known = {f.name for f in dataclasses.fields(self)}
        lists = []
        for name, values in axes.items():
            if name not in known:
                raise ValueError(
                    f"sweep axis {name!r} is not a RunSpec field "
                    f"(choices: {', '.join(sorted(known))})"
                )
            if isinstance(values, (str, bytes)) or not isinstance(values, Iterable):
                raise TypeError(
                    f"sweep axis {name!r} needs an iterable of field values "
                    f"(got {values!r}); a single value still goes in a list"
                )
            values = list(values)
            if not values:
                raise ValueError(f"sweep axis {name!r} is empty")
            lists.append(values)
        names = list(axes)
        return [
            dataclasses.replace(self, **dict(zip(names, combo))).validate()
            for combo in itertools.product(*lists)
        ]

    def groundtruth_signature(self) -> Tuple[Any, ...]:
        """Compile statics of the single full-data groundtruth chain."""
        return (
            "groundtruth", self.model, self.resolved_sampler(),
            self.groundtruth_T, self.warmup, self.resolved_n(),
            self.sgld_batch, self.sampler_options,
        )


# All-static pytree node (no leaves): a RunSpec can sit inside pytrees handed
# to jax transforms and comes back unchanged.
jax.tree_util.register_pytree_node(
    RunSpec, lambda spec: ((), spec), lambda spec, _: spec
)
