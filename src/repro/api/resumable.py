"""Chunked, checkpointable subposterior sampling — resume mid-chain.

Since the streaming refactor this module is a thin adapter: the chunk loop
itself lives in :mod:`repro.api.streaming` (``ShardChainStream`` /
``stream_sample``), where checkpoint persistence is one *subscriber* of the
chunk stream rather than a fork of the driver. What this wrapper pins down
is the historical resumable contract:

- the per-step RNG keys are a pure function of the spec's seed
  (``jax.random.split(k_collect, T)`` computed identically on every
  session), not of the chunking;
- the kernel is rebuilt on resume from the checkpointed warmup-adapted step
  size ε (``warmup_chain`` returns ``factory(ε)``, so rebuild ≡ original);
- chunk boundaries are *global* (k·checkpoint_every) and sessions advance in
  whole chunks, so resume replays exactly the same chunk programs on the
  same inputs as a never-interrupted run — no reliance on XLA fusing a
  split scan identically to one big scan (it may differ at the last ulp;
  ``tests/test_api_resume.py`` pins the bitwise contract and the numerical
  agreement with the one-shot vmap path separately).

Checkpoint layout (one ``repro.checkpoint`` step per boundary, step number =
draws collected): kernel state stacked over chains, per-chain ε and collect
key, the draws so far, and acceptance sums; metadata records the owning
``spec_id`` (a directory can never resume a different scenario) plus the
checkpoint and chunk cadences (a mid-flight run is cadence-locked).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax

from repro.models.bayes import BayesModel
from repro.api.sampling import SampleResult
from repro.api.streaming import StreamChunk, stream_sample

PyTree = Any


class ResumableSample(NamedTuple):
    """Sampling-stage artifact that may be mid-flight.

    ``result.theta`` holds the first ``t_done`` draws per chain; ``complete``
    is True once ``t_done == T`` (only then is ``accept`` meaningful).
    """

    result: SampleResult
    t_done: int
    total: int
    resumed_from: int  # 0 on a fresh run, else the checkpointed draw count

    @property
    def complete(self) -> bool:
        return self.t_done >= self.total


def sample_subposteriors_resumable(
    key: jax.Array,
    model: BayesModel,
    data: PyTree,
    num_shards: int,
    num_samples: int,
    *,
    sampler: Optional[str] = None,
    warmup: int = 200,
    burn_in: int = 0,
    step_size: float = 0.1,
    sgld_batch: int = 256,
    sampler_options=(),
    checkpoint_dir: str,
    checkpoint_every: int = 0,
    spec_id: str = "",
    max_steps: Optional[int] = None,
    shards: Optional[PyTree] = None,
    counts: Optional[jax.Array] = None,
    chunk_size: int = 0,
    on_chunk: Sequence[Callable[[StreamChunk], None]] = (),
    mesh_shape: Optional[tuple] = None,
    check_hlo: bool = True,
) -> ResumableSample:
    """Run (or resume) the parallel sampling stage with chunked persistence.

    ``checkpoint_every`` draws per saved boundary (0 ⇒ one chunk, persisted
    only at the end); ``chunk_size`` optionally emits finer-grained chunks
    between saves (``checkpoint_every`` must then be a multiple of it — the
    combine-while-sampling cadence); ``max_steps`` stops after that many
    draws this session — budgeted sampling, and the test hook for simulating
    preemption. Sessions advance in whole chunks, so ``max_steps`` requires
    a cadence it can actually express (anything less would silently do zero
    durable work). A later call with the same ``checkpoint_dir``/``spec_id``
    picks up where this one stopped; a directory owned by a different
    ``spec_id`` raises; ``on_chunk`` subscribers see every chunk, restored
    prefix included (``replayed=True``). ``mesh_shape`` selects the
    :mod:`repro.api.backends` execution backend — checkpointing works
    unchanged on the mesh (saves land host-side, restores are re-committed
    to the mesh).
    """
    ss = stream_sample(
        key,
        model,
        data,
        num_shards,
        num_samples,
        sampler=sampler,
        warmup=warmup,
        burn_in=burn_in,
        step_size=step_size,
        sgld_batch=sgld_batch,
        sampler_options=sampler_options,
        shards=shards,
        counts=counts,
        chunk_size=chunk_size,
        max_steps=max_steps,
        checkpoint_dir=checkpoint_dir,
        checkpoint_every=checkpoint_every,
        spec_id=spec_id,
        on_chunk=on_chunk,
        mesh_shape=mesh_shape,
        check_hlo=check_hlo,
    )
    return ResumableSample(
        result=ss.result,
        t_done=ss.t_done,
        total=ss.total,
        resumed_from=ss.resumed_from,
    )
