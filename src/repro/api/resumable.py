"""Chunked, checkpointable subposterior sampling — resume mid-chain.

The one-shot drivers in :mod:`repro.api.sampling` run each chain under a
single ``lax.scan``; a preemption loses the whole stage. This driver runs
the *same* per-step transitions in chunks, persisting the live kernel state
between chunks via :mod:`repro.checkpoint`, so sampling interrupted at chain
step t resumes from the persisted state rather than restarting — and the
final draws are **bitwise identical** to the uninterrupted (chunked) run,
because:

- the per-step RNG keys are a pure function of the spec's seed
  (``jax.random.split(k_collect, T)`` computed identically on every
  session), not of the chunking;
- the kernel is rebuilt on resume from the checkpointed warmup-adapted step
  size ε (``warmup_chain`` returns ``factory(ε)``, so rebuild ≡ original);
- chunk boundaries are *global* (k·checkpoint_every) and sessions advance in
  whole chunks, so resume replays exactly the same chunk programs on the
  same inputs as a never-interrupted run — no reliance on XLA fusing a
  split scan identically to one big scan (it may differ at the last ulp;
  ``tests/test_api_resume.py`` pins the bitwise contract and the numerical
  agreement with the one-shot vmap path separately).

Checkpoint layout (one ``repro.checkpoint`` step per chunk boundary, step
number = draws collected): kernel state stacked over chains, per-chain ε and
collect key, the draws so far, and acceptance sums; metadata records the
owning ``spec_id`` so a directory can never resume a different scenario.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore, save
from repro.core.subposterior import partition_data
from repro.models.bayes import BayesModel
from repro.samplers.adaptation import warmup_chain
from repro.api.sampling import (
    SampleResult,
    ShardKernel,
    is_padded,
    _shard_axes,
    make_shard_kernel,
)

PyTree = Any


class ResumableSample(NamedTuple):
    """Sampling-stage artifact that may be mid-flight.

    ``result.theta`` holds the first ``t_done`` draws per chain; ``complete``
    is True once ``t_done == T`` (only then is ``accept`` meaningful).
    """

    result: SampleResult
    t_done: int
    total: int
    resumed_from: int  # 0 on a fresh run, else the checkpointed draw count

    @property
    def complete(self) -> bool:
        return self.t_done >= self.total


def _setup_one(sk: ShardKernel, shard, count, key, *, burn_in, warmup, step_size):
    """Warmup + burn-in for one shard; mirrors ``run_shard_chain``'s RNG
    discipline exactly so chunked draws match the one-shot path bitwise."""
    k_init, k_run = jax.random.split(key)
    pos0 = sk.init_position(k_init, shard)
    if sk.adaptive and warmup > 0:
        k_run, k_warm = jax.random.split(k_run)
        kernel, pos0, eps = warmup_chain(
            k_warm,
            lambda e: sk.build(shard, count, e),
            pos0,
            warmup,
            initial_step_size=step_size,
            target_accept=sk.target_accept,
        )
        burn = burn_in
    else:
        eps = jnp.asarray(step_size, jnp.float32)
        kernel = sk.build(shard, count, step_size)
        burn = burn_in + (0 if sk.adaptive else warmup)
    state = kernel.init(pos0)
    if burn > 0:
        keys = jax.random.split(k_run, burn + 1)
        k_run = keys[0]

        def warm(s, k):
            s, _ = kernel.step(k, s)
            return s, None

        state, _ = jax.lax.scan(warm, state, keys[1:])
    return state, eps, k_run


def _chunk_one(sk: ShardKernel, shard, count, eps, state, keys):
    """Advance one chain by ``len(keys)`` draws from a live kernel state."""
    kernel = sk.build(shard, count, eps)

    def collect(s, k):
        s, info = kernel.step(k, s)
        return s, (s.position, info.is_accepted)

    state, (pos, acc) = jax.lax.scan(collect, state, keys)
    return state, sk.extract(pos), acc.astype(jnp.float32).sum()


def sample_subposteriors_resumable(
    key: jax.Array,
    model: BayesModel,
    data: PyTree,
    num_shards: int,
    num_samples: int,
    *,
    sampler: Optional[str] = None,
    warmup: int = 200,
    burn_in: int = 0,
    step_size: float = 0.1,
    sgld_batch: int = 256,
    sampler_options=(),
    checkpoint_dir: str,
    checkpoint_every: int = 0,
    spec_id: str = "",
    max_steps: Optional[int] = None,
    shards: Optional[PyTree] = None,
    counts: Optional[jnp.ndarray] = None,
) -> ResumableSample:
    """Run (or resume) the parallel sampling stage with chunked persistence.

    ``checkpoint_every`` draws per chunk (0 ⇒ one chunk, persisted only at
    the end); ``max_steps`` stops after that many draws this session —
    budgeted sampling, and the test hook for simulating preemption. Sessions
    advance in whole chunks, so ``max_steps`` requires a chunk cadence it
    can actually express: ``checkpoint_every > 0`` and at least one chunk's
    worth of budget (anything less would silently do zero durable work).
    A later call with the same ``checkpoint_dir``/``spec_id`` picks up where
    this one stopped; a directory owned by a different ``spec_id`` raises.
    """
    if max_steps is not None and (
        checkpoint_every <= 0 or max_steps < checkpoint_every
    ):
        raise ValueError(
            f"max_steps={max_steps} cannot make durable progress: sessions "
            "advance in whole chunks, so it needs checkpoint_every > 0 and "
            f"max_steps >= checkpoint_every (got {checkpoint_every})"
        )
    sampler = sampler or model.default_sampler
    if shards is None or counts is None:
        shards, counts = partition_data(
            data, num_shards, only=model.shard_keys, pad=True
        )
    padded = is_padded(model, shards, counts, sampler)
    sk = make_shard_kernel(
        model,
        num_shards,
        sampler,
        sgld_batch=sgld_batch,
        use_counts=padded,
        sampler_options=sampler_options,
    )
    keys = jax.random.split(key, num_shards)
    shard_axes = _shard_axes(shards, model.shard_keys, 0, None)
    setup = jax.jit(
        jax.vmap(
            functools.partial(
                _setup_one, sk, burn_in=burn_in, warmup=warmup, step_size=step_size
            ),
            in_axes=(shard_axes, 0, 0),
        )
    )

    # -- restore or initialize ----------------------------------------------
    step = latest_step(checkpoint_dir)
    if step is not None:
        state_struct = jax.eval_shape(setup, shards, counts, keys)
        carry, meta = _restore_carry(
            checkpoint_dir, step, state_struct, model.d, num_shards
        )
        if meta.get("spec_id") != spec_id or meta.get("T") != num_samples:
            raise ValueError(
                f"checkpoint at {checkpoint_dir} belongs to spec "
                f"{meta.get('spec_id')!r} (T={meta.get('T')}), not "
                f"{spec_id!r} (T={num_samples}) — refusing to resume"
            )
        t_done = int(meta["t_done"])
        # the bitwise guarantee rests on GLOBAL chunk boundaries; resuming an
        # unfinished run at a different cadence would replay the tail under a
        # different program split (a finished run has no tail to replay)
        if t_done < num_samples and meta.get("checkpoint_every") != checkpoint_every:
            raise ValueError(
                f"checkpoint at {checkpoint_dir} was written with "
                f"checkpoint_every={meta.get('checkpoint_every')}; resuming "
                f"mid-run with checkpoint_every={checkpoint_every} would "
                "shift chunk boundaries and void the bitwise-resume "
                "guarantee — pass the original cadence"
            )
        resumed_from = t_done
    else:
        state, eps, k_collect = setup(shards, counts, keys)
        carry = {
            "state": state,
            "eps": eps,
            "k_collect": k_collect,
            "theta": jnp.zeros((num_shards, 0, model.d), jnp.float32),
            "accept_sum": jnp.zeros((num_shards,), jnp.float32),
        }
        t_done = 0
        resumed_from = 0

    # per-step keys: pure function of the seed — identical on every session
    collect_keys = jax.vmap(lambda k: jax.random.split(k, num_samples))(
        carry["k_collect"]
    )

    chunk_fn = jax.jit(
        jax.vmap(
            functools.partial(_chunk_one, sk),
            in_axes=(shard_axes, 0, 0, 0, 0),
        )
    )

    # sessions advance in WHOLE chunks: boundaries at k·checkpoint_every (+ T)
    # are global, so an interrupted-then-resumed run replays exactly the same
    # chunk programs as an uninterrupted one — that is what makes the bitwise
    # guarantee structural rather than a fusion accident. max_steps therefore
    # rounds DOWN to a chunk boundary (preemption semantics: partial-chunk
    # work is lost anyway).
    stop = num_samples if max_steps is None else min(num_samples, t_done + max_steps)
    chunk = checkpoint_every if checkpoint_every > 0 else num_samples
    while t_done < stop:
        t1 = min(t_done + chunk, num_samples)
        if t1 > stop:
            break  # ragged chunk would shift later boundaries; stop here
        state, theta_c, acc_c = chunk_fn(
            shards, counts, carry["eps"], carry["state"], collect_keys[:, t_done:t1]
        )
        carry = {
            "state": state,
            "eps": carry["eps"],
            "k_collect": carry["k_collect"],
            "theta": jnp.concatenate([carry["theta"], theta_c], axis=1),
            "accept_sum": carry["accept_sum"] + acc_c,
        }
        t_done = t1
        save(
            checkpoint_dir,
            t_done,
            carry,
            metadata={
                "spec_id": spec_id,
                "t_done": t_done,
                "T": num_samples,
                "checkpoint_every": checkpoint_every,
            },
            keep=2,
        )

    accept = carry["accept_sum"] / jnp.maximum(t_done, 1)
    return ResumableSample(
        result=SampleResult(
            carry["theta"], accept, counts, "vmap[resumable]", None
        ),
        t_done=t_done,
        total=num_samples,
        resumed_from=resumed_from,
    )


def _restore_carry(checkpoint_dir, step, state_struct, d, num_shards):
    """Rebuild the carry pytree from a checkpoint, typed by the setup shapes."""
    state, eps, k_collect = state_struct
    template = {
        "state": state,
        "eps": eps,
        "k_collect": k_collect,
        "theta": jax.ShapeDtypeStruct((num_shards, step, d), jnp.float32),
        "accept_sum": jax.ShapeDtypeStruct((num_shards,), jnp.float32),
    }
    return restore(checkpoint_dir, step=step, template=template)
