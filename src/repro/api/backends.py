"""Pluggable chunk-emitting execution backends for the sampling stage.

Before this module the sampling stage had three forked paths — the chunked
vmap driver, its fused whole-run variant, and a one-shot ``shard_map`` mesh
program — and the forks leaked upward: ``Pipeline.sample`` raised on any
spec that asked for both a mesh and a stream. The fork is now a *backend*:
one :class:`ChunkBackend` contract (jitted ``setup`` + ``next_chunk`` chunk
programs, a ``fused_program`` runner, a backend-id constructor, an HLO
assert hook) with two implementations —

- :class:`VmapChunkBackend` — M chains vmapped on one device, the classic
  driver behind ``"vmap[chunked]"`` / ``"vmap[fused]"`` / ``"vmap[resumable]"``;
- :class:`MeshChunkBackend` — the *same* vmapped per-chain programs wrapped
  in ``shard_map`` over the ``data`` axis of a ``(ndata, nmodel)`` mesh, so
  every chunk is a compiled SPMD program whose post-SPMD HLO is asserted
  collective-free across chains (lazily, once per chunk shape) exactly like
  the historical one-shot path. Chunks land as dense ``(M, C, d)`` device
  slices — the same streaming-gather layout
  :func:`repro.distributed.epmcmc.gather_subset_samples` produces with
  ``chunk=`` — so every chunk subscriber (checkpointing, streaming
  combiners, :func:`repro.api.streaming.fused_fold`) drives either backend
  unchanged.

:class:`BackendId` is the one constructor for ``Scoreboard.backend``
strings; call sites must not assemble them ad hoc. The historical strings
are preserved exactly (``"vmap"``, ``"vmap[chunked]"``,
``"shard_map(4 devices)"``, …); mesh streaming adds the bracketed variants
(``"shard_map[chunked](4 devices)"``) and the multi-controller launch path
(:mod:`repro.api.launch`) adds ``"jax.distributed(2 processes)"``.

Backends are cached per compile-relevant statics (the run_matrix compile-
hygiene convention): a serving loop instantiating one stream per request
re-traces nothing, and the HLO assert runs once per (program, chunk shape)
per process.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Protocol, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.api.sampling import ShardKernel, _shard_axes, make_shard_kernel
from repro.models.bayes import BayesModel
from repro.samplers.adaptation import warmup_chain

PyTree = Any

# execution modes a chunk backend can report (BackendId bracket tags)
CHUNKED = "chunked"
FUSED = "fused"
RESUMABLE = "resumable"
_MODES = (None, CHUNKED, FUSED, RESUMABLE)


class BackendId:
    """The one constructor for sampling-backend identifier strings.

    ``Scoreboard.backend`` / ``SampleResult.backend`` values are assembled
    here and nowhere else — tests assert call sites against these exact
    spellings, so the historical strings are load-bearing.
    """

    @staticmethod
    def _check_mode(mode: Optional[str]) -> None:
        if mode not in _MODES:
            raise ValueError(
                f"unknown backend mode {mode!r} (choices: "
                f"{', '.join(repr(m) for m in _MODES)})"
            )

    @staticmethod
    def vmap(mode: Optional[str] = None) -> str:
        """``"vmap"`` or ``"vmap[chunked|fused|resumable]"``."""
        BackendId._check_mode(mode)
        return "vmap" if mode is None else f"vmap[{mode}]"

    @staticmethod
    def mesh(ndata: int, mode: Optional[str] = None) -> str:
        """``"shard_map(<ndata> devices)"`` (one-shot) or the bracketed
        chunk-streaming variants; ``ndata`` is the mesh data-axis size —
        the number of chain groups, the historical spelling."""
        BackendId._check_mode(mode)
        tag = "" if mode is None else f"[{mode}]"
        return f"shard_map{tag}({int(ndata)} devices)"

    @staticmethod
    def mesh_fanout(ndev: int) -> str:
        """``run_matrix`` fanning whole cells over a 1-axis device mesh
        (:func:`repro.api.matrix._fanout_sample`)."""
        return f"shard_map[fanout]({int(ndev)} devices)"

    @staticmethod
    def distributed(num_processes: int) -> str:
        """The multi-controller launch path (:mod:`repro.api.launch`)."""
        return f"jax.distributed({int(num_processes)} processes)"


class ChunkBackend(Protocol):
    """What every chunk-emitting execution backend provides.

    The drivers (:class:`repro.api.streaming.ShardChainStream`,
    :func:`repro.api.streaming.stream_sample`, the checkpoint subscriber,
    :meth:`Pipeline.stream_combine`) program against exactly this surface —
    a new backend that implements it streams, checkpoints, and fuses with
    zero driver changes.
    """

    kind: str  # "vmap" | "mesh"
    cache_key: Tuple  # compile-relevant statics (keys the fused-program cache)

    @property
    def collectives_checked(self) -> Optional[int]:
        """HLO collectives verified chain-local so far (None ⇒ no assert)."""

    def backend_id(self, mode: Optional[str] = None) -> str:
        """This backend's :class:`BackendId` string for ``mode``."""

    def setup(self, shards, counts, keys):
        """Jitted init + warmup + burn-in → ``(state, eps, k_collect)``."""

    def next_chunk(self, shards, counts, eps, state, keys):
        """Jitted chunk program → ``(state, theta (M, C, d), accept (M,))``;
        must be callable under an outer trace (the fused program scans it).
        Concrete calls run the backend's HLO-assert hook lazily."""

    def prepare(self, shards, counts, keys):
        """One-time device placement of the stage inputs."""

    def put_carry(self, carry: PyTree) -> PyTree:
        """Device placement of a restored checkpoint carry."""

    def localize(self, tree: PyTree) -> PyTree:
        """Bring an emitted chunk onto the default single-device layout
        before it reaches subscribers (combiner folds, checkpoint saves):
        device sharding is an execution detail and must not leak into
        subscriber numerics — the same chunk values must fold to the same
        combiner state on every backend."""

    def run_fused(self, prog_key: Tuple, prog, shards, counts, keys):
        """Execute a fused whole-run program (jitted ``run(shards, counts,
        keys) -> (theta, accept_sum)``), applying the backend's compilation
        strategy and HLO assert; cached per ``prog_key``."""


def _setup_one(sk: ShardKernel, shard, count, key, *, burn_in, warmup, step_size):
    """Warmup + burn-in for one shard; mirrors ``run_shard_chain``'s RNG
    discipline exactly so chunked draws match the one-shot path bitwise."""
    k_init, k_run = jax.random.split(key)
    pos0 = sk.init_position(k_init, shard)
    if sk.adaptive and warmup > 0:
        k_run, k_warm = jax.random.split(k_run)
        kernel, pos0, eps = warmup_chain(
            k_warm,
            lambda e: sk.build(shard, count, e),
            pos0,
            warmup,
            initial_step_size=step_size,
            target_accept=sk.target_accept,
        )
        burn = burn_in
    else:
        eps = jnp.asarray(step_size, jnp.float32)
        kernel = sk.build(shard, count, step_size)
        burn = burn_in + (0 if sk.adaptive else warmup)
    state = kernel.init(pos0)
    if burn > 0:
        keys = jax.random.split(k_run, burn + 1)
        k_run = keys[0]

        def warm(s, k):
            s, _ = kernel.step(k, s)
            return s, None

        state, _ = jax.lax.scan(warm, state, keys[1:])
    return state, eps, k_run


def _chunk_one(sk: ShardKernel, shard, count, eps, state, keys):
    """Advance one chain by ``len(keys)`` draws from a live kernel state."""
    kernel = sk.build(shard, count, eps)

    def collect(s, k):
        s, info = kernel.step(k, s)
        return s, (s.position, info.is_accepted)

    state, (pos, acc) = jax.lax.scan(collect, state, keys)
    return state, sk.extract(pos), acc.astype(jnp.float32).sum()


def _freeze_options(options) -> Tuple:
    items = options.items() if hasattr(options, "items") else options
    return tuple(sorted((str(k), v) for k, v in items))


def _is_traced(*trees) -> bool:
    return any(
        isinstance(leaf, jax.core.Tracer)
        for tree in trees
        for leaf in jax.tree.leaves(tree)
    )


class VmapChunkBackend:
    """M chains vmapped on one device — the default chunk backend.

    ``setup(shards, counts, keys) -> (state, eps, k_collect)`` and
    ``next_chunk(shards, counts, eps, state, keys) -> (state, theta, acc)``
    are the jitted per-chunk programs every driver composes; both are safe
    to call under an outer trace (the fused whole-run program scans
    ``next_chunk``).
    """

    kind = "vmap"

    def __init__(self, sk: ShardKernel, axes, *, burn_in, warmup, step_size,
                 cache_key: Tuple):
        self.cache_key = cache_key
        self.setup = jax.jit(
            jax.vmap(
                functools.partial(
                    _setup_one, sk,
                    burn_in=burn_in, warmup=warmup, step_size=step_size,
                ),
                in_axes=(axes, 0, 0),
            )
        )
        self._chunk = jax.jit(
            jax.vmap(
                functools.partial(_chunk_one, sk),
                in_axes=(axes, 0, 0, 0, 0),
            )
        )

    @property
    def collectives_checked(self) -> Optional[int]:
        return None  # single-device program — no collectives to assert

    def backend_id(self, mode: Optional[str] = None) -> str:
        return BackendId.vmap(mode)

    def next_chunk(self, shards, counts, eps, state, keys):
        return self._chunk(shards, counts, eps, state, keys)

    def prepare(self, shards, counts, keys):
        """Device placement hook — a no-op off the mesh."""
        return shards, counts, keys

    def put_carry(self, carry: PyTree) -> PyTree:
        """Restored-checkpoint placement hook — jit resharding suffices."""
        return carry

    def localize(self, tree: PyTree) -> PyTree:
        """Chunks already live on the one default device."""
        return tree

    def run_fused(self, prog_key: Tuple, prog, shards, counts, keys):
        return prog(shards, counts, keys)


class MeshChunkBackend:
    """The same chunk programs ``shard_map``-ped over the mesh data axis.

    Each device owns ``M/ndata`` chains + their data shards (broadcast
    leaves replicated). Every compiled program this backend runs — the
    chunk program (lazily, once per chunk shape) and the fused whole-run
    program — has its post-SPMD HLO asserted collective-free across chain
    groups via :func:`repro.distributed.epmcmc.assert_no_cross_chain_collectives`,
    the machine-checked "embarrassingly parallel" property the one-shot
    path established. ``collectives_checked`` accumulates across programs.
    """

    kind = "mesh"

    def __init__(self, model: BayesModel, sk: ShardKernel, axes, shards,
                 mesh_shape: Tuple[int, int], *, burn_in, warmup, step_size,
                 check_hlo: bool, cache_key: Tuple):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        need = int(mesh_shape[0]) * int(mesh_shape[1])
        ndev = jax.device_count()
        if need > ndev:
            raise ValueError(
                f"mesh_shape={tuple(mesh_shape)} needs {need} devices but "
                f"only {ndev} are visible — launch with e.g. "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
                "(or drop mesh_shape for the vmap backend)"
            )
        self.cache_key = cache_key
        self.mesh_shape = tuple(int(x) for x in mesh_shape)
        self.mesh = jax.make_mesh(self.mesh_shape, ("data", "model"))
        self._check_hlo = check_hlo
        self._checked: set = set()
        self._n_checked = 0
        self._fused: Dict[Tuple, Any] = {}
        self._shard_specs = _shard_axes(shards, model.shard_keys, P("data"), P())
        self._data_spec = P("data")

        setup_v = jax.vmap(
            functools.partial(
                _setup_one, sk,
                burn_in=burn_in, warmup=warmup, step_size=step_size,
            ),
            in_axes=(axes, 0, 0),
        )
        chunk_v = jax.vmap(
            functools.partial(_chunk_one, sk), in_axes=(axes, 0, 0, 0, 0)
        )
        self.setup = jax.jit(
            shard_map(
                setup_v,
                mesh=self.mesh,
                in_specs=(self._shard_specs, P("data"), P("data")),
                out_specs=P("data"),
                check_rep=False,
            )
        )
        self._chunk = jax.jit(
            shard_map(
                chunk_v,
                mesh=self.mesh,
                in_specs=(
                    self._shard_specs, P("data"), P("data"), P("data"),
                    P("data"),
                ),
                out_specs=P("data"),
                check_rep=False,
            )
        )

    @property
    def collectives_checked(self) -> Optional[int]:
        return self._n_checked if self._check_hlo else None

    def backend_id(self, mode: Optional[str] = None) -> str:
        return BackendId.mesh(self.mesh_shape[0], mode)

    def _assert_hlo(self, hlo_text: str) -> None:
        # late import: epmcmc pulls the heavy LM stack
        from repro.distributed.epmcmc import assert_no_cross_chain_collectives

        self._n_checked += assert_no_cross_chain_collectives(
            hlo_text, self.mesh
        )

    def next_chunk(self, shards, counts, eps, state, keys):
        # the per-chunk HLO assert: lazily, once per chunk shape, and only
        # outside a trace (the fused program scans this method — its whole-
        # run HLO is asserted by run_fused instead)
        if self._check_hlo and not _is_traced(shards, eps, state, keys):
            shape_key = ("chunk", keys.shape)
            if shape_key not in self._checked:
                self._checked.add(shape_key)
                compiled = self._chunk.lower(
                    shards, counts, eps, state, keys
                ).compile()
                self._assert_hlo(compiled.as_text())
        return self._chunk(shards, counts, eps, state, keys)

    def _put(self, tree, specs):
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        # P subclasses tuple, so test it before the container check — a bare
        # spec broadcasts over the tree rather than flattening as one
        if isinstance(specs, P) or not isinstance(specs, (dict, list, tuple)):
            specs = jax.tree.map(lambda _: specs, tree)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            tree, specs,
        )

    def prepare(self, shards, counts, keys):
        """Commit the stage inputs to the mesh once, so every chunk (and the
        AOT-compiled fused program) runs without per-call redistribution."""
        return (
            self._put(shards, self._shard_specs),
            self._put(counts, self._data_spec),
            self._put(keys, self._data_spec),
        )

    def put_carry(self, carry: PyTree) -> PyTree:
        """Re-commit a restored (host) checkpoint carry to the mesh — every
        leaf carries the leading chain axis, sharded over ``data``."""
        return self._put(carry, self._data_spec)

    def localize(self, tree: PyTree) -> PyTree:
        """De-shard an emitted chunk onto the default device. Subscriber
        math (combiner folds) must be bitwise the vmap backend's for equal
        chunk values, and a mesh-sharded operand compiles to different HLO
        — so chunks leave the mesh before anyone computes on them."""
        return jax.tree.map(lambda x: jnp.asarray(jax.device_get(x)), tree)

    def run_fused(self, prog_key: Tuple, prog, shards, counts, keys):
        """AOT-compile the fused whole-run program once per key, assert its
        HLO collective-free, then run the compiled executable directly (the
        inputs were committed by :meth:`prepare`, so shardings match)."""
        compiled = self._fused.get(prog_key)
        if compiled is None:
            compiled = prog.lower(shards, counts, keys).compile()
            if self._check_hlo:
                self._assert_hlo(compiled.as_text())
            self._fused[prog_key] = compiled
        return compiled(shards, counts, keys)


# Per-process backend cache, keyed by every compile-relevant static (plus
# the backend kind/mesh): repeated Pipeline/stream instantiations re-trace
# nothing, and each mesh program's HLO assert runs once per process.
_BACKEND_CACHE: Dict[Tuple, Any] = {}


def get_chunk_backend(
    model: BayesModel,
    num_shards: int,
    sampler: str,
    *,
    warmup: int = 200,
    burn_in: int = 0,
    step_size: float = 0.1,
    sgld_batch: int = 256,
    sampler_options=(),
    use_counts: bool = True,
    shards: PyTree,
    mesh_shape: Optional[Sequence[int]] = None,
    check_hlo: bool = True,
):
    """Resolve (and cache) the chunk backend for one sampling configuration.

    ``mesh_shape=None`` (or a data axis of 1) selects the vmap backend;
    anything else the mesh backend. ``shards`` is a structure template only
    — per-leaf vmap axes / partition specs depend on the model's
    ``shard_keys``, never on shard contents or batch size (the launch path
    drives the same cached backend with rank-local slices).
    """
    use_mesh = mesh_shape is not None and int(mesh_shape[0]) > 1
    base_key = (
        model.name, sampler, num_shards, warmup, burn_in, float(step_size),
        sgld_batch, _freeze_options(sampler_options), use_counts,
    )
    cache_key = base_key + (
        ("mesh", tuple(int(x) for x in mesh_shape), bool(check_hlo))
        if use_mesh
        else ("vmap",)
    )
    backend = _BACKEND_CACHE.get(cache_key)
    if backend is None:
        sk = make_shard_kernel(
            model,
            num_shards,
            sampler,
            sgld_batch=sgld_batch,
            use_counts=use_counts,
            sampler_options=sampler_options,
        )
        axes = _shard_axes(shards, model.shard_keys, 0, None)
        if use_mesh:
            backend = MeshChunkBackend(
                model, sk, axes, shards, tuple(mesh_shape),
                burn_in=burn_in, warmup=warmup, step_size=step_size,
                check_hlo=check_hlo, cache_key=cache_key,
            )
        else:
            backend = VmapChunkBackend(
                sk, axes,
                burn_in=burn_in, warmup=warmup, step_size=step_size,
                cache_key=cache_key,
            )
        _BACKEND_CACHE[cache_key] = backend
    return backend
