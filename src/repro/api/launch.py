"""Multi-controller launch path: ``python -m repro.api.launch``.

The paper's algorithm is embarrassingly parallel *across machines*, not
just across devices — each machine owns a data shard, runs its subposterior
chains with zero communication, and only the combination step talks. This
module is that deployment shape as a CLI: every process (one per host/rank)
runs the same command with its ``--process-id``, and

- **data** is generated identically everywhere from the spec seed (rank
  *slices* are taken from the same global partition, so the union of ranks
  is exactly the single-host run);
- **sampling** drives the rank's chain slice through
  :func:`repro.api.backends.get_chunk_backend` chunk programs of width 1,
  one chain at a time — per-chain RNG keys are the rank's slice of the
  *global* ``split(fold_in(key, 1), M)``, and because every chain runs the
  same width-1 executable whatever the rank count, a launch is
  **rank-count-invariant**: 1, 2, or M processes produce bitwise-identical
  draws per chain (a width-M vmap would fuse differently at the ulp level
  and diverge under rejection loops);
- **combination** folds each chunk into a moments-backed streaming
  combiner state (``repro.core.combiners.get_streaming_combiner``), and
  only that O(M·d²) state ever crosses hosts: ranks exchange their slices
  through the ``jax.distributed`` coordinator's key-value store and
  concatenate along the chain axis (per-chain Welford states are disjoint,
  so the concatenation is bitwise the single-host state). The draws
  themselves — the O(M·T·d) payload — never leave their host.

The KV-store exchange is deliberately platform-neutral: CPU hosts cannot
run multi-process XLA collectives at all ("Multiprocess computations
aren't implemented on the CPU backend"), and the state is small enough
that a device collective would buy nothing. That is also why only
moments-backed combiners (``--combiner online``) are launchable —
draw-buffer streaming states grow with T, and shipping them cross-host
would be the gather this path exists to avoid.

2-process smoke (two shells, or ``tests/test_launch_distributed.py``)::

  python -m repro.api.launch --coordinator localhost:9123 \\
      --num-processes 2 --process-id 0 --model poisson --sampler gibbs \\
      --M 4 --T 200 --json out0.json &
  python -m repro.api.launch --coordinator localhost:9123 \\
      --num-processes 2 --process-id 1 --model poisson --sampler gibbs \\
      --M 4 --T 200

Rank 0 writes/prints the finalized result; with ``--num-processes 1`` (the
default) no coordinator is needed and the same code path runs locally —
the reference a distributed run must reproduce.
"""

from __future__ import annotations

import argparse
import base64
import io
import json
import time
import zlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

# NOTE: repro imports are deliberately lazy (inside the functions below) —
# several modules build jnp constants at import time, and JAX refuses
# jax.distributed.initialize() after any computation has run. main() must
# initialize first, import second.

PyTree = Any

# moments-backed streaming combiners: state size independent of T, hence
# cheap to exchange cross-host. Anything else would ship draw buffers.
LAUNCHABLE_COMBINERS = ("online",)


def _kv_allgather(tag: str, tree: PyTree, rank: int, num_processes: int,
                  *, timeout_ms: int = 120_000) -> PyTree:
    """Allgather a small pytree across ranks via the coordinator KV store,
    concatenating every leaf along its leading (chain) axis in rank order."""
    from jax._src import distributed  # the coordinator client lives here

    client = distributed.global_state.client
    leaves, treedef = jax.tree.flatten(tree)
    buf = io.BytesIO()
    # fixed-width names keep np.load's file order stable past 10 leaves
    np.savez(buf, **{
        f"a{i:03d}": np.asarray(jax.device_get(leaf))
        for i, leaf in enumerate(leaves)
    })
    client.key_value_set(
        f"{tag}/{rank}", base64.b64encode(buf.getvalue()).decode("ascii")
    )
    client.wait_at_barrier(f"{tag}/barrier", timeout_ms)
    per_rank = []
    for r in range(num_processes):
        raw = base64.b64decode(client.blocking_key_value_get(
            f"{tag}/{r}", timeout_ms
        ))
        with np.load(io.BytesIO(raw)) as z:
            per_rank.append([z[f"a{i:03d}"] for i in range(len(leaves))])
    merged = [
        np.concatenate([g[i] for g in per_rank], axis=0)
        for i in range(len(leaves))
    ]
    return jax.tree.unflatten(treedef, [jnp.asarray(m) for m in merged])


def _slice_chains(model, shards, counts, keys, lo: int, hi: int):
    """This rank's chain slice of the global partition: per-datum shard
    leaves and per-chain arrays sliced, broadcast leaves kept whole."""
    from repro.api.sampling import _shard_axes

    axes = _shard_axes(shards, model.shard_keys, 0, None)
    local_shards = jax.tree.map(
        lambda x, a: x[lo:hi] if a == 0 else x, shards, axes
    )
    return local_shards, counts[lo:hi], keys[lo:hi]


def run_launch(spec, *, num_processes: int = 1,
               process_id: int = 0) -> Dict[str, Any]:
    """One rank of the multi-controller run; returns the result record
    (every rank computes the identical finalized estimate)."""
    from repro.api.backends import BackendId, get_chunk_backend
    from repro.api.sampling import is_padded
    from repro.core.combiners import filter_options, get_streaming_combiner
    from repro.core.subposterior import partition_data
    from repro.models.bayes import get_model

    spec = spec.validate()
    names = spec.combiner_names()
    bad = [n for n in names if n not in LAUNCHABLE_COMBINERS]
    if bad:
        raise ValueError(
            f"combiner(s) {bad} cannot run on the launch path — only the "
            f"moments-backed {LAUNCHABLE_COMBINERS} exchange O(M*d^2) state "
            "across hosts (draw-buffer streaming states grow with T; run "
            "those single-host via Pipeline.stream_combine)"
        )
    if spec.M % num_processes != 0:
        raise ValueError(
            f"M={spec.M} chains must divide evenly over "
            f"--num-processes {num_processes}"
        )
    if spec.mesh_shape is not None:
        raise ValueError(
            "the launch path shards chains across *processes* — "
            f"mesh_shape={spec.mesh_shape} (within-process device mesh) "
            "belongs to repro.api.Pipeline"
        )

    t_start = time.time()
    model = get_model(spec.model)
    key = jax.random.PRNGKey(spec.seed)
    data, _ = model.generate_data(key, spec.resolved_n())
    shards, counts = partition_data(
        data, spec.M, only=model.shard_keys, pad=True
    )
    padded = is_padded(model, shards, counts, spec.resolved_sampler())
    keys_all = jax.random.split(jax.random.fold_in(key, 1), spec.M)

    chains_per_rank = spec.M // num_processes
    lo, hi = process_id * chains_per_rank, (process_id + 1) * chains_per_rank
    local_shards, local_counts, local_keys = _slice_chains(
        model, shards, counts, keys_all, lo, hi
    )

    # Every chain runs through the SAME width-1 chunk programs, whatever the
    # rank count: a vmap over 2 chains and a vmap over 4 fuse differently at
    # the ulp level, and samplers with rejection loops (gibbs' gamma draws,
    # MH accepts) amplify one flipped comparison into a divergent chain.
    # Width-1 execution makes the run *rank-count-invariant* — launching on
    # 1, 2, or M hosts produces bitwise-identical draws per chain — at the
    # cost of the vmap batching a single-host Pipeline would enjoy.
    backend = get_chunk_backend(
        model,
        1,
        spec.resolved_sampler(),
        warmup=spec.warmup,
        burn_in=spec.resolved_burn_in(),
        step_size=spec.step_size,
        sgld_batch=spec.sgld_batch,
        sampler_options=spec.sampler_options,
        use_counts=padded,
        shards=local_shards,
    )

    def chain_slice(c):
        sh, cn, ks = _slice_chains(
            model, local_shards, local_counts, local_keys, c, c + 1
        )
        return backend.prepare(sh, cn, ks)

    T = spec.T
    cadence = spec.stream_every if spec.stream_every > 0 else T
    chains = [chain_slice(c) for c in range(chains_per_rank)]
    carries = []
    for sh, cn, ks in chains:
        state, eps, k_collect = backend.setup(sh, cn, ks)
        ck = jax.vmap(lambda k: jax.random.split(k, T))(k_collect)
        carries.append({"state": state, "eps": eps, "ck": ck})

    scs = {name: get_streaming_combiner(name) for name in names}
    options = dict(
        {"rescale": True, "n_batch": 1}, **dict(spec.combiner_options)
    )
    states: Dict[str, Any] = {name: None for name in names}
    accept_sum = jnp.zeros((chains_per_rank,), jnp.float32)
    for t0 in range(0, T, cadence):
        t1 = min(t0 + cadence, T)
        thetas, accs = [], []
        for (sh, cn, _), carry in zip(chains, carries):
            carry["state"], theta_c, acc_c = backend.next_chunk(
                sh, cn, carry["eps"], carry["state"], carry["ck"][:, t0:t1]
            )
            thetas.append(theta_c)
            accs.append(acc_c)
        theta = jnp.concatenate(thetas, axis=0)
        accept_sum = accept_sum + jnp.concatenate(accs, axis=0)
        for name in names:
            sc = scs[name]
            if states[name] is None:
                states[name] = sc.init(chains_per_rank, model.d)
            states[name] = sc.update(states[name], theta)

    # -- the only cross-host traffic: combine state + accept counts -------
    if num_processes > 1:
        for name in names:
            states[name] = _kv_allgather(
                f"combine/{name}", states[name], process_id, num_processes
            )
        accept_sum = _kv_allgather(
            "accept", accept_sum, process_id, num_processes
        )

    # finalize with Pipeline's exact RNG discipline — the distributed run
    # must score as the same experiment
    kc = jax.random.fold_in(key, 3)
    combined: Dict[str, Any] = {}
    for name in names:
        k_name = jax.random.fold_in(kc, zlib.crc32(name.encode()) & 0x7FFFFFFF)
        fn = scs[name].finalize
        res = fn(k_name, states[name], T, **filter_options(fn, options))
        combined[name] = np.asarray(jax.device_get(res.samples))

    record = {
        "spec_id": spec.spec_id,
        "backend": BackendId.distributed(num_processes),
        "model": spec.model,
        "sampler": spec.resolved_sampler(),
        "M": spec.M,
        "T": T,
        "seed": spec.seed,
        "num_processes": num_processes,
        "process_id": process_id,
        "accept": float(jnp.mean(accept_sum) / T),
        "combined": {
            name: {
                "mean": np.mean(s, axis=0).tolist(),
                "std": np.std(s, axis=0).tolist(),
                "samples": s.tolist(),
            }
            for name, s in combined.items()
        },
        "wall_s": time.time() - t_start,
    }
    return record


def main(argv=None) -> Optional[Dict[str, Any]]:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                    help="jax.distributed coordinator (rank 0's address); "
                    "required when --num-processes > 1")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--model", default="poisson")
    ap.add_argument("--sampler", default=None)
    ap.add_argument("--combiner", default="online")
    ap.add_argument("--M", type=int, default=4)
    ap.add_argument("--T", type=int, default=200)
    ap.add_argument("--warmup", type=int, default=50)
    ap.add_argument("--step", type=float, default=0.1)
    ap.add_argument("--n", type=int, default=0,
                    help="dataset size (0 = model default)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stream-every", type=int, default=0,
                    help="chunk cadence (0 = one chunk)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="rank 0 writes the result record here")
    args = ap.parse_args(argv)

    if args.num_processes > 1:
        if args.coordinator is None:
            raise SystemExit(
                "--num-processes > 1 needs --coordinator HOST:PORT "
                "(rank 0's address, same value on every rank)"
            )
        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_processes,
            process_id=args.process_id,
        )

    from repro.api.spec import RunSpec  # after initialize — see note above

    spec = RunSpec(
        model=args.model, sampler=args.sampler, combiner=args.combiner,
        M=args.M, T=args.T, warmup=args.warmup, step_size=args.step,
        n=args.n, seed=args.seed, stream_every=args.stream_every,
    )
    record = run_launch(
        spec, num_processes=args.num_processes, process_id=args.process_id
    )
    if args.process_id == 0:
        out = json.dumps(record, indent=1)
        if args.json:
            with open(args.json, "w") as f:
                f.write(out + "\n")
        print(out)
    if args.num_processes > 1:
        jax.distributed.shutdown()
    return record if args.process_id == 0 else None


if __name__ == "__main__":
    main()
