"""repro.api — the programmatic experiment layer over the three registries.

The paper's pipeline is one fixed dataflow (partition data → sample
subposteriors independently → combine → score); this package makes any
model × sampler × combiner × mesh scenario a *value* instead of a script:

- :class:`RunSpec` — a declarative, hashable, JSON-round-trippable spec
  validated against the model/sampler/combiner registries, with a canonical
  ``spec_id`` content hash and a compile-grouping ``executable_signature``;
- :class:`Pipeline` — staged execution (``partition() → sample() →
  combine() → score()``) with explicit typed artifacts (:class:`ShardedData`,
  :class:`SubposteriorDraws`, ``CombineResult``, :class:`Scoreboard`);
  given a ``checkpoint_dir`` the sampling stage persists live kernel state
  via :mod:`repro.checkpoint` and resumes mid-chain, bitwise;
- :func:`run_matrix` — a scenario sweep that compiles one executable per
  distinct signature (seeds/step sizes are runtime inputs) and emits a tidy
  results table (stdout + JSON);
- :func:`combine_draws` — registry-dispatched combination for callers that
  already hold an ``(M, T, d)`` stack (backed by
  ``repro.distributed.epmcmc.combine_gathered``, same as the mesh run).

Quickstart::

    from repro.api import Pipeline, RunSpec

    spec = RunSpec(model="poisson", sampler="rwmh", M=8, T=1000, seed=0)
    board = Pipeline(spec).run()
    print(board.table())

``repro.launch.mcmc_run`` is a thin argparse adapter over this layer;
``examples/`` and ``benchmarks/`` drive it programmatically.
"""

from repro.api.backends import (  # noqa: F401
    BackendId,
    ChunkBackend,
    MeshChunkBackend,
    VmapChunkBackend,
    get_chunk_backend,
)
from repro.api.pipeline import (  # noqa: F401
    LOG_L2_DIM,
    Pipeline,
    Scoreboard,
    ShardedData,
    StreamResult,
    StreamSetup,
    SubposteriorDraws,
    combine_draws,
)
from repro.api.resumable import (  # noqa: F401
    ResumableSample,
    sample_subposteriors_resumable,
)
from repro.api.streaming import (  # noqa: F401
    ShardChainStream,
    StreamChunk,
    StreamedSample,
    stream_sample,
)
from repro.api.sampling import (  # noqa: F401
    SampleResult,
    ShardKernel,
    groundtruth_chain,
    make_shard_kernel,
    make_shard_sampler,
    run_shard_chain,
    sample_subposteriors,
)
from repro.api.spec import RunSpec  # noqa: F401


def __getattr__(name: str):
    # lazy: `python -m repro.api.matrix` first imports this package, and an
    # eager submodule import here would re-execute matrix.py as __main__
    # (sys.modules RuntimeWarning, two distinct class identities)
    if name in ("MatrixResult", "run_matrix", "ExecutableCache"):
        from repro.api import matrix

        return getattr(matrix, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "BackendId",
    "ChunkBackend",
    "LOG_L2_DIM",
    "MatrixResult",
    "MeshChunkBackend",
    "Pipeline",
    "VmapChunkBackend",
    "get_chunk_backend",
    "ResumableSample",
    "RunSpec",
    "SampleResult",
    "Scoreboard",
    "ShardChainStream",
    "ShardKernel",
    "ShardedData",
    "StreamChunk",
    "StreamResult",
    "StreamSetup",
    "StreamedSample",
    "SubposteriorDraws",
    "combine_draws",
    "groundtruth_chain",
    "make_shard_kernel",
    "make_shard_sampler",
    "run_matrix",
    "run_shard_chain",
    "sample_subposteriors",
    "sample_subposteriors_resumable",
    "stream_sample",
]
