"""Scenario-matrix driver: sweep RunSpecs with compiled-executable reuse.

``run_matrix(specs)`` executes an iterable of :class:`RunSpec` cells and
emits a tidy results table (stdout + JSON). The point, beyond the loop, is
**compile hygiene** at sweep scale:

- specs are grouped by :meth:`RunSpec.executable_signature`; one jitted
  sampling program is built per group with ``seed`` (the RNG key) and
  ``step_size`` as *runtime* arguments, so a sweep over seeds/step sizes
  lowers exactly once per distinct signature instead of once per cell;
- groundtruth chains get the same treatment keyed by
  :meth:`RunSpec.groundtruth_signature`;
- stage *outputs* are reused too: cells that differ only in combiner share
  one set of subposterior draws and one groundtruth chain.

The returned :class:`MatrixResult` carries per-cell rows plus the compile
accounting (``n_executables`` vs ``n_specs``) that
``tests/test_api.py::test_run_matrix_compiles_once_per_signature`` locks.

Two execution backends (``backend=``):

- ``"vmap"`` (default) — every cell runs on the single-device vmap path;
- ``"mesh_fanout"`` — independent *cells* fan out over mesh slices: each
  signature group stacks its pending cells along a leading axis and runs
  one ``shard_map(vmap(cell))`` program over a 1-axis device mesh, with
  the compiled HLO asserted collective-free (cells never talk to each
  other — the paper's embarrassing parallelism, one level up).

Either way, a spec carrying its *own* ``mesh_shape`` (sharding chains
within a cell) is rejected — that belongs to :class:`repro.api.Pipeline`.

CLI (the CI ``scenario-matrix`` smoke job)::

  PYTHONPATH=src python -m repro.api.matrix \\
      --models poisson,linear --samplers rwmh,gibbs \\
      --combiners parametric,nonparametric --M 4 --T 200 --json perf/
"""

from __future__ import annotations

import argparse
import itertools
import json
import os
import time
from typing import Any, Callable, Dict, Iterable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api.spec import RunSpec
from repro.api.pipeline import (
    combine_spec_draws,
    groundtruth_step_size,
    resolve_metric,
)
from repro.api.sampling import (
    is_padded,
    _shard_axes,
    make_shard_kernel,
    run_shard_chain,
)
from repro.core.subposterior import partition_data
from repro.models.bayes import get_model

Signature = Tuple[Any, ...]


class MatrixResult(NamedTuple):
    """Outcome of one sweep: tidy rows + compile-cache accounting."""

    rows: List[Dict[str, Any]]
    n_specs: int
    n_executables: int  # distinct sampling programs compiled
    n_groundtruth_executables: int
    signatures: Dict[str, int]  # repr(signature) -> specs served
    backend: str = "vmap"  # BackendId string of the sampling executor

    def table(self) -> str:
        head = f"{'spec_id':12s} {'model':8s} {'sampler':8s} {'combiner':16s} " \
               f"{'M':>3s} {'T':>5s} {'seed':>4s} {'acc':>5s} {'metric':6s} {'error':>10s} {'wall_s':>7s}"
        lines = [head, "-" * len(head)]
        for r in self.rows:
            lines.append(
                f"{r['spec_id']:12s} {r['model']:8s} {r['sampler']:8s} "
                f"{r['combiner']:16s} {r['M']:3d} {r['T']:5d} {r['seed']:4d} "
                f"{r['accept']:5.2f} {r['metric']:6s} {r['error']:10.4f} "
                f"{r['wall_s']:7.2f}"
            )
        lines.append(
            f"# {self.n_specs} cells on {self.backend}, "
            f"{self.n_executables} sampling executables, "
            f"{self.n_groundtruth_executables} groundtruth "
            "executables (compile-cache hits for the rest)"
        )
        return "\n".join(lines)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "rows": self.rows,
            "n_specs": self.n_specs,
            "n_executables": self.n_executables,
            "n_groundtruth_executables": self.n_groundtruth_executables,
            "signatures": self.signatures,
            "backend": self.backend,
        }


class ExecutableCache:
    """Per-signature jit cache. ``seed``/``step_size`` stay runtime inputs,
    so every spec in a group reuses one lowered program. Public: benchmarks
    (``bench_samplers``) time cells through the same cache the sweep uses."""

    def __init__(self):
        self.sample: Dict[Signature, Callable] = {}
        self.groundtruth: Dict[Signature, Callable] = {}
        self._raw: Dict[Signature, Callable] = {}

    def raw_sample_fn(self, spec: RunSpec, model, padded: bool) -> Callable:
        """The unjitted cell body ``(shards, counts, keys, step_size) ->
        (theta, accept)`` — what ``sample_fn`` jits, and what the mesh
        fan-out vmaps a second time over a leading *cell* axis."""
        sig = spec.executable_signature() + (padded,)
        if sig not in self._raw:
            sk = make_shard_kernel(
                model,
                spec.M,
                spec.resolved_sampler(),
                sgld_batch=spec.sgld_batch,
                use_counts=padded,
                sampler_options=spec.sampler_options,
            )
            T, burn, warm = spec.T, spec.resolved_burn_in(), spec.warmup

            def run(shards, counts, keys, step_size):
                one = lambda s, c, k: run_shard_chain(
                    sk, s, c, k,
                    num_samples=T, burn_in=burn, warmup=warm,
                    step_size=step_size,
                )
                in_axes = (_shard_axes(shards, model.shard_keys, 0, None), 0, 0)
                return jax.vmap(one, in_axes=in_axes)(shards, counts, keys)

            self._raw[sig] = run
        return self._raw[sig]

    def sample_fn(self, spec: RunSpec, model, padded: bool) -> Callable:
        sig = spec.executable_signature() + (padded,)
        if sig not in self.sample:
            self.sample[sig] = jax.jit(self.raw_sample_fn(spec, model, padded))
        return self.sample[sig]

    def groundtruth_fn(self, spec: RunSpec, model) -> Callable:
        sig = spec.groundtruth_signature()
        if sig not in self.groundtruth:
            sk = make_shard_kernel(
                model, 1, spec.resolved_sampler(),
                sgld_batch=spec.sgld_batch, use_counts=False,
                sampler_options=spec.sampler_options,
            )
            gt_T, warm = spec.groundtruth_T, spec.warmup

            def run(data, key, step_size):
                theta, _ = run_shard_chain(
                    sk, data, jnp.zeros((), jnp.int32), key,
                    num_samples=gt_T, burn_in=gt_T // 6, warmup=warm,
                    step_size=step_size,
                )
                return theta

            self.groundtruth[sig] = jax.jit(run)
        return self.groundtruth[sig]


def _partitioned(spec: RunSpec, model, key, part_cache: Dict[Tuple, Tuple]):
    """Data generation + partition, cached across cells that share them."""
    part_key = (spec.model, spec.resolved_n(), spec.seed, spec.M)
    if part_key not in part_cache:
        data, _ = model.generate_data(key, spec.resolved_n())
        shards, counts = partition_data(
            data, spec.M, only=model.shard_keys, pad=True
        )
        part_cache[part_key] = (data, shards, counts)
    return part_cache[part_key]


def _fanout_sample(
    specs: List[RunSpec],
    execs: ExecutableCache,
    part_cache: Dict[Tuple, Tuple],
    draws_cache: Dict[Tuple, Tuple],
    *,
    verbose: bool = False,
) -> int:
    """mesh_fanout prepass: fill ``draws_cache`` for every distinct draw
    cell, one ``shard_map(vmap(cell))`` program per signature group.

    Cells in a group (same executable signature, distinct seed/step) stack
    along a leading axis sharded ``P("data")`` over a 1-axis device mesh;
    the group is padded to a device multiple by repeating the last cell.
    Each compiled program's HLO is asserted collective-free — independent
    cells must stay independent on the mesh. Returns the program count.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.distributed.epmcmc import assert_no_cross_chain_collectives

    ndev = jax.device_count()
    if ndev < 2:
        raise ValueError(
            "run_matrix(backend='mesh_fanout') needs >=2 visible devices "
            f"but only {ndev} is — launch with e.g. "
            "XLA_FLAGS=--xla_force_host_platform_device_count=4 "
            "(or use backend='vmap')"
        )

    # group the *distinct* draw cells by signature (combiner-only sweeps
    # collapse, exactly as on the vmap path)
    groups: Dict[Signature, List[Tuple]] = {}
    pending: set = set()
    for spec in specs:
        model = get_model(spec.model)
        key = jax.random.PRNGKey(spec.seed)
        _, shards, counts = _partitioned(spec, model, key, part_cache)
        padded = is_padded(model, shards, counts, spec.resolved_sampler())
        sig = spec.executable_signature() + (padded,)
        draws_key = (sig, spec.seed, spec.step_size)
        if draws_key in draws_cache or draws_key in pending:
            continue
        pending.add(draws_key)
        keys = jax.random.split(jax.random.fold_in(key, 1), spec.M)
        groups.setdefault(sig, []).append(
            (draws_key, spec, model, padded,
             (shards, counts, keys, jnp.float32(spec.step_size)))
        )

    mesh = jax.make_mesh((ndev,), ("data",))
    sharding = NamedSharding(mesh, P("data"))
    n_programs = 0
    for sig, cells in groups.items():
        spec, model, padded = cells[0][1], cells[0][2], cells[0][3]
        raw = execs.raw_sample_fn(spec, model, padded)
        n_cells = len(cells)
        pad_to = -(-n_cells // ndev) * ndev
        inputs = [c[4] for c in cells] + [cells[-1][4]] * (pad_to - n_cells)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *inputs)
        stacked = jax.tree.map(lambda x: jax.device_put(x, sharding), stacked)
        fan = shard_map(
            jax.vmap(raw), mesh=mesh,
            in_specs=(P("data"),) * 4, out_specs=P("data"),
            check_rep=False,
        )
        compiled = jax.jit(fan).lower(*stacked).compile()
        assert_no_cross_chain_collectives(compiled.as_text(), mesh)
        n_programs += 1
        theta, acc = jax.block_until_ready(compiled(*stacked))
        for i, (draws_key, *_rest) in enumerate(cells):
            draws_cache[draws_key] = (theta[i], acc[i])
        if verbose:
            print(
                f"# fanout: {n_cells} cell(s) of signature "
                f"{cells[0][1].spec_id}-group over {ndev} devices "
                f"(padded to {pad_to})",
                flush=True,
            )
    return n_programs


def run_matrix(
    specs: Iterable[RunSpec],
    *,
    json_path: Optional[str] = None,
    verbose: bool = False,
    backend: str = "vmap",
) -> MatrixResult:
    """Execute every spec; compile once per signature; return tidy rows.

    RNG discipline matches :class:`repro.api.Pipeline` exactly (data from
    ``PRNGKey(seed)``, sampling ``fold_in 1``, groundtruth ``fold_in 2``,
    per-combiner streams off ``fold_in 3``), so a matrix cell and a
    standalone Pipeline over the same spec agree to the last-ulp fusion
    tolerance of tracing ``step_size`` instead of closing over it.

    ``backend="mesh_fanout"`` runs the sampling stage of independent cells
    in parallel over mesh slices (see :func:`_fanout_sample`); groundtruth
    chains and combine/score stay host-sequential either way, and the RNG
    discipline is identical, so a fanout sweep scores the same cells.
    """
    if backend not in ("vmap", "mesh_fanout"):
        raise ValueError(
            f"unknown run_matrix backend {backend!r} — expected 'vmap' or "
            "'mesh_fanout'"
        )
    specs = [s.validate() for s in specs]
    for spec in specs:
        if spec.mesh_shape is not None:
            # Pipeline owns within-cell meshes; a sweep must not quietly
            # drop the shard_map/HLO-assert request (mesh_fanout shards
            # whole cells, never the chains inside one)
            raise ValueError(
                f"spec {spec.spec_id}: run_matrix drives the vmap backend "
                f"only within a cell — mesh_shape={spec.mesh_shape} belongs "
                "to repro.api.Pipeline"
            )
    execs = ExecutableCache()
    draws_cache: Dict[Tuple, Tuple] = {}  # (sig, seed, step) -> (theta, acc)
    gt_cache: Dict[Tuple, jnp.ndarray] = {}
    part_cache: Dict[Tuple, Tuple] = {}  # (model, n, seed, M) -> stage inputs
    rows: List[Dict[str, Any]] = []
    signatures: Dict[str, int] = {}

    n_fanout = 0
    if backend == "mesh_fanout":
        # batch-sample every distinct draw cell up front; the per-spec loop
        # below then cache-hits on draws and only runs gt + combine + score
        n_fanout = _fanout_sample(
            specs, execs, part_cache, draws_cache, verbose=verbose
        )

    for spec in specs:
        t0 = time.time()
        model = get_model(spec.model)
        key = jax.random.PRNGKey(spec.seed)
        # data generation + partition reused across cells differing only in
        # sampler/combiner/step — cache-hit cells' wall_s stays honest
        data, shards, counts = _partitioned(spec, model, key, part_cache)
        padded = is_padded(model, shards, counts, spec.resolved_sampler())
        sig = spec.executable_signature() + (padded,)
        signatures[repr(sig)] = signatures.get(repr(sig), 0) + 1

        draws_key = (sig, spec.seed, spec.step_size)
        if draws_key not in draws_cache:
            fn = execs.sample_fn(spec, model, padded)
            keys = jax.random.split(jax.random.fold_in(key, 1), spec.M)
            draws_cache[draws_key] = jax.block_until_ready(
                fn(shards, counts, keys, jnp.float32(spec.step_size))
            )
        theta, acc = draws_cache[draws_key]

        # keyed on the COMPENSATED step (it depends on M, which the gt
        # signature excludes) — specs differing only in M must not share
        # a groundtruth chain run at the wrong ε
        gt_step = groundtruth_step_size(spec)
        gt_key = (spec.groundtruth_signature(), spec.seed, gt_step)
        if gt_key not in gt_cache:
            fn = execs.groundtruth_fn(spec, model)
            gt_cache[gt_key] = jax.block_until_ready(
                fn(data, jax.random.fold_in(key, 2), jnp.float32(gt_step))
            )
        gt = gt_cache[gt_key]

        # -- combine + score (eager; RNG/options shared with Pipeline) ------
        dist, label = resolve_metric(spec, model.d)
        t_row = time.time()
        for name in spec.combiner_names():
            out = combine_spec_draws(spec, key, theta, names=(name,))[name]
            err = float(dist(gt, out.samples))  # forces the async dispatch
            now = time.time()
            rows.append({
                "spec_id": spec.spec_id,
                "model": spec.model,
                "sampler": spec.resolved_sampler(),
                "combiner": name,
                "M": spec.M,
                "T": spec.T,
                "seed": spec.seed,
                "accept": float(jnp.mean(acc)),
                "metric": label,
                "error": err,
                # per-row delta (first row absorbs the cell's sampling/
                # groundtruth cost) — cumulative stamps would skew the
                # perf-trajectory JSON by row order
                "wall_s": now - t_row,
            })
            t_row = now
        if verbose:
            print(f"# cell {spec.spec_id} ({spec.model}/{spec.resolved_sampler()}) "
                  f"done in {time.time() - t0:.1f}s", flush=True)

    from repro.api.backends import BackendId  # late: backends pulls sampling

    backend_id = (
        BackendId.mesh_fanout(jax.device_count())
        if backend == "mesh_fanout"
        else BackendId.vmap()
    )
    result = MatrixResult(
        rows=rows,
        n_specs=len(specs),
        n_executables=len(execs.sample) + n_fanout,
        n_groundtruth_executables=len(execs.groundtruth),
        signatures=signatures,
        backend=backend_id,
    )
    if json_path is not None:
        path = _json_path(json_path)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(result.to_dict(), f, indent=1)
    return result


def _json_path(arg: str) -> str:
    """A ``.json`` arg is a file; anything else a directory getting an
    auto-named ``MATRIX_<timestamp>.json`` (mirrors ``benchmarks.run``)."""
    if arg.endswith(".json") and not os.path.isdir(arg):
        return arg
    return os.path.join(arg, f"MATRIX_{time.strftime('%Y%m%d_%H%M%S')}.json")


def main(argv=None) -> MatrixResult:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--models", default="poisson,linear")
    ap.add_argument("--samplers", default="rwmh,gibbs")
    ap.add_argument("--combiners", default="parametric,nonparametric")
    ap.add_argument("--seeds", default="0")
    ap.add_argument("--M", type=int, default=4)
    ap.add_argument("--T", type=int, default=200)
    ap.add_argument("--warmup", type=int, default=50)
    ap.add_argument("--step", type=float, default=0.1)
    ap.add_argument("--n", type=int, default=0, help="dataset size (0 = model default)")
    ap.add_argument("--gt-T", type=int, default=400)
    ap.add_argument(
        "--metric", default="auto", choices=("auto", "l2", "logl2"),
        help="scoreboard distance (logl2 keeps narrow posteriors finite)",
    )
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument(
        "--backend", default="vmap", choices=("vmap", "mesh_fanout"),
        help="mesh_fanout shards independent cells over visible devices",
    )
    args = ap.parse_args(argv)

    split = lambda s: tuple(x for x in s.split(",") if x)
    specs = [
        RunSpec(
            model=m, sampler=s, combiner=c, M=args.M, T=args.T,
            warmup=args.warmup, step_size=args.step, n=args.n,
            seed=int(seed), groundtruth_T=args.gt_T,
            score_metric=args.metric,
        )
        for m, s, c, seed in itertools.product(
            split(args.models), split(args.samplers),
            split(args.combiners), split(args.seeds),
        )
    ]
    result = run_matrix(
        specs, json_path=args.json, verbose=True, backend=args.backend
    )
    print(result.table())
    return result


if __name__ == "__main__":
    main()
