"""Staged, resumable execution of one :class:`RunSpec`.

The paper's dataflow is fixed — **partition → sample → combine → score** —
so the Pipeline exposes exactly those stages, each returning an explicit
typed artifact that can be inspected, persisted, or fed onward:

    ``partition() -> ShardedData``             (M shards + valid-row counts)
    ``sample()    -> SubposteriorDraws``       ((M, T, d) θ + diagnostics)
    ``combine()   -> dict[str, CombineResult]``(one per requested combiner)
    ``score()     -> Scoreboard``              (error per combiner vs groundtruth)

Stages are lazy and cached: each runs its predecessors on demand, so
``Pipeline(spec).run()`` is the whole paper and ``pipe.sample()`` alone is
just the embarrassingly parallel stage. RNG discipline is fixed by the spec
seed (data from ``PRNGKey(seed)``, sampling from ``fold_in(key, 1)``,
groundtruth ``fold_in(key, 2)``, one independent stream per combiner from
``fold_in(key, 3)`` + a stable hash of the name), so the same spec always
produces bitwise-identical artifacts.

With ``checkpoint_dir`` set, the sampling stage runs the chunked driver of
:mod:`repro.api.resumable`: every ``checkpoint_every`` draws the live kernel
state is persisted via :mod:`repro.checkpoint`, and a new Pipeline pointed
at the same directory resumes mid-chain instead of restarting.

The combination stage dispatches through
:func:`repro.distributed.epmcmc.combine_gathered` — the same registry-name
backend the mesh EP-MCMC run uses — so scenario code and the distributed
runtime share one combine path.
"""

from __future__ import annotations

import math
import time
import zlib
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api.spec import RunSpec
from repro.api.sampling import groundtruth_chain, sample_subposteriors
from repro.core import metrics
from repro.core.subposterior import partition_data
from repro.core.combiners import CombineResult
from repro.models.bayes import get_model
from repro.samplers import sampler_spec

PyTree = Any

# models at or above this θ-dimension are scored in log space: raw
# `l2_distance` enters the f32-overflow regime of the KDE normalizer there
# (its own docstring's warning) and becomes hypersensitive to dispersion
LOG_L2_DIM = 40


def groundtruth_step_size(spec: RunSpec) -> float:
    """Full-chain step compensation, shared by Pipeline and run_matrix.

    The full posterior is ~√M narrower than a subposterior and its gradient
    M× larger; warmup absorbs that for adaptive kernels, fixed-step ones
    need the classic compensation (ε/M for Langevin time steps, ε/√M for
    proposal scales).
    """
    sp = sampler_spec(spec.resolved_sampler())
    if sp.name == "sgld":
        return spec.step_size / spec.M
    if not (sp.adaptive and spec.warmup > 0):
        return spec.step_size / math.sqrt(spec.M)
    return spec.step_size


def combine_spec_draws(
    spec: RunSpec,
    base_key: jax.Array,
    theta: jnp.ndarray,
    names: Optional[Tuple[str, ...]] = None,
) -> "Dict[str, CombineResult]":
    """The combine stage for one spec, shared by Pipeline and run_matrix.

    One independent RNG stream per estimator (``fold_in(base_key, 3)`` then
    a fold by a stable hash of the name — one shared key would correlate the
    scoreboard entries, and it also makes each combiner's result independent
    of which subset ``names`` selects); options merge the spec's
    ``combiner_options`` over the driver defaults and are filtered per
    combiner signature by the ``combine_gathered`` backend.
    """
    # late import — epmcmc pulls the heavy LM stack
    from repro.distributed.epmcmc import combine_gathered

    kc = jax.random.fold_in(base_key, 3)
    options = dict({"rescale": True, "n_batch": 1}, **dict(spec.combiner_options))
    out: Dict[str, CombineResult] = {}
    for name in names if names is not None else spec.combiner_names():
        k_name = jax.random.fold_in(kc, zlib.crc32(name.encode()) & 0x7FFFFFFF)
        out[name] = combine_gathered(
            k_name, theta, spec.T, combiner=name, **options
        )
    return out


def resolve_metric(spec: RunSpec, d: int):
    """``(distance_fn, label)`` for a spec: ``score_metric`` override or the
    dimension rule above (narrow posteriors can force ``"logl2"`` explicitly
    — e.g. the scenario-matrix CI cells on the linear exactness oracle)."""
    use_log = spec.score_metric == "logl2" or (
        spec.score_metric == "auto" and d >= LOG_L2_DIM
    )
    if use_log:
        return metrics.log_l2_distance, "logL2"
    return metrics.l2_distance, "L2"


class ShardedData(NamedTuple):
    """Partition-stage artifact: the paper's M "machines" worth of data."""

    shards: PyTree  # per-datum leaves carry a leading (M, ...) chain axis
    counts: jnp.ndarray  # (M,) real rows per shard (edge-pad convention)
    data: PyTree  # the full dataset (groundtruth stage input)
    theta_true: jnp.ndarray  # generating parameters (diagnostics only)


class SubposteriorDraws(NamedTuple):
    """Sampling-stage artifact: M independent subposterior chains."""

    theta: jnp.ndarray  # (M, T, d) shared-θ draws
    accept: jnp.ndarray  # (M,) mean acceptance per chain
    counts: jnp.ndarray  # (M,)
    backend: str  # "vmap" | "shard_map(...)" | "vmap[resumable]"
    collectives_checked: Optional[int]
    t_done: int  # draws collected so far (== T unless interrupted)
    complete: bool


class Scoreboard(NamedTuple):
    """Score-stage artifact: the paper's error table for one scenario."""

    spec_id: str
    model: str
    sampler: str
    M: int
    T: int
    metric: str  # "L2" | "logL2"
    errors: Dict[str, float]  # combiner name -> distance to groundtruth
    accept: float
    backend: str
    collectives_checked: Optional[int]
    timings: Dict[str, float]  # stage -> seconds

    def table(self) -> str:
        lines = [
            f"model={self.model} M={self.M} T={self.T} sampler={self.sampler} "
            f"acc={self.accept:.2f} backend={self.backend}"
        ]
        for name, err in sorted(self.errors.items(), key=lambda kv: kv[1]):
            lines.append(f"  {self.metric}({name:15s}) = {err:.4f}")
        return "\n".join(lines)

    def to_dict(self) -> Dict[str, Any]:
        return dict(self._asdict())


class Pipeline:
    """Run one :class:`RunSpec` stage by stage (see module docstring)."""

    def __init__(
        self,
        spec: RunSpec,
        *,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        check_hlo: bool = True,
    ):
        self.spec = spec.validate()
        self.checkpoint_dir = str(checkpoint_dir) if checkpoint_dir else None
        if checkpoint_every > 0 and self.checkpoint_dir is None:
            raise ValueError(
                "checkpoint_every > 0 without a checkpoint_dir would "
                "silently persist nothing — pass checkpoint_dir (or drop "
                "the cadence)"
            )
        self.checkpoint_every = checkpoint_every
        self.check_hlo = check_hlo
        self.timings: Dict[str, float] = {}
        self._model = get_model(spec.model)
        self._key = jax.random.PRNGKey(spec.seed)
        self._sharded: Optional[ShardedData] = None
        self._draws: Optional[SubposteriorDraws] = None
        self._groundtruth: Optional[jnp.ndarray] = None
        self._combined: Optional[Dict[str, CombineResult]] = None
        self._board: Optional[Scoreboard] = None

    # -- stage 1: partition --------------------------------------------------

    def partition(self) -> ShardedData:
        if self._sharded is None:
            model, spec = self._model, self.spec
            data, theta_true = model.generate_data(self._key, spec.resolved_n())
            shards, counts = partition_data(
                data, spec.M, only=model.shard_keys, pad=True
            )
            self._sharded = ShardedData(shards, counts, data, theta_true)
        return self._sharded

    # -- stage 2: sample (embarrassingly parallel) ---------------------------

    def sample(self, max_steps: Optional[int] = None) -> SubposteriorDraws:
        """Run (or resume) the M subposterior chains.

        ``max_steps`` bounds the draws collected *this call* (resumable mode
        only) — the budgeted-sampling / preemption-simulation hook. A
        partial artifact has ``complete=False``; calling ``sample()`` again
        continues from the persisted kernel state.
        """
        if self._draws is not None and self._draws.complete:
            return self._draws
        spec = self.spec
        sharded = self.partition()
        t0 = time.time()
        if self.checkpoint_dir is not None:
            if spec.mesh_shape is not None:
                raise ValueError(
                    "checkpointed sampling runs the vmap backend only — a "
                    f"spec with mesh_shape={spec.mesh_shape} would silently "
                    "lose its shard_map/HLO-assert request; drop one of the two"
                )
            from repro.api.resumable import sample_subposteriors_resumable

            rs = sample_subposteriors_resumable(
                jax.random.fold_in(self._key, 1),
                self._model,
                sharded.data,
                spec.M,
                spec.T,
                sampler=spec.sampler,
                warmup=spec.warmup,
                burn_in=spec.resolved_burn_in(),
                step_size=spec.step_size,
                sgld_batch=spec.sgld_batch,
                sampler_options=spec.sampler_options,
                checkpoint_dir=self.checkpoint_dir,
                checkpoint_every=self.checkpoint_every,
                spec_id=spec.spec_id,
                max_steps=max_steps,
                shards=sharded.shards,
                counts=sharded.counts,
            )
            res, t_done, complete = rs.result, rs.t_done, rs.complete
        else:
            if max_steps is not None:
                raise ValueError(
                    "max_steps needs a checkpoint_dir: a partial sampling "
                    "stage is only useful if it can be resumed"
                )
            res = sample_subposteriors(
                jax.random.fold_in(self._key, 1),
                self._model,
                sharded.data,
                spec.M,
                spec.T,
                sampler=spec.sampler,
                warmup=spec.warmup,
                burn_in=spec.resolved_burn_in(),
                step_size=spec.step_size,
                sgld_batch=spec.sgld_batch,
                check_hlo=self.check_hlo,
                mesh_shape=spec.mesh_shape,
                sampler_options=spec.sampler_options,
                shards=sharded.shards,
                counts=sharded.counts,
            )
            t_done, complete = spec.T, True
        self.timings["sample_s"] = self.timings.get("sample_s", 0.0) + (
            time.time() - t0
        )
        self._draws = SubposteriorDraws(
            res.theta, res.accept, res.counts, res.backend,
            res.collectives_checked, t_done, complete,
        )
        return self._draws

    # -- groundtruth: single full-data chain ---------------------------------

    def groundtruth(self) -> jnp.ndarray:
        """Long full-data chain at the compensated step size
        (:func:`groundtruth_step_size`)."""
        if self._groundtruth is None:
            spec = self.spec
            gt_step = groundtruth_step_size(spec)
            t0 = time.time()
            self._groundtruth = groundtruth_chain(
                jax.random.fold_in(self._key, 2),
                self._model,
                self.partition().data,
                spec.groundtruth_T,
                sampler=spec.sampler,
                warmup=spec.warmup,
                burn_in=spec.groundtruth_T // 6,
                step_size=gt_step,
                sgld_batch=spec.sgld_batch,
                sampler_options=spec.sampler_options,
            )
            self.timings["groundtruth_s"] = time.time() - t0
        return self._groundtruth

    # -- stage 3: combine (the only communicating stage) ---------------------

    def combine(self) -> Dict[str, CombineResult]:
        if self._combined is None:
            spec = self.spec
            draws = self.sample()
            if not draws.complete:
                raise RuntimeError(
                    f"sampling stage incomplete ({draws.t_done}/{spec.T} "
                    "draws) — call sample() until complete before combine()"
                )
            t0 = time.time()
            self._combined = combine_spec_draws(spec, self._key, draws.theta)
            self.timings["combine_s"] = time.time() - t0
        return self._combined

    # -- stage 4: score ------------------------------------------------------

    def score(self) -> Scoreboard:
        if self._board is None:
            spec = self.spec
            combined = self.combine()
            gt = self.groundtruth()
            # high-d runs score in log space (f32-overflow regime of raw L2)
            dist, label = resolve_metric(spec, self._model.d)
            errors = {
                name: float(dist(gt, res.samples))
                for name, res in combined.items()
            }
            draws = self._draws
            self._board = Scoreboard(
                spec_id=spec.spec_id,
                model=spec.model,
                sampler=spec.resolved_sampler(),
                M=spec.M,
                T=spec.T,
                metric=label,
                errors=errors,
                accept=float(jnp.mean(draws.accept)),
                backend=draws.backend,
                collectives_checked=draws.collectives_checked,
                timings=dict(self.timings),
            )
        return self._board

    def run(self) -> Scoreboard:
        """All four stages; equivalent to the historical ``mcmc_run`` body."""
        return self.score()


def combine_draws(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    combiner: str = "nonparametric",
    **options,
) -> CombineResult:
    """Registry-dispatched combination of a dense ``(M, T, d)`` stack.

    The programmatic face of the combine stage for callers that already
    hold subposterior draws (e.g. the LM-scale example's low-dim subset
    history) — same backend as ``Pipeline.combine()``.
    """
    from repro.distributed.epmcmc import combine_gathered

    return combine_gathered(key, samples, n_draws, combiner=combiner, **options)
