"""Staged, resumable execution of one :class:`RunSpec`.

The paper's dataflow is fixed — **partition → sample → combine → score** —
so the Pipeline exposes exactly those stages, each returning an explicit
typed artifact that can be inspected, persisted, or fed onward:

    ``partition() -> ShardedData``             (M shards + valid-row counts)
    ``sample()    -> SubposteriorDraws``       ((M, T, d) θ + diagnostics)
    ``combine()   -> dict[str, CombineResult]``(one per requested combiner)
    ``score()     -> Scoreboard``              (error per combiner vs groundtruth)

Stages are lazy and cached: each runs its predecessors on demand, so
``Pipeline(spec).run()`` is the whole paper and ``pipe.sample()`` alone is
just the embarrassingly parallel stage. RNG discipline is fixed by the spec
seed (data from ``PRNGKey(seed)``, sampling from ``fold_in(key, 1)``,
groundtruth ``fold_in(key, 2)``, one independent stream per combiner from
``fold_in(key, 3)`` + a stable hash of the name), so the same spec always
produces bitwise-identical artifacts.

The sampling stage always runs the chunk-emitting driver of
:mod:`repro.api.streaming`: chunks of ``spec.stream_every`` draws (one
T-sized chunk when 0) land in order, and everything else subscribes —
checkpoint persistence (``checkpoint_dir`` / ``checkpoint_every``, resume
mid-chain bitwise), and **combine-while-sampling** via
:meth:`Pipeline.stream_combine`, which folds every landed chunk into the
requested streaming combiners
(:func:`repro.core.combiners.get_streaming_combiner`), records a per-chunk
scoreboard trajectory, and finalizes estimates that are bitwise the
gather-then-combine result for the buffered combiners. Which *execution
backend* emits the chunks is a :mod:`repro.api.backends` decision: the
vmap backend on one device, or — ``mesh_shape`` (explicit or the >1-device
auto-mesh) — the mesh chunk backend, which ``shard_map``\\ s the same chunk
programs over chain groups and asserts each compiled program's HLO
collective-free across chains. A mesh spec with no stream/checkpoint
request keeps the historical one-shot ``shard_map`` program
(whole-chain HLO assert, ``backend="shard_map(N devices)"``).

The batch combination stage dispatches through
:func:`repro.distributed.epmcmc.combine_gathered` — the same registry-name
backend the mesh EP-MCMC run uses — so scenario code and the distributed
runtime share one combine path.
"""

from __future__ import annotations

import math
import time
import zlib
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.api.spec import RunSpec
from repro.api.sampling import groundtruth_chain, sample_subposteriors
from repro.api.streaming import StreamChunk, stream_sample
from repro.core import metrics
from repro.core.subposterior import partition_data
from repro.core.combiners import (
    BufferState,
    CombineResult,
    StreamingCombiner,
    filter_options,
    get_combiner,
    get_scan_face,
    get_streaming_combiner,
)
from repro.models.bayes import get_model
from repro.samplers import sampler_spec

PyTree = Any

# models at or above this θ-dimension are scored in log space: raw
# `l2_distance` enters the f32-overflow regime of the KDE normalizer there
# (its own docstring's warning) and becomes hypersensitive to dispersion
LOG_L2_DIM = 40


def groundtruth_step_size(spec: RunSpec) -> float:
    """Full-chain step compensation, shared by Pipeline and run_matrix.

    The full posterior is ~√M narrower than a subposterior and its gradient
    M× larger; warmup absorbs that for adaptive kernels, fixed-step ones
    need the classic compensation (ε/M for Langevin time steps, ε/√M for
    proposal scales).
    """
    sp = sampler_spec(spec.resolved_sampler())
    if sp.name == "sgld":
        return spec.step_size / spec.M
    if not (sp.adaptive and spec.warmup > 0):
        return spec.step_size / math.sqrt(spec.M)
    return spec.step_size


def combine_spec_draws(
    spec: RunSpec,
    base_key: jax.Array,
    theta: jnp.ndarray,
    names: Optional[Tuple[str, ...]] = None,
) -> "Dict[str, CombineResult]":
    """The combine stage for one spec, shared by Pipeline and run_matrix.

    One independent RNG stream per estimator (``fold_in(base_key, 3)`` then
    a fold by a stable hash of the name — one shared key would correlate the
    scoreboard entries, and it also makes each combiner's result independent
    of which subset ``names`` selects); options merge the spec's
    ``combiner_options`` over the driver defaults and are filtered per
    combiner signature by the ``combine_gathered`` backend.
    """
    # late import — epmcmc pulls the heavy LM stack
    from repro.distributed.epmcmc import combine_gathered

    kc = jax.random.fold_in(base_key, 3)
    options = dict({"rescale": True, "n_batch": 1}, **dict(spec.combiner_options))
    out: Dict[str, CombineResult] = {}
    for name in names if names is not None else spec.combiner_names():
        k_name = jax.random.fold_in(kc, zlib.crc32(name.encode()) & 0x7FFFFFFF)
        out[name] = combine_gathered(
            k_name, theta, spec.T, combiner=name, **options
        )
    return out


def resolve_metric(spec: RunSpec, d: int):
    """``(distance_fn, label)`` for a spec: ``score_metric`` override or the
    dimension rule above (narrow posteriors can force ``"logl2"`` explicitly
    — e.g. the scenario-matrix CI cells on the linear exactness oracle)."""
    use_log = spec.score_metric == "logl2" or (
        spec.score_metric == "auto" and d >= LOG_L2_DIM
    )
    if use_log:
        return metrics.log_l2_distance, "logL2"
    return metrics.l2_distance, "L2"


class ShardedData(NamedTuple):
    """Partition-stage artifact: the paper's M "machines" worth of data."""

    shards: PyTree  # per-datum leaves carry a leading (M, ...) chain axis
    counts: jnp.ndarray  # (M,) real rows per shard (edge-pad convention)
    data: PyTree  # the full dataset (groundtruth stage input)
    theta_true: jnp.ndarray  # generating parameters (diagnostics only)


class SubposteriorDraws(NamedTuple):
    """Sampling-stage artifact: M independent subposterior chains."""

    theta: jnp.ndarray  # (M, T, d) shared-θ draws
    accept: jnp.ndarray  # (M,) mean acceptance per chain
    counts: jnp.ndarray  # (M,)
    backend: str  # a repro.api.backends.BackendId string ("vmap[chunked]",
    # "shard_map[fused](4 devices)", ...) — never assembled ad hoc
    collectives_checked: Optional[int]
    t_done: int  # draws collected so far (== T unless interrupted)
    complete: bool


class StreamResult(NamedTuple):
    """Artifact of :meth:`Pipeline.stream_combine` (combine-while-sampling).

    ``trajectory`` rows are ``{"t", "combiner", "error", "elapsed_s"}`` —
    one per (chunk boundary, combiner-with-a-cheap-``estimate``), in
    landing order (fallback-streamed combiners fold every chunk but only
    finalize, so they contribute no rows); ``elapsed_s`` is
    wall time since the stream started, stamped per row when that row's
    estimate has actually materialized (``block_until_ready`` before the
    clock read) — so it is monotone in landing order and honest in both
    modes (``trajectory[0]["elapsed_s"]`` is the time-to-first-estimate the
    bench tracks; on a resumed run the replayed prefix carries the resume
    session's clock; on the fused path the one compiled combine-fold
    program materializes estimates close together, so consecutive stamps
    can be near-identical — but each is still that row's true availability
    instant). ``combined`` holds
    the finalized per-combiner results (empty while ``complete`` is False).
    """

    combined: Dict[str, CombineResult]
    trajectory: List[Dict[str, Any]]
    t_done: int
    total: int
    complete: bool
    metric: str  # "L2" | "logL2" | "" when unscored
    stream_every: int
    n_estimate: int


class StreamSetup(NamedTuple):
    """Resolved combine-while-sampling surfaces for one stream consumer.

    The shared setup of everything that folds the chunk stream —
    :meth:`Pipeline.stream_combine` and the ``repro.serve`` query layer —
    so both consume identical streaming combiners, per-name RNG streams
    (``fold_in(key, 3)`` + stable name hash, the combine stage's
    discipline), and merged options. Anything folding the same chunks
    through the same setup reproduces the trajectory estimates bitwise.
    """

    names: Tuple[str, ...]
    combiners: Dict[str, StreamingCombiner]
    keys: Dict[str, jax.Array]  # name -> independent RNG stream
    options: Dict[str, Any]  # merged spec.combiner_options over defaults


class Scoreboard(NamedTuple):
    """Score-stage artifact: the paper's error table for one scenario."""

    spec_id: str
    model: str
    sampler: str
    M: int
    T: int
    metric: str  # "L2" | "logL2"
    errors: Dict[str, float]  # combiner name -> distance to groundtruth
    accept: float
    backend: str
    collectives_checked: Optional[int]
    timings: Dict[str, float]  # stage -> seconds

    def table(self) -> str:
        lines = [
            f"model={self.model} M={self.M} T={self.T} sampler={self.sampler} "
            f"acc={self.accept:.2f} backend={self.backend}"
        ]
        for name, err in sorted(self.errors.items(), key=lambda kv: kv[1]):
            lines.append(f"  {self.metric}({name:15s}) = {err:.4f}")
        return "\n".join(lines)

    def to_dict(self) -> Dict[str, Any]:
        return dict(self._asdict())


class Pipeline:
    """Run one :class:`RunSpec` stage by stage (see module docstring)."""

    def __init__(
        self,
        spec: RunSpec,
        *,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        check_hlo: bool = True,
    ):
        self.spec = spec.validate()
        self.checkpoint_dir = str(checkpoint_dir) if checkpoint_dir else None
        if checkpoint_every > 0 and self.checkpoint_dir is None:
            raise ValueError(
                "checkpoint_every > 0 without a checkpoint_dir would "
                "silently persist nothing — pass checkpoint_dir (or drop "
                "the cadence)"
            )
        self.checkpoint_every = checkpoint_every
        self.check_hlo = check_hlo
        self.timings: Dict[str, float] = {}
        self._model = get_model(spec.model)
        self._key = jax.random.PRNGKey(spec.seed)
        self._sharded: Optional[ShardedData] = None
        self._draws: Optional[SubposteriorDraws] = None
        self._groundtruth: Optional[jnp.ndarray] = None
        self._combined: Optional[Dict[str, CombineResult]] = None
        self._board: Optional[Scoreboard] = None

    # -- stage 1: partition --------------------------------------------------

    def partition(self) -> ShardedData:
        if self._sharded is None:
            model, spec = self._model, self.spec
            data, theta_true = model.generate_data(self._key, spec.resolved_n())
            shards, counts = partition_data(
                data, spec.M, only=model.shard_keys, pad=True
            )
            self._sharded = ShardedData(shards, counts, data, theta_true)
        return self._sharded

    # -- stage 2: sample (embarrassingly parallel) ---------------------------

    def sample(
        self,
        max_steps: Optional[int] = None,
        on_chunk: Sequence[Callable[[StreamChunk], None]] = (),
    ) -> SubposteriorDraws:
        """Run (or resume) the M subposterior chains as one chunk stream.

        ``max_steps`` bounds the draws collected *this call* (checkpointed
        runs only) — the budgeted-sampling / preemption-simulation hook. A
        partial artifact has ``complete=False``; calling ``sample()`` again
        continues from the persisted kernel state. ``on_chunk`` subscribers
        see every landed ``(M, C, d)`` chunk in order, restored prefixes
        included (:meth:`stream_combine` is the built-in subscriber).

        Backend routing: the chunk-emitting driver
        (:func:`repro.api.streaming.stream_sample`) everywhere, on the
        backend the spec's ``mesh_shape`` selects (explicit, or the
        >1-device auto-mesh when M divides evenly): mesh specs that
        stream/checkpoint run the chunked mesh backend with per-program HLO
        asserts; mesh specs with no stream/checkpoint request keep the
        historical one-shot ``shard_map`` program and its whole-chain HLO
        assert.
        """
        if self._draws is not None and self._draws.complete:
            return self._draws
        spec = self.spec
        wants_stream = (
            spec.stream_every > 0
            or self.checkpoint_dir is not None
            or bool(on_chunk)
        )
        sharded = self.partition()
        t0 = time.time()
        ndev = jax.device_count()
        mesh_shape = spec.mesh_shape
        if mesh_shape is None and ndev > 1 and spec.M % ndev == 0:
            mesh_shape = (ndev, 1)
        use_mesh = mesh_shape is not None and mesh_shape[0] > 1
        if use_mesh and not wants_stream:
            if max_steps is not None:
                raise ValueError(
                    "max_steps needs a checkpoint_dir: a partial sampling "
                    "stage is only useful if it can be resumed"
                )
            res = sample_subposteriors(
                jax.random.fold_in(self._key, 1),
                self._model,
                sharded.data,
                spec.M,
                spec.T,
                sampler=spec.sampler,
                warmup=spec.warmup,
                burn_in=spec.resolved_burn_in(),
                step_size=spec.step_size,
                sgld_batch=spec.sgld_batch,
                check_hlo=self.check_hlo,
                mesh_shape=mesh_shape,
                sampler_options=spec.sampler_options,
                shards=sharded.shards,
                counts=sharded.counts,
            )
            t_done, complete = spec.T, True
        else:
            if max_steps is not None and self.checkpoint_dir is None:
                raise ValueError(
                    "max_steps needs a checkpoint_dir: a partial sampling "
                    "stage is only useful if it can be resumed"
                )
            rs = stream_sample(
                jax.random.fold_in(self._key, 1),
                self._model,
                sharded.data,
                spec.M,
                spec.T,
                sampler=spec.sampler,
                warmup=spec.warmup,
                burn_in=spec.resolved_burn_in(),
                step_size=spec.step_size,
                sgld_batch=spec.sgld_batch,
                sampler_options=spec.sampler_options,
                shards=sharded.shards,
                counts=sharded.counts,
                chunk_size=spec.stream_every,
                max_steps=max_steps,
                checkpoint_dir=self.checkpoint_dir,
                checkpoint_every=self.checkpoint_every,
                spec_id=spec.spec_id,
                on_chunk=on_chunk,
                mesh_shape=mesh_shape if use_mesh else None,
                check_hlo=self.check_hlo,
            )
            res, t_done, complete = rs.result, rs.t_done, rs.complete
        self.timings["sample_s"] = self.timings.get("sample_s", 0.0) + (
            time.time() - t0
        )
        self._draws = SubposteriorDraws(
            res.theta, res.accept, res.counts, res.backend,
            res.collectives_checked, t_done, complete,
        )
        return self._draws

    # -- groundtruth: single full-data chain ---------------------------------

    def groundtruth(self) -> jnp.ndarray:
        """Long full-data chain at the compensated step size
        (:func:`groundtruth_step_size`)."""
        if self._groundtruth is None:
            spec = self.spec
            gt_step = groundtruth_step_size(spec)
            t0 = time.time()
            self._groundtruth = groundtruth_chain(
                jax.random.fold_in(self._key, 2),
                self._model,
                self.partition().data,
                spec.groundtruth_T,
                sampler=spec.sampler,
                warmup=spec.warmup,
                burn_in=spec.groundtruth_T // 6,
                step_size=gt_step,
                sgld_batch=spec.sgld_batch,
                sampler_options=spec.sampler_options,
            )
            self.timings["groundtruth_s"] = time.time() - t0
        return self._groundtruth

    # -- stage 3b: combine-while-sampling ------------------------------------

    def stream_setup(
        self, names: Optional[Tuple[str, ...]] = None
    ) -> StreamSetup:
        """Resolve the streaming surfaces for ``names`` (default: the
        spec's combiners) — see :class:`StreamSetup`. Fails fast on
        unknown names."""
        spec = self.spec
        names = spec.combiner_names() if names is None else tuple(names)
        scs: Dict[str, StreamingCombiner] = {}
        for name in names:
            get_combiner(name)  # fail fast on unknown names
            scs[name] = get_streaming_combiner(name)
        options = dict(
            {"rescale": True, "n_batch": 1}, **dict(spec.combiner_options)
        )
        kc = jax.random.fold_in(self._key, 3)
        k_names = {
            name: jax.random.fold_in(kc, zlib.crc32(name.encode()) & 0x7FFFFFFF)
            for name in names
        }
        return StreamSetup(names, scs, k_names, options)

    def stream_combine(
        self,
        names: Optional[Tuple[str, ...]] = None,
        *,
        n_estimate: int = 128,
        max_steps: Optional[int] = None,
        score: bool = True,
        fused: Optional[bool] = None,
    ) -> StreamResult:
        """Fold each landed sampling chunk into the streaming combiners.

        Requires ``spec.stream_every > 0``. As every ``stream_every``-draw
        chunk lands, it is ``update``-folded into one
        :class:`~repro.core.combiners.api.StreamingCombiner` per requested
        name and a cheap ``estimate`` (``n_estimate`` draws) is taken — the
        per-chunk scoreboard trajectory. Combiners whose streaming form has
        no cheap ``estimate`` (the generic buffered fallback — weierstrass,
        rpt, …) still fold every chunk but contribute no mid-stream rows:
        re-running a heavy batch combiner on the growing buffer at every
        boundary would cost more than the gather path the stream exists to
        beat. When sampling completes, each state
        is ``finalize``\\ d with the *same* RNG stream and options as the
        batch combine stage, so the final results are bitwise the
        gather-then-combine ones for the buffered combiners (``parametric``,
        ``pool``, ``nonparametric``, every fallback) and within Welford
        merge-rounding for ``online``; :meth:`score` then reuses them.

        ``fused`` selects the hot path: ``None`` (default) fuses
        automatically when every requested combiner has a scan face
        (:func:`repro.core.combiners.get_scan_face`) and nothing needs the
        host between chunks (no checkpointing, no ``max_steps`` budget) —
        sampling runs as one compiled program shared with the gather path
        (same theta bitwise) and the combiner folds + in-scan trajectory
        estimates run as a second compiled program over the device-resident
        draws (:func:`repro.api.streaming.fused_fold`), zero per-chunk host
        hops. ``fused=False`` forces the subscriber-driven path;
        ``fused=True`` asserts fusability and raises when the run needs the
        subscriber path. Finals are bitwise identical between the two modes
        (same theta, same keys, same host ``finalize``); trajectory
        estimates agree to compile-scheduling rounding, and ``online``'s
        fused folds to Welford merge-rounding (its scan face runs the
        Pallas ``online_update`` kernel).

        ``score=False`` skips the groundtruth chain and leaves trajectory
        errors ``None`` (the bench's time-to-first-estimate mode);
        ``max_steps`` bounds this session (checkpointed runs — a later
        ``stream_combine`` on the same directory replays the restored
        prefix and reproduces the uninterrupted trajectory exactly).
        """
        spec = self.spec
        if spec.stream_every <= 0:
            raise ValueError(
                "stream_combine needs RunSpec.stream_every > 0 — with no "
                "chunk cadence there is nothing to fold mid-run (set e.g. "
                "stream_every=T//10, or use combine())"
            )
        names, scs, k_names, options = self.stream_setup(names)

        faces = {name: get_scan_face(name) for name in names}
        can_fuse = (
            fused is not False
            and self.checkpoint_dir is None
            and max_steps is None
            and all(faces[name] is not None for name in names)
        )
        if fused is True and not can_fuse:
            blockers = [n for n in names if faces[n] is None]
            raise ValueError(
                "fused=True but this run needs the subscriber path: "
                + (
                    f"combiners without a scan face: {blockers}"
                    if blockers
                    else "checkpointing/max_steps require per-chunk host "
                    "subscribers"
                )
            )
        if can_fuse:
            return self._stream_combine_fused(
                names, scs, faces, k_names, options, n_estimate, score
            )
        states: Dict[str, Any] = {name: None for name in names}
        rows: List[Dict[str, Any]] = []
        estimates: List[Tuple[int, str, jnp.ndarray]] = []
        t_start = time.time()

        def fold(ev: StreamChunk) -> None:
            M, _, d = ev.theta.shape
            for name in names:
                sc = scs[name]
                if states[name] is None:
                    states[name] = sc.init(M, d)
                states[name] = sc.update(states[name], ev.theta)
            for name in names:
                est_fn = scs[name].estimate
                if est_fn is None:
                    continue  # no cheap mid-stream estimate — finalize-only
                k_est = jax.random.fold_in(k_names[name], ev.t1)
                est = est_fn(
                    k_est, states[name], n_estimate,
                    **filter_options(est_fn, options),
                )
                est.samples.block_until_ready()  # honest elapsed_s
                if score:
                    estimates.append((ev.t1, name, est.samples))
                rows.append({
                    "t": ev.t1,
                    "combiner": name,
                    "error": None,
                    "elapsed_s": time.time() - t_start,
                })

        if self._draws is not None and self._draws.complete:
            # sampling already ran (e.g. combine() first): replay the cached
            # draws at the stream cadence — same chunks, same states
            theta = self._draws.theta
            zeros = jnp.zeros((spec.M,), jnp.float32)
            for r0 in range(0, spec.T, spec.stream_every):
                r1 = min(r0 + spec.stream_every, spec.T)
                fold(StreamChunk(
                    theta[:, r0:r1], zeros, r0, r1, spec.T, {}, replayed=True
                ))
            draws = self._draws
        else:
            draws = self.sample(max_steps=max_steps, on_chunk=(fold,))

        final: Dict[str, CombineResult] = {}
        if draws.complete:
            t0 = time.time()
            for name in names:
                fn = scs[name].finalize
                final[name] = fn(
                    k_names[name], states[name], spec.T,
                    **filter_options(fn, options),
                )
            self.timings["stream_combine_s"] = time.time() - t0
            # the finals ARE the combine-stage results (bitwise for the
            # buffered implementations) — let score() reuse them
            if self._combined is None and set(names) == set(spec.combiner_names()):
                self._combined = dict(final)
                self.timings.setdefault(
                    "combine_s", self.timings["stream_combine_s"]
                )

        label = ""
        if score:
            gt = self.groundtruth()
            dist, label = resolve_metric(spec, self._model.d)
            for row, (_, _, samples) in zip(rows, estimates):
                row["error"] = float(dist(gt, samples))
        return StreamResult(
            combined=final,
            trajectory=rows,
            t_done=draws.t_done,
            total=spec.T,
            complete=draws.complete,
            metric=label,
            stream_every=spec.stream_every,
            n_estimate=n_estimate,
        )

    def _stream_combine_fused(
        self,
        names: Tuple[str, ...],
        scs: Dict[str, Any],
        faces: Dict[str, Any],
        k_names: Dict[str, jax.Array],
        options: Dict[str, Any],
        n_estimate: int,
        score: bool,
    ) -> StreamResult:
        """The fused mode of :meth:`stream_combine`: one compiled sampling
        program (shared with the plain stage — same theta bitwise), one
        compiled combine-fold program over the device-resident draws.

        Trajectory rows land for exactly the combiners the subscriber path
        would estimate (host ``estimate`` non-None), in the same
        per-boundary order and from the same ``fold_in(k_name, t1)`` keys:
        in-scan for faces shipping a scan ``estimate`` (``parametric``),
        post-hoc on buffered prefixes of the gathered draws for the rest
        (``pool``, ``nonparametric``, ...).
        """
        from repro.api.streaming import fused_fold

        spec = self.spec
        t_start = time.time()
        draws = self.sample()  # the fused program, or the cached draws
        theta = draws.theta
        chunk = spec.stream_every
        counts_T = jnp.full((spec.M,), spec.T, jnp.int32)

        t0 = time.time()
        n_full, tail = divmod(spec.T, chunk)
        boundaries = tuple(chunk * (i + 1) for i in range(n_full)) + (
            (spec.T,) if tail else ()
        )
        est_keys = {
            name: jnp.stack(
                [jax.random.fold_in(k_names[name], t1) for t1 in boundaries]
            )
            for name in names
            if faces[name].estimate is not None and scs[name].estimate is not None
        }
        ff = fused_fold(
            theta, {n: faces[n] for n in names}, est_keys, n_estimate,
            chunk, options,
        )

        rows: List[Dict[str, Any]] = []
        estimates: List[Tuple[int, str, jnp.ndarray]] = []
        for i, t1 in enumerate(ff.boundaries):
            for name in names:
                est_fn = scs[name].estimate
                if est_fn is None:
                    continue  # no mid-stream row on the subscriber path either
                if name in est_keys:
                    samples = ff.est_draws[name][i]
                else:
                    prefix = BufferState(
                        theta[:, :t1], jnp.full((spec.M,), t1, jnp.int32)
                    )
                    samples = est_fn(
                        jax.random.fold_in(k_names[name], t1), prefix,
                        n_estimate, **filter_options(est_fn, options),
                    ).samples
                estimates.append((t1, name, samples))
                rows.append({
                    "t": t1, "combiner": name, "error": None, "elapsed_s": None,
                })
        # honest per-boundary stamps: each row's clock reads only after THAT
        # row's estimate is device-complete, so elapsed_s is the row's true
        # availability instant (monotone in landing order) — not one post-run
        # stamp smeared across the trajectory. The fused program materializes
        # estimates close together, so consecutive stamps may be near-equal;
        # they are still each row's own wall-clock.
        for row, (_, _, samples) in zip(rows, estimates):
            jax.block_until_ready(samples)
            row["elapsed_s"] = time.time() - t_start

        final: Dict[str, CombineResult] = {}
        for name in names:
            fn = scs[name].finalize
            host_state = faces[name].to_state(ff.states[name], theta, counts_T)
            final[name] = fn(
                k_names[name], host_state, spec.T,
                **filter_options(fn, options),
            )
        self.timings["stream_combine_s"] = time.time() - t0
        if self._combined is None and set(names) == set(spec.combiner_names()):
            self._combined = dict(final)
            self.timings.setdefault("combine_s", self.timings["stream_combine_s"])

        label = ""
        if score:
            gt = self.groundtruth()
            dist, label = resolve_metric(spec, self._model.d)
            for row, (_, _, samples) in zip(rows, estimates):
                row["error"] = float(dist(gt, samples))
        return StreamResult(
            combined=final,
            trajectory=rows,
            t_done=draws.t_done,
            total=spec.T,
            complete=True,
            metric=label,
            stream_every=spec.stream_every,
            n_estimate=n_estimate,
        )

    # -- stage 3: combine (the only communicating stage) ---------------------

    def combine(self) -> Dict[str, CombineResult]:
        if self._combined is None:
            spec = self.spec
            draws = self.sample()
            if not draws.complete:
                raise RuntimeError(
                    f"sampling stage incomplete ({draws.t_done}/{spec.T} "
                    "draws) — call sample() until complete before combine()"
                )
            t0 = time.time()
            self._combined = combine_spec_draws(spec, self._key, draws.theta)
            self.timings["combine_s"] = time.time() - t0
        return self._combined

    # -- stage 4: score ------------------------------------------------------

    def score(self) -> Scoreboard:
        if self._board is None:
            spec = self.spec
            combined = self.combine()
            gt = self.groundtruth()
            # high-d runs score in log space (f32-overflow regime of raw L2)
            dist, label = resolve_metric(spec, self._model.d)
            errors = {
                name: float(dist(gt, res.samples))
                for name, res in combined.items()
            }
            draws = self._draws
            self._board = Scoreboard(
                spec_id=spec.spec_id,
                model=spec.model,
                sampler=spec.resolved_sampler(),
                M=spec.M,
                T=spec.T,
                metric=label,
                errors=errors,
                accept=float(jnp.mean(draws.accept)),
                backend=draws.backend,
                collectives_checked=draws.collectives_checked,
                timings=dict(self.timings),
            )
        return self._board

    def run(self) -> Scoreboard:
        """All four stages; equivalent to the historical ``mcmc_run`` body."""
        return self.score()


def combine_draws(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    combiner: str = "nonparametric",
    **options,
) -> CombineResult:
    """Registry-dispatched combination of a dense ``(M, T, d)`` stack.

    The programmatic face of the combine stage for callers that already
    hold subposterior draws (e.g. the LM-scale example's low-dim subset
    history) — same backend as ``Pipeline.combine()``.
    """
    from repro.distributed.epmcmc import combine_gathered

    return combine_gathered(key, samples, n_draws, combiner=combiner, **options)
