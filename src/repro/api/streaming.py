"""The one chunk-emitting sampling driver behind every ``repro.api`` run.

Historically the sampling stage had two bodies: the one-shot ``lax.scan``
drivers in :mod:`repro.api.sampling` and a separate chunked loop in
:mod:`repro.api.resumable`. This module is the merge: **one** generator
(:meth:`ShardChainStream.chunks`) advances all M chains in global chunks and
yields each landed ``(M, C, d)`` slice, and everything else subscribes —

- checkpoint persistence (:mod:`repro.api.resumable` is now a thin wrapper
  that adds restore/validation and a save-at-boundary subscriber);
- streaming combination (``Pipeline.stream_combine`` folds every chunk into
  the registered :class:`~repro.core.combiners.api.StreamingCombiner`\\ s);
- the plain sampling stage (one chunk of T draws when neither is asked for).

The bitwise-resume guarantee is unchanged and structural: per-step RNG keys
are a pure function of the seed, chunk boundaries are global multiples of
the cadence, and sessions advance in whole chunks — so an interrupted-then-
resumed run replays exactly the same chunk programs on the same inputs as
one that never stopped.

Execution is delegated to a pluggable :mod:`repro.api.backends`
:class:`~repro.api.backends.ChunkBackend`: the vmap backend on one device,
or — ``mesh_shape=`` with a data axis > 1 — the mesh backend, which
``shard_map``\\ s the *same* chunk programs over chain groups and asserts
every compiled program's HLO collective-free across chains (per chunk
shape, and for the fused whole-run program). Checkpointing, streaming
combination, and the fused fold subscribe identically on either backend.

Fused hot path: when nobody subscribes (no checkpointing, no ``on_chunk``,
no budget) a chunked run pays the host loop for nothing — every chunk is a
device→host→device round trip of pure dispatch overhead. ``stream_sample``
then runs :meth:`ShardChainStream.fused_program` instead: setup + a
``lax.scan`` over the *same* chunk programs inside ONE jitted executable
(backend tag ``"vmap[fused]"``). Two executables matter here, not one:

- the fused **sampling** program is shared by every caller at the same
  cadence — the plain sampling stage (hence the gather-then-combine path)
  and ``Pipeline.stream_combine``'s fused mode produce the *same* theta
  array from the same compiled program, which is what makes the fused
  stream's finals bitwise the gather results;
- the fused **combine-fold** program (:func:`fused_fold`) scans the
  requested combiners' :class:`~repro.core.combiners.api.ScanStreamingFace`
  updates (and in-scan trajectory estimates) over that device-resident
  theta, with the fold states donated between steps — zero per-chunk host
  hops on the combine side too.

A literal single sample+combine scan was measured and rejected: hoisting
the combine update into the sampling scan changes the XLA schedule enough
that theta drifts from the chunked driver at the last ulp (~2e-7), which
would break the bitwise gather contract. The split keeps both programs
fused end-to-end *and* keeps theta identical by construction.
"""

from __future__ import annotations

import functools
import time
from typing import Any, Callable, Dict, Iterator, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore, save
from repro.core.subposterior import partition_data
from repro.models.bayes import BayesModel
from repro.api.backends import (  # noqa: F401  (historical homes re-exported)
    CHUNKED,
    FUSED,
    RESUMABLE,
    BackendId,
    _chunk_one,
    _freeze_options,
    _setup_one,
    get_chunk_backend,
)
from repro.api.sampling import SampleResult, ShardKernel, is_padded

PyTree = Any


class StreamChunk(NamedTuple):
    """One landed chunk of subposterior draws (what subscribers consume).

    On a resumed run the restored prefix is re-emitted with
    ``replayed=True``: there ``theta``/``t0``/``t1`` are faithful per-chunk
    (sliced from the restored draws at the original boundaries), but the
    historical kernel states are gone — ``carry`` holds the *restored*
    (latest) state and ``accept`` is zeroed. Subscribers that need per-chunk
    carry/acceptance must skip replayed chunks; the streaming combiners
    consume only ``theta``.

    ``landed_s`` is the ``time.monotonic()`` instant the driver emitted the
    chunk (replays stamp their re-emission, not the original landing) — the
    honest per-boundary clock behind trajectory ``elapsed_s`` and the
    serving layer's ``last_fold_monotonic_s`` staleness field. It is
    metadata, not part of the bitwise-resume contract.
    """

    theta: jnp.ndarray  # (M, C, d) this chunk's draws
    accept: jnp.ndarray  # (M,) accepted count in the chunk (zeros if replayed)
    t0: int  # first global draw index of the chunk
    t1: int  # one past the last (t1 - t0 == C)
    total: int  # the run's T
    carry: Dict[str, jnp.ndarray]  # live driver state (restored if replayed)
    replayed: bool = False  # True when re-emitted from restored draws
    landed_s: Optional[float] = None  # monotonic emission instant (metadata)


# fused whole-run sampling programs: backend cache key + (T, chunk)
_FUSED_SAMPLE_CACHE: Dict[Tuple, Any] = {}
# fused combine-fold programs: (combiner names, chunking, shapes, options)
_FUSED_FOLD_CACHE: Dict[Tuple, Any] = {}


class ShardChainStream:
    """M parallel subposterior chains, advanced in global chunks.

    Owns the resolved :class:`~repro.api.backends.ChunkBackend` (the jitted
    setup and chunk programs, shared across instances via the backend
    cache), the mesh-committed stage inputs, and the per-step collect keys
    (a pure function of the seed — identical on every session, whatever the
    chunking).
    """

    def __init__(
        self,
        key: jax.Array,
        model: BayesModel,
        num_shards: int,
        num_samples: int,
        *,
        sampler: Optional[str] = None,
        warmup: int = 200,
        burn_in: int = 0,
        step_size: float = 0.1,
        sgld_batch: int = 256,
        sampler_options=(),
        shards: PyTree,
        counts: jnp.ndarray,
        use_counts: bool,
        mesh_shape: Optional[Tuple[int, int]] = None,
        check_hlo: bool = True,
    ):
        self.model = model
        self.num_shards = num_shards
        self.num_samples = num_samples
        sampler = sampler or model.default_sampler
        self.backend = get_chunk_backend(
            model,
            num_shards,
            sampler,
            warmup=warmup,
            burn_in=burn_in,
            step_size=step_size,
            sgld_batch=sgld_batch,
            sampler_options=sampler_options,
            use_counts=use_counts,
            shards=shards,
            mesh_shape=mesh_shape,
            check_hlo=check_hlo,
        )
        self._cache_key = self.backend.cache_key
        self.setup = self.backend.setup
        self.chunk_fn = self.backend.next_chunk
        self.shards, self.counts, self.keys = self.backend.prepare(
            shards, counts, jax.random.split(key, num_shards)
        )

    def setup_struct(self):
        """Abstract ``(state, eps, k_collect)`` shapes — the restore template."""
        return jax.eval_shape(self.setup, self.shards, self.counts, self.keys)

    def fresh_carry(self) -> Dict[str, jnp.ndarray]:
        state, eps, k_collect = self.setup(self.shards, self.counts, self.keys)
        return {
            "state": state,
            "eps": eps,
            "k_collect": k_collect,
            "theta": jnp.zeros(
                (self.num_shards, 0, self.model.d), jnp.float32
            ),
            "accept_sum": jnp.zeros((self.num_shards,), jnp.float32),
        }

    def fused_program(self, chunk: int):
        """ONE jitted executable for the whole run: setup + ``lax.scan`` over
        the chunk programs (plus the statically-unrolled ragged tail).

        The scan body calls the *same* ``chunk_fn`` the host-driven
        :meth:`chunks` loop dispatches — whoever samples at this cadence
        through this program (the plain stage, the fused stream) gets the
        same theta from the same executable. Returns ``run(shards, counts,
        keys) -> (theta (M, T, d), accept_sum (M,))``.
        """
        T = self.num_samples
        key = self._cache_key + (T, int(chunk))
        prog = _FUSED_SAMPLE_CACHE.get(key)
        if prog is None:
            n_full, tail = divmod(T, chunk)
            setup, chunk_fn = self.setup, self.chunk_fn

            def run(shards, counts, keys):
                state, eps, k_collect = setup(shards, counts, keys)
                ck = jax.vmap(lambda k: jax.random.split(k, T))(k_collect)
                body = ck[:, : n_full * chunk]
                xs = jnp.moveaxis(
                    body.reshape(
                        (body.shape[0], n_full, chunk) + body.shape[2:]
                    ),
                    1, 0,
                )  # (n_full, M, chunk, key)

                def step(st, kc):
                    st, th, ac = chunk_fn(shards, counts, eps, st, kc)
                    return st, (th, ac)

                state, (ths, acs) = jax.lax.scan(step, state, xs)
                theta = jnp.moveaxis(ths, 0, 1).reshape(
                    ths.shape[1], n_full * chunk, ths.shape[-1]
                )
                accept = acs.sum(axis=0)
                if tail:
                    state, th_t, ac_t = chunk_fn(
                        shards, counts, eps, state,
                        ck[:, n_full * chunk :],
                    )
                    theta = jnp.concatenate([theta, th_t], axis=1)
                    accept = accept + ac_t
                return theta, accept

            prog = _FUSED_SAMPLE_CACHE[key] = jax.jit(run)
        return prog

    def fused_sample(self, chunk: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Run the fused whole-run program on this stream's inputs via the
        backend's compilation strategy (the mesh backend AOT-compiles and
        asserts the whole-run HLO collective-free before executing)."""
        prog_key = self._cache_key + (self.num_samples, int(chunk))
        return self.backend.run_fused(
            prog_key, self.fused_program(chunk),
            self.shards, self.counts, self.keys,
        )

    def chunks(
        self,
        carry: Dict[str, jnp.ndarray],
        t_done: int,
        chunk_size: int,
        stop: Optional[int] = None,
    ) -> Iterator[StreamChunk]:
        """Yield whole chunks from ``t_done`` until ``stop`` (default T).

        Boundaries are global multiples of ``chunk_size`` (+ the final T), so
        the emitted chunk *programs* are independent of where a session
        starts — the structural bitwise-resume property. A ``stop`` that a
        whole chunk would overshoot ends the iteration early (preemption
        semantics: partial-chunk work is lost anyway).
        """
        T = self.num_samples
        chunk = chunk_size if chunk_size > 0 else T
        stop = T if stop is None else min(stop, T)
        # per-step keys: pure function of the seed — identical every session
        collect_keys = jax.vmap(lambda k: jax.random.split(k, T))(
            carry["k_collect"]
        )
        while t_done < stop:
            t1 = min(t_done + chunk, T)
            if t1 > stop:
                break  # ragged chunk would shift later boundaries; stop here
            state, theta_c, acc_c = self.chunk_fn(
                self.shards,
                self.counts,
                carry["eps"],
                carry["state"],
                collect_keys[:, t_done:t1],
            )
            carry = {
                "state": state,
                "eps": carry["eps"],
                "k_collect": carry["k_collect"],
                "theta": jnp.concatenate([carry["theta"], theta_c], axis=1),
                "accept_sum": carry["accept_sum"] + acc_c,
            }
            t0, t_done = t_done, t1
            # emitted chunks leave the backend's device layout (mesh
            # sharding must not leak into subscriber/combiner numerics)
            theta_l = self.backend.localize(theta_c)
            acc_l = self.backend.localize(acc_c)
            jax.block_until_ready(theta_l)  # honest landed_s: draws are real
            yield StreamChunk(
                theta_l, acc_l, t0, t1, T, carry,
                landed_s=time.monotonic(),
            )


class StreamedSample(NamedTuple):
    """Outcome of :func:`stream_sample` (superset of the resumable artifact)."""

    result: SampleResult
    t_done: int
    total: int
    resumed_from: int  # 0 on a fresh run, else the restored draw count

    @property
    def complete(self) -> bool:
        return self.t_done >= self.total


def _restore_carry(checkpoint_dir, step, state_struct, d, num_shards):
    """Rebuild the carry pytree from a checkpoint, typed by the setup shapes."""
    state, eps, k_collect = state_struct
    template = {
        "state": state,
        "eps": eps,
        "k_collect": k_collect,
        "theta": jax.ShapeDtypeStruct((num_shards, step, d), jnp.float32),
        "accept_sum": jax.ShapeDtypeStruct((num_shards,), jnp.float32),
    }
    return restore(checkpoint_dir, step=step, template=template)


def stream_sample(
    key: jax.Array,
    model: BayesModel,
    data: PyTree,
    num_shards: int,
    num_samples: int,
    *,
    sampler: Optional[str] = None,
    warmup: int = 200,
    burn_in: int = 0,
    step_size: float = 0.1,
    sgld_batch: int = 256,
    sampler_options=(),
    shards: Optional[PyTree] = None,
    counts: Optional[jnp.ndarray] = None,
    chunk_size: int = 0,
    max_steps: Optional[int] = None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    spec_id: str = "",
    on_chunk: Sequence[Callable[[StreamChunk], None]] = (),
    mesh_shape: Optional[Tuple[int, int]] = None,
    check_hlo: bool = True,
) -> StreamedSample:
    """Run (or resume) the parallel sampling stage as one chunked stream.

    ``chunk_size`` is the emission cadence (0 ⇒ ``checkpoint_every``, else
    one T-sized chunk); ``on_chunk`` subscribers see every chunk *in order*,
    including — on a resumed run — the restored prefix re-emitted as
    ``replayed=True`` chunks at the original boundaries, so stateful
    subscribers (streaming combiners) rebuild exactly the uninterrupted
    trajectory. With ``checkpoint_dir`` the carry is persisted at every
    ``checkpoint_every`` boundary (which must be a multiple of the chunk
    cadence) and a later call resumes mid-chain bitwise; ``max_steps``
    bounds the draws collected this call (whole chunks only).

    ``mesh_shape`` with a data axis > 1 runs every chunk on the
    :class:`~repro.api.backends.MeshChunkBackend` — same streaming,
    checkpointing, and fused semantics, with each compiled program's HLO
    asserted collective-free across chain groups (``check_hlo=False`` skips
    the assert).
    """
    chunk = chunk_size if chunk_size > 0 else checkpoint_every
    if checkpoint_every > 0 and chunk_size > 0 and checkpoint_every % chunk_size:
        raise ValueError(
            f"checkpoint_every={checkpoint_every} must be a multiple of the "
            f"stream chunk cadence {chunk_size} — saves land on chunk "
            "boundaries"
        )
    if max_steps is not None:
        if (
            checkpoint_dir is None
            or checkpoint_every <= 0
            or max_steps < checkpoint_every
        ):
            raise ValueError(
                f"max_steps={max_steps} cannot make durable progress: "
                "saves land on checkpoint boundaries, so it needs a "
                "checkpoint_dir, checkpoint_every > 0 and max_steps >= "
                f"checkpoint_every (got checkpoint_every={checkpoint_every})"
            )
    sampler = sampler or model.default_sampler
    if shards is None or counts is None:
        shards, counts = partition_data(
            data, num_shards, only=model.shard_keys, pad=True
        )
    padded = is_padded(model, shards, counts, sampler)
    stream = ShardChainStream(
        key,
        model,
        num_shards,
        num_samples,
        sampler=sampler,
        warmup=warmup,
        burn_in=burn_in,
        step_size=step_size,
        sgld_batch=sgld_batch,
        sampler_options=sampler_options,
        shards=shards,
        counts=counts,
        use_counts=padded,
        mesh_shape=mesh_shape,
        check_hlo=check_hlo,
    )

    # -- fused hot path: nobody subscribes, nothing to persist ---------------
    # (the 0 < chunk < T guard keeps the classic one-chunk program — and its
    # established numerics — for cadence-less runs)
    if (
        checkpoint_dir is None
        and not on_chunk
        and max_steps is None
        and 0 < chunk < num_samples
    ):
        theta, accept_sum = stream.fused_sample(chunk)
        return StreamedSample(
            result=SampleResult(
                theta,
                accept_sum / jnp.maximum(num_samples, 1),
                counts,
                stream.backend.backend_id(FUSED),
                stream.backend.collectives_checked,
            ),
            t_done=num_samples,
            total=num_samples,
            resumed_from=0,
        )

    # -- restore or initialize ----------------------------------------------
    step = latest_step(checkpoint_dir) if checkpoint_dir is not None else None
    if step is not None:
        carry, meta = _restore_carry(
            checkpoint_dir, step, stream.setup_struct(), model.d, num_shards
        )
        # checkpoints restore as host arrays; the mesh backend re-commits
        # them to its devices (a no-op on the vmap backend)
        carry = stream.backend.put_carry(carry)
        if meta.get("spec_id") != spec_id or meta.get("T") != num_samples:
            raise ValueError(
                f"checkpoint at {checkpoint_dir} belongs to spec "
                f"{meta.get('spec_id')!r} (T={meta.get('T')}), not "
                f"{spec_id!r} (T={num_samples}) — refusing to resume"
            )
        t_done = int(meta["t_done"])
        # the bitwise guarantee rests on GLOBAL chunk boundaries; resuming an
        # unfinished run at a different cadence would replay the tail under a
        # different program split (a finished run has no tail to replay)
        if t_done < num_samples:
            if meta.get("checkpoint_every") != checkpoint_every:
                raise ValueError(
                    f"checkpoint at {checkpoint_dir} was written with "
                    f"checkpoint_every={meta.get('checkpoint_every')}; "
                    f"resuming mid-run with checkpoint_every="
                    f"{checkpoint_every} would shift chunk boundaries and "
                    "void the bitwise-resume guarantee — pass the original "
                    "cadence"
                )
            if meta.get("chunk", meta.get("checkpoint_every")) != chunk:
                raise ValueError(
                    f"checkpoint at {checkpoint_dir} streamed in chunks of "
                    f"{meta.get('chunk')}; resuming mid-run at cadence "
                    f"{chunk} would shift chunk boundaries and void the "
                    "bitwise-resume guarantee — pass the original cadence"
                )
        resumed_from = t_done
        # replay the restored prefix to subscribers at the original
        # boundaries so streaming-combiner state matches an uninterrupted run
        if on_chunk and t_done > 0:
            replay_chunk = chunk if chunk > 0 else num_samples
            zeros = jnp.zeros((num_shards,), jnp.float32)
            for r0 in range(0, t_done, replay_chunk):
                r1 = min(r0 + replay_chunk, t_done)
                ev = StreamChunk(
                    stream.backend.localize(carry["theta"][:, r0:r1]),
                    zeros, r0, r1, num_samples, carry, replayed=True,
                    landed_s=time.monotonic(),
                )
                for sub in on_chunk:
                    sub(ev)
    else:
        carry = stream.fresh_carry()
        t_done = 0
        resumed_from = 0

    # -- the loop: chunks stream, everyone else subscribes -------------------
    stop = (
        num_samples if max_steps is None else min(num_samples, t_done + max_steps)
    )
    if stop < num_samples and checkpoint_every > 0:
        # a budgeted session must end on a SAVE boundary, not merely a chunk
        # boundary — chunks past the last checkpoint would be computed and
        # then silently lost (the work is only as durable as its last save)
        stop = (stop // checkpoint_every) * checkpoint_every
    for ev in stream.chunks(carry, t_done, chunk, stop):
        carry, t_done = ev.carry, ev.t1
        for sub in on_chunk:
            sub(ev)
        at_boundary = (
            checkpoint_every > 0 and t_done % checkpoint_every == 0
        ) or t_done == num_samples
        if checkpoint_dir is not None and at_boundary:
            save(
                checkpoint_dir,
                t_done,
                carry,
                metadata={
                    "spec_id": spec_id,
                    "t_done": t_done,
                    "T": num_samples,
                    "checkpoint_every": checkpoint_every,
                    "chunk": chunk,
                },
                keep=2,
            )

    accept = carry["accept_sum"] / jnp.maximum(t_done, 1)
    backend = stream.backend.backend_id(
        RESUMABLE if checkpoint_dir is not None else CHUNKED
    )
    return StreamedSample(
        result=SampleResult(
            carry["theta"], accept, counts, backend,
            stream.backend.collectives_checked,
        ),
        t_done=t_done,
        total=num_samples,
        resumed_from=resumed_from,
    )


# ---------------------------------------------------------------------------
# fused combine-fold (the P₁ program of the fused streaming hot path)
# ---------------------------------------------------------------------------


class FusedFold(NamedTuple):
    """Artifact of :func:`fused_fold`.

    ``states``: final in-scan state per combiner (feed through the face's
    ``to_state`` before the host ``finalize``). ``est_draws``: stacked
    ``(n_boundaries, n_estimate, d)`` in-scan trajectory draws for the
    combiners whose face ships a scan ``estimate``. ``boundaries``: the
    global draw indices the fold estimated at (full chunks + ragged tail).
    """

    states: Dict[str, Any]
    est_draws: Dict[str, jnp.ndarray]
    boundaries: Tuple[int, ...]


def fused_fold(
    theta: jnp.ndarray,
    faces: Dict[str, Any],  # name -> ScanStreamingFace, insertion-ordered
    est_keys: Dict[str, jnp.ndarray],  # name -> (n_boundaries,) stacked keys
    n_estimate: int,
    chunk: int,
    options: Dict[str, Any],
) -> FusedFold:
    """Fold the gathered draws through every scan face in ONE jitted program.

    A single ``lax.scan`` walks the ``(M, chunk, d)`` slices of ``theta`` (a
    reshape of the device-resident array — no host hop per chunk), folds each
    combiner's ``update`` and takes its in-scan ``estimate`` at every
    boundary; the fold states are donated into the program. The per-boundary
    estimate keys arrive pre-stacked so the trajectory RNG stream is exactly
    the subscriber path's (``fold_in(k_name, t1)``).

    Compiled programs are cached per (names, chunking, shapes, options) —
    scan faces resolve from the immutable in-process registry, so the name
    tuple pins the face closures exactly (same justification as the sampling
    executable cache).
    """
    M, T, d = theta.shape
    names = tuple(faces)
    est_names = tuple(n for n in names if n in est_keys)
    n_full, tail = divmod(T, chunk)
    boundaries = tuple(chunk * (i + 1) for i in range(n_full)) + (
        (T,) if tail else ()
    )
    key = (
        names, est_names, int(chunk), T, M, d, int(n_estimate),
        _freeze_options(options),
    )
    prog = _FUSED_FOLD_CACHE.get(key)
    if prog is None:
        from repro.utils.options import filter_kwargs

        upd = {n: faces[n].update for n in names}
        est_fns = {
            n: functools.partial(
                faces[n].estimate, **filter_kwargs(faces[n].estimate, options)
            )
            for n in est_names
        }

        def run(th, states, eks):
            body = th[:, : n_full * chunk]
            xs = jnp.moveaxis(body.reshape(M, n_full, chunk, d), 1, 0)
            eks_body = {n: eks[n][:n_full] for n in est_names}

            def step(ss, inp):
                th_c, ek = inp
                ss = {n: upd[n](ss[n], th_c) for n in names}
                ests = {
                    n: est_fns[n](ek[n], ss[n], n_estimate) for n in est_names
                }
                return ss, ests

            states, ests = jax.lax.scan(step, states, (xs, eks_body))
            if tail:
                th_t = th[:, n_full * chunk :]
                states = {n: upd[n](states[n], th_t) for n in names}
                ests = {
                    n: jnp.concatenate(
                        [ests[n], est_fns[n](eks[n][n_full], states[n], n_estimate)[None]]
                    )
                    for n in est_names
                }
            return states, ests

        prog = _FUSED_FOLD_CACHE[key] = jax.jit(run, donate_argnums=(1,))
    init_states = {n: faces[n].init(M, d) for n in names}
    states, ests = prog(theta, init_states, dict(est_keys))
    return FusedFold(states=states, est_draws=ests, boundaries=boundaries)
