"""Unified model configuration covering all 10 assigned architectures.

One dataclass describes dense GQA transformers, MoE (incl. MLA), Mamba-2 SSD,
hybrid (Jamba) interleaves, encoder–decoder (Whisper) and VLM-stub (LLaVA)
backbones. ``src/repro/configs/<arch>.py`` instantiate it with the exact
assigned numbers; ``reduced()`` shrinks any config for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 1
    d_ff_expert: int = 0
    num_shared_experts: int = 0  # DeepSeek shared experts (always-on)
    # which decoder layers are MoE: every `every`-th layer, skipping the
    # first `first_dense` layers (DeepSeek-V2: first layer dense).
    every: int = 1
    first_dense: int = 0
    group_size: int = 256  # GShard dispatch group size (perf-tunable)
    capacity_factor: float = 1.25
    router_normalize_topk: bool = True  # renormalize top-k weights to sum 1


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 0  # compressed KV latent dim (DeepSeek-V2: 512)
    q_lora_rank: int = 0  # 0 = full-rank q projection
    rope_head_dim: int = 64  # decoupled RoPE dims per head
    nope_head_dim: int = 128  # non-RoPE q/k dims per head
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256  # SSD chunk length
    head_block: int = 0  # >0: lax.map the SSD core over head blocks (memory knob)
    # dt initialization bounds (softplus-space), Mamba-2 defaults
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Jamba-style interleave: one period = ``period`` layers with attention at
    ``attn_index`` and Mamba elsewhere; MoE replaces the MLP on layers where
    ``layer_in_period % moe_every == moe_offset``."""

    period: int = 8
    attn_index: int = 4
    moe_every: int = 2
    moe_offset: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # encoder–decoder (whisper): encoder layer count + fixed frame count;
    # the conv frontend is a STUB — input_specs() supplies frame embeddings.
    num_encoder_layers: int = 0
    encoder_seq: int = 1500
    # VLM stub: number of image patch tokens prepended to the text sequence.
    num_image_tokens: int = 0
    # numerics / performance knobs
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"  # AdamW μ/ν storage (236B/398B: bfloat16)
    # decode-time MoE: "dispatch" = capacity-based EP (weights stay put,
    # activations move — §Perf iteration 1); "gather" = per-token weight
    # gather (dropless but moves expert matrices across shards — baseline).
    moe_decode_impl: str = "dispatch"
    remat: Literal["none", "full", "dots"] = "full"
    attn_impl: Literal["einsum", "chunked"] = "chunked"
    attn_chunk: int = 1024  # KV block for chunked (flash-style) attention
    fsdp: bool = False  # additionally shard params over the data axis (ZeRO-3)
    seq_parallel: bool = False  # Megatron-SP: shard residual S axis over 'model'
    scan_layers: bool = True
    max_seq_len: int = 32_768  # serving cache bound (long_500k overrides)
    subquadratic: bool = False  # True for SSM/hybrid: long_500k cell applies

    # ---------------------------------------------------------------- sizes
    def moe_layer_indices(self) -> Tuple[int, ...]:
        if self.moe is None:
            return ()
        if self.hybrid is not None:
            idx = []
            for i in range(self.num_layers):
                if i % self.hybrid.moe_every == self.hybrid.moe_offset:
                    idx.append(i)
            return tuple(idx)
        m = self.moe
        return tuple(
            i
            for i in range(self.num_layers)
            if i >= m.first_dense and (i - m.first_dense) % m.every == 0
        )

    def attn_layer_indices(self) -> Tuple[int, ...]:
        if self.family == "ssm":
            return ()
        if self.hybrid is not None:
            return tuple(
                i
                for i in range(self.num_layers)
                if i % self.hybrid.period == self.hybrid.attn_index
            )
        return tuple(range(self.num_layers))

    def param_count(self) -> int:
        """Total parameter count (analytic, matches init shapes)."""
        return _count_params(self, active_only=False)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k + shared)."""
        return _count_params(self, active_only=True)


def _attn_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    if cfg.mla is not None:
        m = cfg.mla
        h = cfg.num_heads
        q_in = (
            d * m.q_lora_rank + m.q_lora_rank * h * (m.nope_head_dim + m.rope_head_dim)
            if m.q_lora_rank
            else d * h * (m.nope_head_dim + m.rope_head_dim)
        )
        kv_down = d * (m.kv_lora_rank + m.rope_head_dim)
        kv_up = m.kv_lora_rank * h * (m.nope_head_dim + m.v_head_dim)
        out = h * m.v_head_dim * d
        # RMSNorms on the compressed latents (DeepSeek-V2 places one after
        # each down-projection)
        norms = (m.q_lora_rank if m.q_lora_rank else 0) + m.kv_lora_rank
        return q_in + kv_down + kv_up + out + norms
    hd = cfg.head_dim
    q = d * cfg.num_heads * hd
    kv = 2 * d * cfg.num_kv_heads * hd
    o = cfg.num_heads * hd * d
    bias = (cfg.num_heads * hd + 2 * cfg.num_kv_heads * hd) if cfg.qkv_bias else 0
    return q + kv + o + bias


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    # SwiGLU: gate + up + down
    return 3 * cfg.d_model * d_ff


def _ssm_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.d_state
    in_proj = d * (2 * d_inner + 2 * s.d_state + n_heads)  # split z/x/B/C/dt
    conv = conv_dim * s.d_conv + conv_dim  # per-component kernels + biases
    extras = 3 * n_heads  # A_log, dt_bias, D
    norm = d_inner
    out_proj = d_inner * d
    return in_proj + conv + extras + norm + out_proj


def _count_params(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    total = cfg.vocab_size * d  # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d  # lm head
    total += d  # final norm
    moe_layers = set(cfg.moe_layer_indices())
    attn_layers = set(cfg.attn_layer_indices())
    for i in range(cfg.num_layers):
        total += d  # ln1
        has_ffn = (i in moe_layers) or (
            cfg.d_ff > 0 and not (cfg.ssm is not None and cfg.hybrid is None)
        )
        if has_ffn:
            total += d  # ln2 (pure-Mamba blocks have no FFN, hence no ln2)
        if i in attn_layers:
            total += _attn_params(cfg)
        elif cfg.ssm is not None:
            total += _ssm_params(cfg)
        if i in moe_layers:
            m = cfg.moe
            total += d * m.num_experts  # router
            n_routed = m.top_k if active_only else m.num_experts
            total += n_routed * _mlp_params(cfg, m.d_ff_expert)
            total += m.num_shared_experts * _mlp_params(cfg, m.d_ff_expert)
        elif cfg.family != "ssm" and cfg.d_ff > 0:
            total += _mlp_params(cfg, cfg.d_ff)
    if cfg.num_encoder_layers:
        for _ in range(cfg.num_encoder_layers):
            total += 2 * d + _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff)
        total += d  # enc_norm
        # decoder cross-attention (one per decoder layer)
        total += cfg.num_layers * (_attn_params(cfg) + cfg.d_model)
    if cfg.num_image_tokens:
        total += 1024 * d  # img_proj from the stub vision-tower width
    return int(total)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a config for CPU smoke tests, preserving the family structure."""
    changes: dict = dict(
        num_layers=min(cfg.num_layers, 4 if cfg.hybrid is None else cfg.hybrid.period),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 16),
        num_image_tokens=min(cfg.num_image_tokens, 8),
        max_seq_len=128,
        remat="none",
        dtype="float32",
        param_dtype="float32",
        fsdp=False,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            group_size=16,
            capacity_factor=4.0,  # dropless at smoke scale (consistency tests)
        )
    if cfg.mla is not None:
        changes["mla"] = dataclasses.replace(
            cfg.mla,
            kv_lora_rank=32,
            q_lora_rank=(48 if cfg.mla.q_lora_rank else 0),
            rope_head_dim=16,
            nope_head_dim=32,
            v_head_dim=32,
        )
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=32, chunk=16
        )
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
