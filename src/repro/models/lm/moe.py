"""Mixture-of-Experts FFN — GShard/Switch-style grouped one-hot dispatch.

TPU-native design notes (vs a CUDA grouped-GEMM port):

- Tokens are processed in *groups* of ``cfg.moe.group_size``; dispatch/combine
  are one-hot einsums per group, which GSPMD partitions cleanly (experts on
  the ``model`` axis → the dispatch einsum lowers to an all-to-all). This is
  the canonical TPU MoE (GShard, Switch, GLaM) rather than sort-based CUDA
  dispatch.
- Dispatch-einsum overhead is 2·S·E·C_g·d FLOPs with C_g = cf·k·S_g/E, i.e.
  a fraction  cf·S_g/(3·d_ff)  of the expert FLOPs — group_size is chosen per
  arch to keep it ≤~10% and is a §Perf hillclimb knob.
- Over-capacity tokens are *dropped* (their combine weight is 0 and the
  residual path carries them), matching Switch semantics.

Routing: softmax → top-k (renormalized when cfg.moe.router_normalize_topk),
plus optional always-on shared experts (DeepSeek-V2). The load-balancing aux
loss (Switch §2.2) is returned for the training loss.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm.config import ModelConfig
from repro.models.lm.layers import init_mlp, mlp

PyTree = Dict[str, jnp.ndarray]


def init_moe(key: jax.Array, cfg: ModelConfig) -> PyTree:
    m = cfg.moe
    d = cfg.d_model
    k_router, k_experts, k_shared = jax.random.split(key, 3)
    ke = jax.random.split(k_experts, 3)
    scale = (1.0 / d) ** 0.5
    p: PyTree = {
        "router": (jax.random.normal(k_router, (d, m.num_experts), jnp.float32) * scale).astype(
            jnp.dtype(cfg.param_dtype)
        ),
        "experts": {
            "w_gate": (
                jax.random.normal(ke[0], (m.num_experts, d, m.d_ff_expert), jnp.float32) * scale
            ).astype(jnp.dtype(cfg.param_dtype)),
            "w_up": (
                jax.random.normal(ke[1], (m.num_experts, d, m.d_ff_expert), jnp.float32) * scale
            ).astype(jnp.dtype(cfg.param_dtype)),
            "w_down": (
                jax.random.normal(ke[2], (m.num_experts, m.d_ff_expert, d), jnp.float32)
                * (1.0 / m.d_ff_expert) ** 0.5
            ).astype(jnp.dtype(cfg.param_dtype)),
        },
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(
            k_shared, d, m.num_shared_experts * m.d_ff_expert, dtype=cfg.param_dtype
        )
    return p


def _capacity(cfg: ModelConfig, group: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * m.top_k * group / m.num_experts) + 1
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4 (lane-friendly)


def moe_forward(
    p: PyTree, cfg: ModelConfig, x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) → (y, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    n = tokens.shape[0]
    g = min(m.group_size, n)
    pad = (-n) % g
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    ng = tokens.shape[0] // g
    tokens = tokens.reshape(ng, g, d)
    cap = _capacity(cfg, g)
    e = m.num_experts

    logits = (tokens @ p["router"].astype(tokens.dtype)).astype(jnp.float32)  # (ng,g,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, m.top_k)  # (ng, g, k)
    if m.router_normalize_topk:
        top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    # Switch load-balancing aux loss: E·Σ_e f_e·P_e over all groups.
    me = jnp.mean(probs, axis=(0, 1))  # mean router prob per expert
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
        / (ng * g),
        axis=0,
    )
    aux_loss = e * jnp.sum(me * ce)

    dispatch = jnp.zeros((ng, g, e, cap), jnp.float32)
    combine = jnp.zeros((ng, g, e, cap), jnp.float32)
    counts = jnp.zeros((ng, e), jnp.float32)
    for slot in range(m.top_k):
        onehot = jax.nn.one_hot(top_idx[..., slot], e, dtype=jnp.float32)  # (ng,g,E)
        pos = jnp.cumsum(onehot, axis=1) - 1.0 + counts[:, None, :]
        keep = onehot * (pos < cap)
        counts = counts + keep.sum(axis=1)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)  # (ng,g,E,C)
        sel = keep[..., None] * pos_oh
        dispatch = dispatch + sel
        combine = combine + top_vals[..., slot][..., None, None] * sel

    dx = dispatch.astype(tokens.dtype)
    expert_in = jnp.einsum("gsec,gsd->egcd", dx, tokens)  # (E, ng, C, d)
    w_gate = p["experts"]["w_gate"].astype(tokens.dtype)
    w_up = p["experts"]["w_up"].astype(tokens.dtype)
    w_down = p["experts"]["w_down"].astype(tokens.dtype)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", expert_in, w_gate)) * jnp.einsum(
        "egcd,edf->egcf", expert_in, w_up
    )
    expert_out = jnp.einsum("egcf,efd->egcd", h, w_down)
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(tokens.dtype), expert_out)

    y = y.reshape(-1, d)
    if pad:
        y = y[:n]
    y = y.reshape(b, s, d)
    if "shared" in p:
        y = y + mlp(p["shared"], x)
    return y, aux_loss.astype(jnp.float32)


def moe_forward_gather(
    p: PyTree, cfg: ModelConfig, x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dropless gather-based MoE for DECODE (few tokens): each token gathers
    its top-k experts' weights directly — no capacity, no drops, bit-exact
    routing. This is the serving-time semantics (capacity dropping is a
    *training* batch effect); decode is memory-bound so the per-token weight
    gather is the natural cost model.
    """
    m = cfg.moe
    b, s, d = x.shape
    tokens = x.reshape(-1, d)  # (n, d)
    logits = (tokens @ p["router"].astype(tokens.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, m.top_k)  # (n, k)
    if m.router_normalize_topk:
        top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    w_gate = p["experts"]["w_gate"].astype(tokens.dtype)  # (E, d, f)
    w_up = p["experts"]["w_up"].astype(tokens.dtype)
    w_down = p["experts"]["w_down"].astype(tokens.dtype)

    def per_slot(slot):
        idx = top_idx[:, slot]  # (n,)
        g = jnp.take(w_gate, idx, axis=0)  # (n, d, f)
        u = jnp.take(w_up, idx, axis=0)
        dn = jnp.take(w_down, idx, axis=0)
        h = jax.nn.silu(jnp.einsum("nd,ndf->nf", tokens, g)) * jnp.einsum(
            "nd,ndf->nf", tokens, u
        )
        return jnp.einsum("nf,nfd->nd", h, dn) * top_vals[:, slot][:, None].astype(
            tokens.dtype
        )

    y = sum(per_slot(slot) for slot in range(m.top_k))
    y = y.reshape(b, s, d)
    if "shared" in p:
        y = y + mlp(p["shared"], x)
    return y, jnp.zeros((), jnp.float32)
