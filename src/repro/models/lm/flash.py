"""Flash attention in pure JAX (custom_vjp) — the memory-term workhorse.

XLA does not fuse softmax(QKᵀ)V, so einsum attention materializes the (S,S)
score matrix: at prefill_32k that is O(terabytes)/device — the cell would not
fit at all. This module implements the FlashAttention-2 algorithm with
``lax.scan`` tiling:

- forward: online-softmax accumulation over KV tiles; saves only (out, lse);
- backward: recomputes score tiles from (q,k,v,out,lse) — two tiled passes
  (dq over KV tiles; dk/dv over Q tiles) so *no* O(S²) residual is ever
  stored (a plain scan-based forward would stack per-step softmax residuals
  and reintroduce the S² memory in the backward).

GQA layout: q (B,S,K,G,hd), k/v (B,T,K,hd). MLA reuses this by concatenating
nope⊕rope into one head dim. Numerics: tile scores/stats in fp32, matmul
inputs in the model dtype. This is also the blueprint the Pallas TPU kernel
would follow (q_chunk × kv_chunk ↦ VMEM BlockSpecs); on this rig the jnp
form is what the dry-run lowers.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def _tile_scores(qi, kj, scale):
    # qi (b,qc,kh,g,hd), kj (b,tc,kh,hd) -> (b,kh,g,qc,tc) fp32
    return jnp.einsum("bqkgd,btkd->bkgqt", qi, kj).astype(jnp.float32) * scale


def _mask(scores, q_pos, kv_pos, kv_valid, causal):
    m = kv_valid[None, :]
    if causal:
        m = m & (q_pos[:, None] >= kv_pos[None, :])
    return jnp.where(m[None, None, None], scores, _NEG_INF)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(
    q: jnp.ndarray,  # (B, S, K, G, hd)
    k: jnp.ndarray,  # (B, T, K, hd)
    v: jnp.ndarray,  # (B, T, K, hd)
    causal: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 512,
) -> jnp.ndarray:
    out, _ = _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk)
    return out


def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk):
    b, s, kh, g, hd = q.shape
    t = k.shape[1]
    hd_v = v.shape[-1]
    scale = hd ** -0.5
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    n_q, n_kv = -(-s // q_chunk), -(-t // kv_chunk)
    sp, tp = n_q * q_chunk, n_kv * kv_chunk
    qp = _pad_to(q, sp, 1).reshape(b, n_q, q_chunk, kh, g, hd)
    kp = _pad_to(k, tp, 1).reshape(b, n_kv, kv_chunk, kh, k.shape[-1])
    vp = _pad_to(v, tp, 1).reshape(b, n_kv, kv_chunk, kh, v.shape[-1])
    kv_pos = jnp.arange(tp).reshape(n_kv, kv_chunk)
    kv_valid = kv_pos < t
    q_positions = jnp.arange(sp).reshape(n_q, q_chunk)

    def q_block(args):
        qi, q_pos = args  # (b,qc,kh,g,hd), (qc,)

        def kv_step(carry, inputs):
            acc, m, denom = carry
            kj, vj, pos_j, valid_j = inputs
            scores = _mask(_tile_scores(qi, kj, scale), q_pos, pos_j, valid_j, causal)
            m_new = jnp.maximum(m, scores.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(scores - m_new[..., None])
            denom = denom * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(qi.dtype), vj
            ).astype(jnp.float32)
            return (acc, m_new, denom), None

        acc0 = jnp.zeros((b, kh, g, q_chunk, hd_v), jnp.float32)
        m0 = jnp.full((b, kh, g, q_chunk), _NEG_INF, jnp.float32)
        d0 = jnp.zeros((b, kh, g, q_chunk), jnp.float32)
        (acc, m, denom), _ = jax.lax.scan(
            kv_step,
            (acc0, m0, d0),
            (kp.swapaxes(0, 1), vp.swapaxes(0, 1), kv_pos, kv_valid),
        )
        denom = jnp.maximum(denom, 1e-30)
        out = (acc / denom[..., None]).astype(q.dtype)
        lse = m + jnp.log(denom)
        return out, lse  # (b,kh,g,qc,hd), (b,kh,g,qc)

    outs, lses = jax.lax.map(q_block, (qp.swapaxes(0, 1), q_positions))
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sp, kh, g, hd_v)[:, :s]
    lse = lses.transpose(1, 0, 4, 2, 3).reshape(b, sp, kh, g)[:, :s]
    return out, lse


def _flash_fwd(q, k, v, causal, q_chunk, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_chunk, kv_chunk, res, dout):
    q, k, v, out, lse = res
    b, s, kh, g, hd = q.shape
    hd_v = v.shape[-1]  # MLA: v head dim (128) ≠ qk head dim (nope⊕rope = 192)
    t = k.shape[1]
    scale = hd ** -0.5
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    n_q, n_kv = -(-s // q_chunk), -(-t // kv_chunk)
    sp, tp = n_q * q_chunk, n_kv * kv_chunk

    qp = _pad_to(q, sp, 1).reshape(b, n_q, q_chunk, kh, g, hd)
    dop = _pad_to(dout, sp, 1).reshape(b, n_q, q_chunk, kh, g, hd_v)
    # lse padding must keep exp(scores − lse) = 0 on padded rows
    lsep = _pad_to(lse, sp, 1).reshape(b, n_q, q_chunk, kh, g)
    # D_i = rowsum(dout ∘ out)  (b, s, kh, g)
    dsum = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    dsump = _pad_to(dsum, sp, 1).reshape(b, n_q, q_chunk, kh, g)
    kp = _pad_to(k, tp, 1).reshape(b, n_kv, kv_chunk, kh, k.shape[-1])
    vp = _pad_to(v, tp, 1).reshape(b, n_kv, kv_chunk, kh, v.shape[-1])
    kv_pos = jnp.arange(tp).reshape(n_kv, kv_chunk)
    kv_valid = kv_pos < t
    q_positions = jnp.arange(sp).reshape(n_q, q_chunk)

    def p_tile(qi, kj, q_pos, pos_j, valid_j, lse_i):
        scores = _mask(_tile_scores(qi, kj, scale), q_pos, pos_j, valid_j, causal)
        # p = exp(scores − lse); padded q rows have lse=0, scores=-inf ⇒ p=0
        return jnp.exp(scores - lse_i.transpose(0, 2, 3, 1)[..., None])

    # ---- pass 1: dq over kv tiles ----------------------------------------
    def dq_block(args):
        qi, doi, lse_i, dsum_i, q_pos = args

        def kv_step(dq_acc, inputs):
            kj, vj, pos_j, valid_j = inputs
            p = p_tile(qi, kj, q_pos, pos_j, valid_j, lse_i)  # (b,kh,g,qc,tc) f32
            dp = jnp.einsum("bqkgd,btkd->bkgqt", doi, vj).astype(jnp.float32)
            ds = p * (dp - dsum_i.transpose(0, 2, 3, 1)[..., None])  # (b,kh,g,qc,tc)
            dq_acc = dq_acc + jnp.einsum(
                "bkgqt,btkd->bqkgd", ds.astype(qi.dtype), kj
            ).astype(jnp.float32)
            return dq_acc, None

        dq0 = jnp.zeros((b, q_chunk, kh, g, hd), jnp.float32)
        dq_acc, _ = jax.lax.scan(
            kv_step, dq0, (kp.swapaxes(0, 1), vp.swapaxes(0, 1), kv_pos, kv_valid)
        )
        return (dq_acc * scale).astype(q.dtype)

    dqs = jax.lax.map(
        dq_block,
        (qp.swapaxes(0, 1), dop.swapaxes(0, 1), lsep.swapaxes(0, 1),
         dsump.swapaxes(0, 1), q_positions),
    )  # (n_q, b, qc, kh, g, hd)
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sp, kh, g, hd)[:, :s]

    # ---- pass 2: dk/dv over q tiles ---------------------------------------
    def dkv_block(args):
        kj, vj, pos_j, valid_j = args

        def q_step(carry, inputs):
            dk_acc, dv_acc = carry
            qi, doi, lse_i, dsum_i, q_pos = inputs
            p = p_tile(qi, kj, q_pos, pos_j, valid_j, lse_i)
            dv_acc = dv_acc + jnp.einsum(
                "bkgqt,bqkgd->btkd", p.astype(q.dtype), doi
            ).astype(jnp.float32)
            dp = jnp.einsum("bqkgd,btkd->bkgqt", doi, vj).astype(jnp.float32)
            ds = p * (dp - dsum_i.transpose(0, 2, 3, 1)[..., None])
            dk_acc = dk_acc + jnp.einsum(
                "bkgqt,bqkgd->btkd", ds.astype(q.dtype), qi
            ).astype(jnp.float32)
            return (dk_acc, dv_acc), None

        dk0 = jnp.zeros((b, kv_chunk, kh, k.shape[-1]), jnp.float32)
        dv0 = jnp.zeros((b, kv_chunk, kh, v.shape[-1]), jnp.float32)
        (dk_acc, dv_acc), _ = jax.lax.scan(
            q_step,
            (dk0, dv0),
            (qp.swapaxes(0, 1), dop.swapaxes(0, 1), lsep.swapaxes(0, 1),
             dsump.swapaxes(0, 1), q_positions),
        )
        return (dk_acc * scale).astype(k.dtype), dv_acc.astype(v.dtype)

    dks, dvs = jax.lax.map(
        dkv_block, (kp.swapaxes(0, 1), vp.swapaxes(0, 1), kv_pos, kv_valid)
    )  # (n_kv, b, tc, kh, hd)
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, tp, kh, k.shape[-1])[:, :t]
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, tp, kh, v.shape[-1])[:, :t]
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)
