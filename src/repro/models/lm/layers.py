"""Primitive layers (functional, pytree params — no framework dependency).

Conventions
-----------
- ``init_*`` return nested dicts of arrays; ``*_apply`` are pure functions.
- Weight names follow a fixed vocabulary so the sharding policy
  (:mod:`repro.distributed.sharding`) can pattern-match:
  ``embed``, ``w_q/w_k/w_v/w_o``, ``w_gate/w_up/w_down``, ``experts_*``,
  ``router``, ``lm_head``, ``scale`` ...
- All matmuls compute in ``cfg.dtype`` (bf16 on TPU) with fp32 softmax/norm
  accumulations where it matters.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

PyTree = Dict[str, jnp.ndarray]


def _dtype(name: str):
    return jnp.dtype(name)


def init_linear(
    key: jax.Array,
    d_in: int,
    d_out: int,
    *,
    dtype: str = "bfloat16",
    bias: bool = False,
    scale: Optional[float] = None,
) -> PyTree:
    scale = (1.0 / d_in) ** 0.5 if scale is None else scale
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(_dtype(dtype))
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), _dtype(dtype))
    return p


def linear(p: PyTree, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_rmsnorm(d: int, dtype: str = "bfloat16") -> PyTree:
    return {"scale": jnp.ones((d,), _dtype(dtype))}


def rmsnorm(p: PyTree, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype: str = "bfloat16") -> PyTree:
    return {"scale": jnp.ones((d,), _dtype(dtype)), "bias": jnp.zeros((d,), _dtype(dtype))}


def layernorm(p: PyTree, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10_000.0
) -> jnp.ndarray:
    """Rotate pairs. x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., 0::2], x32[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key: jax.Array, d: int, d_ff: int, dtype: str = "bfloat16") -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_linear(k1, d, d_ff, dtype=dtype)["w"],
        "w_up": init_linear(k2, d, d_ff, dtype=dtype)["w"],
        "w_down": init_linear(k3, d_ff, d, dtype=dtype)["w"],
    }


def mlp(p: PyTree, x: jnp.ndarray) -> jnp.ndarray:
    gate = jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
    up = x @ p["w_up"].astype(x.dtype)
    return (gate * up) @ p["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def init_embed(key: jax.Array, vocab: int, d: int, dtype: str = "bfloat16") -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(_dtype(dtype))


def embed_lookup(table: jnp.ndarray, tokens: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    # One-hot-free gather; GSPMD shards the table on the vocab axis and turns
    # this into a masked gather + all-reduce.
    return jnp.take(table, tokens, axis=0).astype(compute_dtype)
