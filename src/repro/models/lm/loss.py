"""Token cross-entropy (+ z-loss, MoE aux) — sharded-vocab friendly.

The log-softmax is written as explicit max/logsumexp reductions over the vocab
axis so that when logits are sharded on the ``model`` axis GSPMD lowers them
into partial reductions + small all-reduces instead of an all-gather of the
(B, S, V) tensor.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def cross_entropy(
    logits: jnp.ndarray,  # (B, S, V)
    labels: jnp.ndarray,  # (B, S) int32
    *,
    z_loss_coeff: float = 0.0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean next-token CE over all positions. Returns (loss, z_loss)."""
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - jax.lax.stop_gradient(m)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    label_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - label_logit
    loss = jnp.mean(nll)
    zl = jnp.mean(lse**2) * z_loss_coeff if z_loss_coeff else jnp.zeros(())
    return loss, zl


def shift_labels(tokens: jnp.ndarray, pad_id: int = 0) -> jnp.ndarray:
    """Next-token labels: labels[t] = tokens[t+1]; final position pads."""
    return jnp.concatenate(
        [tokens[:, 1:], jnp.full_like(tokens[:, :1], pad_id)], axis=1
    )
