"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD forward (train/prefill): within-chunk quadratic "attention-like"
term + across-chunk linear recurrence carried by ``lax.scan``. All decays are
expressed as ``exp(cumsum(log a))`` *differences* (≤ 0 ⇒ every exp ≤ 1 —
numerically safe in bf16).

Single-token decode keeps the recurrent state h (B, H, hd, N) and a causal-conv
ring window — O(1) per token, which is why the ``long_500k`` cell runs on this
family (DESIGN.md §4).

TPU adaptations (vs the fused CUDA kernel):
- The chunk-quadratic term is an MXU-shaped einsum (Q×Q tiles, Q a multiple of
  128) and the inter-chunk recurrence is a scan over chunk states — the
  natural VMEM-resident decomposition.
- Projections are SPLIT per component (z/x/B/C/dt + per-component causal conv)
  instead of Mamba's fused ``in_proj``: the concatenated output dim is not
  divisible by the model axis (Jamba: 33048 ∤ 16) and mixes tensor-parallel
  (z, x → d_inner, i.e. SSM heads) with replicated (B, C, dt) quantities.
  Split weights give clean head-sharded TP with zero collectives inside the
  SSD core (B/C are head-shared and replicated).
- ``cfg.ssm.head_block`` runs the SSD core in head blocks under ``lax.map`` —
  bounds the (B,L,Q,Q,H_blk) decay tensor (Jamba: 256 heads unblocked would be
  ~17 GB/device at train_4k).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm.config import ModelConfig
from repro.models.lm.layers import init_linear, rmsnorm

PyTree = Dict[str, jnp.ndarray]


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def init_mamba2(key: jax.Array, cfg: ModelConfig) -> PyTree:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads = _dims(cfg)
    keys = jax.random.split(key, 8)
    pd = cfg.param_dtype

    def lin(k, i, o):
        return init_linear(k, i, o, dtype=pd)["w"]

    def conv_w(k, ch):
        return (
            jax.random.normal(k, (s.d_conv, ch), jnp.float32) * (1.0 / s.d_conv) ** 0.5
        ).astype(jnp.dtype(pd))

    p: PyTree = {
        "w_z": lin(keys[0], d, d_inner),
        "w_x": lin(keys[1], d, d_inner),
        "w_B": lin(keys[2], d, s.d_state),
        "w_C": lin(keys[3], d, s.d_state),
        "w_dt": lin(keys[4], d, n_heads),
        "conv_x": conv_w(keys[5], d_inner),
        "conv_B": conv_w(keys[6], s.d_state),
        "conv_C": conv_w(keys[7], s.d_state),
        "conv_bias_x": jnp.zeros((d_inner,), jnp.dtype(pd)),
        "conv_bias_B": jnp.zeros((s.d_state,), jnp.dtype(pd)),
        "conv_bias_C": jnp.zeros((s.d_state,), jnp.dtype(pd)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "dt_bias": jnp.log(
            jnp.exp(
                jnp.exp(
                    jax.random.uniform(jax.random.fold_in(key, 99), (n_heads,), jnp.float32)
                    * (jnp.log(s.dt_max) - jnp.log(s.dt_min))
                    + jnp.log(s.dt_min)
                )
            )
            - 1.0
            + 1e-6
        ),  # softplus^{-1}(dt_init)
        "D": jnp.ones((n_heads,), jnp.float32),
        "norm": jnp.ones((d_inner,), jnp.dtype(pd)),
        "w_out": lin(jax.random.fold_in(key, 100), d_inner, d),
    }
    return p


def _causal_conv(conv_w, conv_b, u: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along axis 1. u: (B, S, C); kernel (K, C)."""
    k = conv_w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(k):  # k = 4 — unrolled taps beat a conv op at this size
        out = out + pad[:, i : i + u.shape[1], :] * conv_w[i].astype(u.dtype)
    return out + conv_b.astype(u.dtype)


def _project(p: PyTree, cfg: ModelConfig, x: jnp.ndarray, *, conv: bool = True):
    """x (B,S,d) → z, xs, B, C (post-conv, silu), dt (fp32 softplus)."""
    z = x @ p["w_z"].astype(x.dtype)
    xs = x @ p["w_x"].astype(x.dtype)
    b_ = x @ p["w_B"].astype(x.dtype)
    c_ = x @ p["w_C"].astype(x.dtype)
    dt = x @ p["w_dt"].astype(x.dtype)
    if conv:
        xs = jax.nn.silu(_causal_conv(p["conv_x"], p["conv_bias_x"], xs))
        b_ = jax.nn.silu(_causal_conv(p["conv_B"], p["conv_bias_B"], b_))
        c_ = jax.nn.silu(_causal_conv(p["conv_C"], p["conv_bias_C"], c_))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    return z, xs, b_, c_, dt


def _ssd_core(
    xh: jnp.ndarray,  # (B, L, Q, H, hd)
    bh: jnp.ndarray,  # (B, L, Q, N)
    ch: jnp.ndarray,  # (B, L, Q, N)
    dtc: jnp.ndarray,  # (B, L, Q, H) fp32
    cum: jnp.ndarray,  # (B, L, Q, H) fp32 inclusive cumulative log decay
    out_dtype,
) -> jnp.ndarray:
    b, L, q, h, hd = xh.shape
    # ---- intra-chunk (quadratic within Q) --------------------------------
    cb = jnp.einsum("blqn,blpn->blqp", ch.astype(jnp.float32), bh.astype(jnp.float32))
    # decay(i,j) = exp(cum_i − cum_j) for i ≥ j (diag includes a_i ... a_{j+1}).
    # exp() is evaluated in f32 (cum differences span many decades) but the
    # RESULT lies in [0,1] — safe to carry at bf16. Folding mask→exp→scale
    # into one expression leaves a single (B,L,Q,Q,H) materialization in the
    # activation dtype instead of several f32 ones (≈4× HBM-traffic cut on
    # the dominant SSD term; EXPERIMENTS.md §Perf jamba iteration 1).
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,L,Q,Q,H) f32
    mask = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])[None, None, :, :, None]
    decay = jnp.exp(jnp.where(mask, dec, -jnp.inf))
    att = (
        cb[..., None] * decay * dtc[:, :, None, :, :]
    ).astype(out_dtype)  # (B,L,Q,Q,H) bf16
    y_intra = jnp.einsum("blqph,blphd->blqhd", att, xh)

    # ---- inter-chunk recurrence ------------------------------------------
    # chunk summary: Σ_j exp(cum_Q − cum_j)·dt_j·B_j ⊗ x_j ; decay_chunk = exp(cum_Q)
    chunk_dec = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,L,Q,H)
    summary = jnp.einsum(
        "blqh,blqn,blqhd->blhdn",
        (chunk_dec * dtc).astype(jnp.float32),
        bh.astype(jnp.float32),
        xh.astype(jnp.float32),
    )  # (B,L,H,hd,N)
    total_dec = jnp.exp(cum[:, :, -1, :])  # (B,L,H)

    def chunk_scan(hstate, inp):
        summ, tdec = inp  # (B,H,hd,N), (B,H)
        h_out = hstate  # state entering this chunk
        h_new = hstate * tdec[..., None, None] + summ
        return h_new, h_out

    ds = bh.shape[-1]
    h0 = jnp.zeros((b, h, hd, ds), jnp.float32)
    _, h_states = jax.lax.scan(
        chunk_scan, h0, (summary.swapaxes(0, 1), total_dec.swapaxes(0, 1))
    )  # (L,B,H,hd,N) state at chunk start
    h_states = h_states.swapaxes(0, 1)  # (B,L,H,hd,N)
    y_inter = jnp.einsum(
        "blqh,blqn,blhdn->blqhd", jnp.exp(cum), ch.astype(jnp.float32), h_states
    ).astype(out_dtype)
    return y_intra + y_inter  # (B,L,Q,H,hd)


def mamba2_forward(p: PyTree, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Chunked SSD. x: (B, S, d) → (B, S, d). S must divide by cfg.ssm.chunk."""
    s_cfg = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    hd, ds, q = s_cfg.head_dim, s_cfg.d_state, s_cfg.chunk
    b, S, _ = x.shape
    q = min(q, S)
    assert S % q == 0, f"seq {S} not divisible by ssd chunk {q}"
    L = S // q

    z, xs, b_, c_, dt = _project(p, cfg, x)
    a_log = -jnp.exp(p["A_log"]) * dt  # log a_t  (B,S,H), ≤ 0

    xh = xs.reshape(b, L, q, n_heads, hd)
    bh = b_.reshape(b, L, q, ds)
    ch = c_.reshape(b, L, q, ds)
    dtc = dt.reshape(b, L, q, n_heads)
    cum = jnp.cumsum(a_log.reshape(b, L, q, n_heads), axis=2)

    hb = s_cfg.head_block
    if hb and hb < n_heads and n_heads % hb == 0:
        nb = n_heads // hb
        xh_b = xh.reshape(b, L, q, nb, hb, hd).transpose(3, 0, 1, 2, 4, 5)
        dtc_b = dtc.reshape(b, L, q, nb, hb).transpose(3, 0, 1, 2, 4)
        cum_b = cum.reshape(b, L, q, nb, hb).transpose(3, 0, 1, 2, 4)
        y_b = jax.lax.map(
            lambda args: _ssd_core(args[0], bh, ch, args[1], args[2], x.dtype),
            (xh_b, dtc_b, cum_b),
        )  # (nb, B, L, Q, hb, hd)
        y = y_b.transpose(1, 2, 3, 0, 4, 5).reshape(b, L, q, n_heads, hd)
    else:
        y = _ssd_core(xh, bh, ch, dtc, cum, x.dtype)

    y = y.reshape(b, S, n_heads, hd)
    y = y + xs.reshape(b, S, n_heads, hd) * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, S, d_inner)
    y = rmsnorm({"scale": p["norm"]}, y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["w_out"].astype(x.dtype)


def ssm_state_after(p: PyTree, cfg: ModelConfig, x: jnp.ndarray) -> PyTree:
    """Exact recurrent state after consuming x (B,S,d) — prefill cache."""
    s_cfg = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    hd, ds = s_cfg.head_dim, s_cfg.d_state
    b, S, _ = x.shape
    # conv windows: last (d_conv−1) *pre-conv* component inputs
    xs_raw = x @ p["w_x"].astype(x.dtype)
    b_raw = x @ p["w_B"].astype(x.dtype)
    c_raw = x @ p["w_C"].astype(x.dtype)
    k = s_cfg.d_conv - 1
    conv_state = {
        "x": xs_raw[:, -k:, :],
        "B": b_raw[:, -k:, :],
        "C": c_raw[:, -k:, :],
    }
    _, xs, b_, c_, dt = _project(p, cfg, x)
    a_log = -jnp.exp(p["A_log"]) * dt  # (B,S,H)
    cum = jnp.cumsum(a_log, axis=1)
    suffix = jnp.exp(cum[:, -1:, :] - cum)  # decay from t to end (B,S,H)
    xh = xs.reshape(b, S, n_heads, hd).astype(jnp.float32)
    h = jnp.einsum("bsh,bsn,bshd->bhdn", suffix * dt, b_.astype(jnp.float32), xh)
    return {"conv": conv_state, "h": h}


def init_mamba2_cache(cfg: ModelConfig, batch: int, dtype) -> PyTree:
    s = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    k = s.d_conv - 1
    return {
        "conv": {
            "x": jnp.zeros((batch, k, d_inner), dtype),
            "B": jnp.zeros((batch, k, s.d_state), dtype),
            "C": jnp.zeros((batch, k, s.d_state), dtype),
        },
        "h": jnp.zeros((batch, n_heads, s.head_dim, s.d_state), jnp.float32),
    }


def mamba2_decode(
    p: PyTree, cfg: ModelConfig, x: jnp.ndarray, cache: PyTree
) -> Tuple[jnp.ndarray, PyTree]:
    """One-token recurrent step. x: (B, 1, d)."""
    s_cfg = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    hd, ds = s_cfg.head_dim, s_cfg.d_state
    b = x.shape[0]
    x0 = x[:, 0]
    z = x0 @ p["w_z"].astype(x.dtype)
    xs_raw = x0 @ p["w_x"].astype(x.dtype)
    b_raw = x0 @ p["w_B"].astype(x.dtype)
    c_raw = x0 @ p["w_C"].astype(x.dtype)
    dt = x0 @ p["w_dt"].astype(x.dtype)

    def conv_step(name, raw, conv_w, conv_b):
        window = jnp.concatenate(
            [cache["conv"][name].astype(x.dtype), raw[:, None]], axis=1
        )  # (B, K, C)
        out = jnp.einsum("bkc,kc->bc", window, conv_w.astype(x.dtype)) + conv_b.astype(
            x.dtype
        )
        return jax.nn.silu(out), window[:, 1:]

    xs, conv_x = conv_step("x", xs_raw, p["conv_x"], p["conv_bias_x"])
    b_, conv_b_ = conv_step("B", b_raw, p["conv_B"], p["conv_bias_B"])
    c_, conv_c = conv_step("C", c_raw, p["conv_C"], p["conv_bias_C"])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)  # (B,H)
    xh = xs.reshape(b, n_heads, hd).astype(jnp.float32)
    h = cache["h"] * a[..., None, None] + jnp.einsum(
        "bh,bn,bhd->bhdn", dt, b_.astype(jnp.float32), xh
    )
    y = jnp.einsum("bn,bhdn->bhd", c_.astype(jnp.float32), h)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(b, d_inner).astype(x.dtype)
    y = rmsnorm({"scale": p["norm"]}, y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ p["w_out"].astype(x.dtype))[:, None]
    new_cache = {
        "conv": {
            "x": conv_x.astype(cache["conv"]["x"].dtype),
            "B": conv_b_.astype(cache["conv"]["B"].dtype),
            "C": conv_c.astype(cache["conv"]["C"].dtype),
        },
        "h": h,
    }
    return out, new_cache
