"""Assigned LM architecture zoo (10 archs) as one composable model family."""

from repro.models.lm.config import (  # noqa: F401
    HybridConfig,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SSMConfig,
    reduced,
)
from repro.models.lm import model as model  # noqa: F401
from repro.models.lm import steps as steps  # noqa: F401
