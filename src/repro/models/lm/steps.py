"""Pure train / serve step functions (the units the launcher jits + shards).

``train_step``  : CE (+ MoE aux + z-loss) → grads → AdamW. One optimizer
                  step; the EP-MCMC (SGLD subposterior) variant lives in
                  :mod:`repro.distributed.epmcmc` and reuses the same loss.
``serve_prefill``: prompt pass → caches + first sampled token.
``serve_decode_step``: one token against the caches (the decode_* /
                  long_* dry-run cells lower THIS, not train_step).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm import model as mdl
from repro.models.lm.config import ModelConfig
from repro.models.lm.loss import cross_entropy, shift_labels
from repro.optim.adamw import AdamWState, adamw_init, adamw_update

PyTree = Any

MOE_AUX_COEFF = 0.01
Z_LOSS_COEFF = 1e-4


def loss_fn(
    params: PyTree, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, moe_aux = mdl.forward(
        params,
        cfg,
        batch["tokens"],
        img_embeds=batch.get("img_embeds"),
        enc_frames=batch.get("enc_frames"),
    )
    labels = batch.get("labels")
    if labels is None:
        labels = shift_labels(batch["tokens"])
    if cfg.num_image_tokens and "img_embeds" in batch:
        # image prefix positions carry no next-token loss
        logits = logits[:, cfg.num_image_tokens :]
    ce, zl = cross_entropy(logits, labels, z_loss_coeff=Z_LOSS_COEFF)
    total = ce + zl + MOE_AUX_COEFF * moe_aux
    return total, {"ce": ce, "z_loss": zl, "moe_aux": moe_aux}


def train_step(
    params: PyTree,
    opt_state: AdamWState,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    lr: float | jnp.ndarray = 3e-4,
) -> Tuple[PyTree, AdamWState, Dict[str, jnp.ndarray]]:
    (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch
    )
    new_params, new_opt = adamw_update(params, grads, opt_state, lr=lr)
    metrics = dict(metrics, loss=total)
    return new_params, new_opt, metrics


def init_train_state(key: jax.Array, cfg: ModelConfig) -> Tuple[PyTree, AdamWState]:
    params = mdl.init_params(key, cfg)
    return params, adamw_init(params, state_dtype=jnp.dtype(cfg.opt_state_dtype))


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


class DecodeState(NamedTuple):
    caches: PyTree
    position: jnp.ndarray  # () int32 — next cache write index
    last_token: jnp.ndarray  # (B, 1)
    memory: Optional[jnp.ndarray] = None  # whisper encoder output


def serve_prefill(
    params: PyTree,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    max_len: int,
) -> DecodeState:
    logits, caches, memory = mdl.prefill(
        params,
        cfg,
        batch["tokens"],
        max_len,
        img_embeds=batch.get("img_embeds"),
        enc_frames=batch.get("enc_frames"),
    )
    token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    seq = batch["tokens"].shape[1] + (
        cfg.num_image_tokens if "img_embeds" in batch else 0
    )
    return DecodeState(
        caches=caches,
        position=jnp.asarray(seq, jnp.int32),
        last_token=token,
        memory=memory,
    )


def serve_decode_step(
    params: PyTree, cfg: ModelConfig, state: DecodeState
) -> Tuple[DecodeState, jnp.ndarray]:
    """Greedy one-token step; returns (new state, logits (B, 1, V))."""
    logits, caches = mdl.decode_step(
        params,
        cfg,
        state.last_token,
        state.caches,
        state.position,
        memory=state.memory,
    )
    token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    new_state = DecodeState(
        caches=caches,
        position=state.position + 1,
        last_token=token,
        memory=state.memory,
    )
    return new_state, logits
