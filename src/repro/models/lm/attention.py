"""Attention: GQA (with RoPE, optional QKV bias), MLA (DeepSeek-V2), cross-attn.

Three execution paths:

- ``gqa_forward``   full-sequence causal/bidirectional attention (train/prefill).
  ``cfg.attn_impl``: "einsum" materializes (S,S) scores (XLA-fused baseline);
  "chunked" is a flash-style two-level blocking (Q blocks × KV blocks with an
  online-softmax inner scan) that never materializes the score matrix — the
  TPU-native memory-term optimization used in the §Perf hillclimb.
- ``gqa_decode``    one-token step against a KV cache laid out (B, S, K, hd);
  the cache's S axis may be sharded (GSPMD lowers the softmax into partial
  reductions + small all-reduces — flash-decoding at the collective level).
- ``mla_*``         multi-head latent attention; decode uses the *absorbed*
  formulation (scores in the 512-d latent space, cache = c_kv ⊕ k_rope —
  the 93% cache shrink that is DeepSeek-V2's point).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm.config import ModelConfig
from repro.models.lm.flash import flash_attention
from repro.models.lm.layers import apply_rope, init_linear, rmsnorm

PyTree = Dict[str, jnp.ndarray]

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_gqa(key: jax.Array, cfg: ModelConfig) -> PyTree:
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    keys = jax.random.split(key, 4)
    p = {
        "w_q": init_linear(keys[0], d, h * hd, dtype=cfg.param_dtype, bias=cfg.qkv_bias),
        "w_k": init_linear(keys[1], d, k * hd, dtype=cfg.param_dtype, bias=cfg.qkv_bias),
        "w_v": init_linear(keys[2], d, k * hd, dtype=cfg.param_dtype, bias=cfg.qkv_bias),
        "w_o": init_linear(keys[3], h * hd, d, dtype=cfg.param_dtype),
    }
    return p


def _project_qkv(p: PyTree, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray):
    b, s, _ = x.shape
    h, k, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def lin(pp, x):
        y = x @ pp["w"].astype(x.dtype)
        if "b" in pp:
            y = y + pp["b"].astype(x.dtype)
        return y

    q = lin(p["w_q"], x).reshape(b, s, h, hd)
    kk = lin(p["w_k"], x).reshape(b, s, k, hd)
    v = lin(p["w_v"], x).reshape(b, s, k, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    kk = apply_rope(kk, positions, cfg.rope_theta)
    return q, kk, v


def _einsum_attention(
    q: jnp.ndarray,  # (B, S, H, hd)
    k: jnp.ndarray,  # (B, T, K, hd)
    v: jnp.ndarray,  # (B, T, K, hd)
    *,
    causal: bool,
    q_offset: jnp.ndarray | int = 0,
    kv_valid_len: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    if causal:
        qpos = jnp.arange(s) + q_offset
        mask = qpos[:, None] >= jnp.arange(t)[None, :]
        scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
    if kv_valid_len is not None:
        valid = jnp.arange(t)[None, :] < kv_valid_len[:, None]  # (B, T)
        scores = jnp.where(valid[:, None, None, None, :], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, v.shape[-1])


def sdpa(
    cfg: ModelConfig,
    q: jnp.ndarray,  # (B, S, H, hd)
    k: jnp.ndarray,  # (B, T, K, hd)
    v: jnp.ndarray,  # (B, T, K, hd_v)
    *,
    causal: bool,
) -> jnp.ndarray:
    """Dispatch: flash (tiled, O(S) memory) vs einsum (materialized scores).

    Flash is the default whenever S exceeds one tile — einsum attention at
    these shapes materializes O(S²) scores per layer (137 TB/device at
    prefill_32k), so "einsum" is kept only as the small-seq fast path and as
    the §Perf before/after baseline at train_4k.
    """
    b, s, h, hd = q.shape
    kh = k.shape[2]
    q5 = q.reshape(b, s, kh, h // kh, hd)
    if cfg.attn_impl == "chunked" and s > cfg.attn_chunk:
        out = flash_attention(
            q5, k, v, causal, cfg.attn_chunk, cfg.attn_chunk
        )
    else:
        out = _einsum_attention(q, k, v, causal=causal)
        return out.reshape(b, s, h, v.shape[-1])
    return out.reshape(b, s, h, v.shape[-1])


def gqa_forward(
    p: PyTree,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    causal: bool = True,
) -> jnp.ndarray:
    """Full-sequence attention. x: (B, S, d); positions: (B, S)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = sdpa(cfg, q, k, v, causal=causal)
    return out.reshape(b, s, -1) @ p["w_o"]["w"].astype(x.dtype)


def init_gqa_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype
) -> PyTree:
    k, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, k, hd), dtype),
        "v": jnp.zeros((batch, max_len, k, hd), dtype),
    }


def gqa_decode(
    p: PyTree,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, 1, d)
    cache: PyTree,
    position: jnp.ndarray,  # () current index (same for whole batch)
) -> Tuple[jnp.ndarray, PyTree]:
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(p, cfg, x, position[None, None].astype(jnp.int32) + jnp.zeros((b, 1), jnp.int32))
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), position, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), position, axis=1)
    valid_len = jnp.full((b,), position + 1, jnp.int32)
    out = _einsum_attention(
        q, cache_k.astype(x.dtype), cache_v.astype(x.dtype), causal=False, kv_valid_len=valid_len
    )
    out = out.reshape(b, 1, -1) @ p["w_o"]["w"].astype(x.dtype)
    return out, {"k": cache_k, "v": cache_v}


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------


def init_cross(key: jax.Array, cfg: ModelConfig) -> PyTree:
    return init_gqa(key, cfg)


def cross_forward(
    p: PyTree, cfg: ModelConfig, x: jnp.ndarray, memory: jnp.ndarray
) -> jnp.ndarray:
    """Decoder cross-attention onto encoder output ``memory`` (B, T_enc, d).

    No RoPE on cross-attention (Whisper uses learned/sinusoidal absolute
    positions on the encoder side; the stub frontend embeds them already).
    """
    b, s, _ = x.shape
    t = memory.shape[1]
    h, k, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def lin(pp, z):
        y = z @ pp["w"].astype(z.dtype)
        if "b" in pp:
            y = y + pp["b"].astype(z.dtype)
        return y

    q = lin(p["w_q"], x).reshape(b, s, h, hd)
    kk = lin(p["w_k"], memory).reshape(b, t, k, hd)
    v = lin(p["w_v"], memory).reshape(b, t, k, hd)
    out = _einsum_attention(q, kk, v, causal=False)
    return out.reshape(b, s, -1) @ p["w_o"]["w"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(key: jax.Array, cfg: ModelConfig) -> PyTree:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    keys = jax.random.split(key, 6)
    p: PyTree = {}
    if m.q_lora_rank:
        p["w_dq"] = init_linear(keys[0], d, m.q_lora_rank, dtype=cfg.param_dtype)["w"]
        p["q_norm"] = jnp.ones((m.q_lora_rank,), jnp.dtype(cfg.param_dtype))
        p["w_uq"] = init_linear(
            keys[1], m.q_lora_rank, h * (m.nope_head_dim + m.rope_head_dim), dtype=cfg.param_dtype
        )["w"]
    else:
        p["w_q"] = init_linear(
            keys[1], d, h * (m.nope_head_dim + m.rope_head_dim), dtype=cfg.param_dtype
        )["w"]
    p["w_dkv"] = init_linear(keys[2], d, m.kv_lora_rank + m.rope_head_dim, dtype=cfg.param_dtype)["w"]
    p["kv_norm"] = jnp.ones((m.kv_lora_rank,), jnp.dtype(cfg.param_dtype))
    p["w_uk"] = init_linear(keys[3], m.kv_lora_rank, h * m.nope_head_dim, dtype=cfg.param_dtype)["w"]
    p["w_uv"] = init_linear(keys[4], m.kv_lora_rank, h * m.v_head_dim, dtype=cfg.param_dtype)["w"]
    p["w_o"] = init_linear(keys[5], h * m.v_head_dim, d, dtype=cfg.param_dtype)["w"]
    return p


def _mla_q(p: PyTree, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    if m.q_lora_rank:
        cq = x @ p["w_dq"].astype(x.dtype)
        cq = rmsnorm({"scale": p["q_norm"]}, cq, cfg.norm_eps)
        q = cq @ p["w_uq"].astype(x.dtype)
    else:
        q = x @ p["w_q"].astype(x.dtype)
    q = q.reshape(b, s, h, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latents(p: PyTree, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray):
    m = cfg.mla
    dkv = x @ p["w_dkv"].astype(x.dtype)  # (B, S, kv_lora + rope)
    c_kv, k_rope = dkv[..., : m.kv_lora_rank], dkv[..., m.kv_lora_rank :]
    c_kv = rmsnorm({"scale": p["kv_norm"]}, c_kv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def mla_forward(
    p: PyTree, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray
) -> jnp.ndarray:
    """Training/prefill MLA, expanded form, routed through the flash kernel.

    nope⊕rope parts concatenate into one head dim (their dot products add),
    so the GQA flash path applies with K=H, G=1 and v_head_dim ≠ qk dim.
    """
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_latents(p, cfg, x, positions)
    k_nope = (c_kv @ p["w_uk"].astype(x.dtype)).reshape(b, s, h, m.nope_head_dim)
    v = (c_kv @ p["w_uv"].astype(x.dtype)).reshape(b, s, h, m.v_head_dim)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)  # (B,S,H,nope+rope)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, m.rope_head_dim))],
        axis=-1,
    )
    out = sdpa(cfg, q_full, k_full, v, causal=True)
    return out.reshape(b, s, -1) @ p["w_o"].astype(x.dtype)


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> PyTree:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
    }


def mla_decode(
    p: PyTree,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, 1, d)
    cache: PyTree,
    position: jnp.ndarray,
) -> Tuple[jnp.ndarray, PyTree]:
    """Absorbed-form decode: scores and context stay in the latent space.

    q_eff[h] = W_uk[h]ᵀ q_nope[h]  (kv_lora,)   — absorb W_uk into q
    score    = q_eff · c_kv + q_rope · k_rope
    ctx[h]   = Σ_t α_t c_kv[t]  → out[h] = ctx[h] @ W_uv[h]
    Cache per token: kv_lora + rope floats (vs H·(nope+v) expanded) — 576 vs
    32768 for the full config: a 57× memory-term cut.
    """
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    pos_b = position[None, None].astype(jnp.int32) + jnp.zeros((b, 1), jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, pos_b)  # (B,1,H,nope), (B,1,H,rope)
    c_new, kr_new = _mla_latents(p, cfg, x, pos_b)
    cache_c = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), position, axis=1
    )
    cache_r = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), position, axis=1
    )
    w_uk = p["w_uk"].astype(x.dtype).reshape(m.kv_lora_rank, h, m.nope_head_dim)
    q_eff = jnp.einsum("bshd,lhd->bshl", q_nope, w_uk)  # (B,1,H,kv_lora)
    t = cache_c.shape[1]
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    scores = (
        jnp.einsum("bshl,btl->bhst", q_eff, cache_c.astype(x.dtype))
        + jnp.einsum("bshd,btd->bhst", q_rope, cache_r.astype(x.dtype))
    ).astype(jnp.float32) * scale
    valid = jnp.arange(t)[None, :] <= position  # (1, T)
    scores = jnp.where(valid[None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhst,btl->bshl", probs, cache_c.astype(x.dtype))  # (B,1,H,l)
    w_uv = p["w_uv"].astype(x.dtype).reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bshl,lhd->bshd", ctx, w_uv).reshape(b, 1, -1)
    out = out @ p["w_o"].astype(x.dtype)
    return out, {"c_kv": cache_c, "k_rope": cache_r}
