"""Model assembly for all assigned architectures.

A model is a list of *layer groups*: each group is ``period`` heterogeneous
block specs repeated ``repeat`` times, executed as ``lax.scan`` over stacked
parameters (compile time and HLO size stay O(period), not O(num_layers) —
essential for the 62/72-layer archs to compile on this rig).

Families map to specs:
- dense / vlm:  [attn+mlp] × L
- moe:          [attn+moe] × L  (DeepSeek-V2: first layer attn+mlp unrolled)
- ssm:          [mamba] × L
- hybrid:       period-8 Jamba pattern (attn at index 4, MoE on odd layers)
- encdec:       encoder stack (bidir attn+mlp) + decoder stack (causal
                attn + cross-attn + mlp); the conv/audio frontend is a stub —
                ``input_specs`` feeds precomputed frame embeddings.

Three execution paths share the block definitions: ``forward`` (train),
``prefill`` (forward + cache emission), ``decode_step`` (one token against
caches). Caches are pytrees stacked like the parameter groups so the decode
scan streams both together.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm import attention as attn
from repro.models.lm import mamba2 as m2
from repro.models.lm import moe as moe_lib
from repro.models.lm.config import ModelConfig
from repro.models.lm.layers import (
    embed_lookup,
    init_embed,
    init_mlp,
    init_rmsnorm,
    mlp,
    rmsnorm,
)

PyTree = Any


class LayerSpec(NamedTuple):
    mixer: str  # "attn" | "mla" | "mamba"
    ffn: str  # "mlp" | "moe" | "none"
    cross: bool = False
    causal: bool = True


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    specs: Tuple[LayerSpec, ...]  # one period
    repeat: int


# ---------------------------------------------------------------------------
# spec derivation
# ---------------------------------------------------------------------------


def layer_specs(cfg: ModelConfig) -> List[LayerSpec]:
    moe_set = set(cfg.moe_layer_indices())
    attn_set = set(cfg.attn_layer_indices())
    specs = []
    for i in range(cfg.num_layers):
        if i in attn_set:
            mixer = "mla" if cfg.mla is not None else "attn"
        else:
            mixer = "mamba"
        if mixer == "mamba" and cfg.hybrid is None:
            ffn = "none"  # pure Mamba blocks have no FFN
        elif i in moe_set:
            ffn = "moe"
        else:
            ffn = "mlp" if cfg.d_ff > 0 else "none"
        specs.append(
            LayerSpec(mixer=mixer, ffn=ffn, cross=(cfg.num_encoder_layers > 0))
        )
    return specs


def layer_groups(cfg: ModelConfig) -> List[GroupSpec]:
    specs = layer_specs(cfg)
    n = len(specs)
    if cfg.hybrid is not None:
        p = cfg.hybrid.period
        assert n % p == 0
        return [GroupSpec(specs=tuple(specs[:p]), repeat=n // p)]
    # leading irregular prefix (e.g. DeepSeek-V2 first dense layer)
    prefix = 0
    while prefix < n and specs[prefix] != specs[-1]:
        prefix += 1
    groups: List[GroupSpec] = []
    if prefix:
        groups.append(GroupSpec(specs=tuple(specs[:prefix]), repeat=1))
    if n - prefix:
        groups.append(GroupSpec(specs=(specs[-1],), repeat=n - prefix))
    return groups


# ---------------------------------------------------------------------------
# block init / apply
# ---------------------------------------------------------------------------


def _init_block(key: jax.Array, cfg: ModelConfig, spec: LayerSpec) -> PyTree:
    keys = jax.random.split(key, 4)
    p: Dict[str, PyTree] = {"ln1": init_rmsnorm(cfg.d_model, cfg.param_dtype)}
    if spec.mixer == "attn":
        p["attn"] = attn.init_gqa(keys[0], cfg)
    elif spec.mixer == "mla":
        p["attn"] = attn.init_mla(keys[0], cfg)
    else:
        p["mamba"] = m2.init_mamba2(keys[0], cfg)
    if spec.cross:
        p["ln_cross"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["cross"] = attn.init_cross(keys[1], cfg)
    if spec.ffn == "mlp":
        p["ln2"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["mlp"] = init_mlp(keys[2], cfg.d_model, cfg.d_ff, cfg.param_dtype)
    elif spec.ffn == "moe":
        p["ln2"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["moe"] = moe_lib.init_moe(keys[3], cfg)
    return p


def _block_forward(
    p: PyTree,
    cfg: ModelConfig,
    spec: LayerSpec,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    memory: Optional[jnp.ndarray],
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        h = attn.gqa_forward(p["attn"], cfg, h, positions, causal=spec.causal)
    elif spec.mixer == "mla":
        h = attn.mla_forward(p["attn"], cfg, h, positions)
    else:
        h = m2.mamba2_forward(p["mamba"], cfg, h)
    x = x + h
    if spec.cross and memory is not None:
        h = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        x = x + attn.cross_forward(p["cross"], cfg, h, memory)
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn == "mlp":
        x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    elif spec.ffn == "moe":
        y, aux = moe_lib.moe_forward(p["moe"], cfg, rmsnorm(p["ln2"], x, cfg.norm_eps))
        x = x + y
    return x, aux


def _block_prefill(
    p: PyTree,
    cfg: ModelConfig,
    spec: LayerSpec,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    memory: Optional[jnp.ndarray],
    max_len: int,
) -> Tuple[jnp.ndarray, PyTree]:
    """Forward + emit this layer's cache (padded to max_len)."""
    b, s, _ = x.shape
    h_in = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        q, k, v = attn._project_qkv(p["attn"], cfg, h_in, positions)
        out = attn.sdpa(cfg, q, k, v, causal=spec.causal)
        h = out.reshape(b, s, -1) @ p["attn"]["w_o"]["w"].astype(x.dtype)
        pad = max_len - s
        cache = {
            "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
        }
    elif spec.mixer == "mla":
        h = attn.mla_forward(p["attn"], cfg, h_in, positions)
        c_kv, k_rope = attn._mla_latents(p["attn"], cfg, h_in, positions)
        pad = max_len - s
        cache = {
            "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
            "k_rope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
        }
    else:
        # Mamba prefill: chunked forward + exact state reconstruction.
        h = m2.mamba2_forward(p["mamba"], cfg, h_in)
        cache = m2.ssm_state_after(p["mamba"], cfg, h_in)
    x = x + h
    if spec.cross and memory is not None:
        hc = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        x = x + attn.cross_forward(p["cross"], cfg, hc, memory)
    if spec.ffn == "mlp":
        x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    elif spec.ffn == "moe":
        y, _ = moe_lib.moe_forward(p["moe"], cfg, rmsnorm(p["ln2"], x, cfg.norm_eps))
        x = x + y
    return x, cache


def _block_decode(
    p: PyTree,
    cfg: ModelConfig,
    spec: LayerSpec,
    x: jnp.ndarray,  # (B, 1, d)
    cache: PyTree,
    position: jnp.ndarray,
    memory: Optional[jnp.ndarray],
) -> Tuple[jnp.ndarray, PyTree]:
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        h, cache = attn.gqa_decode(p["attn"], cfg, h, cache, position)
    elif spec.mixer == "mla":
        h, cache = attn.mla_decode(p["attn"], cfg, h, cache, position)
    else:
        h, cache = m2.mamba2_decode(p["mamba"], cfg, h, cache)
    x = x + h
    if spec.cross and memory is not None:
        hc = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        x = x + attn.cross_forward(p["cross"], cfg, hc, memory)
    if spec.ffn == "mlp":
        x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    elif spec.ffn == "moe":
        # decode MoE: capacity-dispatch EP keeps expert weights stationary
        # (the gather path moves per-token weight matrices across shards —
        # 249 GiB/step on deepseek-v2 decode_32k; see EXPERIMENTS.md §Perf).
        moe_fn = (
            moe_lib.moe_forward
            if cfg.moe_decode_impl == "dispatch"
            else moe_lib.moe_forward_gather
        )
        y, _ = moe_fn(p["moe"], cfg, rmsnorm(p["ln2"], x, cfg.norm_eps))
        x = x + y
    return x, cache


def _init_cache_for_spec(
    cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int, dtype
) -> PyTree:
    if spec.mixer == "attn":
        return attn.init_gqa_cache(cfg, batch, max_len, dtype)
    if spec.mixer == "mla":
        return attn.init_mla_cache(cfg, batch, max_len, dtype)
    return m2.init_mamba2_cache(cfg, batch, dtype)


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: ModelConfig) -> PyTree:
    groups = layer_groups(cfg)
    k_embed, k_head, k_groups, k_enc, k_img = jax.random.split(key, 5)
    params: Dict[str, PyTree] = {
        "embed": init_embed(k_embed, cfg.vocab_size, cfg.d_model, cfg.param_dtype),
        "final_norm": init_rmsnorm(cfg.d_model, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size), jnp.float32)
            * (1.0 / cfg.d_model) ** 0.5
        ).astype(jnp.dtype(cfg.param_dtype))

    gkeys = jax.random.split(k_groups, len(groups))
    for gi, (gk, group) in enumerate(zip(gkeys, groups)):
        def init_period(pk):
            pkeys = jax.random.split(pk, len(group.specs))
            return {
                f"l{i}": _init_block(pkeys[i], cfg, spec)
                for i, spec in enumerate(group.specs)
            }

        if group.repeat == 1:
            params[f"g{gi}"] = init_period(gk)
        else:
            rkeys = jax.random.split(gk, group.repeat)
            params[f"g{gi}"] = jax.vmap(init_period)(rkeys)

    if cfg.num_encoder_layers:
        enc_spec = LayerSpec(mixer="attn", ffn="mlp", cross=False, causal=False)
        ekeys = jax.random.split(k_enc, cfg.num_encoder_layers)
        params["encoder"] = jax.vmap(
            lambda kk: {"l0": _init_block(kk, cfg, enc_spec)}
        )(ekeys)
        params["enc_norm"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
    if cfg.num_image_tokens:
        d_vis = 1024  # stub vision tower output width
        params["img_proj"] = (
            jax.random.normal(k_img, (d_vis, cfg.d_model), jnp.float32) * (1.0 / d_vis) ** 0.5
        ).astype(jnp.dtype(cfg.param_dtype))
    return params


# ---------------------------------------------------------------------------
# execution: train forward / prefill / decode
# ---------------------------------------------------------------------------


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _seq_parallel_constraint(cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Megatron-style sequence parallelism: pin the residual stream's S axis
    to the `model` mesh axis at block boundaries. GSPMD then lowers the TP
    boundary collectives as reduce-scatter(+all-gather at consumers) instead
    of full all-reduces of replicated activations — halving the boundary
    bytes and sharding every norm/elementwise op between blocks 16-way."""
    from jax.sharding import PartitionSpec as P

    U = P.UNCONSTRAINED
    try:
        return jax.lax.with_sharding_constraint(x, P(U, "model", U))
    except (ValueError, RuntimeError):  # no mesh in context (CPU unit tests)
        return x


def _run_groups(
    params: PyTree,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    memory: Optional[jnp.ndarray],
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    aux_total = jnp.zeros((), jnp.float32)
    for gi, group in enumerate(layer_groups(cfg)):
        gparams = params[f"g{gi}"]

        def period_fn(x, lparams):
            aux = jnp.zeros((), jnp.float32)
            for i, spec in enumerate(group.specs):
                if cfg.seq_parallel:
                    x = _seq_parallel_constraint(cfg, x)
                x, a = _block_forward(lparams[f"l{i}"], cfg, spec, x, positions, memory)
                aux = aux + a
            return x, aux

        period_fn = _maybe_remat(cfg, period_fn)
        if group.repeat == 1:
            x, aux = period_fn(x, gparams)
            aux_total = aux_total + aux
        else:

            def scan_body(x, lparams):
                return period_fn(x, lparams)

            x, auxes = jax.lax.scan(scan_body, x, gparams)
            aux_total = aux_total + jnp.sum(auxes)
    return x, aux_total


def _encode(params: PyTree, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """Whisper encoder over stub frame embeddings (B, T_enc, d)."""
    enc_spec = LayerSpec(mixer="attn", ffn="mlp", cross=False, causal=False)
    positions = jnp.broadcast_to(
        jnp.arange(frames.shape[1]), frames.shape[:2]
    )

    def body(x, lparams):
        x, _ = _block_forward(lparams["l0"], cfg, enc_spec, x, positions, None)
        return x, None

    x, _ = jax.lax.scan(body, frames, params["encoder"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _inputs_to_h(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    img_embeds: Optional[jnp.ndarray],
) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """Token (+ image prefix) embedding; returns (h, positions, n_prefix)."""
    compute = jnp.dtype(cfg.dtype)
    h = embed_lookup(params["embed"], tokens, compute)
    n_prefix = 0
    if cfg.num_image_tokens and img_embeds is not None:
        vis = (img_embeds.astype(compute) @ params["img_proj"].astype(compute))
        h = jnp.concatenate([vis, h], axis=1)
        n_prefix = img_embeds.shape[1]
    positions = jnp.broadcast_to(jnp.arange(h.shape[1]), h.shape[:2])
    return h, positions, n_prefix


def forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, S)
    *,
    img_embeds: Optional[jnp.ndarray] = None,  # (B, n_img, d_vis) vlm stub
    enc_frames: Optional[jnp.ndarray] = None,  # (B, T_enc, d) audio stub
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training/eval forward → (logits (B, S_total, V), moe_aux_loss)."""
    memory = None
    if cfg.num_encoder_layers and enc_frames is not None:
        memory = _encode(params, cfg, enc_frames.astype(jnp.dtype(cfg.dtype)))
    h, positions, _ = _inputs_to_h(params, cfg, tokens, img_embeds)
    h, aux = _run_groups(params, cfg, h, positions, memory)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = h @ head.astype(h.dtype)
    return logits, aux


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype) -> PyTree:
    caches: Dict[str, PyTree] = {}
    for gi, group in enumerate(layer_groups(cfg)):
        def one_period():
            return {
                f"l{i}": _init_cache_for_spec(cfg, spec, batch, max_len, dtype)
                for i, spec in enumerate(group.specs)
            }

        entry = one_period()
        if group.repeat > 1:
            entry = jax.tree.map(
                lambda l: jnp.broadcast_to(l, (group.repeat,) + l.shape).copy(), entry
            )
        caches[f"g{gi}"] = entry
    return caches


def prefill(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    max_len: int,
    *,
    img_embeds: Optional[jnp.ndarray] = None,
    enc_frames: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, PyTree, Optional[jnp.ndarray]]:
    """Run the prompt, returning (last-token logits, caches, memory)."""
    memory = None
    if cfg.num_encoder_layers and enc_frames is not None:
        memory = _encode(params, cfg, enc_frames.astype(jnp.dtype(cfg.dtype)))
    h, positions, _ = _inputs_to_h(params, cfg, tokens, img_embeds)
    caches: Dict[str, PyTree] = {}
    for gi, group in enumerate(layer_groups(cfg)):
        gparams = params[f"g{gi}"]

        def period_prefill(x, lparams):
            cc = {}
            for i, spec in enumerate(group.specs):
                x, c = _block_prefill(
                    lparams[f"l{i}"], cfg, spec, x, positions, memory, max_len
                )
                cc[f"l{i}"] = c
            return x, cc

        if group.repeat == 1:
            h, cache = period_prefill(h, gparams)
        else:
            h, cache = jax.lax.scan(period_prefill, h, gparams)
        caches[f"g{gi}"] = cache
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = h[:, -1:] @ head.astype(h.dtype)
    return logits, caches, memory


def decode_step(
    params: PyTree,
    cfg: ModelConfig,
    token: jnp.ndarray,  # (B, 1) the token generated at `position`-1
    caches: PyTree,
    position: jnp.ndarray,  # () write index into the caches
    *,
    memory: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, PyTree]:
    """One decode step → (logits (B, 1, V), updated caches)."""
    compute = jnp.dtype(cfg.dtype)
    h = embed_lookup(params["embed"], token, compute)
    new_caches: Dict[str, PyTree] = {}
    for gi, group in enumerate(layer_groups(cfg)):
        gparams = params[f"g{gi}"]
        gcache = caches[f"g{gi}"]

        def period_decode(x, scan_in):
            lparams, lcache = scan_in
            new_cc = {}
            for i, spec in enumerate(group.specs):
                x, c = _block_decode(
                    lparams[f"l{i}"], cfg, spec, x, lcache[f"l{i}"], position, memory
                )
                new_cc[f"l{i}"] = c
            return x, new_cc

        if group.repeat == 1:
            h, new_cache = period_decode(h, (gparams, gcache))
        else:
            h, new_cache = jax.lax.scan(period_decode, h, (gparams, gcache))
        new_caches[f"g{gi}"] = new_cache
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = h @ head.astype(h.dtype)
    return logits, new_caches
