"""Models: paper §8 Bayesian experiment models + assigned LM architecture zoo."""
