"""Bayes-model registry: the ``BayesModel`` protocol behind the EP pipeline.

A registered model packages everything the model-agnostic driver
(:mod:`repro.launch.mcmc_run`) needs to run the paper's full pipeline —
partition → sample → combine → score — without per-model branching:

- ``generate_data(key, n) -> (data, theta_true)``
- ``log_prior(theta) -> ()`` and ``log_lik(theta, data) -> ()`` (summed over
  the data's leading axis — the contract the subposterior builder and its
  ``count`` masking rely on)
- ``d``: dimension of the shared θ (what the combination stage sees)
- ``init_position(key, data_shard) -> θ0`` (defaults to a small-jitter
  origin start)
- ``shard_keys``: which dict keys hold per-datum arrays (``None`` = every
  leaf); global quantities (mixture weights …) are broadcast to every shard
  — this retires the driver's old ``only=("x",)`` gmm special-case
- ``default_sampler``: registry name the CLI falls back to
- optional Gibbs surface (paper §8.3 / criterion 3): ``gibbs_blocks(shard,
  M, *, step_size)`` building block updates against a concrete shard,
  ``gibbs_init(key, shard)`` for the extended position pytree, and
  ``gibbs_extract(positions)`` projecting stacked positions back to the
  shared ``(T, d)`` θ — latents stay shard-local, exactly as §8.3 requires.
  ``gibbs_counts=True`` declares that ``gibbs_blocks`` additionally accepts
  ``count=`` (the edge-pad valid-prefix convention) and masks the padded
  replicated rows out of its conditionals — such models run ``--sampler
  gibbs`` on non-divisible N; models without it keep requiring divisible N.

Models self-register at import time via :func:`register_model` (importing
:mod:`repro.models.bayes` populates the registry); consumers resolve them by
name with :func:`get_model` — mirroring ``repro.core.combiners`` and
``repro.samplers``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
Data = Any


@dataclasses.dataclass(frozen=True)
class BayesModel:
    """One paper-§8-style experiment family, pipeline-ready."""

    name: str
    generate_data: Callable[..., Tuple[Data, jnp.ndarray]]
    log_prior: Callable[[jnp.ndarray], jnp.ndarray]
    log_lik: Callable[[jnp.ndarray, Data], jnp.ndarray]
    d: int
    default_n: int = 50_000
    default_sampler: str = "rwmh"
    shard_keys: Optional[Tuple[str, ...]] = None
    init_position: Optional[Callable[[jax.Array, Data], jnp.ndarray]] = None
    gibbs_blocks: Optional[Callable[..., Any]] = None
    gibbs_init: Optional[Callable[[jax.Array, Data], PyTree]] = None
    gibbs_extract: Optional[Callable[[PyTree], jnp.ndarray]] = None
    gibbs_counts: bool = False  # gibbs_blocks masks padded rows via count=

    def initial_position(self, key: jax.Array, data_shard: Data) -> jnp.ndarray:
        """θ0 for one chain: model-provided init or jittered origin."""
        if self.init_position is not None:
            return self.init_position(key, data_shard)
        return 0.01 * jax.random.normal(key, (self.d,))

    @property
    def has_gibbs(self) -> bool:
        return self.gibbs_blocks is not None


_REGISTRY: Dict[str, BayesModel] = {}
_CANONICAL: Dict[str, BayesModel] = {}


def register_model(model: BayesModel, *aliases: str) -> BayesModel:
    """Add a model to the registry under its name (+ aliases)."""
    for key in (model.name, *aliases):
        if key in _REGISTRY:
            raise ValueError(f"model {key!r} already registered")
        _REGISTRY[key] = model
    _CANONICAL[model.name] = model
    return model


def get_model(name: str) -> BayesModel:
    """Resolve a model by registry name (raises KeyError with choices)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown model {name!r}; available: {', '.join(available_models())}"
        ) from None


def available_models() -> Tuple[str, ...]:
    """All registered model names (aliases included), sorted."""
    return tuple(sorted(_REGISTRY))


def canonical_models() -> Tuple[str, ...]:
    """Primary registration names only (aliases dropped), sorted."""
    return tuple(sorted(_CANONICAL))
