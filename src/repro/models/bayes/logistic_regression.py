"""Bayesian logistic regression — paper §8.1.

Synthetic data matches §8.1.1: each element of β and X drawn standard normal,
y_i ~ Bernoulli(logit⁻¹(X_i β)), N=50,000, d=50 (no intercept, per footnote 6).
The covtype task (§8.1.2) is emulated by :func:`generate_covtype_like` —
581,012×54 with a correlated design and class imbalance — since the real
dataset is not available offline; benchmarks report the same accuracy-vs-time
protocol.

θ = β ∈ R^d (already unconstrained — paper §6 scope).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.bayes import registry

Data = Dict[str, jnp.ndarray]


def generate_data(
    key: jax.Array, n: int = 50_000, d: int = 50, dtype=jnp.float32
) -> Tuple[Data, jnp.ndarray]:
    """§8.1.1 synthetic set: X, β ~ N(0,1) elementwise; y ~ Bern(σ(Xβ))."""
    k_beta, k_x, k_y = jax.random.split(key, 3)
    beta = jax.random.normal(k_beta, (d,), dtype)
    x = jax.random.normal(k_x, (n, d), dtype)
    logits = x @ beta
    y = jax.random.bernoulli(k_y, jax.nn.sigmoid(logits)).astype(dtype)
    return {"x": x, "y": y}, beta


def generate_covtype_like(
    key: jax.Array, n: int = 581_012, d: int = 54, dtype=jnp.float32
) -> Tuple[Data, jnp.ndarray]:
    """Covtype stand-in: correlated features, heavier class imbalance."""
    k_beta, k_x, k_mix, k_y = jax.random.split(key, 4)
    beta = jax.random.normal(k_beta, (d,), dtype) * 0.5
    base = jax.random.normal(k_x, (n, d), dtype)
    mixer = jax.random.normal(k_mix, (d, d), dtype) * (0.3 / jnp.sqrt(d))
    x = base + base @ mixer  # mildly correlated design
    logits = x @ beta - 0.8  # imbalance
    y = jax.random.bernoulli(k_y, jax.nn.sigmoid(logits)).astype(dtype)
    return {"x": x, "y": y}, beta


def log_prior(theta: jnp.ndarray, sigma: float = 5.0) -> jnp.ndarray:
    """β ~ N(0, σ² I) — weakly informative (Stan default-style)."""
    d = theta.shape[-1]
    return -0.5 * jnp.sum(theta**2) / sigma**2 - 0.5 * d * jnp.log(
        2.0 * jnp.pi * sigma**2
    )


def log_lik(theta: jnp.ndarray, data: Data) -> jnp.ndarray:
    """Σ_i log p(y_i | x_i, β) = Σ_i log σ(s_i · x_i β) with s_i = 2y_i − 1.

    The fused Pallas version (matvec + log-sigmoid reduce, never materializing
    logits in HBM) is ``repro.kernels.logreg_loglik`` — this jnp form is its
    reference oracle and the CPU path.
    """
    s = 2.0 * data["y"] - 1.0
    return jnp.sum(jax.nn.log_sigmoid(s * (data["x"] @ theta)))


def predictive_accuracy(
    betas: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray, *, chunk: int = 1024
) -> jnp.ndarray:
    """§8.1.2 posterior-predictive classification accuracy.

    P(y|x) ≈ (1/S) Σ_s σ(xᵀβ_s); predict the argmax class.
    """
    n = x.shape[0]
    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))

    def block(xc):
        probs = jnp.mean(jax.nn.sigmoid(xc @ betas.T), axis=1)
        return probs

    probs = jax.lax.map(block, xp.reshape(-1, chunk, x.shape[1])).reshape(-1)[:n]
    return jnp.mean((probs > 0.5).astype(jnp.float32) == y)


registry.register_model(
    registry.BayesModel(
        name="logreg",
        generate_data=generate_data,
        log_prior=log_prior,
        log_lik=log_lik,
        d=50,
        default_n=50_000,
        default_sampler="mala",
    ),
    "logistic_regression",
)

registry.register_model(
    registry.BayesModel(
        name="covtype",
        generate_data=lambda key, n=581_012: generate_covtype_like(key, n),
        log_prior=log_prior,
        log_lik=log_lik,
        d=54,
        default_n=581_012,
        default_sampler="mala",
    )
)
