"""Hierarchical Poisson–gamma model — paper §8.3.

    a ~ Exponential(λ),  b ~ Gamma(α, β),
    q_i ~ Gamma(a, b),   x_i ~ Poisson(q_i·t_i),   i = 1..N (N = 50,000).

Two equivalent samplers are provided (criterion 3 — any MCMC works):

1. **Marginalized HMC/MALA path** — q_i integrates out analytically
   (negative-binomial likelihood), leaving the 2-d global θ = (log a, log b),
   unconstrained as §6 requires (log transform + Jacobian):

     x_i | a,b ~ NB:  log p = lgamma(x_i+a) − lgamma(a) − lgamma(x_i+1)
                              + a·log(b/(b+t_i)) + x_i·log(t_i/(b+t_i))

2. **Gibbs path** — explicit latents: q_i | a,b,x ~ Gamma(a+x_i, b+t_i) is
   conjugate; b | a,q ~ Gamma(α+N·a, β+Σq_i) is conjugate; a | b,q via
   MH-within-Gibbs. Only (log a, log b) are shared across machines, so the
   combination stage sees d=2 regardless of N (latents are shard-local).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln

from repro.models.bayes import registry
from repro.samplers import randgamma

Data = Dict[str, jnp.ndarray]

# Hyperparameters (fixed, as the paper fixes λ, α, β before data generation).
LAMBDA = 1.0  # a ~ Exponential(1)
ALPHA = 2.0  # b ~ Gamma(2, 2)
BETA = 2.0


def generate_data(
    key: jax.Array,
    n: int = 50_000,
    a_true: float = 2.0,
    b_true: float = 1.0,
    dtype=jnp.float32,
) -> Tuple[Data, jnp.ndarray]:
    k_q, k_x, k_t = jax.random.split(key, 3)
    t = jnp.exp(0.3 * jax.random.normal(k_t, (n,), dtype))  # exposures t_i > 0
    q = jax.random.gamma(k_q, a_true, (n,), dtype) / b_true
    x = jax.random.poisson(k_x, q * t).astype(dtype)
    true_theta = jnp.log(jnp.asarray([a_true, b_true], dtype))
    return {"x": x, "t": t}, true_theta


def log_prior(theta: jnp.ndarray) -> jnp.ndarray:
    """Prior on θ=(log a, log b) incl. the log-transform Jacobians.

    p(a) = λ e^{-λa};  p(b) = β^α b^{α-1} e^{-βb} / Γ(α);  |da/dθ| = a, etc.
    """
    log_a, log_b = theta[0], theta[1]
    a, b = jnp.exp(log_a), jnp.exp(log_b)
    lp_a = jnp.log(LAMBDA) - LAMBDA * a + log_a
    lp_b = ALPHA * jnp.log(BETA) - gammaln(ALPHA) + (ALPHA - 1.0) * jnp.log(b) - BETA * b + log_b
    return lp_a + lp_b


def log_lik(theta: jnp.ndarray, data: Data) -> jnp.ndarray:
    """Marginal (negative-binomial) log-likelihood summed over the shard."""
    a, b = jnp.exp(theta[0]), jnp.exp(theta[1])
    x, t = data["x"], data["t"]
    return jnp.sum(
        gammaln(x + a)
        - gammaln(a)
        - gammaln(x + 1.0)
        + a * (jnp.log(b) - jnp.log(b + t))
        + x * (jnp.log(t) - jnp.log(b + t))
    )


# ---------------------------------------------------------------------------
# Gibbs path (explicit latents) — used to demonstrate criterion 3
# ---------------------------------------------------------------------------


def gibbs_blocks(data: Data, num_shards: int, mh_step: float = 0.15, count=None):
    """Block updates over position dict {"theta": (2,), "q": (n,)}.

    The prior on (a,b) is raised to 1/M (subposterior, Eq. 2.1); the latent
    q_i are shard-local so their conditionals are untouched by 1/M.

    ``count`` masks the edge-pad convention's replicated tail rows out of the
    global conditionals: the per-row latents q_i are still refreshed for every
    row (identical RNG consumption either way, and padded q_i stay proper
    Gamma draws), but the b- and a-conditionals only see the first ``count``
    rows' sufficient statistics (Σ w·q, Σ w·log q, count·a, ...), exactly the
    shard's real data. ``count=None`` leaves every statistic bit-identical to
    the unmasked path.
    """
    x, t = data["x"], data["t"]
    n = x.shape[0]
    inv_m = 1.0 / float(num_shards)
    w = None if count is None else (jnp.arange(n) < count).astype(x.dtype)
    n_eff = float(n) if count is None else count.astype(x.dtype)

    def update_q(key, pos):
        a, b = jnp.exp(pos["theta"][0]), jnp.exp(pos["theta"][1])
        # q_i | a,b,x ~ Gamma(a + x_i, rate b + t_i). Marsaglia–Tsang
        # rejection, not jax.random.gamma: this n-vector of gamma draws per
        # sweep is the whole-sampler bottleneck, and the conditional never
        # needs d/dα (see repro.samplers.randgamma).
        q = randgamma.gamma(key, a + x, (n,)) / (b + t)
        return {**pos, "q": q}

    def update_b(key, pos):
        a = jnp.exp(pos["theta"][0])
        # b | a, q ~ Gamma(α/M' + N a, β' + Σ q)  — prior tempered by 1/M:
        # p(b)^{1/M} ∝ b^{(α-1)/M} e^{-βb/M}; conjugate with ∏ Gamma(q_i|a,b).
        shape = (ALPHA - 1.0) * inv_m + 1.0 + n_eff * a
        rate = BETA * inv_m + (
            jnp.sum(pos["q"]) if w is None else jnp.sum(w * pos["q"])
        )
        b = randgamma.gamma(key, shape) / rate
        theta = pos["theta"].at[1].set(jnp.log(b))
        return {**pos, "theta": theta}

    def update_a(key, pos):
        # a | b, q: non-conjugate — random-walk MH on log a.
        k_prop, k_acc = jax.random.split(key)
        b = jnp.exp(pos["theta"][1])
        q = pos["q"]

        def cond(log_a):
            a = jnp.exp(log_a)
            prior = inv_m * (-LAMBDA * a) + log_a  # tempered Exp(λ) + Jacobian
            if w is None:
                lik = jnp.sum((a - 1.0) * jnp.log(q) + a * jnp.log(b) - gammaln(a))
            else:
                lik = (a - 1.0) * jnp.sum(w * jnp.log(q)) + n_eff * (
                    a * jnp.log(b) - gammaln(a)
                )
            return prior + lik

        log_a = pos["theta"][0]
        prop = log_a + mh_step * jax.random.normal(k_prop)
        log_ratio = cond(prop) - cond(log_a)
        accept = jnp.log(jax.random.uniform(k_acc)) < log_ratio
        theta = pos["theta"].at[0].set(jnp.where(accept, prop, log_a))
        return {**pos, "theta": theta}

    return [update_q, update_b, update_a]


def gibbs_init(key: jax.Array, data: Data) -> Dict[str, jnp.ndarray]:
    n = data["x"].shape[0]
    q0 = jnp.maximum(data["x"] / jnp.maximum(data["t"], 1e-6), 0.1)
    return {"theta": jnp.zeros((2,)), "q": q0}


registry.register_model(
    registry.BayesModel(
        name="poisson",
        generate_data=generate_data,
        log_prior=log_prior,
        log_lik=log_lik,
        d=2,
        default_n=50_000,
        default_sampler="rwmh",
        # criterion 3 (§8.3): conjugate latent-q Gibbs path — only (log a,
        # log b) are shared across machines, the q_i stay shard-local;
        # count masks edge-padded rows so ragged shards sample exactly
        gibbs_blocks=lambda shard, num_shards, *, step_size=0.15, count=None:
            gibbs_blocks(shard, num_shards, mh_step=step_size, count=count),
        gibbs_init=gibbs_init,
        gibbs_extract=lambda positions: positions["theta"],
        gibbs_counts=True,
    ),
    "poisson_gamma",
)
