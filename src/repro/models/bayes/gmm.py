"""Gaussian mixture model with known weights — paper §8.2 (multimodal case).

Data: 50,000 draws from a K=10 component mixture of 2-d Gaussians. The
posterior is over the K component means (θ ∈ R^{K·2}); mixture weights and
component variance are known. Label permutations leave the posterior invariant
⇒ the posterior over any single mean has K modes — the case where
asymptotically-biased combiners (parametric, subpostAvg) fail (Fig. 4).

Sampling uses MH where "the component labels were permuted before each step"
(paper §8.2): the proposal composes a uniform random permutation of the K
means (a symmetric move between equal-probability points) with Gaussian noise.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.bayes import registry

Data = Dict[str, jnp.ndarray]

K_DEFAULT = 10
DIM = 2


def generate_data(
    key: jax.Array,
    n: int = 50_000,
    k: int = K_DEFAULT,
    component_std: float = 1.0,
    spread: float = 8.0,
    dtype=jnp.float32,
) -> Tuple[Data, jnp.ndarray]:
    """Mixture of k 2-d Gaussians with uniform weights, means on a ring."""
    k_means, k_assign, k_noise = jax.random.split(key, 3)
    angles = jnp.linspace(0.0, 2.0 * jnp.pi, k, endpoint=False)
    ring = spread * jnp.stack([jnp.cos(angles), jnp.sin(angles)], axis=-1)
    means = ring + jax.random.normal(k_means, (k, DIM), dtype)
    assign = jax.random.randint(k_assign, (n,), 0, k)
    x = means[assign] + component_std * jax.random.normal(k_noise, (n, DIM), dtype)
    weights = jnp.full((k,), 1.0 / k, dtype)
    return {"x": x, "weights": weights, "component_std": jnp.asarray(component_std)}, means


def log_prior(theta: jnp.ndarray, sigma: float = 20.0) -> jnp.ndarray:
    """Means ~ N(0, σ² I), broad (θ is the flattened (K·2,) mean vector)."""
    d = theta.shape[-1]
    return -0.5 * jnp.sum(theta**2) / sigma**2 - 0.5 * d * jnp.log(
        2.0 * jnp.pi * sigma**2
    )


def log_lik(theta: jnp.ndarray, data: Data) -> jnp.ndarray:
    """Σ_i log Σ_k w_k N(x_i | μ_k, s² I) with known w, s."""
    k = data["weights"].shape[0]
    means = theta.reshape(k, DIM)
    s2 = data["component_std"] ** 2
    x = data["x"]  # (n, 2)
    sq = jnp.sum((x[:, None, :] - means[None, :, :]) ** 2, axis=-1)  # (n, k)
    log_comp = -0.5 * sq / s2 - jnp.log(2.0 * jnp.pi * s2)
    return jnp.sum(
        jax.scipy.special.logsumexp(log_comp + jnp.log(data["weights"])[None, :], axis=1)
    )


def permutation_rw_proposal(k: int, step_size: float = 0.05):
    """Proposal for §8.2 MH: permute component means uniformly, then RW jitter.

    Both pieces are symmetric ⇒ plain Metropolis acceptance applies.
    """

    def proposal(key: jax.Array, theta: jnp.ndarray) -> jnp.ndarray:
        k_perm, k_noise = jax.random.split(key)
        means = theta.reshape(k, DIM)
        perm = jax.random.permutation(k_perm, k)
        permuted = means[perm]
        noise = step_size * jax.random.normal(k_noise, permuted.shape, theta.dtype)
        return (permuted + noise).reshape(-1)

    return proposal


def single_mean_marginal(samples: jnp.ndarray, component: int = 0) -> jnp.ndarray:
    """Extract the (T, 2) marginal of one mean component (Fig. 4's view)."""
    t = samples.shape[0]
    return samples.reshape(t, -1, DIM)[:, component, :]


registry.register_model(
    registry.BayesModel(
        name="gmm",
        generate_data=generate_data,
        log_prior=log_prior,
        log_lik=log_lik,
        d=K_DEFAULT * DIM,
        default_n=50_000,
        default_sampler="rwmh",
        # only x is per-datum; mixture weights / component_std broadcast to
        # every shard (this retires the driver's old only=("x",) special-case)
        shard_keys=("x",),
    )
)
