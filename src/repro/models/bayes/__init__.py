"""Paper §8 experiment models, behind the ``BayesModel`` registry.

Every model exposes the same surface so the EP-MCMC driver is model-agnostic:

- ``generate_data(key, ...) -> (data, true_params)``
- ``log_prior(theta) -> ()``           (θ is a flat, unconstrained array)
- ``log_lik(theta, data) -> ()``       (summed over the data's leading axis)

plus model-specific extras (closed-form posteriors, Gibbs blocks, predictive
accuracy, label-permutation proposals). Importing this package registers every
built-in model with :mod:`repro.models.bayes.registry`; consumers (the
``mcmc_run`` pipeline, benchmarks) resolve them by name with
:func:`get_model` — the same architecture as ``repro.core.combiners`` and
``repro.samplers``.
"""

from repro.models.bayes import registry as registry  # noqa: F401
from repro.models.bayes.registry import (  # noqa: F401
    BayesModel,
    available_models,
    canonical_models,
    get_model,
    register_model,
)

from repro.models.bayes import gmm as gmm  # noqa: F401
from repro.models.bayes import linear_gaussian as linear_gaussian  # noqa: F401
from repro.models.bayes import logistic_regression as logistic_regression  # noqa: F401
from repro.models.bayes import poisson_gamma as poisson_gamma  # noqa: F401
