"""Bayesian linear regression with known noise — the exactness test oracle.

y = Xβ + ε, ε ~ N(0, σ²), prior β ~ N(0, τ² I).  The posterior is Gaussian in
closed form, *and* every subposterior p_m(β) ∝ N(β|0, Mτ² I)·N(y_m|X_m β, σ²)
is exactly Gaussian, so:

- the parametric combiner (Eqs. 3.1/3.2) recovers the full posterior exactly
  (up to Monte-Carlo error) — the strongest possible unit test of the
  combination formulas and of the 1/M prior weighting;
- the nonparametric/semiparametric combiners must converge to the same
  moments as T grows (asymptotic-exactness test, Thm 5.3).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.gaussian import GaussianMoments
from repro.models.bayes import registry

Data = Dict[str, jnp.ndarray]


def generate_data(
    key: jax.Array,
    n: int = 10_000,
    d: int = 10,
    noise_std: float = 1.0,
    dtype=jnp.float32,
) -> Tuple[Data, jnp.ndarray]:
    k_beta, k_x, k_eps = jax.random.split(key, 3)
    beta = jax.random.normal(k_beta, (d,), dtype)
    x = jax.random.normal(k_x, (n, d), dtype)
    y = x @ beta + noise_std * jax.random.normal(k_eps, (n,), dtype)
    return {"x": x, "y": y}, beta


def log_prior(theta: jnp.ndarray, tau: float = 3.0) -> jnp.ndarray:
    d = theta.shape[-1]
    return -0.5 * jnp.sum(theta**2) / tau**2 - 0.5 * d * jnp.log(2.0 * jnp.pi * tau**2)


def log_lik(theta: jnp.ndarray, data: Data, noise_std: float = 1.0) -> jnp.ndarray:
    resid = data["y"] - data["x"] @ theta
    n = data["y"].shape[0]
    return -0.5 * jnp.sum(resid**2) / noise_std**2 - 0.5 * n * jnp.log(
        2.0 * jnp.pi * noise_std**2
    )


def posterior_moments(
    data: Data, tau: float = 3.0, noise_std: float = 1.0
) -> GaussianMoments:
    """Exact posterior N(μ*, Σ*): Σ* = (I/τ² + XᵀX/σ²)⁻¹, μ* = Σ* Xᵀy/σ²."""
    x, y = data["x"], data["y"]
    d = x.shape[1]
    prec = jnp.eye(d) / tau**2 + (x.T @ x) / noise_std**2
    chol = jnp.linalg.cholesky(prec)
    mean = jax.scipy.linalg.cho_solve((chol, True), x.T @ y / noise_std**2)
    cov = jax.scipy.linalg.cho_solve((chol, True), jnp.eye(d))
    return GaussianMoments(mean=mean, cov=0.5 * (cov + cov.T))


def subposterior_moments(
    data_shard: Data, num_shards: int, tau: float = 3.0, noise_std: float = 1.0
) -> GaussianMoments:
    """Exact moments of one subposterior (prior underweighted to 1/M)."""
    x, y = data_shard["x"], data_shard["y"]
    d = x.shape[1]
    prec = jnp.eye(d) / (num_shards * tau**2) + (x.T @ x) / noise_std**2
    chol = jnp.linalg.cholesky(prec)
    mean = jax.scipy.linalg.cho_solve((chol, True), x.T @ y / noise_std**2)
    cov = jax.scipy.linalg.cho_solve((chol, True), jnp.eye(d))
    return GaussianMoments(mean=mean, cov=0.5 * (cov + cov.T))


registry.register_model(
    registry.BayesModel(
        name="linear",
        generate_data=generate_data,
        log_prior=log_prior,
        log_lik=log_lik,
        d=10,
        default_n=10_000,
        default_sampler="mala",
    ),
    "linear_gaussian",
)
