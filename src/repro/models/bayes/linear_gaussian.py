"""Bayesian linear regression with known noise — the exactness test oracle.

y = Xβ + ε, ε ~ N(0, σ²), prior β ~ N(0, τ² I).  The posterior is Gaussian in
closed form, *and* every subposterior p_m(β) ∝ N(β|0, Mτ² I)·N(y_m|X_m β, σ²)
is exactly Gaussian, so:

- the parametric combiner (Eqs. 3.1/3.2) recovers the full posterior exactly
  (up to Monte-Carlo error) — the strongest possible unit test of the
  combination formulas and of the 1/M prior weighting;
- the nonparametric/semiparametric combiners must converge to the same
  moments as T grows (asymptotic-exactness test, Thm 5.3).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.gaussian import GaussianMoments
from repro.models.bayes import registry

Data = Dict[str, jnp.ndarray]


def generate_data(
    key: jax.Array,
    n: int = 10_000,
    d: int = 10,
    noise_std: float = 1.0,
    dtype=jnp.float32,
) -> Tuple[Data, jnp.ndarray]:
    k_beta, k_x, k_eps = jax.random.split(key, 3)
    beta = jax.random.normal(k_beta, (d,), dtype)
    x = jax.random.normal(k_x, (n, d), dtype)
    y = x @ beta + noise_std * jax.random.normal(k_eps, (n,), dtype)
    return {"x": x, "y": y}, beta


def log_prior(theta: jnp.ndarray, tau: float = 3.0) -> jnp.ndarray:
    d = theta.shape[-1]
    return -0.5 * jnp.sum(theta**2) / tau**2 - 0.5 * d * jnp.log(2.0 * jnp.pi * tau**2)


def log_lik(theta: jnp.ndarray, data: Data, noise_std: float = 1.0) -> jnp.ndarray:
    resid = data["y"] - data["x"] @ theta
    n = data["y"].shape[0]
    return -0.5 * jnp.sum(resid**2) / noise_std**2 - 0.5 * n * jnp.log(
        2.0 * jnp.pi * noise_std**2
    )


def posterior_moments(
    data: Data, tau: float = 3.0, noise_std: float = 1.0
) -> GaussianMoments:
    """Exact posterior N(μ*, Σ*): Σ* = (I/τ² + XᵀX/σ²)⁻¹, μ* = Σ* Xᵀy/σ²."""
    x, y = data["x"], data["y"]
    d = x.shape[1]
    prec = jnp.eye(d) / tau**2 + (x.T @ x) / noise_std**2
    chol = jnp.linalg.cholesky(prec)
    mean = jax.scipy.linalg.cho_solve((chol, True), x.T @ y / noise_std**2)
    cov = jax.scipy.linalg.cho_solve((chol, True), jnp.eye(d))
    return GaussianMoments(mean=mean, cov=0.5 * (cov + cov.T))


def subposterior_moments(
    data_shard: Data, num_shards: int, tau: float = 3.0, noise_std: float = 1.0
) -> GaussianMoments:
    """Exact moments of one subposterior (prior underweighted to 1/M)."""
    x, y = data_shard["x"], data_shard["y"]
    d = x.shape[1]
    prec = jnp.eye(d) / (num_shards * tau**2) + (x.T @ x) / noise_std**2
    chol = jnp.linalg.cholesky(prec)
    mean = jax.scipy.linalg.cho_solve((chol, True), x.T @ y / noise_std**2)
    cov = jax.scipy.linalg.cho_solve((chol, True), jnp.eye(d))
    return GaussianMoments(mean=mean, cov=0.5 * (cov + cov.T))


# ---------------------------------------------------------------------------
# Gibbs path (conjugate coordinate blocks) — every sampler family covers the
# exactness oracle, so scenario matrices can cross it with gibbs too
# ---------------------------------------------------------------------------


def gibbs_blocks(
    data: Data,
    num_shards: int,
    n_blocks: int = 2,
    tau: float = 3.0,
    noise_std: float = 1.0,
    count=None,
):
    """Exact block-Gaussian Gibbs sweeps over β.

    The subposterior is Gaussian with precision A = I/(Mτ²) + XᵀX/σ² and
    shift b = Xᵀy/σ², so each coordinate block S has the closed-form full
    conditional β_S | β_₋S ~ N(A_SS⁻¹ (b_S − A_{S,₋S} β_₋S), A_SS⁻¹).
    Per-block Cholesky factors are precomputed from the shard (A is data,
    not state), leaving each sweep two triangular solves per block.

    ``count`` masks the edge-pad convention's replicated tail rows out of
    the sufficient statistics: with the 0/1 row weight w (wᵀw = w), the
    masked Gram is (w∘X)ᵀX and the masked shift (w∘X)ᵀy, so A and b are
    exactly those of the shard's first ``count`` real rows — the Gibbs
    counterpart of ``make_subposterior_logpdf(count=...)``. ``count=None``
    (or a count covering every row — w ≡ 1.0 multiplies exactly) leaves the
    statistics bit-identical to the unmasked path.
    """
    x, y = data["x"], data["y"]
    d = x.shape[1]
    if count is None:
        xw = x
    else:
        w = (jnp.arange(x.shape[0]) < count).astype(x.dtype)
        xw = x * w[:, None]
    A = jnp.eye(d) / (num_shards * tau**2) + (xw.T @ x) / noise_std**2
    b = xw.T @ y / noise_std**2
    bounds = [(i * d) // n_blocks for i in range(n_blocks)] + [d]

    def block_update(s0: int, s1: int):
        chol = jnp.linalg.cholesky(A[s0:s1, s0:s1])

        def update(key, beta):
            # residual shift with the own-block contribution added back
            r = b[s0:s1] - A[s0:s1] @ beta + A[s0:s1, s0:s1] @ beta[s0:s1]
            mu = jax.scipy.linalg.cho_solve((chol, True), r)
            z = jax.random.normal(key, (s1 - s0,))
            noise = jax.scipy.linalg.solve_triangular(chol.T, z, lower=False)
            return beta.at[s0:s1].set(mu + noise)

        return update

    return [block_update(s0, s1) for s0, s1 in zip(bounds[:-1], bounds[1:])]


def gibbs_init(key: jax.Array, data: Data) -> jnp.ndarray:
    return 0.01 * jax.random.normal(key, (data["x"].shape[1],))


registry.register_model(
    registry.BayesModel(
        name="linear",
        generate_data=generate_data,
        log_prior=log_prior,
        log_lik=log_lik,
        d=10,
        default_n=10_000,
        default_sampler="mala",
        # conjugate exact-conditional blocks: step_size is accepted for
        # registry-signature uniformity and ignored (no MH moves here);
        # count masks edge-padded rows so ragged shards sample exactly
        gibbs_blocks=lambda shard, num_shards, *, step_size=0.1, count=None:
            gibbs_blocks(shard, num_shards, count=count),
        gibbs_init=gibbs_init,
        gibbs_extract=lambda positions: positions,
        gibbs_counts=True,
    ),
    "linear_gaussian",
)
