"""Deterministic, shardable, resumable synthetic token stream.

Every batch is a pure function of ``(seed, step, shard_index)`` — no state to
checkpoint beyond the integer step, restart-safe by construction, and each
EP-MCMC chain group reads a *disjoint* shard (the paper's data partition).
A Zipf-ish marginal over the vocabulary makes CE trajectories non-degenerate.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp


class TokenStream:
    """Stateless batch source. ``batch(step) -> {"tokens", "labels"}``."""

    def __init__(
        self,
        vocab_size: int,
        batch_size: int,
        seq_len: int,
        *,
        seed: int = 0,
        shard_index: int = 0,
        num_shards: int = 1,
    ):
        self.vocab_size = vocab_size
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.seed = seed
        self.shard_index = shard_index
        self.num_shards = num_shards

    def batch(self, step: int | jnp.ndarray) -> Dict[str, jnp.ndarray]:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), self.shard_index),
            step,
        )
        # Zipf-ish marginal: u^4 pushes mass toward low token ids.
        u = jax.random.uniform(key, (self.batch_size, self.seq_len + 1))
        tokens = (u**4 * (self.vocab_size - 1)).astype(jnp.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def make_batch_specs(
    cfg,
    batch_size: int,
    seq_len: int,
    *,
    dtype=jnp.int32,
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one training batch of ``cfg``.

    Includes the modality-stub inputs ([audio]: encoder frame embeddings,
    [vlm]: patch embeddings) exactly as ``input_specs`` feeds the dry-run.
    """
    specs: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((batch_size, seq_len), dtype),
        "labels": jax.ShapeDtypeStruct((batch_size, seq_len), dtype),
    }
    if cfg.num_encoder_layers:
        specs["enc_frames"] = jax.ShapeDtypeStruct(
            (batch_size, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    if cfg.num_image_tokens:
        specs["img_embeds"] = jax.ShapeDtypeStruct(
            (batch_size, cfg.num_image_tokens, 1024), jnp.dtype(cfg.dtype)
        )
    return specs
