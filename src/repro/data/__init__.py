"""Data pipelines: deterministic synthetic token streams + Bayes generators."""

from repro.data.tokens import TokenStream, make_batch_specs  # noqa: F401
