"""Async client for the posterior server's newline-delimited-JSON protocol.

One request per line, one response per line, in order — so a single
connection is a serial query stream and concurrency comes from opening
more connections (what the probe pool in
:func:`repro.serve.server.serve_pipeline` and ``benchmarks/bench_serve.py``
do: one connection per concurrent reader).

    client = await ServeClient.connect(host, port)
    resp = await client.request("mean_cov", combiner="parametric")
    resp["result"]["mean"], resp["staleness"]["draws_seen"]
    await client.close()

:meth:`ServeClient.ask` additionally raises the typed :class:`ServeError`
on ``ok=False`` responses and returns just the ``result`` payload.
"""

from __future__ import annotations

import asyncio
import json
from typing import Any, Dict


class ServeError(RuntimeError):
    """An ``ok=False`` response, with the server's code/reason attached."""

    def __init__(self, error: Dict[str, Any], staleness: Dict[str, Any]):
        self.code = int(error.get("code", 500))
        self.reason = str(error.get("reason", "unknown"))
        self.staleness = staleness
        super().__init__(f"[{self.code}] {self.reason}")


class ServeClient:
    def __init__(self, reader: asyncio.StreamReader, writer: asyncio.StreamWriter):
        self._reader = reader
        self._writer = writer
        self._lock = asyncio.Lock()  # serialize request/response pairs

    @classmethod
    async def connect(cls, host: str, port: int) -> "ServeClient":
        reader, writer = await asyncio.open_connection(host, port)
        return cls(reader, writer)

    async def request(self, op: str, **params: Any) -> Dict[str, Any]:
        """Send one request, return the raw response dict (ok or not)."""
        payload = json.dumps({"op": op, **params}).encode() + b"\n"
        async with self._lock:
            self._writer.write(payload)
            await self._writer.drain()
            line = await self._reader.readline()
        if not line:
            raise ConnectionError("server closed the connection")
        return json.loads(line)

    async def ask(self, op: str, **params: Any) -> Dict[str, Any]:
        """Like :meth:`request`, but raise :class:`ServeError` on failures
        and unwrap the ``result`` payload."""
        resp = await self.request(op, **params)
        if not resp.get("ok"):
            raise ServeError(resp.get("error", {}), resp.get("staleness", {}))
        return resp["result"]

    async def close(self) -> None:
        self._writer.close()
        try:
            await self._writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass
