"""Posterior query handlers: request dict in, response dict out.

Every handler is a pure function of a :class:`~repro.serve.state.ServeState`
plus the request parameters — no asyncio, no transport — so the whole query
surface is unit-testable synchronously and the server's TCP loop is a thin
line-framing shell around :func:`answer`.

Query surface (``op`` field):

``mean_cov``
    Posterior mean and covariance of the current estimate cloud (plus the
    per-dimension marginal std).
``quantiles``
    Marginal quantiles per dimension at ``probs`` (default five-number-ish
    ``0.05/0.25/0.5/0.75/0.95``).
``draws``
    ``n`` predictive draws from the estimate cloud — a deterministic seeded
    subsample, so the same request against the same snapshot returns the
    same draws.
``logpdf``
    Unnormalized log posterior density at ``points`` via the batched
    machine-KDE scorer (PR 8): Σ_m log p̂_m on the accumulated draw buffer
    (``reduce="product"`` — the paper's subposterior-product density; also
    accepts ``"mixture"``).
``status``
    Staleness metadata only (no estimate required).

Responses are ``{"ok": True, "op", "combiner", "result", "staleness"}`` or
``{"ok": False, "error": {"code", "reason", ...}, "staleness"}``. The typed
:class:`~repro.core.combiners.api.EstimateUnavailable` maps to ``code=503``
(the combiner folds but cannot refresh — retry another name or wait for
completion); unknown ops/combiners/bad params map to ``code=400``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

from repro.core.combiners import EstimateUnavailable, counts_or_full
from repro.core.combiners.density import machine_kde_scores, masked_silverman
from repro.serve.state import ServeState

DEFAULT_PROBS = (0.05, 0.25, 0.5, 0.75, 0.95)


def handle_mean_cov(state: ServeState, name: str, params: Dict[str, Any]):
    snap = state.snapshot(name)
    return {
        "mean": snap.mean.tolist(),
        "cov": snap.cov.tolist(),
        "std": np.sqrt(np.clip(np.diag(snap.cov), 0.0, None)).tolist(),
        "n_estimate": int(snap.samples.shape[0]),
    }


def handle_quantiles(state: ServeState, name: str, params: Dict[str, Any]):
    probs = [float(p) for p in params.get("probs", DEFAULT_PROBS)]
    if not probs or any(not (0.0 <= p <= 1.0) for p in probs):
        raise ValueError(f"probs must lie in [0, 1], got {probs}")
    snap = state.snapshot(name)
    q = np.quantile(snap.samples, probs, axis=0)  # (P, d)
    return {"probs": probs, "quantiles": q.tolist()}


def handle_draws(state: ServeState, name: str, params: Dict[str, Any]):
    n = int(params.get("n", 16))
    if n <= 0:
        raise ValueError(f"n must be positive, got {n}")
    seed = int(params.get("seed", 0))
    snap = state.snapshot(name)
    # deterministic per (snapshot, seed): same request, same draws
    idx = np.random.default_rng(seed).integers(0, snap.samples.shape[0], size=n)
    return {"draws": snap.samples[idx].tolist(), "seed": seed}


def handle_logpdf(state: ServeState, name: str, params: Dict[str, Any]):
    import jax.numpy as jnp

    if "points" not in params:
        raise ValueError("logpdf needs 'points': one d-vector or a list of them")
    pts = np.asarray(params["points"], dtype=np.float32)
    if pts.ndim == 1:
        pts = pts[None, :]
    if pts.ndim != 2:
        raise ValueError(f"points must be (d,) or (Q, d), got shape {pts.shape}")
    reduce = str(params.get("reduce", "product"))
    if reduce not in ("product", "mixture"):
        raise ValueError(f"reduce must be 'product' or 'mixture', got {reduce!r}")
    theta, counts = state.logpdf_inputs()
    if pts.shape[1] != theta.shape[-1]:
        raise ValueError(
            f"points are {pts.shape[1]}-dimensional, posterior is "
            f"{theta.shape[-1]}-dimensional"
        )
    h = masked_silverman(theta, counts_or_full(theta, counts))
    scores = machine_kde_scores(
        jnp.asarray(pts), theta, counts, h, reduce=reduce
    )
    return {
        "log_density": np.asarray(scores).tolist(),
        "reduce": reduce,
        "normalized": False,  # Σ_m log p̂_m is the unnormalized product score
    }


def handle_status(state: ServeState, name: str, params: Dict[str, Any]):
    return {
        "combiners": list(state.setup.names),
        "ops": sorted(HANDLERS),
        "n_estimate": state.n_estimate,
    }


HANDLERS = {
    "mean_cov": handle_mean_cov,
    "quantiles": handle_quantiles,
    "draws": handle_draws,
    "predictive": handle_draws,  # alias
    "logpdf": handle_logpdf,
    "status": handle_status,
}


def answer(state: ServeState, request: Dict[str, Any]) -> Dict[str, Any]:
    """Dispatch one request dict; never raises — failures become typed
    ``{"ok": False, "error": ...}`` responses (still carrying staleness, so
    even a 503 tells the reader where the stream is)."""
    op = request.get("op")
    name: Optional[str] = request.get("combiner") or (
        state.setup.names[0] if state.setup.names else None
    )
    base: Dict[str, Any] = {"op": op, "combiner": name}
    if "id" in request:
        base["id"] = request["id"]
    try:
        handler = HANDLERS.get(op)
        if handler is None:
            raise KeyError(
                f"unknown op {op!r}; available: {sorted(HANDLERS)}"
            )
        result = handler(state, name, request)
        return {
            "ok": True, **base,
            "result": result,
            "staleness": state.staleness(name),
        }
    except EstimateUnavailable as exc:
        return {
            "ok": False, **base,
            "error": {"code": 503, "reason": exc.reason, "combiner": exc.combiner},
            "staleness": state.staleness(name),
        }
    except (KeyError, ValueError, TypeError) as exc:
        return {
            "ok": False, **base,
            "error": {"code": 400, "reason": str(exc)},
            "staleness": state.staleness(
                name if name in state.setup.names else None
            ),
        }
