"""Posterior-as-a-service: the asyncio request loop over the chunk stream.

:class:`PosteriorServer` wires three actors around one
:class:`~repro.serve.state.ServeState`:

- the **sampler** runs ``Pipeline.sample(on_chunk=...)`` in an executor
  thread — the unchanged chunk-emitting driver, checkpoint subscriber and
  all. Each landed chunk is pushed onto a *bounded* asyncio queue from the
  sampler thread; the push only blocks when the folder has fallen a full
  ``queue_depth`` chunks behind, which bounds how stale a reader's view can
  get (and is the only way serving ever slows sampling);
- the **folder task** drains the queue: every chunk is folded (chunks are
  NEVER dropped — the combine state must stay exact), but estimate
  refreshes are coalesced under backpressure: when more chunks are already
  queued, the refresh is skipped and counted in ``refreshes_dropped``, so
  the folder catches up at fold speed rather than refresh speed;
- **readers** — newline-delimited-JSON TCP connections (and the in-process
  :meth:`query`) — answer from the freshest
  :class:`~repro.serve.state.EstimateSnapshot` without ever touching the
  stream. Handler work runs in the executor so a heavy query (e.g. a big
  ``logpdf`` batch) never blocks the event loop.

Every response carries the staleness metadata contract
(``chunks_folded`` / ``draws_seen`` / ``last_fold_monotonic_s`` /
``spec_id`` — see :mod:`repro.serve.state`).

Degradation on restart: construct the Pipeline with its ``checkpoint_dir``
and the server resumes from the last checkpoint — the stream driver
re-emits the restored prefix as ``replayed=True`` chunks, the folder
rebuilds combine state bitwise from them, and the staleness counters keep
replays out of the double-counting (``draws_seen`` is a stream position).
Queries served during the replay answer from the checkpointed posterior —
graceful degradation to the last durable state, not an error.

:func:`serve_pipeline` is the synchronous driver behind ``mcmc_run
--serve`` and the CI smoke: start the server, optionally hammer it with
concurrent probe readers while sampling runs, assert staleness counters
monotone, and return a latency/throughput summary.
"""

from __future__ import annotations

import asyncio
import json
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.api.pipeline import Pipeline
from repro.api.streaming import StreamChunk
from repro.serve import handlers
from repro.serve.state import ServeState


class PosteriorServer:
    """Serve posterior queries from a live (or resuming) sampling run.

    Lifecycle: ``await start()`` → queries via TCP or :meth:`query` →
    ``await wait_complete()`` (sampling done, final refresh folded) →
    ``await stop()``. ``refresh="every"`` disables coalescing (every fold
    refreshes — the deterministic mode tests use); the default
    ``"coalesce"`` drops refreshes under backpressure, never chunks.
    """

    def __init__(
        self,
        pipeline: Pipeline,
        names: Optional[Tuple[str, ...]] = None,
        *,
        n_estimate: int = 128,
        queue_depth: int = 8,
        host: str = "127.0.0.1",
        port: int = 0,
        refresh: str = "coalesce",
        max_steps: Optional[int] = None,
        keep_draws: bool = True,
    ):
        if pipeline.spec.stream_every <= 0:
            raise ValueError(
                "PosteriorServer needs RunSpec.stream_every > 0 — with no "
                "chunk cadence the whole run lands as one chunk and there "
                "is nothing to serve mid-stream"
            )
        if refresh not in ("coalesce", "every"):
            raise ValueError(f"refresh must be 'coalesce' or 'every', got {refresh!r}")
        if queue_depth <= 0:
            raise ValueError(f"queue_depth must be positive, got {queue_depth}")
        self.pipeline = pipeline
        self.host = host
        self.port = int(port)  # replaced by the bound port after start()
        self.refresh = refresh
        self.max_steps = max_steps
        setup = pipeline.stream_setup(names)
        self.state = ServeState(
            setup,
            spec_id=pipeline.spec.spec_id,
            total_draws=pipeline.spec.T,
            n_estimate=n_estimate,
            keep_draws=keep_draws,
        )
        self._queue_depth = int(queue_depth)
        self._queue: Optional[asyncio.Queue] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._tcp: Optional[asyncio.base_events.Server] = None
        self._folder: Optional[asyncio.Task] = None
        self._sampler: Optional[asyncio.Future] = None
        self._complete = asyncio.Event()
        self.sample_s: Optional[float] = None  # sampler wall time (throughput)

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._queue = asyncio.Queue(maxsize=self._queue_depth)
        self._tcp = await asyncio.start_server(
            self._handle_conn, self.host, self.port
        )
        self.port = self._tcp.sockets[0].getsockname()[1]
        self._folder = asyncio.create_task(self._fold_loop())
        self._sampler = self._loop.run_in_executor(None, self._run_sampler)

    async def wait_complete(self) -> None:
        """Block until sampling finished AND the folder drained the queue
        (including the final refresh)."""
        await self._complete.wait()

    async def stop(self) -> None:
        if self._sampler is not None:
            await self._sampler  # the executor thread cannot be cancelled
        if self._folder is not None:
            await self._complete.wait()
            self._folder.cancel()
            try:
                await self._folder
            except asyncio.CancelledError:
                pass
        if self._tcp is not None:
            self._tcp.close()
            await self._tcp.wait_closed()

    # -- sampler thread → queue (backpressure boundary) ----------------------

    def _run_sampler(self) -> None:
        t0 = time.monotonic()
        try:
            self.pipeline.sample(
                max_steps=self.max_steps, on_chunk=(self._enqueue_chunk,)
            )
        finally:
            self.sample_s = time.monotonic() - t0
            asyncio.run_coroutine_threadsafe(
                self._queue.put(None), self._loop
            ).result()

    def _enqueue_chunk(self, ev: StreamChunk) -> None:
        # runs on the sampler thread: block only when the folder is a full
        # queue_depth of chunks behind — the server's staleness horizon
        asyncio.run_coroutine_threadsafe(self._queue.put(ev), self._loop).result()

    # -- folder task ---------------------------------------------------------

    async def _fold_loop(self) -> None:
        while True:
            ev = await self._queue.get()
            if ev is None:  # sampler done (this session)
                # final refresh: readers see the completed (or budgeted)
                # posterior even if every mid-stream refresh was coalesced
                await self._loop.run_in_executor(None, self.state.refresh)
                self._complete.set()
                self._queue.task_done()
                continue  # keep draining: a restart test may reuse the loop
            await self._loop.run_in_executor(None, self.state.fold, ev)
            if self.refresh == "every" or self._queue.empty():
                await self._loop.run_in_executor(None, self.state.refresh)
            else:
                self.state.note_dropped_refresh()
            self._queue.task_done()

    # -- readers -------------------------------------------------------------

    async def query(self, op: str, **params: Any) -> Dict[str, Any]:
        """In-process reader: same handlers, same staleness contract."""
        req = {"op": op, **params}
        return await self._loop.run_in_executor(
            None, handlers.answer, self.state, req
        )

    async def _handle_conn(
        self, reader: asyncio.StreamReader, writer: asyncio.StreamWriter
    ) -> None:
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                try:
                    req = json.loads(line)
                    if not isinstance(req, dict):
                        raise ValueError("request must be a JSON object")
                except ValueError as exc:
                    resp: Dict[str, Any] = {
                        "ok": False,
                        "error": {"code": 400, "reason": f"bad request: {exc}"},
                        "staleness": self.state.staleness(),
                    }
                else:
                    resp = await self._loop.run_in_executor(
                        None, handlers.answer, self.state, req
                    )
                writer.write(json.dumps(resp).encode() + b"\n")
                await writer.drain()
        except (ConnectionResetError, BrokenPipeError, asyncio.CancelledError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass


# ---------------------------------------------------------------------------
# synchronous driver (mcmc_run --serve, CI smoke, bench_serve)
# ---------------------------------------------------------------------------


def _percentile(sorted_vals: List[float], p: float) -> float:
    if not sorted_vals:
        return float("nan")
    i = min(len(sorted_vals) - 1, int(round(p * (len(sorted_vals) - 1))))
    return sorted_vals[i]


PROBE_OPS: Tuple[Dict[str, Any], ...] = (
    {"op": "mean_cov"},
    {"op": "quantiles"},
    {"op": "draws", "n": 8},
    {"op": "status"},
)


def serve_pipeline(
    pipeline: Pipeline,
    *,
    names: Optional[Tuple[str, ...]] = None,
    port: int = 0,
    probe_readers: int = 0,
    n_estimate: int = 128,
    queue_depth: int = 8,
    refresh: str = "coalesce",
    max_steps: Optional[int] = None,
    probe_logpdf: bool = True,
    probe_interval_s: float = 0.0,
    log=print,
) -> Dict[str, Any]:
    """Run a full serving session synchronously and return a summary.

    Starts a :class:`PosteriorServer` for ``pipeline``, optionally spawns
    ``probe_readers`` concurrent TCP readers that cycle posterior queries
    for as long as sampling runs (every reader asserts the staleness
    counters it observes are monotone — the CI smoke's contract), waits for
    completion, and returns ``{"port", "queries", "reader_p50_s",
    "reader_p99_s", "sample_s", "staleness", "probe_errors"}``.

    ``probe_interval_s > 0`` paces each reader to a steady offered load
    (one request per interval) instead of the default closed-loop hammer —
    the throughput bench uses this: an unpaced probe pool on a small CPU
    rig measures its own compute stealing the sampler's core, not serving
    overhead.
    """
    from repro.serve.client import ServeClient

    ops = list(PROBE_OPS)
    if probe_logpdf:
        d = pipeline._model.d
        ops.append({"op": "logpdf", "points": [[0.0] * d]})

    async def _probe(server: PosteriorServer, latencies: List[float],
                     errors: List[str], idx: int) -> int:
        client = await ServeClient.connect(server.host, server.port)
        served = 0
        last = (-1, -1)  # (chunks_folded, draws_seen) must be monotone
        try:
            while not server._complete.is_set():
                req = ops[(served + idx) % len(ops)]
                t0 = time.monotonic()
                resp = await client.request(**req)
                latencies.append(time.monotonic() - t0)
                served += 1
                st = resp.get("staleness", {})
                seen = (st.get("chunks_folded", 0), st.get("draws_seen", 0))
                if seen < last:
                    raise AssertionError(
                        f"staleness went backwards: {last} -> {seen}"
                    )
                last = seen
                if not resp.get("ok") and resp.get("error", {}).get("code") != 503:
                    errors.append(str(resp.get("error")))
                if probe_interval_s > 0:
                    await asyncio.sleep(probe_interval_s)
        finally:
            await client.close()
        return served

    async def _main() -> Dict[str, Any]:
        server = PosteriorServer(
            pipeline, names,
            n_estimate=n_estimate, queue_depth=queue_depth,
            port=port, refresh=refresh, max_steps=max_steps,
        )
        await server.start()
        log(f"serve: listening on {server.host}:{server.port} "
            f"(combiners: {', '.join(server.state.setup.names)})")
        latencies: List[float] = []
        errors: List[str] = []
        probes = [
            asyncio.create_task(_probe(server, latencies, errors, i))
            for i in range(probe_readers)
        ]
        await server.wait_complete()
        served = sum(await asyncio.gather(*probes)) if probes else 0
        # one last full round against the completed posterior
        final = {
            str(req["op"]): await server.query(**req) for req in ops
        }
        staleness = server.state.staleness(server.state.setup.names[0])
        await server.stop()
        lat = sorted(latencies)
        return {
            "port": server.port,
            "queries": served + len(ops),
            "reader_p50_s": _percentile(lat, 0.50),
            "reader_p99_s": _percentile(lat, 0.99),
            "sample_s": server.sample_s,
            "staleness": staleness,
            "probe_errors": errors,
            "final": final,
        }

    summary = asyncio.run(_main())
    if summary["probe_errors"]:
        raise RuntimeError(
            f"serve probe saw non-503 errors: {summary['probe_errors'][:3]}"
        )
    st = summary["staleness"]
    log(
        f"serve: {summary['queries']} queries answered "
        f"(p50 {summary['reader_p50_s'] * 1e3:.1f} ms, "
        f"p99 {summary['reader_p99_s'] * 1e3:.1f} ms) while folding "
        f"{st['chunks_folded']} chunks / {st['draws_seen']} draws "
        f"(replayed {st['chunks_replayed']}, "
        f"refreshes dropped {st['refreshes_dropped']}, "
        f"complete={st['complete']})"
    )
    return summary
