"""Deterministic serving state: per-combiner folds + staleness accounting.

:class:`ServeState` is the synchronous core of the posterior server — the
part that folds :class:`~repro.api.streaming.StreamChunk` events into
per-combiner :class:`~repro.core.combiners.api.StreamingCombiner` state and
refreshes cheap ``estimate`` snapshots readers answer from. It is built on
a :class:`~repro.api.pipeline.StreamSetup` (the *same* resolved combiners,
per-name RNG streams, and merged options ``Pipeline.stream_combine`` uses)
and refreshes with ``fold_in(key_name, draws_seen)`` — the trajectory key
discipline — so an estimate refreshed at draw boundary ``t`` is **bitwise**
the trajectory estimate ``stream_combine`` would have recorded at ``t``.

Keeping this core free of asyncio is what makes the serving layer's restart
semantics testable deterministically: tests fold the same chunk stream
through two ``ServeState`` instances (one interrupted+resumed, one not) and
compare snapshots bitwise, no event loop involved.

Staleness model (Terenin et al., *Asynchronous Gibbs Sampling*): readers may
consume arbitrarily stale combine state without a barrier — correctness
degrades gracefully with staleness rather than failing — provided every
response says *how* stale it is. :meth:`ServeState.staleness` is that
contract: ``chunks_folded`` / ``draws_seen`` / ``last_fold_monotonic_s`` on
every response, with replayed (post-restart) chunks counted separately and
never double-folded (``draws_seen`` tracks the stream *position* ``t1``, not
a cumulative sum, so a replay that rebuilds state leaves it unchanged).
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import numpy as np

from repro.api.pipeline import StreamSetup
from repro.api.streaming import StreamChunk
from repro.core.combiners import (
    BufferState,
    EstimateUnavailable,
    buffer_append,
    buffer_init,
    filter_options,
    streaming_estimate,
)


class EstimateSnapshot(NamedTuple):
    """One refreshed posterior estimate, host-resident (what readers see).

    ``samples`` is the ``(n_estimate, d)`` draw cloud the handlers reduce
    (mean/cov, quantiles, predictive draws); ``draws_seen`` is the stream
    position the estimate reflects — compare against the state's current
    ``draws_seen`` for the estimate's staleness in draws.
    """

    samples: np.ndarray  # (n_estimate, d)
    mean: np.ndarray  # (d,)
    cov: np.ndarray  # (d, d)
    draws_seen: int  # stream position (t1) this estimate reflects
    refreshed_monotonic_s: float


class ServeState:
    """Fold chunks, refresh estimates, answer staleness — thread-safe.

    ``fold`` is called by exactly one folder (the server's folder task, or a
    test driving ``pipe.sample(on_chunk=...)`` directly); ``snapshot`` /
    ``staleness`` / ``logpdf_inputs`` may be called concurrently from reader
    threads. A single lock guards the counters and the snapshot map — folds
    and refreshes are eager array ops outside the lock, so readers never
    wait on device work.

    ``keep_draws=False`` drops the shared draw buffer (no log-density
    queries, O(1) memory for moment-only combiners like ``online``).
    ``track_history=True`` records every refreshed estimate — the bitwise
    restart tests compare these against ``stream_combine`` trajectories.
    """

    def __init__(
        self,
        setup: StreamSetup,
        *,
        spec_id: str,
        total_draws: int,
        n_estimate: int = 128,
        keep_draws: bool = True,
        track_history: bool = False,
    ):
        self.setup = setup
        self.spec_id = spec_id
        self.total_draws = int(total_draws)
        self.n_estimate = int(n_estimate)
        self.keep_draws = keep_draws
        self.track_history = track_history
        self.history: List[Tuple[int, str, np.ndarray]] = []

        self._lock = threading.Lock()
        self._states: Dict[str, Any] = {name: None for name in setup.names}
        self._buffer: Optional[BufferState] = None
        self._snapshots: Dict[str, EstimateSnapshot] = {}
        self._chunks_folded = 0
        self._chunks_replayed = 0
        self._draws_seen = 0
        self._last_fold_monotonic_s: Optional[float] = None
        self._refreshes_dropped = 0

    # -- folding (one writer) ------------------------------------------------

    def fold(self, ev: StreamChunk) -> None:
        """Fold one landed chunk into every combiner state (+ draw buffer).

        Replayed chunks fold too — that is how post-restart state is rebuilt
        bitwise — but ``draws_seen`` is the stream *position* ``ev.t1``, so
        replays never double-count; they are tallied in ``chunks_replayed``.
        """
        M, _, d = ev.theta.shape
        for name in self.setup.names:
            sc = self.setup.combiners[name]
            if self._states[name] is None:
                self._states[name] = sc.init(M, d)
            self._states[name] = sc.update(self._states[name], ev.theta)
        if self.keep_draws:
            if self._buffer is None:
                self._buffer = buffer_init(M, d)
            self._buffer = buffer_append(self._buffer, ev.theta)
        landed = ev.landed_s if ev.landed_s is not None else time.monotonic()
        with self._lock:
            self._chunks_folded += 1
            if ev.replayed:
                self._chunks_replayed += 1
            self._draws_seen = int(ev.t1)
            self._last_fold_monotonic_s = landed

    def refresh(self, names: Optional[Tuple[str, ...]] = None) -> None:
        """Recompute the snapshot for each named combiner (default: all that
        can). Keys are ``fold_in(key_name, draws_seen)`` — the trajectory
        discipline — so refreshed estimates are bitwise ``stream_combine``'s
        rows at the same boundary. Names without a cheap ``estimate`` are
        skipped here (queries on them raise the typed failure instead)."""
        with self._lock:
            t1 = self._draws_seen
        if t1 <= 0:
            return
        for name in names if names is not None else self.setup.names:
            est_fn = self.setup.combiners[name].estimate
            if est_fn is None:
                continue
            k_est = jax.random.fold_in(self.setup.keys[name], t1)
            est = est_fn(
                k_est, self._states[name], self.n_estimate,
                **filter_options(est_fn, self.setup.options),
            )
            samples = np.asarray(est.samples)
            snap = EstimateSnapshot(
                samples=samples,
                mean=samples.mean(axis=0),
                cov=np.cov(samples, rowvar=False).reshape(
                    samples.shape[1], samples.shape[1]
                ),
                draws_seen=t1,
                refreshed_monotonic_s=time.monotonic(),
            )
            with self._lock:
                self._snapshots[name] = snap
            if self.track_history:
                self.history.append((t1, name, samples))

    def note_dropped_refresh(self) -> None:
        """Backpressure accounting: the folder skipped a refresh because
        chunks were queued behind it (chunks are never dropped)."""
        with self._lock:
            self._refreshes_dropped += 1

    # -- reading (many readers) ----------------------------------------------

    def snapshot(self, name: str) -> EstimateSnapshot:
        """The freshest estimate for ``name``; raises the typed
        :class:`EstimateUnavailable` when the combiner cannot estimate or
        nothing has been folded/refreshed yet."""
        if name not in self.setup.names:
            raise KeyError(
                f"combiner {name!r} not served; serving: {self.setup.names}"
            )
        streaming_estimate(name)  # typed EstimateUnavailable for finalize-only
        with self._lock:
            snap = self._snapshots.get(name)
        if snap is None:
            raise EstimateUnavailable(
                name, "no estimate refreshed yet — no chunks have landed"
            )
        return snap

    def logpdf_inputs(self) -> Tuple[Any, Any]:
        """``(theta, counts)`` of the shared draw buffer for KDE scoring
        (``counts=None`` when dense — the batch combiners' convention)."""
        from repro.core.combiners.api import buffer_batch_args

        if not self.keep_draws or self._buffer is None:
            raise EstimateUnavailable(
                "logpdf",
                "no draw buffer — nothing folded yet"
                if self.keep_draws
                else "server started with keep_draws=False",
            )
        return buffer_batch_args(self._buffer)

    def staleness(self, name: Optional[str] = None) -> Dict[str, Any]:
        """The metadata every response carries (see module docstring)."""
        with self._lock:
            out: Dict[str, Any] = {
                "spec_id": self.spec_id,
                "chunks_folded": self._chunks_folded,
                "chunks_replayed": self._chunks_replayed,
                "draws_seen": self._draws_seen,
                "total_draws": self.total_draws,
                "complete": self._draws_seen >= self.total_draws,
                "last_fold_monotonic_s": self._last_fold_monotonic_s,
                "refreshes_dropped": self._refreshes_dropped,
            }
            snap = self._snapshots.get(name) if name is not None else None
        if name is not None:
            out["combiner"] = name
            if snap is not None:
                out["estimate_draws_seen"] = snap.draws_seen
                out["estimate_age_draws"] = out["draws_seen"] - snap.draws_seen
        return out
