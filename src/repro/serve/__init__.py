"""repro.serve — posterior-as-a-service on the streaming combine engine.

The paper's machines sample independently and communicate only at
combination time (§3/§4); PRs 5–8 built the engine that *folds* chunks as
they land. This package is the layer that **serves** the evolving posterior
while the chains still extend — the north-star "heavy traffic from millions
of users" shape of ROADMAP item 1:

- :class:`~repro.serve.state.ServeState` — the deterministic core: folds
  :class:`~repro.api.streaming.StreamChunk` events through the same
  :class:`~repro.api.pipeline.StreamSetup` surfaces ``stream_combine``
  uses, refreshes cheap per-combiner estimates with the trajectory RNG
  discipline (bitwise ``stream_combine``'s rows), and owns the staleness
  counters every response carries;
- :mod:`~repro.serve.handlers` — the pure query surface (``mean_cov``,
  ``quantiles``, ``draws``, ``logpdf`` via the PR-8 batched machine-KDE
  scorer, ``status``), typed 503s for combiners that cannot estimate
  (:class:`~repro.core.combiners.api.EstimateUnavailable`);
- :class:`~repro.serve.server.PosteriorServer` — the asyncio loop: sampler
  in an executor thread feeding a bounded chunk queue, a folder task that
  never drops chunks but coalesces estimate refreshes under backpressure,
  and TCP/in-process readers answering from the freshest snapshot;
- :class:`~repro.serve.client.ServeClient` — the matching
  newline-delimited-JSON client.

Readers consume *stale* combine state without a barrier — principled per
Terenin et al.'s Asynchronous Gibbs analysis — so every response reports
``chunks_folded`` / ``draws_seen`` / ``last_fold_monotonic_s`` / ``spec_id``.
Restart degrades gracefully to the last checkpoint: build the Pipeline with
its ``checkpoint_dir`` and the server rebuilds state from replayed
(``replayed=True``) chunks without double-counting.

Quickstart (also ``python -m repro.launch.mcmc_run ... --serve``)::

    from repro.api import Pipeline, RunSpec
    from repro.serve import serve_pipeline

    spec = RunSpec(model="linear", sampler="mala", M=4, T=2000,
                   stream_every=100, combiner=("parametric", "online"))
    serve_pipeline(Pipeline(spec), probe_readers=8)

Not to be confused with :mod:`repro.launch.serve`, the LM prefill/decode
driver — this package serves *posteriors*, not tokens.
"""

from repro.serve.client import ServeClient, ServeError  # noqa: F401
from repro.serve.handlers import HANDLERS, answer  # noqa: F401
from repro.serve.server import PosteriorServer, serve_pipeline  # noqa: F401
from repro.serve.state import EstimateSnapshot, ServeState  # noqa: F401

__all__ = [
    "EstimateSnapshot",
    "HANDLERS",
    "PosteriorServer",
    "ServeClient",
    "ServeError",
    "ServeState",
    "answer",
    "serve_pipeline",
]
