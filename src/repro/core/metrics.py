"""Evaluation metrics — the paper's L2 density distance (§8) + ESS/MMD.

The paper measures ``d₂(p, p̂) = ‖p − p̂‖₂`` between the groundtruth posterior
and a proposed posterior, both represented by samples. With Gaussian-KDE
density estimates this has a *closed form* in the kernel cross-terms (no grid):

  ‖p̂ − q̂‖₂² = 1/T² ΣΣ N(xᵢ−xⱼ | 0, 2h₁²I) + 1/S² ΣΣ N(yᵢ−yⱼ | 0, 2h₂²I)
              − 2/(TS) ΣΣ N(xᵢ−yⱼ | 0, (h₁²+h₂²)I)

Each double sum is a pairwise-Gaussian reduction — the exact computation the
``repro.kernels.kde_density`` Pallas kernel tiles (flash-style streaming
logsumexp, no (T,S) matrix in HBM). The jnp implementation here is chunked so
CPU tests stay in memory.
"""

from __future__ import annotations

import math

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import bandwidth as bw

# host-side, not jnp.log(...): module import must not run a JAX
# computation (jax.distributed.initialize refuses to start after one)
_LOG2PI = math.log(2.0 * math.pi)


def log_mean_gaussian_cross(
    x: jnp.ndarray, y: jnp.ndarray, var: jnp.ndarray | float, *, chunk: int = 512
) -> jnp.ndarray:
    """log [ 1/(TS) ΣΣ N(xᵢ − yⱼ | 0, var·I) ] computed in row chunks.

    x ``(T, d)``, y ``(S, d)``. Stable via a single global logsumexp performed
    over per-chunk partial logsumexps.
    """
    T, d = x.shape
    S = y.shape[0]
    var = jnp.asarray(var, x.dtype)
    log_norm = -0.5 * d * (jnp.log(var) + _LOG2PI)
    pad = (-T) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    valid = jnp.pad(jnp.ones((T,), x.dtype), (0, pad))
    xb = xp.reshape(-1, chunk, d)
    vb = valid.reshape(-1, chunk)

    def row_block(xc, vc):
        # (chunk, S) squared distances via ‖x‖² + ‖y‖² − 2x·y
        sq = (
            jnp.sum(xc**2, -1)[:, None]
            + jnp.sum(y**2, -1)[None, :]
            - 2.0 * xc @ y.T
        )
        logk = -0.5 * sq / var
        block_lse = jax.scipy.special.logsumexp(logk, axis=(0, 1), b=vc[:, None])
        return block_lse

    block_lses = jax.lax.map(lambda args: row_block(*args), (xb, vb))
    total = jax.scipy.special.logsumexp(block_lses)
    return total + log_norm - jnp.log(jnp.asarray(T * S, x.dtype))


def l2_distance(
    p_samples: jnp.ndarray,
    q_samples: jnp.ndarray,
    *,
    h_p: Optional[float] = None,
    h_q: Optional[float] = None,
    chunk: int = 512,
) -> jnp.ndarray:
    """Paper's d₂(p, q) between two sample sets via Gaussian-KDE closed form.

    Bandwidths default to Silverman's rule per sample set.
    """
    hp = bw.silverman(p_samples) if h_p is None else jnp.asarray(h_p)
    hq = bw.silverman(q_samples) if h_q is None else jnp.asarray(h_q)
    t_pp = log_mean_gaussian_cross(p_samples, p_samples, 2.0 * hp**2, chunk=chunk)
    t_qq = log_mean_gaussian_cross(q_samples, q_samples, 2.0 * hq**2, chunk=chunk)
    t_pq = log_mean_gaussian_cross(p_samples, q_samples, hp**2 + hq**2, chunk=chunk)
    # ∫(p̂−q̂)² = e^{t_pp} + e^{t_qq} − 2 e^{t_pq}; do it in a stable scaled
    # space and return in LOG-SQRT form folded back at f64 precision — at
    # d≈50 the KDE normalizer (2πh²)^{−d/2} overflows f32 (paper §8.1.3
    # plots exactly this regime).
    m = jnp.maximum(jnp.maximum(t_pp, t_qq), t_pq)
    val = jnp.exp(t_pp - m) + jnp.exp(t_qq - m) - 2.0 * jnp.exp(t_pq - m)
    log_d2 = 0.5 * (jnp.log(jnp.maximum(val, 1e-38)) + m)
    return jnp.exp(log_d2)  # may overflow f32 beyond d≈40 — use log_l2_distance


def log_l2_distance(
    p_samples: jnp.ndarray,
    q_samples: jnp.ndarray,
    *,
    h_p: Optional[float] = None,
    h_q: Optional[float] = None,
    chunk: int = 512,
) -> jnp.ndarray:
    """log d₂(p, q) — overflow-proof form for high-d comparisons."""
    hp = bw.silverman(p_samples) if h_p is None else jnp.asarray(h_p)
    hq = bw.silverman(q_samples) if h_q is None else jnp.asarray(h_q)
    t_pp = log_mean_gaussian_cross(p_samples, p_samples, 2.0 * hp**2, chunk=chunk)
    t_qq = log_mean_gaussian_cross(q_samples, q_samples, 2.0 * hq**2, chunk=chunk)
    t_pq = log_mean_gaussian_cross(p_samples, q_samples, hp**2 + hq**2, chunk=chunk)
    m = jnp.maximum(jnp.maximum(t_pp, t_qq), t_pq)
    val = jnp.exp(t_pp - m) + jnp.exp(t_qq - m) - 2.0 * jnp.exp(t_pq - m)
    return 0.5 * (jnp.log(jnp.maximum(val, 1e-38)) + m)


def kde_logpdf(
    queries: jnp.ndarray, samples: jnp.ndarray, h: jnp.ndarray | float, *, chunk: int = 512
) -> jnp.ndarray:
    """log p̂(queries) under the Gaussian KDE of ``samples`` with bandwidth h.

    queries ``(Q, d)``, samples ``(T, d)`` → ``(Q,)``. Chunked over queries;
    Pallas-accelerated variant in ``repro.kernels.kde_density``.
    """
    Q, d = queries.shape
    T = samples.shape[0]
    h = jnp.asarray(h, queries.dtype)
    log_norm = -0.5 * d * (2.0 * jnp.log(h) + _LOG2PI) - jnp.log(jnp.asarray(T, queries.dtype))
    pad = (-Q) % chunk
    qp = jnp.pad(queries, ((0, pad), (0, 0))).reshape(-1, chunk, d)

    def block(qc):
        sq = (
            jnp.sum(qc**2, -1)[:, None]
            + jnp.sum(samples**2, -1)[None, :]
            - 2.0 * qc @ samples.T
        )
        return jax.scipy.special.logsumexp(-0.5 * sq / h**2, axis=1)

    out = jax.lax.map(block, qp).reshape(-1)[:Q]
    return out + log_norm


def effective_sample_size(chain: jnp.ndarray) -> jnp.ndarray:
    """ESS of a 1-d chain via FFT autocorrelation + Geyer initial positive pairs."""
    n = chain.shape[0]
    x = chain - jnp.mean(chain)
    nfft = 2 * n
    f = jnp.fft.rfft(x, nfft)
    acov = jnp.fft.irfft(f * jnp.conj(f), nfft)[:n].real / n
    rho = acov / acov[0]
    # Geyer: sum consecutive pairs Γ_k = ρ_{2k}+ρ_{2k+1}; truncate at first Γ<0.
    n_pairs = n // 2
    gamma = rho[0 : 2 * n_pairs : 2] + rho[1 : 2 * n_pairs : 2]
    positive = jnp.cumprod(gamma > 0.0)
    tau = -1.0 + 2.0 * jnp.sum(jnp.where(positive, gamma, 0.0))
    return n / jnp.maximum(tau, 1.0)


@partial(jax.jit, static_argnames=("chunk",))
def mmd2_rbf(
    x: jnp.ndarray, y: jnp.ndarray, lengthscale: float | jnp.ndarray, *, chunk: int = 512
) -> jnp.ndarray:
    """Biased MMD² with an RBF kernel (sanity-check metric alongside d₂)."""
    v = 2.0 * jnp.asarray(lengthscale) ** 2

    def mean_k(a, b):
        lse = log_mean_gaussian_cross(a, b, v, chunk=chunk)
        d = a.shape[-1]
        # undo the Gaussian normalizer so k(0)=1
        return jnp.exp(lse + 0.5 * d * (jnp.log(v) + _LOG2PI))

    return mean_k(x, x) + mean_k(y, y) - 2.0 * mean_k(x, y)
