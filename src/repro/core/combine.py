"""Subposterior sample combination — paper §3 (the core contribution).

Implemented procedures
----------------------
- :func:`parametric`          §3.1  Gaussian (BvM) product — approximate, fast
- :func:`nonparametric_img`   §3.2  Algorithm 1 — asymptotically exact
- :func:`semiparametric_img`  §3.3  Hjort–Glad product — asymptotically exact,
                               with both weight variants (W_t and w_t)
- :func:`subpost_average`     §8    "subpostAvg" baseline (uniform average)
- :func:`consensus_weighted`  §7    Consensus Monte Carlo (Scott et al.) baseline
- :func:`pool`                §8    "subpostPool" baseline (sample union)

Layout: subposterior samples are a dense array ``(M, T, d)``. Ragged sample
counts (straggler chains — paper footnote 1) are supported via ``counts (M,)``:
chain m's valid samples are rows ``[0, counts[m])``.

Complexity note (beyond-paper, algebraically exact): Algorithm 1 as written
recomputes ``w_t`` from scratch per proposal — O(dTM²) total. We maintain the
running component mean θ̄_t and Σ_m‖θ^m_{t_m}‖² incrementally, using

    Σ_m ‖θ_m − θ̄‖²  =  Σ_m ‖θ_m‖²  −  M·‖θ̄‖²,

so each single-index proposal is O(d) and the whole run is O(dTM) — the same
asymptotic cost the paper only achieves with the pairwise-tree variant, but
with *zero* change to the sampled distribution. The pairwise tree
(:mod:`repro.core.tree_combine`) is still provided (it additionally improves
IMG acceptance); brute-force weight evaluation lives in
:func:`log_weight_bruteforce` as the test oracle and is what the Pallas kernel
``repro.kernels.img_weights`` accelerates for batched/vectorized use.

Bandwidth convention: the Gaussian kernel is ``N(θ | θ^m_{t_m}, h² I_d)``; the
paper's §3.3 occasionally writes ``h`` where dimensional consistency requires
``h²`` — we use ``h²`` throughout (matching §3.2 and the annealed schedule).
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bandwidth as bw
from repro.core.gaussian import (
    GaussianMoments,
    fit_moments,
    log_normal_pdf,
    product_moments,
    product_moments_diag,
    sample_gaussian,
)

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


class CombineResult(NamedTuple):
    """Output of a combination procedure."""

    samples: jnp.ndarray  # (n_draws, d) draws from the density-product estimate
    acceptance_rate: jnp.ndarray  # IMG acceptance rate (1.0 for non-MCMC combiners)
    moments: Optional[GaussianMoments] = None  # parametric product moments if computed


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _counts_or_full(samples: jnp.ndarray, counts: Optional[jnp.ndarray]) -> jnp.ndarray:
    M, T, _ = samples.shape
    if counts is None:
        return jnp.full((M,), T, dtype=jnp.int32)
    return counts.astype(jnp.int32)


def _masks(samples: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    _, T, _ = samples.shape
    return (jnp.arange(T)[None, :] < counts[:, None]).astype(samples.dtype)  # (M, T)


def log_weight_bruteforce(theta_sel: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Unnormalized log w_t (Eq. 3.5) for selected samples ``(..., M, d)``.

    log w_t = Σ_m log N(θ^m | θ̄, h² I) — the test oracle for the incremental
    update and the reference for the Pallas kernel.
    """
    mean = jnp.mean(theta_sel, axis=-2, keepdims=True)
    sse = jnp.sum((theta_sel - mean) ** 2, axis=(-1, -2))
    m, d = theta_sel.shape[-2], theta_sel.shape[-1]
    return -0.5 * sse / (h**2) - m * (d / 2.0) * jnp.log(2.0 * jnp.pi * h**2)


# ---------------------------------------------------------------------------
# §3.1 parametric
# ---------------------------------------------------------------------------


def parametric(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    diag: bool = False,
) -> CombineResult:
    """Sample from the Gaussian product estimate (Eqs. 3.1–3.2)."""
    M, T, d = samples.shape
    counts = _counts_or_full(samples, counts)
    masks = _masks(samples, counts)
    moments = jax.vmap(lambda s, mk: fit_moments(s, mk, diag=diag))(samples, masks)
    if diag:
        prod = product_moments_diag(moments.mean, moments.cov)
    else:
        prod = product_moments(moments.mean, moments.cov)
    draws = sample_gaussian(key, prod, n_draws)
    return CombineResult(samples=draws, acceptance_rate=jnp.ones(()), moments=prod)


# ---------------------------------------------------------------------------
# §3.2 nonparametric — Algorithm 1 (IMG over mixture indices)
# ---------------------------------------------------------------------------


class _ImgCarry(NamedTuple):
    key: jax.Array
    t_idx: jnp.ndarray  # (M,) current component indices
    theta_sel: jnp.ndarray  # (M, d) samples[m, t_idx[m]]
    mean: jnp.ndarray  # (d,) running θ̄_t
    sumsq: jnp.ndarray  # () running Σ_m ‖θ^m_{t_m}‖²
    extra: jnp.ndarray  # () running Σ_m aux[m, t_m] (semiparametric term3; 0 o.w.)
    n_accept: jnp.ndarray  # () accepted proposals


def _init_img_carry(
    key: jax.Array,
    samples: jnp.ndarray,
    counts: jnp.ndarray,
    aux: Optional[jnp.ndarray],
) -> _ImgCarry:
    M, T, d = samples.shape
    key, sub = jax.random.split(key)
    t0 = jax.random.randint(sub, (M,), 0, counts)  # Alg 1 line 1
    theta_sel = jnp.take_along_axis(samples, t0[:, None, None], axis=1)[:, 0, :]
    extra = jnp.zeros(()) if aux is None else jnp.sum(aux[jnp.arange(M), t0])
    return _ImgCarry(
        key=key,
        t_idx=t0,
        theta_sel=theta_sel,
        mean=jnp.mean(theta_sel, axis=0),
        sumsq=jnp.sum(theta_sel**2),
        extra=extra,
        n_accept=jnp.zeros(()),
    )


def _img_gibbs_sweep(
    carry: _ImgCarry,
    samples: jnp.ndarray,
    counts: jnp.ndarray,
    h: jnp.ndarray,
    aux: Optional[jnp.ndarray],
    extra_logweight: Optional[Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]],
) -> _ImgCarry:
    """One sweep of Alg 1 lines 4–11: propose a new index for each m in turn.

    ``aux`` (M, T): per-sample additive log-weight terms, gathered incrementally
    (semiparametric −log N(θ^m_t | μ̂_m, Σ̂_m); None ⇒ 0).
    ``extra_logweight(mean, extra_sum)``: state-level additive log-weight (the
    semiparametric log N(θ̄ | μ̂_M, Σ̂_M + h²/M I) term; None ⇒ 0).
    """
    M, T, d = samples.shape
    inv_m = 1.0 / M

    def log_w(mean, sumsq, extra):
        sse = sumsq - M * jnp.sum(mean**2)
        lw = -0.5 * sse / (h**2)
        if extra_logweight is not None:
            lw = lw + extra_logweight(mean, extra)
        return lw

    def body(carry: _ImgCarry, m: jnp.ndarray) -> Tuple[_ImgCarry, None]:
        key, k_prop, k_acc = jax.random.split(carry.key, 3)
        c_m = jax.random.randint(k_prop, (), 0, counts[m])  # line 6
        theta_new = samples[m, c_m]
        theta_old = carry.theta_sel[m]
        mean_new = carry.mean + (theta_new - theta_old) * inv_m
        sumsq_new = carry.sumsq + jnp.sum(theta_new**2) - jnp.sum(theta_old**2)
        extra_new = (
            carry.extra
            if aux is None
            else carry.extra - aux[m, carry.t_idx[m]] + aux[m, c_m]
        )
        log_ratio = log_w(mean_new, sumsq_new, extra_new) - log_w(
            carry.mean, carry.sumsq, carry.extra
        )
        accept = jnp.log(jax.random.uniform(k_acc)) < log_ratio  # lines 7–8
        new_carry = _ImgCarry(
            key=key,
            t_idx=jnp.where(accept, carry.t_idx.at[m].set(c_m), carry.t_idx),
            theta_sel=jnp.where(accept, carry.theta_sel.at[m].set(theta_new), carry.theta_sel),
            mean=jnp.where(accept, mean_new, carry.mean),
            sumsq=jnp.where(accept, sumsq_new, carry.sumsq),
            extra=jnp.where(accept, extra_new, carry.extra),
            n_accept=carry.n_accept + accept,
        )
        return new_carry, None

    carry, _ = jax.lax.scan(body, carry, jnp.arange(M))
    return carry


def nonparametric_img(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    schedule: Optional[Schedule] = None,
    rescale: bool = False,
) -> CombineResult:
    """Algorithm 1 — asymptotically exact sampling from ∏_m KDE(p_m).

    ``schedule``: bandwidth h_i (defaults to the paper's annealed i^{-1/(4+d)}).
    ``rescale``: multiply the schedule by the pooled sample std (production
    robustness; off by default = verbatim Algorithm 1).
    """
    M, T, d = samples.shape
    counts = _counts_or_full(samples, counts)
    if schedule is None:
        scale = bw.pooled_scale(samples) if rescale else 1.0
        schedule = bw.annealed(d, scale=scale)

    carry = _init_img_carry(key, samples, counts, aux=None)

    def step(carry: _ImgCarry, i: jnp.ndarray):
        h = schedule(i + 1).astype(samples.dtype)  # line 3 (1-based)
        carry = _img_gibbs_sweep(carry, samples, counts, h, None, None)
        key, k_draw = jax.random.split(carry.key)
        carry = carry._replace(key=key)
        # line 12: θ_i ~ N(θ̄_t, h²/M I)
        theta = carry.mean + jax.random.normal(k_draw, (d,), samples.dtype) * h / jnp.sqrt(
            jnp.asarray(M, samples.dtype)
        )
        return carry, theta

    carry, draws = jax.lax.scan(step, carry, jnp.arange(n_draws))
    return CombineResult(
        samples=draws, acceptance_rate=carry.n_accept / (n_draws * M), moments=None
    )


# ---------------------------------------------------------------------------
# §3.3 semiparametric
# ---------------------------------------------------------------------------


def semiparametric_img(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    schedule: Optional[Schedule] = None,
    rescale: bool = False,
    nonparametric_weights: bool = False,
) -> CombineResult:
    """§3.3 semiparametric combiner.

    Components are N(μ_t, Σ_t) with  Σ_t = (M/h² I + Σ̂_M^{-1})^{-1},
    μ_t = Σ_t (M/h² θ̄_t + Σ̂_M^{-1} μ̂_M).

    ``nonparametric_weights=False``: IMG weights W_t (paper's primary §3.3 form)
        log W_t = log w_t + log N(θ̄_t | μ̂_M, Σ̂_M + h²/M I)
                  − Σ_m log N(θ^m_{t_m} | μ̂_m, Σ̂_m).
    ``nonparametric_weights=True``: the paper's second variant — weights w_t
        (higher IMG acceptance), same semiparametric components.
    """
    M, T, d = samples.shape
    counts = _counts_or_full(samples, counts)
    masks = _masks(samples, counts)
    if schedule is None:
        scale = bw.pooled_scale(samples) if rescale else 1.0
        schedule = bw.annealed(d, scale=scale)

    # Parametric start: per-subposterior moments and their Gaussian product.
    moments = jax.vmap(lambda s, mk: fit_moments(s, mk))(samples, masks)
    prod = product_moments(moments.mean, moments.cov)
    lam_m = jnp.linalg.inv(prod.cov + 1e-10 * jnp.eye(d))  # Σ̂_M^{-1}
    eta_m = lam_m @ prod.mean  # Σ̂_M^{-1} μ̂_M

    if nonparametric_weights:
        aux = None
        extra_lw = None
    else:
        # term3: −Σ_m log N(θ^m_{t_m} | μ̂_m, Σ̂_m), gathered incrementally.
        aux = -jax.vmap(lambda s, mom: log_normal_pdf(s, mom[0], mom[1]))(
            samples, (moments.mean, moments.cov)
        )  # (M, T)

    carry = _init_img_carry(key, samples, counts, aux=aux)

    def step(carry: _ImgCarry, i: jnp.ndarray):
        h = schedule(i + 1).astype(samples.dtype)
        h2 = h**2
        if nonparametric_weights:
            extra_lw_i = None
        else:
            cov_i = prod.cov + (h2 / M) * jnp.eye(d)

            def extra_lw_i(mean, extra_sum):
                # + log N(θ̄ | μ̂_M, Σ̂_M + h²/M I)  + Σ_m aux  (aux already −logN)
                return log_normal_pdf(mean, prod.mean, cov_i) + extra_sum

        carry = _img_gibbs_sweep(carry, samples, counts, h, aux, extra_lw_i)
        key, k_draw = jax.random.split(carry.key)
        carry = carry._replace(key=key)
        # Draw from the semiparametric component N(μ_t, Σ_t) via the precision
        # form: P = M/h² I + Λ_M, θ = μ_t + chol(P)^{-T} ε.
        prec = (M / h2) * jnp.eye(d) + lam_m
        chol_p = jnp.linalg.cholesky(prec)
        rhs = (M / h2) * carry.mean + eta_m
        mu_t = jax.scipy.linalg.cho_solve((chol_p, True), rhs)
        eps = jax.random.normal(k_draw, (d,), samples.dtype)
        theta = mu_t + jax.scipy.linalg.solve_triangular(chol_p.T, eps, lower=False)
        return carry, theta

    carry, draws = jax.lax.scan(step, carry, jnp.arange(n_draws))
    return CombineResult(
        samples=draws, acceptance_rate=carry.n_accept / (n_draws * M), moments=prod
    )


# ---------------------------------------------------------------------------
# §7/§8 baselines
# ---------------------------------------------------------------------------


def subpost_average(
    samples: jnp.ndarray, *, counts: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """"subpostAvg": θ_t = (1/M) Σ_m θ^m_t — one aligned draw per machine.

    With ragged counts, index t wraps modulo counts[m] so every machine always
    contributes (the baseline stays defined under stragglers).
    """
    M, T, d = samples.shape
    counts = _counts_or_full(samples, counts)
    idx = jnp.arange(T)[None, :] % counts[:, None]  # (M, T)
    gathered = jnp.take_along_axis(samples, idx[:, :, None], axis=1)
    return jnp.mean(gathered, axis=0)


def consensus_weighted(
    samples: jnp.ndarray, *, counts: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Consensus Monte Carlo (Scott et al. 2013): precision-weighted averaging

        θ_t = (Σ_m Σ̂_m^{-1})^{-1} Σ_m Σ̂_m^{-1} θ^m_t.

    The paper (§7) views this as a relaxation of Algorithm 1; it is one of the
    experimental baselines.
    """
    M, T, d = samples.shape
    counts = _counts_or_full(samples, counts)
    masks = _masks(samples, counts)
    moments = jax.vmap(lambda s, mk: fit_moments(s, mk))(samples, masks)
    precs = jax.vmap(lambda c: jnp.linalg.inv(c + 1e-10 * jnp.eye(d)))(moments.cov)
    total = jnp.sum(precs, axis=0)
    chol = jnp.linalg.cholesky(total)
    idx = jnp.arange(T)[None, :] % counts[:, None]
    gathered = jnp.take_along_axis(samples, idx[:, :, None], axis=1)  # (M, T, d)
    weighted = jnp.einsum("mij,mtj->ti", precs, gathered)
    return jax.scipy.linalg.cho_solve((chol, True), weighted.T).T


def pool(samples: jnp.ndarray, *, counts: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """"subpostPool": the union of all subposterior samples.

    Ragged counts: invalid rows are replaced by wrapping valid ones so the
    output stays a dense ``(M·T, d)`` array.
    """
    M, T, d = samples.shape
    counts = _counts_or_full(samples, counts)
    idx = jnp.arange(T)[None, :] % counts[:, None]
    gathered = jnp.take_along_axis(samples, idx[:, :, None], axis=1)
    return gathered.reshape(M * T, d)


# ---------------------------------------------------------------------------
# Online parametric combiner (paper §4: combine as samples stream in)
# ---------------------------------------------------------------------------


class OnlineMoments(NamedTuple):
    """Welford running moments per subposterior — O(d²) state, O(1) per sample."""

    count: jnp.ndarray  # (M,)
    mean: jnp.ndarray  # (M, d)
    m2: jnp.ndarray  # (M, d, d) sum of outer products of residuals


def online_init(M: int, d: int, dtype=jnp.float32) -> OnlineMoments:
    return OnlineMoments(
        count=jnp.zeros((M,), dtype),
        mean=jnp.zeros((M, d), dtype),
        m2=jnp.zeros((M, d, d), dtype),
    )


def online_update(state: OnlineMoments, m: jnp.ndarray, theta: jnp.ndarray) -> OnlineMoments:
    """Fold one new sample ``theta`` (d,) from machine ``m`` into the moments."""
    n = state.count[m] + 1.0
    delta = theta - state.mean[m]
    mean_m = state.mean[m] + delta / n
    m2_m = state.m2[m] + jnp.outer(delta, theta - mean_m)
    return OnlineMoments(
        count=state.count.at[m].set(n),
        mean=state.mean.at[m].set(mean_m),
        m2=state.m2.at[m].set(m2_m),
    )


def online_product(state: OnlineMoments, *, jitter: float = 1e-8) -> GaussianMoments:
    """Current parametric product estimate from streaming moments."""
    d = state.mean.shape[-1]
    denom = jnp.maximum(state.count - 1.0, 1.0)[:, None, None]
    covs = state.m2 / denom + jitter * jnp.eye(d)
    return product_moments(state.mean, covs)
