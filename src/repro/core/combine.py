"""DEPRECATED backwards-compatibility shim over :mod:`repro.core.combiners`.

The 428-line monolith this module used to be was split into the registry-
backed ``repro.core.combiners`` package (PR 1); since the ``repro.api``
experiment layer landed, combiners should be resolved by registry name
(``repro.core.combiners.get_combiner``) or driven end-to-end through
``repro.api`` (RunSpec / Pipeline / run_matrix).

Every historical public name still resolves here — lazily, via module
``__getattr__`` — to the *same object* the registry serves, so results are
registry-identical; each access emits a ``DeprecationWarning`` naming the
replacement (asserted by ``tests/test_deprecation.py``).
"""

from __future__ import annotations

import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]

# names re-exported verbatim from the combiners package
_FORWARDED = (
    "CombineResult",
    "OnlineMoments",
    "consensus_weighted",
    "log_weight_bruteforce",
    "online_init",
    "online_product",
    "online_update",
    "parametric",
    "pool",
    "subpost_average",
)


def _warn(name: str, replacement: str) -> None:
    warnings.warn(
        f"repro.core.combine.{name} is deprecated; use {replacement} "
        "(or drive runs through repro.api.RunSpec/Pipeline)",
        DeprecationWarning,
        stacklevel=3,
    )


def _nonparametric_img(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    schedule: Optional[Schedule] = None,
    rescale: bool = False,
):
    """Algorithm 1 (§3.2) — historical signature; see ``combiners.img``."""
    from repro.core.combiners import img

    return img.nonparametric(
        key, samples, n_draws, counts=counts, schedule=schedule, rescale=rescale
    )


def _semiparametric_img(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    schedule: Optional[Schedule] = None,
    rescale: bool = False,
    nonparametric_weights: bool = False,
):
    """§3.3 semiparametric combiner — historical signature; see ``combiners.img``."""
    from repro.core.combiners import img

    return img.semiparametric(
        key,
        samples,
        n_draws,
        counts=counts,
        schedule=schedule,
        rescale=rescale,
        nonparametric_weights=nonparametric_weights,
    )


def __getattr__(name: str):
    if name in _FORWARDED:
        _warn(name, f"repro.core.combiners.{name}")
        import repro.core.combiners as combiners

        return getattr(combiners, name)
    if name == "nonparametric_img":
        _warn(name, "repro.core.combiners.get_combiner('nonparametric')")
        return _nonparametric_img
    if name == "semiparametric_img":
        _warn(name, "repro.core.combiners.get_combiner('semiparametric')")
        return _semiparametric_img
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(
        list(globals()) + list(_FORWARDED)
        + ["nonparametric_img", "semiparametric_img"]
    )
