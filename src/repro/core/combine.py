"""Backwards-compatibility shim over :mod:`repro.core.combiners`.

The 428-line monolith this module used to be was split into the registry-
backed ``repro.core.combiners`` package (api / parametric / img / baselines /
online). Every historical public name is re-exported here with its original
signature; new code should resolve combiners through
``repro.core.combiners.get_combiner(name)`` instead.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.combiners import (  # noqa: F401
    CombineResult,
    OnlineMoments,
    consensus_weighted,
    log_weight_bruteforce,
    online_init,
    online_product,
    online_update,
    parametric,
    pool,
    subpost_average,
)
from repro.core.combiners import img as _img

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def nonparametric_img(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    schedule: Optional[Schedule] = None,
    rescale: bool = False,
) -> CombineResult:
    """Algorithm 1 (§3.2) — historical signature; see ``combiners.img``."""
    return _img.nonparametric(
        key, samples, n_draws, counts=counts, schedule=schedule, rescale=rescale
    )


def semiparametric_img(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    schedule: Optional[Schedule] = None,
    rescale: bool = False,
    nonparametric_weights: bool = False,
) -> CombineResult:
    """§3.3 semiparametric combiner — historical signature; see ``combiners.img``."""
    return _img.semiparametric(
        key,
        samples,
        n_draws,
        counts=counts,
        schedule=schedule,
        rescale=rescale,
        nonparametric_weights=nonparametric_weights,
    )
