"""Subposterior construction — paper Eq. 2.1.

Given a prior log-density, a per-datum log-likelihood, and a data shard, the
subposterior for machine m is

    p_m(θ) ∝ p(θ)^{1/M} · p(x^{n_m} | θ)

i.e. the shard's likelihood with an *underweighted* prior, so that the product
of all M subposteriors is proportional to the full-data posterior.

This module provides:

- :func:`partition_data`        deterministic arbitrary partition onto M shards
- :func:`make_subposterior_logpdf`   θ ↦ (1/M)·log p(θ) + Σ_{i∈shard} log p(x_i|θ)
- :func:`make_minibatch_logpdf`      the stochastic-gradient estimate used by SGLD
  at LM scale: (1/M)·log p(θ) + (N_m/B)·Σ_{i∈batch} log p(x_i|θ)
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
LogDensityFn = Callable[[PyTree], jnp.ndarray]


def partition_data(
    data: PyTree,
    num_shards: int,
    shard_index: int | None = None,
    *,
    only: tuple[str, ...] | None = None,
    pad: bool = False,
) -> PyTree:
    """Partition leading axis of every per-datum leaf into equal shards.

    The paper allows *arbitrary* partitions for i.i.d. data; we use contiguous
    blocks (deterministic, reshard-friendly for elastic restarts).

    ``only``: names of dict keys that hold per-datum arrays; other leaves
    (global quantities like mixture weights) are broadcast unchanged to every
    shard. ``None`` = every leaf is per-datum.

    ``pad=False`` (default): ``N`` must be divisible by ``num_shards`` or a
    ``ValueError`` is raised. ``pad=True``: non-divisible ``N`` is padded up
    to ``M·ceil(N/M)`` rows by replicating the final datum, and the return
    value becomes ``(shards, counts)`` where ``counts (M,) int32`` is each
    shard's number of REAL rows — the same valid-prefix convention the
    combiners' ``counts=`` masking uses, so the vector can flow through the
    whole pipeline. Pass ``counts[m]`` as ``count=`` to
    :func:`make_subposterior_logpdf`, which subtracts the padded rows'
    (replicated-final-datum) likelihood exactly.

    Returns either shard ``shard_index`` or, if ``shard_index is None``, all
    shards stacked on a new leading axis ``(M, ceil(N/M), ...)``.
    """

    def _split(x):
        n = x.shape[0]
        if n % num_shards != 0:
            if not pad:
                raise ValueError(
                    f"leading dim {n} not divisible by M={num_shards} "
                    "(pass pad=True for edge-padded shards + counts)"
                )
            size = -(-n // num_shards)  # ceil(N/M)
            # edge padding: rows beyond N replicate the final datum (finite
            # for every model; make_subposterior_logpdf's `count` correction
            # removes their likelihood contribution exactly)
            idx = jnp.minimum(jnp.arange(num_shards * size), n - 1)
            x = x[idx]
        else:
            size = n // num_shards
        shards = x.reshape((num_shards, size) + x.shape[1:])
        return shards if shard_index is None else shards[shard_index]

    def _counts(n: int) -> jnp.ndarray:
        size = -(-n // num_shards)
        full = jnp.clip(n - jnp.arange(num_shards) * size, 0, size)
        counts = full.astype(jnp.int32)
        return counts if shard_index is None else counts[shard_index]

    if only is None:
        shards = jax.tree.map(_split, data)
        n_lead = jax.tree.leaves(data)[0].shape[0]
    else:
        if not isinstance(data, dict):
            raise TypeError("`only` requires dict data")
        shards = {k: (_split(v) if k in only else v) for k, v in data.items()}
        n_lead = data[only[0]].shape[0]
    if not pad:
        return shards
    return shards, _counts(n_lead)


def make_subposterior_logpdf(
    log_prior: LogDensityFn,
    log_lik: Callable[[PyTree, PyTree], jnp.ndarray],
    data_shard: PyTree,
    num_shards: int,
    *,
    count: jnp.ndarray | int | None = None,
    per_datum: tuple[str, ...] | None = None,
) -> LogDensityFn:
    """Build the shard-m subposterior log-density (paper Eq. 2.1).

    ``log_lik(theta, data_shard)`` must return the *summed* log-likelihood of
    the shard. The prior is raised to 1/M in log space. With ``num_shards=1``
    this is the ordinary full-data posterior (used for groundtruth chains).

    ``count`` supports :func:`partition_data`'s ``pad=True`` shards: rows
    ``[count, S)`` are replicas of the shard's final row, so the exact masked
    log-likelihood is ``log_lik(shard) − (S − count)·log_lik(final row)``
    (log_lik is a per-datum sum by the model contract). ``count`` may be a
    traced scalar — the correction is O(1), vmap/shard_map friendly.
    ``per_datum`` names the dict keys holding per-datum arrays (same meaning
    as ``partition_data``'s ``only``; ``None`` = every leaf).
    """

    inv_m = 1.0 / float(num_shards)

    if count is None:
        def logpdf(theta: PyTree) -> jnp.ndarray:
            return inv_m * log_prior(theta) + log_lik(theta, data_shard)

        return logpdf

    if per_datum is None:
        last_row = jax.tree.map(lambda x: x[-1:], data_shard)
        shard_size = jax.tree.leaves(data_shard)[0].shape[0]
    else:
        last_row = {
            k: (v[-1:] if k in per_datum else v) for k, v in data_shard.items()
        }
        shard_size = data_shard[per_datum[0]].shape[0]
    n_pad = jnp.asarray(shard_size, jnp.float32) - jnp.asarray(count, jnp.float32)

    def logpdf(theta: PyTree) -> jnp.ndarray:
        full = log_lik(theta, data_shard)
        pad_ll = log_lik(theta, last_row)
        return inv_m * log_prior(theta) + full - n_pad * pad_ll

    return logpdf


def make_minibatch_logpdf(
    log_prior: LogDensityFn,
    log_lik: Callable[[PyTree, PyTree], jnp.ndarray],
    num_shards: int,
    shard_size: int,
) -> Callable[[PyTree, PyTree], jnp.ndarray]:
    """Unbiased minibatch estimator of the subposterior log-density.

    Used by SGLD/SGHMC at LM scale where a full-shard pass per step is not
    affordable: ``(1/M)·log p(θ) + (N_m/B)·log p(batch|θ)`` with B the batch's
    leading dim. The caller supplies a fresh batch per step.
    """

    inv_m = 1.0 / float(num_shards)

    def logpdf(theta: PyTree, batch: PyTree) -> jnp.ndarray:
        batch_size = jax.tree.leaves(batch)[0].shape[0]
        scale = shard_size / float(batch_size)
        return inv_m * log_prior(theta) + scale * log_lik(theta, batch)

    return logpdf


def mh_correction_ratio(
    log_prior: LogDensityFn,
    log_lik: Callable[[PyTree, PyTree], jnp.ndarray],
    data_shard: PyTree,
    num_shards: int,
) -> Callable[[PyTree, PyTree], jnp.ndarray]:
    """The paper §2 footnote form of the MH ratio on a subposterior:

    log [ p(θ*)^{1/M} p(x^{n_m}|θ*) ] − log [ p(θ)^{1/M} p(x^{n_m}|θ) ].

    Provided as a named helper so model code can be written once and reused
    for both full-posterior and subposterior sampling.
    """
    logpdf = make_subposterior_logpdf(log_prior, log_lik, data_shard, num_shards)

    def ratio(theta_new: PyTree, theta_old: PyTree) -> jnp.ndarray:
        return logpdf(theta_new) - logpdf(theta_old)

    return ratio
