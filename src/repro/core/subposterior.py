"""Subposterior construction — paper Eq. 2.1.

Given a prior log-density, a per-datum log-likelihood, and a data shard, the
subposterior for machine m is

    p_m(θ) ∝ p(θ)^{1/M} · p(x^{n_m} | θ)

i.e. the shard's likelihood with an *underweighted* prior, so that the product
of all M subposteriors is proportional to the full-data posterior.

This module provides:

- :func:`partition_data`        deterministic arbitrary partition onto M shards
- :func:`make_subposterior_logpdf`   θ ↦ (1/M)·log p(θ) + Σ_{i∈shard} log p(x_i|θ)
- :func:`make_minibatch_logpdf`      the stochastic-gradient estimate used by SGLD
  at LM scale: (1/M)·log p(θ) + (N_m/B)·Σ_{i∈batch} log p(x_i|θ)
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
LogDensityFn = Callable[[PyTree], jnp.ndarray]


def partition_data(
    data: PyTree,
    num_shards: int,
    shard_index: int | None = None,
    *,
    only: tuple[str, ...] | None = None,
) -> PyTree:
    """Partition leading axis of every per-datum leaf into equal shards.

    The paper allows *arbitrary* partitions for i.i.d. data; we use contiguous
    blocks (deterministic, reshard-friendly for elastic restarts). ``N`` must
    be divisible by ``num_shards`` — the data pipeline pads otherwise.

    ``only``: names of dict keys that hold per-datum arrays; other leaves
    (global quantities like mixture weights) are broadcast unchanged to every
    shard. ``None`` = every leaf is per-datum.

    Returns either shard ``shard_index`` or, if ``shard_index is None``, all
    shards stacked on a new leading axis ``(M, N/M, ...)``.
    """

    def _split(x):
        n = x.shape[0]
        if n % num_shards != 0:
            raise ValueError(f"leading dim {n} not divisible by M={num_shards}")
        shards = x.reshape((num_shards, n // num_shards) + x.shape[1:])
        return shards if shard_index is None else shards[shard_index]

    if only is None:
        return jax.tree.map(_split, data)
    if not isinstance(data, dict):
        raise TypeError("`only` requires dict data")
    return {k: (_split(v) if k in only else v) for k, v in data.items()}


def make_subposterior_logpdf(
    log_prior: LogDensityFn,
    log_lik: Callable[[PyTree, PyTree], jnp.ndarray],
    data_shard: PyTree,
    num_shards: int,
) -> LogDensityFn:
    """Build the shard-m subposterior log-density (paper Eq. 2.1).

    ``log_lik(theta, data_shard)`` must return the *summed* log-likelihood of
    the shard. The prior is raised to 1/M in log space. With ``num_shards=1``
    this is the ordinary full-data posterior (used for groundtruth chains).
    """

    inv_m = 1.0 / float(num_shards)

    def logpdf(theta: PyTree) -> jnp.ndarray:
        return inv_m * log_prior(theta) + log_lik(theta, data_shard)

    return logpdf


def make_minibatch_logpdf(
    log_prior: LogDensityFn,
    log_lik: Callable[[PyTree, PyTree], jnp.ndarray],
    num_shards: int,
    shard_size: int,
) -> Callable[[PyTree, PyTree], jnp.ndarray]:
    """Unbiased minibatch estimator of the subposterior log-density.

    Used by SGLD/SGHMC at LM scale where a full-shard pass per step is not
    affordable: ``(1/M)·log p(θ) + (N_m/B)·log p(batch|θ)`` with B the batch's
    leading dim. The caller supplies a fresh batch per step.
    """

    inv_m = 1.0 / float(num_shards)

    def logpdf(theta: PyTree, batch: PyTree) -> jnp.ndarray:
        batch_size = jax.tree.leaves(batch)[0].shape[0]
        scale = shard_size / float(batch_size)
        return inv_m * log_prior(theta) + scale * log_lik(theta, batch)

    return logpdf


def mh_correction_ratio(
    log_prior: LogDensityFn,
    log_lik: Callable[[PyTree, PyTree], jnp.ndarray],
    data_shard: PyTree,
    num_shards: int,
) -> Callable[[PyTree, PyTree], jnp.ndarray]:
    """The paper §2 footnote form of the MH ratio on a subposterior:

    log [ p(θ*)^{1/M} p(x^{n_m}|θ*) ] − log [ p(θ)^{1/M} p(x^{n_m}|θ) ].

    Provided as a named helper so model code can be written once and reused
    for both full-posterior and subposterior sampling.
    """
    logpdf = make_subposterior_logpdf(log_prior, log_lik, data_shard, num_shards)

    def ratio(theta_new: PyTree, theta_old: PyTree) -> jnp.ndarray:
        return logpdf(theta_new) - logpdf(theta_old)

    return ratio
