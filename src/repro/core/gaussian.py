"""Gaussian-product algebra for subposterior combination (paper Eqs. 3.1–3.2).

Everything here is Cholesky-based for numerical stability: subposterior sample
covariances can be poorly conditioned (thin posteriors at large shard sizes),
and the combination formulas multiply M precision matrices.

Two parameterizations are provided:

- full covariance ``(d, d)`` — used by the paper's experiments (d ≤ ~100);
- diagonal covariance ``(d,)`` — used for the LM-scale parametric combiner
  (d up to 10^9 parameters, where a dense ``(d, d)`` is impossible and the
  BvM regime makes the diagonal approximation standard practice).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

# a host-side constant, NOT jnp.log(...): importing this module must not run
# a JAX computation — jax.distributed.initialize() (repro.api.launch) refuses
# to start after one, and import must stay launch-safe
_LOG2PI = math.log(2.0 * math.pi)


class GaussianMoments(NamedTuple):
    """First two moments of a (sub)posterior sample set."""

    mean: jnp.ndarray  # (d,)
    cov: jnp.ndarray  # (d, d) or (d,) when diagonal


def fit_moments(
    samples: jnp.ndarray,
    mask: Optional[jnp.ndarray] = None,
    *,
    diag: bool = False,
    jitter: float = 1e-8,
) -> GaussianMoments:
    """Sample mean/covariance of ``samples`` ``(T, d)``.

    ``mask`` (T,) marks valid rows (ragged T_m support — straggler chains
    contribute fewer samples, paper footnote 1). Covariance uses the unbiased
    1/(T-1) normalizer and is jittered for downstream Cholesky stability.
    """
    samples = jnp.asarray(samples)
    T, d = samples.shape
    if mask is None:
        n = jnp.asarray(T, samples.dtype)
        mean = jnp.mean(samples, axis=0)
        centered = samples - mean
    else:
        mask = mask.astype(samples.dtype)
        n = jnp.maximum(jnp.sum(mask), 2.0)
        mean = jnp.sum(samples * mask[:, None], axis=0) / n
        centered = (samples - mean) * mask[:, None]
    denom = jnp.maximum(n - 1.0, 1.0)
    if diag:
        var = jnp.sum(centered**2, axis=0) / denom + jitter
        return GaussianMoments(mean=mean, cov=var)
    cov = centered.T @ centered / denom
    cov = cov + jitter * jnp.eye(d, dtype=samples.dtype)
    return GaussianMoments(mean=mean, cov=cov)


def _chol_inverse(cov: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return (precision, chol(cov)) via Cholesky."""
    chol = jnp.linalg.cholesky(cov)
    eye = jnp.eye(cov.shape[-1], dtype=cov.dtype)
    inv = jax.scipy.linalg.cho_solve((chol, True), eye)
    return inv, chol


def product_moments(
    means: jnp.ndarray, covs: jnp.ndarray, *, jitter: float = 1e-10
) -> GaussianMoments:
    """Moments of ``∏_m N(θ | μ_m, Σ_m)`` — paper Eqs. 3.1 / 3.2.

    means ``(M, d)``, covs ``(M, d, d)``. Computed in precision space with
    Cholesky solves; never forms an explicit matrix inverse of Σ̂_M.
    """
    d = means.shape[-1]

    def precision_and_weighted_mean(mu, cov):
        prec, _ = _chol_inverse(cov)
        return prec, prec @ mu

    precs, wmeans = jax.vmap(precision_and_weighted_mean)(means, covs)
    lam = jnp.sum(precs, axis=0) + jitter * jnp.eye(d, dtype=means.dtype)
    eta = jnp.sum(wmeans, axis=0)
    chol_lam = jnp.linalg.cholesky(lam)
    mean = jax.scipy.linalg.cho_solve((chol_lam, True), eta)
    cov = jax.scipy.linalg.cho_solve((chol_lam, True), jnp.eye(d, dtype=means.dtype))
    # Symmetrize: cho_solve output drifts slightly off-symmetric in fp32.
    cov = 0.5 * (cov + cov.T)
    return GaussianMoments(mean=mean, cov=cov)


def product_moments_diag(means: jnp.ndarray, variances: jnp.ndarray) -> GaussianMoments:
    """Diagonal-covariance version of :func:`product_moments`.

    means/variances ``(M, d)``. This is the LM-scale path: O(M·d) memory, maps
    cleanly onto a sharded ``d`` axis (each TP shard combines its slice
    independently — the combination itself is embarrassingly parallel in d).
    """
    precs = 1.0 / variances
    lam = jnp.sum(precs, axis=0)
    mean = jnp.sum(precs * means, axis=0) / lam
    return GaussianMoments(mean=mean, cov=1.0 / lam)


def sample_gaussian(
    key: jax.Array, moments: GaussianMoments, n: int
) -> jnp.ndarray:
    """Draw ``n`` samples from N(mean, cov); cov may be full or diagonal."""
    d = moments.mean.shape[-1]
    eps = jax.random.normal(key, (n, d), dtype=moments.mean.dtype)
    if moments.cov.ndim == 1:
        return moments.mean + eps * jnp.sqrt(moments.cov)
    chol = jnp.linalg.cholesky(moments.cov)
    return moments.mean + eps @ chol.T


def log_normal_pdf(
    x: jnp.ndarray, mean: jnp.ndarray, cov: jnp.ndarray
) -> jnp.ndarray:
    """log N(x | mean, cov) with full ``(d,d)`` or diagonal ``(d,)`` cov.

    Broadcasts over leading dims of ``x``.
    """
    d = x.shape[-1]
    diff = x - mean
    if cov.ndim == 1:
        quad = jnp.sum(diff**2 / cov, axis=-1)
        logdet = jnp.sum(jnp.log(cov))
    else:
        chol = jnp.linalg.cholesky(cov)
        batch_shape = diff.shape[:-1]
        flat = diff.reshape(-1, d).T  # (d, B)
        sol = jax.scipy.linalg.solve_triangular(chol, flat, lower=True)
        quad = jnp.sum(sol**2, axis=0).reshape(batch_shape)
        logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol)))
    return -0.5 * (quad + logdet + d * _LOG2PI)


def log_isotropic_normal_pdf(
    x: jnp.ndarray, mean: jnp.ndarray, var: jnp.ndarray | float
) -> jnp.ndarray:
    """log N(x | mean, var·I). ``var`` is a scalar; broadcasts over leading dims."""
    d = x.shape[-1]
    sq = jnp.sum((x - mean) ** 2, axis=-1)
    return -0.5 * (sq / var + d * (jnp.log(var) + _LOG2PI))
