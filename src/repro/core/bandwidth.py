"""Bandwidth schedules for the (semi)nonparametric combiners.

Algorithm 1 anneals ``h_i = i^{-1/(4+d)}`` — the optimal KDE rate for a
twice-differentiable density (β=2 in Thm 5.3's ``h ≍ T^{-1/(2β+d)}``).
We also provide Silverman's rule (a data-driven fixed bandwidth) and a
θ-scale-aware variant: the paper's annealed schedule implicitly assumes
unit-scale parameters; for posteriors with very small scales (large shards ⇒
tight subposteriors) an unscaled h=1 start yields astronomically small
acceptance, so production use rescales by the pooled sample std.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp


def annealed(d: int, *, scale: float | jnp.ndarray = 1.0) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Paper's Algorithm 1 line 3: ``h_i = i^{-1/(4+d)}`` (times ``scale``)."""

    exponent = -1.0 / (4.0 + d)

    def schedule(i: jnp.ndarray) -> jnp.ndarray:
        return scale * jnp.asarray(i, jnp.float32) ** exponent

    return schedule


def fixed(h: float) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Constant bandwidth."""

    def schedule(i: jnp.ndarray) -> jnp.ndarray:
        del i
        return jnp.asarray(h, jnp.float32)

    return schedule


def silverman(samples: jnp.ndarray) -> jnp.ndarray:
    """Silverman's rule-of-thumb bandwidth for ``(T, d)`` samples (scalar h).

    h = (4/(d+2))^{1/(d+4)} · T^{-1/(d+4)} · σ̄ with σ̄ the mean marginal std.
    """
    T, d = samples.shape
    sigma = jnp.mean(jnp.std(samples, axis=0))
    return (4.0 / (d + 2.0)) ** (1.0 / (d + 4.0)) * T ** (-1.0 / (d + 4.0)) * sigma


def pooled_scale(samples: jnp.ndarray) -> jnp.ndarray:
    """Mean marginal std across all subposteriors ``(M, T, d)`` → scalar.

    Used to rescale the annealed schedule so h starts at the posterior's own
    scale rather than 1.0 (beyond-paper robustness fix; with scale=1 this
    reduces exactly to Algorithm 1).
    """
    return jnp.mean(jnp.std(samples, axis=1))
