"""Pairwise-recursive combination — paper §3.2 (end) and §4.

Applying the IMG combiner to pairs of subposteriors, then to pairs of the
resulting sample sets, and so on, reduces total work to O(dTM) and markedly
improves IMG acceptance (with M̃=2 the proposal perturbs half the component).

Samples emitted by a pair's combiner are (asymptotically) draws from
``p_a · p_b`` — exactly the subposterior of the merged shard (its prior weight
``2/M`` is the sum of the pair's) — so recursion is closed: round k operates on
M/2^k sample sets, all still "subposterior samples" in the paper's sense.

All pairs in a round are combined with one ``vmap`` — on the production mesh
this is what the data-axis tree reduction lowers to (log₂ M rounds of
neighbour ``collective-permute`` + local combine; see
``repro.distributed.epmcmc``).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.combiners import CombineResult, filter_options, get_combiner


def _combine_pairs(
    key: jax.Array,
    pairs: jnp.ndarray,  # (P, 2, T, d)
    counts: jnp.ndarray,  # (P, 2)
    n_draws: int,
    method: str,
    rescale: bool,
) -> jnp.ndarray:
    combiner = get_combiner(method)
    # per-signature filtering: baselines without a bandwidth anneal simply
    # don't receive ``rescale`` (option-forwarding convention, combiners pkg)
    opts = filter_options(combiner, dict(rescale=rescale))

    def one(key, pair, cnt):
        return combiner(key, pair, n_draws, counts=cnt, **opts).samples

    keys = jax.random.split(key, pairs.shape[0])
    out = jax.vmap(one)(keys, pairs, counts)
    if out.shape[1] != n_draws:
        # e.g. "pool" emits the 2T-row union; the next round's valid-prefix
        # counts would then silently keep only the first machine's half.
        raise ValueError(
            f"combiner {method!r} returned {out.shape[1]} rows per pair instead "
            f"of n_draws={n_draws}; it cannot be used as a tree-reduction step"
        )
    return out


def tree_combine(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    method: str = "nonparametric",
    rescale: bool = False,
) -> CombineResult:
    """Combine ``(M, T, d)`` subposterior samples pairwise until one set remains.

    Odd set counts pass the last set through unchanged (paper §3.2). Output has
    ``n_draws`` samples. O(dTM) total work across all rounds.
    """
    M, T, d = samples.shape
    counts = (
        jnp.full((M,), T, dtype=jnp.int32) if counts is None else counts.astype(jnp.int32)
    )

    level = samples
    level_counts = counts
    while level.shape[0] > 1:
        m = level.shape[0]
        n_pairs = m // 2
        odd = m % 2 == 1
        paired = level[: 2 * n_pairs].reshape(n_pairs, 2, level.shape[1], d)
        paired_counts = level_counts[: 2 * n_pairs].reshape(n_pairs, 2)
        key, sub = jax.random.split(key)
        out_t = n_draws if n_pairs * 2 == m and not odd and n_pairs == 1 else level.shape[1]
        combined = _combine_pairs(sub, paired, paired_counts, out_t, method, rescale)
        new_counts = jnp.full((n_pairs,), out_t, dtype=jnp.int32)
        if odd:
            # Carry the unpaired set through; pad draws count to match if needed.
            leftover = level[-1:]
            leftover_counts = level_counts[-1:]
            if leftover.shape[1] != combined.shape[1]:
                pad_t = combined.shape[1]
                idx = jnp.arange(pad_t)[None, :] % jnp.maximum(leftover_counts[:, None], 1)
                leftover = jnp.take_along_axis(leftover, idx[:, :, None], axis=1)
                leftover_counts = jnp.minimum(leftover_counts, pad_t)
            level = jnp.concatenate([combined, leftover], axis=0)
            level_counts = jnp.concatenate([new_counts, leftover_counts], axis=0)
        else:
            level = combined
            level_counts = new_counts

    out = level[0]
    if out.shape[0] != n_draws:
        # Final level came from a passthrough with T != n_draws: resample rows.
        idx = jnp.arange(n_draws) % out.shape[0]
        out = out[idx]
    return CombineResult(samples=out, acceptance_rate=jnp.ones(()), moments=None)
