"""Combiner engine API: result type, registry, and shared array helpers.

A *combiner* is any callable with the uniform signature

    combiner(key, samples, n_draws, *, counts=None, **options) -> CombineResult

where ``samples`` is the dense ``(M, T, d)`` subposterior stack and ``counts
(M,)`` marks the valid prefix of each chain (ragged/straggler support — paper
footnote 1). Options a given combiner does not understand are ignored, so
callers (tree reduction, CLI, benchmarks, mesh EP-MCMC) can dispatch through
:func:`get_combiner` without per-method branching.

Registry: implementations self-register at import time via :func:`register`;
consumers resolve them by name with :func:`get_combiner` and enumerate them
with :func:`available_combiners`. Importing :mod:`repro.core.combiners`
populates the registry with every built-in combiner.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp

from repro.core import bandwidth as bw
from repro.core.gaussian import GaussianMoments
from repro.utils.options import filter_kwargs


class CombineResult(NamedTuple):
    """Output of a combination procedure.

    ``extras`` carries combiner-specific diagnostics (e.g. per-chain IMG
    acceptance, sweep counts, bandwidth at the final draw) without widening
    the core contract; non-MCMC combiners leave it ``None``.
    """

    samples: jnp.ndarray  # (n_draws, d) draws from the density-product estimate
    acceptance_rate: jnp.ndarray  # IMG acceptance rate (1.0 for non-MCMC combiners)
    moments: Optional[GaussianMoments] = None  # parametric product moments if computed
    extras: Optional[Dict[str, jnp.ndarray]] = None  # combiner-specific diagnostics


class Combiner(Protocol):
    """Uniform combiner callable; unknown keyword options must be ignored."""

    def __call__(
        self,
        key: jax.Array,
        samples: jnp.ndarray,
        n_draws: int,
        *,
        counts: Optional[jnp.ndarray] = None,
        **options,
    ) -> CombineResult: ...


_REGISTRY: Dict[str, Combiner] = {}
_CANONICAL: Dict[str, Combiner] = {}  # primary names only (no aliases)


def register(name: str, *aliases: str) -> Callable[[Combiner], Combiner]:
    """Decorator: add a combiner to the registry under ``name`` (+ aliases)."""

    def deco(fn: Combiner) -> Combiner:
        for key in (name, *aliases):
            if key in _REGISTRY:
                raise ValueError(f"combiner {key!r} already registered")
            _REGISTRY[key] = fn
        _CANONICAL[name] = fn
        return fn

    return deco


def get_combiner(name: str) -> Combiner:
    """Resolve a combiner by registry name (raises KeyError with choices)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown combiner {name!r}; available: {', '.join(available_combiners())}"
        ) from None


def available_combiners() -> Tuple[str, ...]:
    """All registered combiner names (aliases included), sorted."""
    return tuple(sorted(_REGISTRY))


def canonical_combiners() -> Tuple[str, ...]:
    """Primary registration names only (aliases dropped), sorted."""
    return tuple(sorted(_CANONICAL))


def filter_options(combiner: Combiner, options: Dict[str, Any]) -> Dict[str, Any]:
    """Keep only the ``options`` the combiner's signature declares.

    The option-forwarding convention (see the package docstring): callers
    that broadcast one option dict to *every* registered combiner (the CLI's
    ``--combiner all`` loop, the tree reduction's ``rescale``) must filter it
    per combiner signature instead of relying on catch-all kwargs to swallow
    mismatches. Two catch-all spellings are distinguished:

    - ``**options`` (no underscore) marks a *passthrough* wrapper that
      forwards to an inner combiner (e.g. ``semiparametric_w``) — it receives
      the full dict;
    - ``**_ignored`` marks tolerated-but-unused keywords — unknown keys are
      dropped here rather than silently swallowed there.

    Shared with the sampler registry via
    :func:`repro.utils.options.filter_kwargs`.
    """
    return filter_kwargs(combiner, options)


Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def resolve_schedule(
    samples: jnp.ndarray, schedule: Optional[Schedule], rescale: bool
) -> Schedule:
    """Default bandwidth schedule: Algorithm 1's anneal, optionally rescaled
    by the pooled sample scale (shared by every annealing combiner)."""
    if schedule is not None:
        return schedule
    d = samples.shape[-1]
    scale = bw.pooled_scale(samples) if rescale else 1.0
    return bw.annealed(d, scale=scale)


# ---------------------------------------------------------------------------
# shared array helpers
# ---------------------------------------------------------------------------


def counts_or_full(samples: jnp.ndarray, counts: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Normalize ``counts`` to an int32 ``(M,)`` vector (None ⇒ all-T)."""
    M, T, _ = samples.shape
    if counts is None:
        return jnp.full((M,), T, dtype=jnp.int32)
    return counts.astype(jnp.int32)


def valid_masks(samples: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """``(M, T)`` 0/1 mask of valid rows under ragged ``counts``."""
    _, T, _ = samples.shape
    return (jnp.arange(T)[None, :] < counts[:, None]).astype(samples.dtype)


def ragged_gather(samples: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """Densify ragged chains: row t of chain m becomes ``samples[m, t % counts[m]]``.

    Every machine keeps contributing under stragglers and the output stays a
    dense ``(M, T, d)`` array — the shared gather behind subpostAvg, pool and
    consensus (previously duplicated at each call site).
    """
    _, T, _ = samples.shape
    idx = jnp.arange(T)[None, :] % counts[:, None]  # (M, T)
    return jnp.take_along_axis(samples, idx[:, :, None], axis=1)


def log_weight_bruteforce(theta_sel: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Unnormalized log w_t (Eq. 3.5) for selected samples ``(..., M, d)``.

    log w_t = Σ_m log N(θ^m | θ̄, h² I) — the test oracle for the incremental
    update and the reference for the Pallas ``img_weights`` kernel.
    """
    mean = jnp.mean(theta_sel, axis=-2, keepdims=True)
    sse = jnp.sum((theta_sel - mean) ** 2, axis=(-1, -2))
    m, d = theta_sel.shape[-2], theta_sel.shape[-1]
    return -0.5 * sse / (h**2) - m * (d / 2.0) * jnp.log(2.0 * jnp.pi * h**2)
