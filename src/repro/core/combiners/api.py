"""Combiner engine API: result type, registry, and shared array helpers.

A *combiner* is any callable with the uniform signature

    combiner(key, samples, n_draws, *, counts=None, **options) -> CombineResult

where ``samples`` is the dense ``(M, T, d)`` subposterior stack and ``counts
(M,)`` marks the valid prefix of each chain (ragged/straggler support — paper
footnote 1). Options a given combiner does not understand are ignored, so
callers (tree reduction, CLI, benchmarks, mesh EP-MCMC) can dispatch through
:func:`get_combiner` without per-method branching.

Registry: implementations self-register at import time via :func:`register`;
consumers resolve them by name with :func:`get_combiner` and enumerate them
with :func:`available_combiners`. Importing :mod:`repro.core.combiners`
populates the registry with every built-in combiner.

Streaming (paper §4 — combine as samples arrive): every registered name also
resolves to a :class:`StreamingCombiner` via :func:`get_streaming_combiner` —
either a native incremental implementation (attached through ``register``'s
``streaming=`` slot or :func:`register_streaming`) or the exact buffered
fallback (:func:`buffered_streaming`), whose updates-then-``finalize``
is bitwise identical to calling the batch combiner on the gathered stack.
The streaming drivers run on the host between chunk arrivals (``update`` may
branch on concrete shapes/counts); do not wrap them in ``jax.jit``.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp

from repro.core import bandwidth as bw
from repro.core.gaussian import GaussianMoments
from repro.utils.options import filter_kwargs


class CombineResult(NamedTuple):
    """Output of a combination procedure.

    ``extras`` carries combiner-specific diagnostics (e.g. per-chain IMG
    acceptance, sweep counts, bandwidth at the final draw) without widening
    the core contract; non-MCMC combiners leave it ``None``.
    """

    samples: jnp.ndarray  # (n_draws, d) draws from the density-product estimate
    acceptance_rate: jnp.ndarray  # IMG acceptance rate (1.0 for non-MCMC combiners)
    moments: Optional[GaussianMoments] = None  # parametric product moments if computed
    extras: Optional[Dict[str, jnp.ndarray]] = None  # combiner-specific diagnostics


class Combiner(Protocol):
    """Uniform combiner callable; unknown keyword options must be ignored."""

    def __call__(
        self,
        key: jax.Array,
        samples: jnp.ndarray,
        n_draws: int,
        *,
        counts: Optional[jnp.ndarray] = None,
        **options,
    ) -> CombineResult: ...


class StreamingCombiner(NamedTuple):
    """Uniform incremental combination protocol (paper §4).

    - ``init(M, d) -> state``: empty accumulator for M machines in d dims;
    - ``update(state, chunk, chunk_counts) -> state``: fold one dense
      ``(M, C, d)`` per-machine chunk of draws in; ``chunk_counts (M,)``
      marks each machine's valid prefix *within the chunk* (None ⇒ all C);
    - ``finalize(key, state, n_draws, **options) -> CombineResult``: draw
      the combined estimate. Pure — a state may be finalized repeatedly
      (and updated further afterwards);
    - ``estimate`` (optional): a cheap mid-stream snapshot with the same
      signature as ``finalize`` — what the per-chunk scoreboard trajectory
      calls; ``None`` means finalize is already cheap enough.

    States are ordinary pytrees handed back to the caller; the protocol is
    host-driven (``update`` may branch on concrete counts — don't jit it).
    """

    init: Callable[[int, int], Any]
    update: Callable[..., Any]
    finalize: Callable[..., CombineResult]
    estimate: Optional[Callable[..., CombineResult]] = None


class ScanStreamingFace(NamedTuple):
    """Scan-compatible face of a streaming combiner (the fused hot path).

    Where :class:`StreamingCombiner` is host-driven (``update`` may branch
    on concrete shapes), this face is the fully *traceable* subset the fused
    sample+combine program scans over — every callable here runs inside one
    jitted ``lax.scan`` step, so it must be shape-stable and jit-safe:

    - ``init(M, d) -> scan_state``: the in-scan accumulator (any pytree of
      arrays; ``()`` for combiners whose only state is the draw buffer the
      scan already carries);
    - ``update(scan_state, chunk) -> scan_state``: fold one dense
      ``(M, C, d)`` chunk in (chunks inside the fused program are always
      dense — the driver owns raggedness);
    - ``to_state(scan_state, theta, counts) -> state``: rebuild the
      *host-side* :class:`StreamingCombiner` state from the final scan
      state plus the full gathered ``(M, T, d)`` draws, so the existing
      ``finalize`` runs unchanged (bitwise for the buffered combiners);
    - ``estimate`` (optional): ``(key, scan_state, n_draws, **options) ->
      (n_draws, d)`` in-scan trajectory draws. ``None`` means mid-stream
      rows (if the host face has an ``estimate``) are computed post-hoc on
      buffered prefixes of the returned draws — valid because every current
      host ``estimate`` without a scan counterpart takes a
      :class:`BufferState`; a future non-buffer streaming state must ship
      its own scan ``estimate`` (or none at all).
    """

    init: Callable[[int, int], Any]
    update: Callable[..., Any]
    to_state: Callable[..., Any]
    estimate: Optional[Callable[..., jnp.ndarray]] = None


_REGISTRY: Dict[str, Combiner] = {}
_CANONICAL: Dict[str, Combiner] = {}  # primary names only (no aliases)
_STREAMING: Dict[str, StreamingCombiner] = {}  # native incremental impls
_SCAN: Dict[str, ScanStreamingFace] = {}  # scan-compatible (fusable) faces


def register(
    name: str, *aliases: str, streaming: Optional[StreamingCombiner] = None
) -> Callable[[Combiner], Combiner]:
    """Decorator: add a combiner to the registry under ``name`` (+ aliases).

    ``streaming=`` attaches a native :class:`StreamingCombiner` under the
    same names; combiners without one fall back to the exact buffered
    adapter in :func:`get_streaming_combiner`.
    """

    def deco(fn: Combiner) -> Combiner:
        for key in (name, *aliases):
            if key in _REGISTRY:
                raise ValueError(f"combiner {key!r} already registered")
            _REGISTRY[key] = fn
            if streaming is not None:
                _STREAMING[key] = streaming
        _CANONICAL[name] = fn
        return fn

    return deco


def register_streaming(name: str, sc: StreamingCombiner) -> StreamingCombiner:
    """Attach a native streaming implementation to an already-registered
    batch combiner ``name`` (propagates to its aliases)."""
    fn = get_combiner(name)
    for key, batch in _REGISTRY.items():
        if batch is fn:
            _STREAMING[key] = sc
    return sc


def get_combiner(name: str) -> Combiner:
    """Resolve a combiner by registry name (raises KeyError with choices)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown combiner {name!r}; available: {', '.join(available_combiners())}"
        ) from None


def available_combiners() -> Tuple[str, ...]:
    """All registered combiner names (aliases included), sorted."""
    return tuple(sorted(_REGISTRY))


def canonical_combiners() -> Tuple[str, ...]:
    """Primary registration names only (aliases dropped), sorted."""
    return tuple(sorted(_CANONICAL))


def streaming_combiners() -> Tuple[str, ...]:
    """Canonical names with a *native* incremental implementation (every
    other registered name still streams via the buffered fallback)."""
    return tuple(sorted(k for k in _STREAMING if k in _CANONICAL))


def get_streaming_combiner(name: str) -> StreamingCombiner:
    """Resolve a name to a :class:`StreamingCombiner`.

    Natively streaming combiners return their registered implementation;
    everything else gets :func:`buffered_streaming` over the batch callable,
    whose ``update*k + finalize`` is *bitwise* the batch result.
    """
    if name in _STREAMING:
        return _STREAMING[name]
    return buffered_streaming(get_combiner(name))


class EstimateUnavailable(RuntimeError):
    """A streaming combiner has no cheap mid-stream ``estimate``.

    Raised by :func:`streaming_estimate` (and the serving layer) for names
    that stream through the generic buffered fallback — re-running a heavy
    batch combiner (weierstrass, rpt, semiparametric, ...) on the growing
    buffer at every refresh would cost more than the gather path the stream
    exists to beat. Carries the combiner name and a human-readable reason so
    callers can surface a typed failure (``repro.serve`` maps it to a
    503-with-reason) instead of a bare ``AttributeError``.
    """

    def __init__(self, combiner: str, reason: str):
        self.combiner = combiner
        self.reason = reason
        super().__init__(f"{combiner}: {reason}")


def streaming_estimate(name: str) -> Callable[..., "CombineResult"]:
    """Resolve ``name`` to its streaming face's cheap ``estimate``.

    The typed counterpart of ``get_streaming_combiner(name).estimate``:
    names whose streaming form deliberately leaves ``estimate=None`` raise
    :class:`EstimateUnavailable` (with the reason) rather than handing the
    caller ``None`` to trip over.
    """
    sc = get_streaming_combiner(name)
    if sc.estimate is None:
        raise EstimateUnavailable(
            name,
            "no cheap mid-stream estimate: this combiner streams through "
            "the buffered fallback and only finalizes (its batch body is "
            "too heavy to re-run per refresh); query it after the stream "
            "completes, or pick a combiner with a streaming estimate",
        )
    return sc.estimate


def register_scan_face(name: str, face: ScanStreamingFace) -> ScanStreamingFace:
    """Attach a scan-compatible streaming face to a registered combiner
    ``name`` (propagates to its aliases, like :func:`register_streaming`)."""
    fn = get_combiner(name)
    for key, batch in _REGISTRY.items():
        if batch is fn:
            _SCAN[key] = face
    return face


def get_scan_face(name: str) -> Optional[ScanStreamingFace]:
    """Resolve a name to its :class:`ScanStreamingFace`, if it has one.

    Three cases decide whether ``Pipeline.stream_combine`` may fuse:

    - an explicitly registered face (``parametric``, ``online``, ...) — use
      it;
    - no *native* streaming implementation at all (the generic buffered
      fallback) — the scan face is trivial: the fused scan already carries
      the draws, so the in-scan state is ``()`` and ``to_state`` wraps the
      gathered stack in a :class:`BufferState` (``finalize`` then replays
      the batch combiner bitwise);
    - a native streaming implementation *without* a declared scan face —
      ``None``: its host ``update`` may be un-traceable, so the driver must
      stay on the subscriber path.
    """
    if name in _SCAN:
        return _SCAN[name]
    if name not in _STREAMING:
        return ScanStreamingFace(
            init=lambda M, d: (),
            update=lambda state, chunk: state,
            to_state=lambda state, theta, counts: BufferState(theta, counts),
        )
    return None


# ---------------------------------------------------------------------------
# buffered streaming state (the exact fallback + the KDE-center accumulator)
# ---------------------------------------------------------------------------


class BufferState(NamedTuple):
    """Dense accumulated draws: the gathered ``(M, t, d)`` stack grown
    chunk by chunk, with the valid-prefix ``counts`` convention."""

    theta: jnp.ndarray  # (M, t, d)
    counts: jnp.ndarray  # (M,) valid prefix per machine


def buffer_init(M: int, d: int, dtype=jnp.float32) -> BufferState:
    return BufferState(
        theta=jnp.zeros((M, 0, d), dtype), counts=jnp.zeros((M,), jnp.int32)
    )


def buffer_append(
    state: BufferState, chunk: jnp.ndarray, chunk_counts: Optional[jnp.ndarray] = None
) -> BufferState:
    """Append a dense ``(M, C, d)`` chunk, keeping valid rows a prefix.

    Dense-so-far chunks concatenate verbatim (the bitwise-fallback hot
    path); ragged ones are compacted per machine so chain m's valid draws
    stay rows ``[0, counts[m])`` — the combiners' layout contract.
    """
    M, C, _ = chunk.shape
    cc = (
        jnp.full((M,), C, jnp.int32)
        if chunk_counts is None
        else chunk_counts.astype(jnp.int32)
    )
    t = state.theta.shape[1]
    stacked = jnp.concatenate([state.theta, chunk], axis=1)
    total = state.counts + cc
    if bool(jnp.all(state.counts == t)) and bool(jnp.all(cc == C)):
        return BufferState(stacked, total)
    # compact: old valid prefix, then this chunk's valid prefix; the tail
    # beyond total[m] is garbage and invalid by construction
    j = jnp.arange(t + C)[None, :]
    idx = jnp.where(j < state.counts[:, None], j, t + j - state.counts[:, None])
    idx = jnp.clip(idx, 0, t + C - 1)
    return BufferState(jnp.take_along_axis(stacked, idx[:, :, None], axis=1), total)


def buffer_batch_args(state: BufferState):
    """``(theta, counts)`` ready for a batch combiner call — ``counts`` is
    ``None`` when every chain is dense, so the fallback takes *exactly* the
    code path (and numerics) of the gather-then-combine caller."""
    t = state.theta.shape[1]
    dense = bool(jnp.all(state.counts == t))
    return state.theta, (None if dense else state.counts)


def buffered_streaming(fn: Combiner) -> StreamingCombiner:
    """The exact streaming fallback for a batch combiner.

    State is the growing :class:`BufferState`; ``finalize`` replays the
    batch combiner on it, so ``update*k + finalize`` ≡ batch **bitwise**
    (identical arrays, identical key, identical option filtering).
    """

    def finalize(key, state: BufferState, n_draws: int, **options):
        theta, counts = buffer_batch_args(state)
        if theta.shape[1] == 0:
            raise ValueError("streaming finalize before any update() chunk")
        kwargs = filter_kwargs(fn, options)
        if counts is not None:
            kwargs["counts"] = counts
        return fn(key, theta, n_draws, **kwargs)

    return StreamingCombiner(init=buffer_init, update=buffer_append, finalize=finalize)


def filter_options(combiner: Combiner, options: Dict[str, Any]) -> Dict[str, Any]:
    """Keep only the ``options`` the combiner's signature declares.

    The option-forwarding convention (see the package docstring): callers
    that broadcast one option dict to *every* registered combiner (the CLI's
    ``--combiner all`` loop, the tree reduction's ``rescale``) must filter it
    per combiner signature instead of relying on catch-all kwargs to swallow
    mismatches. Two catch-all spellings are distinguished:

    - ``**options`` (no underscore) marks a *passthrough* wrapper that
      forwards to an inner combiner (e.g. ``semiparametric_w``) — it receives
      the full dict;
    - ``**_ignored`` marks tolerated-but-unused keywords — unknown keys are
      dropped here rather than silently swallowed there.

    Shared with the sampler registry via
    :func:`repro.utils.options.filter_kwargs`.
    """
    return filter_kwargs(combiner, options)


Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def resolve_schedule(
    samples: jnp.ndarray, schedule: Optional[Schedule], rescale: bool
) -> Schedule:
    """Default bandwidth schedule: Algorithm 1's anneal, optionally rescaled
    by the pooled sample scale (shared by every annealing combiner)."""
    if schedule is not None:
        return schedule
    d = samples.shape[-1]
    scale = bw.pooled_scale(samples) if rescale else 1.0
    return bw.annealed(d, scale=scale)


# ---------------------------------------------------------------------------
# shared array helpers
# ---------------------------------------------------------------------------


def counts_or_full(samples: jnp.ndarray, counts: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Normalize ``counts`` to an int32 ``(M,)`` vector (None ⇒ all-T)."""
    M, T, _ = samples.shape
    if counts is None:
        return jnp.full((M,), T, dtype=jnp.int32)
    return counts.astype(jnp.int32)


def valid_masks(samples: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """``(M, T)`` 0/1 mask of valid rows under ragged ``counts``."""
    _, T, _ = samples.shape
    return (jnp.arange(T)[None, :] < counts[:, None]).astype(samples.dtype)


def ragged_gather(samples: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """Densify ragged chains: row t of chain m becomes ``samples[m, t % counts[m]]``.

    Every machine keeps contributing under stragglers and the output stays a
    dense ``(M, T, d)`` array — the shared gather behind subpostAvg, pool and
    consensus (previously duplicated at each call site).
    """
    _, T, _ = samples.shape
    idx = jnp.arange(T)[None, :] % counts[:, None]  # (M, T)
    return jnp.take_along_axis(samples, idx[:, :, None], axis=1)


def log_weight_bruteforce(theta_sel: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Unnormalized log w_t (Eq. 3.5) for selected samples ``(..., M, d)``.

    log w_t = Σ_m log N(θ^m | θ̄, h² I) — the test oracle for the incremental
    update and the reference for the Pallas ``img_weights`` kernel.
    """
    mean = jnp.mean(theta_sel, axis=-2, keepdims=True)
    sse = jnp.sum((theta_sel - mean) ** 2, axis=(-1, -2))
    m, d = theta_sel.shape[-2], theta_sel.shape[-1]
    return -0.5 * sse / (h**2) - m * (d / 2.0) * jnp.log(2.0 * jnp.pi * h**2)
