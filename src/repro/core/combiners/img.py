"""The shared IMG engine behind every asymptotically exact combiner (§3.2/§3.3).

One Algorithm-1 core, parameterized by a *weight model* (:class:`ImgWeightModel`):

- nonparametric ``w_t`` (Eq. 3.5) with Gaussian KDE components       — §3.2
- semiparametric ``W_t`` (Hjort–Glad correction)                      — §3.3
- semiparametric components with ``w_t`` weights (higher acceptance)  — §3.3

replacing the two duplicated scan bodies the old ``combine.py`` monolith
carried. Complexity note (beyond-paper, algebraically exact): Algorithm 1 as
written recomputes ``w_t`` from scratch per proposal — O(dTM²) total. We
maintain the running component mean θ̄_t and Σ_m‖θ^m_{t_m}‖² incrementally,
using  Σ_m ‖θ_m − θ̄‖² = Σ_m ‖θ_m‖² − M·‖θ̄‖², so each single-index proposal
is O(d) and the whole run is O(dTM).

Execution modes (:func:`run_img`):

``n_batch=1`` (default)
    The classic serial chain: one sweep of M Metropolis-within-Gibbs index
    proposals per emitted draw.

``n_batch=B > 1``
    B independent IMG index-chains run under ``vmap``, each doing
    ``ceil(n_draws/B)`` sweeps from independently-initialized indices. Every
    chain is a bona-fide (shorter) run of Algorithm 1 — identical per-chain
    stationary distribution — so the serial O(n_draws·M) recursion becomes
    ~B-way parallel work. The bandwidth anneal uses a **shared global
    index**: chain b's sweep i anneals at h(i·B + b + 1), exactly the index
    the serial chain would use for that output row, so large B no longer
    stalls every chain at the under-annealed h(n_draws/B) endpoint.

``weight_eval="kernel"``
    The vectorized all-M-proposals-per-sweep variant: each sweep draws index
    proposals for *all* machines up front, evaluates all B·M candidate
    mixture weights in one batched call to the Pallas
    :func:`repro.kernels.img_weights.img_log_weights` kernel, and then runs
    the accept/reject recursion on O(M) scalars per site using an exact
    rank-one correction (below) — the sequential chain's distribution is
    preserved exactly, while all O(d)-heavy work becomes one kernel call plus
    one Gram matmul per sweep.

    Correction math: with base state (θ̄₀, Σ‖θ‖²₀), candidate deltas
    Δ_m = cand_m − θ_m and accepted set J at site m,

        log w(state_J ∪ {m}) = LW_m − (1/2h²)·[A − 2·s_B − (s_G + 2·g_m)/M]

    where LW_m is the kernel's base-state weight of the single-site-m
    modification, A = Σ_J (‖cand_j‖²−‖θ_j‖²), s_B = θ̄₀·S, s_G = ‖S‖²,
    g_m = S·Δ_m, S = Σ_J Δ_j — all maintained in O(M) per site from the
    precomputed Gram matrix G = ΔΔᵀ.

    Full semiparametric ``W_t`` rides the same recursion: the candidate
    state's mean is θ̄₀ + (S + Δ_m)/M and its per-sample term3 sum is
    extra₀ + Σ_J δaux_j + δaux_m with δaux_m = aux[m, c_m] − aux[m, t_m],
    so carrying S (B, d) and the accepted δaux sum (B,) exposes every
    quantity the state-level correction log N(θ̄ | μ̂_M, Σ̂_M + h²/M I) +
    Σ_m aux needs — O(B·d) per site, the same asymptotics as the Gram
    precompute. The pure-``w_t`` models skip all of it at trace time.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.combiners.api import (
    CombineResult,
    counts_or_full,
    register,
    resolve_schedule as _resolve_schedule,
    valid_masks,
)
from repro.core.gaussian import (
    GaussianMoments,
    fit_moments,
    log_normal_pdf,
    product_moments,
)

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


class ImgWeightModel(NamedTuple):
    """What varies between §3.2 and §3.3: the weight terms and component law.

    ``aux`` (M, T): per-sample additive log-weight terms, gathered
    incrementally (semiparametric −log N(θ^m_t | μ̂_m, Σ̂_m); None ⇒ 0).
    ``extra_logweight(h)``: builds the state-level additive log-weight for
    bandwidth h (the semiparametric log N(θ̄ | μ̂_M, Σ̂_M + h²/M I) term;
    None ⇒ 0). ``draw(key, mean, h)``: one draw from the mixture component
    selected by the current indices. ``moments``: parametric product moments
    if the model computed them (reported in :class:`CombineResult`).
    """

    aux: Optional[jnp.ndarray]
    extra_logweight: Optional[Callable[[jnp.ndarray], Callable]]
    draw: Callable[[jax.Array, jnp.ndarray, jnp.ndarray], jnp.ndarray]
    moments: Optional[GaussianMoments]


# ---------------------------------------------------------------------------
# per-chain carry + incremental Gibbs sweep (Alg 1 lines 4–11)
# ---------------------------------------------------------------------------


class _ImgCarry(NamedTuple):
    key: jax.Array
    t_idx: jnp.ndarray  # (M,) current component indices
    theta_sel: jnp.ndarray  # (M, d) samples[m, t_idx[m]]
    mean: jnp.ndarray  # (d,) running θ̄_t
    sumsq: jnp.ndarray  # () running Σ_m ‖θ^m_{t_m}‖²
    extra: jnp.ndarray  # () running Σ_m aux[m, t_m] (semiparametric term3; 0 o.w.)
    n_accept: jnp.ndarray  # () accepted proposals


def _init_img_carry(
    key: jax.Array,
    samples: jnp.ndarray,
    counts: jnp.ndarray,
    aux: Optional[jnp.ndarray],
) -> _ImgCarry:
    M, T, d = samples.shape
    key, sub = jax.random.split(key)
    t0 = jax.random.randint(sub, (M,), 0, counts)  # Alg 1 line 1
    theta_sel = jnp.take_along_axis(samples, t0[:, None, None], axis=1)[:, 0, :]
    extra = jnp.zeros(()) if aux is None else jnp.sum(aux[jnp.arange(M), t0])
    return _ImgCarry(
        key=key,
        t_idx=t0,
        theta_sel=theta_sel,
        mean=jnp.mean(theta_sel, axis=0),
        sumsq=jnp.sum(theta_sel**2),
        extra=extra,
        n_accept=jnp.zeros(()),
    )


def _img_gibbs_sweep(
    carry: _ImgCarry,
    samples: jnp.ndarray,
    counts: jnp.ndarray,
    h: jnp.ndarray,
    aux: Optional[jnp.ndarray],
    extra_logweight: Optional[Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]],
) -> _ImgCarry:
    """One sweep of Alg 1 lines 4–11: propose a new index for each m in turn."""
    M, T, d = samples.shape
    inv_m = 1.0 / M

    def log_w(mean, sumsq, extra):
        sse = sumsq - M * jnp.sum(mean**2)
        lw = -0.5 * sse / (h**2)
        if extra_logweight is not None:
            lw = lw + extra_logweight(mean, extra)
        return lw

    def body(carry: _ImgCarry, m: jnp.ndarray) -> Tuple[_ImgCarry, None]:
        key, k_prop, k_acc = jax.random.split(carry.key, 3)
        c_m = jax.random.randint(k_prop, (), 0, counts[m])  # line 6
        theta_new = samples[m, c_m]
        theta_old = carry.theta_sel[m]
        mean_new = carry.mean + (theta_new - theta_old) * inv_m
        sumsq_new = carry.sumsq + jnp.sum(theta_new**2) - jnp.sum(theta_old**2)
        extra_new = (
            carry.extra
            if aux is None
            else carry.extra - aux[m, carry.t_idx[m]] + aux[m, c_m]
        )
        log_ratio = log_w(mean_new, sumsq_new, extra_new) - log_w(
            carry.mean, carry.sumsq, carry.extra
        )
        accept = jnp.log(jax.random.uniform(k_acc)) < log_ratio  # lines 7–8
        new_carry = _ImgCarry(
            key=key,
            t_idx=jnp.where(accept, carry.t_idx.at[m].set(c_m), carry.t_idx),
            theta_sel=jnp.where(accept, carry.theta_sel.at[m].set(theta_new), carry.theta_sel),
            mean=jnp.where(accept, mean_new, carry.mean),
            sumsq=jnp.where(accept, sumsq_new, carry.sumsq),
            extra=jnp.where(accept, extra_new, carry.extra),
            n_accept=carry.n_accept + accept,
        )
        return new_carry, None

    carry, _ = jax.lax.scan(body, carry, jnp.arange(M))
    return carry


def _run_chain(
    key: jax.Array,
    samples: jnp.ndarray,
    counts: jnp.ndarray,
    n_sweeps: int,
    schedule: Schedule,
    model: ImgWeightModel,
    anneal_offset: jnp.ndarray | int = 1,
    anneal_stride: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One serial IMG chain: ``n_sweeps`` anneal steps, one draw per sweep.

    Sweep i anneals at global index ``anneal_offset + i·anneal_stride``.
    Batched runs pass offset b+1 / stride B so chain b's sweep i sits at the
    exact index the serial chain would use for output row i·B+b — the shared
    global anneal that keeps large-``n_batch`` runs as annealed as ``B=1``.
    """
    carry = _init_img_carry(key, samples, counts, model.aux)

    def step(carry: _ImgCarry, i: jnp.ndarray):
        h = schedule(anneal_offset + i * anneal_stride).astype(samples.dtype)  # line 3 (1-based)
        extra_lw = model.extra_logweight(h) if model.extra_logweight is not None else None
        carry = _img_gibbs_sweep(carry, samples, counts, h, model.aux, extra_lw)
        key, k_draw = jax.random.split(carry.key)
        carry = carry._replace(key=key)
        theta = model.draw(k_draw, carry.mean, h)  # line 12
        return carry, theta

    carry, draws = jax.lax.scan(step, carry, jnp.arange(n_sweeps))
    return draws, carry.n_accept


# ---------------------------------------------------------------------------
# vectorized all-M-proposals sweep (Pallas weight kernel on the hot path)
# ---------------------------------------------------------------------------


def _img_kernel_sweep(
    carry: _ImgCarry,  # batched: every leaf has a leading (B,) axis
    samples: jnp.ndarray,
    counts: jnp.ndarray,
    h: jnp.ndarray,
    aux: Optional[jnp.ndarray] = None,
    extra_lw: Optional[Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]] = None,
) -> _ImgCarry:
    """One sweep for B chains at once, weights evaluated by the Pallas kernel.

    All B·M candidate states (single-site modifications of each chain's base
    state) are scored in one ``img_log_weights`` call; the site recursion then
    runs on O(M) scalars per chain using the exact rank-one correction
    derived in the module docstring — bitwise different, distribution-exact.
    With ``extra_lw`` (semiparametric ``W_t``) the recursion also carries the
    accepted delta sum S (B, d) and the accepted δaux sum (B,), so every
    candidate's state-level correction term is evaluated from the base state
    in O(d) — the pure-``w_t`` path is untouched at trace time.
    """
    from repro.kernels.img_weights import img_log_weights

    M, T, d = samples.shape
    B = carry.mean.shape[0]
    dtype = samples.dtype

    keys = jax.vmap(lambda k: jax.random.split(k, 3))(carry.key)  # (B, 3, 2)
    key_next, k_prop, k_acc = keys[:, 0], keys[:, 1], keys[:, 2]
    c = jax.vmap(lambda k: jax.random.randint(k, (M,), 0, counts))(k_prop)  # (B, M)
    u = jax.vmap(lambda k: jax.random.uniform(k, (M,)))(k_acc)  # (B, M)

    cand = samples[jnp.arange(M)[None, :], c]  # (B, M, d) cand[b,m]=samples[m,c[b,m]]
    delta = cand - carry.theta_sel  # (B, M, d) Δ_m
    nsq = jnp.sum(cand**2, axis=-1) - jnp.sum(carry.theta_sel**2, axis=-1)  # (B, M)
    b_dot = jnp.einsum("bd,bmd->bm", carry.mean, delta)  # θ̄₀·Δ_m
    gram = jnp.einsum("bmd,bnd->bmn", delta, delta)  # Δ_j·Δ_m
    msq0 = jnp.sum(carry.mean**2, axis=-1)  # (B,)

    h32 = h.astype(jnp.float32)
    inv2h2 = 0.5 / (h32 * h32)
    log_norm = M * (d / 2.0) * jnp.log(2.0 * jnp.pi * h32 * h32)

    # All B·M single-site candidate states, scored in one kernel call. A
    # closed form for these base weights exists from the scalars above
    # (LW_m = lw_cur0 − inv2h2·(nsq_m − 2·b_m − G_mm/M)); routing through the
    # kernel instead is deliberate: it keeps the O(B·M²·d) bulk of the sweep
    # in the offloadable Pallas path (same asymptotics as the Gram matmul),
    # which is the TPU hot path this engine exists to feed.
    eye = jnp.eye(M, dtype=dtype)[None, :, :, None]  # (1, prop, machine, 1)
    theta_prop = (1.0 - eye) * carry.theta_sel[:, None, :, :] + eye * cand[:, :, None, :]
    lw_base = img_log_weights(theta_prop.reshape(B * M, M, d), h32).reshape(B, M)

    lw_cur0 = -(carry.sumsq - M * msq0) * inv2h2 - log_norm  # current-state weight

    semip = extra_lw is not None
    if semip:
        # δaux_m = aux[m, c_m] − aux[m, t_m]: per-site change of the Σ_m aux
        # term (zero when the model has no per-sample terms but still wants
        # the state-level correction — not a case the current models hit).
        if aux is not None:
            delta_aux = (
                aux[jnp.arange(M)[None, :], c]
                - aux[jnp.arange(M)[None, :], carry.t_idx]
            ).astype(jnp.float32)  # (B, M)
        else:
            delta_aux = jnp.zeros((B, M), jnp.float32)
        lw_cur0 = lw_cur0 + extra_lw(carry.mean, carry.extra)

    def site(state, m):
        if semip:
            lw_cur, acc_nsq, s_b, s_g, g, s_vec, acc_aux, a_mask, n_acc = state
        else:
            lw_cur, acc_nsq, s_b, s_g, g, a_mask, n_acc = state
        g_m = g[:, m]
        corr = -(acc_nsq - 2.0 * s_b - (s_g + 2.0 * g_m) / M) * inv2h2
        lw_prop = lw_base[:, m] + corr
        if semip:
            mean_m = carry.mean + (s_vec + delta[:, m]) / M  # candidate θ̄
            extra_m = carry.extra + acc_aux + delta_aux[:, m]
            lw_prop = lw_prop + extra_lw(mean_m, extra_m)
        accept = jnp.log(u[:, m]) < lw_prop - lw_cur  # (B,)
        af = accept.astype(jnp.float32)
        out = (
            jnp.where(accept, lw_prop, lw_cur),
            acc_nsq + af * nsq[:, m],
            s_b + af * b_dot[:, m],
            s_g + af * (2.0 * g_m + gram[:, m, m]),
            g + af[:, None] * gram[:, m, :],
        )
        if semip:
            out = out + (
                s_vec + af[:, None] * delta[:, m],
                acc_aux + af * delta_aux[:, m],
            )
        return out + (a_mask.at[:, m].set(accept), n_acc + af), None

    zeros_b = jnp.zeros((B,), jnp.float32)
    init = (
        lw_cur0.astype(jnp.float32),
        zeros_b,
        zeros_b,
        zeros_b,
        jnp.zeros((B, M), jnp.float32),
    )
    if semip:
        init = init + (jnp.zeros((B, d), dtype), zeros_b)
    init = init + (jnp.zeros((B, M), bool), zeros_b)
    final, _ = jax.lax.scan(site, init, jnp.arange(M))
    a_mask, n_acc = final[-2], final[-1]

    af = a_mask.astype(dtype)
    mean_new = carry.mean + jnp.einsum("bm,bmd->bd", af, delta) / M
    sumsq_new = carry.sumsq + jnp.sum(af * nsq, axis=-1)
    return carry._replace(
        key=key_next,
        t_idx=jnp.where(a_mask, c, carry.t_idx),
        theta_sel=jnp.where(a_mask[:, :, None], cand, carry.theta_sel),
        mean=mean_new,
        sumsq=sumsq_new,
        extra=(carry.extra + final[6]) if semip else carry.extra,
        n_accept=carry.n_accept + n_acc,
    )


def _run_batched_kernel(
    key: jax.Array,
    samples: jnp.ndarray,
    counts: jnp.ndarray,
    n_sweeps: int,
    n_batch: int,
    schedule: Schedule,
    model: ImgWeightModel,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """B chains × ``n_sweeps`` vectorized sweeps → ((n_sweeps, B, d), (B,))."""
    M, T, d = samples.shape
    keys = jax.random.split(key, n_batch)
    carry = jax.vmap(lambda k: _init_img_carry(k, samples, counts, model.aux))(keys)

    def step(carry: _ImgCarry, i: jnp.ndarray):
        # Shared global anneal index: sweep i covers serial rows (i·B, (i+1)·B];
        # the kernel sweep scores all B chains at one scalar h, so use the
        # block's most-annealed index — after n_sweeps the bandwidth matches
        # the serial chain's h(n_draws) instead of stalling at h(n_draws/B).
        h = schedule((i + 1) * n_batch).astype(samples.dtype)
        extra_lw = (
            model.extra_logweight(h) if model.extra_logweight is not None else None
        )
        carry = _img_kernel_sweep(carry, samples, counts, h, model.aux, extra_lw)
        split = jax.vmap(jax.random.split)(carry.key)  # (B, 2, 2)
        carry = carry._replace(key=split[:, 0])
        theta = jax.vmap(lambda k, mn: model.draw(k, mn, h))(split[:, 1], carry.mean)
        return carry, theta

    carry, draws = jax.lax.scan(step, carry, jnp.arange(n_sweeps))
    return draws, carry.n_accept


# ---------------------------------------------------------------------------
# the engine entry point
# ---------------------------------------------------------------------------


def run_img(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    model: ImgWeightModel,
    *,
    counts: jnp.ndarray,
    schedule: Schedule,
    n_batch: int = 1,
    weight_eval: str = "incremental",
) -> CombineResult:
    """Run the IMG engine and package draws + diagnostics.

    ``n_batch``: number of independent index-chains (each does
    ``ceil(n_draws/n_batch)`` sweeps). ``weight_eval``: ``"incremental"``
    (O(d) single-site recursion) or ``"kernel"`` (vectorized sweeps scored by
    the Pallas ``img_weights`` kernel; supports every registered weight model
    including full semiparametric ``W_t``).
    """
    M, T, d = samples.shape
    n_batch = max(1, min(int(n_batch), int(n_draws)))
    n_sweeps = -(-n_draws // n_batch)  # ceil

    if weight_eval == "kernel":
        draws, n_acc = _run_batched_kernel(
            key, samples, counts, n_sweeps, n_batch, schedule, model
        )
        draws = draws.reshape(n_sweeps * n_batch, d)
        per_chain = n_acc / (n_sweeps * M)
        n_acc = jnp.sum(n_acc)
    elif weight_eval == "incremental":
        if n_batch == 1:
            draws, n_acc = _run_chain(key, samples, counts, n_sweeps, schedule, model)
            per_chain = (n_acc / (n_sweeps * M))[None]
        else:
            keys = jax.random.split(key, n_batch)
            offsets = jnp.arange(1, n_batch + 1, dtype=jnp.float32)
            draws, n_acc = jax.vmap(
                lambda k, off: _run_chain(
                    k, samples, counts, n_sweeps, schedule, model,
                    anneal_offset=off, anneal_stride=n_batch,
                )
            )(keys, offsets)
            draws = jnp.swapaxes(draws, 0, 1).reshape(n_sweeps * n_batch, d)
            per_chain = n_acc / (n_sweeps * M)
            n_acc = jnp.sum(n_acc)
    else:
        raise ValueError(f"unknown weight_eval {weight_eval!r}")

    # ceil-rounding emits < n_batch surplus draws; drop the *earliest* (least
    # annealed) rows so the kept draws are the best of every chain.
    draws = draws[-n_draws:]
    return CombineResult(
        samples=draws,
        acceptance_rate=n_acc / (n_sweeps * n_batch * M),
        moments=model.moments,
        extras={
            "n_batch": jnp.asarray(n_batch),
            "n_sweeps_per_chain": jnp.asarray(n_sweeps),
            "per_chain_acceptance": per_chain,
        },
    )


# ---------------------------------------------------------------------------
# weight models
# ---------------------------------------------------------------------------


def nonparametric_model(samples: jnp.ndarray) -> ImgWeightModel:
    """§3.2: weights w_t (Eq. 3.5), components N(θ̄_t, h²/M I)."""
    M, _, d = samples.shape

    def draw(key, mean, h):
        eps = jax.random.normal(key, (d,), samples.dtype)
        return mean + eps * h / jnp.sqrt(jnp.asarray(M, samples.dtype))

    return ImgWeightModel(aux=None, extra_logweight=None, draw=draw, moments=None)


def semiparametric_model(
    samples: jnp.ndarray,
    counts: jnp.ndarray,
    *,
    nonparametric_weights: bool = False,
) -> ImgWeightModel:
    """§3.3: components N(μ_t, Σ_t) with Σ_t = (M/h² I + Σ̂_M^{-1})^{-1},
    μ_t = Σ_t (M/h² θ̄_t + Σ̂_M^{-1} μ̂_M).

    ``nonparametric_weights=False``: IMG weights W_t (paper's primary form)
        log W_t = log w_t + log N(θ̄_t | μ̂_M, Σ̂_M + h²/M I)
                  − Σ_m log N(θ^m_{t_m} | μ̂_m, Σ̂_m).
    ``nonparametric_weights=True``: the paper's second variant — weights w_t
        (higher IMG acceptance), same semiparametric components.
    """
    M, T, d = samples.shape
    masks = valid_masks(samples, counts)

    # Parametric start: per-subposterior moments and their Gaussian product.
    moments = jax.vmap(lambda s, mk: fit_moments(s, mk))(samples, masks)
    prod = product_moments(moments.mean, moments.cov)
    lam_m = jnp.linalg.inv(prod.cov + 1e-10 * jnp.eye(d))  # Σ̂_M^{-1}
    eta_m = lam_m @ prod.mean  # Σ̂_M^{-1} μ̂_M

    if nonparametric_weights:
        aux = None
        extra_logweight = None
    else:
        # term3: −Σ_m log N(θ^m_{t_m} | μ̂_m, Σ̂_m), gathered incrementally.
        aux = -jax.vmap(lambda s, mom: log_normal_pdf(s, mom[0], mom[1]))(
            samples, (moments.mean, moments.cov)
        )  # (M, T)

        def extra_logweight(h):
            cov_i = prod.cov + (h**2 / M) * jnp.eye(d)

            def term(mean, extra_sum):
                # + log N(θ̄ | μ̂_M, Σ̂_M + h²/M I) + Σ_m aux  (aux already −logN)
                return log_normal_pdf(mean, prod.mean, cov_i) + extra_sum

            return term

    def draw(key, mean, h):
        # Precision form: P = M/h² I + Λ_M, θ = μ_t + chol(P)^{-T} ε.
        h2 = h**2
        prec = (M / h2) * jnp.eye(d) + lam_m
        chol_p = jnp.linalg.cholesky(prec)
        rhs = (M / h2) * mean + eta_m
        mu_t = jax.scipy.linalg.cho_solve((chol_p, True), rhs)
        eps = jax.random.normal(key, (d,), samples.dtype)
        return mu_t + jax.scipy.linalg.solve_triangular(chol_p.T, eps, lower=False)

    return ImgWeightModel(
        aux=aux, extra_logweight=extra_logweight, draw=draw, moments=prod
    )


# ---------------------------------------------------------------------------
# registered combiners
# ---------------------------------------------------------------------------


@register("nonparametric", "nonparametric_img")
def nonparametric(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    schedule: Optional[Schedule] = None,
    rescale: bool = False,
    n_batch: int = 1,
    weight_eval: str = "incremental",
    **_ignored,
) -> CombineResult:
    """Algorithm 1 — asymptotically exact sampling from ∏_m KDE(p_m)."""
    counts = counts_or_full(samples, counts)
    schedule = _resolve_schedule(samples, schedule, rescale)
    model = nonparametric_model(samples)
    return run_img(
        key, samples, n_draws, model,
        counts=counts, schedule=schedule, n_batch=n_batch, weight_eval=weight_eval,
    )


@register("semiparametric", "semiparametric_img")
def semiparametric(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    schedule: Optional[Schedule] = None,
    rescale: bool = False,
    nonparametric_weights: bool = False,
    n_batch: int = 1,
    weight_eval: str = "incremental",
    **_ignored,
) -> CombineResult:
    """§3.3 semiparametric combiner (see :func:`semiparametric_model`)."""
    counts = counts_or_full(samples, counts)
    schedule = _resolve_schedule(samples, schedule, rescale)
    model = semiparametric_model(
        samples, counts, nonparametric_weights=nonparametric_weights
    )
    return run_img(
        key, samples, n_draws, model,
        counts=counts, schedule=schedule, n_batch=n_batch, weight_eval=weight_eval,
    )


@register("semiparametric_w", "semiparametric_wt")
def semiparametric_w(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    **options,
) -> CombineResult:
    """§3.3 second variant: semiparametric components, nonparametric weights."""
    options.pop("nonparametric_weights", None)
    return semiparametric(
        key, samples, n_draws, counts=counts, nonparametric_weights=True, **options
    )
