"""Combiner engine: registry-backed subposterior combination — paper §3.

Every combination procedure in the paper (and its experimental baselines)
lives here behind one registry. Resolve by name with
``get_combiner(name)(key, samples, n_draws, counts=..., **options)``;
enumerate with :func:`available_combiners`.

Registered combiners ↔ paper sections
-------------------------------------
==========================  =======  ==================================================
registry name               paper    procedure
==========================  =======  ==================================================
``parametric``              §3.1     Gaussian (BvM) product of subposterior moments —
                                     approximate, fast (Eqs. 3.1–3.2)
``nonparametric``           §3.2     Algorithm 1: IMG sampling from the product of
                                     subposterior KDEs — asymptotically exact
``semiparametric``          §3.3     Hjort–Glad product with weights W_t —
                                     asymptotically exact, parametric efficiency
``semiparametric_w``        §3.3     second variant: semiparametric components with
                                     nonparametric weights w_t (higher acceptance)
``subpost_average``         §8       "subpostAvg" baseline: uniform average of aligned
                                     draws (alias ``subpostAvg``)
``consensus``               §7       Consensus Monte Carlo (Scott et al.):
                                     precision-weighted averaging
``pool``                    §8       "subpostPool" baseline: union of all subposterior
                                     samples (alias ``subpostPool``)
``weierstrass``             related  Weierstrass refinement sampler (Wang & Dunson):
                                     exact Gibbs over latent per-machine refinement
                                     draws with the shared shrinking-h anneal
                                     (alias ``weierstrass_refine``)
``rpt``                     related  random-partition-tree pooling (Wang, Guo &
                                     Dunson): median-cut partition of the pooled
                                     cloud, per-leaf product of block densities
                                     (alias ``random_partition_tree``)
``importance_pool``         related  importance-weighted pooling: pooled draws
                                     reweighted by Σ_m log p̂_m − log q̂ with
                                     self-normalized (truncated) resampling
                                     (alias ``importance_weighted_pool``)
``online``                  §4       streaming parametric product from Welford
                                     running moments — O(d²) state, no gathered
                                     stack (alias ``online_parametric``)
==========================  =======  ==================================================

The IMG combiners additionally accept ``n_batch`` (independent vmapped index
chains — see :mod:`repro.core.combiners.img`) and ``weight_eval="kernel"``
(vectorized sweeps scored by the Pallas ``repro.kernels.img_weights``
kernel). The pairwise-tree reduction (:mod:`repro.core.tree_combine`), the
CLI driver (:mod:`repro.launch.mcmc_run`), the benchmarks, and the mesh
EP-MCMC final stage (:func:`repro.distributed.epmcmc.combine_gathered`) all
dispatch through this registry; adding a combiner here makes it available to
every consumer at once.

Layout convention: subposterior samples are a dense array ``(M, T, d)``.
Ragged sample counts (straggler chains — paper footnote 1) are supported via
``counts (M,)``: chain m's valid samples are rows ``[0, counts[m])``.
The mesh gather in :func:`repro.distributed.epmcmc.gather_subset_samples`
returns a single snapshot ``(C, d_sub)``; before it can feed a combiner it
must gain the T axis — pass ``history=True`` there (T=1 adapter) or stack
per-step snapshots with ``epmcmc.stack_subset_history`` → ``(C, T, d_sub)``.

Option-forwarding convention: callers broadcasting one option dict to many
combiners (the CLI's ``--combiner all`` loop, ``tree_combine``'s
``rescale``, ``epmcmc.combine_gathered``) filter it per combiner signature
with :func:`filter_options` — a combiner only sees options it declares.
``**options`` (no underscore) in a signature marks a passthrough wrapper
that receives everything; ``**_ignored`` marks tolerated-but-unused
keywords, which :func:`filter_options` drops before the call.

Bandwidth convention: the Gaussian kernel is ``N(θ | θ^m_{t_m}, h² I_d)``;
the paper's §3.3 occasionally writes ``h`` where dimensional consistency
requires ``h²`` — we use ``h²`` throughout (matching §3.2 and the annealed
schedule).

Streaming convention (paper §4): every registered name also resolves to a
:class:`StreamingCombiner` (``init(M, d) → update(state, chunk, counts)* →
finalize(key, state, n_draws)``) via :func:`get_streaming_combiner` —
natively incremental for ``parametric``/``pool``/``subpost_average``/
``nonparametric``/``online`` (:mod:`repro.core.combiners.streaming` and
``online``'s own registration), exact buffered fallback for the rest.
Chunks are dense ``(M, C, d)`` per-machine slices; ``finalize`` on the
buffered implementations is bitwise the batch combiner on the gathered
stack. Consumers: ``Pipeline.stream_combine`` (combine-while-sampling),
``epmcmc.combine_stream`` (mesh chunked gather), and the ``repro.serve``
query layer. Mid-stream refreshes go through the optional ``estimate`` slot;
:func:`streaming_estimate` resolves it with a typed
:class:`EstimateUnavailable` for names that only finalize.

Fused streaming (the scan face): names additionally resolve through
:func:`get_scan_face` to an optional :class:`ScanStreamingFace` — the
jit-traceable subset (``init``/``update``/``to_state``/``estimate``) that
``Pipeline.stream_combine`` scans inside one compiled combine-fold program
when every requested combiner has one. ``parametric``/``online`` register
explicit faces (``online``'s update runs the Pallas
``repro.kernels.online_update`` kernel); buffered combiners get the trivial
face automatically.
"""

from repro.core.combiners.api import (  # noqa: F401
    BufferState,
    Combiner,
    CombineResult,
    EstimateUnavailable,
    ScanStreamingFace,
    StreamingCombiner,
    available_combiners,
    buffer_append,
    buffer_init,
    buffered_streaming,
    canonical_combiners,
    counts_or_full,
    filter_options,
    get_combiner,
    get_scan_face,
    get_streaming_combiner,
    log_weight_bruteforce,
    ragged_gather,
    register,
    register_scan_face,
    register_streaming,
    resolve_schedule,
    streaming_combiners,
    streaming_estimate,
    valid_masks,
)
from repro.core.combiners.baselines import (  # noqa: F401
    consensus_weighted,
    pool,
    subpost_average,
)
from repro.core.combiners.img import (  # noqa: F401
    ImgWeightModel,
    nonparametric,
    nonparametric_model,
    run_img,
    semiparametric,
    semiparametric_model,
    semiparametric_w,
)
from repro.core.combiners.density import (  # noqa: F401
    machine_kde_logpdfs,
    machine_kde_scores,
    masked_silverman,
)
from repro.core.combiners.importance_pool import importance_pool  # noqa: F401
from repro.core.combiners.online import (  # noqa: F401
    OnlineMoments,
    online,
    online_init,
    online_product,
    online_update,
    online_update_chunk,
    online_update_chunk_kernel,
)
from repro.core.combiners.parametric import parametric  # noqa: F401
from repro.core.combiners.rpt import rpt  # noqa: F401
from repro.core.combiners.weierstrass import weierstrass  # noqa: F401

# native streaming implementations attach to the names registered above, so
# this import must stay last
from repro.core.combiners import streaming as _streaming  # noqa: F401
