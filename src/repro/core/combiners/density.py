"""Shared subposterior-KDE evaluation for the sample-reweighting combiners.

The Weierstrass refinement sampler and importance-weighted pooling both need
``log p̂_m(θ)`` — each machine's Gaussian-KDE log density — evaluated at many
query points. Two execution paths behind one helper:

- ``counts is None`` (dense chains): one call per machine to the Pallas
  :func:`repro.kernels.kde_density.kde_log_density` streaming kernel — the
  TPU hot path (flash-style tiled logsumexp, no (Q, T) matrix in HBM).
- ragged ``counts``: a chunked masked-logsumexp jnp path, because the valid
  prefix of each chain is data-dependent and the kernel scores all centers.
  This is also the path the pairwise tree reduction takes (it always carries
  per-pair counts), which keeps the whole combiner vmap-able over pairs.

Bandwidths come from :func:`masked_silverman` — Silverman's rule per machine
over the valid prefix only, so straggler chains don't drag garbage rows into
the scale estimate.
"""

from __future__ import annotations

import math

from typing import Optional

import jax
import jax.numpy as jnp

# host-side, not jnp.log(...): module import must not run a JAX
# computation (jax.distributed.initialize refuses to start after one)
_LOG2PI = math.log(2.0 * math.pi)


def masked_silverman(samples: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """Per-machine Silverman bandwidth over the valid prefix → ``(M,)``.

    h_m = (4/(d+2))^{1/(d+4)} · T_m^{-1/(d+4)} · σ̄_m with σ̄_m the mean
    marginal std of chain m's first ``counts[m]`` rows (unbiased normalizer).
    """
    M, T, d = samples.shape
    # where (not mask-multiply): invalid rows may hold NaN garbage, and 0·NaN
    # would leak it into the reduction.
    mask = (jnp.arange(T)[None, :] < counts[:, None])[..., None]  # (M, T, 1)
    n = jnp.maximum(counts.astype(samples.dtype), 1.0)
    valid = jnp.where(mask, samples, 0.0)
    mean = jnp.sum(valid, axis=1) / n[:, None]
    var = jnp.sum(jnp.where(mask, samples - mean[:, None, :], 0.0) ** 2, axis=1)
    var = var / jnp.maximum(n - 1.0, 1.0)[:, None]
    sigma = jnp.mean(jnp.sqrt(var), axis=-1)
    h = (4.0 / (d + 2.0)) ** (1.0 / (d + 4.0)) * n ** (-1.0 / (d + 4.0)) * sigma
    # floor: a constant (or single-draw) chain has sigma 0, and h=0 would
    # NaN-poison every downstream logit via 0/0 — a floored h makes its KDE
    # an effective point mass instead
    return jnp.maximum(h, 1e-8)


def machine_kde_logpdfs(
    queries: jnp.ndarray,  # (Q, d)
    samples: jnp.ndarray,  # (M, T, d)
    counts: Optional[jnp.ndarray],  # None ⇒ dense (Pallas kernel path)
    h: jnp.ndarray,  # (M,) per-machine bandwidths
    *,
    chunk: int = 256,
) -> jnp.ndarray:
    """``log p̂_m(queries)`` for every machine → ``(M, Q)``.

    ``Σ over axis 0`` of the result is the pooled product score Σ_m log p̂_m;
    a counts-weighted logsumexp over axis 0 is the pooled-mixture proposal
    density — the two quantities the reweighting combiners build on.
    """
    M, T, d = samples.shape
    if counts is None:
        from repro.kernels.kde_density import kde_log_density

        return jnp.stack(
            [kde_log_density(queries, samples[m], h[m]) for m in range(M)]
        )

    mask = jnp.arange(T)[None, :] < counts[:, None]  # (M, T) bool
    csq = jnp.sum(samples**2, axis=-1)  # (M, T)
    Q = queries.shape[0]
    pad = (-Q) % chunk
    qp = jnp.pad(queries, ((0, pad), (0, 0))).reshape(-1, chunk, d)

    def block(qc):  # (chunk, d) → (M, chunk)
        sq = (
            jnp.sum(qc**2, axis=-1)[None, :, None]
            + csq[:, None, :]
            - 2.0 * jnp.einsum("qd,mtd->mqt", qc, samples)
        )
        logk = -0.5 * sq / (h[:, None, None] ** 2)
        logk = jnp.where(mask[:, None, :], logk, -jnp.inf)
        return jax.scipy.special.logsumexp(logk, axis=-1)

    out = jax.lax.map(block, qp)  # (n_chunks, M, chunk)
    lse = jnp.moveaxis(out, 0, 1).reshape(M, -1)[:, :Q]  # (M, Q)
    log_norm = (
        -jnp.log(jnp.maximum(counts.astype(queries.dtype), 1.0))
        - 0.5 * d * (2.0 * jnp.log(h) + _LOG2PI)
    )
    return lse + log_norm[:, None]
