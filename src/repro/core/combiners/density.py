"""Shared subposterior-KDE evaluation for the sample-reweighting combiners.

The Weierstrass refinement sampler and importance-weighted pooling both need
``log p̂_m(θ)`` — each machine's Gaussian-KDE log density — evaluated at many
query points. Since the batched scoring engine landed this is ONE code path
for dense and ragged chains: :func:`repro.kernels.kde_density.
machine_kde_log_density` scores all machines in a single launch (one Pallas
program on TPU — grid over (query-tile, machine, center-tile), flash-style
tiled logsumexp, per-machine bandwidth and valid-prefix ``counts`` applied
inside the kernel; the vectorized chunked jnp ref elsewhere). Callers that
only need the pooled product score Σ_m log p̂_m or a mixture proposal score
should use :func:`machine_kde_scores`, whose fused reductions never
materialize the (M, Q) matrix on the kernel path.

The pairwise tree reduction reuses the same helpers (it always carries
per-pair counts), which keeps the whole combiner vmap-able over pairs — the
ref path is pure jnp and vmaps transparently.

Bandwidths come from :func:`masked_silverman` — Silverman's rule per machine
over the valid prefix only, so straggler chains don't drag garbage rows into
the scale estimate.
"""

from __future__ import annotations

from typing import Optional, Tuple, Union

import jax.numpy as jnp


def masked_silverman(samples: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """Per-machine Silverman bandwidth over the valid prefix → ``(M,)``.

    h_m = (4/(d+2))^{1/(d+4)} · T_m^{-1/(d+4)} · σ̄_m with σ̄_m the mean
    marginal std of chain m's first ``counts[m]`` rows (unbiased normalizer).
    """
    M, T, d = samples.shape
    # where (not mask-multiply): invalid rows may hold NaN garbage, and 0·NaN
    # would leak it into the reduction.
    mask = (jnp.arange(T)[None, :] < counts[:, None])[..., None]  # (M, T, 1)
    n = jnp.maximum(counts.astype(samples.dtype), 1.0)
    valid = jnp.where(mask, samples, 0.0)
    mean = jnp.sum(valid, axis=1) / n[:, None]
    var = jnp.sum(jnp.where(mask, samples - mean[:, None, :], 0.0) ** 2, axis=1)
    var = var / jnp.maximum(n - 1.0, 1.0)[:, None]
    sigma = jnp.mean(jnp.sqrt(var), axis=-1)
    h = (4.0 / (d + 2.0)) ** (1.0 / (d + 4.0)) * n ** (-1.0 / (d + 4.0)) * sigma
    # floor: a constant (or single-draw) chain has sigma 0, and h=0 would
    # NaN-poison every downstream logit via 0/0 — a floored h makes its KDE
    # an effective point mass instead
    return jnp.maximum(h, 1e-8)


def machine_kde_logpdfs(
    queries: jnp.ndarray,  # (Q, d)
    samples: jnp.ndarray,  # (M, T, d)
    counts: Optional[jnp.ndarray],  # None ⇒ every chain dense (all T rows)
    h: jnp.ndarray,  # (M,) per-machine bandwidths
    *,
    chunk: int = 256,
) -> jnp.ndarray:
    """``log p̂_m(queries)`` for every machine → ``(M, Q)``.

    ``Σ over axis 0`` of the result is the pooled product score Σ_m log p̂_m;
    a counts-weighted logsumexp over axis 0 is the pooled-mixture proposal
    density — but callers needing only those reductions should go through
    :func:`machine_kde_scores` to keep (M, Q) off the hot path.
    """
    from repro.kernels.kde_density import machine_kde_log_density

    return machine_kde_log_density(queries, samples, h, counts, chunk=chunk)


def machine_kde_scores(
    queries: jnp.ndarray,  # (Q, d)
    samples: jnp.ndarray,  # (M, T, d)
    counts: Optional[jnp.ndarray],
    h: jnp.ndarray,  # (M,)
    *,
    reduce: str,
    mixture_weights: str = "uniform",
    chunk: int = 256,
) -> Union[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Fused pooled scores: ``reduce`` ∈ {"product", "mixture",
    "product_mixture"} → (Q,) (or a pair of them), computed without ever
    materializing the (M, Q) log-density matrix on the kernel path.
    """
    from repro.kernels.kde_density import machine_kde_log_density

    return machine_kde_log_density(
        queries, samples, h, counts,
        reduce=reduce, mixture_weights=mixture_weights, chunk=chunk,
    )
