"""Native streaming implementations for the core combiners (paper §4).

Attached to the registry via :func:`~repro.core.combiners.api.register_streaming`
(the ``online`` combiner attaches its own through ``register(streaming=)`` in
:mod:`repro.core.combiners.online`):

``parametric``
    State = the draw buffer **plus** Welford running moments
    (:class:`~repro.core.combiners.online.OnlineMoments`). ``finalize``
    replays the batch parametric combiner on the buffer — **bitwise** the
    gather-then-combine result — while ``estimate`` samples the product of
    the streaming moments in O(d²), the cheap per-chunk trajectory point.

``pool`` / ``subpost_average``
    The union *is* the accumulated buffer, so the exact buffered adapter is
    already their natural streaming form (bitwise finalize); their
    ``estimate`` subsamples the buffer at even stride in O(n_draws) — the
    rows the batch body would select, without replaying it.

``nonparametric``
    Chunk updates accumulate the per-machine KDE state — the mixture
    centers and valid counts each machine's ``p̂_m`` is built from —
    and ``finalize`` runs the full IMG chain (Algorithm 1) against it:
    bitwise the batch combiner on the same gathered stack. ``estimate``
    runs a short *batched* IMG (``n_batch`` floored at 8) so mid-stream
    trajectory points cost ~1/8 the serial scan length.

Every other registered combiner streams through the generic buffered
fallback of :func:`~repro.core.combiners.api.get_streaming_combiner`.

Scan faces (the fused sample+combine hot path — see
:class:`~repro.core.combiners.api.ScanStreamingFace`): ``parametric`` scans
its Welford moments only (the draw buffer is the fused scan's own output)
and estimates the moment product in-scan; the buffer-state combiners
(``pool``, ``subpost_average``, ``nonparametric``) carry a trivial ``()``
scan state and rebuild their :class:`BufferState` from the gathered draws
after the scan, so their host ``estimate``/``finalize`` run unchanged.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.combiners.api import (
    BufferState,
    CombineResult,
    ScanStreamingFace,
    StreamingCombiner,
    buffer_append,
    buffer_init,
    buffered_streaming,
    register_scan_face,
    register_streaming,
)
from repro.core.combiners.baselines import pool_combiner, subpost_average_combiner
from repro.core.combiners.img import nonparametric
from repro.core.combiners.online import (
    OnlineMoments,
    online_init,
    online_product,
    online_update_chunk,
)
from repro.core.combiners.online import _finalize as _online_finalize
from repro.core.combiners.parametric import parametric
from repro.core.gaussian import sample_gaussian


# ---------------------------------------------------------------------------
# parametric: exact buffered finalize + O(d²) Welford trajectory estimates
# ---------------------------------------------------------------------------


class ParametricStreamState(NamedTuple):
    buffer: BufferState
    moments: OnlineMoments


_PARAMETRIC_BUFFERED = buffered_streaming(parametric)


def _parametric_init(M: int, d: int) -> ParametricStreamState:
    return ParametricStreamState(buffer_init(M, d), online_init(M, d))


def _parametric_update(state, chunk, chunk_counts=None) -> ParametricStreamState:
    return ParametricStreamState(
        buffer=buffer_append(state.buffer, chunk, chunk_counts),
        moments=online_update_chunk(state.moments, chunk, chunk_counts),
    )


def _parametric_finalize(key, state, n_draws, **options) -> CombineResult:
    # one option-filtering convention for batch and stream alike: delegate
    # to the buffered adapter, which replays the batch combiner exactly
    return _PARAMETRIC_BUFFERED.finalize(key, state.buffer, n_draws, **options)


def _parametric_estimate(
    key, state, n_draws, *, jitter: float = 1e-8, **_ignored
) -> CombineResult:
    return _online_finalize(key, state.moments, n_draws, jitter=jitter)


PARAMETRIC_STREAMING = register_streaming(
    "parametric",
    StreamingCombiner(
        init=_parametric_init,
        update=_parametric_update,
        finalize=_parametric_finalize,
        estimate=_parametric_estimate,
    ),
)


def _parametric_scan_estimate(
    key, moments: OnlineMoments, n_draws: int, *, jitter: float = 1e-8, **_ignored
):
    # same math as _parametric_estimate (sample the product of the running
    # moments), as raw draws — traced into the fused scan step
    return sample_gaussian(key, online_product(moments, jitter=jitter), n_draws)


PARAMETRIC_SCAN = register_scan_face(
    "parametric",
    ScanStreamingFace(
        init=online_init,
        # the jnp chunk merge, not the Pallas kernel: trajectory estimates
        # then track the subscriber path's moment math exactly (the kernel
        # is the `online` combiner's scan face)
        update=online_update_chunk,
        to_state=lambda moments, theta, counts: ParametricStreamState(
            BufferState(theta, counts), moments
        ),
        estimate=_parametric_scan_estimate,
    ),
)


# ---------------------------------------------------------------------------
# pool / subpostAvg: the buffered adapter IS the streaming form (exact), and
# a genuinely cheap `estimate` reads O(n_draws) rows straight off the buffer
# — unlike the generic fallback, which deliberately leaves `estimate=None`
# so trajectory consumers (and the serving layer) don't re-run heavy
# combiners (weierstrass, rpt, ...) on the growing buffer every refresh.
# Historically `estimate` aliased `finalize`, which replays the full batch
# body per call: pool materializes the whole M·t union (a payload that grows
# with the stream) and subpostAvg gathers/averages every buffered row. Both
# estimates below subsample FIRST, so a refresh costs O(n_draws·d) however
# long the stream has run — the latency bound `repro.serve` readers sit on.
# ---------------------------------------------------------------------------


def _pool_estimate(key, state: BufferState, n_draws, **_ignored) -> CombineResult:
    """Even-strided ``n_draws`` rows of the current union — elementwise the
    rows ``pool``'s finalize would put at those indices (same ``m·t + r``
    flattening, same ragged wrap), without materializing the M·t cloud."""
    del key
    theta, counts = state.theta, state.counts
    M, t, _ = theta.shape
    if t == 0:
        raise ValueError("streaming estimate before any update() chunk")
    total = M * t
    if n_draws <= total:
        flat = (jnp.arange(n_draws) * total) // n_draws
    else:
        flat = jnp.arange(n_draws) % total
    m_idx, r_idx = flat // t, flat % t
    r_idx = r_idx % jnp.maximum(counts[m_idx], 1)
    return CombineResult(samples=theta[m_idx, r_idx], acceptance_rate=jnp.ones(()))


def _subpost_avg_estimate(
    key, state: BufferState, n_draws, **_ignored
) -> CombineResult:
    """subpostAvg at ``n_draws`` even-strided draw indices: gather the (M,
    n_draws, d) slice (ragged wrap per machine) and average over machines —
    bitwise the rows ``finalize``'s full gather-then-average would select,
    since the mean over machines commutes with row selection."""
    del key
    theta, counts = state.theta, state.counts
    M, t, _ = theta.shape
    if t == 0:
        raise ValueError("streaming estimate before any update() chunk")
    if n_draws <= t:
        idx = (jnp.arange(n_draws) * t) // n_draws
    else:
        idx = jnp.arange(n_draws) % t
    rows = idx[None, :] % jnp.maximum(counts[:, None], 1)  # (M, n_draws)
    sel = jnp.take_along_axis(theta, rows[:, :, None], axis=1)
    return CombineResult(samples=jnp.mean(sel, axis=0), acceptance_rate=jnp.ones(()))


POOL_STREAMING = register_streaming(
    "pool",
    buffered_streaming(pool_combiner)._replace(estimate=_pool_estimate),
)
SUBPOST_AVERAGE_STREAMING = register_streaming(
    "subpost_average",
    buffered_streaming(subpost_average_combiner)._replace(
        estimate=_subpost_avg_estimate
    ),
)


# ---------------------------------------------------------------------------
# nonparametric: accumulated per-machine KDE state + batched-IMG estimates
# ---------------------------------------------------------------------------

_NONPARAMETRIC_BUFFERED = buffered_streaming(nonparametric)


def _nonparametric_estimate(key, state, n_draws, **options) -> CombineResult:
    # mid-stream snapshots ride the vmapped index chains: same stationary
    # distribution per chain (see img.run_img), ~1/n_batch the scan length
    opts = dict(options)
    opts["n_batch"] = max(int(opts.get("n_batch", 1) or 1), 8)
    return _NONPARAMETRIC_BUFFERED.finalize(key, state, n_draws, **opts)


NONPARAMETRIC_STREAMING = register_streaming(
    "nonparametric",
    StreamingCombiner(
        init=buffer_init,
        update=buffer_append,
        finalize=_NONPARAMETRIC_BUFFERED.finalize,
        estimate=_nonparametric_estimate,
    ),
)


# ---------------------------------------------------------------------------
# buffer-state scan faces: the fused scan already materializes the draws, so
# the in-scan state is trivial and the host BufferState is rebuilt from the
# gathered (M, T, d) stack afterwards. `estimate=None` here means mid-stream
# rows are computed post-hoc on buffered prefixes by the fused driver.
# ---------------------------------------------------------------------------

_BUFFER_SCAN = ScanStreamingFace(
    init=lambda M, d: (),
    update=lambda state, chunk: state,
    to_state=lambda state, theta, counts: BufferState(theta, counts),
)
for _name in ("pool", "subpost_average", "nonparametric"):
    register_scan_face(_name, _BUFFER_SCAN)
