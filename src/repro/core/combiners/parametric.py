"""§3.1 parametric combiner: Gaussian (BvM) product — approximate, fast."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.combiners.api import (
    CombineResult,
    counts_or_full,
    register,
    valid_masks,
)
from repro.core.gaussian import (
    fit_moments,
    product_moments,
    product_moments_diag,
    sample_gaussian,
)


@register("parametric")
def parametric(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    diag: bool = False,
    **_ignored,
) -> CombineResult:
    """Sample from the Gaussian product estimate (Eqs. 3.1–3.2)."""
    counts = counts_or_full(samples, counts)
    masks = valid_masks(samples, counts)
    moments = jax.vmap(lambda s, mk: fit_moments(s, mk, diag=diag))(samples, masks)
    if diag:
        prod = product_moments_diag(moments.mean, moments.cov)
    else:
        prod = product_moments(moments.mean, moments.cov)
    draws = sample_gaussian(key, prod, n_draws)
    return CombineResult(samples=draws, acceptance_rate=jnp.ones(()), moments=prod)
