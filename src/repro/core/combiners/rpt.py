"""Random-partition-tree pooling — per-leaf product of block densities.

Wang, Guo & Dunson's random-partition-tree view of the density product: a
space partition shared by all machines turns the product of M continuous
densities into a product of M *histograms* on the same bins, which is exact
to evaluate — no MCMC over indices at all. A single partition is a noisy,
blocky estimate, so (as in the source method) the combiner averages an
**ensemble** of ``n_trees`` independently randomized partitions: the
estimate is the uniform mixture of the per-tree product histograms.

Per-tree construction (all static-shape, vmap-able — over trees here and
over pairs inside the pairwise tree reduction):

1. pool the ``(M·T, d)`` cloud (ragged chains densified by wrap —
   ``ragged_gather``), randomly permute, truncate to a multiple of 2^depth;
2. recursively median-cut: each *level* picks one cut dimension by
   Gumbel-perturbed log-variance (high-spread dims are likelier cuts, ties
   break randomly — this and the permutation are the tree's randomness),
   every node segment sorts its points along it and splits at its own
   median, giving perfectly balanced leaves of S = N/2^depth points each;
3. a vmapped per-leaf pass computes each leaf's per-machine occupancy
   c_m(leaf), bounding box, and spread;
4. the leaf's product mass is ∏_m [ĉ_m(leaf)/(T_m·vol)] · vol, i.e. in logs
   Σ_m log(c_m + α) − Σ_m log(T_m + α·L) − (M−1)·log vol, with a Jeffreys
   pseudocount α keeping empty-machine leaves finite. ``vol`` is the box
   volume over the *cut* dimensions only: because the cut-dim multiset is
   shared by every leaf (level-wise choice above), the un-cut dimensions
   contribute one common factor that cancels in the leaf softmax — at
   d ≫ depth a full-box volume would be (M−1)·(d−depth) dims of pure
   min/max noise, which is exactly the degenerate all-mass-on-one-leaf
   failure mode this sidesteps;
5. draws: tree ~ Uniform(n_trees), leaf | tree ~ Categorical(product mass),
   then a point within the leaf — ``within="resample"`` (default) re-draws
   one of the leaf's pooled members plus a ``jitter``·leaf-std Gaussian
   perturbation (smoothed bootstrap; respects the data manifold at high d),
   ``within="uniform"`` draws uniform in the leaf's bounding box (the
   piecewise-constant estimator taken literally — fine at low d, hopeless
   at d ≳ 10).

Asymptotics: as T → ∞ with depth → ∞, S/N → 0, each histogram product
converges to the true density product on the partition refinement — the
same asymptotically exact family as the KDE-product combiners, with
O(n_trees·N·d·depth) one-shot cost instead of a chain.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.combiners.api import (
    CombineResult,
    counts_or_full,
    ragged_gather,
    register,
)


def _default_depth(n: int, m: int) -> int:
    """Deepest balanced tree keeping every machine's leaf occupancy stable.

    The weight's count term Σ_m log(c_m + α) carries ~Σ_m c_m^{-1/2} of
    sampling noise, so leaves need ≥ ~25 points *per machine* before the
    leaf softmax measures density product rather than occupancy noise."""
    leaf_target = max(32, 24 * m)
    return max(1, min(12, int(math.floor(math.log2(max(2, n // leaf_target))))))


@register("rpt", "random_partition_tree")
def rpt(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    depth: Optional[int] = None,
    n_trees: int = 8,
    pseudocount: float = 0.5,
    within: str = "resample",
    jitter: float = 1.0,
    **_ignored,
) -> CombineResult:
    """Sample the random-partition-tree-ensemble product-density estimate.

    ``depth``: tree depth (2^depth leaves); default keeps ≥ max(32, 24M)
    points per leaf. ``n_trees``: ensemble size (uniform mixture of
    per-tree estimates). ``pseudocount``: Jeffreys smoothing α on leaf
    counts. ``within``: ``"resample"`` (leaf-member redraw + ``jitter``·
    leaf-std Gaussian smoothing) or ``"uniform"`` (uniform in the leaf box).
    """
    if within not in ("resample", "uniform"):
        raise ValueError(f"unknown within={within!r}; use 'resample' or 'uniform'")
    M, T, d = samples.shape
    dtype = samples.dtype
    counts_arr = counts_or_full(samples, counts)
    N = M * T
    L = _default_depth(N, M) if depth is None else max(1, int(depth))
    # a tree can never be deeper than the pooled cloud can populate
    L = min(L, int(math.floor(math.log2(max(2, N)))))
    K = max(1, int(n_trees))
    n_leaf = 2**L
    S = max(1, N // n_leaf)
    n_keep = S * n_leaf

    pooled = ragged_gather(samples, counts_arr).reshape(N, d)
    machine = jnp.repeat(jnp.arange(M), T)  # (N,)

    k_tree, k_pick, k_member, k_within = jax.random.split(key, 4)

    # global per-dim scale for the degenerate-span guard (duplicate-heavy
    # leaves from ragged wrapping must not get a log(0) volume bonus)
    span_floor = 1e-6 * (jnp.max(pooled, axis=0) - jnp.min(pooled, axis=0)) + 1e-12
    alpha = jnp.asarray(pseudocount, jnp.float32)

    def one_tree(k):
        """Build one randomized balanced partition → leaf arrays + log mass."""
        k_perm, k_dim = jax.random.split(k)
        perm = jax.random.permutation(k_perm, N)[:n_keep]
        pts = pooled[perm]
        ids = machine[perm]

        # the median-cut recursion is a scan over levels, not an unrolled
        # Python loop: every level has the same static point shapes, node
        # statistics come from segment reductions, and the per-node stable
        # argsort is one lexsort keyed (cut coordinate, node id) — identical
        # ordering (both sorts are stable), one compiled level body instead
        # of L gather programs.
        def level(carry, lvl):
            pts, ids = carry
            n_nodes = jnp.left_shift(1, lvl)  # traced 2^lvl
            seg = n_keep // n_nodes
            seg_id = jnp.arange(n_keep) // seg  # point → node, (n_keep,)
            seg_f = seg.astype(pts.dtype)
            # one cut dim per LEVEL (mean within-node variance, Gumbel-
            # perturbed) so every leaf shares the same cut-dim multiset —
            # see the module docstring's volume-cancellation argument
            node_mean = (
                jax.ops.segment_sum(pts, seg_id, num_segments=n_leaf) / seg_f
            )  # (n_leaf, d); rows ≥ n_nodes stay zero and drop out below
            dev = (pts - node_mean[seg_id]) ** 2
            node_var = jax.ops.segment_sum(dev, seg_id, num_segments=n_leaf) / seg_f
            var = jnp.sum(node_var, axis=0) / n_nodes.astype(pts.dtype)  # (d,)
            gum = jax.random.gumbel(jax.random.fold_in(k_dim, lvl), var.shape)
            cut = jnp.argmax(jnp.log(var + 1e-20) + gum)  # () traced dim index
            order = jnp.lexsort((jnp.take(pts, cut, axis=1), seg_id))
            return (pts[order], ids[order]), cut

        (pts, ids), cut_dims = jax.lax.scan(level, (pts, ids), jnp.arange(L))
        leaves = pts.reshape(n_leaf, S, d)
        leaf_ids = ids.reshape(n_leaf, S)

        def leaf_stats(members, member_ids):
            occ = jnp.sum(jax.nn.one_hot(member_ids, M, dtype=jnp.float32), axis=0)
            return occ, jnp.min(members, 0), jnp.max(members, 0), jnp.std(members, 0)

        occ, lo, hi, std = jax.vmap(leaf_stats)(leaves, leaf_ids)  # per-leaf pass

        t_m = jnp.sum(occ, axis=0)  # (M,) per-machine points after truncation
        # volume over the cut-dim multiset only (a dim cut twice enters its
        # span twice — wrong absolutely, identical across leaves, so
        # softmax-exact)
        log_span = jnp.log(hi - lo + span_floor)  # (n_leaf, d)
        log_vol = jnp.sum(log_span[:, cut_dims], axis=-1)  # (n_leaf,)
        log_w = (
            jnp.sum(jnp.log(occ + alpha), axis=-1)
            - jnp.sum(jnp.log(t_m + alpha * n_leaf))
            - (M - 1) * log_vol
        )  # (n_leaf,) unnormalized log product mass
        log_w = log_w - jax.scipy.special.logsumexp(log_w)  # normalized per tree
        return leaves, lo, hi, std, log_w

    leaves, lo, hi, std, log_w = jax.vmap(one_tree)(jax.random.split(k_tree, K))
    # → (K, n_leaf, S, d), (K, n_leaf, d) ×3, (K, n_leaf)

    # uniform tree mixture: draw (tree, leaf) jointly from the normalized
    # per-tree masses — flat categorical over K·n_leaf with equal tree weight
    flat_logw = (log_w - jnp.log(float(K))).reshape(K * n_leaf)
    pick = jax.random.categorical(k_pick, flat_logw, shape=(n_draws,))
    tree_idx, leaf_idx = pick // n_leaf, pick % n_leaf
    if within == "uniform":
        u = jax.random.uniform(k_within, (n_draws, d), dtype)
        span = (hi - lo)[tree_idx, leaf_idx]
        draws = lo[tree_idx, leaf_idx] + u * span
    else:
        member = jax.random.randint(k_member, (n_draws,), 0, S)
        eps = jax.random.normal(k_within, (n_draws, d), dtype)
        draws = (
            leaves[tree_idx, leaf_idx, member]
            + jitter * std[tree_idx, leaf_idx] * eps
        )

    mix_logw = flat_logw - jax.scipy.special.logsumexp(flat_logw)
    return CombineResult(
        samples=draws,
        acceptance_rate=jnp.ones(()),  # one-shot estimator: nothing rejected
        moments=None,
        extras={
            "depth": jnp.asarray(L),
            "n_trees": jnp.asarray(K),
            "leaf_size": jnp.asarray(S),
            # perplexity of the (tree, leaf) mixture — effective support size
            "leaf_perplexity": jnp.exp(-jnp.sum(jnp.exp(mix_logw) * mix_logw)),
        },
    )
