"""Importance-weighted pooling — reweight the pooled cloud to the product.

The ``pool`` baseline treats the union of all subposterior draws as if it
targeted the full posterior; it actually targets the *mixture*
(1/M)Σ_m p_m. This combiner keeps pooling's one-shot, chain-free character
but corrects the distribution by self-normalized importance sampling:

    target    p(θ)  ∝ ∏_m p̂_m(θ)           (product of subposterior KDEs)
    proposal  q(θ)  =  (1/M) Σ_m p̂_m(θ)     (the pooled cloud's own law —
                                             wrap-densified ragged chains all
                                             contribute exactly T rows)
    log w_i   =  Σ_m log p̂_m(θ_i) − log q(θ_i)

evaluated on every pooled point θ_i with the registry's counts-masked KDE
API (:mod:`repro.core.combiners.density` — the batched all-machines
``machine_kde_log_density`` op). Target and proposal are one fused
``product_mixture`` evaluation: the kernel path computes both (N,) scores in
a single launch without materializing the (M, N) log-density matrix.

Self-normalized resampling then emits exactly ``n_draws`` rows. Two
standard IS safeguards, both optional:

- ``truncate=True`` clips log-weights at  log w̄ + ½·log N  (Ionides 2008
  truncated IS: the cap grows with N, so asymptotic exactness is kept while
  a single dominant pooled point can no longer swallow the whole resample);
- ``smooth=True`` adds N(0, h̄²/M · I) jitter to the resampled rows — the
  same component law the IMG combiners draw from, turning the weighted
  empirical measure into the corresponding product-KDE smoothed bootstrap
  and de-duplicating repeated resamples.

``extras["ess"]`` reports the importance ESS (Σw)²/Σw² — the honest
diagnostic for whether pooling's proposal covers the product's region (it
collapses toward 1 when subposteriors barely overlap; the IMG/Weierstrass
chains are the right tool there).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.combiners.api import (
    CombineResult,
    counts_or_full,
    ragged_gather,
    register,
)
from repro.core.combiners.density import machine_kde_scores, masked_silverman


@register("importance_pool", "importance_weighted_pool")
def importance_pool(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    bandwidth: Optional[float] = None,
    truncate: bool = True,
    smooth: bool = True,
    temper: float = 1.0,
    **_ignored,
) -> CombineResult:
    """Self-normalized importance resampling of the pooled cloud.

    ``bandwidth`` overrides the per-machine Silverman KDE bandwidths with a
    shared scalar. ``temper`` ∈ (0, 1] flattens the weights (w^temper) for
    very low-overlap regimes. See the module docstring for ``truncate`` and
    ``smooth``.
    """
    M, T, d = samples.shape
    dtype = samples.dtype
    counts_arr = counts_or_full(samples, counts)
    N = M * T

    pooled = ragged_gather(samples, counts_arr).reshape(N, d)
    if bandwidth is None:
        h = masked_silverman(samples, counts_arr)  # (M,)
    else:
        h = jnp.full((M,), bandwidth, dtype)

    # ragged chains are wrap-densified, so every machine contributes exactly
    # T pooled rows — the pooled cloud's law is the *uniform* mixture of the
    # per-machine KDEs regardless of counts. Both pooled scores come from one
    # fused batched-KDE evaluation; the (M, N) matrix never materializes on
    # the kernel path.
    target, log_q = machine_kde_scores(
        pooled, samples, counts if counts is None else counts_arr, h,
        reduce="product_mixture", mixture_weights="uniform",
    )
    log_w = (target - log_q) * jnp.asarray(temper, jnp.float32)

    if truncate:
        log_mean_w = jax.scipy.special.logsumexp(log_w) - jnp.log(float(N))
        log_w = jnp.minimum(log_w, log_mean_w + 0.5 * jnp.log(float(N)))

    k_sel, k_smooth = jax.random.split(key)
    idx = jax.random.categorical(k_sel, log_w, shape=(n_draws,))
    draws = pooled[idx]
    if smooth:
        h_prod = jnp.mean(h) / jnp.sqrt(jnp.asarray(M, dtype))
        draws = draws + h_prod * jax.random.normal(k_smooth, (n_draws, d), dtype)

    log_z = jax.scipy.special.logsumexp(log_w)
    ess = jnp.exp(2.0 * log_z - jax.scipy.special.logsumexp(2.0 * log_w))
    return CombineResult(
        samples=draws,
        acceptance_rate=jnp.ones(()),  # one-shot resampler: nothing rejected
        moments=None,
        extras={
            "ess": ess,
            "log_weight_max": jnp.max(log_w) - log_z,
            "h_mean": jnp.mean(h),
        },
    )
