"""Online parametric combiner (paper §4: combine as samples stream in)."""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.gaussian import GaussianMoments, product_moments


class OnlineMoments(NamedTuple):
    """Welford running moments per subposterior — O(d²) state, O(1) per sample."""

    count: jnp.ndarray  # (M,)
    mean: jnp.ndarray  # (M, d)
    m2: jnp.ndarray  # (M, d, d) sum of outer products of residuals


def online_init(M: int, d: int, dtype=jnp.float32) -> OnlineMoments:
    return OnlineMoments(
        count=jnp.zeros((M,), dtype),
        mean=jnp.zeros((M, d), dtype),
        m2=jnp.zeros((M, d, d), dtype),
    )


def online_update(state: OnlineMoments, m: jnp.ndarray, theta: jnp.ndarray) -> OnlineMoments:
    """Fold one new sample ``theta`` (d,) from machine ``m`` into the moments."""
    n = state.count[m] + 1.0
    delta = theta - state.mean[m]
    mean_m = state.mean[m] + delta / n
    m2_m = state.m2[m] + jnp.outer(delta, theta - mean_m)
    return OnlineMoments(
        count=state.count.at[m].set(n),
        mean=state.mean.at[m].set(mean_m),
        m2=state.m2.at[m].set(m2_m),
    )


def online_product(state: OnlineMoments, *, jitter: float = 1e-8) -> GaussianMoments:
    """Current parametric product estimate from streaming moments."""
    d = state.mean.shape[-1]
    denom = jnp.maximum(state.count - 1.0, 1.0)[:, None, None]
    covs = state.m2 / denom + jitter * jnp.eye(d)
    return product_moments(state.mean, covs)
