"""Online parametric combiner (paper §4: combine as samples stream in).

The Welford/product machinery keeps O(d²) state per machine and needs O(1)
work per sample, so the parametric product estimate is available at *any*
point of the stream — no gathered ``(M, T, d)`` stack required. It is
registered as the ``online`` combiner with both faces:

- batch: ``online(key, samples, n_draws, counts=...)`` folds the whole
  stack through one chunk update and samples the product — so
  ``--combiner online`` works from ``mcmc_run`` / ``bench_combine`` even
  outside streaming mode;
- streaming: the registry's :class:`~repro.core.combiners.api.StreamingCombiner`
  slot, whose state *is* :class:`OnlineMoments` — the one built-in combiner
  that never buffers draws.

The scan face (fused streaming hot path) folds chunks through the Pallas
``online_update`` kernel via :func:`online_update_chunk_kernel`. The
merge-rounding tolerance contract lives next to that kernel, in
:mod:`repro.kernels.online_update.ops` — in short: Welford merges associate
differently across chunkings and evaluation orders, so streamed/fused
``online`` runs agree with the batch face to f32 last-ulp per fold, never
bitwise; the exact-bitwise streaming guarantee belongs to the buffered
combiners (see ``api.buffered_streaming``).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.combiners.api import (
    CombineResult,
    ScanStreamingFace,
    StreamingCombiner,
    counts_or_full,
    register,
    register_scan_face,
)
from repro.core.gaussian import GaussianMoments, product_moments, sample_gaussian


class OnlineMoments(NamedTuple):
    """Welford running moments per subposterior — O(d²) state, O(1) per sample."""

    count: jnp.ndarray  # (M,)
    mean: jnp.ndarray  # (M, d)
    m2: jnp.ndarray  # (M, d, d) sum of outer products of residuals


def online_init(M: int, d: int, dtype=jnp.float32) -> OnlineMoments:
    return OnlineMoments(
        count=jnp.zeros((M,), dtype),
        mean=jnp.zeros((M, d), dtype),
        m2=jnp.zeros((M, d, d), dtype),
    )


def online_update(state: OnlineMoments, m: jnp.ndarray, theta: jnp.ndarray) -> OnlineMoments:
    """Fold one new sample ``theta`` (d,) from machine ``m`` into the moments."""
    n = state.count[m] + 1.0
    delta = theta - state.mean[m]
    mean_m = state.mean[m] + delta / n
    m2_m = state.m2[m] + jnp.outer(delta, theta - mean_m)
    return OnlineMoments(
        count=state.count.at[m].set(n),
        mean=state.mean.at[m].set(mean_m),
        m2=state.m2.at[m].set(m2_m),
    )


def online_update_chunk(
    state: OnlineMoments,
    chunk: jnp.ndarray,
    chunk_counts: Optional[jnp.ndarray] = None,
) -> OnlineMoments:
    """Fold a dense ``(M, C, d)`` chunk into the moments (Chan's parallel
    Welford merge, vectorized over machines).

    ``chunk_counts (M,)`` marks each machine's valid prefix within the chunk
    (None ⇒ all C rows). Invalid rows may hold arbitrary garbage — they are
    excluded with ``where``, never mask-multiplied (0·NaN would leak).
    """
    M, C, d = chunk.shape
    cc = (
        jnp.full((M,), C, jnp.int32)
        if chunk_counts is None
        else chunk_counts.astype(jnp.int32)
    )
    mask = (jnp.arange(C)[None, :] < cc[:, None])[..., None]  # (M, C, 1)
    n_b = cc.astype(chunk.dtype)
    n_b_safe = jnp.maximum(n_b, 1.0)
    valid = jnp.where(mask, chunk, 0.0)
    mean_b = jnp.sum(valid, axis=1) / n_b_safe[:, None]  # (M, d)
    cent = jnp.where(mask, chunk - mean_b[:, None, :], 0.0)
    m2_b = jnp.einsum("mci,mcj->mij", cent, cent)  # (M, d, d)

    n_a = state.count
    n = n_a + n_b
    n_safe = jnp.maximum(n, 1.0)
    delta = mean_b - state.mean
    mean = state.mean + delta * (n_b / n_safe)[:, None]
    m2 = state.m2 + m2_b + jnp.einsum("mi,mj->mij", delta, delta) * (
        n_a * n_b / n_safe
    )[:, None, None]
    # machines contributing nothing this chunk keep their state untouched
    upd = (n_b > 0)[:, None]
    return OnlineMoments(
        count=n,
        mean=jnp.where(upd, mean, state.mean),
        m2=jnp.where(upd[..., None], m2, state.m2),
    )


def online_update_chunk_kernel(
    state: OnlineMoments,
    chunk: jnp.ndarray,
    chunk_counts: Optional[jnp.ndarray] = None,
) -> OnlineMoments:
    """Pallas-backed chunk fold: same merge as :func:`online_update_chunk`,
    computed by the fused ``online_update`` kernel
    (:func:`repro.kernels.online_update.online_moments_update` — batch
    moments + Chan merge in one VMEM-resident pass per machine). Agreement
    with the jnp path is f32 last-ulp per fold; see the tolerance note in
    :mod:`repro.kernels.online_update.ops`. jit-safe — this is the scan
    face's update on the fused streaming hot path.
    """
    from repro.kernels.online_update import online_moments_update

    count, mean, m2 = online_moments_update(
        state.count, state.mean, state.m2, chunk, chunk_counts
    )
    return OnlineMoments(count=count, mean=mean, m2=m2)


def online_product(state: OnlineMoments, *, jitter: float = 1e-8) -> GaussianMoments:
    """Current parametric product estimate from streaming moments."""
    d = state.mean.shape[-1]
    denom = jnp.maximum(state.count - 1.0, 1.0)[:, None, None]
    covs = state.m2 / denom + jitter * jnp.eye(d)
    return product_moments(state.mean, covs)


def _finalize(
    key: jax.Array,
    state: OnlineMoments,
    n_draws: int,
    *,
    jitter: float = 1e-8,
    **_ignored,
) -> CombineResult:
    prod = online_product(state, jitter=jitter)
    draws = sample_gaussian(key, prod, n_draws)
    return CombineResult(samples=draws, acceptance_rate=jnp.ones(()), moments=prod)


# estimate IS finalize: sampling the moment product is already O(d²) — the
# cheapest mid-stream snapshot any combiner has. Declaring it (rather than
# leaving None-means-cheap implicit) lets trajectory consumers and the
# serving layer treat `estimate is None` uniformly as "cannot refresh".
ONLINE_STREAMING = StreamingCombiner(
    init=online_init,
    update=online_update_chunk,
    finalize=_finalize,
    estimate=_finalize,
)


@register("online", "online_parametric", streaming=ONLINE_STREAMING)
def online(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    jitter: float = 1e-8,
    **_ignored,
) -> CombineResult:
    """Batch face of the streaming moments: one whole-stack chunk update."""
    counts = counts_or_full(samples, counts)
    M, _, d = samples.shape
    state = online_update_chunk(online_init(M, d, samples.dtype), samples, counts)
    return _finalize(key, state, n_draws, jitter=jitter)


def _online_scan_estimate(
    key, state: OnlineMoments, n_draws: int, *, jitter: float = 1e-8, **_ignored
) -> jnp.ndarray:
    """In-scan trajectory draws: the same moment-product sample as the host
    ``estimate``, as raw draws — traced into the fused combine-fold step."""
    return sample_gaussian(key, online_product(state, jitter=jitter), n_draws)


# Scan face (fused streaming): the host state already IS the scan state —
# OnlineMoments pass through ``to_state`` untouched, and chunk folds run the
# Pallas kernel. The in-scan ``estimate`` mirrors the host one, so fused and
# subscriber streams emit rows at the same boundaries (agreeing to Welford
# merge-rounding — the kernel-vs-jnp fold tolerance documented above).
ONLINE_SCAN = register_scan_face(
    "online",
    ScanStreamingFace(
        init=online_init,
        update=online_update_chunk_kernel,
        to_state=lambda scan_state, theta, counts: scan_state,
        estimate=_online_scan_estimate,
    ),
)
