"""Weierstrass refinement sampler — exact Gibbs over latent per-machine draws.

Wang & Dunson's Weierstrass transform view of the density product: replace
each subposterior p_m with its Gaussian-smoothed version
``∫ N(θ | θ_m, h²I) p_m(θ_m) dθ_m`` and sample the *extended* model over
(θ, θ¹, …, θᴹ) by Gibbs. With the empirical (sample-cloud) approximation of
each p_m, both conditionals are exact and closed-form:

1. refinement step — for each machine m, the latent θᵐ is one of chain m's
   stored draws, selected with probability ∝ N(θ | θᵐ_t, h²I) over the valid
   prefix (a softmax of negative squared distances — the KDE responsibilities
   of θ under machine m's cloud);
2. pooling step — θ | θ¹..θᴹ ~ N(θ̄, h²/M · I), the product of the M
   Gaussian kernels around the selected latents.

No accept/reject anywhere (acceptance ≡ 1): unlike the IMG combiners, every
sweep refreshes *all* M latent indices from their full conditionals, so
mixing does not degrade with M. The price is O(M·T·d) per sweep (a dense
distance matvec) versus IMG's O(M·d) incremental recursion.

As h → 0 the smoothed product converges to the product of subposterior KDEs
— the same asymptotically exact target as Algorithm 1 — so the combiner
reuses the shared shrinking-``bandwidth`` anneal schedules (``rescale=True``
starts h at the pooled sample scale).

Initialization: the default start is a uniform pooled draw — the analog of
Algorithm 1's uniform index init, whose wide early-anneal transient is part
of the emitted trajectory by convention. ``init_pool > 0`` switches to a
density-guided start: it scores a strided subsample of the pooled cloud
under Σ_m log p̂_m via the batched ``machine_kde_log_density`` op (fused
product epilogue — one launch, no (M, pool) matrix on the kernel path) and
draws each chain's θ₀ from the softmax of those scores — chains start in
the product's high-density region, cutting the transient (useful when the
combined draws feed a downstream consumer rather than a KDE metric). The
final latent states are scored by the Pallas ``img_weights`` kernel and
reported in ``extras["final_log_weight"]`` — directly comparable to the IMG
chain's mixture weight w_t at the same bandwidth.

``n_chains=B`` (default 8) runs an ensemble of independent Gibbs chains
under ``vmap`` with the same shared global anneal index as the batched IMG
engine: chain b's sweep i anneals at h(i·B + b + 1), and draws interleave
to one (n_draws, d) output. The ensemble is this combiner's natural
parallelism *and* robustness knob — independent diffuse starts cover a
thin or multi-well product overlap region the way ``rpt``'s ``n_trees``
covers partition noise — and is deliberately distinct from the IMG
engine's ``n_batch`` (the CLI's ``--img-batch`` tunes IMG index chains,
not this ensemble).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.combiners.api import (
    CombineResult,
    Schedule,
    counts_or_full,
    ragged_gather,
    register,
    resolve_schedule,
)
from repro.core.combiners.density import machine_kde_scores, masked_silverman


@register("weierstrass", "weierstrass_refine")
def weierstrass(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    schedule: Optional[Schedule] = None,
    rescale: bool = False,
    n_chains: int = 8,
    init_pool: int = 0,
    **_ignored,
) -> CombineResult:
    """Gibbs refinement sampling from the Weierstrass-smoothed density product.

    ``n_chains``: ensemble size (independent Gibbs chains, interleaved
    draws). ``init_pool``: 0 (default) starts each chain at a uniform pooled
    draw (Algorithm 1's diffuse-init convention); > 0 enables the
    density-guided start over a strided pooled subsample of that size.
    """
    M, T, d = samples.shape
    dtype = samples.dtype
    counts_arr = counts_or_full(samples, counts)
    schedule = resolve_schedule(samples, schedule, rescale)
    n_batch = max(1, min(int(n_chains), int(n_draws)))
    n_sweeps = -(-n_draws // n_batch)  # ceil

    k_init, k_run = jax.random.split(key)
    pooled = ragged_gather(samples, counts_arr).reshape(M * T, d)
    if init_pool and init_pool > 0:
        h0 = masked_silverman(samples, counts_arr)  # (M,)
        stride = max(1, (M * T) // min(int(init_pool), M * T))
        cand = pooled[::stride]
        # Σ_m log p̂_m over the candidate pool — one fused batched-KDE launch,
        # product epilogue (no (M, pool) matrix).
        score = machine_kde_scores(
            cand, samples, counts if counts is None else counts_arr, h0,
            reduce="product",
        )
        idx0 = jax.random.categorical(k_init, score, shape=(n_batch,))
        theta0 = cand[idx0]  # (B, d)
    else:
        idx0 = jax.random.randint(k_init, (n_batch,), 0, M * T)
        theta0 = pooled[idx0]

    mask = jnp.arange(T)[None, :] < counts_arr[:, None]  # (M, T)
    csq = jnp.where(mask, jnp.sum(samples**2, axis=-1), 0.0)  # (M, T)
    offsets = jnp.arange(1, n_batch + 1, dtype=jnp.float32)  # shared global anneal
    inv_sqrt_m = 1.0 / jnp.sqrt(jnp.asarray(M, dtype))

    def sweep(carry, i):
        theta, sel, k = carry  # (B, d), (B, M, d), key
        h = schedule(offsets + i * n_batch).astype(dtype)  # (B,)
        k, k_ref, k_pool = jax.random.split(k, 3)
        # refinement: categorical over each machine's valid prefix with
        # logits −‖θ − θᵐ_t‖²/(2h²), drawn via Gumbel-max in one shot.
        cross = jnp.einsum("mtd,bd->bmt", samples, theta)
        qsq = jnp.sum(theta**2, axis=-1)  # (B,)
        sq = csq[None, :, :] - 2.0 * cross + qsq[:, None, None]
        logits = -0.5 * sq / (h[:, None, None] ** 2)
        logits = jnp.where(mask[None, :, :], logits, -jnp.inf)
        gumbel = jax.random.gumbel(k_ref, logits.shape, logits.dtype)
        t_sel = jnp.argmax(logits + gumbel, axis=-1)  # (B, M)
        sel = samples[jnp.arange(M)[None, :], t_sel]  # (B, M, d)
        # pooling: θ ~ N(θ̄, h²/M I) — the product of the M kernels.
        eps = jax.random.normal(k_pool, (theta.shape[0], d), dtype)
        theta = jnp.mean(sel, axis=1) + eps * (h[:, None] * inv_sqrt_m)
        return (theta, sel, k), theta

    init = (theta0, jnp.zeros((n_batch, M, d), dtype), k_run)
    (theta_f, sel_f, _), draws = jax.lax.scan(sweep, init, jnp.arange(n_sweeps))

    # scan emits (n_sweeps, B, d): flattening interleaves chains so row
    # i·B + b carries anneal index i·B + b + 1 — the serial ordering. Drop
    # the earliest (least annealed) ceil-surplus rows.
    draws = draws.reshape(n_sweeps * n_batch, d)[-n_draws:]

    from repro.kernels.img_weights import img_log_weights

    h_final = schedule(jnp.asarray(n_sweeps * n_batch, jnp.float32))
    final_lw = img_log_weights(sel_f, h_final.astype(jnp.float32))  # (B,)
    return CombineResult(
        samples=draws,
        acceptance_rate=jnp.ones(()),  # exact Gibbs: every sweep accepted
        moments=None,
        extras={
            "n_chains": jnp.asarray(n_batch),
            "n_sweeps_per_chain": jnp.asarray(n_sweeps),
            "h_final": h_final,
            "final_log_weight": final_lw,
        },
    )
