"""§7/§8 experimental baselines: subpostAvg, subpostPool, consensus MC.

Each baseline has two faces: the raw array function (``subpost_average`` /
``pool`` / ``consensus_weighted`` — the historical API, re-exported by the
``repro.core.combine`` shim) and a registered adapter with the uniform
combiner signature so registry consumers can score them alongside the exact
combiners.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.combiners.api import (
    CombineResult,
    counts_or_full,
    ragged_gather,
    register,
    valid_masks,
)
from repro.core.gaussian import fit_moments


def subpost_average(
    samples: jnp.ndarray, *, counts: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """"subpostAvg": θ_t = (1/M) Σ_m θ^m_t — one aligned draw per machine.

    With ragged counts, index t wraps modulo counts[m] so every machine always
    contributes (the baseline stays defined under stragglers).
    """
    counts = counts_or_full(samples, counts)
    return jnp.mean(ragged_gather(samples, counts), axis=0)


def consensus_weighted(
    samples: jnp.ndarray, *, counts: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Consensus Monte Carlo (Scott et al. 2013): precision-weighted averaging

        θ_t = (Σ_m Σ̂_m^{-1})^{-1} Σ_m Σ̂_m^{-1} θ^m_t.

    The paper (§7) views this as a relaxation of Algorithm 1; it is one of the
    experimental baselines.
    """
    M, T, d = samples.shape
    counts = counts_or_full(samples, counts)
    masks = valid_masks(samples, counts)
    moments = jax.vmap(lambda s, mk: fit_moments(s, mk))(samples, masks)
    precs = jax.vmap(lambda c: jnp.linalg.inv(c + 1e-10 * jnp.eye(d)))(moments.cov)
    total = jnp.sum(precs, axis=0)
    chol = jnp.linalg.cholesky(total)
    gathered = ragged_gather(samples, counts)  # (M, T, d)
    weighted = jnp.einsum("mij,mtj->ti", precs, gathered)
    return jax.scipy.linalg.cho_solve((chol, True), weighted.T).T


def pool(samples: jnp.ndarray, *, counts: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """"subpostPool": the union of all subposterior samples.

    Ragged counts: invalid rows are replaced by wrapping valid ones so the
    output stays a dense ``(M·T, d)`` array.
    """
    M, T, d = samples.shape
    counts = counts_or_full(samples, counts)
    return ragged_gather(samples, counts).reshape(M * T, d)


# ---------------------------------------------------------------------------
# registry adapters (uniform combiner signature; ``n_draws`` selects rows
# for baselines whose natural output length is fixed by T)
# ---------------------------------------------------------------------------


def _as_result(draws: jnp.ndarray, n_draws: int) -> CombineResult:
    """Resize subpostAvg/consensus output (naturally T rows) to ``n_draws``:
    even stride when shrinking, wrap when growing."""
    if n_draws <= draws.shape[0]:
        idx = (jnp.arange(n_draws) * draws.shape[0]) // n_draws
    else:
        idx = jnp.arange(n_draws) % draws.shape[0]
    return CombineResult(samples=draws[idx], acceptance_rate=jnp.ones(()))


@register("subpost_average", "subpostAvg")
def subpost_average_combiner(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    **_ignored,
) -> CombineResult:
    del key
    return _as_result(subpost_average(samples, counts=counts), n_draws)


@register("consensus")
def consensus_combiner(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    **_ignored,
) -> CombineResult:
    del key
    return _as_result(consensus_weighted(samples, counts=counts), n_draws)


@register("pool", "subpostPool")
def pool_combiner(
    key: jax.Array,
    samples: jnp.ndarray,
    n_draws: int,
    *,
    counts: Optional[jnp.ndarray] = None,
    **_ignored,
) -> CombineResult:
    """``n_draws`` is ignored: subpostPool *is* the full M·T union — returning
    a subsample would change what the baseline measures (and silently shift
    the benchmark numbers recorded before the registry rewire)."""
    del key, n_draws
    return CombineResult(
        samples=pool(samples, counts=counts), acceptance_rate=jnp.ones(())
    )
