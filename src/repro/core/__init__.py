"""The paper's primary contribution: subposterior sampling + combination.

- :mod:`repro.core.subposterior` -- Eq. 2.1 subposterior construction
- :mod:`repro.core.combiners`    -- S3 combiner engine (registry: parametric /
                                    nonparametric / semiparametric / baselines)
- :mod:`repro.core.combine`      -- backwards-compat shim over ``combiners``
- :mod:`repro.core.tree_combine` -- S3.2/S4 O(dTM) pairwise recursion
- :mod:`repro.core.gaussian`     -- Eqs. 3.1/3.2 Gaussian-product algebra
- :mod:`repro.core.bandwidth`    -- h schedules (Alg. 1 line 3, Silverman)
- :mod:`repro.core.metrics`      -- S8 L2 density distance, ESS, MMD
"""

from repro.core import bandwidth as bandwidth  # noqa: F401
from repro.core import combine as combine  # noqa: F401
from repro.core import combiners as combiners  # noqa: F401
from repro.core import gaussian as gaussian  # noqa: F401
from repro.core import metrics as metrics  # noqa: F401
from repro.core import subposterior as subposterior  # noqa: F401
from repro.core import tree_combine as tree_combine  # noqa: F401
