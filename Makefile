PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench bench-json dev-deps

test:  ## tier-1 verify
	$(PYTHON) -m pytest -x -q

bench:  ## CPU-sized benchmark suite (CSV to stdout)
	$(PYTHON) -m benchmarks.run

bench-json:  ## benchmark suite + BENCH_<timestamp>.json in perf/
	$(PYTHON) -m benchmarks.run --json perf/

dev-deps:  ## optional test deps (pytest, hypothesis)
	$(PYTHON) -m pip install -r requirements-dev.txt
