"""Paper §8.1.3 / Figure 3 (right): error scaling with dimension.

Relative posterior error (normalized so regularChain = 1) vs dimension for
the three combination procedures, M=10. The paper's finding: parametric
scales best, semiparametric close behind, nonparametric degrades fastest.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, block
from repro.core import metrics
from repro.core.combiners import get_combiner, parametric
from repro.core.subposterior import make_subposterior_logpdf, partition_data
from repro.models.bayes import logistic_regression as logreg
from repro.samplers import get_sampler, run_chain

mala_kernel = get_sampler("mala")

M, N = 10, 20_000


def run(full: bool = False) -> List[Row]:
    rows: List[Row] = []
    dims = (5, 20, 50, 75) if full else (5, 20, 50)
    T = 1200 if full else 800
    burn = T // 6
    for d in dims:
        key = jax.random.PRNGKey(d)
        data, beta_true = logreg.generate_data(key, N, d)
        shards = partition_data(data, M)

        def one(i, k):
            shard = jax.tree.map(lambda x: x[i], shards)
            logpdf = make_subposterior_logpdf(logreg.log_prior, logreg.log_lik, shard, M)
            pos, _ = run_chain(k, mala_kernel(logpdf, step_size=0.08), beta_true, T, burn_in=burn)
            return pos

        sub = block(jax.jit(jax.vmap(one))(jnp.arange(M), jax.random.split(key, M)))

        logpdf_full = make_subposterior_logpdf(logreg.log_prior, logreg.log_lik, data, 1)
        gt = block(jax.jit(
            lambda k: run_chain(k, mala_kernel(logpdf_full, step_size=0.025), beta_true, 2 * T, burn_in=T // 2)[0]
        )(jax.random.fold_in(key, 9)))
        ref = block(jax.jit(
            lambda k: run_chain(k, mala_kernel(logpdf_full, step_size=0.025), beta_true, T, burn_in=burn)[0]
        )(jax.random.fold_in(key, 10)))
        # moment-error metric: KDE-d2 at d≥20 with T≤1k samples is dominated
        # by bandwidth-normalizer noise (documented deviation from the paper,
        # which runs far longer chains); first+second-moment error against the
        # long groundtruth chain measures the same bias ordering robustly.
        def moment_err(s):
            em = float(jnp.linalg.norm(s.mean(0) - gt.mean(0)))
            es = float(jnp.linalg.norm(s.std(0) - gt.std(0)))
            return em + es

        base = moment_err(ref) + 1e-12
        for name, fn in {
            "parametric": lambda k_: parametric(k_, sub, T).samples,
            "nonparametric": lambda k_: get_combiner("nonparametric")(k_, sub, T, rescale=True).samples,
            "semiparametric": lambda k_: get_combiner("semiparametric")(k_, sub, T, rescale=True).samples,
        }.items():
            s = block(jax.jit(fn)(jax.random.PRNGKey(3)))
            rows.append(Row("fig3_dims", f"d={d}", f"rel_err_{name}", moment_err(s) / base,
                            "x_regularChain", "moment-err ratio"))
    return rows
