"""Scenario matrix through ``repro.api.run_matrix`` — the sweep workload.

The ROADMAP's "as many scenarios as you can imagine" face: a 2×2×2 grid
(poisson/linear × rwmh/gibbs × parametric/nonparametric) of declarative
RunSpecs driven through the compile-cached matrix runner. Rows report the
per-cell posterior error and, crucially, the compile accounting — 8 cells
must lower at most one sampling executable per distinct signature (4 here),
which is the quantity that decides whether big sweeps are affordable.
"""

from __future__ import annotations

import itertools
import time
from typing import List

from benchmarks.common import Row
from repro.api import RunSpec, run_matrix

MODELS = ("poisson", "linear")
SAMPLERS = ("rwmh", "gibbs")
COMBINERS = ("parametric", "nonparametric")


def run(full: bool = False) -> List[Row]:
    T = 600 if full else 200
    specs = [
        RunSpec(
            model=m, sampler=s, combiner=c, M=4, T=T, warmup=200,
            n=2000, groundtruth_T=2 * T, score_metric="logl2",
        )
        for m, s, c in itertools.product(MODELS, SAMPLERS, COMBINERS)
    ]
    t0 = time.perf_counter()
    res = run_matrix(specs)
    wall = time.perf_counter() - t0

    rows = [
        Row("matrix", f"{r['model']}/{r['sampler']}/{r['combiner']}",
            "posterior_logl2", r["error"], "log_d2",
            f"acc={r['accept']:.2f}")
        for r in res.rows
    ]
    rows.append(Row("matrix", "sweep", "wall_time", wall, "s",
                    f"{res.n_specs} cells"))
    rows.append(Row("matrix", "sweep", "sampling_executables",
                    res.n_executables, "count",
                    f"{res.n_specs} cells share {res.n_executables} compiles"))
    rows.append(Row("matrix", "sweep", "groundtruth_executables",
                    res.n_groundtruth_executables, "count"))
    return rows
