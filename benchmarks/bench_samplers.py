"""Sampling-stage throughput: every registered sampler, M ∈ {4, 10}.

The paper's cost story is the *sampling* stage (the combine stage is measured
by ``bench_combine``): M independent subposterior chains, zero communication.
This bench times that stage through :mod:`repro.api` — each (sampler, M)
cell is a declarative :class:`repro.api.RunSpec`, and the compiled program
comes from the same per-signature executable cache ``run_matrix`` uses, so
the numbers measure exactly what a matrix sweep pays per cell. Seeds the
sampling-side perf trajectory (``--json perf/`` through ``benchmarks.run``).

Workload: hierarchical Poisson–gamma (paper §8.3) — the one model every
sampler family covers (gradient kernels on the marginalized NB form, Gibbs on
the conjugate latent-q form, SGLD on minibatches).
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, block, timed
from repro.api import RunSpec
from repro.api.matrix import ExecutableCache
from repro.api.sampling import is_padded
from repro.core.subposterior import partition_data
from repro.models.bayes import get_model
from repro.samplers import canonical_samplers

N = 4_000  # divisible by both M values
WARMUP = 100

# fixed steps for the non-adaptive samplers (adaptive ones warm up from 0.1)
_STEP = {"gibbs": 0.15, "sgld": 0.002}


def run(full: bool = False) -> List[Row]:
    rows: List[Row] = []
    T = 600 if full else 200
    model = get_model("poisson")
    key = jax.random.PRNGKey(0)
    data, _ = model.generate_data(key, N)
    execs = ExecutableCache()

    for M in (4, 10):
        shards, counts = partition_data(data, M, only=model.shard_keys, pad=True)
        keys = jax.random.split(jax.random.fold_in(key, M), M)
        for name in canonical_samplers():
            spec = RunSpec(
                model="poisson", sampler=name, M=M, T=T,
                warmup=WARMUP, burn_in=T // 6,
                step_size=_STEP.get(name, 0.1), n=N,
            )
            padded = is_padded(model, shards, counts, name)
            fn = execs.sample_fn(spec, model, padded)
            step = jnp.float32(spec.step_size)
            last = {}

            def call():
                last["out"] = block(fn(shards, counts, keys, step))
                return last["out"]

            t = timed(call, warmup=1, iters=3)
            _theta, acc = last["out"]
            rows.append(
                Row("samplers", f"{name}_M={M}", "parallel_sampling_wall_time",
                    t, "s",
                    f"T={T} warmup={WARMUP} n={N} acc={float(acc.mean()):.2f}")
            )
            # gibbs throughput history: the PR-8 Marsaglia–Tsang conditionals
            # (repro.samplers.randgamma) replaced jax.random.gamma's Newton
            # inversion — before: 33.6 draws/s (M=4) / 160.9 (M=10) at T=200
            # (BENCH_20260808_021223); after: O(10³–10⁴) draws/s.
            extra = (
                "randgamma conditionals; pre-randgamma 33.6 draws/s @ M=4"
                if name == "gibbs" else ""
            )
            rows.append(
                Row("samplers", f"{name}_M={M}", "draws_per_second",
                    M * T / t, "draws/s", extra)
            )
    return rows
