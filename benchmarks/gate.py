"""CI perf-regression gate over the ``perf/BENCH_*.json`` trajectory.

The repo accumulates one benchmark snapshot per PR (``benchmarks.run
--json perf/``). This gate keeps the streaming/combination hot path honest:
it compares the newest snapshot's wall-time rows for the ``stream``,
``combine``, ``matrix``, and ``serve`` benches against the **median of the
previous three** snapshots (per ``(bench, case, metric)``) and fails when
any row regressed by more than 25 %.

  PYTHONPATH=src python -m benchmarks.gate                 # gate newest vs history
  PYTHONPATH=src python -m benchmarks.gate --candidate p.json
  PYTHONPATH=src python -m benchmarks.gate --threshold 0.4 --last 5

Design notes:

- Only ``units == "s"`` rows gate (timings); ``x``-unit ratio rows like
  ``fused_speedup`` are diagnostics, not gates — a ratio can legitimately
  move when its numerator improves.
- The baseline is a per-metric **median** over up to ``--last`` prior
  snapshots, so one noisy CI run can't poison the reference, and a metric
  must appear in at least one prior snapshot to gate at all (new metrics —
  e.g. ``stream_total_fused`` on the PR that introduces it — pass
  vacuously and start gating on the next PR).
- CI boxes are noisy: rows faster than ``--min-seconds`` (default 30 ms)
  are reported but never fail the gate; their jitter is scheduler noise,
  not a code regression.
- Fast rows above the floor are still jitter-prone in *absolute* terms — a
  75 ms baseline can flake past +25 % on pure scheduler noise. A failure
  therefore also requires the row to regress by more than ``--abs-slack``
  (default 75 ms) in absolute seconds, so a sub-100 ms row must lose both
  >25 % *and* >75 ms before it fails. Big wins (e.g. a combiner dropping
  from 12 s to 3 s) shrink their own baselines over the rolling window;
  the absolute slack keeps the gate meaningful at the new fast scale
  without re-tuning the relative threshold.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from statistics import median
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

GATED_BENCHES = ("stream", "combine", "matrix", "serve")
GATED_UNITS = "s"

RowKey = Tuple[str, str, str]  # (bench, case, metric)


class Verdict(NamedTuple):
    key: RowKey
    value: float
    baseline: Optional[float]  # None → no history, vacuous pass
    ratio: Optional[float]
    failed: bool


def gated_rows(snapshot: dict) -> Dict[RowKey, float]:
    """The timing rows of one snapshot that participate in the gate."""
    out: Dict[RowKey, float] = {}
    for row in snapshot.get("rows", []):
        if row.get("bench") in GATED_BENCHES and row.get("units") == GATED_UNITS:
            out[(row["bench"], row["case"], row["metric"])] = float(row["value"])
    return out


def baseline_of(history: Sequence[dict], key: RowKey) -> Optional[float]:
    """Median of ``key``'s value over the snapshots that have it."""
    vals = [gated_rows(s)[key] for s in history if key in gated_rows(s)]
    return median(vals) if vals else None


def evaluate(
    candidate: dict,
    history: Sequence[dict],
    *,
    threshold: float = 0.25,
    min_seconds: float = 0.03,
    abs_slack: float = 0.075,
) -> List[Verdict]:
    """Gate ``candidate`` against ``history`` (older snapshots, any order).

    A row fails iff it has a baseline, the baseline is at least
    ``min_seconds`` (sub-noise-floor rows never fail), and the value exceeds
    **both** ``baseline * (1 + threshold)`` and ``baseline + abs_slack`` —
    the absolute slack keeps sub-100 ms rows from flaking on scheduler
    jitter that easily clears a purely relative bar.
    """
    verdicts: List[Verdict] = []
    for key, value in sorted(gated_rows(candidate).items()):
        base = baseline_of(history, key)
        ratio = (value / base) if base else None
        failed = (
            base is not None
            and base >= min_seconds
            and value > base * (1.0 + threshold)
            and value > base + abs_slack
        )
        verdicts.append(Verdict(key, value, base, ratio, failed))
    return verdicts


def load_snapshots(perf_dir: str) -> List[Tuple[str, dict]]:
    """(path, snapshot) pairs sorted oldest→newest by filename timestamp."""
    out = []
    for path in sorted(glob.glob(os.path.join(perf_dir, "BENCH_*.json"))):
        with open(path) as f:
            out.append((path, json.load(f)))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--perf-dir", default="perf", help="snapshot directory")
    ap.add_argument(
        "--candidate", default=None, metavar="PATH",
        help="snapshot to gate (default: newest BENCH_*.json in --perf-dir; "
        "a candidate inside --perf-dir is excluded from its own baseline)",
    )
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated slowdown fraction (default 0.25)")
    ap.add_argument("--last", type=int, default=3,
                    help="baseline = median of this many prior snapshots")
    ap.add_argument("--min-seconds", type=float, default=0.03,
                    help="rows with baselines below this never fail (noise floor)")
    ap.add_argument("--abs-slack", type=float, default=0.075,
                    help="a failing row must also regress by more than this "
                    "many absolute seconds (sub-100 ms jitter guard)")
    args = ap.parse_args(argv)

    snapshots = load_snapshots(args.perf_dir)
    if args.candidate:
        with open(args.candidate) as f:
            candidate = json.load(f)
        cand_path = args.candidate
        history = [s for p, s in snapshots if os.path.abspath(p) != os.path.abspath(cand_path)]
    else:
        if not snapshots:
            print(f"gate: no BENCH_*.json under {args.perf_dir}; nothing to gate")
            return 0
        cand_path, candidate = snapshots[-1]
        history = [s for _, s in snapshots[:-1]]

    history = history[-args.last:]
    verdicts = evaluate(
        candidate, history, threshold=args.threshold,
        min_seconds=args.min_seconds, abs_slack=args.abs_slack,
    )

    print(f"gate: {cand_path} vs median of last {len(history)} snapshot(s), "
          f"threshold +{args.threshold:.0%}")
    failures = 0
    for v in verdicts:
        bench, case, metric = v.key
        if v.baseline is None:
            status, detail = "  new ", "no history"
        else:
            status = " FAIL " if v.failed else "  ok  "
            detail = f"baseline {v.baseline:.4f}s ratio {v.ratio:.2f}x"
        failures += v.failed
        print(f"[{status}] {bench}/{case}/{metric}: {v.value:.4f}s  {detail}")

    if failures:
        print(f"gate: FAILED — {failures} row(s) regressed more than "
              f"{args.threshold:.0%} vs the rolling median")
        return 1
    print("gate: passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
