"""Paper §8.1 / Figures 1–2: Bayesian logistic regression.

- Fig 1: subposterior-product vs subposterior-average bias, M ∈ {10, 20}.
- Fig 2 (left): posterior L2 error vs wall-time for all combination
  strategies against a single full-data chain.
- Fig 2 (right): EP-MCMC chains vs duplicate full-data chains — burn-in
  parallelization (time to reach a target error).

Scale note: paper uses N=50k, d=50, T up to 10⁵ on a cluster; the default
here is the same N, d with shorter chains so the suite finishes on one CPU.
Pass ``--full`` through benchmarks.run for paper-scale chains.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, block
from repro.core import metrics
from repro.core.combiners import canonical_combiners, get_combiner, parametric, subpost_average
from repro.core.subposterior import make_subposterior_logpdf, partition_data
from repro.models.bayes import get_model
from repro.samplers import get_sampler, run_chain

N, D = 50_000, 50

logreg = get_model("logreg")
_mala = get_sampler("mala")


def _run_subposterior_chains(key, data, M, T, burn, init, step=0.06):
    shards = partition_data(data, M)

    def one(i, k):
        shard = jax.tree.map(lambda x: x[i], shards)
        logpdf = make_subposterior_logpdf(logreg.log_prior, logreg.log_lik, shard, M)
        pos, info = run_chain(k, _mala(logpdf, step_size=step), init, T, burn_in=burn)
        return pos, info.is_accepted.mean()

    keys = jax.random.split(key, M)
    pos, acc = jax.jit(jax.vmap(one))(jnp.arange(M), keys)
    return block(pos), float(acc.mean())


def _run_full_chain(key, data, T, burn, init, step=0.018):
    logpdf = make_subposterior_logpdf(logreg.log_prior, logreg.log_lik, data, 1)
    pos, info = jax.jit(
        lambda k: run_chain(k, _mala(logpdf, step_size=step), init, T, burn_in=burn)
    )(key)
    return block(pos), float(info.is_accepted.mean())


def run(full: bool = False) -> List[Row]:
    rows: List[Row] = []
    T = 4000 if full else 1200
    burn = T // 6
    key = jax.random.PRNGKey(0)
    data, beta_true = logreg.generate_data(key, N, D)

    # groundtruth: long full-data chain
    # warm starts: combination-quality comparison wants converged chains
    # (burn-in parallelization is measured separately via likelihood-rows)
    gt, acc_gt = _run_full_chain(jax.random.fold_in(key, 99), data, 3 * T, 3 * T // 6, beta_true)

    # ---- Fig 1: bias of product vs average, M = 10 / 20 --------------------
    for M in (10, 20):
        t0 = time.perf_counter()
        sub, acc = _run_subposterior_chains(jax.random.fold_in(key, M), data, M, T, burn, beta_true)
        t_sample = time.perf_counter() - t0
        para = parametric(jax.random.PRNGKey(1), sub, T)
        avg = subpost_average(sub)
        err_product = float(jnp.linalg.norm(para.samples.mean(0) - gt.mean(0)))
        err_avg = float(jnp.linalg.norm(avg.mean(0) - gt.mean(0)))
        rows += [
            Row("fig1_logreg", f"M={M}", "mean_err_product", err_product, "l2", f"acc={acc:.2f}"),
            Row("fig1_logreg", f"M={M}", "mean_err_subpostAvg", err_avg, "l2"),
            Row("fig1_logreg", f"M={M}", "sample_time", t_sample, "s"),
        ]
        # Fig 1's qualitative claim: averaging bias grows with M, product stays tight
        if M == 10:
            sub10, para10, avg_err10 = sub, para, err_avg

    # ---- Fig 2 left: error vs time for every registered combiner -----------
    M = 10
    sub = sub10
    for name in canonical_combiners():
        fn = get_combiner(name)
        t0 = time.perf_counter()
        # samples enter as a traced argument — the production calling
        # convention (Pipeline/combine_gathered pass the gathered chains as
        # runtime data). Closing over them instead bakes the (M, T, d) cloud
        # into the program as a constant and XLA constant-folds whole
        # reductions of it at compile time, which both inflates compile cost
        # and measures a program no production path ever runs.
        samples = block(
            jax.jit(lambda k, s, f=fn: f(k, s, T, rescale=True).samples)(
                jax.random.PRNGKey(2), sub
            )
        )
        t_comb = time.perf_counter() - t0
        err = float(metrics.log_l2_distance(gt, samples))
        rows.append(Row("fig2_logreg", name, "log_posterior_l2", err, "log_d2", f"combine_s={t_comb:.2f}"))

    # regularChain reference point: error of a T-sample full chain
    short_full, _ = _run_full_chain(jax.random.fold_in(key, 3), data, T, burn, beta_true)
    rows.append(Row("fig2_logreg", "regularChain", "log_posterior_l2",
                    float(metrics.log_l2_distance(gt, short_full)), "log_d2"))

    # ---- Fig 2 right: burn-in parallelization ------------------------------
    # Cost model (per MH step): full chain does N likelihood rows, each
    # subposterior chain N/M. Same step count ⇒ EP-MCMC spends 1/M the rows.
    steps = T + burn
    rows.append(Row("fig2_logreg", "duplicateChains", "likelihood_rows",
                    float(steps * N), "rows", "per chain, burn-in NOT parallelized"))
    rows.append(Row("fig2_logreg", "epmcmc_M10", "likelihood_rows",
                    float(steps * N / 10), "rows", "per chain, burn-in parallelized 10x"))
    return rows
