"""Roofline table reader — surfaces the dry-run artifacts as benchmark rows.

Reads ``results/dryrun/<mesh>/*.json`` (produced by repro.launch.dryrun) and
emits the three roofline terms + dominant bottleneck per (arch × shape ×
mesh). This is deliberately a *reader*: compiling 64 cells belongs to the
dry-run stage, not the benchmark suite.
"""

from __future__ import annotations

import json
import pathlib
from typing import List

from benchmarks.common import Row

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def run(full: bool = False) -> List[Row]:
    rows: List[Row] = []
    if not RESULTS.exists():
        rows.append(Row("roofline", "missing", "cells", 0, "n",
                        "run: python -m repro.launch.dryrun"))
        return rows
    for mesh_dir in sorted(RESULTS.iterdir()):
        for f in sorted(mesh_dir.glob("*.json")):
            rec = json.loads(f.read_text())
            case = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
            if rec["status"] == "skip":
                rows.append(Row("roofline", case, "skipped", 1, "flag", rec["reason"][:60]))
                continue
            if rec["status"] != "ok":
                rows.append(Row("roofline", case, "ERROR", 1, "flag", rec.get("error", "")[:60]))
                continue
            r = rec["roofline"]
            rows.append(Row("roofline", case, "compute_ms", r["compute_s"] * 1e3, "ms"))
            rows.append(Row("roofline", case, "memory_ms", r["memory_s"] * 1e3, "ms"))
            rows.append(Row("roofline", case, "collective_ms", r["collective_s"] * 1e3, "ms",
                            f"dominant={r['dominant']}"))
            if rec.get("useful_flops_ratio"):
                rows.append(Row("roofline", case, "useful_flops", rec["useful_flops_ratio"], "frac"))
    return rows
