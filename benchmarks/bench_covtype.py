"""Paper §8.1.2 / Figure 3 (left): classification accuracy vs time, M=50.

The covtype dataset is not redistributable offline, so we use the
``generate_covtype_like`` surrogate (581k × 54, comparable conditioning) and
report posterior-predictive accuracy per strategy, plus the per-step
likelihood-row cost that produces the paper's wall-time gap.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, block
from repro.core.combiners import get_combiner, parametric, subpost_average
from repro.core.subposterior import make_subposterior_logpdf, partition_data
from repro.models.bayes import logistic_regression as logreg
from repro.samplers import get_sampler, run_chain

mala_kernel = get_sampler("mala")

M = 50


def run(full: bool = False) -> List[Row]:
    rows: List[Row] = []
    N = 581_012 if full else 100_000
    T = 800 if full else 500
    burn = T // 6
    key = jax.random.PRNGKey(0)
    data, beta_true = logreg.generate_covtype_like(key, N)
    d = data["x"].shape[1]
    test = jax.tree.map(lambda x: x[:20_000], data)

    shards = partition_data(jax.tree.map(lambda x: x[20_000 : 20_000 + (N - 20_000) // M * M], data), M)

    def one(i, k):
        shard = jax.tree.map(lambda x: x[i], shards)
        logpdf = make_subposterior_logpdf(logreg.log_prior, logreg.log_lik, shard, M)
        pos, _ = run_chain(k, mala_kernel(logpdf, step_size=0.02), jnp.zeros(d), T, burn_in=burn)
        return pos

    t0 = time.perf_counter()
    sub = block(jax.jit(jax.vmap(one))(jnp.arange(M), jax.random.split(key, M)))
    t_sub = time.perf_counter() - t0
    rows.append(Row("fig3_covtype", "sampling", "subposterior_time", t_sub, "s", f"M={M}"))

    for name, fn in {
        "parametric": lambda k_: parametric(k_, sub, T).samples,
        "semiparametric": lambda k_: get_combiner("semiparametric")(k_, sub, T, rescale=True).samples,
        "subpostAvg": lambda k_: subpost_average(sub),
    }.items():
        s = block(jax.jit(fn)(jax.random.PRNGKey(1)))
        acc = float(logreg.predictive_accuracy(s, test["x"], test["y"]))
        rows.append(Row("fig3_covtype", name, "test_accuracy", acc, "frac"))

    # single-chain cost comparison (the paper's 15.76 min/sample point):
    # full-data chain costs N rows/step; a subposterior chain N/M.
    rows.append(Row("fig3_covtype", "regularChain", "rows_per_step", float(N), "rows"))
    rows.append(Row("fig3_covtype", f"epmcmc_M{M}", "rows_per_step", float(N / M), "rows"))
    return rows
