"""Paper §8.2 / Figures 4–5 (left): multimodal Gaussian-mixture posterior.

The posterior over a component mean has k modes (label permutation).
Asymptotically-biased combiners (parametric, subpostAvg) collapse the modes;
the nonparametric/semiparametric combiners must preserve them. We measure
d₂ to a groundtruth label-permuting chain and a mode-coverage statistic.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, block
from repro.core import metrics
from repro.core.combiners import get_combiner
from repro.core.subposterior import make_subposterior_logpdf, partition_data
from repro.models.bayes import gmm
from repro.samplers import get_sampler
from repro.samplers.base import MCMCKernel, run_chain

K = 4  # mixture components (paper uses 10; 4 keeps the CPU suite quick)
N = 20_000
M = 10


def _permute_kernel(logpdf, k, step):
    """RWMH + uniform label permutation before each proposal (paper §8.2)."""
    base = get_sampler("rwmh")(logpdf, step_size=step)

    def step_fn(key, state):
        k_perm, k_step = jax.random.split(key)
        means = state.position.reshape(k, gmm.DIM)
        perm = jax.random.permutation(k_perm, k)
        permuted = means[perm].reshape(-1)
        state = state._replace(position=permuted)
        return base.step(k_step, state)

    return MCMCKernel(init=base.init, step=step_fn)


def run(full: bool = False) -> List[Row]:
    rows: List[Row] = []
    T = 3000 if full else 1200
    burn = T // 6
    key = jax.random.PRNGKey(0)
    data, true_means = gmm.generate_data(key, N, K)
    d = K * gmm.DIM

    def chains(keyc, M_, data_, num_shards, T_, step=0.035):
        shards = partition_data(data_, M_, only=("x",))

        def one(i, kk):
            shard = dict(shards, x=shards["x"][i])
            logpdf = make_subposterior_logpdf(gmm.log_prior, gmm.log_lik, shard, num_shards)
            kern = _permute_kernel(logpdf, K, step)
            init = true_means.reshape(-1) + 0.5 * jax.random.normal(kk, (d,))
            pos, info = run_chain(kk, kern, init, T_, burn_in=burn)
            return pos, info.is_accepted.mean()

        keys = jax.random.split(keyc, M_)
        pos, acc = jax.jit(jax.vmap(one))(jnp.arange(M_), keys)
        return block(pos), float(acc.mean())

    t0 = time.perf_counter()
    sub, acc = chains(jax.random.fold_in(key, 1), M, data, M, T)
    t_sample = time.perf_counter() - t0
    gt, acc_gt = chains(jax.random.fold_in(key, 2), 1, data, 1, 3 * T, step=0.012)
    gt = gt[0]
    rows.append(Row("fig4_gmm", "sampling", "subposterior_time", t_sample, "s",
                    f"acc={acc:.2f} acc_gt={acc_gt:.2f}"))

    # first-mean 2-d marginal (paper Fig 4 shows this slice)
    gt_m = gmm.single_mean_marginal(gt)

    def mode_coverage(samples2d):
        """Fraction of the k true modes with ≥2% of samples within r=2."""
        dists = jnp.linalg.norm(samples2d[:, None, :] - true_means[None], axis=-1)
        closest = jnp.argmin(dists, axis=1)
        near = jnp.min(dists, axis=1) < 2.0
        frac = jnp.stack([jnp.mean((closest == i) & near) for i in range(K)])
        return float(jnp.mean(frac > 0.02))

    # registry subset: the Fig-4 mode-collapse story needs the exact combiners
    # vs the asymptotically-biased ones, not every baseline
    for name in ("parametric", "nonparametric", "semiparametric", "subpost_average"):
        fn = get_combiner(name)
        samples = block(
            jax.jit(lambda k_, f=fn: f(k_, sub, T, rescale=True).samples)(jax.random.PRNGKey(3))
        )
        s2 = gmm.single_mean_marginal(samples)
        rows.append(Row("fig4_gmm", name, "posterior_l2",
                        float(metrics.l2_distance(gt_m, s2)), "d2"))
        rows.append(Row("fig4_gmm", name, "mode_coverage", mode_coverage(s2), "frac",
                        f"modes={K}"))
    return rows
