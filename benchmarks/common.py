"""Shared benchmark plumbing: timing, row records, CSV emission."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax


@dataclasses.dataclass
class Row:
    bench: str
    case: str
    metric: str
    value: float
    units: str
    extra: str = ""

    def csv(self) -> str:
        return f"{self.bench},{self.case},{self.metric},{self.value:.6g},{self.units},{self.extra}"


HEADER = "bench,case,metric,value,units,extra"


def timed(fn: Callable[[], Any], *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time of ``fn`` (which must block, e.g. via block_until_ready)."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def block(x):
    return jax.block_until_ready(x)
