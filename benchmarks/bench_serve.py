"""Posterior-as-a-service: reader latency + sampler throughput under load.

The serving loop's contract (ISSUE 9) is that concurrent readers answer
from the freshest snapshot **without stalling the sampler**: chunks are
never dropped, estimate refreshes coalesce under backpressure, and the only
way serving slows sampling is the bounded chunk queue. This bench measures
both sides of that contract on the quick linear/MALA configuration:

- ``sample_unserved``: wall time of the sampling stage driven by a bare
  ``PosteriorServer`` with **zero** readers (the serving-loop baseline —
  same queue, folder, and refresh machinery, nobody asking questions);
- ``sample_served``: the same run with 32 concurrent TCP probe readers,
  each paced to a steady 10 requests/s offered load, cycling the snapshot
  query types (mean/cov, quantiles, draws, status) for the whole duration
  of sampling. Two deliberate choices keep this an honest *serving
  overhead* figure rather than a probe-compute figure: ``logpdf`` is
  excluded from the probe mix (it is a heavy analysis op — batched
  machine-KDE scoring over the whole accumulated draw buffer, re-jitted
  per buffer shape — covered functionally by the CI serve smoke and
  ``tests/test_serve.py``), and the readers are **paced** rather than
  closed-loop: an unpaced pool on a small CPU rig just measures its own
  python busy-loop stealing the sampler's core;
- ``reader_p50`` / ``reader_p99``: per-query latency percentiles observed
  by those readers mid-stream;
- ``throughput_ratio``: ``sample_unserved / sample_served`` — the
  acceptance criterion tracks ≥ 0.95 (≤ 5% sampler throughput loss under
  32 readers). Ratio rows ("x" units) are diagnostic: the perf gate
  (``benchmarks.gate``) gates the wall-clock rows, CI smoke asserts the
  serving contract itself.

Both runs are warmed once (fresh Pipelines hitting the jit cache) so the
figures compare serving dataflow, not XLA compile time. Readers run in the
same process — on a GIL'd CPU rig the probe pool costs some sampler time of
its own, which makes the ratio a *conservative* bound on the server-side
overhead a remote reader pool would impose.
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.api import Pipeline, RunSpec
from repro.serve import serve_pipeline

T_QUICK, T_FULL = 1200, 4000
READERS = 32
PROBE_HZ = 10.0  # steady offered load per reader
COMBINER = "parametric"


def _spec(T: int) -> RunSpec:
    return RunSpec(
        model="linear",
        sampler="mala",
        combiner=(COMBINER,),
        M=4,
        T=T,
        warmup=50,
        n=4096,
        seed=0,
        groundtruth_T=100,  # unused (no scoring stage) but part of the spec
        score_metric="logl2",
        stream_every=max(T // 12, 1),
    )


def _serve_run(T: int, readers: int) -> dict:
    pipe = Pipeline(_spec(T), check_hlo=False)
    return serve_pipeline(
        pipe, probe_readers=readers, probe_logpdf=False,
        probe_interval_s=1.0 / PROBE_HZ, log=lambda *_: None,
    )


def run(full: bool = False) -> List[Row]:
    T = T_FULL if full else T_QUICK
    _serve_run(T, readers=0)  # warm the sampling + estimate programs

    quiet = _serve_run(T, readers=0)
    served = _serve_run(T, readers=READERS)

    st = served["staleness"]
    assert st["complete"], "served run did not complete"
    assert st["chunks_folded"] == T // _spec(T).stream_every, (
        "serving dropped chunks"  # the never-drop-chunks contract
    )
    assert served["queries"] > 0 and not served["probe_errors"]

    extra = (
        f"model=linear M=4 T={T} stream_every={_spec(T).stream_every} "
        f"combiner={COMBINER}"
    )
    ratio = quiet["sample_s"] / max(served["sample_s"], 1e-9)
    return [
        Row("serve", "readers=0", "sample_unserved",
            quiet["sample_s"], "s", extra),
        Row("serve", f"readers={READERS}", "sample_served",
            served["sample_s"], "s",
            f"{served['queries']} queries answered at {PROBE_HZ:g} Hz/reader, "
            f"{st['refreshes_dropped']} refreshes coalesced"),
        Row("serve", f"readers={READERS}", "reader_p50",
            served["reader_p50_s"], "s", extra),
        Row("serve", f"readers={READERS}", "reader_p99",
            served["reader_p99_s"], "s", extra),
        Row("serve", f"readers={READERS}", "throughput_ratio",
            ratio, "x",
            "unserved/served sampler wall time (acceptance tracks >= 0.95; "
            "in-process GIL'd probe pool makes this a conservative bound)"),
    ]
