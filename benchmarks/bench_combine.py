"""Combiner engine throughput: sequential vs batched IMG chains.

The combine stage is the paper's core contribution but, run as written
(Algorithm 1), it is a strictly serial chain — one sweep of M index proposals
per emitted draw. The engine's ``n_batch`` mode runs B independent IMG chains
under ``vmap`` (each doing n_draws/B sweeps), so the same total draw count
costs ~1/B the sequential scan length. This bench measures that directly on
one workload, plus the Pallas-kernel vectorized-sweep variant.
"""

from __future__ import annotations

from typing import List

import jax

from benchmarks.common import Row, block, timed
from repro.core.combiners import filter_options, get_combiner

M, T, D = 8, 500, 10
N_DRAWS = 1024


def run(full: bool = False) -> List[Row]:
    rows: List[Row] = []
    key = jax.random.PRNGKey(0)
    n_draws = 4096 if full else N_DRAWS
    samples = 0.3 * jax.random.normal(key, (M, T, D)) + jax.random.normal(
        jax.random.fold_in(key, 1), (M, 1, D)
    )
    combiner = get_combiner("nonparametric")

    t_seq = None
    for n_batch in (1, 4, 16, 64):
        fn = jax.jit(
            lambda k, s, nb=n_batch: combiner(
                k, s, n_draws, rescale=True, n_batch=nb
            ).samples
        )
        t = timed(lambda: block(fn(jax.random.PRNGKey(2), samples)), warmup=1, iters=3)
        case = "sequential" if n_batch == 1 else f"batched_B={n_batch}"
        rows.append(Row("combine", case, "img_wall_time", t, "s",
                        f"n_draws={n_draws} M={M} T={T} d={D}"))
        if n_batch == 1:
            t_seq = t
        else:
            rows.append(Row("combine", case, "speedup_vs_sequential", t_seq / t, "x"))

    # Pallas-kernel vectorized sweep (interpret mode on CPU — correctness/
    # shape regression guard; TPU latencies are what the kernel is for).
    fn_k = jax.jit(
        lambda k, s: combiner(
            k, s, n_draws, rescale=True, n_batch=16, weight_eval="kernel"
        ).samples
    )
    t_k = timed(lambda: block(fn_k(jax.random.PRNGKey(2), samples)), warmup=1, iters=3)
    rows.append(Row("combine", "kernel_B=16", "img_wall_time", t_k, "s",
                    "vectorized all-M-proposals sweep via Pallas img_weights"))

    # The PR-2 exact families on the same workload — one-shot (rpt /
    # importance_pool) vs annealed-Gibbs (weierstrass) vs the IMG chain
    # above — plus the PR-5 streaming-moments parametric product.
    for name, note in (
        ("weierstrass", "Gibbs refinement ensemble (n_chains=8 default)"),
        ("rpt", "median-cut partition + per-leaf product mass"),
        ("importance_pool", "pooled cloud reweighted by product/mixture KDEs"),
        ("online", "Welford streaming moments, batch face (paper §4)"),
    ):
        cfn = get_combiner(name)
        opts = filter_options(cfn, dict(rescale=True, n_batch=4))
        fn_n = jax.jit(
            lambda k, s, cfn=cfn, opts=opts: cfn(k, s, n_draws, **opts).samples
        )
        t_n = timed(lambda: block(fn_n(jax.random.PRNGKey(2), samples)),
                    warmup=1, iters=3)
        rows.append(Row("combine", name, "wall_time", t_n, "s", note))
    return rows
