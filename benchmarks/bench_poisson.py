"""Paper §8.3 / Figure 5 (right): hierarchical Poisson–gamma model.

Error vs time for the combination strategies on the (log a, log b) posterior,
including the Gibbs path (criterion 3: ANY sampler per machine — here the
marginal MH and the latent-q Gibbs sampler mix freely across machines).
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, block
from repro.core import metrics
from repro.core.combiners import get_combiner, parametric, pool, subpost_average
from repro.core.subposterior import make_subposterior_logpdf, partition_data
from repro.models.bayes import get_model
from repro.samplers import get_sampler, run_chain

N, M = 50_000, 10


def run(full: bool = False) -> List[Row]:
    rows: List[Row] = []
    T = 3000 if full else 1500
    burn = T // 6
    key = jax.random.PRNGKey(0)
    pg = get_model("poisson")
    rwmh = get_sampler("rwmh")
    data, theta_true = pg.generate_data(key, N)

    shards = partition_data(data, M)

    def one(i, k):
        shard = jax.tree.map(lambda x: x[i], shards)
        logpdf = make_subposterior_logpdf(pg.log_prior, pg.log_lik, shard, M)
        pos, info = run_chain(
            k, rwmh(logpdf, step_size=0.04), theta_true + 0.3, T, burn_in=burn
        )
        return pos, info.is_accepted.mean()

    t0 = time.perf_counter()
    sub, acc = jax.jit(jax.vmap(one))(jnp.arange(M), jax.random.split(key, M))
    sub = block(sub)
    t_sub = time.perf_counter() - t0

    logpdf_full = make_subposterior_logpdf(pg.log_prior, pg.log_lik, data, 1)
    t0 = time.perf_counter()
    gt, info_gt = jax.jit(
        lambda k: run_chain(
            k, rwmh(logpdf_full, step_size=0.012), theta_true, 3 * T, burn_in=T // 2
        )
    )(jax.random.fold_in(key, 5))
    gt = block(gt)
    acc_gt = info_gt.is_accepted.mean()
    t_full = time.perf_counter() - t0
    rows.append(Row("fig5_poisson", "sampling", "subposterior_time", t_sub, "s",
                    f"acc={float(acc.mean()):.2f}"))
    rows.append(Row("fig5_poisson", "sampling", "fullchain_time", t_full, "s",
                    f"3x samples, acc={float(acc_gt):.2f}"))

    for name, fn in {
        "parametric": lambda k_: parametric(k_, sub, T).samples,
        "nonparametric": lambda k_: get_combiner("nonparametric")(k_, sub, T, rescale=True).samples,
        "semiparametric": lambda k_: get_combiner("semiparametric")(k_, sub, T, rescale=True).samples,
        "subpostAvg": lambda k_: subpost_average(sub),
        "subpostPool": lambda k_: pool(sub),
    }.items():
        samples = block(jax.jit(fn)(jax.random.PRNGKey(3)))
        rows.append(Row("fig5_poisson", name, "posterior_l2",
                        float(metrics.l2_distance(gt, samples)), "d2"))

    # posterior-mean error in (log a, log b) against the long chain
    para = parametric(jax.random.PRNGKey(4), sub, T)
    rows.append(Row("fig5_poisson", "parametric", "mean_abs_err",
                    float(jnp.abs(para.samples.mean(0) - gt.mean(0)).max()), "logparam"))
    return rows
