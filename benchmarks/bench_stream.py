"""Streaming combination: time-to-first-scoreboard vs gather-then-combine.

The gather path cannot produce *any* posterior estimate until all T draws
per chain have landed and the combiner has run on the full ``(M, T, d)``
stack. The streaming engine (``Pipeline.stream_combine``) folds each
``stream_every``-draw chunk into the combiners as it lands, so the first
estimate exists after one chunk of sampling plus one cheap ``estimate``
call — a latency win that grows with T. This bench records, at M ∈ {4, 10}:

- ``gather_then_combine``: wall time until the batch path's first combined
  result (full sampling + one combine);
- ``time_to_first_estimate``: wall time until the streaming path's first
  trajectory point (the acceptance criterion: strictly below the above);
- ``stream_total``: the streaming run's time to its *final* (bitwise-equal)
  combined result on the subscriber-driven chunked path (``fused=False``) —
  the overlap overhead/amortization figure;
- ``stream_total_fused``: the same run on the fused hot path (one compiled
  sampling executable + one compiled combine-fold scan, the
  ``stream_combine`` default when every combiner has a scan face);
- ``first_estimate_speedup``: gather latency / time-to-first-estimate;
- ``fused_speedup``: ``stream_total / stream_total_fused`` — the fused hot
  path's win over the per-chunk host-loop driver (acceptance floor: ≥ 2×
  at M=4 on CPU);
- ``stream_total_mesh``: the same streaming run on the
  :class:`repro.api.backends.MeshChunkBackend` (mesh (4,1) at M=4, (2,1)
  at M=10), timed in a forced-4-device subprocess — the figure that keeps
  mesh streaming from silently regressing vs the vmap backend. A broken
  subprocess fails the bench loudly; it is never skipped.

Groundtruth scoring is skipped on both sides (``score=False``): the bench
measures the sample→combine dataflow, not the reference chain. Both paths
are warmed once before timing (each timed run is a fresh Pipeline hitting
the jit cache): the figures compare dataflow latency — what a serving loop
pays per run — not one-off XLA compile time, which would otherwise swamp
the CPU-sized quick configuration.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time
from typing import List

import jax

from benchmarks.common import Row, block
from repro.api import Pipeline, RunSpec

# quick T is sized so chain compute (not per-run tracing) dominates even on
# a CPU rig — smaller T turns both paths into pure trace benchmarks
T_QUICK, T_FULL = 1200, 4000
COMBINER = "parametric"


def _spec(M: int, T: int, stream_every: int = 0) -> RunSpec:
    return RunSpec(
        model="linear",
        sampler="mala",
        combiner=(COMBINER,),
        M=M,
        T=T,
        warmup=50,
        n=4096,
        seed=0,
        groundtruth_T=100,  # unused (score=False) but part of the spec
        score_metric="logl2",
        stream_every=stream_every,
    )


def _gather_latency(M: int, T: int) -> float:
    """Full sampling, then one batch combine — time to the first estimate
    the classic path can offer."""
    pipe = Pipeline(_spec(M, T), check_hlo=False)
    t0 = time.perf_counter()
    draws = pipe.sample()
    block(draws.theta)
    res = pipe.combine()[COMBINER]
    block(res.samples)
    return time.perf_counter() - t0


def _stream_run(M: int, T: int, stream_every: int, fused: bool):
    pipe = Pipeline(_spec(M, T, stream_every), check_hlo=False)
    t0 = time.perf_counter()
    sr = pipe.stream_combine(n_estimate=128, score=False, fused=fused)
    return time.perf_counter() - t0, sr


def _mesh_rows(T: int) -> List[Row]:
    """``stream_total_mesh`` at M ∈ {4, 10}, timed in a forced-4-device
    subprocess (the parent's device count is fixed at JAX init). Subprocess
    failure raises — a mesh-streaming regression must fail the bench."""
    code = textwrap.dedent(f"""
        import json, time
        from repro.api import Pipeline, RunSpec
        out = []
        for M, mesh in ((4, (4, 1)), (10, (2, 1))):
            spec = RunSpec(
                model="linear", sampler="mala", combiner=("{COMBINER}",),
                M=M, T={T}, warmup=50, n=4096, seed=0, groundtruth_T=100,
                score_metric="logl2", stream_every=max({T} // 12, 1),
                mesh_shape=mesh)
            Pipeline(spec, check_hlo=False).stream_combine(
                n_estimate=128, score=False)  # warm the jit caches
            t0 = time.perf_counter()
            sr = Pipeline(spec, check_hlo=False).stream_combine(
                n_estimate=128, score=False)
            assert sr.complete and len(sr.trajectory) >= 2
            out.append({{"M": M, "mesh": list(mesh),
                         "t": time.perf_counter() - t0,
                         "points": len(sr.trajectory)}})
        print("MESH_ROWS=" + json.dumps(out))
    """)
    import repro

    src_dir = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=4",
        JAX_PLATFORMS="cpu",
        PYTHONPATH=src_dir + os.pathsep + os.environ.get("PYTHONPATH", ""),
    )
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"mesh stream bench subprocess failed (exit {proc.returncode}):\n"
            f"{proc.stderr[-2000:]}"
        )
    line = [
        ln for ln in proc.stdout.splitlines() if ln.startswith("MESH_ROWS=")
    ][-1]
    rows = []
    for rec in json.loads(line[len("MESH_ROWS="):]):
        rows.append(Row(
            "stream", f"M={rec['M']}", "stream_total_mesh", rec["t"], "s",
            f"mesh={tuple(rec['mesh'])} {rec['points']} trajectory points "
            "(fused mesh hot path, forced-4-device subprocess)",
        ))
    return rows


def run(full: bool = False) -> List[Row]:
    rows: List[Row] = []
    T = T_FULL if full else T_QUICK
    for M in (4, 10):
        stream_every = max(T // 12, 1)
        _gather_latency(M, T)  # warm (compile) both program sets
        _stream_run(M, T, stream_every, fused=False)
        _stream_run(M, T, stream_every, fused=True)

        t_gather = _gather_latency(M, T)
        t_stream_total, sr = _stream_run(M, T, stream_every, fused=False)
        t_fused_total, sf = _stream_run(M, T, stream_every, fused=True)
        t_first = sr.trajectory[0]["elapsed_s"]

        extra = f"model=linear T={T} stream_every={stream_every} combiner={COMBINER}"
        rows.append(Row("stream", f"M={M}", "gather_then_combine",
                        t_gather, "s", extra))
        rows.append(Row("stream", f"M={M}", "time_to_first_estimate",
                        t_first, "s", extra))
        rows.append(Row("stream", f"M={M}", "stream_total",
                        t_stream_total, "s",
                        f"{len(sr.trajectory)} trajectory points"))
        rows.append(Row("stream", f"M={M}", "stream_total_fused",
                        t_fused_total, "s",
                        f"{len(sf.trajectory)} trajectory points"))
        rows.append(Row("stream", f"M={M}", "first_estimate_speedup",
                        t_gather / max(t_first, 1e-9), "x",
                        "gather latency / time-to-first-estimate"))
        rows.append(Row("stream", f"M={M}", "fused_speedup",
                        t_stream_total / max(t_fused_total, 1e-9), "x",
                        "subscriber-path stream_total / fused stream_total"))
        assert sr.complete and sf.complete
        assert len(sr.trajectory) >= 2 and len(sf.trajectory) >= 2
    rows.extend(_mesh_rows(T))
    return rows
