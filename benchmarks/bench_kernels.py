"""Pallas kernel micro-benchmarks (interpret mode) + combination complexity.

Wall-times here are CPU-interpret numbers — meaningful as *correct-shape*
regression guards, not TPU latencies. The complexity check is the paper §4
claim: the incremental IMG sweep is O(dTM) — doubling M must ~double, not
~quadruple, the combine time.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, block, timed
from repro.core.combiners import get_combiner
from repro.kernels import default_interpret
from repro.kernels.img_weights import img_log_weights, img_log_weights_ref
from repro.kernels.kde_density import (
    kde_log_density,
    kde_log_density_ref,
    machine_kde_log_density,
)
from repro.kernels.logreg_loglik import logreg_loglik_grad, logreg_loglik_grad_ref


def run(full: bool = False) -> List[Row]:
    rows: List[Row] = []
    key = jax.random.PRNGKey(0)

    # img_weights
    theta = jax.random.normal(key, (2048, 16, 64))
    t_k = timed(lambda: block(img_log_weights(theta, 0.5)))
    t_r = timed(lambda: block(img_log_weights_ref(theta, 0.5)))
    rows.append(Row("kernels", "img_weights_2048x16x64", "kernel_us", t_k * 1e6, "us", "interpret"))
    rows.append(Row("kernels", "img_weights_2048x16x64", "ref_us", t_r * 1e6, "us"))

    # logreg fused loglik+grad
    X = jax.random.normal(key, (50_000, 50))
    y = jnp.where(jax.random.uniform(jax.random.fold_in(key, 1), (50_000,)) < 0.5, 1.0, -1.0)
    beta = jax.random.normal(jax.random.fold_in(key, 2), (50,)) * 0.1
    t_k = timed(lambda: block(logreg_loglik_grad(X, y, beta)))
    t_r = timed(lambda: block(logreg_loglik_grad_ref(X, y, beta)))
    rows.append(Row("kernels", "logreg_50000x50", "kernel_us", t_k * 1e6, "us", "interpret"))
    rows.append(Row("kernels", "logreg_50000x50", "ref_us", t_r * 1e6, "us"))

    # kde streaming logsumexp
    q = jax.random.normal(key, (1024, 50))
    s = jax.random.normal(jax.random.fold_in(key, 3), (4096, 50))
    t_k = timed(lambda: block(kde_log_density(q, s, 0.5)))
    t_r = timed(lambda: block(kde_log_density_ref(q, s, 0.5)))
    rows.append(Row("kernels", "kde_1024x4096x50", "kernel_us", t_k * 1e6, "us", "interpret"))
    rows.append(Row("kernels", "kde_1024x4096x50", "ref_us", t_r * 1e6, "us"))

    # batched all-machines KDE scoring (PR 8 engine) — production routing:
    # Pallas kernel on real TPU, chunked jnp ref on CPU/interpret. The extra
    # records which path ran so interpret-mode CPU numbers are never read as
    # TPU kernel regressions.
    interp = default_interpret()
    route = f"interpret={interp} impl={'ref' if interp else 'kernel'}"
    Mk, Tk = 8, 4096
    mq = jax.random.normal(jax.random.fold_in(key, 4), (1024, 50))
    ms = jax.random.normal(jax.random.fold_in(key, 5), (Mk, Tk, 50))
    mh = jnp.full((Mk,), 0.5)
    t_full = timed(lambda: block(machine_kde_log_density(mq, ms, mh)))
    t_fused = timed(lambda: block(machine_kde_log_density(
        mq, ms, mh, reduce="product_mixture", mixture_weights="uniform")))
    rows.append(Row("kernels", "machine_kde_1024x8x4096", "op_us",
                    t_full * 1e6, "us", route))
    rows.append(Row("kernels", "machine_kde_1024x8x4096", "fused_us",
                    t_fused * 1e6, "us", route + " reduce=product_mixture"))

    # ---- §4 complexity: combine cost vs M (incremental = O(dTM)) ----------
    T, d = 400, 10
    times = {}
    nonparametric = get_combiner("nonparametric")
    for M in (4, 8, 16):
        samples = jax.random.normal(jax.random.fold_in(key, M), (M, T, d))
        fn = jax.jit(lambda k, s: nonparametric(k, s, T, rescale=True).samples)
        t = timed(lambda: block(fn(jax.random.PRNGKey(0), samples)), warmup=1, iters=3)
        times[M] = t
        rows.append(Row("complexity", f"M={M}", "img_combine_time", t, "s", f"T={T} d={d}"))
    growth_8_16 = times[16] / times[8]
    rows.append(Row("complexity", "M8->M16", "time_ratio", growth_8_16, "x",
                    "O(dTM) predicts ~2, O(dTM^2) predicts ~4"))
    return rows
