"""Benchmark suite entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick (CPU-sized)
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale chains
  PYTHONPATH=src python -m benchmarks.run --only fig4_gmm

Emits CSV rows (bench,case,metric,value,units,extra) to stdout.
"""

from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

from benchmarks.common import HEADER

BENCHES = [
    ("fig1+2_logreg", "benchmarks.bench_logreg"),
    ("fig3_covtype", "benchmarks.bench_covtype"),
    ("fig3_dims", "benchmarks.bench_dims"),
    ("fig4_gmm", "benchmarks.bench_gmm"),
    ("fig5_poisson", "benchmarks.bench_poisson"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scale chain lengths")
    ap.add_argument("--only", default=None, help="substring filter on bench name")
    args = ap.parse_args(argv)

    print(HEADER)
    failures = 0
    for name, module in BENCHES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(module)
            rows = mod.run(full=args.full)
            for row in rows:
                print(row.csv())
            print(f"# {name}: {len(rows)} rows in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {name}: FAILED\n{traceback.format_exc()}", file=sys.stderr)
    return failures


if __name__ == "__main__":
    sys.exit(main())
