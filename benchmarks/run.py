"""Benchmark suite entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick (CPU-sized)
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale chains
  PYTHONPATH=src python -m benchmarks.run --only fig4_gmm
  PYTHONPATH=src python -m benchmarks.run --json perf/   # + BENCH_<ts>.json

Emits CSV rows (bench,case,metric,value,units,extra) to stdout; ``--json``
additionally writes the same rows as machine-readable JSON — the
perf-trajectory files this repo accumulates across PRs.
"""

from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time
import traceback

from benchmarks.common import HEADER

BENCHES = [
    ("fig1+2_logreg", "benchmarks.bench_logreg"),
    ("fig3_covtype", "benchmarks.bench_covtype"),
    ("fig3_dims", "benchmarks.bench_dims"),
    ("fig4_gmm", "benchmarks.bench_gmm"),
    ("fig5_poisson", "benchmarks.bench_poisson"),
    ("samplers", "benchmarks.bench_samplers"),
    ("matrix", "benchmarks.bench_matrix"),
    ("combine", "benchmarks.bench_combine"),
    # "stream", not "stream_combine": --only combine must keep selecting the
    # combine bench alone (substring filter)
    ("stream", "benchmarks.bench_stream"),
    ("serve", "benchmarks.bench_serve"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),
]


def _json_path(arg: str, timestamp: str) -> str:
    """Anything not explicitly a ``.json`` file is a directory (created on
    demand) that gets an auto BENCH_<ts>.json name."""
    if arg.endswith(".json") and not os.path.isdir(arg):
        return arg
    return os.path.join(arg, f"BENCH_{timestamp}.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scale chain lengths")
    ap.add_argument("--only", default=None, help="substring filter on bench name")
    ap.add_argument(
        "--json", default=None, metavar="PATH",
        help="also write rows as JSON (a directory gets BENCH_<timestamp>.json)",
    )
    args = ap.parse_args(argv)

    timestamp = time.strftime("%Y%m%d_%H%M%S")
    print(HEADER)
    failures = 0
    all_rows = []
    for name, module in BENCHES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(module)
            rows = mod.run(full=args.full)
            for row in rows:
                print(row.csv())
            all_rows += [
                dict(bench=r.bench, case=r.case, metric=r.metric,
                     value=r.value, units=r.units, extra=r.extra)
                for r in rows
            ]
            print(f"# {name}: {len(rows)} rows in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {name}: FAILED\n{traceback.format_exc()}", file=sys.stderr)

    if args.json is not None:
        path = _json_path(args.json, timestamp)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(
                {"timestamp": timestamp, "full": args.full, "failures": failures,
                 "rows": all_rows},
                f, indent=1,
            )
        print(f"# wrote {len(all_rows)} rows to {path}", file=sys.stderr)
    return failures


if __name__ == "__main__":
    sys.exit(main())
