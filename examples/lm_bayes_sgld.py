"""End-to-end driver: EP-MCMC posterior sampling over a ~130M-param LM.

This is the LM-scale face of the paper: M independent pSGLD chains, each on
a disjoint token shard with the 1/M-weighted prior (Eq 2.1), zero cross-chain
communication during sampling, streaming Welford moments per chain, and the
parametric (BvM, diagonal) combination at the end — plus checkpoint/restart.

On the production mesh the same step function lowers with the chain axis
sharded over data×pod (see repro/distributed/epmcmc.py and the dry-run);
here it runs 4 chains on CPU at the mamba2-130m architecture (reduced by
default so the example finishes in ~2 minutes; pass --full-width for the
real 130M config, which is CPU-feasible but slower).

  PYTHONPATH=src python examples/lm_bayes_sgld.py [--steps 60] [--full-width]
"""

import argparse
import functools
import tempfile

import jax
import jax.numpy as jnp

from repro.api import combine_draws
from repro.checkpoint import Checkpointer, restore
from repro.configs import get_config
from repro.core.combiners import available_combiners
from repro.data.tokens import TokenStream
from repro.distributed import epmcmc
from repro.models.lm.config import reduced

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--chains", type=int, default=4)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--burn-in", type=int, default=20)
ap.add_argument("--full-width", action="store_true")
ap.add_argument(
    "--combiner", default="weierstrass", choices=available_combiners(),
    help="registry name for the exact low-dim combination stage",
)
args = ap.parse_args()

cfg = get_config("mamba2_130m")
if not args.full_width:
    cfg = reduced(cfg)
C = args.chains
key = jax.random.PRNGKey(0)

streams = [
    TokenStream(cfg.vocab_size, args.batch, args.seq, seed=0, shard_index=c, num_shards=C)
    for c in range(C)
]

state = epmcmc.init_state(key, cfg, C)
n_params = sum(p.size for p in jax.tree.leaves(state.params)) // C
print(f"{cfg.name}: {n_params/1e6:.1f}M params/chain × {C} chains")

step_fn = jax.jit(
    functools.partial(
        epmcmc.epmcmc_step,
        cfg=cfg,
        num_shards=C,
        shard_tokens=float(args.batch * args.seq * 200),
        step_size=2e-5,
        burn_in=args.burn_in,
    ),
    donate_argnums=(0,),
)

with tempfile.TemporaryDirectory() as ckdir:
    ck = Checkpointer(ckdir, keep=2)
    subset_history = []  # per-step (C, d_sub) gathers for the exact combiners
    for step in range(args.steps):
        batch = {
            k: jnp.stack([s.batch(step)[k] for s in streams]) for k in ("tokens", "labels")
        }
        state, metrics = step_fn(state, batch)
        if step >= args.burn_in:
            subset_history.append(epmcmc.gather_subset_samples(state.params))
        if step % 10 == 0 or step == args.steps - 1:
            losses = metrics["loss_per_chain"]
            print(f"step {step:4d}  -log p_c(θ) per chain: "
                  f"min={float(losses.min()):.0f} max={float(losses.max()):.0f}")
        if (step + 1) % 25 == 0:
            ck.save(step + 1, state, metadata={"num_chains": C, "train_step": step + 1})
    ck.close()

    # simulate a preemption: restore and verify the moments survived
    restored, meta = restore(ckdir, template=state)
    print(f"restart check: restored step-{meta['train_step']} checkpoint, "
          f"{int(restored.m_count[0])} post-burn-in samples folded per chain")

# the single communicating stage: parametric product over chains (Eq 3.1/3.2)
moments = jax.jit(epmcmc.combine_parametric_diag)(state)
total = sum(m.size for m in jax.tree.leaves(moments.mean))
mean_sd = jnp.sqrt(jnp.mean(jnp.concatenate([v.reshape(-1) for v in jax.tree.leaves(moments.cov)])))
print(f"combined posterior over {total/1e6:.1f}M parameter dims; "
      f"mean posterior sd = {float(mean_sd):.2e}")

# exact combiners on a low-dim subset (the final-norm vector): the per-step
# (C, d_sub) gathers stack into the (M, T, d_sub) layout the registry's
# combiners require (epmcmc.stack_subset_history; a lone snapshot would use
# gather_subset_samples(..., history=True) instead). combine_draws is the
# repro.api face of the same registry-name backend Pipeline.combine() uses —
# any --combiner choice lands here with zero example changes.
history = epmcmc.stack_subset_history(subset_history)
print(f"low-dim subset history for exact combiners: {history.shape} "
      "(per-chain final_norm)")
res = combine_draws(
    jax.random.PRNGKey(7), history, 64, combiner=args.combiner, rescale=True
)
print(f"{args.combiner}-combined subset draws: {res.samples.shape}")
