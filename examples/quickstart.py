"""Quickstart: the whole paper in ~60 lines.

Partition data onto M "machines", sample each subposterior independently
(zero communication), combine with all three estimators, and check against
the closed-form posterior of a linear-Gaussian model.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import combine
from repro.core.subposterior import make_subposterior_logpdf, partition_data
from repro.models.bayes import linear_gaussian as lg
from repro.samplers.base import run_chain
from repro.samplers.rwmh import rwmh_kernel

M, T, D, N = 8, 2000, 4, 4096

key = jax.random.PRNGKey(0)
data, theta_true = lg.generate_data(key, N, D)
posterior = lg.posterior_moments(data)  # closed form — our exam answer key
print(f"true posterior mean: {posterior.mean}")

# -- step 1: partition the data onto M machines -----------------------------
shards = partition_data(data, M)

# -- step 2: each machine samples its subposterior (Eq 2.1), independently --
def sample_machine(m, k):
    shard = jax.tree.map(lambda x: x[m], shards)
    logpdf = make_subposterior_logpdf(lg.log_prior, lg.log_lik, shard, M)
    samples, info = run_chain(
        k, rwmh_kernel(logpdf, step_size=0.08), jnp.zeros(D), T, burn_in=T // 6
    )
    return samples, info.is_accepted.mean()

keys = jax.random.split(jax.random.fold_in(key, 1), M)
subposterior_samples, acc = jax.jit(jax.vmap(sample_machine))(jnp.arange(M), keys)
print(f"sampled {M} subposteriors in parallel (mean acceptance {float(acc.mean()):.2f})")

# -- step 3: combine (the only communicating stage) --------------------------
for name, fn in {
    "parametric     (§3.1)": lambda k: combine.parametric(k, subposterior_samples, T),
    "nonparametric  (§3.2)": lambda k: combine.nonparametric_img(
        k, subposterior_samples, T, rescale=True
    ),
    "semiparametric (§3.3)": lambda k: combine.semiparametric_img(
        k, subposterior_samples, T, rescale=True
    ),
}.items():
    result = jax.jit(fn)(jax.random.PRNGKey(2))
    err = float(jnp.linalg.norm(result.samples.mean(0) - posterior.mean))
    print(f"{name}: |combined mean − true mean| = {err:.4f} "
          f"(IMG acceptance {float(result.acceptance_rate):.2f})")

# the wrong thing to do, for contrast (paper Fig 1):
avg = combine.subpost_average(subposterior_samples)
print(f"subpostAvg baseline:  |avg mean − true mean| = "
      f"{float(jnp.linalg.norm(avg.mean(0) - posterior.mean)):.4f}")
