"""Quickstart: the whole paper through ``repro.api`` in ~40 lines.

One declarative :class:`RunSpec` names the scenario (model × sampler ×
combiners × M); the staged :class:`Pipeline` runs the paper's dataflow —
partition → sample (zero communication) → combine → score — with every
stage's artifact inspectable on the way. The linear-Gaussian model has a
closed-form posterior, so we can grade the combiners against the exact
answer key, not just a long chain.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.api import Pipeline, RunSpec
from repro.models.bayes import linear_gaussian as lg

# -- the scenario, as data ----------------------------------------------------
spec = RunSpec(
    model="linear",
    sampler="rwmh",  # paper §2's example sampler; any registry name works
    combiner=("parametric", "nonparametric", "semiparametric", "subpost_average"),
    M=8,
    T=2000,
    n=4096,
    warmup=300,
    groundtruth_T=2000,
    score_metric="logl2",  # the linear posterior is narrow: score in log space
    seed=0,
)
print(f"spec {spec.spec_id}: {spec.to_json()}")

pipe = Pipeline(spec)

# -- stage 1: partition onto M "machines" ------------------------------------
sharded = pipe.partition()
posterior = lg.posterior_moments(sharded.data)  # closed form — our answer key
print(f"partitioned n={spec.n} rows into M={spec.M} shards "
      f"(counts={sharded.counts.tolist()})")
print(f"true posterior mean: {posterior.mean[:4]}...")

# -- stage 2: each machine samples its subposterior (Eq 2.1), independently --
draws = pipe.sample()
print(f"sampled {spec.M} subposteriors in parallel: θ {draws.theta.shape}, "
      f"mean acceptance {float(draws.accept.mean()):.2f}, backend={draws.backend}")

# -- stage 3: combine (the only communicating stage) --------------------------
for name, result in pipe.combine().items():
    err = float(jnp.linalg.norm(result.samples.mean(0) - posterior.mean))
    print(f"{name:16s}: |combined mean − true mean| = {err:.4f} "
          f"(IMG acceptance {float(result.acceptance_rate):.2f})")

# -- stage 4: score against a full-data groundtruth chain ---------------------
# (subpost_average is the paper's Fig-1 cautionary baseline — watch it lose)
print(pipe.score().table())
