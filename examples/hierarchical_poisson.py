"""Paper §8.3: hierarchical Poisson–gamma model, EP-MCMC end to end.

Demonstrates criterion 3 ("any MCMC method per machine"): half the machines
run random-walk MH on the marginal likelihood, half run MALA — the
combination stage neither knows nor cares.

  PYTHONPATH=src python examples/hierarchical_poisson.py
"""

import jax
import jax.numpy as jnp

from repro.core import metrics
from repro.core.combiners import get_combiner, parametric, subpost_average
from repro.core.subposterior import make_subposterior_logpdf, partition_data
from repro.models.bayes import poisson_gamma as pg
from repro.samplers.base import run_chain
from repro.samplers.mala import mala_kernel
from repro.samplers.rwmh import rwmh_kernel

N, M, T = 50_000, 10, 2000

key = jax.random.PRNGKey(0)
data, theta_true = pg.generate_data(key, N)
print(f"true (log a, log b) = {theta_true}")

shards = partition_data(data, M)


def machine(m, k, use_mala):
    shard = jax.tree.map(lambda x: x[m], shards)
    logpdf = make_subposterior_logpdf(pg.log_prior, pg.log_lik, shard, M)
    kern = mala_kernel(logpdf, step_size=0.004) if use_mala else rwmh_kernel(logpdf, step_size=0.04)
    pos, info = run_chain(k, kern, theta_true + 0.3, T, burn_in=T // 6)
    return pos, info.is_accepted.mean()


keys = jax.random.split(key, M)
sub_mh, acc_mh = jax.jit(jax.vmap(lambda m, k: machine(m, k, False)))(
    jnp.arange(M // 2), keys[: M // 2]
)
sub_mala, acc_mala = jax.jit(jax.vmap(lambda m, k: machine(m, k, True)))(
    jnp.arange(M // 2, M), keys[M // 2 :]
)
sub = jnp.concatenate([sub_mh, sub_mala])
print(f"machines 0-{M//2-1}: RWMH (acc {float(acc_mh.mean()):.2f}); "
      f"machines {M//2}-{M-1}: MALA (acc {float(acc_mala.mean()):.2f})")

# groundtruth long chain
logpdf_full = make_subposterior_logpdf(pg.log_prior, pg.log_lik, data, 1)
gt, _ = jax.jit(
    lambda k: run_chain(k, rwmh_kernel(logpdf_full, step_size=0.012), theta_true, 3 * T, burn_in=T)
)(jax.random.fold_in(key, 9))

for name, fn in {
    "parametric": lambda k: parametric(k, sub, T).samples,
    "nonparametric": lambda k: get_combiner("nonparametric")(k, sub, T, rescale=True).samples,
    "semiparametric": lambda k: get_combiner("semiparametric")(k, sub, T, rescale=True).samples,
    "subpostAvg": lambda k: subpost_average(sub),
}.items():
    s = jax.jit(fn)(jax.random.PRNGKey(1))
    print(f"{name:15s} posterior mean = {s.mean(0)}  "
          f"d2(gt, ·) = {float(metrics.l2_distance(gt, s)):.4f}")
