"""Paper §8.2: multimodal GMM posterior — where biased combiners fail.

The posterior over a component mean has K modes (label permutation symmetry).
This example shows the parametric (Gaussian) combiner collapsing the modes
while the nonparametric/semiparametric combiners keep them.

  PYTHONPATH=src python examples/gmm_multimodal.py
"""

import jax
import jax.numpy as jnp

from repro.core.combiners import get_combiner, parametric, pool, subpost_average
from repro.core.subposterior import make_subposterior_logpdf, partition_data
from repro.models.bayes import gmm
from repro.samplers.base import MCMCKernel, run_chain
from repro.samplers.rwmh import rwmh_kernel

K, N, M, T = 4, 20_000, 6, 1500

key = jax.random.PRNGKey(0)
data, true_means = gmm.generate_data(key, N, K)
d = K * gmm.DIM


def permuting_kernel(logpdf, step):
    """MH with label-permutation moves (the paper's §8.2 sampler)."""
    base = rwmh_kernel(logpdf, step_size=step)

    def step_fn(k, state):
        k_perm, k_mh = jax.random.split(k)
        means = state.position.reshape(K, gmm.DIM)
        perm = jax.random.permutation(k_perm, K)
        return base.step(k_mh, state._replace(position=means[perm].reshape(-1)))

    return MCMCKernel(init=base.init, step=step_fn)


shards = partition_data(data, M, only=("x",))


def one_machine(m, k):
    shard = dict(shards, x=shards["x"][m])
    logpdf = make_subposterior_logpdf(gmm.log_prior, gmm.log_lik, shard, M)
    init = true_means.reshape(-1) + 0.3 * jax.random.normal(k, (d,))
    pos, _ = run_chain(k, permuting_kernel(logpdf, 0.04), init, T, burn_in=T // 6)
    return pos


sub = jax.jit(jax.vmap(one_machine))(jnp.arange(M), jax.random.split(key, M))
print(f"{M} subposterior chains × {T} samples over a {K}-mode posterior")


def describe(name, samples):
    marg = gmm.single_mean_marginal(samples)  # 2-d slice, K modes expected
    dists = jnp.linalg.norm(marg[:, None, :] - true_means[None], axis=-1)
    closest = jnp.argmin(dists, axis=1)
    near = jnp.min(dists, axis=1) < 2.0
    occupancy = jnp.stack([jnp.mean((closest == i) & near) for i in range(K)])
    modes = int(jnp.sum(occupancy > 0.02))
    print(f"{name:22s} modes covered: {modes}/{K}   occupancy={occupancy}")


describe("groundtruth-ish pool", pool(sub))
res_np = jax.jit(lambda k: get_combiner("nonparametric")(k, sub, T, rescale=True))(key)
describe("nonparametric (§3.2)", res_np.samples)
res_sp = jax.jit(lambda k: get_combiner("semiparametric")(k, sub, T, rescale=True))(key)
describe("semiparametric (§3.3)", res_sp.samples)
res_p = jax.jit(lambda k: parametric(k, sub, T))(key)
describe("parametric (biased)", res_p.samples)
describe("subpostAvg (biased)", subpost_average(sub))
