"""Eq 2.1 identities + partitioner properties (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core.subposterior import (
    make_minibatch_logpdf,
    make_subposterior_logpdf,
    partition_data,
)


@given(st.integers(1, 8), st.integers(0, 1000))
def test_partition_is_a_partition(m, seed):
    n = m * 12
    key = jax.random.PRNGKey(seed)
    data = {"x": jax.random.normal(key, (n, 3)), "y": jnp.arange(n)}
    shards = partition_data(data, m)
    assert shards["x"].shape == (m, n // m, 3)
    # disjoint + exhaustive: concatenating shards reproduces the data
    np.testing.assert_array_equal(shards["y"].reshape(-1), data["y"])


def test_partition_rejects_nondivisible():
    with pytest.raises(ValueError):
        partition_data({"x": jnp.zeros((10, 2))}, 3)


@given(st.integers(1, 10), st.integers(0, 500))
def test_subposteriors_sum_to_posterior_logpdf(m, seed):
    """Σ_m log p_m(θ) == log p(θ) + log p(x|θ) (both up to the same constant):
    the defining identity p₁···p_M ∝ p(θ|x^N) of Eq 2.1."""
    key = jax.random.PRNGKey(seed)
    n = m * 6
    data = jax.random.normal(key, (n, 2))
    theta = jax.random.normal(jax.random.fold_in(key, 1), (2,))

    log_prior = lambda th: -0.5 * jnp.sum(th**2)
    log_lik = lambda th, x: -0.5 * jnp.sum((x - th) ** 2)

    shards = partition_data(data, m)
    total = sum(
        make_subposterior_logpdf(
            log_prior, log_lik, shards[i], m
        )(theta)
        for i in range(m)
    )
    full = make_subposterior_logpdf(log_prior, log_lik, data, 1)(theta)
    np.testing.assert_allclose(total, full, rtol=1e-5, atol=1e-4)


def test_minibatch_logpdf_is_unbiased():
    """E over minibatches of the stochastic estimator == full-shard value."""
    key = jax.random.PRNGKey(0)
    n, b = 60, 10
    data = jax.random.normal(key, (n, 2))
    theta = jnp.array([0.3, -0.7])
    log_prior = lambda th: -0.5 * jnp.sum(th**2)
    log_lik = lambda th, x: -0.5 * jnp.sum((x - th) ** 2)
    est = make_minibatch_logpdf(log_prior, log_lik, num_shards=4, shard_size=n)
    full = (1.0 / 4.0) * log_prior(theta) + log_lik(theta, data)
    # average over all disjoint minibatches
    vals = [est(theta, data[i * b : (i + 1) * b]) for i in range(n // b)]
    np.testing.assert_allclose(np.mean(vals), full, rtol=1e-5)


def test_mh_ratio_uses_underweighted_prior():
    from repro.core.subposterior import mh_correction_ratio

    key = jax.random.PRNGKey(1)
    data = jax.random.normal(key, (8, 2))
    log_prior = lambda th: -0.5 * jnp.sum(th**2)
    log_lik = lambda th, x: -0.5 * jnp.sum((x - th) ** 2)
    ratio = mh_correction_ratio(log_prior, log_lik, data, num_shards=4)
    t1, t0 = jnp.array([1.0, 0.0]), jnp.array([0.0, 0.0])
    want = (0.25 * log_prior(t1) + log_lik(t1, data)) - (
        0.25 * log_prior(t0) + log_lik(t0, data)
    )
    np.testing.assert_allclose(ratio(t1, t0), want, rtol=1e-6)
