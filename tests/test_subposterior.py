"""Eq 2.1 identities + partitioner properties (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core.subposterior import (
    make_minibatch_logpdf,
    make_subposterior_logpdf,
    partition_data,
)


@given(st.integers(1, 8), st.integers(0, 1000))
def test_partition_is_a_partition(m, seed):
    n = m * 12
    key = jax.random.PRNGKey(seed)
    data = {"x": jax.random.normal(key, (n, 3)), "y": jnp.arange(n)}
    shards = partition_data(data, m)
    assert shards["x"].shape == (m, n // m, 3)
    # disjoint + exhaustive: concatenating shards reproduces the data
    np.testing.assert_array_equal(shards["y"].reshape(-1), data["y"])


def test_partition_rejects_nondivisible():
    with pytest.raises(ValueError):
        partition_data({"x": jnp.zeros((10, 2))}, 3)


@given(st.integers(1, 8), st.integers(9, 40))
def test_partition_pad_counts_and_edge_padding(m, n):
    """pad=True: dense (M, ceil(N/M), ...) shards, valid-prefix counts summing
    to N, padded rows replicating the final datum."""
    data = {"x": jnp.arange(n, dtype=jnp.float32)[:, None] * jnp.ones((1, 3))}
    shards, counts = partition_data(data, m, pad=True)
    size = -(-n // m)
    assert shards["x"].shape == (m, size, 3)
    assert counts.shape == (m,) and counts.dtype == jnp.int32
    assert int(counts.sum()) == n
    flat = np.asarray(shards["x"][:, :, 0]).reshape(-1)
    # real rows reproduce the data in order; padded rows replicate datum N-1
    cts = np.asarray(counts)
    got = np.concatenate([flat[i * size : i * size + cts[i]] for i in range(m)])
    np.testing.assert_array_equal(got, np.arange(n))
    for i in range(m):
        np.testing.assert_array_equal(
            flat[i * size + cts[i] : (i + 1) * size],
            np.full(size - cts[i], n - 1, np.float32),
        )


@given(st.integers(2, 7), st.integers(0, 300))
def test_padded_subposteriors_still_sum_to_posterior(m, seed):
    """The Eq 2.1 identity must survive padding: the `count` correction in
    make_subposterior_logpdf removes padded rows' likelihood exactly."""
    key = jax.random.PRNGKey(seed)
    n = m * 5 + (seed % m)  # usually non-divisible
    data = {"x": jax.random.normal(key, (n, 2))}
    theta = jax.random.normal(jax.random.fold_in(key, 1), (2,))
    log_prior = lambda th: -0.5 * jnp.sum(th**2)
    log_lik = lambda th, d: -0.5 * jnp.sum((d["x"] - th) ** 2)

    shards, counts = partition_data(data, m, pad=True)
    total = sum(
        make_subposterior_logpdf(
            log_prior,
            log_lik,
            jax.tree.map(lambda x, i=i: x[i], shards),
            m,
            count=counts[i],
        )(theta)
        for i in range(m)
    )
    full = make_subposterior_logpdf(log_prior, log_lik, data, 1)(theta)
    np.testing.assert_allclose(total, full, rtol=1e-5, atol=1e-4)


def test_padded_subposterior_identity_fixed_case():
    """Non-hypothesis twin of the property above (always runs): N=23, M=4."""
    key = jax.random.PRNGKey(7)
    data = {"x": jax.random.normal(key, (23, 2))}
    theta = jnp.array([0.3, -0.7])
    log_prior = lambda th: -0.5 * jnp.sum(th**2)
    log_lik = lambda th, d: -0.5 * jnp.sum((d["x"] - th) ** 2)
    shards, counts = partition_data(data, 4, pad=True)
    np.testing.assert_array_equal(np.asarray(counts), [6, 6, 6, 5])
    total = sum(
        make_subposterior_logpdf(
            log_prior, log_lik,
            jax.tree.map(lambda x, i=i: x[i], shards), 4, count=counts[i],
        )(theta)
        for i in range(4)
    )
    full = make_subposterior_logpdf(log_prior, log_lik, data, 1)(theta)
    np.testing.assert_allclose(total, full, rtol=1e-5)


def test_pad_with_broadcast_leaves_only_keys():
    data = {"x": jnp.arange(10.0)[:, None], "w": jnp.ones(3)}
    shards, counts = partition_data(data, 3, only=("x",), pad=True)
    assert shards["x"].shape == (3, 4, 1)
    assert shards["w"].shape == (3,)  # broadcast, untouched
    np.testing.assert_array_equal(np.asarray(counts), [4, 4, 2])


@given(st.integers(1, 10), st.integers(0, 500))
def test_subposteriors_sum_to_posterior_logpdf(m, seed):
    """Σ_m log p_m(θ) == log p(θ) + log p(x|θ) (both up to the same constant):
    the defining identity p₁···p_M ∝ p(θ|x^N) of Eq 2.1."""
    key = jax.random.PRNGKey(seed)
    n = m * 6
    data = jax.random.normal(key, (n, 2))
    theta = jax.random.normal(jax.random.fold_in(key, 1), (2,))

    log_prior = lambda th: -0.5 * jnp.sum(th**2)
    log_lik = lambda th, x: -0.5 * jnp.sum((x - th) ** 2)

    shards = partition_data(data, m)
    total = sum(
        make_subposterior_logpdf(
            log_prior, log_lik, shards[i], m
        )(theta)
        for i in range(m)
    )
    full = make_subposterior_logpdf(log_prior, log_lik, data, 1)(theta)
    np.testing.assert_allclose(total, full, rtol=1e-5, atol=1e-4)


def test_minibatch_logpdf_is_unbiased():
    """E over minibatches of the stochastic estimator == full-shard value."""
    key = jax.random.PRNGKey(0)
    n, b = 60, 10
    data = jax.random.normal(key, (n, 2))
    theta = jnp.array([0.3, -0.7])
    log_prior = lambda th: -0.5 * jnp.sum(th**2)
    log_lik = lambda th, x: -0.5 * jnp.sum((x - th) ** 2)
    est = make_minibatch_logpdf(log_prior, log_lik, num_shards=4, shard_size=n)
    full = (1.0 / 4.0) * log_prior(theta) + log_lik(theta, data)
    # average over all disjoint minibatches
    vals = [est(theta, data[i * b : (i + 1) * b]) for i in range(n // b)]
    np.testing.assert_allclose(np.mean(vals), full, rtol=1e-5)


def test_mh_ratio_uses_underweighted_prior():
    from repro.core.subposterior import mh_correction_ratio

    key = jax.random.PRNGKey(1)
    data = jax.random.normal(key, (8, 2))
    log_prior = lambda th: -0.5 * jnp.sum(th**2)
    log_lik = lambda th, x: -0.5 * jnp.sum((x - th) ** 2)
    ratio = mh_correction_ratio(log_prior, log_lik, data, num_shards=4)
    t1, t0 = jnp.array([1.0, 0.0]), jnp.array([0.0, 0.0])
    want = (0.25 * log_prior(t1) + log_lik(t1, data)) - (
        0.25 * log_prior(t0) + log_lik(t0, data)
    )
    np.testing.assert_allclose(ratio(t1, t0), want, rtol=1e-6)
