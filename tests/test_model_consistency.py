"""Decode-vs-forward consistency: teacher-forcing the same tokens through
(prefill + decode_step×k) must reproduce forward()'s logits — this is the
invariant that makes the decode_* dry-run cells meaningful."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.lm import model as mdl
from repro.models.lm.config import reduced

B, S_PROMPT, S_GEN = 2, 12, 4

CONSISTENCY_ARCHS = [a for a in ARCH_IDS if a not in ("llava_next_mistral_7b",)]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = mdl.init_params(key, cfg)
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (B, S_PROMPT + S_GEN), 0, cfg.vocab_size)
    enc = (
        0.1 * jnp.ones((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.num_encoder_layers
        else None
    )

    full_logits, _ = mdl.forward(params, cfg, tokens, enc_frames=enc)

    _, caches, memory = mdl.prefill(
        params, cfg, tokens[:, :S_PROMPT], max_len=S_PROMPT + S_GEN, enc_frames=enc
    )
    got = []
    for t in range(S_GEN):
        logits, caches = mdl.decode_step(
            params, cfg, tokens[:, S_PROMPT + t : S_PROMPT + t + 1],
            caches, jnp.asarray(S_PROMPT + t, jnp.int32), memory=memory,
        )
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1).astype(jnp.float32)
    want = full_logits[:, S_PROMPT : S_PROMPT + S_GEN].astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_prefill_last_logits_match_forward():
    cfg = reduced(get_config("llama3_2_3b"))
    key = jax.random.PRNGKey(3)
    params = mdl.init_params(key, cfg)
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (B, S_PROMPT), 0, cfg.vocab_size)
    logits_fwd, _ = mdl.forward(params, cfg, tokens)
    logits_pre, _, _ = mdl.prefill(params, cfg, tokens, max_len=S_PROMPT + 2)
    np.testing.assert_allclose(
        logits_pre[:, 0].astype(jnp.float32),
        logits_fwd[:, -1].astype(jnp.float32),
        rtol=2e-3, atol=2e-3,
    )
