"""Per-arch smoke tests (assignment requirement): REDUCED config of the same
family — one forward/train step + prefill/decode on CPU, asserting output
shapes and finiteness. The FULL configs are exercised only via the dry-run."""

import functools

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.lm import model as mdl, steps
from repro.models.lm.config import reduced

B, S = 2, 16


def _batch(cfg):
    batch = {"tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % cfg.vocab_size}
    if cfg.num_image_tokens:
        batch["img_embeds"] = 0.1 * jnp.ones((B, cfg.num_image_tokens, 1024), jnp.float32)
    if cfg.num_encoder_layers:
        batch["enc_frames"] = 0.1 * jnp.ones((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def states():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced(get_config(arch))
            params, opt = steps.init_train_state(jax.random.PRNGKey(0), cfg)
            cache[arch] = (cfg, params, opt)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_runs_and_is_finite(states, arch):
    cfg, params, opt = states(arch)
    batch = _batch(cfg)
    p2, o2, metrics = jax.jit(functools.partial(steps.train_step, cfg=cfg))(
        params, opt, batch
    )
    assert jnp.isfinite(metrics["loss"]), metrics
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes(states, arch):
    cfg, params, _ = states(arch)
    batch = _batch(cfg)
    logits, aux = mdl.forward(
        params, cfg, batch["tokens"],
        img_embeds=batch.get("img_embeds"), enc_frames=batch.get("enc_frames"),
    )
    s_total = S + (cfg.num_image_tokens if "img_embeds" in batch else 0)
    assert logits.shape == (B, s_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(states, arch):
    cfg, params, _ = states(arch)
    batch = _batch(cfg)
    state = steps.serve_prefill(params, cfg, batch, max_len=S + cfg.num_image_tokens + 8)
    assert state.last_token.shape == (B, 1)
    for _ in range(3):
        state, logits = steps.serve_decode_step(params, cfg, state)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert int(state.position) == S + cfg.num_image_tokens + 3


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_init(states, arch):
    """config.param_count() (used for MODEL_FLOPS) must match the real tree."""
    cfg, params, _ = states(arch)
    actual = sum(p.size for p in jax.tree.leaves(params))
    assert actual == cfg.param_count(), (actual, cfg.param_count())
