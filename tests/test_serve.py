"""repro.serve: posterior-as-a-service on the chunk stream.

Three layers of coverage, mirroring the package split:

- **state**: ServeState folds == stream_combine's engine (refreshed
  estimates score identically to the trajectory rows), restart-from-
  checkpoint rebuilds bitwise with replayed chunks counted separately and
  never double-folded (extends test_streaming's interrupt→resume contract
  to the serving loop — the satellite);
- **handlers**: the pure query surface — all four posterior ops plus
  status, typed 503 for EstimateUnavailable, 400s for malformed requests,
  staleness metadata on every response;
- **server**: the asyncio loop end to end — concurrent TCP readers during
  live sampling, monotone staleness counters, chunks never dropped under
  backpressure, clean completion.
"""

import asyncio
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Pipeline, RunSpec
from repro.api.pipeline import resolve_metric
from repro.core.combiners import EstimateUnavailable
from repro.serve import (
    PosteriorServer,
    ServeClient,
    ServeError,
    ServeState,
    answer,
    serve_pipeline,
)

SPEC = RunSpec(
    model="linear", M=4, T=60, warmup=30, n=512, seed=3,
    groundtruth_T=120, combiner=("parametric", "pool", "online"),
    score_metric="logl2", stream_every=20,
)


def _serve_state(pipe, names=None, **kw):
    kw.setdefault("n_estimate", 32)
    return ServeState(
        pipe.stream_setup(names),
        spec_id=pipe.spec.spec_id,
        total_draws=pipe.spec.T,
        **kw,
    )


def _folding_subscriber(state):
    """fold + refresh every chunk — the deterministic (refresh='every')
    folder the bitwise tests drive without an event loop."""

    def on_chunk(ev):
        state.fold(ev)
        state.refresh()

    return on_chunk


# ---------------------------------------------------------------------------
# state: the deterministic core
# ---------------------------------------------------------------------------


def test_serve_state_estimates_are_stream_combine_rows():
    """The serving contract: an estimate refreshed at boundary t scores
    identically to the stream_combine trajectory row at t — same streaming
    state, same fold_in(k_name, t) key, bitwise the same draw cloud."""
    spec = dataclasses.replace(SPEC, combiner=("parametric", "pool"))
    pipe = Pipeline(spec)
    state = _serve_state(pipe, track_history=True)
    pipe.sample(on_chunk=(_folding_subscriber(state),))

    ref_pipe = Pipeline(spec)
    sr = ref_pipe.stream_combine(n_estimate=32, fused=False)
    gt = ref_pipe.groundtruth()
    dist, _ = resolve_metric(spec, ref_pipe._model.d)

    by_row = {(t, name): samples for t, name, samples in state.history}
    assert len(by_row) == len(sr.trajectory)
    for row in sr.trajectory:
        served = by_row[(row["t"], row["combiner"])]
        # jnp.asarray: feed dist the same input type the trajectory used — a
        # numpy operand can select a different-layout executable whose
        # reduction order drifts at the last ulp
        err = float(dist(gt, jnp.asarray(served)))
        assert err == row["error"], (row["t"], row["combiner"])


def test_serve_state_staleness_counters():
    pipe = Pipeline(SPEC)
    state = _serve_state(pipe)
    seen = []
    def on_chunk(ev):
        state.fold(ev)
        seen.append(dict(state.staleness("parametric")))
    pipe.sample(on_chunk=(on_chunk,))
    state.refresh()

    assert [s["draws_seen"] for s in seen] == [20, 40, 60]
    assert [s["chunks_folded"] for s in seen] == [1, 2, 3]
    assert all(s["chunks_replayed"] == 0 for s in seen)
    assert not seen[0]["complete"] and seen[-1]["complete"]
    stamps = [s["last_fold_monotonic_s"] for s in seen]
    assert stamps == sorted(stamps)  # honest per-chunk landed clock
    final = state.staleness("parametric")
    assert final["spec_id"] == SPEC.spec_id
    assert final["estimate_draws_seen"] == 60
    assert final["estimate_age_draws"] == 0


def test_serve_restart_from_checkpoint_is_bitwise(tmp_path):
    """Satellite: kill the serving fold mid-stream, restart from the
    checkpoint dir — replayed chunks are marked, counted separately, never
    double-folded, and every post-restart estimate is bitwise the
    uninterrupted run's."""
    spec = dataclasses.replace(SPEC, combiner=("parametric", "pool", "online"))

    ref_pipe = Pipeline(spec, checkpoint_dir=tmp_path / "ref", checkpoint_every=20)
    ref = _serve_state(ref_pipe, track_history=True)
    ref_pipe.sample(on_chunk=(_folding_subscriber(ref),))
    assert ref.staleness()["complete"]

    # session 1: budget of one chunk, then "killed"
    p1 = Pipeline(spec, checkpoint_dir=tmp_path / "run", checkpoint_every=20)
    s1 = _serve_state(p1, track_history=True)
    p1.sample(max_steps=20, on_chunk=(_folding_subscriber(s1),))
    st1 = s1.staleness()
    assert st1["draws_seen"] == 20 and not st1["complete"]

    # session 2: fresh server state, resumes from the checkpoint — the
    # restored prefix arrives as replayed=True chunks and rebuilds state
    p2 = Pipeline(spec, checkpoint_dir=tmp_path / "run", checkpoint_every=20)
    s2 = _serve_state(p2, track_history=True)
    p2.sample(on_chunk=(_folding_subscriber(s2),))

    st2 = s2.staleness()
    assert st2["complete"] and st2["draws_seen"] == spec.T
    assert st2["chunks_replayed"] == 1  # the restored 1-chunk prefix
    assert st2["chunks_folded"] == spec.T // spec.stream_every  # no double-fold
    # every refreshed estimate bitwise-matches the uninterrupted run
    assert [(t, n) for t, n, _ in s2.history] == [(t, n) for t, n, _ in ref.history]
    for (t, name, got), (_, _, want) in zip(s2.history, ref.history):
        np.testing.assert_array_equal(got, want, err_msg=f"{name}@{t}")
    for name in spec.combiner_names():
        np.testing.assert_array_equal(
            s2.snapshot(name).samples, ref.snapshot(name).samples, err_msg=name
        )


# ---------------------------------------------------------------------------
# handlers: the pure query surface
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def folded_state():
    spec = dataclasses.replace(SPEC, combiner=("parametric", "pool", "consensus"))
    pipe = Pipeline(spec)
    state = _serve_state(pipe)
    pipe.sample(on_chunk=(_folding_subscriber(state),))
    return state


def test_answer_mean_cov_quantiles_draws(folded_state):
    d = folded_state.snapshot("parametric").samples.shape[1]
    for name in ("parametric", "pool"):
        r = answer(folded_state, {"op": "mean_cov", "combiner": name})
        assert r["ok"], r
        assert len(r["result"]["mean"]) == d
        assert len(r["result"]["cov"]) == d and len(r["result"]["cov"][0]) == d
        assert r["staleness"]["draws_seen"] == SPEC.T
        assert r["staleness"]["spec_id"] == folded_state.spec_id

    q = answer(folded_state, {"op": "quantiles", "probs": [0.1, 0.5, 0.9]})
    assert q["ok"] and np.asarray(q["result"]["quantiles"]).shape == (3, d)
    med = np.asarray(q["result"]["quantiles"])[1]
    lo, hi = np.asarray(q["result"]["quantiles"])[0], np.asarray(q["result"]["quantiles"])[2]
    assert np.all(lo <= med) and np.all(med <= hi)

    d1 = answer(folded_state, {"op": "draws", "n": 5, "seed": 7})
    d2 = answer(folded_state, {"op": "draws", "n": 5, "seed": 7})
    assert d1["result"]["draws"] == d2["result"]["draws"]  # deterministic
    assert np.asarray(d1["result"]["draws"]).shape == (5, d)
    # "predictive" is an alias
    assert answer(folded_state, {"op": "predictive", "n": 3})["ok"]


def test_answer_logpdf_matches_direct_scoring(folded_state):
    from repro.core.combiners import counts_or_full
    from repro.core.combiners.density import machine_kde_scores, masked_silverman

    snap = folded_state.snapshot("parametric")
    pts = [snap.mean.tolist(), (snap.mean + 1.0).tolist()]
    r = answer(folded_state, {"op": "logpdf", "points": pts})
    assert r["ok"], r
    got = np.asarray(r["result"]["log_density"])
    assert got.shape == (2,) and np.all(np.isfinite(got))
    assert got[0] > got[1]  # the posterior mean outscores an offset point

    theta, counts = folded_state.logpdf_inputs()
    h = masked_silverman(theta, counts_or_full(theta, counts))
    want = machine_kde_scores(
        jnp.asarray(np.asarray(pts, np.float32)), theta, counts, h,
        reduce="product",
    )
    np.testing.assert_array_equal(got, np.asarray(want))
    assert r["result"]["normalized"] is False


def test_answer_maps_estimate_unavailable_to_503(folded_state):
    r = answer(folded_state, {"op": "mean_cov", "combiner": "consensus"})
    assert not r["ok"]
    assert r["error"]["code"] == 503
    assert "estimate" in r["error"]["reason"]
    assert r["staleness"]["draws_seen"] == SPEC.T  # 503s still say where we are


def test_answer_rejects_malformed_requests(folded_state):
    assert answer(folded_state, {"op": "nope"})["error"]["code"] == 400
    assert answer(
        folded_state, {"op": "mean_cov", "combiner": "no_such"}
    )["error"]["code"] == 400
    assert answer(folded_state, {"op": "logpdf"})["error"]["code"] == 400
    assert answer(
        folded_state, {"op": "quantiles", "probs": [1.5]}
    )["error"]["code"] == 400
    assert answer(folded_state, {"op": "draws", "n": 0})["error"]["code"] == 400


def test_answer_before_any_fold_is_503_with_position():
    pipe = Pipeline(SPEC)
    state = _serve_state(pipe)
    r = answer(state, {"op": "mean_cov"})
    assert not r["ok"] and r["error"]["code"] == 503
    assert r["staleness"]["draws_seen"] == 0 and not r["staleness"]["complete"]
    assert answer(state, {"op": "status"})["ok"]  # status needs no estimate


def test_serve_state_typed_unavailability():
    pipe = Pipeline(dataclasses.replace(SPEC, combiner=("consensus",)))
    state = _serve_state(pipe, keep_draws=False)
    with pytest.raises(EstimateUnavailable):
        state.snapshot("consensus")
    with pytest.raises(EstimateUnavailable, match="keep_draws"):
        state.logpdf_inputs()
    with pytest.raises(KeyError, match="not served"):
        state.snapshot("parametric")


# ---------------------------------------------------------------------------
# server: the asyncio loop
# ---------------------------------------------------------------------------


def test_server_concurrent_queries_during_sampling():
    """All four posterior query types answered over TCP while the chains
    extend, staleness on every response and monotone per connection."""
    spec = dataclasses.replace(SPEC, combiner=("parametric", "online"))

    async def main():
        server = PosteriorServer(Pipeline(spec), refresh="every", queue_depth=2)
        await server.start()

        async def reader(idx):
            client = await ServeClient.connect(server.host, server.port)
            ops = (
                {"op": "mean_cov", "combiner": "online"},
                {"op": "quantiles"},
                {"op": "draws", "n": 4},
                {"op": "logpdf", "points": [[0.0] * 10]},
            )
            last = (-1, -1)
            answered = 0
            try:
                while not server._complete.is_set():
                    resp = await client.request(**ops[(answered + idx) % len(ops)])
                    st = resp["staleness"]
                    now = (st["chunks_folded"], st["draws_seen"])
                    assert now >= last, (last, now)
                    last = now
                    if resp["ok"]:
                        answered += 1
                    else:
                        assert resp["error"]["code"] == 503, resp
            finally:
                await client.close()
            return answered

        readers = [asyncio.create_task(reader(i)) for i in range(6)]
        await server.wait_complete()
        answered = sum(await asyncio.gather(*readers))
        # completed posterior answers everything
        for op in ("mean_cov", "quantiles", "draws", "logpdf", "status"):
            params = {"points": [[0.0] * 10]} if op == "logpdf" else {}
            resp = await server.query(op, **params)
            assert resp["ok"], resp
            assert resp["staleness"]["complete"]
        st = server.state.staleness()
        await server.stop()
        return answered, st

    answered, st = asyncio.run(main())
    assert st["chunks_folded"] == spec.T // spec.stream_every  # never dropped
    assert st["draws_seen"] == spec.T and st["complete"]
    assert answered >= 0  # mid-stream answers are timing-dependent; 503s ok


def test_serve_pipeline_summary_and_backpressure():
    """The sync driver (mcmc_run --serve / CI smoke): probes assert
    monotone staleness internally; chunks are never dropped even at
    queue_depth=1 with refresh coalescing; the final snapshot is fresh."""
    spec = dataclasses.replace(SPEC, combiner=("parametric",))
    summary = serve_pipeline(
        Pipeline(spec), probe_readers=3, queue_depth=1,
        probe_logpdf=True, log=lambda *_: None,
    )
    st = summary["staleness"]
    assert st["chunks_folded"] == spec.T // spec.stream_every
    assert st["draws_seen"] == spec.T and st["complete"]
    assert st["refreshes_dropped"] >= 0
    assert st["estimate_draws_seen"] == spec.T  # final refresh always lands
    assert summary["queries"] > 0
    assert summary["probe_errors"] == []
    for op in ("mean_cov", "quantiles", "draws", "status", "logpdf"):
        assert summary["final"][op]["ok"], op


def test_server_requires_stream_cadence_and_valid_options():
    spec = dataclasses.replace(SPEC, stream_every=0)
    with pytest.raises(ValueError, match="stream_every"):
        PosteriorServer(Pipeline(spec))
    with pytest.raises(ValueError, match="refresh"):
        PosteriorServer(Pipeline(SPEC), refresh="sometimes")
    with pytest.raises(ValueError, match="queue_depth"):
        PosteriorServer(Pipeline(SPEC), queue_depth=0)


def test_client_ask_raises_typed_serve_error():
    spec = dataclasses.replace(SPEC, combiner=("parametric", "consensus"))

    async def main():
        server = PosteriorServer(Pipeline(spec), refresh="every")
        await server.start()
        await server.wait_complete()
        client = await ServeClient.connect(server.host, server.port)
        try:
            result = await client.ask("mean_cov", combiner="parametric")
            assert len(result["mean"]) == 10
            with pytest.raises(ServeError) as exc:
                await client.ask("mean_cov", combiner="consensus")
            assert exc.value.code == 503
            assert exc.value.staleness["complete"]
        finally:
            await client.close()
            await server.stop()

    asyncio.run(main())
