"""Registry-conformance suite: every registered combiner honors the uniform
contract — exactly ``n_draws`` rows, ``counts`` masking, finite output —
plus tree-reduction acceptance for the PR-2 families and the batched-IMG
global-anneal regression guard. Plain pytest parameterization (no
hypothesis) so the suite always runs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics
from repro.core.combiners import (
    CombineResult,
    canonical_combiners,
    filter_options,
    get_combiner,
)
from repro.core.tree_combine import tree_combine

M, T, D = 3, 120, 2

# pool is the one documented exception to the exact-n_draws rule: the
# baseline IS the full M·T union (see baselines.pool_combiner)
FIXED_OUTPUT = {"pool"}


@pytest.fixture(scope="module")
def cloud():
    """Well-separated machines so masking bugs shift the output visibly."""
    key = jax.random.PRNGKey(0)
    centers = jnp.linspace(-1.0, 1.0, M)[:, None, None] * jnp.ones((1, 1, D))
    return centers + 0.5 * jax.random.normal(key, (M, T, D))


@pytest.fixture(scope="module")
def ragged(cloud):
    """Ragged counts with large-but-finite garbage beyond every valid prefix.

    (Finite, not NaN: mask-multiply implementations — fit_moments — are
    NaN-poisoned by design; the contract only promises garbage rows are
    never *selected*, which boundedness below detects.)
    """
    counts = jnp.asarray([T, 80, 50], jnp.int32)
    garbage = cloud
    for m, c in enumerate([T, 80, 50]):
        garbage = garbage.at[m, c:].set(1e4)
    return garbage, counts


@pytest.mark.parametrize("name", canonical_combiners())
@pytest.mark.parametrize("n_draws", [37, 64])
def test_emits_exactly_n_draws(cloud, name, n_draws):
    fn = get_combiner(name)
    res = fn(jax.random.PRNGKey(1), cloud, n_draws)
    assert isinstance(res, CombineResult), name
    if name in FIXED_OUTPUT:
        assert res.samples.shape == (M * T, D), name
    else:
        assert res.samples.shape == (n_draws, D), name
    assert bool(jnp.all(jnp.isfinite(res.samples))), name


@pytest.mark.parametrize("name", canonical_combiners())
def test_counts_mask_excludes_garbage_rows(ragged, name):
    """Rows beyond counts[m] hold 1e4 garbage — a combiner that honors the
    mask can never emit (or average in) anything near them."""
    garbage, counts = ragged
    fn = get_combiner(name)
    res = fn(jax.random.PRNGKey(2), garbage, 64, counts=counts)
    assert bool(jnp.all(jnp.isfinite(res.samples))), name
    assert float(jnp.max(jnp.abs(res.samples))) < 100.0, name


@pytest.mark.parametrize("name", canonical_combiners())
def test_ignores_unknown_options_after_filtering(cloud, name):
    """The option-forwarding convention end-to-end: the CLI-style broadcast
    dict filtered per signature must be accepted by every combiner.
    (Passthrough wrappers — a bare ``**options`` — keep the full dict and
    tolerate the unknowns themselves; everyone else has them filtered.)"""
    import inspect

    fn = get_combiner(name)
    opts = filter_options(fn, dict(rescale=True, n_batch=2, no_such_option=1))
    passthrough = any(
        p.kind is inspect.Parameter.VAR_KEYWORD and not p.name.startswith("_")
        for p in inspect.signature(fn).parameters.values()
    )
    if not passthrough:
        assert "no_such_option" not in opts
    res = fn(jax.random.PRNGKey(3), cloud, 16, **opts)
    assert bool(jnp.all(jnp.isfinite(res.samples))), name


@pytest.mark.parametrize("name", ["weierstrass", "rpt", "importance_pool"])
def test_new_families_accepted_by_tree_combine(cloud, name):
    """Exactly-n_draws output makes each new family a valid reduction step."""
    res = tree_combine(jax.random.PRNGKey(4), cloud, 48, method=name)
    assert res.samples.shape == (48, D)
    assert bool(jnp.all(jnp.isfinite(res.samples)))


def test_tree_combine_odd_m_keeps_counts_honest():
    """Odd-M leftover path: the unpaired chain is modulo-padded to the round's
    draw count (tree_combine.py leftover branch). Padding duplicates *valid*
    draws only — with NaN planted beyond the leftover chain's counts, any
    dishonest count would poison the final draws."""
    key = jax.random.PRNGKey(5)
    m, t, d = 3, 96, 2
    samples = 0.4 * jax.random.normal(key, (m, t, d))
    counts = jnp.asarray([t, t, 30], jnp.int32)
    samples = samples.at[2, 30:].set(jnp.nan)  # invalid tail of the odd chain
    res = tree_combine(jax.random.PRNGKey(6), samples, 40, counts=counts,
                       method="nonparametric")
    assert res.samples.shape == (40, d)
    assert bool(jnp.all(jnp.isfinite(res.samples)))


def test_tree_combine_odd_m_leftover_not_duplicated_into_counts():
    """The modulo-padded leftover must keep counts = the original valid
    length (not the padded T) so the next round's index proposals stay on
    distinct draws: plant a sentinel at the first invalid row and check the
    pad wraps to row 0 instead."""
    from repro.core.tree_combine import tree_combine as tc

    m, t, d = 3, 64, 1
    base = jnp.zeros((m, t, d)) + jnp.arange(m)[:, None, None].astype(jnp.float32)
    counts = jnp.asarray([t, t, 5], jnp.int32)
    # chain 2 valid rows are exactly 2.0; everything after is the sentinel
    base = base.at[2, 5:].set(1e4)
    res = tc(jax.random.PRNGKey(7), base, 32, counts=counts, method="subpost_average")
    assert float(jnp.max(jnp.abs(res.samples))) < 100.0


def test_batched_img_anneal_matches_serial_l2():
    """ROADMAP item: with the shared global anneal index, B=4 must not emit
    under-annealed draws — its L2 to the closed-form product stays within
    noise of the serial chain's on the bench-sized workload."""
    key = jax.random.PRNGKey(8)
    m, t, d = 8, 500, 10
    sigma = 0.5
    mus = 0.3 * jax.random.normal(key, (m, 1, d))
    samples = mus + sigma * jax.random.normal(jax.random.fold_in(key, 1), (m, t, d))
    # exact product of the m sampling Gaussians: N(mean(mu), sigma²/m I)
    gt = jnp.mean(mus, axis=0) + (sigma / jnp.sqrt(m)) * jax.random.normal(
        jax.random.fold_in(key, 2), (2000, d)
    )
    combiner = get_combiner("nonparametric")
    l2 = {}
    for b in (1, 4):
        res = combiner(jax.random.PRNGKey(9), samples, 1024, rescale=True, n_batch=b)
        l2[b] = float(metrics.l2_distance(gt, res.samples))
        assert np.isfinite(l2[b])
    assert l2[4] <= 1.35 * l2[1] + 1e-6, l2


def test_batched_img_chains_share_global_anneal_index():
    """Chain b's sweep i must anneal at index i·B + b + 1 — exactly the
    serial chain's index for that output row. Probe by injecting a weight
    model whose draw *is* the bandwidth and an identity schedule: the
    interleaved output rows must read 1, 2, …, n_draws."""
    from repro.core.combiners.api import counts_or_full
    from repro.core.combiners.img import ImgWeightModel, run_img

    m, t, d = 2, 40, 3
    samples = 0.3 * jax.random.normal(jax.random.PRNGKey(10), (m, t, d))
    probe = ImgWeightModel(
        aux=None,
        extra_logweight=None,
        draw=lambda k, mean, h: jnp.full((d,), h),
        moments=None,
    )
    res = run_img(
        jax.random.PRNGKey(11), samples, 8, probe,
        counts=counts_or_full(samples, None),
        schedule=lambda i: jnp.asarray(i, jnp.float32),
        n_batch=4,
    )
    np.testing.assert_allclose(
        np.asarray(res.samples[:, 0]), np.arange(1, 9, dtype=np.float32)
    )
