"""repro.api: RunSpec validation/serialization, Pipeline stages + determinism,
run_matrix compile caching, and the platform-aware Pallas interpret resolver."""

import jax
import jax.numpy as jnp
import pytest

from repro.api import Pipeline, RunSpec, run_matrix
from repro.models.bayes import linear_gaussian as lg

# small-but-real scenario shared by the pipeline tests (linear: every stage
# has a closed-form oracle and the default mala sampler exercises warmup)
SPEC = RunSpec(
    model="linear",
    M=4,
    T=60,
    warmup=30,
    n=512,
    seed=3,
    groundtruth_T=120,
    combiner=("parametric", "nonparametric"),
    score_metric="logl2",
)


# ---------------------------------------------------------------------------
# RunSpec
# ---------------------------------------------------------------------------


def test_runspec_validates_against_all_three_registries():
    with pytest.raises(KeyError, match="unknown model"):
        RunSpec(model="nope").validate()
    with pytest.raises(KeyError, match="unknown sampler"):
        RunSpec(model="linear", sampler="nope").validate()
    with pytest.raises(KeyError, match="unknown combiner"):
        RunSpec(model="linear", combiner="nope").validate()


def test_runspec_gibbs_needs_model_surface():
    # gmm registers no Gibbs blocks — the spec must fail fast, not at trace
    with pytest.raises(ValueError, match="Gibbs"):
        RunSpec(model="gmm", sampler="gibbs").validate()
    RunSpec(model="linear", sampler="gibbs").validate()  # conjugate blocks exist


def test_runspec_field_validation():
    with pytest.raises(ValueError, match="step_size"):
        RunSpec(model="linear", step_size=0.0)
    with pytest.raises(ValueError, match="score_metric"):
        RunSpec(model="linear", score_metric="l3")
    with pytest.raises(ValueError, match="must be >="):
        RunSpec(model="linear", M=0)


def test_runspec_json_roundtrip_and_spec_id():
    spec = RunSpec(
        model="poisson", sampler="gibbs", M=8, seed=7,
        combiner_options={"n_batch": 4}, combiner=["parametric"],
    )
    again = RunSpec.from_json(spec.to_json())
    assert again == spec
    assert again.spec_id == spec.spec_id
    # content hash is sensitive to every field, stable under identity
    assert spec.spec_id != RunSpec(model="poisson", sampler="gibbs", M=8, seed=8,
                                   combiner_options={"n_batch": 4},
                                   combiner=["parametric"]).spec_id
    with pytest.raises(ValueError, match="unknown RunSpec fields"):
        RunSpec.from_dict({"model": "linear", "bogus": 1})


def test_runspec_is_hashable_static_pytree():
    spec = RunSpec(model="linear", sampler_options={"a": 1})
    assert hash(spec) == hash(RunSpec(model="linear", sampler_options={"a": 1}))
    leaves, treedef = jax.tree_util.tree_flatten(spec)
    assert leaves == []  # all-static: safe inside jitted closures
    assert jax.tree_util.tree_unflatten(treedef, leaves) == spec


def test_executable_signature_groups_seed_and_step_sweeps():
    base = RunSpec(model="linear", T=50, warmup=10, n=256)
    assert base.executable_signature() == \
        RunSpec(model="linear", T=50, warmup=10, n=256, seed=9,
                step_size=0.3, combiner="parametric").executable_signature()
    assert base.executable_signature() != \
        RunSpec(model="linear", T=51, warmup=10, n=256).executable_signature()


# ---------------------------------------------------------------------------
# Pipeline stages
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pipeline():
    pipe = Pipeline(SPEC)
    pipe.run()
    return pipe


def test_pipeline_stage_artifacts(pipeline):
    sharded = pipeline.partition()
    assert jax.tree.leaves(sharded.shards)[0].shape[0] == SPEC.M
    assert sharded.counts.shape == (SPEC.M,)

    draws = pipeline.sample()
    assert draws.theta.shape == (SPEC.M, SPEC.T, 10)
    assert draws.complete and draws.t_done == SPEC.T
    assert bool(jnp.all(jnp.isfinite(draws.theta)))

    combined = pipeline.combine()
    assert set(combined) == {"parametric", "nonparametric"}
    for res in combined.values():
        assert res.samples.shape == (SPEC.T, 10)

    board = pipeline.score()
    assert board.metric == "logL2"
    assert set(board.errors) == set(combined)
    assert all(v == v for v in board.errors.values())  # finite, no NaN
    assert board.spec_id == SPEC.spec_id


def test_pipeline_parametric_recovers_closed_form(pipeline):
    """The linear model is the exactness oracle: the parametric product must
    land on the closed-form posterior mean (Thm 3.1 regime)."""
    posterior = lg.posterior_moments(pipeline.partition().data)
    samples = pipeline.combine()["parametric"].samples
    err = float(jnp.linalg.norm(samples.mean(0) - posterior.mean))
    scale = float(jnp.linalg.norm(posterior.mean))
    assert err < 0.25 * scale


def test_same_spec_is_bitwise_deterministic(pipeline):
    """Same RunSpec ⇒ bitwise-identical artifacts, stage by stage."""
    other = Pipeline(SPEC)
    assert bool(jnp.all(other.sample().theta == pipeline.sample().theta))
    ours, theirs = pipeline.combine(), other.combine()
    for name in ours:
        assert bool(jnp.all(ours[name].samples == theirs[name].samples)), name
    assert other.score().errors == pipeline.score().errors


def test_max_steps_requires_checkpoint_dir():
    with pytest.raises(ValueError, match="checkpoint_dir"):
        Pipeline(SPEC).sample(max_steps=10)


def test_max_steps_requires_a_chunk_cadence(tmp_path):
    # sessions advance in whole chunks: a budget the cadence can't express
    # must raise instead of silently doing zero durable work
    with pytest.raises(ValueError, match="durable progress"):
        Pipeline(SPEC, checkpoint_dir=tmp_path).sample(max_steps=10)
    with pytest.raises(ValueError, match="durable progress"):
        Pipeline(SPEC, checkpoint_dir=tmp_path, checkpoint_every=20).sample(
            max_steps=10
        )


def test_mesh_specs_with_checkpointing_need_devices_not_a_fork(tmp_path):
    """Mesh + checkpointing is supported since the backend unification —
    the only remaining failure mode is a genuine resource problem, and the
    error must say how to fix it (the old path raised unconditionally)."""
    spec = RunSpec(**{**SPEC.to_dict(), "mesh_shape": (4, 1)})
    with pytest.raises(ValueError, match="devices but only"):
        Pipeline(spec, checkpoint_dir=tmp_path).sample()


def test_checkpoint_every_requires_a_dir():
    with pytest.raises(ValueError, match="persist nothing"):
        Pipeline(SPEC, checkpoint_every=20)


def test_run_matrix_rejects_mesh_specs():
    spec = RunSpec(**{**SPEC.to_dict(), "mesh_shape": (4, 1)})
    with pytest.raises(ValueError, match="vmap backend only"):
        run_matrix([spec])


def test_sampler_options_reach_the_kernel_factory():
    """RunSpec.sampler_options must actually change the kernel, not just the
    spec_id — hmc trajectories of length 1 vs 10 give different draws."""
    from repro.api import sample_subposteriors
    from repro.models.bayes import get_model

    model = get_model("linear")
    key = jax.random.PRNGKey(0)
    data, _ = model.generate_data(key, 256)
    kw = dict(sampler="hmc", warmup=0, burn_in=5, step_size=0.05)
    base = sample_subposteriors(key, model, data, 2, 10, **kw)
    short = sample_subposteriors(
        key, model, data, 2, 10,
        sampler_options={"num_integration_steps": 1}, **kw,
    )
    assert not bool(jnp.all(base.theta == short.theta))
    # unknown keys are dropped per the registry filter convention, not fatal
    ignored = sample_subposteriors(
        key, model, data, 2, 10,
        sampler_options={"not_an_option": 1}, **kw,
    )
    assert bool(jnp.all(base.theta == ignored.theta))


# ---------------------------------------------------------------------------
# run_matrix: compile-cache accounting + Pipeline agreement
# ---------------------------------------------------------------------------


def test_run_matrix_compiles_once_per_signature(tmp_path):
    """8 specs spanning 2 signatures (2 models × 2 seeds × 2 step sizes)
    must build exactly 2 sampling executables — seeds and step sizes are
    runtime inputs, not compile triggers."""
    specs = [
        RunSpec(model=m, sampler="mala", combiner="parametric", M=4, T=40,
                warmup=30, n=256, seed=seed, step_size=step,
                groundtruth_T=80, score_metric="logl2")
        for m in ("linear", "poisson")
        for seed in (0, 1)
        for step in (0.1, 0.2)
    ]
    assert len(specs) == 8
    assert len({s.executable_signature() for s in specs}) == 2
    res = run_matrix(specs, json_path=str(tmp_path / "matrix.json"))
    assert res.n_specs == 8
    assert res.n_executables == 2  # the compile-cache acceptance criterion
    assert res.n_groundtruth_executables == 2
    assert len(res.rows) == 8
    assert all(r["error"] == r["error"] for r in res.rows)
    assert (tmp_path / "matrix.json").exists()
    assert "8 cells on vmap, 2 sampling executables" in res.table()


def test_run_matrix_agrees_with_pipeline(pipeline):
    """A matrix cell and a standalone Pipeline over the same spec share the
    RNG discipline end to end — same scoreboard numbers (to the last-ulp
    fusion tolerance of tracing step_size instead of closing over it)."""
    res = run_matrix([SPEC])
    matrix_errors = {r["combiner"]: r["error"] for r in res.rows}
    board = pipeline.score().errors
    assert set(matrix_errors) == set(board)
    for name in board:
        assert matrix_errors[name] == pytest.approx(board[name], rel=1e-4)


# ---------------------------------------------------------------------------
# linear-Gaussian Gibbs surface (scenario-matrix feasibility)
# ---------------------------------------------------------------------------


def test_linear_gibbs_blocks_recover_closed_form_posterior():
    key = jax.random.PRNGKey(0)
    data, _ = lg.generate_data(key, 2000, 6)
    post = lg.posterior_moments(data)
    from repro.samplers import get_sampler
    from repro.samplers.base import run_chain

    kern = get_sampler("gibbs")(None, block_updates=lg.gibbs_blocks(data, 1))
    pos, info = jax.jit(
        lambda k: run_chain(k, kern, jnp.zeros(6), 2000, burn_in=200)
    )(jax.random.fold_in(key, 1))
    assert bool(jnp.all(info.is_accepted))  # exact conditionals: no MH moves
    assert float(jnp.linalg.norm(pos.mean(0) - post.mean)) < 0.01
    cov_err = float(jnp.linalg.norm(jnp.cov(pos.T) - post.cov))
    assert cov_err < 0.25 * float(jnp.linalg.norm(post.cov))


# ---------------------------------------------------------------------------
# platform-aware Pallas interpret resolver
# ---------------------------------------------------------------------------


def test_default_interpret_platform_and_env(monkeypatch):
    from repro.kernels import default_interpret

    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    # CPU rig: interpret mode on by default (False only on a real TPU)
    assert default_interpret() is (jax.default_backend() != "tpu")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert default_interpret() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "true")
    assert default_interpret() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "maybe")
    with pytest.raises(ValueError, match="REPRO_PALLAS_INTERPRET"):
        default_interpret()
