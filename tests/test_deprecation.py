"""Deprecation hygiene: the legacy entry points still work — and say so.

``repro.core.combine`` and the ``mcmc_run`` module internals moved behind
``repro.core.combiners`` / ``repro.api``; the shims must emit a
``DeprecationWarning`` pointing at the replacement while returning
registry-identical results.
"""

import warnings

import jax
import jax.numpy as jnp
import pytest


def _samples(key=0, M=4, T=50, d=3):
    return jax.random.normal(jax.random.PRNGKey(key), (M, T, d))


def test_combine_shim_warns_and_matches_registry():
    from repro.core import combine

    with pytest.warns(DeprecationWarning, match="repro.core.combiners"):
        parametric = combine.parametric
    # forwarded names ARE the registry objects — identical by construction
    import repro.core.combiners as combiners

    assert parametric is combiners.parametric


def test_combine_shim_img_wrappers_match_registry_bitwise():
    from repro.core import combine
    from repro.core.combiners import get_combiner

    samples = _samples()
    key = jax.random.PRNGKey(1)
    with pytest.warns(DeprecationWarning, match="get_combiner"):
        legacy = combine.nonparametric_img(key, samples, 20, rescale=True)
    registry = get_combiner("nonparametric")(key, samples, 20, rescale=True)
    assert bool(jnp.all(legacy.samples == registry.samples))

    with pytest.warns(DeprecationWarning, match="get_combiner"):
        legacy = combine.semiparametric_img(key, samples, 20, rescale=True)
    registry = get_combiner("semiparametric")(key, samples, 20, rescale=True)
    assert bool(jnp.all(legacy.samples == registry.samples))


def test_combine_shim_unknown_attribute_raises():
    from repro.core import combine

    with pytest.raises(AttributeError):
        combine.does_not_exist


def test_mcmc_run_internals_warn_and_forward_to_api():
    from repro.launch import mcmc_run
    from repro.api import sampling

    with pytest.warns(DeprecationWarning, match="repro.api"):
        assert mcmc_run.make_shard_sampler is sampling.make_shard_sampler
    with pytest.warns(DeprecationWarning, match="repro.api"):
        assert mcmc_run.sample_subposteriors is sampling.sample_subposteriors
    with pytest.warns(DeprecationWarning, match="repro.api"):
        assert mcmc_run.SampleResult is sampling.SampleResult
    with pytest.warns(DeprecationWarning, match="repro.api"):
        assert mcmc_run.LOG_L2_DIM == 40


def test_legacy_sample_subposteriors_import_still_runs():
    """The moved engine keeps its behavior through the shim (the
    test_multidevice subprocess relied on this exact call shape)."""
    from repro.models.bayes import get_model

    with pytest.warns(DeprecationWarning):
        from repro.launch.mcmc_run import sample_subposteriors  # noqa: F401
    model = get_model("poisson")
    data, _ = model.generate_data(jax.random.PRNGKey(0), 400)
    res = sample_subposteriors(
        jax.random.PRNGKey(1), model, data, 4, 20, warmup=5, step_size=0.1
    )
    assert res.theta.shape == (4, 20, 2)
    assert bool(jnp.all(jnp.isfinite(res.theta)))
