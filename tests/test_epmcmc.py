"""EP-MCMC distributed runner: chain independence, streaming moments,
parametric combination, and the zero-cross-chain-collective HLO property."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed import epmcmc
from repro.models.lm.config import reduced

CFG = reduced(get_config("mamba2_130m"), num_layers=2, d_model=64, vocab_size=128)
C = 4


@pytest.fixture(scope="module")
def state0():
    return epmcmc.init_state(jax.random.PRNGKey(0), CFG, C)


def _batch(key, step=0):
    k = jax.random.fold_in(key, step)
    toks = jax.random.randint(k, (C, 2, 16), 0, CFG.vocab_size)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=-1)}


def test_chains_start_overdispersed(state0):
    lead = jax.tree.leaves(state0.params)[0]
    assert lead.shape[0] == C
    assert float(jnp.std(lead.astype(jnp.float32), axis=0).mean()) > 0


def test_step_updates_every_chain_differently(state0):
    step = jax.jit(functools.partial(
        epmcmc.epmcmc_step, cfg=CFG, num_shards=C, shard_tokens=1e4, step_size=1e-4
    ))
    s1, metrics = step(state0, _batch(jax.random.PRNGKey(1)))
    assert metrics["loss_per_chain"].shape == (C,)
    p0 = jax.tree.leaves(state0.params)[1].astype(jnp.float32)
    p1 = jax.tree.leaves(s1.params)[1].astype(jnp.float32)
    delta = jnp.abs(p1 - p0).reshape(C, -1).mean(axis=1)
    assert bool(jnp.all(delta > 0))
    # per-chain updates differ (different data + RNG)
    assert float(jnp.std(delta)) > 0


def test_welford_moments_match_batch_statistics(state0):
    step = jax.jit(functools.partial(
        epmcmc.epmcmc_step, cfg=CFG, num_shards=C, shard_tokens=1e4,
        step_size=1e-4, burn_in=2,
    ))
    state = state0
    snapshots = []
    for t in range(8):
        state, _ = step(state, _batch(jax.random.PRNGKey(2), t))
        if t >= 2:
            snapshots.append(jax.tree.leaves(state.params)[0].astype(jnp.float32))
    stacked = jnp.stack(snapshots)  # (T, C, ...)
    want_mean = stacked.mean(0)
    got_mean = jax.tree.leaves(state.m_mean)[0]
    np.testing.assert_allclose(got_mean, want_mean, rtol=1e-4, atol=1e-5)
    assert float(state.m_count[0]) == len(snapshots)
    # Welford M2 / (n-1) == empirical variance
    got_var = jax.tree.leaves(state.m_var)[0] / (len(snapshots) - 1)
    want_var = stacked.var(0, ddof=1)
    np.testing.assert_allclose(got_var, want_var, rtol=1e-3, atol=1e-7)


def test_combine_parametric_diag_is_precision_weighted(state0):
    """On hand-built moments the combiner must equal the closed form."""
    state = state0._replace(
        m_count=jnp.full((C,), 11.0),
        m_mean=jax.tree.map(
            lambda x: jnp.arange(float(x.size)).reshape(x.shape) % 3.0
            + jnp.arange(C).reshape((C,) + (1,) * (x.ndim - 1)),
            state0.m_mean,
        ),
        m_var=jax.tree.map(lambda x: jnp.full(x.shape, 10.0 * (1 + 1e-6)), state0.m_var),
    )
    mom = epmcmc.combine_parametric_diag(state)
    leaf_mean = jax.tree.leaves(mom.mean)[0]
    m_leaf = jax.tree.leaves(state.m_mean)[0]
    # equal variances ⇒ product mean is the plain average over chains
    np.testing.assert_allclose(leaf_mean, m_leaf.mean(0), rtol=1e-5, atol=1e-5)
    leaf_var = jax.tree.leaves(mom.cov)[0]
    np.testing.assert_allclose(leaf_var, (10.0 / 10.0) / C, rtol=1e-4)


def test_gather_subset_samples(state0):
    sub = epmcmc.gather_subset_samples(state0.params)
    assert sub.shape == (C, CFG.d_model)  # final_norm scale
    sub2 = epmcmc.gather_subset_samples(state0.params, paths=["final_norm", "embed"])
    assert sub2.shape == (C, CFG.d_model + CFG.vocab_size * CFG.d_model)
    # the documented combiner adapter: history=True adds the T axis
    sub3 = epmcmc.gather_subset_samples(state0.params, history=True)
    assert sub3.shape == (C, 1, CFG.d_model)
    np.testing.assert_array_equal(np.asarray(sub3[:, 0]), np.asarray(sub))


def test_gather_history_feeds_combine_gathered_end_to_end(state0):
    """The shape-contract bridge: per-step (C, d_sub) gathers → stacked
    (C, T, d_sub) history → exact combiner via the registry — the mesh
    pipeline's final stage, end to end."""
    step = jax.jit(functools.partial(
        epmcmc.epmcmc_step, cfg=CFG, num_shards=C, shard_tokens=1e4,
        step_size=1e-4,
    ))
    state, snapshots = state0, []
    for t in range(5):
        state, _ = step(state, _batch(jax.random.PRNGKey(3), t))
        snapshots.append(epmcmc.gather_subset_samples(state.params))
    history = epmcmc.stack_subset_history(snapshots)
    assert history.shape == (C, 5, CFG.d_model)
    res = epmcmc.combine_gathered(
        jax.random.PRNGKey(4), history, 16, combiner="nonparametric", rescale=True
    )
    assert res.samples.shape == (16, CFG.d_model)
    assert bool(jnp.all(jnp.isfinite(res.samples)))
    # a single snapshot goes through via the history=True adapter too
    one = epmcmc.gather_subset_samples(state.params, history=True)
    res1 = epmcmc.combine_gathered(jax.random.PRNGKey(5), one, 8, combiner="parametric")
    assert res1.samples.shape == (8, CFG.d_model)


def test_combine_gathered_rejects_snapshot_without_history_axis(state0):
    """A raw (C, d_sub) snapshot must fail loudly with the adapter hint, not
    be silently reinterpreted as (M, T, d)."""
    snap = epmcmc.gather_subset_samples(state0.params)
    with pytest.raises(ValueError, match="history"):
        epmcmc.combine_gathered(jax.random.PRNGKey(6), snap, 8)
    with pytest.raises(ValueError):
        epmcmc.stack_subset_history([])


def test_iota_replica_group_decoding():
    groups = epmcmc._iota_groups(4, 2, [2, 4], [1, 0])
    # iota [2,4] -> transpose -> [[0,4],[1,5],[2,6],[3,7]]
    assert groups == [[0, 4], [1, 5], [2, 6], [3, 7]]
    text = 'x = f32[4] all-reduce(%a), replica_groups=[2,4]<=[4,2]T(1,0), to_apply=%s\n'
    got = epmcmc.collective_groups(text)
    assert got == [("all-reduce", [[0, 2, 4, 6], [1, 3, 5, 7]])]


def test_assert_no_cross_chain_collectives_logic():
    class FakeMesh:
        shape = {"data": 4, "model": 2}

    ok_text = 'y = f32[2] all-gather(%a), replica_groups={{0,1},{2,3},{4,5},{6,7}}, dim=0\n'
    assert epmcmc.assert_no_cross_chain_collectives(ok_text, FakeMesh()) == 1
    bad_text = 'y = f32[2] all-reduce(%a), replica_groups={{0,2,4,6},{1,3,5,7}}, to_apply=%s\n'
    with pytest.raises(AssertionError):
        epmcmc.assert_no_cross_chain_collectives(bad_text, FakeMesh())


def test_combine_gathered_resolves_by_registry_name():
    """The mesh run's final stage picks its combiner with the same string
    the CLI and benchmarks use."""
    key = jax.random.PRNGKey(0)
    samples = 0.3 * jax.random.normal(key, (4, 200, 3)) + 1.0
    for name in ("parametric", "nonparametric", "consensus"):
        res = epmcmc.combine_gathered(key, samples, 64, combiner=name, rescale=True)
        assert res.samples.shape == (64, 3), name
    res = epmcmc.combine_gathered(
        key, samples, 64, combiner="nonparametric", n_batch=4, weight_eval="kernel"
    )
    assert res.samples.shape == (64, 3)
    with pytest.raises(KeyError):
        epmcmc.combine_gathered(key, samples, 64, combiner="bogus")
