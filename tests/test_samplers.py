"""Each MCMC kernel targets a known Gaussian; moments must converge.

This is criterion 3 of the paper ("any MCMC method"): every kernel speaks
the same (init, step) protocol and is exchangeable inside the EP pipeline.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.samplers.base import run_chain, run_chains
from repro.samplers.hmc import hmc_kernel
from repro.samplers.mala import mala_kernel
from repro.samplers.rwmh import rwmh_kernel
from repro.samplers.sgld import sgld_kernel

MEAN = jnp.array([1.0, -2.0])
STD = jnp.array([0.8, 1.4])


def logpdf(theta):
    return -0.5 * jnp.sum(((theta - MEAN) / STD) ** 2)


KERNELS = {
    "rwmh": lambda: rwmh_kernel(logpdf, step_size=0.8),
    "mala": lambda: mala_kernel(logpdf, step_size=0.35),
    "hmc": lambda: hmc_kernel(logpdf, step_size=0.25, num_integration_steps=8),
}


@pytest.mark.parametrize("name", sorted(KERNELS))
def test_kernel_recovers_gaussian_moments(name):
    kern = KERNELS[name]()
    pos, info = jax.jit(
        lambda k: run_chain(k, kern, jnp.zeros(2), 6000, burn_in=1000)
    )(jax.random.PRNGKey(0))
    np.testing.assert_allclose(pos.mean(0), MEAN, atol=0.15)
    np.testing.assert_allclose(pos.std(0), STD, atol=0.2)
    acc = float(info.is_accepted.mean())
    assert 0.1 < acc <= 1.0, acc


def test_run_chains_vmaps_independently():
    kern = KERNELS["rwmh"]()
    pos, _ = jax.jit(
        lambda k: run_chains(k, kern, jnp.zeros((4, 2)), 1500, burn_in=500)
    )(jax.random.PRNGKey(1))
    assert pos.shape == (4, 1500, 2)
    # chains are independent draws — means differ but all near target
    np.testing.assert_allclose(pos.mean(1).mean(0), MEAN, atol=0.2)
    assert float(jnp.std(pos[:, :, 0].mean(1))) > 1e-4  # not identical streams


def test_sgld_targets_gaussian():
    """SGLD with full-batch gradient and small ε approximates the target.

    ε=0.05 trades a little discretization bias for mixing speed — the chain
    is long enough that MCSE, not bias, dominates the tolerance."""
    grad = jax.grad(logpdf)
    kern = sgld_kernel(lambda th, _batch: grad(th), step_size=0.05)
    state = kern.init(jnp.zeros(2))

    def step(state, k):
        state, _ = kern.step(k, state, None)
        return state, state.position

    keys = jax.random.split(jax.random.PRNGKey(0), 40_000)
    _, pos = jax.jit(lambda s, ks: jax.lax.scan(step, s, ks))(state, keys)
    pos = pos[10_000:]
    np.testing.assert_allclose(pos.mean(0), MEAN, atol=0.25)
    np.testing.assert_allclose(pos.std(0), STD, atol=0.3)


def test_sgld_temperature_zero_is_descent():
    grad = jax.grad(logpdf)
    kern = sgld_kernel(lambda th, _b: grad(th), step_size=0.5, temperature=0.0)
    state = kern.init(jnp.array([5.0, 5.0]))
    for i in range(200):
        state, _ = kern.step(jax.random.PRNGKey(i), state, None)
    np.testing.assert_allclose(state.position, MEAN, atol=1e-2)


def test_thinning_changes_autocorrelation_not_target():
    kern = KERNELS["rwmh"]()
    pos, _ = jax.jit(
        lambda k: run_chain(k, kern, jnp.zeros(2), 1500, burn_in=500, thin=4)
    )(jax.random.PRNGKey(3))
    assert pos.shape == (1500, 2)
    np.testing.assert_allclose(pos.mean(0), MEAN, atol=0.2)
