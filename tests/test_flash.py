"""Flash attention (custom_vjp) vs einsum oracle — forward AND backward,
including GQA grouping, MLA-style hd_v != hd, non-divisible sequence lengths,
and the causal/bidirectional variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm.attention import _einsum_attention
from repro.models.lm.flash import flash_attention


def _mk(b, s, t, kh, g, hd, hdv, key=0):
    k = jax.random.PRNGKey(key)
    q = jax.random.normal(k, (b, s, kh, g, hd), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (b, t, kh, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (b, t, kh, hdv), jnp.float32)
    return q, kk, v


@pytest.mark.parametrize("s,chunk", [(64, 16), (100, 32), (33, 32)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("hd,hdv", [(16, 16), (24, 16)])
def test_flash_forward_matches_einsum(s, chunk, causal, hd, hdv):
    b, kh, g = 2, 2, 3
    q, kk, v = _mk(b, s, s, kh, g, hd, hdv)
    out = flash_attention(q, kk, v, causal, chunk, chunk).reshape(b, s, kh * g, hdv)
    want = _einsum_attention(q.reshape(b, s, kh * g, hd), kk, v, causal=causal)
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("hd,hdv", [(16, 16), (24, 16)])
def test_flash_backward_matches_einsum_grads(hd, hdv):
    b, s, kh, g = 2, 72, 2, 2
    q, kk, v = _mk(b, s, s, kh, g, hd, hdv, key=5)

    def loss_flash(q, kk, v):
        o = flash_attention(q, kk, v, True, 32, 32)
        return jnp.sum(jnp.sin(o))

    def loss_ref(qf, kk, v):
        o = _einsum_attention(qf, kk, v, causal=True)
        return jnp.sum(jnp.sin(o))

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, kk, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q.reshape(b, s, kh * g, hd), kk, v)
    np.testing.assert_allclose(gf[0].reshape(b, s, kh * g, hd), gr[0], rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(gf[1], gr[1], rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(gf[2], gr[2], rtol=3e-4, atol=3e-4)


def test_flash_cross_attention_shapes():
    """t != s (decoder cross-attending a fixed memory)."""
    b, s, t, kh, g, hd = 2, 40, 96, 2, 2, 16
    q, kk, v = _mk(b, s, t, kh, g, hd, hd, key=9)
    out = flash_attention(q, kk, v, False, 16, 32)
    want = _einsum_attention(q.reshape(b, s, kh * g, hd), kk, v, causal=False)
    np.testing.assert_allclose(out.reshape(b, s, kh * g, hd), want, rtol=2e-4, atol=2e-4)


def test_flash_is_stable_at_large_scores():
    """Online-softmax must not overflow where naive softmax would."""
    b, s, kh, g, hd = 1, 64, 1, 1, 8
    q, kk, v = _mk(b, s, s, kh, g, hd, hd, key=11)
    out = flash_attention(50.0 * q, 50.0 * kk, v, True, 16, 16)
    assert bool(jnp.all(jnp.isfinite(out)))
