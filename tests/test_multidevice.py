"""Forced-multi-device SPMD integration (subprocess: 8 host devices).

The main test process keeps 1 device (dry-run owns the 512-device trick);
this test spawns one subprocess that builds a 4×2 mesh, runs a REAL
(executed, not just lowered) EP-MCMC step with the production sharding
specs, and checks chain isolation numerically.
"""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools, json
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed import epmcmc
from repro.distributed.sharding import to_shardings
from repro.models.lm.config import reduced

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = reduced(get_config("mamba2_130m"), num_layers=2, d_model=64, vocab_size=128)
C = 4
state = epmcmc.init_state(jax.random.PRNGKey(0), cfg, C)
key = jax.random.PRNGKey(1)
toks = jax.random.randint(key, (C, 2, 16), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, -1)}

s_spec = epmcmc.state_specs(cfg, mesh, state)
b_spec = epmcmc.batch_spec(mesh, batch)
step = jax.jit(
    functools.partial(epmcmc.epmcmc_step, cfg=cfg, num_shards=C, shard_tokens=1e4, step_size=1e-4),
    in_shardings=(to_shardings(mesh, s_spec), to_shardings(mesh, b_spec)),
)
with mesh:
    state1, m1 = step(state, batch)
    # chain isolation: rerun with chain 0's tokens perturbed; only chain 0 moves
    toks2 = toks.at[0].set((toks[0] + 1) % cfg.vocab_size)
    state2, m2 = step(state, {"tokens": toks2, "labels": jnp.roll(toks2, -1, -1)})

l1 = jax.device_get(m1["loss_per_chain"]); l2 = jax.device_get(m2["loss_per_chain"])
hlo = step.lower(state, batch).compile().as_text()
n = epmcmc.assert_no_cross_chain_collectives(hlo, mesh)
print(json.dumps({
    "chain0_moved": bool(abs(l1[0] - l2[0]) > 0),
    "others_fixed": bool(all(abs(float(a) - float(b)) == 0.0 for a, b in zip(l1[1:], l2[1:]))),
    "n_collectives_checked": n,
    "devices": jax.device_count(),
}))
"""


_SAMPLER_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
from repro.api import sample_subposteriors
from repro.models.bayes import get_model

model = get_model("poisson")
key = jax.random.PRNGKey(0)
data, _ = model.generate_data(key, 2000)
res = sample_subposteriors(
    jax.random.fold_in(key, 1), model, data, 4, 100,
    sampler="gibbs", warmup=50, burn_in=20, step_size=0.15,
)
print(json.dumps({
    "devices": jax.device_count(),
    "backend": res.backend,
    "collectives_checked": res.collectives_checked,
    "theta_shape": list(res.theta.shape),
    "finite": bool(jnp.all(jnp.isfinite(res.theta))),
    "accept_one": bool(jnp.all(res.accept == 1.0)),  # Gibbs always accepts
}))
"""


@pytest.mark.slow
def test_sampling_stage_shard_maps_with_no_cross_chain_collectives():
    """The mcmc_run sampling stage on a forced 4-device mesh: shard_map
    backend, compiled-HLO collective check passes, chains produce finite
    (M, T, d) θ — the tentpole's acceptance criterion, in CI."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, "-c", _SAMPLER_SCRIPT], capture_output=True, text=True,
        timeout=420, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 4
    assert rec["backend"] == "shard_map(4 devices)"
    assert rec["collectives_checked"] is not None  # HLO assert actually ran
    assert rec["theta_shape"] == [4, 100, 2]
    assert rec["finite"] is True
    assert rec["accept_one"] is True


@pytest.mark.slow
def test_epmcmc_step_on_8_devices_executes_and_isolates():
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=420, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 8
    assert rec["chain0_moved"] is True
    assert rec["others_fixed"] is True  # data of chain c only affects chain c
