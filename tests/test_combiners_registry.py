"""Combiner engine v2: registry round-trips, batched IMG vs sequential,
Pallas weight path vs the Eq. 3.5 oracle, and the compat shim surface."""

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import combine as shim
from repro.core.combiners import (
    CombineResult,
    available_combiners,
    canonical_combiners,
    get_combiner,
    log_weight_bruteforce,
    ragged_gather,
)
from repro.kernels.img_weights import img_log_weights

M, T, D = 2, 600, 2


@pytest.fixture(scope="module")
def two_gaussian_product():
    """Exact subposterior samples from two Gaussians N(±μ, σ²I); their
    density product is N(0, σ²/2 I) in closed form — the one setting where
    every combiner's output distribution is checkable without MCMC error.
    M=2 is also the paper's high-acceptance regime (each proposal perturbs
    half the mixture component), keeping IMG autocorrelation low."""
    key = jax.random.PRNGKey(0)
    mus = jnp.stack([jnp.full((D,), -0.5), jnp.full((D,), 0.5)])  # (M, D)
    sigma = 0.7
    eps = jax.random.normal(key, (M, T, D))
    samples = mus[:, None, :] + sigma * eps
    prod_mean = mus.mean(0)
    prod_std = sigma / jnp.sqrt(M)
    return samples, prod_mean, prod_std


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_get_combiner_roundtrips_every_registered_name(two_gaussian_product):
    samples, _, _ = two_gaussian_product
    key = jax.random.PRNGKey(1)
    for name in available_combiners():
        fn = get_combiner(name)
        res = fn(key, samples, 64, rescale=True)
        assert isinstance(res, CombineResult), name
        if name in ("pool", "subpostPool"):
            # pool ignores n_draws: the baseline IS the full M·T union
            assert res.samples.shape == (M * T, D), name
        else:
            assert res.samples.shape == (64, D), name
        assert bool(jnp.all(jnp.isfinite(res.samples))), name


def test_canonical_names_are_available_and_deduped():
    names = canonical_combiners()
    assert set(names) <= set(available_combiners())
    assert len(set(get_combiner(n) for n in names)) == len(names)
    for expect in ("parametric", "nonparametric", "semiparametric",
                   "semiparametric_w", "subpost_average", "consensus", "pool"):
        assert expect in names


def test_unknown_combiner_raises_with_choices():
    with pytest.raises(KeyError, match="nonparametric"):
        get_combiner("no_such_combiner")


def test_aliases_resolve_to_same_callable():
    assert get_combiner("nonparametric") is get_combiner("nonparametric_img")
    assert get_combiner("pool") is get_combiner("subpostPool")
    assert get_combiner("subpost_average") is get_combiner("subpostAvg")


# ---------------------------------------------------------------------------
# batched IMG vs sequential
# ---------------------------------------------------------------------------


def _moments(draws):
    return np.asarray(draws.mean(0)), np.asarray(draws.std(0))


@pytest.mark.parametrize("mode", [
    dict(n_batch=8),
    dict(n_batch=8, weight_eval="kernel"),
    dict(n_batch=1, weight_eval="kernel"),
])
def test_batched_img_matches_sequential_moments(two_gaussian_product, mode):
    """n_batch > 1 (and the Pallas-scored vectorized sweep) must target the
    same per-chain stationary distribution as the serial Algorithm 1."""
    samples, prod_mean, prod_std = two_gaussian_product
    n_draws = 3000
    combiner = get_combiner("nonparametric")
    seq = jax.jit(lambda k: combiner(k, samples, n_draws, rescale=True).samples)(
        jax.random.PRNGKey(2)
    )
    bat = jax.jit(
        lambda k: combiner(k, samples, n_draws, rescale=True, **mode).samples
    )(jax.random.PRNGKey(3))
    m_seq, s_seq = _moments(seq)
    m_bat, s_bat = _moments(bat)
    # IMG draws are autocorrelated, so both estimates carry MC wander; the
    # tolerances below are ~3x the observed across-seed scatter at this size.
    np.testing.assert_allclose(m_bat, m_seq, atol=0.25)
    np.testing.assert_allclose(s_bat, s_seq, rtol=0.35)
    # and both track the closed-form product
    np.testing.assert_allclose(m_bat, np.asarray(prod_mean), atol=0.2)
    np.testing.assert_allclose(m_seq, np.asarray(prod_mean), atol=0.2)
    assert abs(float(s_bat.mean()) - float(prod_std)) < 0.5 * float(prod_std)


def test_batched_img_emits_exactly_n_draws(two_gaussian_product):
    samples, _, _ = two_gaussian_product
    combiner = get_combiner("nonparametric")
    # n_draws not divisible by n_batch: ceil-round then trim
    res = combiner(jax.random.PRNGKey(4), samples, 1000, rescale=True, n_batch=7)
    assert res.samples.shape == (1000, D)
    assert res.extras is not None
    assert int(res.extras["n_batch"]) == 7
    assert res.extras["per_chain_acceptance"].shape == (7,)


def test_semiparametric_batched_runs(two_gaussian_product):
    samples, prod_mean, _ = two_gaussian_product
    res = get_combiner("semiparametric")(
        jax.random.PRNGKey(5), samples, 512, rescale=True, n_batch=4
    )
    assert res.samples.shape == (512, D)
    np.testing.assert_allclose(np.asarray(res.samples.mean(0)),
                               np.asarray(prod_mean), atol=0.15)


def test_kernel_path_supports_full_semiparametric_weights(two_gaussian_product):
    """The vectorized sweep now carries the accepted mean-shift and aux
    deltas, so full semiparametric ``W_t`` runs on ``weight_eval="kernel"``
    (it used to raise). Product posterior must match the analytic one."""
    samples, prod_mean, _ = two_gaussian_product
    res = get_combiner("semiparametric")(
        jax.random.PRNGKey(6), samples, 64, weight_eval="kernel", n_batch=4
    )
    out = np.asarray(res.samples)
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out.mean(axis=0), np.asarray(prod_mean), atol=0.2)


def test_kernel_sweep_decisions_match_bruteforce_replay():
    """The vectorized sweep's rank-one weight correction must be *exact*:
    replay its RNG and re-run the accept/reject recursion with brute-force
    Eq. 3.5 weight recomputation — every decision must agree."""
    from repro.core.combiners.api import counts_or_full
    from repro.core.combiners.img import _img_kernel_sweep, _init_img_carry

    key = jax.random.PRNGKey(42)
    m_, t_, d_, b_ = 5, 40, 3, 3
    samples = jax.random.normal(key, (m_, t_, d_))
    counts = counts_or_full(samples, None)
    keys = jax.random.split(jax.random.PRNGKey(7), b_)
    carry = jax.vmap(lambda k: _init_img_carry(k, samples, counts, None))(keys)
    h = jnp.asarray(0.8)
    out = _img_kernel_sweep(carry, samples, counts, h)

    k3 = jax.vmap(lambda k: jax.random.split(k, 3))(carry.key)
    c = np.asarray(jax.vmap(lambda k: jax.random.randint(k, (m_,), 0, counts))(k3[:, 1]))
    u = np.asarray(jax.vmap(lambda k: jax.random.uniform(k, (m_,)))(k3[:, 2]))

    sam = np.asarray(samples)
    for b in range(b_):
        sel = np.asarray(carry.theta_sel[b]).copy()
        tix = np.asarray(carry.t_idx[b]).copy()
        nacc = 0
        for m in range(m_):
            prop = sel.copy()
            prop[m] = sam[m, c[b, m]]
            lw_p = float(log_weight_bruteforce(jnp.asarray(prop), h))
            lw_c = float(log_weight_bruteforce(jnp.asarray(sel), h))
            if np.log(u[b, m]) < lw_p - lw_c:
                sel, tix[m], nacc = prop, c[b, m], nacc + 1
        np.testing.assert_array_equal(tix, np.asarray(out.t_idx[b]))
        assert nacc == int(out.n_accept[b])
        np.testing.assert_allclose(np.asarray(out.theta_sel[b]), sel, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(out.mean[b]), sel.mean(0), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(out.sumsq[b]), (sel**2).sum(), rtol=1e-5)


# ---------------------------------------------------------------------------
# Pallas weight path vs Eq. 3.5 oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,m,d", [(8, 4, 3), (128, 8, 5), (300, 16, 64)])
def test_img_weights_kernel_agrees_with_bruteforce(B, m, d):
    theta = jax.random.normal(jax.random.PRNGKey(B + d), (B, m, d))
    h = jnp.asarray(0.6)
    got = img_log_weights(theta, h)
    want = jax.vmap(lambda t: log_weight_bruteforce(t, h))(theta)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


# ---------------------------------------------------------------------------
# helpers + shim surface
# ---------------------------------------------------------------------------


def test_tree_combine_rejects_non_reduction_combiners(two_gaussian_product):
    """Registry dispatch must not let a fixed-output baseline (pool emits the
    2T-row union) masquerade as a tree-reduction step — the old if/elif raised
    for unknown methods; the registry path needs the equivalent guard."""
    from repro.core.tree_combine import tree_combine

    samples, _, _ = two_gaussian_product
    with pytest.raises(ValueError, match="tree-reduction"):
        tree_combine(jax.random.PRNGKey(0), samples, 64, method="pool")


def test_ragged_gather_wraps_modulo_counts():
    samples = jnp.arange(2 * 4 * 1, dtype=jnp.float32).reshape(2, 4, 1)
    counts = jnp.asarray([4, 3], jnp.int32)
    out = ragged_gather(samples, counts)
    np.testing.assert_array_equal(np.asarray(out[0, :, 0]), [0, 1, 2, 3])
    np.testing.assert_array_equal(np.asarray(out[1, :, 0]), [4, 5, 6, 4])


def test_shim_exposes_historical_api_with_unchanged_signatures():
    for name in ("parametric", "nonparametric_img", "semiparametric_img",
                 "subpost_average", "consensus_weighted", "pool",
                 "log_weight_bruteforce", "online_init", "online_update",
                 "online_product", "CombineResult", "OnlineMoments"):
        assert hasattr(shim, name), name
    np_params = inspect.signature(shim.nonparametric_img).parameters
    assert list(np_params) == ["key", "samples", "n_draws", "counts", "schedule", "rescale"]
    sp_params = inspect.signature(shim.semiparametric_img).parameters
    assert list(sp_params) == ["key", "samples", "n_draws", "counts", "schedule",
                               "rescale", "nonparametric_weights"]
