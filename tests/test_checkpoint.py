"""Checkpoint subsystem: atomicity, retention, async, elastic reshard."""

import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    Checkpointer,
    latest_step,
    restore,
    restore_elastic_chains,
    save,
)


@pytest.fixture()
def tree():
    return {
        "params": {"w": jnp.arange(24.0).reshape(4, 6), "b": jnp.ones((4,))},
        "key": jnp.zeros((4, 2), jnp.uint32),
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path, tree):
    save(tmp_path, 5, tree, metadata={"num_chains": 4})
    got, meta = restore(tmp_path, template=tree)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(a, b)
        assert a.dtype == b.dtype
    assert meta["num_chains"] == 4


def test_commit_is_manifest_gated(tmp_path, tree):
    save(tmp_path, 5, tree)
    # simulate a crashed writer: data without manifest
    broken = tmp_path / "step_000000009"
    (broken / "host_00000").mkdir(parents=True)
    assert latest_step(tmp_path) == 5  # uncommitted dir ignored


def test_retention_keeps_last_k(tmp_path, tree):
    for s in (1, 2, 3, 4):
        save(tmp_path, s, tree, keep=2)
    steps = sorted(p.name for p in tmp_path.iterdir())
    assert steps == ["step_000000003", "step_000000004"]


def test_async_checkpointer_overlaps(tmp_path, tree):
    ck = Checkpointer(tmp_path, keep=5)
    for s in range(3):
        ck.save(s, jax.tree.map(lambda x: x + s, tree))
    ck.close()
    got, _ = restore(tmp_path, step=2, template=tree)
    np.testing.assert_array_equal(got["params"]["b"], tree["params"]["b"] + 2)


def test_elastic_shrink_and_grow(tmp_path, tree):
    save(tmp_path, 1, tree, metadata={"num_chains": 4})
    small = jax.tree.map(lambda x: x[:2] if x.ndim and x.shape[0] == 4 else x, tree)
    got, meta = restore_elastic_chains(tmp_path, small, 2)
    np.testing.assert_array_equal(got["params"]["w"], tree["params"]["w"][:2])
    assert meta["num_chains"] == 2 and meta["elastic_from"] == 4

    big = jax.tree.map(
        lambda x: jnp.concatenate([x, x], 0) if x.ndim and x.shape[0] == 4 else x, tree
    )
    got8, _ = restore_elastic_chains(tmp_path, big, 8)
    np.testing.assert_array_equal(got8["params"]["w"][4:], tree["params"]["w"])
    # tiled RNG keys got bumped so streams de-duplicate
    assert not np.array_equal(np.asarray(got8["key"][4]), np.asarray(got8["key"][0]))


def test_restart_replays_data_stream():
    """Fault-tolerance invariant: data is a pure function of (seed, shard,
    step) — a restart consumes the identical stream."""
    from repro.data.tokens import TokenStream

    a = TokenStream(1000, 4, 32, seed=3, shard_index=2, num_shards=8)
    b = TokenStream(1000, 4, 32, seed=3, shard_index=2, num_shards=8)
    np.testing.assert_array_equal(a.batch(17)["tokens"], b.batch(17)["tokens"])
    c = TokenStream(1000, 4, 32, seed=3, shard_index=3, num_shards=8)
    assert not np.array_equal(np.asarray(a.batch(17)["tokens"]), np.asarray(c.batch(17)["tokens"]))
