"""MoE layer invariants: dispatch-vs-gather consistency, capacity math,
router normalization, shared experts."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.lm import moe as moe_lib
from repro.models.lm.config import reduced


def _cfg(**over):
    cfg = reduced(get_config("granite_moe_1b"))
    if over:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, **over))
    return cfg


def test_dispatch_matches_gather_when_dropless():
    """§Perf iteration 1 safety gate: the capacity-dispatch decode path must
    agree with the dropless per-token gather path whenever capacity suffices
    (reduced configs use capacity_factor=4 ⇒ effectively dropless)."""
    cfg = _cfg()
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    y_dispatch, _aux = moe_lib.moe_forward(p, cfg, x)
    y_gather, _ = moe_lib.moe_forward_gather(p, cfg, x)
    np.testing.assert_allclose(y_dispatch, y_gather, rtol=2e-3, atol=2e-3)


def test_dispatch_drops_only_over_capacity():
    """With capacity_factor → tiny, outputs shrink toward the shared-expert
    path but never NaN; combine weights of dropped tokens are zero."""
    cfg = _cfg(capacity_factor=0.01)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model), jnp.float32)
    y, aux = moe_lib.moe_forward(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.isfinite(aux))


def test_router_topk_normalization():
    cfg = _cfg()
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 4, cfg.d_model), jnp.float32)
    tokens = x.reshape(-1, cfg.d_model)
    logits = (tokens @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, _ = jax.lax.top_k(probs, cfg.moe.top_k)
    normed = vals / vals.sum(-1, keepdims=True)
    np.testing.assert_allclose(normed.sum(-1), 1.0, rtol=1e-6)


def test_aux_loss_uniform_router_is_one():
    """Switch aux loss equals ~1.0 for a perfectly uniform router."""
    cfg = _cfg()
    e = cfg.moe.num_experts
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform probs
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model), jnp.float32)
    _, aux = moe_lib.moe_forward(p, cfg, x)
    # me = 1/E exactly; ce ≈ top-1 histogram (ties broken by index) — aux =
    # E·Σ me·ce = Σ ce = 1 exactly regardless of tie-breaking
    np.testing.assert_allclose(float(aux), 1.0, rtol=1e-5)


def test_shared_experts_always_on():
    cfg = reduced(get_config("deepseek_v2_236b"))
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    assert "shared" in p
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 4, cfg.d_model), jnp.float32)
    y_full, _ = moe_lib.moe_forward(p, cfg, x)
    p_no_routed = jax.tree.map(jnp.zeros_like, p)
    p_no_routed = dict(p, experts=jax.tree.map(jnp.zeros_like, p["experts"]))
    y_shared_only, _ = moe_lib.moe_forward(p_no_routed, cfg, x)
    # shared path contributes even when routed experts output zero
    assert float(jnp.max(jnp.abs(y_shared_only))) > 0
