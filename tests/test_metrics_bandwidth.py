"""L2 metric, bandwidth schedules, tree combiner, ESS — properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core import bandwidth as bw
from repro.core import combine, metrics
from repro.core.tree_combine import tree_combine


def test_l2_distance_zero_for_identical_samples():
    s = jax.random.normal(jax.random.PRNGKey(0), (500, 3))
    d = metrics.l2_distance(s, s)
    assert float(d) < 1e-4


def test_l2_distance_orders_by_mean_shift():
    key = jax.random.PRNGKey(1)
    p = jax.random.normal(key, (800, 2))
    near = jax.random.normal(jax.random.fold_in(key, 1), (800, 2)) + 0.3
    far = jax.random.normal(jax.random.fold_in(key, 2), (800, 2)) + 3.0
    assert float(metrics.l2_distance(p, near)) < float(metrics.l2_distance(p, far))


def test_l2_distance_symmetric():
    key = jax.random.PRNGKey(2)
    a = jax.random.normal(key, (400, 2))
    b = 0.5 + jax.random.normal(jax.random.fold_in(key, 1), (300, 2))
    np.testing.assert_allclose(
        metrics.l2_distance(a, b), metrics.l2_distance(b, a), rtol=1e-4
    )


@given(st.integers(1, 40), st.integers(1, 2000))
def test_annealed_bandwidth_monotone_decreasing(d, i):
    sched = bw.annealed(d)
    assert float(sched(i + 1)) < float(sched(i)) <= 1.0


def test_silverman_scales_with_std():
    key = jax.random.PRNGKey(0)
    s = jax.random.normal(key, (500, 4))
    np.testing.assert_allclose(bw.silverman(3.0 * s), 3.0 * bw.silverman(s), rtol=1e-4)


def test_ess_detects_correlation():
    key = jax.random.PRNGKey(3)
    iid = jax.random.normal(key, (4000,))
    rho = 0.95
    noise = jax.random.normal(jax.random.fold_in(key, 1), (4000,))

    def ar1(carry, eps):
        x = rho * carry + jnp.sqrt(1 - rho**2) * eps
        return x, x

    _, correlated = jax.lax.scan(ar1, 0.0, noise)
    ess_iid = float(metrics.effective_sample_size(iid))
    ess_corr = float(metrics.effective_sample_size(correlated))
    assert ess_corr < 0.3 * ess_iid
    assert ess_iid > 2000


def test_pairwise_tree_combiner_matches_flat_on_gaussians():
    """The O(dTM) tree (paper §3.2 end) must agree with the flat parametric
    combiner in the Gaussian regime."""
    key = jax.random.PRNGKey(4)
    M, T, d = 8, 3000, 3
    means = jax.random.normal(key, (M, d))
    samples = means[:, None, :] + 0.7 * jax.random.normal(
        jax.random.fold_in(key, 1), (M, T, d)
    )
    flat = combine.parametric(jax.random.PRNGKey(5), samples, T)
    tree = tree_combine(jax.random.PRNGKey(6), samples, T, method="parametric")
    np.testing.assert_allclose(
        tree.samples.mean(0), flat.samples.mean(0), atol=0.12
    )


def test_mmd_zero_for_same_distribution():
    key = jax.random.PRNGKey(7)
    a = jax.random.normal(key, (600, 2))
    b = jax.random.normal(jax.random.fold_in(key, 1), (600, 2))
    c = 2.0 + jax.random.normal(jax.random.fold_in(key, 2), (600, 2))
    same = float(metrics.mmd2_rbf(a, b, 1.0))
    diff = float(metrics.mmd2_rbf(a, c, 1.0))
    assert same < 0.01 and diff > 10 * max(same, 1e-6)
