"""The multi-controller launch path (``python -m repro.api.launch``).

A real 2-process ``jax.distributed`` run on CPU: two subprocesses rendezvous
at a local coordinator, each samples its half of the chains, and only the
moments-backed combine state crosses processes (through the coordinator's
key-value store — CPU hosts cannot run multi-process XLA collectives at
all). Rank 0's result record must reproduce a single-process run of the
same spec **bitwise**: every chain runs through the same width-1 chunk
programs whatever the rank count (a vmap over 2 vs 4 chains fuses
differently at the ulp level, and rejection loops amplify one flipped
comparison into a divergent chain — see run_launch), and the combine-state
merge is exact concatenation in rank order.
"""

import json
import os
import socket
import subprocess
import sys

import numpy as np
import pytest

SPEC_ARGS = [
    "--model", "poisson", "--sampler", "gibbs", "--M", "4", "--T", "60",
    "--warmup", "0", "--n", "512", "--stream-every", "20",
]


def _env():
    import repro

    src_dir = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(
        os.environ,
        JAX_PLATFORMS="cpu",
        PYTHONPATH=src_dir + os.pathsep + os.environ.get("PYTHONPATH", ""),
    )
    env.pop("XLA_FLAGS", None)  # single device per process, like real hosts
    return env


def _run_launch(extra, timeout=600):
    return subprocess.run(
        [sys.executable, "-m", "repro.api.launch", *SPEC_ARGS, *extra],
        capture_output=True, text=True, env=_env(), timeout=timeout,
    )


def _free_port():
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


@pytest.fixture(scope="module")
def records(tmp_path_factory):
    d = tmp_path_factory.mktemp("launch")
    one, two = d / "one.json", d / "two.json"

    proc1 = _run_launch(["--json", str(one)])
    assert proc1.returncode == 0, proc1.stderr[-4000:]

    port = _free_port()
    coord = ["--coordinator", f"localhost:{port}", "--num-processes", "2"]
    rank1 = subprocess.Popen(
        [sys.executable, "-m", "repro.api.launch", *SPEC_ARGS, *coord,
         "--process-id", "1"],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=_env(),
    )
    rank0 = _run_launch([*coord, "--process-id", "0", "--json", str(two)])
    out1, err1 = rank1.communicate(timeout=600)
    assert rank0.returncode == 0, rank0.stderr[-4000:]
    assert rank1.returncode == 0, err1[-4000:]

    with open(one) as f:
        single = json.load(f)
    with open(two) as f:
        double = json.load(f)
    return single, double


def test_backend_strings(records):
    single, double = records
    assert single["backend"] == "jax.distributed(1 processes)"
    assert double["backend"] == "jax.distributed(2 processes)"
    assert double["num_processes"] == 2


def test_two_process_result_matches_single_process(records):
    single, double = records
    assert double["spec_id"] == single["spec_id"]  # same declared experiment
    assert double["accept"] == pytest.approx(single["accept"], abs=1e-6)
    s1 = np.asarray(single["combined"]["online"]["samples"])
    s2 = np.asarray(double["combined"]["online"]["samples"])
    assert s1.shape == s2.shape
    # width-1 chunk programs make execution rank-count-invariant, and the
    # KV-store state merge is exact concatenation — so bitwise, not close
    np.testing.assert_array_equal(s2, s1)
    np.testing.assert_array_equal(
        np.asarray(double["combined"]["online"]["mean"]),
        np.asarray(single["combined"]["online"]["mean"]),
    )


def test_launch_rejects_unlaunchable_combiners():
    proc = _run_launch(["--combiner", "parametric"])
    assert proc.returncode != 0
    assert "moments-backed" in proc.stderr


def test_multi_process_needs_a_coordinator():
    proc = _run_launch(["--num-processes", "2", "--process-id", "0"])
    assert proc.returncode != 0
    assert "coordinator" in proc.stderr.lower()
