"""The roofline analyzer itself is load-bearing — test it.

Key invariant (documented in hlo_stats): cost_analysis visits a while body
ONCE; our analyzer multiplies by trip count, so a scanned model must report
the same FLOPs as its unrolled twin.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_stats

D, L = 64, 8


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_equal_unrolled_flops():
    ws = jnp.ones((L, D, D))
    x = jnp.ones((4, D))

    def scanned(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    def unrolled(x, ws):
        h = x
        for i in range(L):
            h = jnp.tanh(h @ ws[i])
        return h

    s_scan = hlo_stats.analyze(_hlo(scanned, x, ws))
    s_unroll = hlo_stats.analyze(_hlo(unrolled, x, ws))
    assert s_scan.flops > 0
    np.testing.assert_allclose(s_scan.flops, s_unroll.flops, rtol=1e-6)
    assert any(t == L for t in s_scan.trip_counts.values())


def test_dot_flops_formula():
    a = jnp.ones((32, 48))
    b = jnp.ones((48, 16))
    s = hlo_stats.analyze(_hlo(lambda a, b: a @ b, a, b))
    np.testing.assert_allclose(s.flops, 2 * 32 * 48 * 16, rtol=1e-6)


def test_scan_bytes_do_not_bill_full_stack_per_iteration():
    """A scan over stacked weights must charge ~L·(slice), not L·(stack)."""
    big_L = 64
    ws = jnp.ones((big_L, D, D))
    x = jnp.ones((4, D))

    def scanned(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    s = hlo_stats.analyze(_hlo(scanned, x, ws))
    stack_bytes = ws.size * 4
    # each weight is read O(1) times (slice + dot operand + boundary write),
    # far below the L×stack ≈ 64×stack the naive operand count would give
    assert s.bytes_accessed < 8 * stack_bytes, (s.bytes_accessed, stack_bytes)
    assert s.bytes_all_ops > 50 * stack_bytes  # the naive count indeed explodes


def test_elementwise_chain_fuses_to_boundary_writes():
    x = jnp.ones((1024, 1024))

    def chain(x):
        for _ in range(12):
            x = jnp.tanh(x * 1.01 + 0.1)
        return x

    s = hlo_stats.analyze(_hlo(chain, x))
    nbytes = x.size * 4
    # 12 tanh+mul+add rounds must NOT cost 36 materializations
    assert s.bytes_accessed <= 6 * nbytes, (s.bytes_accessed / nbytes)


def test_collective_bytes_iota_and_explicit_forms():
    text = """
HloModule m

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024] parameter(0)
  %ar = f32[1024] all-reduce(%p0), replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %cp = f32[1024] collective-permute(%ar), source_target_pairs={{0,1},{1,0}}
}
"""
    s = hlo_stats.analyze(text)
    assert s.collective_bytes == 2 * 1024 * 4
    assert s.collective_count == {"all-reduce": 1, "collective-permute": 1}


def test_type_bytes_tuple_and_dtypes():
    assert hlo_stats._type_bytes("(f32[4,2]{1,0}, bf16[8]{0})") == 4 * 2 * 4 + 8 * 2
    assert hlo_stats._type_bytes("pred[16]") == 16
    assert hlo_stats._type_bytes("token[]") == 0
