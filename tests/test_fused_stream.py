"""Fused streaming hot path: fold parity, Pallas online-update kernel, and
the semiparametric ``weight_eval="kernel"`` sweep.

Correctness contract (ISSUE 6): the fused combine-fold program must agree
with the unfused chunked driver for every registered streaming combiner —
bitwise where the state is a draw buffer (the fused scan carries the draws
themselves), documented-tolerance where the state is running moments (the
scan body and the eager per-chunk calls round reductions differently, and
``online``'s fused face runs the Pallas kernel).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Pipeline, RunSpec
from repro.api.streaming import fused_fold
from repro.core.combiners import (
    BufferState,
    canonical_combiners,
    get_scan_face,
    get_streaming_combiner,
    semiparametric,
    semiparametric_w,
)

M, T, D, CHUNK = 4, 64, 3, 16


def _cloud(key, m=M, t=T, d=D):
    """Synthetic subposterior draws: per-machine offset Gaussian clouds."""
    k1, k2 = jax.random.split(key)
    mu = 0.4 * jax.random.normal(k1, (m, 1, d))
    return mu + 0.6 * jax.random.normal(k2, (m, t, d))


def _host_fold(name, theta, chunk):
    """The unfused chunked driver's state: per-chunk host update calls."""
    sc = get_streaming_combiner(name)
    state = sc.init(theta.shape[0], theta.shape[2])
    for i in range(0, theta.shape[1], chunk):
        state = sc.update(state, theta[:, i : i + chunk])
    return sc, state


def _leaves_equal(a, b, bitwise=True, **tol):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if bitwise:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), **tol)


@pytest.mark.parametrize("name", canonical_combiners())
def test_fused_fold_state_matches_chunked_driver(name):
    """fused_fold's post-scan host state ≡ the subscriber driver's state for
    every registered streaming combiner (buffered fallbacks included)."""
    face = get_scan_face(name)
    assert face is not None, f"{name} lost its scan face — fusion coverage gap"
    theta = _cloud(jax.random.PRNGKey(0))
    counts = jnp.full((M,), T, jnp.int32)

    ff = fused_fold(theta, {name: face}, {}, 16, CHUNK, {})
    fused_state = face.to_state(ff.states[name], theta, counts)
    _, host_state = _host_fold(name, theta, CHUNK)

    if name == "online":
        # moments-only state; fused face runs the Pallas kernel (ref
        # fallback at this chunk size), host runs the jnp merge — the
        # documented merge-rounding tolerance applies
        _leaves_equal(fused_state, host_state, bitwise=False, rtol=1e-5, atol=1e-5)
    elif name == "parametric":
        # buffer component bitwise, Welford moments to scan-vs-eager rounding
        _leaves_equal(fused_state.buffer, host_state.buffer)
        _leaves_equal(fused_state.moments, host_state.moments,
                      bitwise=False, rtol=1e-5, atol=1e-5)
    else:
        # draw-buffer states: the fused scan carries the draws themselves,
        # so the rebuilt state is bitwise the chunk-appended buffer
        _leaves_equal(fused_state, host_state)


@pytest.mark.parametrize("name", ["parametric", "pool", "consensus"])
def test_fused_fold_finalize_parity(name):
    """finalize on the fused-rebuilt state ≡ finalize on the chunk-folded
    state (same key): bitwise for the buffer-backed states."""
    face = get_scan_face(name)
    theta = _cloud(jax.random.PRNGKey(1))
    counts = jnp.full((M,), T, jnp.int32)
    ff = fused_fold(theta, {name: face}, {}, 16, CHUNK, {})
    sc, host_state = _host_fold(name, theta, CHUNK)
    key = jax.random.PRNGKey(7)
    res_f = sc.finalize(key, face.to_state(ff.states[name], theta, counts), 40)
    res_h = sc.finalize(key, host_state, 40)
    np.testing.assert_array_equal(np.asarray(res_f.samples), np.asarray(res_h.samples))


def test_online_scan_face_runs_pallas_kernel_chunked():
    """At kernel-eligible chunk sizes (C ≥ 32) the online face's Pallas
    update stays within merge-rounding tolerance of the jnp chunk merge."""
    face = get_scan_face("online")
    theta = _cloud(jax.random.PRNGKey(2), t=128)
    counts = jnp.full((M,), 128, jnp.int32)
    ff = fused_fold(theta, {"online": face}, {}, 16, 32, {})
    fused_state = face.to_state(ff.states["online"], theta, counts)
    _, host_state = _host_fold("online", theta, 32)
    _leaves_equal(fused_state, host_state, bitwise=False, rtol=2e-4, atol=2e-4)

    sc = get_streaming_combiner("online")
    key = jax.random.PRNGKey(9)
    res_f = sc.finalize(key, fused_state, 40)
    res_h = sc.finalize(key, host_state, 40)
    np.testing.assert_allclose(
        np.asarray(res_f.samples), np.asarray(res_h.samples), rtol=1e-3, atol=1e-3
    )


# ---------------------------------------------------------------------------
# Pallas online_update kernel vs the jnp reference (interpret mode)
# ---------------------------------------------------------------------------


def _np_moments(x):
    """Two-pass numpy reference: (count, mean, M2) of a (C, d) block."""
    mean = x.mean(axis=0)
    c = x - mean
    return float(x.shape[0]), mean, c.T @ c


def test_online_update_kernel_matches_reference_dense():
    from repro.kernels.online_update import (
        online_moments_update,
        online_moments_update_ref,
    )

    key = jax.random.PRNGKey(3)
    m, c, d = 3, 40, 5
    a = jax.random.normal(key, (m, c, d))
    b = 2.0 + 0.5 * jax.random.normal(jax.random.fold_in(key, 1), (m, c, d))

    cnt0 = jnp.zeros((m,))
    mu0 = jnp.zeros((m, d))
    m20 = jnp.zeros((m, d, d))
    ck, mk, m2k = online_moments_update(cnt0, mu0, m20, a, interpret=True)
    ck, mk, m2k = online_moments_update(ck, mk, m2k, b, interpret=True)
    cr, mr, m2r = online_moments_update_ref(cnt0, mu0, m20, a)
    cr, mr, m2r = online_moments_update_ref(cr, mr, m2r, b)

    np.testing.assert_allclose(np.asarray(ck), np.asarray(cr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(mk), np.asarray(mr), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(m2k), np.asarray(m2r), rtol=1e-4, atol=1e-4)

    # and both agree with the two-pass numpy moments of the full stream
    for i in range(m):
        full = np.concatenate([np.asarray(a)[i], np.asarray(b)[i]])
        n, mu, m2 = _np_moments(full)
        assert float(ck[i]) == n
        np.testing.assert_allclose(np.asarray(mk)[i], mu, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(m2k)[i], m2, rtol=1e-3, atol=1e-3)


def test_online_update_kernel_masks_ragged_padding():
    """Rows past ``chunk_counts`` must not contribute — fill them with NaN
    garbage and demand finite, reference-matching moments."""
    from repro.kernels.online_update import (
        online_moments_update,
        online_moments_update_ref,
    )

    key = jax.random.PRNGKey(4)
    m, c, d = 3, 48, 4
    x = jax.random.normal(key, (m, c, d))
    counts = jnp.asarray([48, 17, 0], jnp.int32)
    mask = jnp.arange(c)[None, :, None] < counts[:, None, None]
    x_nan = jnp.where(mask, x, jnp.nan)
    x_zero = jnp.where(mask, x, 0.0)

    cnt0 = jnp.zeros((m,))
    mu0 = jnp.zeros((m, d))
    m20 = jnp.zeros((m, d, d))
    ck, mk, m2k = online_moments_update(
        cnt0, mu0, m20, x_nan, counts, interpret=True
    )
    cr, mr, m2r = online_moments_update_ref(cnt0, mu0, m20, x_zero, counts)

    assert np.isfinite(np.asarray(mk)).all() and np.isfinite(np.asarray(m2k)).all()
    np.testing.assert_allclose(np.asarray(ck), np.asarray(cr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(mk), np.asarray(mr), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(m2k), np.asarray(m2r), rtol=1e-4, atol=1e-4)
    # the count-0 machine is untouched
    np.testing.assert_array_equal(np.asarray(mk)[2], np.zeros(d))
    np.testing.assert_array_equal(np.asarray(m2k)[2], np.zeros((d, d)))


# ---------------------------------------------------------------------------
# semiparametric W_t on the vectorized kernel sweep (ISSUE 6 tentpole part 3)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("combiner", [semiparametric, semiparametric_w])
def test_semiparametric_kernel_sweep_matches_incremental(combiner):
    """``weight_eval="kernel"`` now supports full semiparametric ``W_t``:
    same fixed seed, same cloud — the vectorized sweep must land on the
    same combined posterior as the incremental scorer (distributional
    agreement; the two paths walk different index chains)."""
    theta = _cloud(jax.random.PRNGKey(5), t=120)
    key = jax.random.PRNGKey(11)
    inc = combiner(key, theta, 160, weight_eval="incremental", n_batch=8)
    ker = combiner(key, theta, 160, weight_eval="kernel", n_batch=8)

    si, sk = np.asarray(inc.samples), np.asarray(ker.samples)
    assert np.isfinite(sk).all()
    assert sk.shape == si.shape
    np.testing.assert_allclose(sk.mean(axis=0), si.mean(axis=0), atol=0.2)
    np.testing.assert_allclose(sk.std(axis=0), si.std(axis=0), atol=0.2)


# ---------------------------------------------------------------------------
# pipeline-level: fused default vs subscriber path, and the fused=True gate
# ---------------------------------------------------------------------------


def _spec(**overrides):
    base = dict(
        model="linear", sampler="mala", combiner=("parametric", "pool", "consensus"),
        M=4, T=120, warmup=20, n=512, seed=3, groundtruth_T=60,
        score_metric="logl2", stream_every=40,
    )
    base.update(overrides)
    return RunSpec(**base)


def test_stream_combine_fused_matches_subscriber_end_to_end():
    sf = Pipeline(_spec(), check_hlo=False).stream_combine(n_estimate=32, score=False)
    su = Pipeline(_spec(), check_hlo=False).stream_combine(
        n_estimate=32, score=False, fused=False
    )
    assert sf.complete and su.complete
    # identical trajectory structure: same boundaries, same emitting combiners
    assert [(r["t"], r["combiner"]) for r in sf.trajectory] == [
        (r["t"], r["combiner"]) for r in su.trajectory
    ]
    # finals agree to tolerance: the two paths sample through different
    # executables (fused scan vs sequential chunk dispatches), whose draws
    # agree only to the last ulp, so bitwise equality is not the contract
    for name in ("parametric", "pool", "consensus"):
        np.testing.assert_allclose(
            np.asarray(sf.combined[name].samples),
            np.asarray(su.combined[name].samples),
            rtol=1e-3, atol=1e-3,
        )


def test_fused_flag_raises_when_unfusable(tmp_path):
    """``fused=True`` with a checkpoint subscriber must refuse loudly, not
    silently drop checkpointing."""
    pipe = Pipeline(_spec(), check_hlo=False, checkpoint_dir=str(tmp_path))
    with pytest.raises(ValueError, match="fused"):
        pipe.stream_combine(n_estimate=32, score=False, fused=True)
