"""Checkpoint/resume of the sampling stage.

The guarantee under test: an interrupted-then-resumed sampling stage
reproduces the uninterrupted run **bitwise**. Chunk boundaries are global
(k·checkpoint_every), sessions advance in whole chunks, and the per-step RNG
keys derive from the spec seed alone — so resume replays exactly the same
chunk programs on the same inputs as a run that was never interrupted. The
chunked driver is additionally cross-checked against the one-shot vmap
backend (numerically: XLA may fuse the one-big-scan program differently at
the last ulp, which is why the bitwise contract is defined against the
uninterrupted *chunked* run).
"""

import jax.numpy as jnp
import pytest

from repro.api import Pipeline, RunSpec
from repro.checkpoint import latest_step

SPEC = RunSpec(
    model="linear",
    M=4,
    T=60,
    warmup=30,  # adaptive mala warmup: resume must rebuild from persisted ε
    n=512,
    seed=3,
    groundtruth_T=120,
    combiner=("parametric",),
    score_metric="logl2",
)


def test_interrupt_resume_is_bitwise_identical(tmp_path):
    # uninterrupted references: chunked driver (the bitwise contract) and
    # the one-shot vmap backend (numerical cross-check of the chunking)
    uninterrupted = Pipeline(
        SPEC, checkpoint_dir=tmp_path / "ref", checkpoint_every=20
    ).sample()
    plain = Pipeline(SPEC).sample()

    # interrupted run: stopped at the t=20 chunk boundary (a 30-draw budget
    # rounds down — partial-chunk work is lost on preemption anyway)
    p1 = Pipeline(SPEC, checkpoint_dir=tmp_path / "run", checkpoint_every=20)
    partial = p1.sample(max_steps=30)
    assert not partial.complete
    assert partial.t_done == 20
    assert partial.theta.shape == (SPEC.M, 20, 10)
    assert latest_step(tmp_path / "run") == 20  # kernel state persisted

    # fresh Pipeline (new process in spirit): resumes from the checkpoint
    p2 = Pipeline(SPEC, checkpoint_dir=tmp_path / "run", checkpoint_every=20)
    full = p2.sample()
    assert full.complete and full.t_done == SPEC.T
    assert full.backend == "vmap[resumable]"

    # the acceptance criterion: resume ≡ uninterrupted, bitwise
    assert bool(jnp.all(full.theta == uninterrupted.theta))
    assert bool(jnp.all(full.accept == uninterrupted.accept))
    assert bool(jnp.all(partial.theta == uninterrupted.theta[:, :20]))
    # and the chunked trajectory is the one-shot trajectory numerically
    assert bool(jnp.allclose(full.theta, plain.theta, atol=1e-5))


def test_completed_checkpoint_short_circuits_resampling(tmp_path):
    p1 = Pipeline(SPEC, checkpoint_dir=tmp_path)
    ref = p1.sample()
    assert latest_step(tmp_path) == SPEC.T
    p2 = Pipeline(SPEC, checkpoint_dir=tmp_path)
    again = p2.sample()  # restores the finished stage, runs zero chunks
    assert again.complete
    assert bool(jnp.all(again.theta == ref.theta))
    # and the downstream stages run off the restored artifact
    board = p2.score()
    assert all(v == v for v in board.errors.values())


def test_mid_run_checkpoint_is_cadence_locked(tmp_path):
    """Resuming an unfinished run at a different checkpoint_every would shift
    the global chunk boundaries and void the bitwise guarantee — reject it.
    (A *finished* checkpoint has no tail to replay: any cadence may read it.)"""
    Pipeline(SPEC, checkpoint_dir=tmp_path, checkpoint_every=20).sample(
        max_steps=20
    )
    with pytest.raises(ValueError, match="bitwise-resume"):
        Pipeline(SPEC, checkpoint_dir=tmp_path, checkpoint_every=10).sample()
    with pytest.raises(ValueError, match="bitwise-resume"):
        Pipeline(SPEC, checkpoint_dir=tmp_path).sample()  # default cadence 0
    # original cadence resumes fine, and the finished artifact is readable
    # under any cadence
    Pipeline(SPEC, checkpoint_dir=tmp_path, checkpoint_every=20).sample()
    done = Pipeline(SPEC, checkpoint_dir=tmp_path).sample()
    assert done.complete


def test_checkpoint_dir_is_spec_locked(tmp_path):
    Pipeline(SPEC, checkpoint_dir=tmp_path, checkpoint_every=20).sample(
        max_steps=20
    )
    other = RunSpec(**{**SPEC.to_dict(), "seed": SPEC.seed + 1})
    with pytest.raises(ValueError, match="refusing to resume"):
        Pipeline(other, checkpoint_dir=tmp_path).sample()


def test_resumable_supports_gibbs_extended_positions(tmp_path):
    """Gibbs positions are extended pytrees (shard-local latents) — the
    chunked driver must checkpoint/restore them and extract shared θ."""
    spec = RunSpec(
        model="poisson", sampler="gibbs", M=4, T=40, warmup=10, n=400,
        seed=0, groundtruth_T=80, combiner=("parametric",),
    )
    uninterrupted = Pipeline(
        spec, checkpoint_dir=tmp_path / "ref", checkpoint_every=15
    ).sample()
    p1 = Pipeline(spec, checkpoint_dir=tmp_path / "run", checkpoint_every=15)
    p1.sample(max_steps=15)
    full = Pipeline(
        spec, checkpoint_dir=tmp_path / "run", checkpoint_every=15
    ).sample()
    assert full.theta.shape == (4, 40, 2)
    assert bool(jnp.all(full.theta == uninterrupted.theta))
    # one-shot path agrees numerically (fusion may differ at the last ulp)
    plain = Pipeline(spec).sample()
    assert bool(jnp.allclose(full.theta, plain.theta, atol=1e-4))
