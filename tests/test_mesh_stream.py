"""Mesh streaming + mesh fan-out parity, under a forced 4-device host.

The device count is fixed at JAX init, so everything here runs in ONE
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` and
reports a JSON scorecard that the test functions assert on (a
module-scoped fixture — the subprocess compiles once for all tests).

What the scorecard pins, per ISSUE 7:

- **chunked mesh = chunked vmap, bitwise** for a non-adaptive sampler
  (poisson/gibbs): the backend refactor must not change a single draw.
  Adaptive MH samplers (mala/rwmh) are *not* bitwise across backends —
  ulp-level XLA fusion differences flip accept decisions and amplify
  through the chain — so the cross-backend contract there is statistical,
  not exact; the non-adaptive case is where bitwise is meaningful.
- **stream_combine finals match across backends**: bitwise for buffered
  combiners (the state is the draws themselves), small documented
  tolerance (1e-5) for the moments-backed ``online`` face.
- **every mesh chunk program passes the HLO collective-free assert**
  (``collectives_checked is not None`` — the assert ran; the count may be
  0 when the program legitimately contains no collectives at all).
- **checkpoint/resume works on the mesh** and is bitwise vs an
  uninterrupted mesh run (saves land host-side, restores re-commit to the
  mesh), reporting ``shard_map[resumable](4 devices)``.
- **run_matrix(backend="mesh_fanout")** executes 8 independent cells over
  mesh slices through ONE fanned-out program and reproduces the vmap
  sweep's scoreboard bitwise.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import dataclasses, json
    import jax
    import numpy as np

    from repro.api import Pipeline, RunSpec
    from repro.api.matrix import run_matrix

    out = {"device_count": jax.device_count()}

    base = dict(model="poisson", sampler="gibbs",
                combiner=("parametric", "online"), M=4, T=60, warmup=0,
                n=512, seed=0, groundtruth_T=120, stream_every=20,
                score_metric="logl2")
    # (1, 1) normalizes to the vmap backend — Pipeline would otherwise
    # auto-mesh a mesh_shape=None spec on this forced-4-device host
    spec_v = RunSpec(**base, mesh_shape=(1, 1))
    spec_m = RunSpec(**base, mesh_shape=(4, 1))

    # -- chunked draw parity (subscriber path on both backends) ----------
    pv, pm = Pipeline(spec_v), Pipeline(spec_m)
    rv = pv.stream_combine(fused=False)
    rm = pm.stream_combine(fused=False)
    tv = np.asarray(jax.device_get(pv._draws.theta))
    tm = np.asarray(jax.device_get(pm._draws.theta))
    out["theta_bitwise"] = bool((tv == tm).all())
    out["vmap_backend"] = pv._draws.backend
    out["mesh_backend"] = pm._draws.backend
    out["mesh_collectives_checked"] = pm._draws.collectives_checked

    sv = np.asarray(rv.combined["parametric"].samples)
    sm = np.asarray(rm.combined["parametric"].samples)
    out["buffered_final_bitwise"] = bool((sv == sm).all())
    ov = np.asarray(rv.combined["online"].samples)
    om = np.asarray(rm.combined["online"].samples)
    out["online_final_maxabs"] = float(np.abs(ov - om).max())
    out["trajectory_len"] = len(rv.trajectory)
    out["trajectory_equal"] = bool(
        len(rv.trajectory) == len(rm.trajectory) and all(
            a["t"] == b["t"] and a["combiner"] == b["combiner"]
            and a["error"] == b["error"]
            for a, b in zip(rv.trajectory, rm.trajectory)
        )
    )
    # score() reuses the streamed finals -> the fixed-seed scoreboard
    # parity the backends refactor must preserve
    sbv, sbm = pv.score(), pm.score()
    out["stream_board_errors_equal"] = {
        k: bool(sbv.errors[k] == sbm.errors[k]) for k in sbv.errors
    }

    # -- fused mesh hot path vs fused vmap -------------------------------
    bv = Pipeline(spec_v).run()
    bm = Pipeline(spec_m).run()
    out["board_vmap_backend"] = bv.backend
    out["board_mesh_backend"] = bm.backend
    out["board_mesh_collectives_checked"] = bm.collectives_checked
    out["board_errors"] = {"vmap": dict(bv.errors), "mesh": dict(bm.errors)}

    # -- checkpoint/resume on the mesh -----------------------------------
    import tempfile
    with tempfile.TemporaryDirectory() as d1, \\
            tempfile.TemporaryDirectory() as d2:
        p = Pipeline(spec_m, checkpoint_dir=d1, checkpoint_every=20)
        partial = p.sample(max_steps=40)
        out["resume_partial_t"] = partial.t_done
        p2 = Pipeline(spec_m, checkpoint_dir=d1, checkpoint_every=20)
        resumed = p2.sample()
        straight = Pipeline(
            spec_m, checkpoint_dir=d2, checkpoint_every=20
        ).sample()
        tr = np.asarray(jax.device_get(resumed.theta))
        ts = np.asarray(jax.device_get(straight.theta))
        out["resume_bitwise"] = bool((tr == ts).all())
        out["resume_backend"] = resumed.backend

    # -- run_matrix mesh fan-out: 8 cells, one fanned program ------------
    cells = [RunSpec(model="linear", sampler="mala", combiner="parametric",
                     M=4, T=100, warmup=20, n=512, seed=s,
                     groundtruth_T=200, score_metric="logl2")
             for s in range(8)]
    res_v = run_matrix(cells)
    res_f = run_matrix(cells, backend="mesh_fanout")
    out["fanout_backend"] = res_f.backend
    out["fanout_executables"] = res_f.n_executables
    out["fanout_rows_equal"] = all(
        a["error"] == b["error"] and a["accept"] == b["accept"]
        for a, b in zip(res_v.rows, res_f.rows)
    )
    out["fanout_n_rows"] = len(res_f.rows)

    print("SCORECARD=" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def scorecard():
    import repro

    src_dir = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=4",
        JAX_PLATFORMS="cpu",
        PYTHONPATH=src_dir + os.pathsep + os.environ.get("PYTHONPATH", ""),
    )
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    assert proc.returncode == 0, (
        f"mesh subprocess failed:\n{proc.stdout[-2000:]}\n{proc.stderr[-4000:]}"
    )
    line = [
        ln for ln in proc.stdout.splitlines() if ln.startswith("SCORECARD=")
    ][-1]
    return json.loads(line[len("SCORECARD="):])


def test_subprocess_saw_four_devices(scorecard):
    assert scorecard["device_count"] == 4


def test_chunked_mesh_draws_bitwise_equal_vmap(scorecard):
    assert scorecard["theta_bitwise"] is True
    assert scorecard["vmap_backend"] == "vmap[chunked]"
    assert scorecard["mesh_backend"] == "shard_map[chunked](4 devices)"


def test_mesh_chunk_programs_pass_the_hlo_assert(scorecard):
    # not-None == the per-chunk compiled-HLO assert actually ran (a count
    # of 0 means the program contains no collectives at all — stronger)
    assert scorecard["mesh_collectives_checked"] is not None
    assert scorecard["board_mesh_collectives_checked"] is not None


def test_stream_combine_finals_match_across_backends(scorecard):
    assert scorecard["buffered_final_bitwise"] is True  # draws-backed state
    assert scorecard["online_final_maxabs"] < 1e-5  # moments tolerance
    assert scorecard["trajectory_len"] > 0
    assert scorecard["trajectory_equal"] is True


def test_fixed_seed_scoreboard_parity_across_backends(scorecard):
    # the acceptance contract: same spec, same seed -> same scoreboard,
    # whichever backend sampled (chunk values are bitwise and emitted
    # chunks are localized off the mesh before any combiner computes)
    assert scorecard["stream_board_errors_equal"], "no combiners scored"
    for name, eq in scorecard["stream_board_errors_equal"].items():
        assert eq, f"scoreboard error for {name!r} differs across backends"


def test_fused_mesh_board_is_scored_and_collective_free(scorecard):
    assert scorecard["board_vmap_backend"] == "vmap[fused]"
    assert scorecard["board_mesh_backend"] == "shard_map[fused](4 devices)"
    # fused programs are DIFFERENT executables per backend (AOT shard_map
    # scan vs vmap scan) — gibbs' rejection sampling amplifies their
    # ulp-level divergence into genuinely different (equally valid) draw
    # sequences, so the fused boards are finite and same-shaped, not
    # bitwise; the bitwise scoreboard contract lives on the chunked path
    ev = scorecard["board_errors"]["vmap"]
    em = scorecard["board_errors"]["mesh"]
    assert set(ev) == set(em) and ev, "combiner sets differ or empty"
    import math

    for name in ev:
        assert math.isfinite(ev[name]) and math.isfinite(em[name])
        # empirically ~1e-7 relative on this spec; 1e-2 leaves slack for
        # XLA version drift while still catching a genuinely wrong board
        assert abs(ev[name] - em[name]) <= 1e-2 * max(1.0, abs(ev[name]))


def test_mesh_checkpoint_resume_bitwise(scorecard):
    assert scorecard["resume_partial_t"] == 40
    assert scorecard["resume_bitwise"] is True
    assert scorecard["resume_backend"] == "shard_map[resumable](4 devices)"


def test_mesh_fanout_matrix_reproduces_vmap_sweep(scorecard):
    assert scorecard["fanout_backend"] == "shard_map[fanout](4 devices)"
    assert scorecard["fanout_executables"] == 1  # 8 cells, one program
    assert scorecard["fanout_n_rows"] == 8
    assert scorecard["fanout_rows_equal"] is True
