"""Integration tests: the CLI drivers run end-to-end on CPU (reduced)."""

import jax.numpy as jnp
import pytest

from repro.launch import serve as serve_cli
from repro.launch import train as train_cli


def test_train_epmcmc_then_resume(tmp_path):
    args = [
        "--arch", "mamba2_130m", "--reduced", "--mode", "epmcmc",
        "--steps", "4", "--batch", "2", "--seq", "32", "--chains", "2",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "2", "--log-every", "2",
    ]
    out = train_cli.main(args)
    assert jnp.isfinite(out["loss"])
    # restart from the checkpoint and continue
    out2 = train_cli.main(args + ["--resume", "--steps", "6"])
    assert jnp.isfinite(out2["loss"])


def test_train_adamw_decreases_loss():
    out = train_cli.main([
        "--arch", "mamba2_130m", "--reduced", "--mode", "adamw",
        "--steps", "8", "--batch", "4", "--seq", "64", "--log-every", "8",
    ])
    assert jnp.isfinite(out["loss"])


@pytest.mark.parametrize("arch", ["llama3_2_3b", "granite_moe_1b"])
def test_serve_generates_valid_tokens(arch):
    out = serve_cli.main([
        "--arch", arch, "--reduced", "--batch", "2", "--prompt-len", "12", "--gen", "5",
    ])
    assert out["tokens"].shape == (2, 5)


def test_mcmc_run_smoke():
    from repro.launch import mcmc_run

    res = mcmc_run.main([
        "--model", "poisson", "--M", "4", "--samples", "200", "--n", "2000",
        "--groundtruth-samples", "400",
    ])
    assert set(res) >= {"parametric", "nonparametric", "semiparametric"}
    assert all(v == v for v in res.values())  # no NaNs
