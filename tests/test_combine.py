"""Combiner correctness: closed-form linear-Gaussian oracle + invariants.

The linear-Gaussian model is the one case with an exact posterior AND exact
subposteriors, so every claim in paper §3/§5 is checkable numerically:
- the parametric product of exact subposterior moments equals the posterior;
- nonparametric/semiparametric IMG samples converge to the posterior;
- ragged counts (stragglers) keep all estimators consistent.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import combine
from repro.core.subposterior import make_subposterior_logpdf, partition_data
from repro.models.bayes import linear_gaussian as lg
from repro.samplers.base import run_chain
from repro.samplers.rwmh import rwmh_kernel

M, T, D, N = 8, 2500, 4, 4096


@pytest.fixture(scope="module")
def lg_setup():
    key = jax.random.PRNGKey(1)
    data, _ = lg.generate_data(key, N, D)
    post = lg.posterior_moments(data)
    shards = partition_data(data, M)

    def one(shard_idx, k):
        shard = jax.tree.map(lambda x: x[shard_idx], shards)
        logpdf = make_subposterior_logpdf(lg.log_prior, lg.log_lik, shard, M)
        kern = rwmh_kernel(logpdf, step_size=0.08)
        pos, _ = run_chain(k, kern, jnp.zeros(D), T, burn_in=500)
        return pos

    keys = jax.random.split(jax.random.fold_in(key, 7), M)
    samples = jax.jit(jax.vmap(one))(jnp.arange(M), keys)
    return samples, post


def test_subposterior_product_of_exact_moments_is_posterior():
    """Eq 2.1 sanity: ∏ subposteriors == posterior, in closed form."""
    key = jax.random.PRNGKey(0)
    data, _ = lg.generate_data(key, N, D)
    post = lg.posterior_moments(data)
    subs = [lg.subposterior_moments(jax.tree.map(lambda x, m=m: x[m], partition_data(data, M)), M) for m in range(M)]
    from repro.core.gaussian import product_moments

    prod = product_moments(
        jnp.stack([s.mean for s in subs]), jnp.stack([s.cov for s in subs])
    )
    np.testing.assert_allclose(prod.mean, post.mean, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(prod.cov, post.cov, rtol=1e-3, atol=1e-6)


def test_parametric_combiner_recovers_posterior(lg_setup):
    samples, post = lg_setup
    res = jax.jit(lambda k: combine.parametric(k, samples, 4000))(jax.random.PRNGKey(2))
    err = jnp.linalg.norm(res.samples.mean(0) - post.mean)
    assert float(err) < 0.05, float(err)
    np.testing.assert_allclose(res.moments.mean, post.mean, atol=0.05)
    np.testing.assert_allclose(res.moments.cov, post.cov, rtol=0.5, atol=2e-4)


@pytest.mark.parametrize("method,kwargs", [
    ("nonparametric_img", {}),
    ("semiparametric_img", {}),
    ("semiparametric_img", {"nonparametric_weights": True}),
])
def test_exact_combiners_recover_posterior(lg_setup, method, kwargs):
    samples, post = lg_setup
    fn = getattr(combine, method)
    res = jax.jit(lambda k: fn(k, samples, 3000, rescale=True, **kwargs))(
        jax.random.PRNGKey(3)
    )
    err = jnp.linalg.norm(res.samples.mean(0) - post.mean)
    assert float(err) < 0.12, (method, float(err))
    assert 0.005 < float(res.acceptance_rate) <= 1.0


def test_ragged_counts_consistency(lg_setup):
    """Straggler chains (paper footnote 1): dropping trailing samples of some
    chains must not break any combiner, and parametric stays near-exact."""
    samples, post = lg_setup
    counts = jnp.array([T, T // 2, T, T // 3, T, T, T // 4, T])
    res = jax.jit(lambda k: combine.parametric(k, samples, 2000, counts=counts))(
        jax.random.PRNGKey(4)
    )
    assert float(jnp.linalg.norm(res.samples.mean(0) - post.mean)) < 0.08
    res_np = jax.jit(
        lambda k: combine.nonparametric_img(k, samples, 500, counts=counts, rescale=True)
    )(jax.random.PRNGKey(5))
    assert bool(jnp.all(jnp.isfinite(res_np.samples)))


def test_incremental_weight_equals_bruteforce():
    """The O(d) incremental IMG weight must equal Eq 3.5 exactly."""
    key = jax.random.PRNGKey(6)
    theta = jax.random.normal(key, (5, 3))  # (M, d) one selection
    h = jnp.asarray(0.5)
    lw = combine.log_weight_bruteforce(theta, h)
    mean = theta.mean(0)
    sumsq = jnp.sum(theta**2)
    sse = sumsq - 5 * jnp.sum(mean**2)
    lw_inc = -0.5 * sse / h**2 - 5 * (3 / 2.0) * jnp.log(2 * jnp.pi * h**2)
    np.testing.assert_allclose(lw, lw_inc, rtol=1e-5)


def test_baselines_shapes_and_bias(lg_setup):
    """subpostAvg/pool run. In the linear-Gaussian case the pooled *mean* is
    unbiased (symmetry) — the paper-Fig-1/2 pathology is in the SPREAD:
    pooling keeps the √M-wider subposterior scatter, averaging shrinks it by
    a further √M; the parametric product matches the true posterior scale."""
    samples, post = lg_setup
    avg = combine.subpost_average(samples)
    pool = combine.pool(samples)
    cons = combine.consensus_weighted(samples)
    assert avg.shape == (T, D) and pool.shape == (M * T, D) and cons.shape == (T, D)
    res = combine.parametric(jax.random.PRNGKey(9), samples, T)
    true_scale = float(jnp.sqrt(jnp.trace(post.cov)))
    scale_param = float(jnp.sqrt(jnp.sum(res.samples.std(0) ** 2)))
    scale_pool = float(jnp.sqrt(jnp.sum(pool.std(0) ** 2)))
    assert abs(scale_param - true_scale) < 0.3 * true_scale
    assert scale_pool > 2.0 * true_scale  # pooled spread keeps the √M inflation


def test_online_moments_match_batch(lg_setup):
    samples, _ = lg_setup
    sub = samples[:, :100]  # (M, 100, D)
    state = combine.online_init(M, D)

    def fold(state, t):
        for m in range(M):
            state = combine.online_update(state, m, sub[m, t])
        return state

    for t in range(100):
        state = fold(state, t)
    online = combine.online_product(state)
    batch = combine.parametric(jax.random.PRNGKey(0), sub, 10)
    np.testing.assert_allclose(online.mean, batch.moments.mean, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(online.cov, batch.moments.cov, rtol=1e-2, atol=1e-5)
