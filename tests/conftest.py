import os

# Tests run on the single real CPU device — only the dry-run forces 512
# placeholder devices, and it does so in its own process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# jax 0.4.x's CPU thunk runtime intermittently segfaults inside
# backend_compile after hundreds of in-process compilations (observed at
# ~85% of this suite at *varying* tests, always the same
# compiler.py:backend_compile stack, single-core rigs). The legacy CPU
# runtime is stable under the same load, so pin it for the test process on
# the affected series; newer jaxlib removed the legacy runtime (and the
# flag) along with the instability, so gate on version.
if "xla_cpu_use_thunk_runtime" not in os.environ.get("XLA_FLAGS", ""):
    import jaxlib

    if tuple(int(x) for x in jaxlib.__version__.split(".")[:2]) < (0, 5):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_cpu_use_thunk_runtime=false"
        ).strip()

try:
    import hypothesis
except ImportError:  # optional dev dependency — property tests skip without it
    hypothesis = None

if hypothesis is not None:
    hypothesis.settings.register_profile(
        "repro",
        max_examples=25,
        deadline=None,
        derandomize=True,
        suppress_health_check=list(hypothesis.HealthCheck),
    )
    hypothesis.settings.load_profile("repro")
