import os

# Tests run on the single real CPU device — only the dry-run forces 512
# placeholder devices, and it does so in its own process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    import hypothesis
except ImportError:  # optional dev dependency — property tests skip without it
    hypothesis = None

if hypothesis is not None:
    hypothesis.settings.register_profile(
        "repro",
        max_examples=25,
        deadline=None,
        derandomize=True,
        suppress_health_check=list(hypothesis.HealthCheck),
    )
    hypothesis.settings.load_profile("repro")
