"""Streaming combination engine: combiner-level update*k+finalize ≡ batch,
the Pipeline combine-while-sampling stage (scoreboard parity, trajectory,
interrupt→resume), the RunSpec sweep grammar, the masked linear-Gaussian
Gibbs blocks (ragged N), and the mesh chunked gather / combine_stream."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Pipeline, RunSpec
from repro.core.combiners import (
    buffer_append,
    buffer_init,
    filter_options,
    get_combiner,
    get_streaming_combiner,
    online_update_chunk,
    streaming_combiners,
)
from repro.core.combiners.online import online_init

M, T, D = 4, 120, 3


@pytest.fixture(scope="module")
def cloud():
    key = jax.random.PRNGKey(0)
    return 0.4 * jax.random.normal(key, (M, T, D)) + jax.random.normal(
        jax.random.fold_in(key, 1), (M, 1, D)
    )


def _stream(name, samples, chunk=40, n_draws=64, **options):
    sc = get_streaming_combiner(name)
    state = sc.init(samples.shape[0], samples.shape[2])
    for t0 in range(0, samples.shape[1], chunk):
        state = sc.update(state, samples[:, t0 : t0 + chunk])
    return sc.finalize(
        jax.random.PRNGKey(7), state, n_draws,
        **filter_options(sc.finalize, options),
    )


# ---------------------------------------------------------------------------
# combiner layer: the StreamingCombiner protocol
# ---------------------------------------------------------------------------


def test_native_streaming_implementations_are_registered():
    assert {"parametric", "pool", "subpost_average", "nonparametric", "online"} \
        <= set(streaming_combiners())
    # every OTHER registered name still resolves (buffered fallback)
    assert get_streaming_combiner("consensus") is not None
    with pytest.raises(KeyError, match="unknown combiner"):
        get_streaming_combiner("no_such_combiner")


@pytest.mark.parametrize(
    "name",
    ["parametric", "pool", "subpost_average", "nonparametric",
     "consensus", "weierstrass"],  # last two exercise the generic fallback
)
def test_streaming_updates_then_finalize_is_bitwise_batch(cloud, name):
    """The exact contract: update*k + finalize ≡ the batch combiner on the
    gathered stack, bitwise (same arrays, same key, same option filter)."""
    fin = _stream(name, cloud, rescale=True, n_batch=1)
    fn = get_combiner(name)
    ref = fn(
        jax.random.PRNGKey(7), cloud, 64,
        **filter_options(fn, dict(rescale=True, n_batch=1)),
    )
    assert bool(jnp.all(fin.samples == ref.samples)), name
    assert fin.samples.shape == ref.samples.shape


def test_online_combiner_on_the_registry(cloud):
    """Satellite: --combiner online works outside streaming mode — the batch
    entry point wraps init/update/product and matches parametric moments."""
    res = get_combiner("online")(jax.random.PRNGKey(2), cloud, 64)
    assert res.samples.shape == (64, D)
    assert res.moments is not None
    par = get_combiner("parametric")(jax.random.PRNGKey(2), cloud, 64)
    np.testing.assert_allclose(
        np.asarray(res.moments.mean), np.asarray(par.moments.mean), atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(res.moments.cov), np.asarray(par.moments.cov), atol=1e-4
    )


def test_online_streamed_matches_batch_to_merge_rounding(cloud):
    """Chunked Welford merges reassociate the same sums — the streamed
    online result must agree with its batch face to documented tolerance
    (not bitwise: that guarantee belongs to the buffered combiners)."""
    fin = _stream("online", cloud)
    ref = get_combiner("online")(jax.random.PRNGKey(7), cloud, 64)
    np.testing.assert_allclose(
        np.asarray(fin.moments.mean), np.asarray(ref.moments.mean),
        rtol=1e-4, atol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(fin.samples), np.asarray(ref.samples), rtol=1e-3, atol=1e-4
    )


def test_online_chunk_update_masks_garbage_rows(cloud):
    """chunk_counts' invalid rows may hold NaN — where-based masking must
    keep them out of the moments entirely."""
    chunk = cloud[:, :40].at[:, 30:].set(jnp.nan)
    counts = jnp.full((M,), 30, jnp.int32)
    state = online_update_chunk(online_init(M, D), chunk, counts)
    ref = online_update_chunk(online_init(M, D), cloud[:, :30])
    assert bool(jnp.all(jnp.isfinite(state.mean)))
    np.testing.assert_allclose(
        np.asarray(state.mean), np.asarray(ref.mean), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(state.m2), np.asarray(ref.m2), rtol=1e-4, atol=1e-5
    )


def test_buffer_append_compacts_ragged_chunks(cloud):
    """A mid-stream ragged chunk must keep every chain's valid draws a
    prefix (the combiners' layout contract), not leave holes."""
    c1, c2 = cloud[:, :40], cloud[:, 40:80]
    cc1 = jnp.asarray([40, 30, 40, 20], jnp.int32)
    state = buffer_append(buffer_init(M, D), c1, cc1)
    state = buffer_append(state, c2)
    np.testing.assert_array_equal(np.asarray(state.counts), [80, 70, 80, 60])
    for m, c in enumerate([40, 30, 40, 20]):
        got = np.asarray(state.theta[m, : c + 40])
        want = np.concatenate([np.asarray(c1[m, :c]), np.asarray(c2[m])])
        np.testing.assert_array_equal(got, want)


def test_streaming_finalize_before_update_raises():
    sc = get_streaming_combiner("pool")
    with pytest.raises(ValueError, match="before any update"):
        sc.finalize(jax.random.PRNGKey(0), sc.init(M, D), 16)


def test_pool_estimate_is_strided_union_subsample(cloud):
    """Satellite: pool's cheap estimate returns exactly n_draws even-strided
    rows of the union its finalize materializes — O(n_draws), not O(M·t)."""
    sc = get_streaming_combiner("pool")
    state = buffer_append(buffer_init(M, D), cloud)
    key = jax.random.PRNGKey(1)
    est = sc.estimate(key, state, 32)
    assert est.samples.shape == (32, D)
    full = sc.finalize(key, state, 32).samples  # the whole M·T union
    idx = (jnp.arange(32) * full.shape[0]) // 32
    np.testing.assert_array_equal(np.asarray(est.samples), np.asarray(full[idx]))


def test_subpost_average_estimate_matches_finalize_rows(cloud):
    """Satellite: subpostAvg's cheap estimate (subsample-then-average) is
    bitwise the rows its full gather-then-average finalize selects — the
    mean over machines commutes with row selection."""
    sc = get_streaming_combiner("subpost_average")
    state = buffer_append(buffer_init(M, D), cloud)
    key = jax.random.PRNGKey(1)
    est = sc.estimate(key, state, 32)
    fin = sc.finalize(key, state, 32)
    np.testing.assert_array_equal(np.asarray(est.samples), np.asarray(fin.samples))


def test_online_streaming_face_has_cheap_estimate(cloud):
    """Satellite: the never-buffers combiner can refresh mid-stream on both
    faces (host estimate = the O(d²) moment-product sample; scan face ships
    the in-scan counterpart), so the server answers on it too."""
    from repro.core.combiners import get_scan_face

    sc = get_streaming_combiner("online")
    assert sc.estimate is not None
    assert get_scan_face("online").estimate is not None
    state = online_update_chunk(online_init(M, D), cloud)
    est = sc.estimate(jax.random.PRNGKey(2), state, 16)
    assert est.samples.shape == (16, D)
    # estimate and finalize are the same O(d²) snapshot for online
    fin = sc.finalize(jax.random.PRNGKey(2), state, 16)
    np.testing.assert_array_equal(np.asarray(est.samples), np.asarray(fin.samples))


def test_streaming_estimate_resolution_is_typed():
    """Satellite: names that genuinely can't estimate raise the typed
    EstimateUnavailable (what repro.serve maps to a 503-with-reason), not a
    bare None/AttributeError."""
    from repro.core.combiners import EstimateUnavailable, streaming_estimate

    assert streaming_estimate("parametric") is not None
    for name in ("consensus", "weierstrass", "rpt"):
        with pytest.raises(EstimateUnavailable) as exc:
            streaming_estimate(name)
        assert exc.value.combiner == name
        assert "estimate" in exc.value.reason


# ---------------------------------------------------------------------------
# Pipeline.stream_combine: combine-while-sampling
# ---------------------------------------------------------------------------

# the acceptance grid: 2 models × (parametric, pool bitwise; nonparametric
# documented-tolerance — in practice also bitwise, same buffer + key)
STREAM_SPECS = {
    "linear": RunSpec(
        model="linear", M=4, T=60, warmup=30, n=512, seed=3,
        groundtruth_T=120, combiner=("parametric", "pool", "nonparametric"),
        score_metric="logl2", stream_every=20,
    ),
    "poisson": RunSpec(
        model="poisson", sampler="rwmh", M=4, T=60, warmup=30, n=400, seed=5,
        groundtruth_T=120, combiner=("parametric", "pool", "nonparametric"),
        stream_every=20,
    ),
}


@pytest.fixture(scope="module", params=sorted(STREAM_SPECS))
def streamed(request):
    spec = STREAM_SPECS[request.param]
    pipe = Pipeline(spec)
    return spec, pipe, pipe.stream_combine(n_estimate=32)


def test_stream_combine_final_scoreboard_matches_gather(streamed):
    """Acceptance criterion: the streamed finals equal the gather-then-
    combine path — bitwise for parametric/pool (and the buffered
    nonparametric), same scoreboard errors."""
    spec, pipe, sr = streamed
    assert sr.complete and sr.t_done == spec.T
    gather = Pipeline(spec)
    combined = gather.combine()
    for name in ("parametric", "pool", "nonparametric"):
        assert bool(
            jnp.all(sr.combined[name].samples == combined[name].samples)
        ), name
    assert pipe.score().errors == gather.score().errors


def test_stream_trajectory_shape_and_monotone_t(streamed):
    """Trajectory smoke: one row per (chunk, combiner), strictly growing t,
    finite errors, and the estimates sane enough that the best trajectory
    error is within reach of the final one."""
    spec, pipe, sr = streamed
    names = spec.combiner_names()
    assert len(sr.trajectory) == (spec.T // spec.stream_every) * len(names)
    per_name = {n: [r for r in sr.trajectory if r["combiner"] == n] for n in names}
    board = pipe.score().errors
    for name, rows in per_name.items():
        ts = [r["t"] for r in rows]
        assert ts == sorted(ts) and len(set(ts)) == len(ts)  # monotone chunks
        assert ts[-1] == spec.T
        errs = [r["error"] for r in rows]
        assert all(np.isfinite(e) for e in errs), name
        # the stream must be converging toward the batch answer, not
        # wandering: its best estimate isn't wildly above the final error
        assert min(errs) < 4.0 * abs(board[name]) + 4.0, (name, errs)
    assert all(r["elapsed_s"] >= 0 for r in sr.trajectory)


def test_stream_trajectory_elapsed_is_per_row_and_monotone(streamed):
    """Satellite (bugfix): elapsed_s must be an honest per-boundary stamp in
    BOTH modes — monotone non-decreasing in landing order, never one
    post-run stamp smeared backwards over the trajectory. The fixture runs
    the fused path (every STREAM_SPECS combiner has a scan face); the
    subscriber run is forced here."""
    spec, _, sr_fused = streamed
    for sr in (sr_fused, Pipeline(spec).stream_combine(n_estimate=32, fused=False)):
        stamps = [r["elapsed_s"] for r in sr.trajectory]
        assert stamps == sorted(stamps)
        assert all(s > 0 for s in stamps)


def test_fallback_combiners_fold_but_skip_mid_stream_rows(streamed):
    """A combiner streamed through the generic buffered fallback (no cheap
    estimate) must still finalize bitwise-batch, but not re-run its heavy
    batch body on the growing buffer at every chunk boundary."""
    spec, _, _ = streamed
    pipe = Pipeline(spec)
    sr = pipe.stream_combine(names=("consensus",), n_estimate=16)
    assert sr.trajectory == []  # folds every chunk, estimates none
    from repro.api.pipeline import combine_spec_draws

    ref = combine_spec_draws(
        spec, jax.random.PRNGKey(spec.seed), pipe.sample().theta,
        names=("consensus",),
    )["consensus"]
    assert bool(jnp.all(sr.combined["consensus"].samples == ref.samples))


def test_stream_combine_requires_a_cadence():
    spec = dataclasses.replace(STREAM_SPECS["linear"], stream_every=0)
    with pytest.raises(ValueError, match="stream_every"):
        Pipeline(spec).stream_combine()


def test_stream_combine_after_sample_replays_cached_draws(streamed):
    """stream_combine on a pipeline whose sampling already ran must replay
    the cached draws at the stream cadence — identical trajectory."""
    spec, _, sr = streamed
    pipe = Pipeline(spec)
    pipe.sample()
    sr2 = pipe.stream_combine(n_estimate=32)
    assert [r["error"] for r in sr2.trajectory] == [
        r["error"] for r in sr.trajectory
    ]
    for name in sr.combined:
        assert bool(jnp.all(sr2.combined[name].samples == sr.combined[name].samples))


def test_stream_interrupt_resume_reproduces_scoreboard(tmp_path):
    """Satellite: a streaming run interrupted at a chunk boundary and
    resumed in a fresh Pipeline reproduces the uninterrupted streaming
    scoreboard — trajectory and finals."""
    spec = STREAM_SPECS["linear"]
    ref = Pipeline(
        spec, checkpoint_dir=tmp_path / "ref", checkpoint_every=20
    ).stream_combine(n_estimate=32)

    p1 = Pipeline(spec, checkpoint_dir=tmp_path / "run", checkpoint_every=20)
    partial = p1.stream_combine(n_estimate=32, max_steps=20)
    assert not partial.complete and partial.t_done == 20
    assert partial.combined == {}  # nothing finalized mid-flight
    assert len(partial.trajectory) == len(spec.combiner_names())

    p2 = Pipeline(spec, checkpoint_dir=tmp_path / "run", checkpoint_every=20)
    full = p2.stream_combine(n_estimate=32)
    assert full.complete
    assert [
        (r["t"], r["combiner"], r["error"]) for r in full.trajectory
    ] == [(r["t"], r["combiner"], r["error"]) for r in ref.trajectory]
    for name in ref.combined:
        assert bool(
            jnp.all(full.combined[name].samples == ref.combined[name].samples)
        ), name


def test_stream_checkpoint_cadence_must_align(tmp_path):
    spec = STREAM_SPECS["linear"]  # stream_every=20
    with pytest.raises(ValueError, match="multiple of"):
        Pipeline(spec, checkpoint_dir=tmp_path, checkpoint_every=30).sample()


def test_max_steps_budget_is_durable_with_finer_stream_chunks(tmp_path):
    """Regression: with stream_every < checkpoint_every, a max_steps budget
    smaller than the SAVE cadence could sample a chunk and persist nothing
    (silently lost work) — it must raise instead, and a budget that crosses
    a save boundary must actually land a checkpoint there."""
    from repro.checkpoint import latest_step

    spec = STREAM_SPECS["linear"]  # stream_every=20
    p = Pipeline(spec, checkpoint_dir=tmp_path, checkpoint_every=40)
    with pytest.raises(ValueError, match="durable progress"):
        p.sample(max_steps=20)  # >= chunk (20) but < checkpoint_every (40)
    partial = p.sample(max_steps=50)  # rounds down to the save boundary
    assert partial.t_done == 40
    assert latest_step(tmp_path) == 40  # the budgeted work is durable


def test_mesh_streaming_needs_devices_not_a_fork():
    """Mesh specs stream since the backend unification (the old driver
    raised 'vmap backend only' unconditionally). On a single-device host
    the only failure left is missing devices, and the error must name the
    fix; the positive mesh-streaming path is covered in
    tests/test_mesh_stream.py under a forced multi-device subprocess."""
    spec = dataclasses.replace(STREAM_SPECS["linear"], mesh_shape=(4, 1))
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        Pipeline(spec).stream_combine()


# ---------------------------------------------------------------------------
# RunSpec.sweep grammar
# ---------------------------------------------------------------------------


def test_sweep_outer_product_and_shared_signatures():
    base = RunSpec(model="linear", sampler="mala", combiner="parametric",
                   M=4, T=40, warmup=10, n=256)
    specs = base.sweep(seed=range(4), step_size=[0.1, 0.2])
    assert len(specs) == 8
    assert len({s.spec_id for s in specs}) == 8
    assert [s.seed for s in specs[:2]] == [0, 0]  # last axis varies fastest
    # seeds and step sizes are runtime inputs: ONE executable signature
    assert len({s.executable_signature() for s in specs}) == 1
    # combiner axes accept names and tuples alike, still one signature
    both = base.sweep(combiner=["parametric", ("pool", "nonparametric")])
    assert both[0].combiner == "parametric"
    assert both[1].combiner == ("pool", "nonparametric")
    assert len({s.executable_signature() for s in both}) == 1


def test_sweep_validates_axes():
    base = RunSpec(model="linear")
    assert base.sweep() == [base]
    with pytest.raises(ValueError, match="not a RunSpec field"):
        base.sweep(bogus=[1])
    with pytest.raises(TypeError, match="iterable of field values"):
        base.sweep(combiner="parametric")
    with pytest.raises(ValueError, match="empty"):
        base.sweep(seed=[])
    with pytest.raises(KeyError, match="unknown model"):
        base.sweep(model=["linear", "nope"])


def test_sweep_feeds_run_matrix(tmp_path):
    from repro.api import run_matrix

    specs = RunSpec(
        model="linear", sampler="mala", combiner="parametric", M=4, T=30,
        warmup=10, n=256, groundtruth_T=60, score_metric="logl2",
    ).sweep(seed=range(2))
    res = run_matrix(specs, json_path=str(tmp_path / "sweep.json"))
    assert res.n_specs == 2
    assert res.n_executables == 1
    assert all(np.isfinite(r["error"]) for r in res.rows)


# ---------------------------------------------------------------------------
# masked linear-Gaussian Gibbs (ragged N)
# ---------------------------------------------------------------------------


def test_linear_gibbs_masked_blocks_identity_and_closed_form():
    from repro.models.bayes import linear_gaussian as lg
    from repro.samplers import get_sampler
    from repro.samplers.base import run_chain

    key = jax.random.PRNGKey(0)
    data, _ = lg.generate_data(key, 200, 6)
    z0 = jnp.zeros(6)
    gibbs = get_sampler("gibbs")

    # identity: a count covering every row multiplies by w ≡ 1.0 — the
    # sufficient statistics (and hence the chain) are bitwise the unmasked
    # path's on the same keys
    k_run = jax.random.fold_in(key, 1)
    plain = get_sampler("gibbs")(None, block_updates=lg.gibbs_blocks(data, 4))
    masked = gibbs(None, block_updates=lg.gibbs_blocks(data, 4, count=200))
    pa, _ = jax.jit(lambda k: run_chain(k, plain, z0, 50))(k_run)
    pb, _ = jax.jit(lambda k: run_chain(k, masked, z0, 50))(k_run)
    assert bool(jnp.all(pa == pb))

    # exactness: an edge-padded shard with count masks down to exactly the
    # real rows' closed-form subposterior
    real = {"x": data["x"][:150], "y": data["y"][:150]}
    pad = {
        "x": jnp.concatenate([real["x"], jnp.tile(real["x"][-1:], (50, 1))]),
        "y": jnp.concatenate([real["y"], jnp.tile(real["y"][-1:], 50)]),
    }
    post = lg.subposterior_moments(real, 4)
    kern = gibbs(None, block_updates=lg.gibbs_blocks(pad, 4, count=150))
    pm, _ = jax.jit(lambda k: run_chain(k, kern, z0, 3000, burn_in=200))(
        jax.random.fold_in(key, 2)
    )
    err = float(jnp.linalg.norm(pm.mean(0) - post.mean))
    assert err < 0.05 * float(jnp.linalg.norm(post.mean))


def test_pipeline_linear_gibbs_accepts_non_divisible_n():
    """Satellite: --sampler gibbs no longer rejects ragged counts for models
    that mask (510 = 4·127 + 2 ⇒ edge-padded shards), and the padded run
    matches an unpadded divisible run's scoreboard scale."""
    ragged = RunSpec(
        model="linear", sampler="gibbs", M=4, T=40, warmup=0, n=510, seed=1,
        groundtruth_T=80, combiner=("parametric",), score_metric="logl2",
    )
    board = Pipeline(ragged).run()
    assert all(np.isfinite(v) for v in board.errors.values())
    divisible = dataclasses.replace(ragged, n=512)
    board2 = Pipeline(divisible).run()
    # same scenario up to 2 rows of data: scoreboards on the same scale
    for name in board.errors:
        assert abs(board.errors[name] - board2.errors[name]) < 3.0


def test_poisson_gibbs_accepts_non_divisible_n():
    """Satellite: poisson's per-row latent-q Gibbs conditionals now mask via
    count=, so --sampler gibbs accepts ragged counts (402 = 4·100 + 2 ⇒
    edge-padded shards) and lands on the same scoreboard scale as a
    divisible run."""
    ragged = RunSpec(
        model="poisson", sampler="gibbs", M=4, T=40, warmup=0, n=402, seed=1,
        groundtruth_T=80, combiner=("parametric",),
    )
    board = Pipeline(ragged).run()
    assert all(np.isfinite(v) for v in board.errors.values())
    divisible = dataclasses.replace(ragged, n=400)
    board2 = Pipeline(divisible).run()
    # same scenario up to 2 rows of data: scoreboards on the same scale
    for name in board.errors:
        assert abs(board.errors[name] - board2.errors[name]) < 3.0


def test_poisson_gibbs_count_masks_padding_exactly():
    """An edge-padded poisson shard with count= targets the same subposterior
    as the unpadded real rows: padded q_i are still drawn (identical per-row
    RNG layout) but never enter the (a, b) conditionals' statistics."""
    from repro.models.bayes import poisson_gamma as pg
    from repro.samplers import get_sampler
    from repro.samplers.base import run_chain

    key = jax.random.PRNGKey(0)
    data, _ = pg.generate_data(key, 160)
    real = {"x": data["x"][:120], "t": data["t"][:120]}
    pad = {
        "x": jnp.concatenate([real["x"], jnp.tile(real["x"][-1:], 40)]),
        "t": jnp.concatenate([real["t"], jnp.tile(real["t"][-1:], 40)]),
    }
    gibbs = get_sampler("gibbs")
    kern_real = gibbs(None, block_updates=pg.gibbs_blocks(real, 4))
    kern_pad = gibbs(
        None, block_updates=pg.gibbs_blocks(pad, 4, count=jnp.asarray(120.0))
    )
    k_run = jax.random.fold_in(key, 1)
    pr, _ = jax.jit(lambda k: run_chain(
        k, kern_real, pg.gibbs_init(key, real), 2500, burn_in=250
    ))(k_run)
    pp, _ = jax.jit(lambda k: run_chain(
        k, kern_pad, pg.gibbs_init(key, pad), 2500, burn_in=250
    ))(k_run)
    # different RNG row counts ⇒ different chains; same target ⇒ same moments
    np.testing.assert_allclose(
        np.asarray(pr["theta"].mean(0)), np.asarray(pp["theta"].mean(0)),
        atol=0.2,
    )


# ---------------------------------------------------------------------------
# mesh layer: chunked gather + combine_stream
# ---------------------------------------------------------------------------


def test_gather_subset_samples_chunk_and_combine_stream():
    from repro.distributed.epmcmc import (
        combine_gathered,
        combine_stream,
        gather_subset_samples,
        stack_subset_history,
    )

    key = jax.random.PRNGKey(9)
    C, d_sub, steps = 4, 3, 12
    snaps = [
        {"final_norm": jax.random.normal(jax.random.fold_in(key, t), (C, d_sub))}
        for t in range(steps)
    ]
    # chunked gather: windows of per-step stacked params → (C, k, d_sub)
    win = gather_subset_samples(chunk=snaps[:4])
    assert win.shape == (C, 4, d_sub)
    np.testing.assert_array_equal(
        np.asarray(win),
        np.asarray(stack_subset_history(
            [gather_subset_samples(p) for p in snaps[:4]]
        )),
    )
    with pytest.raises(ValueError, match="at least one"):
        gather_subset_samples(chunk=[])
    with pytest.raises(ValueError, match="not both"):
        gather_subset_samples(snaps[0], chunk=snaps[:2])

    # combine_stream over windows ≡ combine_gathered on the full stack
    chunks = [gather_subset_samples(chunk=snaps[i : i + 4]) for i in (0, 4, 8)]
    full = jnp.concatenate(chunks, axis=1)
    got = combine_stream(jax.random.PRNGKey(1), chunks, 32, combiner="parametric")
    want = combine_gathered(jax.random.PRNGKey(1), full, 32, combiner="parametric")
    assert bool(jnp.all(got.samples == want.samples))
    with pytest.raises(ValueError, match="at least one chunk"):
        combine_stream(jax.random.PRNGKey(1), [], 8)
    with pytest.raises(ValueError, match="chunks"):
        combine_stream(jax.random.PRNGKey(1), [full[0]], 8)
