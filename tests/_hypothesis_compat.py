"""Hypothesis import shim: the real package when installed, skip-stubs otherwise.

Several test modules mix ordinary tests with hypothesis property tests. When
the optional ``hypothesis`` dev-dependency is missing, importing it at module
level would abort collection of the *whole* file; importing from this shim
instead keeps the ordinary tests running and marks each ``@given`` test as
skipped.
"""

import pytest

try:
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    from hypothesis import given

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AbsentStrategies:
        """Absorbs strategy constructors evaluated inside @given(...) calls."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = hnp = _AbsentStrategies()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")
