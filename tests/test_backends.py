"""repro.api.backends: BackendId strings, backend resolution, caching.

The BackendId spellings are load-bearing — ``Scoreboard.backend`` strings
are pinned by tests across the repo (``"vmap[resumable]"``,
``"shard_map(4 devices)"``, …) and this module is their single
constructor. Everything here runs single-device; the mesh backend's
*execution* is covered by tests/test_mesh_stream.py under a forced
multi-device subprocess.
"""

import jax
import pytest

from repro.api import BackendId, get_chunk_backend
from repro.api.backends import CHUNKED, FUSED, RESUMABLE, VmapChunkBackend
from repro.core.subposterior import partition_data
from repro.models.bayes import get_model


# ---------------------------------------------------------------------------
# BackendId — the exact strings, historical ones included
# ---------------------------------------------------------------------------


def test_backend_id_vmap_spellings():
    assert BackendId.vmap() == "vmap"
    assert BackendId.vmap(CHUNKED) == "vmap[chunked]"
    assert BackendId.vmap(FUSED) == "vmap[fused]"
    assert BackendId.vmap(RESUMABLE) == "vmap[resumable]"


def test_backend_id_mesh_spellings():
    # the one-shot spelling predates the backend layer — load-bearing
    assert BackendId.mesh(4) == "shard_map(4 devices)"
    assert BackendId.mesh(4, CHUNKED) == "shard_map[chunked](4 devices)"
    assert BackendId.mesh(2, FUSED) == "shard_map[fused](2 devices)"
    assert BackendId.mesh(2, RESUMABLE) == "shard_map[resumable](2 devices)"


def test_backend_id_fanout_and_distributed_spellings():
    assert BackendId.mesh_fanout(4) == "shard_map[fanout](4 devices)"
    assert BackendId.distributed(2) == "jax.distributed(2 processes)"
    assert BackendId.distributed(1) == "jax.distributed(1 processes)"


def test_backend_id_rejects_unknown_modes():
    with pytest.raises(ValueError, match="unknown backend mode"):
        BackendId.vmap("oneshot")
    with pytest.raises(ValueError, match="unknown backend mode"):
        BackendId.mesh(4, "streamed")


# ---------------------------------------------------------------------------
# get_chunk_backend — resolution + caching
# ---------------------------------------------------------------------------


def _stage_inputs(M=4, n=256):
    model = get_model("poisson")
    data, _ = model.generate_data(jax.random.PRNGKey(0), n)
    shards, counts = partition_data(data, M, only=model.shard_keys, pad=True)
    return model, shards, counts


def test_resolves_vmap_backend_and_caches_by_statics():
    model, shards, _ = _stage_inputs()
    kw = dict(warmup=0, burn_in=5, step_size=0.1, sgld_batch=256,
              sampler_options=(), use_counts=True)
    b1 = get_chunk_backend(model, 4, "gibbs", shards=shards, **kw)
    b2 = get_chunk_backend(model, 4, "gibbs", shards=shards, **kw)
    assert isinstance(b1, VmapChunkBackend)
    assert b1 is b2  # same statics -> same cached backend (no re-trace)
    assert b1.backend_id(CHUNKED) == "vmap[chunked]"
    assert b1.collectives_checked is None  # nothing to assert off the mesh
    b3 = get_chunk_backend(model, 4, "gibbs", shards=shards,
                           **{**kw, "burn_in": 6})
    assert b3 is not b1  # any compile-relevant static forks the cache


def test_mesh_shape_of_one_is_the_vmap_backend():
    # a degenerate (1, 1) mesh would be pure overhead — normalize to vmap
    model, shards, _ = _stage_inputs()
    b = get_chunk_backend(
        model, 4, "gibbs", warmup=0, burn_in=5, step_size=0.1,
        sgld_batch=256, sampler_options=(), use_counts=True,
        shards=shards, mesh_shape=(1, 1),
    )
    assert isinstance(b, VmapChunkBackend)


def test_mesh_backend_without_devices_raises_actionably():
    model, shards, _ = _stage_inputs()
    if jax.device_count() >= 4:
        pytest.skip("host exposes enough devices; error path unreachable")
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        get_chunk_backend(
            model, 4, "gibbs", warmup=0, burn_in=5, step_size=0.1,
            sgld_batch=256, sampler_options=(), use_counts=True,
            shards=shards, mesh_shape=(4, 1),
        )


# ---------------------------------------------------------------------------
# the drivers actually report BackendId strings
# ---------------------------------------------------------------------------


def test_pipeline_reports_backend_id_strings(tmp_path):
    from repro.api import Pipeline, RunSpec

    spec = RunSpec(model="poisson", sampler="gibbs", combiner="parametric",
                   M=4, T=40, warmup=0, n=256, groundtruth_T=80,
                   stream_every=20)
    board = Pipeline(spec).run()
    assert board.backend == BackendId.vmap(FUSED)  # streamed + fusable

    board2 = Pipeline(spec, checkpoint_dir=tmp_path).run()
    assert board2.backend == BackendId.vmap(RESUMABLE)
