"""CI perf-regression gate (benchmarks.gate) logic tests — no jax needed."""

from __future__ import annotations

import json

import pytest

gate = pytest.importorskip("benchmarks.gate")


def _snap(ts, **metrics):
    """A snapshot with stream/M=4 timing rows: _snap("t1", stream_total=0.5)."""
    return {
        "timestamp": ts,
        "rows": [
            {"bench": "stream", "case": "M=4", "metric": k, "value": v, "units": "s"}
            for k, v in metrics.items()
        ],
    }


def test_regression_beyond_threshold_fails():
    history = [_snap(f"t{i}", stream_total=0.5) for i in range(3)]
    bad = _snap("t9", stream_total=0.7)  # +40% vs median 0.5
    verdicts = gate.evaluate(bad, history, threshold=0.25)
    assert [v.failed for v in verdicts] == [True]
    assert verdicts[0].baseline == pytest.approx(0.5)


def test_within_threshold_passes():
    history = [_snap(f"t{i}", stream_total=0.5) for i in range(3)]
    ok = _snap("t9", stream_total=0.6)  # +20% < 25%
    assert not any(v.failed for v in gate.evaluate(ok, history, threshold=0.25))


def test_median_absorbs_one_noisy_baseline_run():
    history = [
        _snap("t0", stream_total=0.5),
        _snap("t1", stream_total=5.0),  # one bad CI box
        _snap("t2", stream_total=0.5),
    ]
    verdicts = gate.evaluate(_snap("t9", stream_total=0.55), history)
    assert verdicts[0].baseline == pytest.approx(0.5)
    assert not verdicts[0].failed


def test_new_metric_passes_vacuously():
    history = [_snap("t0", stream_total=0.5)]
    cand = _snap("t9", stream_total=0.5, stream_total_fused=0.2)
    verdicts = {v.key[2]: v for v in gate.evaluate(cand, history)}
    assert verdicts["stream_total_fused"].baseline is None
    assert not verdicts["stream_total_fused"].failed


def test_only_stream_and_combine_second_rows_gate():
    cand = {
        "rows": [
            {"bench": "stream", "case": "M=4", "metric": "fused_speedup",
             "value": 9.0, "units": "x"},  # ratio row: not gated
            {"bench": "kernels", "case": "d=8", "metric": "t", "value": 9.0,
             "units": "s"},  # non-gated bench
            {"bench": "combine", "case": "M=4", "metric": "t_parametric",
             "value": 0.1, "units": "s"},
        ]
    }
    assert set(gate.gated_rows(cand)) == {("combine", "M=4", "t_parametric")}


def test_noise_floor_rows_never_fail():
    history = [_snap(f"t{i}", tiny=0.001) for i in range(3)]
    verdicts = gate.evaluate(_snap("t9", tiny=0.01), history, min_seconds=0.03)
    assert not verdicts[0].failed  # 10x slower but under the noise floor


def test_fast_row_within_abs_slack_never_fails():
    # 60 ms → 100 ms is +67% relative but only 40 ms absolute: scheduler
    # jitter on a sub-100 ms row, not a regression.
    history = [_snap(f"t{i}", t_fast=0.06) for i in range(3)]
    verdicts = gate.evaluate(_snap("t9", t_fast=0.10), history, abs_slack=0.075)
    assert not verdicts[0].failed


def test_fast_row_beyond_abs_slack_fails():
    # 60 ms → 200 ms clears both the relative threshold and the 75 ms slack.
    history = [_snap(f"t{i}", t_fast=0.06) for i in range(3)]
    verdicts = gate.evaluate(_snap("t9", t_fast=0.20), history, abs_slack=0.075)
    assert verdicts[0].failed


def test_abs_slack_does_not_shield_slow_rows():
    # on a 10 s row the slack is negligible: the relative threshold decides.
    history = [_snap(f"t{i}", t_slow=10.0) for i in range(3)]
    verdicts = gate.evaluate(_snap("t9", t_slow=13.0), history, abs_slack=0.075)
    assert verdicts[0].failed


def test_cli_abs_slack_flag(tmp_path):
    for i in range(3):
        (tmp_path / f"BENCH_2026010{i}_000000.json").write_text(
            json.dumps(_snap(f"t{i}", t_fast=0.06))
        )
    (tmp_path / "BENCH_20260109_000000.json").write_text(
        json.dumps(_snap("t9", t_fast=0.10))
    )
    assert gate.main(["--perf-dir", str(tmp_path)]) == 0  # default 75 ms slack
    assert gate.main(["--perf-dir", str(tmp_path), "--abs-slack", "0.0"]) == 1


def test_cli_end_to_end(tmp_path, capsys):
    for i, v in enumerate((0.5, 0.52, 0.48)):
        (tmp_path / f"BENCH_2026010{i}_000000.json").write_text(
            json.dumps(_snap(f"t{i}", stream_total=v))
        )
    (tmp_path / "BENCH_20260109_000000.json").write_text(
        json.dumps(_snap("t9", stream_total=0.51))
    )
    assert gate.main(["--perf-dir", str(tmp_path)]) == 0
    assert "passed" in capsys.readouterr().out

    (tmp_path / "BENCH_20260110_000000.json").write_text(
        json.dumps(_snap("t10", stream_total=2.0))
    )
    assert gate.main(["--perf-dir", str(tmp_path)]) == 1
    assert "FAIL" in capsys.readouterr().out


def test_cli_explicit_candidate_excluded_from_own_baseline(tmp_path):
    for i, v in enumerate((0.5, 0.5, 0.5)):
        (tmp_path / f"BENCH_2026010{i}_000000.json").write_text(
            json.dumps(_snap(f"t{i}", stream_total=v))
        )
    cand = tmp_path / "BENCH_20260109_000000.json"
    cand.write_text(json.dumps(_snap("t9", stream_total=0.9)))
    assert gate.main(["--perf-dir", str(tmp_path), "--candidate", str(cand)]) == 1


def test_cli_empty_dir_is_a_pass(tmp_path):
    assert gate.main(["--perf-dir", str(tmp_path)]) == 0
