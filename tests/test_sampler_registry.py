"""Sampler-registry conformance: every registered sampler on one 2-d target.

The registry's promise (criterion 3: any sampler per machine) is only real if
every entry honours the uniform contract — this suite drives each canonical
sampler against a known 2-d Gaussian posterior and checks:

- ``accept_prob`` ∈ [0, 1] at every step,
- fixed-seed determinism (bitwise-identical reruns),
- post-warmup acceptance inside the spec's target band (adaptive samplers),
- first/second moments within tolerance of the analytic posterior.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.samplers import (
    available_samplers,
    canonical_samplers,
    filter_options,
    get_sampler,
    mh_within_gibbs_update,
    run_chain,
    sampler_spec,
)

MEAN = jnp.array([1.0, -2.0])
STD = jnp.array([0.8, 1.4])


def logpdf(theta):
    return -0.5 * jnp.sum(((theta - MEAN) / STD) ** 2)


def _gibbs_blocks(step_size=1.2):
    """Per-coordinate MH-within-Gibbs blocks for the 2-d Gaussian target."""
    blocks = []
    for i in (0, 1):
        blocks.append(
            mh_within_gibbs_update(
                logpdf,
                select=lambda pos, i=i: pos[i],
                replace=lambda pos, block, i=i: pos.at[i].set(block),
                step_size=step_size,
            )
        )
    return blocks


def _build(name):
    """Kernel + per-sampler options for the shared conformance target."""
    factory = get_sampler(name)
    options = {
        "rwmh": dict(step_size=0.8),
        "mala": dict(step_size=0.35),
        "hmc": dict(step_size=0.25, num_integration_steps=8),
        "gibbs": dict(block_updates=_gibbs_blocks()),
        "sgld": dict(step_size=0.05),
    }[name]
    return factory(logpdf, **filter_options(factory, options))


def test_registry_contains_the_paper_surface():
    assert {"rwmh", "mala", "hmc", "gibbs", "sgld"} <= set(canonical_samplers())
    assert set(canonical_samplers()) <= set(available_samplers())
    with pytest.raises(KeyError, match="available"):
        sampler_spec("nope")


@pytest.mark.parametrize("name", sorted(canonical_samplers()))
def test_conformance_moments_probabilities_determinism(name):
    kern = _build(name)
    run = jax.jit(
        lambda k: run_chain(k, kern, jnp.zeros(2), 6000, burn_in=1500)
    )
    pos, info = run(jax.random.PRNGKey(0))

    # accept_prob is a probability at every step
    assert float(info.accept_prob.min()) >= 0.0
    assert float(info.accept_prob.max()) <= 1.0
    assert bool(jnp.all(jnp.isfinite(pos)))

    # analytic posterior moments (MCSE-sized tolerances; SGLD adds a small
    # discretization bias at ε=0.05)
    np.testing.assert_allclose(pos.mean(0), MEAN, atol=0.25)
    np.testing.assert_allclose(pos.std(0), STD, atol=0.3)

    # fixed-seed determinism: an identical rerun is bitwise identical
    pos2, _ = run(jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(pos), np.asarray(pos2))


@pytest.mark.parametrize(
    "name",
    [n for n in sorted(canonical_samplers()) if sampler_spec(n).adaptive],
)
def test_warmup_reaches_target_acceptance_band(name):
    """Dual-averaging warmup must land post-warmup acceptance near the spec's
    target from a deliberately terrible initial step size."""
    spec = sampler_spec(name)
    factory = functools.partial(
        lambda eps, f=spec.factory: f(logpdf, step_size=eps)
    )
    _, info = jax.jit(
        lambda k: run_chain(
            k,
            factory,
            jnp.zeros(2),
            2000,
            burn_in=200,
            warmup=600,
            initial_step_size=5.0,  # ~0 acceptance if left unadapted
            target_accept=spec.target_accept,
        )
    )(jax.random.PRNGKey(1))
    acc = float(info.accept_prob.mean())
    assert abs(acc - spec.target_accept) < 0.15, (name, acc, spec.target_accept)


def test_warmup_requires_a_factory():
    kern = _build("rwmh")
    with pytest.raises(TypeError, match="factory"):
        run_chain(jax.random.PRNGKey(0), kern, jnp.zeros(2), 10, warmup=5)


def test_gibbs_requires_block_updates():
    with pytest.raises(ValueError, match="block_updates"):
        get_sampler("gibbs")(logpdf)


def test_factory_filter_options_drops_unknown_keys():
    """One broadcast option dict must be safe for every registered factory."""
    broadcast = dict(step_size=0.5, num_integration_steps=4, not_an_option=1)
    for name in canonical_samplers():
        factory = get_sampler(name)
        opts = filter_options(factory, broadcast)
        assert "not_an_option" not in opts
        if name == "gibbs":
            opts["block_updates"] = _gibbs_blocks()
        kern = factory(logpdf, **opts)
        state = kern.init(jnp.zeros(2))
        _state, info = kern.step(jax.random.PRNGKey(0), state)
        assert jnp.isfinite(info.accept_prob)
