"""Gradient compression + elastic-chain end-to-end restart."""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed import epmcmc
from repro.models.lm.config import reduced
from repro.optim.compression import (
    compress_lowrank,
    decompress_lowrank,
    error_feedback_update,
    init_error_feedback,
)


def test_lowrank_exact_on_lowrank_matrix():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (40, 6))
    b = jax.random.normal(jax.random.fold_in(key, 1), (6, 30))
    g = a @ b  # exactly rank 6
    pair, resid = compress_lowrank(jax.random.fold_in(key, 2), g, rank=6)
    np.testing.assert_allclose(decompress_lowrank(pair, g.shape), g, rtol=1e-3, atol=1e-3)
    assert float(jnp.max(jnp.abs(resid))) < 1e-3


def test_error_feedback_preserves_signal_over_steps():
    """Error feedback's actual guarantee: the *accumulated* transmitted
    signal tracks Σ_t g_t far better than compress-and-forget, because the
    residual is retried every step instead of being lost."""
    key = jax.random.PRNGKey(1)
    g = {"w": jax.random.normal(key, (64, 64)), "b": jnp.ones((64,))}
    T = 12

    def run(with_ef: bool):
        err = init_error_feedback(g)
        total = jax.tree.map(jnp.zeros_like, g)
        for t in range(T):
            sent, new_err = error_feedback_update(
                jax.random.fold_in(key, t), g, err, rank=4
            )
            if with_ef:
                err = new_err
            total = jax.tree.map(jnp.add, total, sent)
        return float(
            jnp.linalg.norm(total["w"] - T * g["w"]) / jnp.linalg.norm(T * g["w"])
        ), total

    rel_ef, total_ef = run(True)
    rel_nef, _ = run(False)
    assert rel_ef < 0.75 * rel_nef, (rel_ef, rel_nef)  # EF strictly recovers signal
    assert rel_ef < 0.9  # and the long-run bias is bounded below "lost it all"
    np.testing.assert_allclose(total_ef["b"], T * g["b"], rtol=1e-5)  # passthrough


def test_compression_ratio():
    g = jnp.ones((256, 512))
    pair, _ = compress_lowrank(jax.random.PRNGKey(0), g, rank=8)
    moved = pair.p.size + pair.q.size
    assert moved < 0.06 * g.size  # r(n+m) ≪ n·m


def test_elastic_restart_end_to_end(tmp_path):
    """Train 3 chains → checkpoint → restore as 5 chains → keep stepping.
    The surviving chains' streaming moments must be preserved exactly."""
    from repro.checkpoint import Checkpointer, restore_elastic_chains

    cfg = reduced(get_config("mamba2_130m"), num_layers=2, d_model=64, vocab_size=128)
    step = jax.jit(functools.partial(
        epmcmc.epmcmc_step, cfg=cfg, num_shards=3, shard_tokens=1e4,
        step_size=1e-4, burn_in=1,
    ))

    def batch(key, c, s):
        toks = jax.random.randint(jax.random.fold_in(key, s), (c, 2, 16), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": jnp.roll(toks, -1, -1)}

    key = jax.random.PRNGKey(0)
    state = epmcmc.init_state(key, cfg, 3)
    for s in range(4):
        state, _ = step(state, batch(key, 3, s))
    ck = Checkpointer(tmp_path, async_io=False)
    ck.save(4, state, metadata={"num_chains": 3, "train_step": 4})
    ck.close()

    template5 = epmcmc.init_state(jax.random.PRNGKey(9), cfg, 5)
    state5, meta = restore_elastic_chains(tmp_path, template5, 5)
    assert meta["num_chains"] == 5 and meta["elastic_from"] == 3
    # surviving chains' moments preserved bit-exactly
    m_old = jax.tree.leaves(state.m_mean)[0]
    m_new = jax.tree.leaves(state5.m_mean)[0]
    np.testing.assert_array_equal(np.asarray(m_new[:3]), np.asarray(m_old))
    # and the widened ensemble can keep stepping with the new 1/M
    step5 = jax.jit(functools.partial(
        epmcmc.epmcmc_step, cfg=cfg, num_shards=5, shard_tokens=1e4,
        step_size=1e-4, burn_in=0,
    ))
    state5, metrics = step5(state5, batch(key, 5, 99))
    assert metrics["loss_per_chain"].shape == (5,)
    assert bool(jnp.all(jnp.isfinite(metrics["loss_per_chain"])))
