"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.img_weights import img_log_weights, img_log_weights_ref
from repro.kernels.kde_density import kde_log_density, kde_log_density_ref
from repro.kernels.logreg_loglik import logreg_loglik_grad, logreg_loglik_grad_ref


@pytest.mark.parametrize("P,M,d", [(300, 10, 50), (256, 4, 512), (100, 20, 7), (64, 2, 1), (65, 3, 130)])
@pytest.mark.parametrize("h", [0.3, 1.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_img_weights_matches_ref(P, M, d, h, dtype):
    theta = jax.random.normal(jax.random.PRNGKey(P + d), (P, M, d), dtype)
    got = img_log_weights(theta, h)
    want = img_log_weights_ref(theta, h)
    rtol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=rtol, atol=5e-3)
    assert got.dtype == jnp.float32


def test_img_weights_matches_algorithm1_oracle():
    """The kernel's weight must equal combine.log_weight_bruteforce (Eq 3.5)."""
    from repro.core.combine import log_weight_bruteforce

    theta = jax.random.normal(jax.random.PRNGKey(0), (128, 8, 5))
    h = jnp.asarray(0.7)
    got = img_log_weights(theta, h)
    want = jax.vmap(lambda t: log_weight_bruteforce(t, h))(theta)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("N,d", [(5000, 50), (1024, 54), (100, 3), (1025, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_logreg_kernel_matches_ref(N, d, dtype):
    k = jax.random.PRNGKey(N + d)
    kx, kb, ky = jax.random.split(k, 3)
    X = jax.random.normal(kx, (N, d), dtype)
    beta = (jax.random.normal(kb, (d,)) * 0.3).astype(dtype)
    y = jnp.where(jax.random.uniform(ky, (N,)) < 0.5, 1.0, -1.0)
    l, g = logreg_loglik_grad(X, y, beta, scale=1.7)
    lr, gr = logreg_loglik_grad_ref(X, y, beta, scale=1.7)
    rtol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(l, lr, rtol=rtol)
    np.testing.assert_allclose(g, gr, rtol=max(rtol, 1e-4), atol=0.3 if dtype == jnp.bfloat16 else 1e-3)


def test_logreg_kernel_multichain():
    k = jax.random.PRNGKey(0)
    X = jax.random.normal(k, (2048, 20))
    y = jnp.where(jax.random.uniform(jax.random.fold_in(k, 1), (2048,)) < 0.5, 1.0, -1.0)
    B = jax.random.normal(jax.random.fold_in(k, 2), (20, 5)) * 0.2
    ls, gs = logreg_loglik_grad(X, y, B)
    for c in range(5):
        lc, gc = logreg_loglik_grad_ref(X, y, B[:, c])
        np.testing.assert_allclose(ls[c], lc, rtol=1e-5)
        np.testing.assert_allclose(gs[:, c], gc, rtol=1e-4, atol=1e-3)


def test_logreg_kernel_grad_is_true_gradient():
    """∇ from the fused kernel == autodiff of the likelihood."""
    k = jax.random.PRNGKey(3)
    X = jax.random.normal(k, (512, 9))
    y = jnp.where(jax.random.uniform(jax.random.fold_in(k, 1), (512,)) < 0.5, 1.0, -1.0)
    beta = jax.random.normal(jax.random.fold_in(k, 2), (9,)) * 0.5
    _, g = logreg_loglik_grad(X, y, beta)
    g_ad = jax.grad(lambda b: logreg_loglik_grad_ref(X, y, b)[0])(beta)
    np.testing.assert_allclose(g, g_ad, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("nq,ns,d", [(300, 700, 10), (256, 512, 2), (100, 999, 54), (64, 64, 1)])
@pytest.mark.parametrize("h", [0.2, 1.0, 3.0])
def test_kde_density_matches_ref(nq, ns, d, h):
    k = jax.random.PRNGKey(nq * ns)
    q = jax.random.normal(k, (nq, d))
    s = jax.random.normal(jax.random.fold_in(k, 1), (ns, d))
    got = kde_log_density(q, s, h)
    want = kde_log_density_ref(q, s, h)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_kde_density_matches_metrics_kde():
    """Kernel and the metrics-module KDE must agree (two independent paths)."""
    from repro.core.metrics import kde_logpdf

    k = jax.random.PRNGKey(7)
    q = jax.random.normal(k, (128, 6))
    s = jax.random.normal(jax.random.fold_in(k, 1), (400, 6))
    np.testing.assert_allclose(
        kde_log_density(q, s, 0.8), kde_logpdf(q, s, 0.8), rtol=1e-5, atol=1e-4
    )


def test_kde_density_is_normalized_density():
    """∫ p̂ ≈ 1 sanity via Monte Carlo over a wide box (d=1)."""
    s = jax.random.normal(jax.random.PRNGKey(0), (500, 1))
    grid = jnp.linspace(-8, 8, 2001)[:, None]
    logp = kde_log_density(grid, s, 0.5)
    integral = jnp.trapezoid(jnp.exp(logp), grid[:, 0])
    assert abs(float(integral) - 1.0) < 1e-2
